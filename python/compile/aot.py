"""AOT pipeline: lower every L2 graph to HLO *text* under artifacts/.

HLO text (not ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Also writes ``artifacts/manifest.json`` — the single source of truth the
rust runtime reads for artifact paths, input/output signatures, geometry
constants and parameter initialization shapes — and
``artifacts/testvec.json`` with exact cross-language test vectors for the
d2r / morph / Aug-Conv algebra (weights are dyadic rationals so both
languages reproduce them bit-exactly in f32).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import geometry as G
from . import model as M
from .kernels import ref
from .kernels.morph import morph_apply


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned, 32-bit)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _sig(avals):
    out = []
    for a in avals:
        out.append({"shape": list(a.shape), "dtype": str(a.dtype)})
    return out


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries = {}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, specs, meta=None):
        """Lower fn at the given ShapeDtypeStructs and write HLO text."""
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, path), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *specs)
        flat, _ = jax.tree_util.tree_flatten(outs)
        self.entries[name] = {
            "path": path,
            "inputs": _sig(specs),
            "outputs": _sig(flat),
            **(meta or {}),
        }
        print(f"  emitted {name}: {len(text)} chars, "
              f"{len(specs)} inputs -> {len(flat)} outputs")


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


# ---------------------------------------------------------------------------
# Artifact set
# ---------------------------------------------------------------------------

def emit_all(out_dir: str):
    em = Emitter(out_dir)
    g = G.SMALL

    # ---- morphing (provider hot path), both geometries -------------------
    for geo, qs, bs in ((G.SMALL, G.MORPH_QS_SMALL, (8, G.TRAIN_BATCH)),
                        (G.CIFAR, G.MORPH_QS_CIFAR, (8,))):
        for q in qs:
            for b in bs:
                name = f"morph_apply_{geo.name}_q{q}_b{b}"
                em.emit(
                    name,
                    lambda d, mp: (morph_apply(d, mp),),
                    [f32(b, geo.d_len), f32(q, q)],
                    meta={"kind": "morph", "geometry": geo.name,
                          "q": q, "kappa": geo.d_len // q, "batch": b},
                )

    # ---- Aug-Conv forward (serving / equivalence checks) -----------------
    for b in (G.EQ_BATCH, 32):
        em.emit(
            f"augconv_forward_{g.name}_b{b}",
            lambda t, cac, b1: (jnp.reshape(
                ref.matmul_ref(t, cac), (t.shape[0], g.beta, g.n, g.n))
                + b1[None, :, None, None],),
            [f32(b, g.d_len), f32(g.d_len, g.f_len), f32(g.beta)],
            meta={"kind": "augconv_forward", "batch": b},
        )

    # ---- parameter shape table -------------------------------------------
    shapes = M.base_param_shapes(g)
    base_shapes = [{"name": nm, "shape": list(sh), "init": ini, "fan_in": f}
                   for nm, sh, ini, f in shapes]
    aug_shapes = base_shapes[2:]  # conv1 (w1, b1) replaced by fixed C^ac/b1p

    nb, na = len(base_shapes), len(aug_shapes)

    # ---- inference -------------------------------------------------------
    for b in G.INFER_BATCHES:
        em.emit(
            f"infer_base_{g.name}_b{b}",
            lambda *a: (M.forward_base(M.BaseParams(*a[:nb]), a[nb]),),
            [f32(*s["shape"]) for s in base_shapes] + [f32(b, g.alpha, g.m, g.m)],
            meta={"kind": "infer_base", "batch": b, "n_params": nb},
        )
        em.emit(
            f"infer_aug_{g.name}_b{b}",
            lambda *a: (M.forward_aug(
                a[0], a[1], M.AugParams(*a[2 : 2 + na]), a[2 + na], g),),
            [f32(g.d_len, g.f_len), f32(g.beta)]
            + [f32(*s["shape"]) for s in aug_shapes] + [f32(b, g.d_len)],
            meta={"kind": "infer_aug", "batch": b, "n_params": na},
        )

    # ---- evaluation (loss, acc on a labelled batch) -----------------------
    bt = G.TRAIN_BATCH
    em.emit(
        f"eval_base_{g.name}_b{bt}",
        lambda *a: M.eval_base(M.BaseParams(*a[:nb]), a[nb], a[nb + 1]),
        [f32(*s["shape"]) for s in base_shapes]
        + [f32(bt, g.alpha, g.m, g.m), i32(bt)],
        meta={"kind": "eval_base", "batch": bt, "n_params": nb},
    )
    em.emit(
        f"eval_aug_{g.name}_b{bt}",
        lambda *a: M.eval_aug(a[0], a[1], M.AugParams(*a[2 : 2 + na]),
                              a[2 + na], a[3 + na], g),
        [f32(g.d_len, g.f_len), f32(g.beta)]
        + [f32(*s["shape"]) for s in aug_shapes] + [f32(bt, g.d_len), i32(bt)],
        meta={"kind": "eval_aug", "batch": bt, "n_params": na},
    )

    # ---- training steps ----------------------------------------------------
    def ts_base(*a):
        p = M.BaseParams(*a[:nb])
        v = M.BaseParams(*a[nb : 2 * nb])
        x, y, lr = a[2 * nb], a[2 * nb + 1], a[2 * nb + 2]
        np_, nm_, loss, acc = M.train_step_base(p, v, x, y, lr)
        return (*np_, *nm_, loss, acc)

    em.emit(
        f"train_step_base_{g.name}_b{bt}",
        ts_base,
        [f32(*s["shape"]) for s in base_shapes] * 2
        + [f32(bt, g.alpha, g.m, g.m), i32(bt), f32()],
        meta={"kind": "train_step_base", "batch": bt, "n_params": nb},
    )

    def ts_aug(*a):
        cac, b1p = a[0], a[1]
        p = M.AugParams(*a[2 : 2 + na])
        v = M.AugParams(*a[2 + na : 2 + 2 * na])
        t, y, lr = a[2 + 2 * na], a[3 + 2 * na], a[4 + 2 * na]
        np_, nm_, loss, acc = M.train_step_aug(cac, b1p, p, v, t, y, lr, g)
        return (*np_, *nm_, loss, acc)

    em.emit(
        f"train_step_aug_{g.name}_b{bt}",
        ts_aug,
        [f32(g.d_len, g.f_len), f32(g.beta)]
        + [f32(*s["shape"]) for s in aug_shapes] * 2
        + [f32(bt, g.d_len), i32(bt), f32()],
        meta={"kind": "train_step_aug", "batch": bt, "n_params": na},
    )

    # ---- manifest ----------------------------------------------------------
    manifest = {
        "version": 1,
        "geometries": {
            geo.name: {
                "alpha": geo.alpha, "m": geo.m, "n": geo.n, "p": geo.p,
                "beta": geo.beta, "d_len": geo.d_len, "f_len": geo.f_len,
                "kappa_mc": geo.kappa_mc,
            } for geo in (G.SMALL, G.CIFAR)
        },
        "train_batch": G.TRAIN_BATCH,
        "infer_batches": list(G.INFER_BATCHES),
        "eq_batch": G.EQ_BATCH,
        "num_classes": G.NUM_CLASSES,
        "momentum": M.MOMENTUM,
        "base_params": base_shapes,
        "aug_params": aug_shapes,
        "artifacts": em.entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote manifest.json ({len(em.entries)} artifacts)")
    return em


# ---------------------------------------------------------------------------
# Cross-language test vectors
# ---------------------------------------------------------------------------

def emit_testvec(out_dir: str):
    """Exact d2r/morph/Aug-Conv vectors both languages must reproduce.

    All inputs are dyadic rationals (k/256) so f32 arithmetic is exact for
    the assignments and small dot products involved; the conv outputs and
    checksums are computed with the numpy oracle."""
    g = G.SMALL
    rng = np.random.default_rng(20190506)  # the paper's date

    def dyadic(shape, lo=-64, hi=64):
        return (rng.integers(lo, hi, size=shape).astype(np.float32)) / 256.0

    x = dyadic((2, g.alpha, g.m, g.m))
    w1 = dyadic((g.beta, g.alpha, g.p, g.p))
    b1 = dyadic((g.beta,))
    conv = ref.conv2d_same_ref(x, w1, b1)
    c_mat = ref.build_c_matrix(w1, g.m)
    d_r = ref.d2r_unroll(x)
    f_r = d_r @ c_mat + np.tile(b1[:, None], (1, g.n * g.n)).reshape(-1)

    # morph core at q=48 (kappa=16), exactly-invertible integer-ish core is
    # not required here: we store M' and record T^r computed by the oracle.
    q = 48
    m_prime = dyadic((q, q))
    # keep it well-conditioned: add 2*I
    m_prime += 2.0 * np.eye(q, dtype=np.float32)
    t_r = np.asarray(ref.morph_ref(jnp.asarray(d_r), jnp.asarray(m_prime)))

    perm = rng.permutation(g.beta)
    c_sha = hashlib.sha256(np.ascontiguousarray(c_mat).tobytes()).hexdigest()

    vec = {
        "geometry": g.name,
        "x": x.tolist(), "w1": w1.tolist(), "b1": b1.tolist(),
        "conv_out": conv.tolist(),
        "c_matrix_sha256": c_sha,
        "c_matrix_shape": list(c_mat.shape),
        "d_r": d_r.tolist(),
        "f_r_first64": f_r[0, :64].tolist(),
        "q": q,
        "m_prime": m_prime.tolist(),
        "t_r": t_r.tolist(),
        "perm": perm.tolist(),
    }
    with open(os.path.join(out_dir, "testvec.json"), "w") as f:
        json.dump(vec, f)
    print("  wrote testvec.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-testvec", action="store_true")
    args = ap.parse_args()
    print(f"AOT lowering to {args.out_dir} (jax {jax.__version__})")
    emit_all(args.out_dir)
    if not args.skip_testvec:
        emit_testvec(args.out_dir)
    print("AOT done.")


if __name__ == "__main__":
    main()
