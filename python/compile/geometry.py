"""Shared geometry for the MoLe reproduction.

The paper's first-conv-layer attributes (§3): input m x m with alpha
channels, output n x n with beta channels, kernel p x p, SAME zero padding
(eq. 1 uses input row = c + a - 1, i.e. offset -1 for p = 3), so n = m.

Two configurations are used throughout the repo:

* ``SMALL``  — the trainable end-to-end configuration (16x16x3 inputs,
  VGG-small).  All train/infer artifacts are lowered at this geometry so a
  single CPU core can run the paper's §4.4 three-group experiment in
  minutes.
* ``CIFAR``  — the paper's analysis geometry (32x32x3, VGG-16 first layer
  beta = 64).  Used for the overhead/security numbers and the morph-kernel
  benchmark artifacts; identical formulas, bigger shapes.

Rust reads the same numbers from ``artifacts/manifest.json`` so the two
languages can never drift.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class FirstLayerGeometry:
    """Geometry of the replaceable first convolutional layer."""

    name: str
    alpha: int  # input channels
    m: int      # input spatial size (m x m)
    beta: int   # output channels of the first layer
    p: int      # kernel size (p x p), SAME padding

    @property
    def n(self) -> int:
        """Output spatial size; SAME padding => n == m."""
        return self.m

    @property
    def d_len(self) -> int:
        """Length of the d2r-unrolled data row vector D^r (= alpha * m^2)."""
        return self.alpha * self.m * self.m

    @property
    def f_len(self) -> int:
        """Length of the unrolled feature row vector F^r (= beta * n^2)."""
        return self.beta * self.n * self.n

    @property
    def kappa_mc(self) -> int:
        """Largest morphing scale factor for the minimal-cost setting
        (eq. 13): kappa_mc = alpha * m^2 / n^2."""
        return (self.alpha * self.m * self.m) // (self.n * self.n)

    def q_for_kappa(self, kappa: int) -> int:
        """Morphing core size q = alpha*m^2 / kappa (eq. 3); kappa must
        divide alpha*m^2 exactly."""
        if self.d_len % kappa != 0:
            raise ValueError(f"kappa={kappa} does not divide alpha*m^2={self.d_len}")
        return self.d_len // kappa


SMALL = FirstLayerGeometry(name="small", alpha=3, m=16, beta=16, p=3)
CIFAR = FirstLayerGeometry(name="cifar", alpha=3, m=32, beta=64, p=3)

# Batch sizes baked into the AOT artifacts (PJRT executables are
# shape-specialised; the rust batcher pads to the nearest available size).
TRAIN_BATCH = 64
INFER_BATCHES = (1, 8, 32)
EQ_BATCH = 8

# Morph core sizes (q) for which morph_apply artifacts are emitted, per
# geometry.  kappa = d_len / q.
MORPH_QS_SMALL = (48, 256, 768)     # kappa = 16, 3 (=kappa_mc), 1 (=MS)
MORPH_QS_CIFAR = (96, 1024, 3072)   # kappa = 32, 3 (=kappa_mc), 1 (=MS)

# VGG-small stack on top of the first layer (SMALL geometry):
#   conv1: alpha -> beta (replaceable)         16x16x16
#   conv2: beta  -> 16, 3x3 SAME, relu, pool   -> 8x8x16
#   conv3: 16    -> 32, 3x3 SAME, relu, pool   -> 4x4x32
#   fc1:   512   -> 64, relu
#   fc2:   64    -> num_classes
VGG_SMALL_C2 = 16
VGG_SMALL_C3 = 32
VGG_SMALL_FC1 = 64
NUM_CLASSES = 10
