"""L2: the developer's VGG-small network in JAX, with a replaceable first
layer (paper §3.3), plus its training step.  Built-time only: everything
here is lowered by aot.py to HLO text and executed from rust via PJRT.

Three variants reproduce the paper's §4.4 experiment groups:

* ``base``  — the original network (trainable conv1) on original images.
* ``aug``   — conv1 replaced by the Aug-Conv layer (a *fixed* d2r matmul
  with C^ac, paper eq. 5), trained on *morphed* rows T^r.  The Aug-Conv
  features are wrapped in stop_gradient: the paper trains it "as a fixed
  feature extractor similarly to pre-trained layers in transfer learning".
* ``noaug`` — the sanity-check group: the original network fed morphed
  data *without* the Aug-Conv layer.  Structurally identical to ``base``
  (the rust driver simply feeds morphed images), so it reuses the base
  artifacts.

All tensors are NCHW / OIHW, matching the paper's d2r unroll order
(channel-major, then rows, then columns — fig. 2).
"""

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from . import geometry as G
from .kernels.d2r_matmul import aug_conv_forward


class BaseParams(NamedTuple):
    """Trainable parameters of the full VGG-small network (10 arrays)."""

    w1: jnp.ndarray  # [beta, alpha, p, p]
    b1: jnp.ndarray  # [beta]
    w2: jnp.ndarray  # [c2, beta, 3, 3]
    b2: jnp.ndarray  # [c2]
    w3: jnp.ndarray  # [c3, c2, 3, 3]
    b3: jnp.ndarray  # [c3]
    wf1: jnp.ndarray  # [c3*(m/4)^2, fc1]
    bf1: jnp.ndarray  # [fc1]
    wf2: jnp.ndarray  # [fc1, classes]
    bf2: jnp.ndarray  # [classes]


class AugParams(NamedTuple):
    """Trainable parameters when conv1 is replaced by Aug-Conv (8 arrays).

    C^ac and the (channel-permuted) first-layer bias are *fixed inputs*,
    not parameters — see train_step_aug."""

    w2: jnp.ndarray
    b2: jnp.ndarray
    w3: jnp.ndarray
    b3: jnp.ndarray
    wf1: jnp.ndarray
    bf1: jnp.ndarray
    wf2: jnp.ndarray
    bf2: jnp.ndarray


def base_param_shapes(g: G.FirstLayerGeometry, classes: int = G.NUM_CLASSES):
    """Shape/initializer table, consumed by aot.py for the manifest and by
    the rust side (via manifest.json) for He initialization."""
    c2, c3, f1 = G.VGG_SMALL_C2, G.VGG_SMALL_C3, G.VGG_SMALL_FC1
    flat = c3 * (g.m // 4) * (g.m // 4)
    return [
        ("w1", (g.beta, g.alpha, g.p, g.p), "he", g.alpha * g.p * g.p),
        ("b1", (g.beta,), "zero", 0),
        ("w2", (c2, g.beta, 3, 3), "he", g.beta * 9),
        ("b2", (c2,), "zero", 0),
        ("w3", (c3, c2, 3, 3), "he", c2 * 9),
        ("b3", (c3,), "zero", 0),
        ("wf1", (flat, f1), "he", flat),
        ("bf1", (f1,), "zero", 0),
        ("wf2", (f1, classes), "he", f1),
        ("bf2", (classes,), "zero", 0),
    ]


def _conv(x, w, b):
    """SAME-padded 3x3 cross-correlation, NCHW/OIHW."""
    y = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return y + b[None, :, None, None]


def _maxpool2(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")


def _trunk(f, p, *, start):
    """Everything above the first layer.  ``f`` is the first-layer
    pre-activation feature map [B, beta, m, m]; ``p`` supplies the
    remaining weights starting at field index ``start``."""
    h = jax.nn.relu(f)
    h = jax.nn.relu(_conv(h, p[start], p[start + 1]))
    h = _maxpool2(h)
    h = jax.nn.relu(_conv(h, p[start + 2], p[start + 3]))
    h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p[start + 4] + p[start + 5])
    return h @ p[start + 6] + p[start + 7]


def forward_base(params: BaseParams, x: jnp.ndarray) -> jnp.ndarray:
    """Original network on images [B, alpha, m, m] -> logits."""
    f = _conv(x, params.w1, params.b1)
    return _trunk(f, params, start=2)


def forward_aug(c_ac: jnp.ndarray, b1p: jnp.ndarray, params: AugParams,
                t_r: jnp.ndarray, g: G.FirstLayerGeometry,
                interpret: bool = True) -> jnp.ndarray:
    """Aug-Conv network on morphed rows [B, alpha*m^2] -> logits.

    The first layer is the L1 Pallas GEMM (fixed feature extractor)."""
    f = aug_conv_forward(t_r, c_ac, b1p, g.beta, g.n, interpret=interpret)
    f = lax.stop_gradient(f)
    return _trunk(f, params, start=0)


def loss_and_acc(logits: jnp.ndarray, y: jnp.ndarray):
    """Mean softmax cross-entropy (integer labels) and top-1 accuracy."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return nll, acc


MOMENTUM = 0.9


def _sgd(params, grads, momenta, lr):
    new_m = jax.tree_util.tree_map(lambda v, dg: MOMENTUM * v + dg, momenta, grads)
    new_p = jax.tree_util.tree_map(lambda w, v: w - lr * v, params, new_m)
    return new_p, new_m


def train_step_base(params: BaseParams, momenta: BaseParams, x, y, lr):
    """One SGD+momentum step on the full network.  Returns
    (new_params..., new_momenta..., loss, acc) — flattened by aot.py."""

    def obj(p):
        logits = forward_base(p, x)
        return loss_and_acc(logits, y)

    (loss, acc), grads = jax.value_and_grad(obj, has_aux=True)(params)
    new_p, new_m = _sgd(params, grads, momenta, lr)
    return new_p, new_m, loss, acc


def train_step_aug(c_ac, b1p, params: AugParams, momenta: AugParams, t_r, y,
                   lr, g: G.FirstLayerGeometry):
    """One SGD+momentum step with the fixed Aug-Conv first layer."""

    def obj(p):
        logits = forward_aug(c_ac, b1p, p, t_r, g)
        return loss_and_acc(logits, y)

    (loss, acc), grads = jax.value_and_grad(obj, has_aux=True)(params)
    new_p, new_m = _sgd(params, grads, momenta, lr)
    return new_p, new_m, loss, acc


def eval_base(params: BaseParams, x, y):
    return loss_and_acc(forward_base(params, x), y)


def eval_aug(c_ac, b1p, params: AugParams, t_r, y, g: G.FirstLayerGeometry):
    return loss_and_acc(forward_aug(c_ac, b1p, params, t_r, g), y)
