"""L1 Pallas kernel: the Aug-Conv forward GEMM F^r = T^r . C^ac (§3.3).

After d2r the first convolutional layer *is* a single fat matmul
[B, alpha*m^2] x [alpha*m^2, beta*n^2].  This kernel tiles it (bm, bk, bn)
for VMEM with an f32 accumulator revisited across the k-grid — the
HBM<->VMEM schedule a CUDA implementation would express with threadblocks
is expressed here with BlockSpec index maps (DESIGN.md §4).

interpret=True for CPU-PJRT; on a real TPU the same BlockSpecs target the
MXU with ~(bm*bk + bk*bn + bm*bn) * 4 bytes of VMEM per program.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    """Grid (nm, nn, nk); k is the innermost (fastest varying) axis so the
    output tile stays resident while partial products accumulate."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pick_tile(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is <= want (keeps the grid exact)."""
    t = min(dim, want)
    while dim % t != 0:
        t -= 1
    return t


# Per-program working-set budget in f32 elements, the perf-pass tuning
# knob: each grid step costs a dynamic-slice round trip in the
# interpret/CPU lowering, so fewer/bigger tiles win on this backend
# (16 MiB working set -> grid of 1-2 programs; 3-6x over the original
# 0.5 MiB/48-program schedule, see EXPERIMENTS.md §Perf L1). A real-TPU
# deployment would set this to ~2M elements (8 MiB of bf16 tile pairs
# inside 16 MiB VMEM with double buffering) — the BlockSpec schedule is
# unchanged, only the budget constant.
_VMEM_BUDGET_F32 = 4 * 1024 * 1024


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def tiled_matmul(x: jnp.ndarray, w: jnp.ndarray, bm: int = 0, bk: int = 0,
                 bn: int = 0, interpret: bool = True) -> jnp.ndarray:
    """[B, K] @ [K, N] -> [B, N] in f32 with explicit VMEM tiling.

    Default tile policy (perf-pass result): take the whole batch and the
    whole K dimension per program (bm = B ≤ 128, bk = K ≤ 1024) and derive
    bn from the VMEM budget. For the Aug-Conv GEMM ([64, 768] × [768,
    4096]) this yields grid = (1, 2, 1) instead of the original
    (1, 16, 3) = 48 programs — a 13× wall-clock win at identical numerics
    (EXPERIMENTS.md §Perf).
    """
    b, k = x.shape
    k2, n = w.shape
    assert k == k2, (k, k2)
    bm = bm or _pick_tile(b, 128)
    bk = bk or _pick_tile(k, 1024)
    if not bn:
        budget = max(_VMEM_BUDGET_F32 - bm * bk, bk)
        bn = _pick_tile(n, max(budget // (bk + bm), 1))
    grid = (b // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(x, w)


def aug_conv_forward(t_r: jnp.ndarray, c_ac: jnp.ndarray, bias: jnp.ndarray,
                     beta: int, n: int, interpret: bool = True) -> jnp.ndarray:
    """Full Aug-Conv layer: F^r = T^r . C^ac, re-rolled to NCHW feature maps
    [B, beta, n, n] with the (channel-shuffled) bias added.

    ``bias`` must already be permuted with the same rand() order that was
    applied to C^ac's column groups (the rust provider does this when it
    builds the layer)."""
    f_r = tiled_matmul(t_r, c_ac, interpret=interpret)
    f = f_r.reshape(t_r.shape[0], beta, n, n)
    return f + bias[None, :, None, None]
