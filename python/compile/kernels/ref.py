"""Pure-jnp / numpy oracles for the Pallas kernels and the d2r algebra.

Everything in this file is the *specification*: the Pallas kernels
(morph.py, d2r_matmul.py), the L2 model graphs, and the rust-side
implementations are all tested against these functions.
"""

import numpy as np
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Data morphing (paper §3.2, eq. 2-4)
# ---------------------------------------------------------------------------

def morph_ref(d_r: jnp.ndarray, m_prime: jnp.ndarray) -> jnp.ndarray:
    """T^r = D^r . M where M = diag(M', M', ..., M') (eq. 4).

    d_r: [B, kappa*q] unrolled data rows; m_prime: [q, q] morphing core.
    Exploits the block-diagonal structure: reshape to [B, kappa, q] and
    multiply each block by the shared core.
    """
    b, dl = d_r.shape
    q = m_prime.shape[0]
    assert dl % q == 0, (dl, q)
    kappa = dl // q
    blocks = d_r.reshape(b, kappa, q)
    out = jnp.einsum("bkq,qr->bkr", blocks, m_prime)
    return out.reshape(b, dl)


def unmorph_ref(t_r: jnp.ndarray, m_prime_inv: jnp.ndarray) -> jnp.ndarray:
    """D^r = T^r . M^{-1}; M^{-1} is block-diagonal with core M'^{-1}."""
    return morph_ref(t_r, m_prime_inv)


# ---------------------------------------------------------------------------
# d2r (paper §3.1, eq. 1)
# ---------------------------------------------------------------------------

def d2r_unroll(x: np.ndarray) -> np.ndarray:
    """Unroll images [B, alpha, m, m] (NCHW) to row vectors [B, alpha*m^2].

    Paper fig. 2: rows of each channel concatenated left-to-right, channels
    concatenated by increasing index — exactly C-order flatten of NCHW.
    """
    b = x.shape[0]
    return x.reshape(b, -1)


def d2r_roll_features(f_r: np.ndarray, beta: int, n: int) -> np.ndarray:
    """Re-roll feature rows [B, beta*n^2] to feature maps [B, beta, n, n]."""
    b = f_r.shape[0]
    return f_r.reshape(b, beta, n, n)


def build_c_matrix(w: np.ndarray, m: int) -> np.ndarray:
    """Build the d2r convolution matrix C (eq. 1) for SAME zero padding.

    w: [beta, alpha, p, p] kernel (out-channel, in-channel, krow, kcol).
    Returns C with shape [alpha*m^2, beta*n^2], n = m, such that
    D^r @ C == unrolled conv output.

    Eq. 1 (zero-based):   col x = n^2 j + n c + d
                          row y = m^2 i + m (c + a - off) + (d + b - off)
    with off = (p-1)//2 (the paper writes the p = 3 case, off = 1), and the
    assignment skipped whenever the input coordinate falls outside [0, m).
    """
    beta, alpha, p, _ = w.shape
    n = m
    off = (p - 1) // 2
    c_mat = np.zeros((alpha * m * m, beta * n * n), dtype=w.dtype)
    for j in range(beta):
        for i in range(alpha):
            for c in range(n):
                for d in range(n):
                    x = n * n * j + n * c + d
                    for a in range(p):
                        rr = c + a - off
                        if rr < 0 or rr >= m:
                            continue
                        for b_ in range(p):
                            cc = d + b_ - off
                            if cc < 0 or cc >= m:
                                continue
                            y = m * m * i + m * rr + cc
                            c_mat[y, x] = w[j, i, a, b_]
    return c_mat


def conv2d_same_ref(x: np.ndarray, w: np.ndarray, b: np.ndarray | None = None) -> np.ndarray:
    """Direct SAME-padded cross-correlation, NCHW.  The ground truth that
    both the C matrix (above) and the jax lax.conv in model.py must match.

    x: [B, alpha, m, m]; w: [beta, alpha, p, p]; b: [beta] or None.
    """
    bs, alpha, m, _ = x.shape
    beta, _, p, _ = w.shape
    off = (p - 1) // 2
    xp = np.zeros((bs, alpha, m + 2 * off, m + 2 * off), dtype=x.dtype)
    xp[:, :, off : off + m, off : off + m] = x
    out = np.zeros((bs, beta, m, m), dtype=np.promote_types(x.dtype, w.dtype))
    for a in range(p):
        for c in range(p):
            patch = xp[:, :, a : a + m, c : c + m]
            out += np.einsum("bimn,ji->bjmn", patch, w[:, :, a, c])
    if b is not None:
        out = out + b[None, :, None, None]
    return out


# ---------------------------------------------------------------------------
# Aug-Conv layer (paper §3.3)
# ---------------------------------------------------------------------------

def build_aug_conv_ref(c_mat: np.ndarray, m_prime_inv: np.ndarray,
                       perm: np.ndarray, n: int) -> np.ndarray:
    """C^ac = M^{-1} . C with feature channel randomization.

    M^{-1} is block diagonal with core m_prime_inv, so M^{-1} . C is done
    block-row-wise.  The rand() step shuffles the beta groups of n^2
    contiguous *columns* according to ``perm`` (group g of the output takes
    original group perm[g]).
    """
    dl = c_mat.shape[0]
    q = m_prime_inv.shape[0]
    kappa = dl // q
    out = np.empty_like(c_mat)
    for k in range(kappa):
        out[k * q : (k + 1) * q, :] = m_prime_inv @ c_mat[k * q : (k + 1) * q, :]
    beta = len(perm)
    shuffled = np.empty_like(out)
    for g in range(beta):
        shuffled[:, g * n * n : (g + 1) * n * n] = \
            out[:, perm[g] * n * n : (perm[g] + 1) * n * n]
    return shuffled


# ---------------------------------------------------------------------------
# Tiled matmul oracle (for the d2r_matmul Pallas kernel)
# ---------------------------------------------------------------------------

def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain [B, K] @ [K, N] in f32."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)
