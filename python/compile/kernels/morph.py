"""L1 Pallas kernel: data morphing T^r = D^r . M (paper §3.2, eq. 2-4).

M is block-diagonal (eq. 4): kappa copies of the q x q core M' on the
diagonal.  The paper's "multiplication with zero element is omitted"
optimization (eq. 16) is expressed here as a *schedule*, not sparse
arithmetic: the grid iterates over the kappa diagonal blocks and each
program multiplies one [B, q] slice of D^r by the single shared M' tile.

TPU mapping (see DESIGN.md §4): block i of D^r and M' live in VMEM; the
MXU sees dense q x q GEMMs; HBM traffic for M' is amortized across the
grid because its index_map is constant.  Lowered with interpret=True for
CPU-PJRT execution (Mosaic custom-calls cannot run on the CPU plugin).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _morph_block_kernel(d_ref, m_ref, o_ref):
    """One diagonal block: o[B, q] = d[B, q] @ m'[q, q]."""
    o_ref[...] = jnp.dot(
        d_ref[...], m_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def morph_apply(d_r: jnp.ndarray, m_prime: jnp.ndarray,
                interpret: bool = True) -> jnp.ndarray:
    """Morph a batch of unrolled data rows.

    d_r: [B, kappa*q] f32, m_prime: [q, q] f32 -> [B, kappa*q] f32.
    """
    b, dl = d_r.shape
    q = m_prime.shape[0]
    if dl % q != 0:
        raise ValueError(f"d2r length {dl} not divisible by core size {q}")
    kappa = dl // q
    return pl.pallas_call(
        _morph_block_kernel,
        grid=(kappa,),
        in_specs=[
            # i-th [B, q] slice of the unrolled rows.
            pl.BlockSpec((b, q), lambda i: (0, i)),
            # The *same* M' core for every block (eq. 4).
            pl.BlockSpec((q, q), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, q), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, dl), jnp.float32),
        interpret=interpret,
    )(d_r, m_prime)


def unmorph_apply(t_r: jnp.ndarray, m_prime_inv: jnp.ndarray,
                  interpret: bool = True) -> jnp.ndarray:
    """Inverse morphing D^r = T^r . M^{-1}; M^{-1} shares the block
    structure of M with core M'^{-1}, so it is the same kernel."""
    return morph_apply(t_r, m_prime_inv, interpret=interpret)
