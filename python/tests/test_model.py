"""L2 model correctness: shapes, the three-group equivalence (paper §4.4 at
toy scale), and train-step sanity (loss decreases, Aug-Conv layer stays
fixed)."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import geometry as G
from compile import model as M
from compile.kernels import ref

g = G.SMALL


def init_params(rng) -> M.BaseParams:
    vals = []
    for name, shape, kind, fan in M.base_param_shapes(g):
        if kind == "zero":
            vals.append(np.zeros(shape, np.float32))
        else:
            std = np.sqrt(2.0 / fan)
            vals.append((rng.standard_normal(shape) * std).astype(np.float32))
    return M.BaseParams(*[jnp.asarray(v) for v in vals])


def make_augconv(rng, w1, b1, q=48):
    mp = (rng.standard_normal((q, q)).astype(np.float32)
          + 4.0 * np.eye(q, dtype=np.float32))
    mpi = np.linalg.inv(mp.astype(np.float64)).astype(np.float32)
    perm = rng.permutation(g.beta)
    c = ref.build_c_matrix(np.asarray(w1), g.m)
    cac = ref.build_aug_conv_ref(c, mpi, perm, g.n)
    b1p = np.asarray(b1)[perm]
    return mp, cac, b1p, perm


def test_forward_base_shape():
    rng = np.random.default_rng(0)
    p = init_params(rng)
    x = jnp.asarray(rng.standard_normal((4, g.alpha, g.m, g.m)), jnp.float32)
    logits = M.forward_base(p, x)
    assert logits.shape == (4, G.NUM_CLASSES)
    assert np.isfinite(np.asarray(logits)).all()


def test_aug_equals_base_up_to_permutation():
    """With C^ac built from the base w1, forward_aug(morph(x)) must equal a
    base network whose conv1 channels were permuted — and since the trunk
    weights are channel-symmetric only when permuted consistently, we
    check at the *feature* level instead, then at the logit level using a
    trunk that consumes permuted channels."""
    rng = np.random.default_rng(1)
    p = init_params(rng)
    mp, cac, b1p, perm = make_augconv(rng, p.w1, p.b1)
    x = rng.standard_normal((4, g.alpha, g.m, g.m)).astype(np.float32)
    d_r = x.reshape(4, -1)
    t_r = np.asarray(ref.morph_ref(jnp.asarray(d_r), jnp.asarray(mp)))

    f_aug = np.asarray(ref.matmul_ref(
        jnp.asarray(t_r), jnp.asarray(cac))).reshape(4, g.beta, g.n, g.n) \
        + b1p[None, :, None, None]
    f_base = ref.conv2d_same_ref(x, np.asarray(p.w1), np.asarray(p.b1))
    np.testing.assert_allclose(f_aug, f_base[:, perm], rtol=5e-3, atol=5e-3)

    # Logit-level: permute conv2's input channels to match.
    aug_p = M.AugParams(p.w2[:, perm], p.b2, p.w3, p.b3, p.wf1, p.bf1,
                        p.wf2, p.bf2)
    logits_aug = M.forward_aug(jnp.asarray(cac), jnp.asarray(b1p), aug_p,
                               jnp.asarray(t_r), g)
    logits_base = M.forward_base(p, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(logits_aug),
                               np.asarray(logits_base), rtol=2e-2, atol=2e-2)


def test_train_step_base_decreases_loss():
    rng = np.random.default_rng(2)
    p = init_params(rng)
    v = jax.tree_util.tree_map(jnp.zeros_like, p)
    x = jnp.asarray(rng.standard_normal((G.TRAIN_BATCH, g.alpha, g.m, g.m)),
                    jnp.float32)
    y = jnp.asarray(rng.integers(0, G.NUM_CLASSES, G.TRAIN_BATCH), jnp.int32)
    losses = []
    for _ in range(12):
        p, v, loss, acc = M.train_step_base(p, v, x, y, jnp.float32(0.05))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses


def test_train_step_aug_decreases_loss_and_keeps_cac_fixed():
    rng = np.random.default_rng(3)
    p = init_params(rng)
    mp, cac, b1p, _ = make_augconv(rng, p.w1, p.b1)
    aug_p = M.AugParams(p.w2, p.b2, p.w3, p.b3, p.wf1, p.bf1, p.wf2, p.bf2)
    v = jax.tree_util.tree_map(jnp.zeros_like, aug_p)
    d = rng.standard_normal((G.TRAIN_BATCH, g.d_len)).astype(np.float32)
    t = ref.morph_ref(jnp.asarray(d), jnp.asarray(mp))
    y = jnp.asarray(rng.integers(0, G.NUM_CLASSES, G.TRAIN_BATCH), jnp.int32)
    cac_j = jnp.asarray(cac)
    losses = []
    for _ in range(12):
        aug_p, v, loss, acc = M.train_step_aug(
            cac_j, jnp.asarray(b1p), aug_p, v, t, y, jnp.float32(0.05), g)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses
    # C^ac is an input, not a parameter: by construction it cannot change;
    # check the step is numerically finite end-to-end instead.
    assert all(np.isfinite(l) for l in losses)


def test_eval_matches_forward():
    rng = np.random.default_rng(4)
    p = init_params(rng)
    x = jnp.asarray(rng.standard_normal((G.TRAIN_BATCH, g.alpha, g.m, g.m)),
                    jnp.float32)
    y = jnp.asarray(rng.integers(0, G.NUM_CLASSES, G.TRAIN_BATCH), jnp.int32)
    loss, acc = M.eval_base(p, x, y)
    logits = M.forward_base(p, x)
    want_acc = float((jnp.argmax(logits, -1) == y).mean())
    assert abs(float(acc) - want_acc) < 1e-6
    assert 0.0 <= float(acc) <= 1.0
