"""AOT pipeline tests: the emitted artifacts + manifest must be mutually
consistent and loadable. Runs against the artifacts/ directory produced by
`make artifacts` (skips cleanly if it has not been built yet)."""

import json
import os

import pytest

from compile import geometry as G

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_geometries(manifest):
    s = manifest["geometries"]["small"]
    assert s["alpha"] == G.SMALL.alpha
    assert s["d_len"] == G.SMALL.d_len == 768
    assert s["kappa_mc"] == G.SMALL.kappa_mc == 3
    c = manifest["geometries"]["cifar"]
    assert c["d_len"] == 3072 and c["f_len"] == 65536


def test_all_artifact_files_exist(manifest):
    for name, entry in manifest["artifacts"].items():
        path = os.path.join(ART, entry["path"])
        assert os.path.exists(path), f"{name}: missing {entry['path']}"
        with open(path) as f:
            head = f.read(512)
        # HLO text modules start with the module header
        assert "HloModule" in head, f"{name}: not HLO text"


def test_train_step_signature(manifest):
    e = manifest["artifacts"][f"train_step_aug_small_b{G.TRAIN_BATCH}"]
    # cac, b1p, 8 params, 8 momenta, t_r, y, lr
    assert len(e["inputs"]) == 2 + 8 + 8 + 3
    assert e["inputs"][0]["shape"] == [G.SMALL.d_len, G.SMALL.f_len]
    assert e["inputs"][-1]["shape"] == []  # lr scalar
    assert e["inputs"][-2]["dtype"] == "int32"  # labels
    # outputs: 8 params + 8 momenta + loss + acc
    assert len(e["outputs"]) == 18


def test_param_tables(manifest):
    base = manifest["base_params"]
    aug = manifest["aug_params"]
    assert [p["name"] for p in base][:2] == ["w1", "b1"]
    assert [p["name"] for p in aug] == [p["name"] for p in base[2:]]
    # he layers carry their fan-in
    for p in base:
        if p["init"] == "he":
            assert p["fan_in"] > 0


def test_morph_artifacts_cover_all_qs(manifest):
    for q in G.MORPH_QS_SMALL:
        assert f"morph_apply_small_q{q}_b{G.TRAIN_BATCH}" in manifest["artifacts"]
    for q in G.MORPH_QS_CIFAR:
        assert f"morph_apply_cifar_q{q}_b8" in manifest["artifacts"]


def test_testvec_consistency():
    with open(os.path.join(ART, "testvec.json")) as f:
        vec = json.load(f)
    import numpy as np

    from compile.kernels import ref

    x = np.asarray(vec["x"], np.float32)
    w1 = np.asarray(vec["w1"], np.float32)
    b1 = np.asarray(vec["b1"], np.float32)
    conv = ref.conv2d_same_ref(x, w1, b1)
    np.testing.assert_allclose(conv, np.asarray(vec["conv_out"], np.float32),
                               rtol=1e-5, atol=1e-5)
    import hashlib

    c = ref.build_c_matrix(w1, x.shape[-1])
    sha = hashlib.sha256(np.ascontiguousarray(c).tobytes()).hexdigest()
    assert sha == vec["c_matrix_sha256"]
