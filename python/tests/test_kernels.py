"""L1 kernel correctness: Pallas kernels vs the pure-jnp/numpy oracles.

hypothesis sweeps shapes; every property here is an invariant the rust
side also relies on (same algebra, same layouts).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.morph import morph_apply, unmorph_apply
from compile.kernels.d2r_matmul import tiled_matmul, aug_conv_forward
from compile import geometry as G


def rnd(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# morph kernel
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 8),
    q=st.sampled_from([2, 4, 8, 16, 48]),
    kappa=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_morph_kernel_matches_ref(b, q, kappa, seed):
    rng = np.random.default_rng(seed)
    d = rnd(rng, b, kappa * q)
    mp = rnd(rng, q, q)
    got = np.asarray(morph_apply(jnp.asarray(d), jnp.asarray(mp)))
    want = np.asarray(ref.morph_ref(jnp.asarray(d), jnp.asarray(mp)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_morph_kernel_is_blockwise():
    """Changing block k of D^r must only change block k of T^r."""
    rng = np.random.default_rng(0)
    q, kappa, b = 8, 4, 3
    mp = rnd(rng, q, q)
    d0 = rnd(rng, b, kappa * q)
    d1 = d0.copy()
    d1[:, q : 2 * q] += 1.0
    t0 = np.asarray(morph_apply(jnp.asarray(d0), jnp.asarray(mp)))
    t1 = np.asarray(morph_apply(jnp.asarray(d1), jnp.asarray(mp)))
    diff = np.abs(t1 - t0)
    assert diff[:, q : 2 * q].max() > 0
    mask = np.ones(kappa * q, bool)
    mask[q : 2 * q] = False
    np.testing.assert_allclose(diff[:, mask], 0.0, atol=0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_morph_roundtrip(seed):
    """unmorph(morph(D)) == D for a well-conditioned core."""
    rng = np.random.default_rng(seed)
    q, kappa, b = 16, 3, 4
    mp = rnd(rng, q, q) + 4.0 * np.eye(q, dtype=np.float32)
    mpi = np.linalg.inv(mp.astype(np.float64)).astype(np.float32)
    d = rnd(rng, b, kappa * q)
    t = morph_apply(jnp.asarray(d), jnp.asarray(mp))
    back = np.asarray(unmorph_apply(t, jnp.asarray(mpi)))
    np.testing.assert_allclose(back, d, rtol=1e-3, atol=1e-3)


def test_morph_full_vs_blockdiag():
    """Block-diag kernel == dense D^r @ M with M per eq. 4."""
    rng = np.random.default_rng(7)
    q, kappa, b = 6, 5, 2
    mp = rnd(rng, q, q)
    d = rnd(rng, b, kappa * q)
    m_full = np.zeros((kappa * q, kappa * q), np.float32)
    for k in range(kappa):
        m_full[k * q : (k + 1) * q, k * q : (k + 1) * q] = mp
    want = d @ m_full
    got = np.asarray(morph_apply(jnp.asarray(d), jnp.asarray(mp)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# tiled matmul kernel
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2, 8]),
    k=st.sampled_from([16, 48, 96]),
    n=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_tiled_matmul_matches_ref(b, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = rnd(rng, b, k), rnd(rng, k, n)
    got = np.asarray(tiled_matmul(jnp.asarray(x), jnp.asarray(w)))
    want = np.asarray(ref.matmul_ref(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bk,bn", [(2, 8, 16), (4, 16, 8), (8, 48, 64)])
def test_tiled_matmul_tile_shapes(bm, bk, bn):
    """Result is tile-shape independent."""
    rng = np.random.default_rng(3)
    x, w = rnd(rng, 8, 48), rnd(rng, 48, 64)
    got = np.asarray(tiled_matmul(jnp.asarray(x), jnp.asarray(w),
                                  bm=bm, bk=bk, bn=bn))
    np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# d2r algebra (the oracle itself, against direct convolution)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([4, 6, 8]),
    alpha=st.integers(1, 3),
    beta=st.integers(1, 4),
    p=st.sampled_from([1, 3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_c_matrix_equals_direct_conv(m, alpha, beta, p, seed):
    """D^r @ C == unroll(conv(D))  (paper eq. 1 / fig. 3)."""
    rng = np.random.default_rng(seed)
    x = rnd(rng, 2, alpha, m, m)
    w = rnd(rng, beta, alpha, p, p)
    want = ref.conv2d_same_ref(x, w).reshape(2, -1)
    c = ref.build_c_matrix(w, m)
    got = ref.d2r_unroll(x) @ c
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_c_matrix_sparsity():
    """Each column of C has at most p^2*alpha non-zeros (kernel support)."""
    rng = np.random.default_rng(1)
    w = rnd(rng, 2, 3, 3, 3)
    c = ref.build_c_matrix(w, 6)
    nz = (c != 0).sum(axis=0)
    assert nz.max() <= 3 * 9
    # interior output pixels see the full support
    assert nz.max() == 3 * 9


# ---------------------------------------------------------------------------
# Aug-Conv layer algebra
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_aug_conv_equivalence(seed):
    """Paper eq. 5: T^r . C^ac == shuffle(D^r . C) — equivalent features up
    to the channel permutation."""
    rng = np.random.default_rng(seed)
    m, alpha, beta, p = 6, 2, 4, 3
    q, kappa = 24, (alpha * m * m) // 24
    x = rnd(rng, 2, alpha, m, m)
    w = rnd(rng, beta, alpha, p, p)
    mp = rnd(rng, q, q) + 4.0 * np.eye(q, dtype=np.float32)
    mpi = np.linalg.inv(mp.astype(np.float64)).astype(np.float32)
    perm = np.random.default_rng(seed + 1).permutation(beta)

    c = ref.build_c_matrix(w, m)
    cac = ref.build_aug_conv_ref(c, mpi, perm, m)
    d_r = ref.d2r_unroll(x)
    t_r = np.asarray(ref.morph_ref(jnp.asarray(d_r), jnp.asarray(mp)))

    f_plain = (d_r @ c).reshape(2, beta, m, m)
    f_aug = (t_r @ cac).reshape(2, beta, m, m)
    np.testing.assert_allclose(f_aug, f_plain[:, perm], rtol=1e-2, atol=1e-2)


def test_aug_conv_forward_kernel_bias():
    """The Pallas aug_conv_forward adds the permuted bias per channel."""
    g = G.SMALL
    rng = np.random.default_rng(5)
    t = rnd(rng, 2, g.d_len)
    cac = rnd(rng, g.d_len, g.f_len) * 0.01
    bias = rnd(rng, g.beta)
    got = np.asarray(aug_conv_forward(
        jnp.asarray(t), jnp.asarray(cac), jnp.asarray(bias), g.beta, g.n))
    want = (t @ cac).reshape(2, g.beta, g.n, g.n) + bias[None, :, None, None]
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
