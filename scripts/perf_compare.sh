#!/usr/bin/env bash
# Compare two mole-bench-v1 JSON files and print a delta table.
#
# Rows are joined on (name, backend, geometry). Timed rows compare
# mean_us (negative delta = faster); serving rows compare throughput_rps
# (positive delta = faster). Rows present in only one file are listed so
# a bench rename never silently drops coverage.
#
# Usage: scripts/perf_compare.sh BASELINE.json CURRENT.json
set -euo pipefail

if [ $# -ne 2 ]; then
    echo "usage: $0 BASELINE.json CURRENT.json" >&2
    exit 2
fi

exec python3 - "$1" "$2" <<'PYEOF'
import json
import sys

base_path, cur_path = sys.argv[1], sys.argv[2]


def load(path):
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if doc.get("schema") != "mole-bench-v1":
        sys.exit(f"{path}: not a mole-bench-v1 file")
    rows = {}
    for row in doc["results"]:
        key = (row["name"], row["backend"], row.get("geometry", ""))
        rows[key] = row
    return doc, rows


base_doc, base = load(base_path)
cur_doc, cur = load(cur_path)

print(f"baseline: {base_path} (cpu {base_doc['cpu']['arch']}/"
      f"{base_doc['cpu']['features']}, {base_doc['threads']} threads)")
print(f"current:  {cur_path} (cpu {cur_doc['cpu']['arch']}/"
      f"{cur_doc['cpu']['features']}, {cur_doc['threads']} threads)")
print()
hdr = f"{'bench':<18} {'backend':<14} {'geometry':<22} {'base':>12} {'cur':>12} {'delta':>8}"
print(hdr)
print("-" * len(hdr))

for key in sorted(set(base) & set(cur)):
    b, c = base[key], cur[key]
    name, backend, geom = key
    if "mean_us" in b and "mean_us" in c:
        bv, cv, unit = b["mean_us"], c["mean_us"], "us"
        delta = (cv - bv) / bv * 100 if bv else float("nan")
    elif "throughput_rps" in b and "throughput_rps" in c:
        bv, cv, unit = b["throughput_rps"], c["throughput_rps"], "rps"
        delta = (cv - bv) / bv * 100 if bv else float("nan")
    else:
        continue
    print(f"{name:<18} {backend:<14} {geom:<22} "
          f"{bv:>10.1f}{unit:>2} {cv:>10.1f}{unit:>2} {delta:>+7.1f}%")

for key in sorted(set(base) - set(cur)):
    print(f"{key[0]:<18} {key[1]:<14} {key[2]:<22} {'(baseline only)':>36}")
for key in sorted(set(cur) - set(base)):
    print(f"{key[0]:<18} {key[1]:<14} {key[2]:<22} {'(current only)':>36}")
PYEOF
