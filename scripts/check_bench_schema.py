#!/usr/bin/env python3
"""Validate BENCH_*.json files against their declared schema.

Stdlib-only (the CI bench-smoke job runs it on the artifacts the bench
binaries just wrote). Checks required keys AND value types, so a refactor
that silently drops a percentile or stringifies a number fails CI rather
than producing un-diffable baselines.

Two schemas are known, dispatched on the document's "schema" key:
* mole-bench-v1    — timed results (percentile rows; BENCH_hotpath.json,
                     BENCH_serving.json, ...)
* mole-overhead-v1 — transmission-overhead rows (raw/delivered byte
                     counts + overhead percentages; BENCH_overhead.json)

Usage: check_bench_schema.py BENCH_hotpath.json [BENCH_overhead.json ...]
"""
import json
import numbers
import sys


def fail(path, msg):
    print(f"{path}: SCHEMA ERROR: {msg}", file=sys.stderr)
    sys.exit(1)


def want(path, cond, msg):
    if not cond:
        fail(path, msg)


def is_num(v):
    return isinstance(v, numbers.Real) and not isinstance(v, bool)


def is_int(v):
    return isinstance(v, int) and not isinstance(v, bool)


# mole-bench-v1 row keys that must be numeric when present
OPTIONAL_NUM = [
    "mean_us",
    "gflops",
    "throughput_rps",
    "speedup_vs_ref",
    "speedup_vs_unbatched",
    "mean_batch",
    # open-loop loadgen rows (serving bench): coordinated-omission-
    # corrected percentiles, the configured arrival rate, and the count
    # of typed Overloaded sheds absorbed by retries (0 for closed loop)
    "corrected_p50_us",
    "corrected_p95_us",
    "corrected_p99_us",
    "offered_rps",
    "shed",
    "connect_shed",
]
OPTIONAL_INT = ["trials", "connections"]

# corrected percentiles travel as a set: a row reporting one must report
# all three (a partial set means the bench refactor dropped a field)
CORRECTED_SET = ("corrected_p50_us", "corrected_p95_us", "corrected_p99_us")


def check_envelope(path, doc, schema_id):
    """The shared envelope both schemas carry: bench/threads/cpu/results."""
    want(path, isinstance(doc, dict), "top level must be an object")
    want(path, doc.get("schema") == schema_id,
         f"schema must be {schema_id!r}, got {doc.get('schema')!r}")
    want(path, isinstance(doc.get("bench"), str) and doc["bench"],
         "bench must be a non-empty string")
    want(path, is_int(doc.get("threads")) and doc["threads"] >= 1,
         "threads must be an int >= 1")

    cpu = doc.get("cpu")
    want(path, isinstance(cpu, dict), "cpu must be an object")
    want(path, isinstance(cpu.get("arch"), str) and cpu["arch"],
         "cpu.arch must be a non-empty string")
    want(path, is_int(cpu.get("cores")) and cpu["cores"] >= 1,
         "cpu.cores must be an int >= 1")
    want(path, isinstance(cpu.get("features"), str) and cpu["features"],
         "cpu.features must be a non-empty string")

    results = doc.get("results")
    want(path, isinstance(results, list) and results,
         "results must be a non-empty array")
    for i, row in enumerate(results):
        want(path, isinstance(row, dict), f"results[{i}] must be an object")
        want(path, isinstance(row.get("name"), str) and row["name"],
             f"results[{i}].name must be a non-empty string")
        if "geometry" in row:
            want(path, isinstance(row["geometry"], str) and row["geometry"],
                 f"results[{i}].geometry must be a non-empty string")
    return results


def check_bench_row(path, where, row):
    want(path, isinstance(row.get("backend"), str) and row["backend"],
         f"{where}.backend must be a non-empty string")
    for key in ("p50_us", "p95_us", "p99_us"):
        want(path, is_num(row.get(key)) and row[key] >= 0,
             f"{where}.{key} must be a number >= 0 (got {row.get(key)!r})")
    for key in OPTIONAL_NUM:
        if key in row:
            want(path, is_num(row[key]),
                 f"{where}.{key} must be numeric (got {row[key]!r})")
    present = [k for k in CORRECTED_SET if k in row]
    want(path, len(present) in (0, len(CORRECTED_SET)),
         f"{where}: corrected percentiles are all-or-nothing, "
         f"got only {present}")
    for key in ("offered_rps", "shed", "connect_shed"):
        if key in row:
            want(path, row[key] >= 0,
                 f"{where}.{key} must be >= 0 (got {row[key]!r})")
    for key in OPTIONAL_INT:
        if key in row:
            want(path, is_int(row[key]) and row[key] >= 1,
                 f"{where}.{key} must be an int >= 1 (got {row[key]!r})")


def check_overhead_row(path, where, row):
    for key in ("raw_bytes", "delivered_bytes"):
        want(path, is_num(row.get(key)) and row[key] >= 0,
             f"{where}.{key} must be a number >= 0 (got {row.get(key)!r})")
    want(path, is_num(row.get("overhead_pct")),
         f"{where}.overhead_pct must be numeric (got {row.get('overhead_pct')!r})")
    for key in ("framing_pct", "paper_pct"):
        if key in row:
            want(path, is_num(row[key]),
                 f"{where}.{key} must be numeric (got {row[key]!r})")
    for key in ("chunk_count", "stripes"):
        if key in row:
            want(path, is_num(row[key]) and row[key] >= 1
                 and float(row[key]).is_integer(),
                 f"{where}.{key} must be an integer >= 1 (got {row[key]!r})")
    # a delivered count below raw would mean negative framing — a
    # byte-counter bug, not a measurement
    want(path, row["delivered_bytes"] >= row["raw_bytes"],
         f"{where}: delivered_bytes < raw_bytes")


ROW_CHECKS = {
    "mole-bench-v1": check_bench_row,
    "mole-overhead-v1": check_overhead_row,
}


def check(path):
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)

    schema = doc.get("schema") if isinstance(doc, dict) else None
    want(path, schema in ROW_CHECKS,
         f"unknown schema {schema!r} (known: {sorted(ROW_CHECKS)})")
    results = check_envelope(path, doc, schema)
    row_check = ROW_CHECKS[schema]
    for i, row in enumerate(results):
        row_check(path, f"results[{i}]", row)
    print(f"{path}: ok ({len(results)} rows, schema={schema}, "
          f"bench={doc['bench']}, cpu={doc['cpu']['arch']}/{doc['cpu']['features']})")


def main():
    if len(sys.argv) < 2:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    for path in sys.argv[1:]:
        check(path)


if __name__ == "__main__":
    main()
