//! Attack lab: run all three §4.2 attacks operationally and compare with
//! the theoretical bounds.
//!
//! Run: `cargo run --release --example attack_lab`

use mole::attacks::{brute_force_attack, dt_pair_attack, reversing_attack};
use mole::augconv::{build_aug_conv, ChannelPerm};
use mole::data::images::photo_like;
use mole::morph::MorphKey;
use mole::rng::Rng;
use mole::security::SecurityReport;
use mole::tensor::Tensor;
use mole::Geometry;

fn main() -> mole::Result<()> {
    mole::logging::init();
    let g = Geometry::SMALL;

    println!("=== theoretical bounds (paper CIFAR/VGG-16 geometry) ===");
    SecurityReport::analyze(Geometry::CIFAR_VGG16, 1, 0.5).print();
    println!();
    SecurityReport::analyze(Geometry::CIFAR_VGG16, 3, 0.5).print();

    println!("\n=== 1. brute-force attack (operational, small geometry) ===");
    let key = MorphKey::generate(g, 48, 11)?; // q=16 so trials are cheap
    let img = photo_like(3, g.m, 5);
    for sigma in [0.5, 0.05, 0.005] {
        let out = brute_force_attack(&key, &img, sigma, 500, 3)?;
        println!(
            "  sigma={sigma:<7} successes={}/{} best_esd={:.4} best_ssim={:.3}",
            out.successes, out.trials, out.best_esd, out.best_ssim
        );
    }
    println!("  (theorem-1 bound at q=16, sigma=0.05: 2^{:.0})",
        mole::security::brute_force_bound(&g, 48, 0.05).log2);

    println!("\n=== 2. Aug-Conv reversing attack across the kappa_mc boundary ===");
    let mut rng = Rng::new(13);
    let w1 = Tensor::new(
        &[g.beta, g.alpha, g.p, g.p],
        rng.normal_vec(g.beta * g.alpha * g.p * g.p, 0.5),
    )?;
    let b1 = vec![0.0f32; g.beta];
    let probe = Tensor::new(&[1, g.d_len()], rng.normal_vec(g.d_len(), 1.0))?;
    for kappa in [16usize, 3, 1] {
        let key = MorphKey::generate(g, kappa, 17)?;
        let perm = ChannelPerm::generate(g.beta, 17);
        let layer = build_aug_conv(&w1, &b1, &key, &perm)?;
        let out = reversing_attack(&g, &key, layer.matrix(), &w1, &probe)?;
        println!(
            "  kappa={kappa:<3} q={:<4} n2={:<4} fitting_candidates={:<3} identified={:<5} probe_esd={:.4}",
            out.q, out.n2, out.candidates_fitting, out.identified, out.probe_esd
        );
    }
    println!("  (kappa > kappa_mc=3 is broken; kappa <= kappa_mc protects the data)");

    println!("\n=== 3. D-T pair attack (SHBC) around the eq.-15 threshold ===");
    let key = MorphKey::generate(g, 16, 19)?; // q=48, 3 images needed
    let mut rng = Rng::new(23);
    let hold = Tensor::new(&[4, g.d_len()], rng.normal_vec(4 * g.d_len(), 1.0))?;
    for pairs in [1usize, 2, 3, 8] {
        let inj =
            Tensor::new(&[pairs, g.d_len()], rng.normal_vec(pairs * g.d_len(), 1.0))?;
        let out = dt_pair_attack(&key, &inj, &hold)?;
        println!(
            "  injected={pairs:<2} rows={}/{} solved={:<5} core_err={:<9.2e} holdout_esd={:.4}",
            out.rows_used, out.q, out.solved, out.core_max_err, out.holdout_esd
        );
    }
    println!("  (threshold: ceil(q/kappa) = 3 injected images; below it the key survives)");
    Ok(())
}
