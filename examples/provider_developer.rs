//! Two-node delivery demo over real TCP (paper Fig. 1).
//!
//! Spawns a data-provider node and a developer node in one process,
//! connected by a localhost socket; the provider never reveals pixels or
//! keys, the developer trains on the morphed stream, then evaluates.
//!
//! Run: `cargo run --release --example provider_developer -- [batches]`
//! (or run `mole provider` / `mole developer` in two terminals.)

use mole::coordinator::developer::run_tcp_session;
use mole::coordinator::provider::{ProviderNode, StreamPlan};
use mole::data::synth::{generate, SynthSpec};
use mole::keys::KeyBundle;
use mole::manifest::Manifest;
use mole::runtime::Engine;
use mole::Geometry;
use std::path::Path;

fn main() -> mole::Result<()> {
    mole::logging::init();
    let batches: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);
    let g = Geometry::SMALL;
    let kappa = 16;

    println!("provider_developer: {batches} morphed batches over TCP, kappa={kappa}");
    let keys = KeyBundle::generate(g, kappa, 20190506)?;
    println!("provider key fingerprint: {}...", &keys.fingerprint()[..16]);
    let dataset = generate(&SynthSpec::small10(7));
    let provider = std::sync::Arc::new(ProviderNode::new(keys, dataset)?);

    let engine = Engine::new(Manifest::load(Path::new("artifacts"))?)?;
    let t0 = std::time::Instant::now();
    let outcome = run_tcp_session(
        provider.clone(),
        &engine,
        StreamPlan { num_batches: batches, batch_size: 64 },
        0.05,
        20190506,
    )?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\ndelivery session complete:");
    println!("  kappa={} fingerprint={}...", outcome.session.kappa,
        &outcome.session.fingerprint[..16]);
    println!("  provider sent {} batches / {:.1} MB",
        provider.batches_sent.get(),
        provider.bytes_sent.get() as f64 / (1 << 20) as f64);
    println!("  developer trained {} steps in {wall:.1}s", outcome.steps);
    println!("  loss: {:.4} -> {:.4}",
        outcome.losses.first().unwrap_or(&f32::NAN),
        outcome.losses.last().unwrap_or(&f32::NAN));
    let tail = outcome.accs.iter().rev().take(10).sum::<f32>()
        / outcome.accs.len().min(10).max(1) as f32;
    println!("  train acc (last 10 steps): {tail:.3}");
    println!("  C^ac on the wire once: {:.1} MB — the whole MoLe transmission overhead",
        (outcome.cac.numel() * 4) as f64 / (1 << 20) as f64);
    Ok(())
}
