//! End-to-end driver: the paper's §4.4 experiment at full (small-machine)
//! scale — train the VGG-small network a few hundred steps in all three
//! groups on the synthetic CIFAR-like corpus, through the complete stack:
//! rust provider (morphing, C^ac) → AOT XLA train-step artifacts → PJRT.
//!
//! Expected (paper, CIFAR-10): base 89.3 %, aug 89.6 %, noaug 60.5 % —
//! i.e. Aug-Conv matches the original within error margin while morphed
//! data *without* Aug-Conv collapses. The shape reproduces here.
//!
//! Run: `cargo run --release --example e2e_train -- [steps] [kappa]`
//! Results land in EXPERIMENTS.md §4.4.

use mole::coordinator::experiment::{run_three_groups, ExperimentConfig};
use mole::manifest::Manifest;
use mole::runtime::Engine;
use std::path::Path;

fn main() -> mole::Result<()> {
    mole::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let kappa: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);

    println!("e2e_train: {steps} steps/group, kappa={kappa}, batch 64, synthetic CIFAR-like (10 classes)");
    let engine = Engine::new(Manifest::load(Path::new("artifacts"))?)?;
    let mut cfg = ExperimentConfig::quick(steps);
    cfg.kappa = kappa;

    let t0 = std::time::Instant::now();
    let result = run_three_groups(&engine, &cfg)?;
    result.print();

    // loss-curve summary (first/mid/last) per group for EXPERIMENTS.md
    println!("\nloss curves (step: loss):");
    for gr in [&result.base, &result.aug, &result.noaug] {
        let pick = |frac: f64| {
            let i = ((gr.losses.len() - 1) as f64 * frac) as usize;
            (i, gr.losses[i])
        };
        let (i0, l0) = pick(0.0);
        let (i1, l1) = pick(0.25);
        let (i2, l2) = pick(0.5);
        let (i3, l3) = pick(0.75);
        let (i4, l4) = pick(1.0);
        println!(
            "  {:<6} {i0}:{l0:.3}  {i1}:{l1:.3}  {i2}:{l2:.3}  {i3}:{l3:.3}  {i4}:{l4:.3}",
            gr.variant
        );
    }

    let ok = result.aug_matches_base(0.05)
        && result.noaug.test_acc < result.aug.test_acc - 0.1;
    println!(
        "\ntotal wall: {:.1}s — paper-shape check (|base-aug| <= 5pp and noaug trails >10pp): {}",
        t0.elapsed().as_secs_f64(),
        if ok { "PASS" } else { "MARGINAL (see EXPERIMENTS.md)" }
    );
    Ok(())
}
