//! Quickstart: the MoLe pipeline in ~80 lines.
//!
//! 1. The provider generates a morph key + channel permutation.
//! 2. The developer supplies a pre-trained first conv layer.
//! 3. The provider builds the Aug-Conv matrix C^ac = M⁻¹·C (shuffled).
//! 4. Data is morphed; the developer extracts features from the morphed
//!    rows through the AOT-compiled XLA artifact — and they match the
//!    original convolution exactly (paper eq. 5).
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use mole::augconv::{build_aug_conv, ChannelPerm};
use mole::coordinator::trainer::init_params;
use mole::manifest::Manifest;
use mole::morph::MorphKey;
use mole::rng::Rng;
use mole::runtime::{Arg, Engine};
use mole::tensor::Tensor;
use mole::{d2r, Geometry};
use std::path::Path;

fn main() -> mole::Result<()> {
    mole::logging::init();
    let g = Geometry::SMALL;
    let kappa = 16;

    // --- provider side ----------------------------------------------------
    let key = MorphKey::generate(g, kappa, 2019)?;
    let perm = ChannelPerm::generate(g.beta, 2019);
    println!("provider: morph key q={} (kappa={kappa}), core cond ~{:.1}",
        key.q(), key.cond_estimate());

    // --- developer's pre-trained first layer -------------------------------
    let mut rng = Rng::new(7);
    let w1 = Tensor::new(
        &[g.beta, g.alpha, g.p, g.p],
        rng.normal_vec(g.beta * g.alpha * g.p * g.p, 0.3),
    )?;
    let b1: Vec<f32> = rng.normal_vec(g.beta, 0.05);

    // --- provider builds + "ships" the Aug-Conv layer ----------------------
    let t0 = std::time::Instant::now();
    let layer = build_aug_conv(&w1, &b1, &key, &perm)?;
    println!(
        "provider: built C^ac {:?} in {:.1}ms ({} MB on the wire)",
        layer.matrix().shape(),
        t0.elapsed().as_secs_f64() * 1e3,
        layer.transfer_bytes() / (1 << 20)
    );

    // --- provider morphs a batch of images --------------------------------
    let images = Tensor::new(&[8, g.alpha, g.m, g.m], rng.normal_vec(8 * g.d_len(), 0.5))?;
    let rows = d2r::unroll(images.clone())?;
    let t_rows = key.morph(&rows)?;
    println!(
        "provider: morphed 8 images, E_sd(original, morphed) = {:.3}",
        t_rows.rms_diff(&rows)?
    );

    // --- developer extracts features from MORPHED data via XLA ------------
    let engine = Engine::new(Manifest::load(Path::new("artifacts"))?)?;
    let bias_t = Tensor::new(&[g.beta], layer.bias().to_vec())?;
    let out = engine.exec(
        "augconv_forward_small_b8",
        &[Arg::T(t_rows), Arg::T(layer.matrix().clone()), Arg::T(bias_t)],
    )?;
    let f_aug = &out[0];

    // --- ground truth: direct conv on the ORIGINAL data --------------------
    let f_plain = mole::nn::conv2d_same(&images, &w1, Some(&b1))?;
    let f_expected = perm.apply_features(&f_plain)?;
    let max_diff = f_aug.max_abs_diff(&f_expected)?;
    println!("equivalence check (eq. 5): max |aug - plain| = {max_diff:.2e}");
    assert!(max_diff < 5e-2, "Aug-Conv equivalence violated!");

    // --- and a full inference through the trained-model artifact ----------
    let manifest = engine.manifest();
    let mut prng = Rng::new(42);
    let params = init_params(&manifest.aug_params, &mut prng);
    let mut args: Vec<Arg> = vec![
        Arg::T(layer.matrix().clone()),
        Arg::T(Tensor::new(&[g.beta], layer.bias().to_vec())?),
    ];
    for p in &params {
        args.push(Arg::T(p.clone()));
    }
    let one = Tensor::new(&[1, g.d_len()], prng.normal_vec(g.d_len(), 0.5))?;
    args.push(Arg::T(one));
    let logits = engine.exec("infer_aug_small_b1", &args)?;
    println!("inference on morphed row -> logits {:?}", &logits[0].data()[..5]);

    println!("\nquickstart OK: morphed data, identical features, zero knowledge of M.");
    Ok(())
}
