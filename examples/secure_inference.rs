//! Secure-inference serving demo: train briefly on morphed data, then
//! register the trained model with a serving registry and drive it with
//! concurrent typed `MoleClient` sessions over loopback TCP, reporting
//! latency percentiles, throughput and batching efficiency. This is the
//! "inference stage" half of the paper's title, on the multi-tenant
//! serving stack.
//!
//! Run: `cargo run --release --example secure_inference -- [clients] [requests]`

use mole::augconv::build_aug_conv;
use mole::coordinator::batcher::BatcherConfig;
use mole::coordinator::client::MoleClient;
use mole::coordinator::experiment::ExperimentConfig;
use mole::coordinator::registry::{ModelRegistry, RegisteredModel};
use mole::coordinator::server::{ServeConfig, Server};
use mole::coordinator::trainer::Trainer;
use mole::data::synth::generate;
use mole::keys::KeyBundle;
use mole::manifest::Manifest;
use mole::rng::Rng;
use mole::runtime::{Engine, SharedEngine};
use mole::{d2r, Geometry};
use std::path::Path;
use std::time::Duration;

fn main() -> mole::Result<()> {
    mole::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let clients: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let per_client: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let g = Geometry::SMALL;

    // --- train a model on morphed data (short run) -------------------------
    let manifest = Manifest::load(Path::new("artifacts"))?;
    let engine = Engine::new(manifest.clone())?;
    let cfg = ExperimentConfig::quick(120);
    let dataset = generate(&cfg.data);
    let keys = KeyBundle::generate(g, cfg.kappa, cfg.seed)?;
    let key = keys.morph_key()?;
    let mut prng = Rng::new(cfg.seed);
    let base_params =
        mole::coordinator::trainer::init_params(&engine.manifest().base_params, &mut prng);
    let layer = build_aug_conv(&base_params[0], base_params[1].data(), &key, &keys.perm)?;

    println!("training {} steps on morphed data...", cfg.steps);
    let mut trainer =
        Trainer::new_aug(&engine, layer.matrix().clone(), layer.bias().to_vec(), cfg.seed)?;
    let mut iter = dataset.train_batches(trainer.batch_size());
    let mut rng = Rng::new(9);
    for _ in 0..cfg.steps {
        let b = iter.next_batch(&mut rng);
        let rows = key.morph(&d2r::unroll(b.images)?)?;
        trainer.step(&rows, &b.labels, cfg.lr)?;
    }

    // --- register the trained model and bind the TCP server ---------------
    let registry = ModelRegistry::new(
        SharedEngine::new(manifest),
        BatcherConfig {
            max_batch: 32,
            timeout: Duration::from_millis(2),
            ..BatcherConfig::default()
        },
    );
    registry.register(RegisteredModel::new(
        "secure_demo",
        &keys,
        layer,
        trainer.params().to_vec(),
    ))?;
    let server = Server::bind(
        registry,
        ServeConfig { addr: "127.0.0.1:0".to_string(), ..ServeConfig::default() },
    )?;
    let addr = server.local_addr();

    // --- fire concurrent typed clients over TCP ----------------------------
    println!("serving: {clients} MoleClient sessions x {per_client} requests -> {addr}");
    let t0 = std::time::Instant::now();
    let mut threads = Vec::new();
    let test = std::sync::Arc::new(dataset.test.clone());
    let key = std::sync::Arc::new(key);
    for c in 0..clients {
        let test = test.clone();
        let key = key.clone();
        threads.push(std::thread::spawn(move || -> mole::Result<usize> {
            let mut client = MoleClient::connect(addr)?;
            let per = 3 * 16 * 16;
            let mut correct = 0usize;
            for i in 0..per_client {
                let idx = (c * per_client + i) % test.len();
                let img = mole::tensor::Tensor::new(
                    &[1, 3, 16, 16],
                    test.images.data()[idx * per..][..per].to_vec(),
                )?;
                let row = key.morph(&d2r::unroll(img)?)?;
                let logits = client.infer(row.row(0))?;
                let pred = logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                if pred == test.labels[idx] as usize {
                    correct += 1;
                }
            }
            client.finish()?;
            Ok(correct)
        }));
    }
    let mut correct = 0usize;
    for t in threads {
        correct += t.join().expect("client panicked")?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = clients * per_client;

    // --- report -------------------------------------------------------------
    let lane = server.registry().resolve("secure_demo", mole::coordinator::EPOCH_LATEST)?;
    let m = &lane.handle().metrics;
    let (p50, p95, p99) = m.total_latency.summary().unwrap_or((0, 0, 0));
    let (e50, e95, _e99) = m.execute_latency.summary().unwrap_or((0, 0, 0));
    let sm = server.metrics();
    println!("\nserving report ({}@{}):", lane.name(), lane.epoch());
    println!("  requests              {total}");
    println!("  accuracy (on morphed) {:.3}", correct as f64 / total as f64);
    println!("  throughput            {:.1} req/s", total as f64 / wall);
    println!("  latency p50/p95/p99   {p50} / {p95} / {p99} µs");
    println!("  execute  p50/p95      {e50} / {e95} µs");
    println!(
        "  batches               {} (mean size {:.2}, padding {:.1}%)",
        m.batches.get(),
        m.mean_batch_size(),
        m.padding_fraction() * 100.0
    );
    println!(
        "  wire                  {} conns, {} B in / {} B out",
        sm.connections.get(),
        sm.bytes_in.get(),
        sm.bytes_out.get()
    );
    server.stop();
    Ok(())
}
