//! §4.3 overhead analysis: computational + transmission overhead for every
//! network the paper quotes, from the audited layer catalogs, plus the
//! measured provider-side morph cost on this machine (rust and XLA paths).
//!
//! Run: `cargo bench --bench bench_overhead`

use mole::bench::{bench, fmt_dur};
use mole::manifest::Manifest;
use mole::morph::MorphKey;
use mole::overhead::{catalog, OverheadReport};
use mole::rng::Rng;
use mole::runtime::{Arg, Engine};
use mole::tensor::Tensor;
use mole::Geometry;
use std::path::Path;

fn main() {
    mole::logging::init();
    println!("=== §4.3 analytic overheads (audited catalogs) ===\n");
    for (net, images, label) in [
        (catalog::vgg16_cifar(), 60_000usize, "paper: 9% comp / 5.12% data"),
        (catalog::vgg16_imagenet(), 1_281_167, "paper: n/a"),
        (catalog::resnet152_imagenet(), 1_281_167, "paper: 10x comp / ~1% data"),
    ] {
        for kappa in [1usize, 3] {
            let r = OverheadReport::analyze(&net, kappa, images);
            r.print();
        }
        println!("  [{label}]\n");
    }

    println!("=== measured provider morph cost (SMALL geometry, batch 64) ===");
    let g = Geometry::SMALL;
    let mut rng = Rng::new(1);
    let rows = Tensor::new(&[64, g.d_len()], rng.normal_vec(64 * g.d_len(), 0.5)).unwrap();
    println!("  kappa    q     rust-path        xla-artifact     MACs/img");
    let engine = Engine::new(Manifest::load(Path::new("artifacts")).unwrap()).unwrap();
    for &kappa in &[16usize, 3, 1] {
        let key = MorphKey::generate(g, kappa, 2).unwrap();
        let r_rust = bench("rust", 2, 20, || key.morph(&rows).unwrap());
        let name = format!("morph_apply_small_q{}_b64", key.q());
        let core = key.core().clone();
        let r_xla = bench("xla", 2, 20, || {
            engine
                .exec(&name, &[Arg::T(rows.clone()), Arg::T(core.clone())])
                .unwrap()
        });
        println!(
            "  {kappa:<6} {:<5} {:<16} {:<16} {}",
            key.q(),
            fmt_dur(r_rust.mean),
            fmt_dur(r_xla.mean),
            key.macs_per_row()
        );
    }

    println!("\n=== C^ac construction cost (one-off per session) ===");
    let mut rng = Rng::new(3);
    let w1 = Tensor::new(
        &[g.beta, g.alpha, g.p, g.p],
        rng.normal_vec(g.beta * g.alpha * g.p * g.p, 0.3),
    )
    .unwrap();
    let b1 = vec![0.0f32; g.beta];
    for &kappa in &[16usize, 3, 1] {
        let key = MorphKey::generate(g, kappa, 4).unwrap();
        let perm = mole::augconv::ChannelPerm::generate(g.beta, 4);
        let r = bench("cac", 1, 5, || {
            mole::augconv::build_aug_conv(&w1, &b1, &key, &perm).unwrap()
        });
        println!("  kappa={kappa:<3} q={:<5} build {}", key.q(), fmt_dur(r.mean));
    }
    println!("\n=== transmission overhead over the delivery plane (§4.3, 5.12%) ===");
    let rep = mole::overhead::transmission::TransmissionReport::analyze(
        mole::overhead::transmission::default_probe_bytes(),
        64 * 1024,
        4,
    )
    .unwrap();
    rep.print();
    match rep.write() {
        Ok(path) => println!("  wrote {}", path.display()),
        Err(e) => eprintln!("  could not write BENCH_overhead.json: {e}"),
    }

    println!("\ndepth-independence: none of the numbers above involve network depth —");
    println!("the paper's central overhead claim, visible directly in eq. 16/17.");
}
