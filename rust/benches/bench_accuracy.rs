//! §4.4 experimental analysis: the three-group accuracy table.
//!
//! Paper (CIFAR-10, VGG-16): original 89.3 %, morphed+AugConv 89.6 %
//! (difference within error margin), morphed w/o AugConv 60.5 %.
//! Here: synthetic CIFAR-like corpus + VGG-small via the AOT train-step
//! artifacts; the *shape* (base ≈ aug ≫ noaug) is the claim under test.
//!
//! Run: `cargo bench --bench bench_accuracy` (env MOLE_ACC_STEPS to scale)

use mole::coordinator::experiment::{run_three_groups, ExperimentConfig};
use mole::manifest::Manifest;
use mole::runtime::Engine;
use std::path::Path;

fn main() {
    mole::logging::init();
    let steps: usize = std::env::var("MOLE_ACC_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(150);
    println!("=== §4.4 three-group experiment ({steps} steps/group, batch 64) ===");
    let engine = Engine::new(Manifest::load(Path::new("artifacts")).unwrap()).unwrap();
    let mut cfg = ExperimentConfig::quick(steps);
    cfg.log_every = 0;
    let r = run_three_groups(&engine, &cfg).unwrap();
    r.print();

    println!("\n                    paper (CIFAR-10)   this repro (synthetic-10)");
    println!("  original            89.3%              {:.1}%", r.base.test_acc * 100.0);
    println!("  morphed + AugConv   89.6%              {:.1}%", r.aug.test_acc * 100.0);
    println!("  morphed, no AugConv 60.5%              {:.1}%", r.noaug.test_acc * 100.0);
    let d = (r.base.test_acc - r.aug.test_acc).abs() * 100.0;
    println!("\n  |base - aug| = {d:.1} pp (paper: 0.3 pp, 'within error margin')");
    println!(
        "  noaug deficit = {:.1} pp (paper: 28.8 pp)",
        (r.aug.test_acc - r.noaug.test_acc) * 100.0
    );
}
