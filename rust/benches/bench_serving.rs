//! Serving-path benchmark: throughput / latency of the adaptive
//! micro-batcher, in-process and over the real TCP serving layer.
//!
//! Part 1 sweeps batcher configurations against in-process clients (no
//! sockets — isolates the batcher). Part 2 drives `Server` + `loadgen`
//! over loopback TCP at 8 concurrent connections and compares
//! one-request-per-GEMM (`max_batch=1`) against micro-batching
//! (`max_batch=32`), reporting the throughput multiple — the number the
//! ISSUE acceptance gate reads (batched ≥ 2x unbatched).
//!
//! Part 2's per-policy numbers (throughput, p50/p95/p99 latency, mean
//! batch size, batched-vs-unbatched speedup) are also serialized to
//! `BENCH_serving.json` at the repo root (schema `mole-bench-v1`).
//! `MOLE_BENCH_BUDGET_MS` shrinks request counts to CI-smoke size.
//!
//! Run: `cargo bench --bench bench_serving`

use mole::bench::{scaled, table_header, table_row, Report};
use mole::coordinator::batcher::{BatcherConfig, ServingHandle, ServingModel};
use mole::coordinator::loadgen::{run as run_loadgen, LoadgenConfig};
use mole::coordinator::registry::{demo_entry, ModelRegistry};
use mole::coordinator::server::{ServeConfig, Server};
use mole::coordinator::trainer::init_params;
use mole::coordinator::EPOCH_LATEST;
use mole::json::Value;
use mole::manifest::Manifest;
use mole::rng::Rng;
use mole::runtime::SharedEngine;
use mole::tensor::Tensor;
use std::path::Path;
use std::time::Duration;

fn run_load(handle: &ServingHandle, clients: usize, per_client: usize) -> f64 {
    let t0 = std::time::Instant::now();
    let mut threads = Vec::new();
    for c in 0..clients {
        let h = handle.clone();
        threads.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64);
            let row = rng.normal_vec(768, 0.5);
            for _ in 0..per_client {
                h.infer(&row).unwrap();
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    (clients * per_client) as f64 / t0.elapsed().as_secs_f64()
}

fn in_process_sweep() {
    println!("--- part 1: in-process batcher sweep ---\n");
    let widths = [10, 12, 9, 12, 10, 10, 10, 11];
    table_header(
        &[
            "max_batch",
            "timeout_ms",
            "clients",
            "throughput",
            "p50_us",
            "p99_us",
            "batchsz",
            "pad%",
        ],
        &widths,
    );

    for (max_batch, timeout_ms) in [(1usize, 0u64), (8, 1), (8, 4), (32, 2), (32, 8)] {
        for clients in [1usize, 4, 16] {
            let manifest = Manifest::load(Path::new("artifacts")).unwrap();
            let g = manifest.geometry("small").unwrap();
            let mut rng = Rng::new(1);
            let model = ServingModel {
                cac: Tensor::new(
                    &[g.d_len(), g.f_len()],
                    rng.normal_vec(g.d_len() * g.f_len(), 0.02),
                )
                .unwrap(),
                bias: vec![0.0; g.beta],
                params: init_params(&manifest.aug_params, &mut rng),
            };
            let handle = ServingHandle::start(
                manifest,
                model,
                BatcherConfig {
                    max_batch,
                    timeout: Duration::from_millis(timeout_ms),
                    ..BatcherConfig::default()
                },
            )
            .unwrap();
            // warmup compiles all bucket executables
            run_load(&handle, 1, 8);
            let thpt = run_load(&handle, clients, scaled(64));
            let m = &handle.metrics;
            let (p50, _p95, p99) = m.total_latency.summary().unwrap_or((0, 0, 0));
            table_row(
                &[
                    max_batch.to_string(),
                    timeout_ms.to_string(),
                    clients.to_string(),
                    format!("{thpt:.0}/s"),
                    p50.to_string(),
                    p99.to_string(),
                    format!("{:.1}", m.mean_batch_size()),
                    format!("{:.0}", m.padding_fraction() * 100.0),
                ],
                &widths,
            );
        }
    }
}

/// One measured TCP serving run.
struct TcpRun {
    throughput_rps: f64,
    p50_us: u64,
    p95_us: u64,
    p99_us: u64,
    /// Coordinated-omission-corrected percentiles (intended send time →
    /// response). Identical to the raw percentiles for closed-loop runs.
    corrected_p50_us: u64,
    corrected_p95_us: u64,
    corrected_p99_us: u64,
    /// Configured open-loop arrival rate (0.0 = closed loop).
    offered_rps: f64,
    /// Typed `Overloaded` sheds absorbed by loadgen retries.
    shed: u64,
    mean_batch: f64,
}

/// Start a loopback server with the given batch policy and drive it with
/// the loadgen. `rate > 0` switches the loadgen to open loop at that
/// aggregate arrival rate.
fn tcp_run(max_batch: usize, timeout: Duration, adaptive: bool, conns: usize, rate: f64) -> TcpRun {
    let manifest = Manifest::load(Path::new("artifacts")).unwrap();
    let engine = SharedEngine::new(manifest.clone());
    let registry = ModelRegistry::new(
        engine,
        BatcherConfig {
            max_batch,
            timeout,
            min_timeout: Duration::from_micros(100),
            adaptive,
            ..BatcherConfig::default()
        },
    );
    registry.register(demo_entry(&manifest, "bench", 16, 7).unwrap()).unwrap();
    let server = Server::bind(
        registry,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            session_workers: conns,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let cfg = LoadgenConfig {
        addr: server.local_addr().to_string(),
        connections: conns,
        requests_per_conn: scaled(96),
        pipeline: 8,
        rate,
        seed: 3,
        model: String::new(),
        epoch: EPOCH_LATEST,
    };
    // warmup stays closed-loop: it exists to compile bucket executables,
    // not to measure, so pacing it would only slow the bench down
    run_loadgen(&LoadgenConfig { requests_per_conn: 8, rate: 0.0, ..cfg.clone() }).unwrap();
    // snapshot so the reported batch size covers the measured run only
    // (batching stats live on the lane's metrics)
    let lane = server.registry().resolve("bench", EPOCH_LATEST).unwrap();
    let batches0 = lane.handle().metrics.batches.get();
    let items0 = lane.handle().metrics.batched_items.get();
    let report = run_loadgen(&cfg).unwrap();
    assert_eq!(report.errors, 0, "loadgen errors under bench load");
    let (p50_us, p95_us, p99_us) = report.latency.summary().unwrap_or((0, 0, 0));
    let (corrected_p50_us, corrected_p95_us, corrected_p99_us) =
        report.corrected.summary().unwrap_or((0, 0, 0));
    let batches = lane.handle().metrics.batches.get() - batches0;
    let items = lane.handle().metrics.batched_items.get() - items0;
    let mean_batch = if batches == 0 { 0.0 } else { items as f64 / batches as f64 };
    server.stop();
    TcpRun {
        throughput_rps: report.throughput_rps(),
        p50_us,
        p95_us,
        p99_us,
        corrected_p50_us,
        corrected_p95_us,
        corrected_p99_us,
        offered_rps: report.offered_rps,
        shed: report.shed,
        mean_batch,
    }
}

/// Schema row for one serving policy.
fn policy_row(name: &str, run: &TcpRun, conns: usize) -> std::collections::BTreeMap<String, Value> {
    let mut m = std::collections::BTreeMap::new();
    m.insert("name".into(), Value::Str(name.to_string()));
    m.insert("backend".into(), Value::Str(mole::backend::active().name().to_string()));
    m.insert("connections".into(), Value::Num(conns as f64));
    m.insert("throughput_rps".into(), Value::Num(run.throughput_rps));
    m.insert("p50_us".into(), Value::Num(run.p50_us as f64));
    m.insert("p95_us".into(), Value::Num(run.p95_us as f64));
    m.insert("p99_us".into(), Value::Num(run.p99_us as f64));
    m.insert("corrected_p50_us".into(), Value::Num(run.corrected_p50_us as f64));
    m.insert("corrected_p95_us".into(), Value::Num(run.corrected_p95_us as f64));
    m.insert("corrected_p99_us".into(), Value::Num(run.corrected_p99_us as f64));
    m.insert("offered_rps".into(), Value::Num(run.offered_rps));
    m.insert("shed".into(), Value::Num(run.shed as f64));
    m.insert("mean_batch".into(), Value::Num(run.mean_batch));
    m
}

fn tcp_comparison(report: &mut Report) {
    println!("\n--- part 2: TCP serving, 8 connections, pipeline 8 ---\n");
    let widths = [24, 12, 10, 10, 10];
    table_header(&["policy", "throughput", "p50_us", "p99_us", "batchsz"], &widths);
    let conns = 8;
    let base = tcp_run(1, Duration::from_millis(0), false, conns, 0.0);
    table_row(
        &[
            "one-request-per-GEMM".into(),
            format!("{:.0}/s", base.throughput_rps),
            base.p50_us.to_string(),
            base.p99_us.to_string(),
            format!("{:.1}", base.mean_batch),
        ],
        &widths,
    );
    report.push(policy_row("serve_unbatched", &base, conns));
    let micro = tcp_run(32, Duration::from_millis(2), true, conns, 0.0);
    table_row(
        &[
            "micro-batch 32, adaptive".into(),
            format!("{:.0}/s", micro.throughput_rps),
            micro.p50_us.to_string(),
            micro.p99_us.to_string(),
            format!("{:.1}", micro.mean_batch),
        ],
        &widths,
    );
    let speedup = micro.throughput_rps / base.throughput_rps.max(1e-9);
    let mut row = policy_row("serve_microbatch", &micro, conns);
    row.insert("speedup_vs_unbatched".into(), Value::Num(speedup));
    report.push(row);
    println!(
        "\nmicro-batched throughput = {speedup:.2}x one-request-per-GEMM at {conns} connections \
         (acceptance gate: >= 2x)"
    );

    // Open-loop run at ~70% of the measured closed-loop capacity: requests
    // arrive on a fixed schedule, so the corrected percentiles charge any
    // server-side queueing against the *intended* send time instead of
    // hiding it behind a stalled closed loop (coordinated omission).
    let rate = (micro.throughput_rps * 0.7).max(conns as f64);
    let open = tcp_run(32, Duration::from_millis(2), true, conns, rate);
    table_row(
        &[
            format!("open-loop @ {rate:.0}/s"),
            format!("{:.0}/s", open.throughput_rps),
            open.p50_us.to_string(),
            open.p99_us.to_string(),
            format!("{:.1}", open.mean_batch),
        ],
        &widths,
    );
    report.push(policy_row("serve_openloop", &open, conns));
    println!(
        "open-loop corrected latency: p50={}us p99={}us (raw p50={}us p99={}us, shed={})",
        open.corrected_p50_us, open.corrected_p99_us, open.p50_us, open.p99_us, open.shed
    );
}

fn main() {
    mole::logging::init();
    println!("=== serving: adaptive micro-batcher throughput/latency ===\n");
    in_process_sweep();
    let mut report = Report::new("serving");
    tcp_comparison(&mut report);
    let path = report.write().expect("write BENCH_serving.json");
    println!("wrote {} ({} rows)", path.display(), report.len());
    println!("\nexpected shape: batching multiplies throughput under concurrency at a");
    println!("bounded p99 cost; padding stays low once load >= bucket sizes.");
}
