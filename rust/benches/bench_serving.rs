//! Serving-path benchmark: throughput / latency of the dynamic batcher
//! over the AOT inference artifacts, across batcher configurations and
//! client counts. Not a paper table per se — it substantiates that the
//! L3 coordinator is not the bottleneck (PERFORMANCE §L3 target).
//!
//! Run: `cargo bench --bench bench_serving`

use mole::bench::{table_header, table_row};
use mole::coordinator::batcher::{BatcherConfig, ServingHandle, ServingModel};
use mole::coordinator::trainer::init_params;
use mole::manifest::Manifest;
use mole::rng::Rng;
use mole::tensor::Tensor;
use std::path::Path;
use std::time::Duration;

fn run_load(handle: &ServingHandle, clients: usize, per_client: usize) -> f64 {
    let t0 = std::time::Instant::now();
    let mut threads = Vec::new();
    for c in 0..clients {
        let h = handle.clone();
        threads.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64);
            let row = rng.normal_vec(768, 0.5);
            for _ in 0..per_client {
                h.infer(&row).unwrap();
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    (clients * per_client) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    mole::logging::init();
    println!("=== serving: dynamic batcher throughput/latency ===\n");
    let widths = [10, 12, 9, 12, 10, 10, 10, 11];
    table_header(
        &["max_batch", "timeout_ms", "clients", "throughput", "p50_us", "p99_us", "batchsz", "pad%"],
        &widths,
    );

    for (max_batch, timeout_ms) in [(1usize, 0u64), (8, 1), (8, 4), (32, 2), (32, 8)] {
        for clients in [1usize, 4, 16] {
            let manifest = Manifest::load(Path::new("artifacts")).unwrap();
            let g = manifest.geometry("small").unwrap();
            let mut rng = Rng::new(1);
            let model = ServingModel {
                cac: Tensor::new(
                    &[g.d_len(), g.f_len()],
                    rng.normal_vec(g.d_len() * g.f_len(), 0.02),
                )
                .unwrap(),
                bias: vec![0.0; g.beta],
                params: init_params(&manifest.aug_params, &mut rng),
            };
            let handle = ServingHandle::start(
                manifest,
                model,
                BatcherConfig {
                    max_batch,
                    timeout: Duration::from_millis(timeout_ms),
                },
            )
            .unwrap();
            // warmup compiles all bucket executables
            run_load(&handle, 1, 8);
            let thpt = run_load(&handle, clients, 64);
            let m = &handle.metrics;
            let (p50, _p95, p99) = m.total_latency.summary().unwrap_or((0, 0, 0));
            table_row(
                &[
                    max_batch.to_string(),
                    timeout_ms.to_string(),
                    clients.to_string(),
                    format!("{thpt:.0}/s"),
                    p50.to_string(),
                    p99.to_string(),
                    format!("{:.1}", m.mean_batch_size()),
                    format!("{:.0}", m.padding_fraction() * 100.0),
                ],
                &widths,
            );
        }
    }
    println!("\nexpected shape: batching multiplies throughput under concurrency at a");
    println!("bounded p99 cost; padding stays low once load >= bucket sizes.");
}
