//! §4.2 security numbers: theoretical bounds for every configuration the
//! paper quotes, plus an empirical brute-force distribution that the
//! Theorem-1 geometry predicts.
//!
//! Run: `cargo bench --bench bench_security`

use mole::attacks::brute_force_attack;
use mole::data::images::photo_like;
use mole::morph::MorphKey;
use mole::security::{self, SecurityReport};
use mole::Geometry;

fn main() {
    mole::logging::init();
    let cifar = Geometry::CIFAR_VGG16;

    println!("=== paper §4.2 quoted numbers ===\n");
    println!("-- MS setting (kappa = 1, sigma = 0.5) --");
    SecurityReport::analyze(cifar, 1, 0.5).print();
    println!("   paper: P_M,bf <= 2^-3072^2 ~ 2^-9e6;  P_M,ar <= 2^-3072x2048 ~ 2^-6e6;");
    println!("          P_r,bf = (64!)^-1 ~ 7.9e-90;   D-T pairs = 3072\n");

    println!("-- MC setting (kappa = kappa_mc = 3, sigma = 0.5) --");
    SecurityReport::analyze(cifar, 3, 0.5).print();
    println!("   paper: P_M,ar <= 2^-1728 at the MC boundary\n");

    println!("-- small geometry (this repo's trainable config), kappa = 16 --");
    SecurityReport::analyze(Geometry::SMALL, 16, 0.5).print();

    // sigma sweep (the privacy-reservation axis of fig. 7)
    println!("\n=== Theorem-1 bound vs sigma (CIFAR, kappa=1) ===");
    println!("  sigma     log2 P_M,bf");
    for sigma in [0.5, 5e-2, 5e-3, 5e-4, 5e-5] {
        let b = security::brute_force_bound(&cifar, 1, sigma);
        println!("  {sigma:<8} {:.3e}", b.log2);
    }

    // empirical distribution at attackable scale
    println!("\n=== empirical brute force (q=16 core, 1000 trials) ===");
    let g = Geometry::SMALL;
    let key = MorphKey::generate(g, 48, 5).unwrap();
    let img = photo_like(3, g.m, 6);
    let out = brute_force_attack(&key, &img, 0.05, 1000, 9).unwrap();
    let mut esd = out.esd.clone();
    esd.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| esd[((esd.len() - 1) as f64 * p) as usize];
    println!("  E_sd distribution: min={:.4} p25={:.4} p50={:.4} p99={:.4}",
        esd[0], pct(0.25), pct(0.5), pct(0.99));
    println!("  successes at sigma=0.05: {}/{} (Theorem-1 bound 2^{:.0})",
        out.successes, out.trials,
        security::brute_force_bound(&g, 48, 0.05).log2);
    println!("  best-guess SSIM vs original: {:.3} (unrecognizable)", out.best_ssim);
}
