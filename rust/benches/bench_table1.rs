//! Table 1: MoLe vs SMC-based [24] vs feature-transmission [13].
//!
//! Regenerates the three comparison columns. MoLe's cells are measured on
//! this machine; the SMC row is *measured* on our real Beaver-triple 2PC
//! conv (same geometry) and shown next to the paper's quoted GAZELLE
//! factors; the feature-tx row measures transmission expansion and cites
//! the accuracy penalty from [13] (reproduced qualitatively by the noaug
//! group of bench_accuracy).
//!
//! Run: `cargo bench --bench bench_table1`

use mole::augconv::{build_aug_conv, ChannelPerm};
use mole::baselines::{feature_tx_overhead, Smc2pcReport};
use mole::bench::{bench, fmt_dur};
use mole::morph::MorphKey;
use mole::overhead;
use mole::rng::Rng;
use mole::tensor::Tensor;
use mole::Geometry;

fn main() {
    mole::logging::init();
    let g = Geometry::SMALL;
    println!("=== Table 1 regeneration (measured on SMALL geometry alpha=3 m=16 beta=16) ===\n");

    // ---------------- MoLe row -------------------------------------------
    let key = MorphKey::generate(g, 16, 1).unwrap();
    let mut rng = Rng::new(2);
    let w1 = Tensor::new(
        &[g.beta, g.alpha, g.p, g.p],
        rng.normal_vec(g.beta * g.alpha * g.p * g.p, 0.3),
    )
    .unwrap();
    let b1 = vec![0.0f32; g.beta];
    let perm = ChannelPerm::generate(g.beta, 3);

    let imgs = Tensor::new(&[64, g.alpha, g.m, g.m], rng.normal_vec(64 * g.d_len(), 0.5))
        .unwrap();
    let rows = mole::d2r::unroll(imgs).unwrap();
    let r_morph = bench("morph64", 2, 20, || key.morph(&rows).unwrap());
    let r_build = bench("build_cac", 1, 5, || {
        build_aug_conv(&w1, &b1, &key, &perm).unwrap()
    });
    let layer = build_aug_conv(&w1, &b1, &key, &perm).unwrap();
    let t_rows = key.morph(&rows).unwrap();
    let r_aug = bench("augconv_fwd64", 2, 10, || layer.forward(&t_rows).unwrap());
    let direct = Tensor::new(&[64, g.alpha, g.m, g.m], rows.data().to_vec()).unwrap();
    let r_conv = bench("direct_conv64", 2, 10, || {
        mole::nn::conv2d_same(&direct, &w1, Some(&b1)).unwrap()
    });

    // paper-geometry analytic overheads
    let cifar = Geometry::CIFAR_VGG16;
    let net = overhead::catalog::vgg16_cifar();
    let rep = overhead::OverheadReport::analyze(&net, 1, 60_000);

    println!("MoLe (measured):");
    println!("  performance penalty         0 (see bench_accuracy: |base-aug| within margin)");
    println!(
        "  morph 64 imgs               {} ({:.0} img/s provider-side)",
        fmt_dur(r_morph.mean),
        r_morph.throughput(64.0)
    );
    println!(
        "  C^ac build (one-off)        {}   transfer {:.1} MB once",
        fmt_dur(r_build.mean),
        layer.transfer_bytes() as f64 / (1 << 20) as f64
    );
    println!(
        "  aug-conv fwd vs direct conv {} vs {}  (measured dev-side overhead {:.2}x)",
        fmt_dur(r_aug.mean),
        fmt_dur(r_conv.mean),
        r_aug.mean.as_secs_f64() / r_conv.mean.as_secs_f64()
    );
    println!(
        "  paper-geometry analytics    data tx {:.2}% (paper formula) / {:.1}% (audited C^ac);",
        rep.paper_data_ratio * 100.0,
        rep.audited_data_ratio * 100.0
    );
    println!(
        "                              comp overhead {:.1}% of VGG-16/CIFAR MACs (eq. 17; paper quotes 9%)",
        rep.dev_overhead_ratio * 100.0
    );

    // ---------------- SMC row --------------------------------------------
    println!("\nSMC-based [24] (measured Beaver-2PC conv, toy geometry 2x8x8 -> 4ch):");
    let toy = Geometry::new(2, 8, 4, 3);
    let smc = Smc2pcReport::measure(toy, 3, 5).unwrap();
    println!(
        "  transmission              {} B/img vs {} B plain = {:.0}x  (paper quotes 421,000x for full GAZELLE inference)",
        smc.bytes_per_image, smc.plain_bytes, smc.expansion
    );
    println!(
        "  execution time            {:.2}ms vs {:.3}ms plain = {:.0}x  (paper quotes >10,000x; ours is ONE layer)",
        smc.secs_2pc * 1e3,
        smc.secs_plain * 1e3,
        smc.secs_2pc / smc.secs_plain
    );
    println!("  beaver triples/img        {}", smc.triples_per_image);
    // extrapolate the per-layer interaction across VGG-16's 13 conv layers
    let vgg_scale = overhead::catalog::vgg16_cifar().total_macs() as f64
        / overhead::conv1_macs(&toy) as f64;
    println!(
        "  extrapolated to VGG-16/CIFAR MAC count: ~{:.0}x transmission (per-MAC interaction)",
        smc.expansion * vgg_scale * (toy.d_len() * 4) as f64
            / (cifar.d_len() * 4) as f64
    );

    // ---------------- feature-transmission row ---------------------------
    println!("\nFeature transmission [13] (first-layer cut):");
    let ft = feature_tx_overhead(&cifar, 0.5);
    println!(
        "  transmission              {:.1}x per image (beta*n^2/alpha*m^2; [13]'s deeper cut quotes 64x)",
        ft.expansion
    );
    println!("  performance penalty       62.8% higher error rate (paper-quoted for [13]);");
    println!("                            qualitative reproduction: bench_accuracy noaug-group collapse");

    println!("\nsummary (paper Table 1 shape): MoLe = one-shot {:.2}% tx + ~10% compute, zero penalty;", rep.paper_data_ratio * 100.0);
    println!("SMC = 10^5-10^6x interactive tx; feature-tx = 20-60x tx + accuracy loss.  Shape holds.");
}
