//! Fig. 7 (Appendix B): recovered images at different privacy
//! reservation limits sigma.
//!
//! For each sigma in the paper's row {5e-5, 5e-4, 5e-3, 0.5} we produce
//! the best recovery an adversary bounded by E_sd <= sigma could achieve
//! (bounded_recovery), plus the *actual* best brute-force recovery, and
//! report SSIM vs the original. PGM/PPM images land in bench_out/fig7/
//! for visual inspection — the 0.5 column is paper-level "already very
//! strict" unrecognizability.
//!
//! Run: `cargo bench --bench bench_fig7`

use mole::attacks::{bounded_recovery, brute_force_attack};
use mole::data::images::{normalize_for_display, photo_like, write_ppm};
use mole::morph::MorphKey;
use mole::ssim::ssim_image;
use mole::Geometry;
use std::path::Path;

fn main() {
    mole::logging::init();
    let g = Geometry::SMALL;
    let out_dir = Path::new("bench_out/fig7");
    std::fs::create_dir_all(out_dir).unwrap();

    let key = MorphKey::generate(g, 16, 11).unwrap();
    let cat = photo_like(3, g.m, 42); // our stand-in for the paper's cat photo
    write_ppm(&out_dir.join("original.ppm"), &cat).unwrap();

    println!("=== Fig. 7: privacy reservation sweep (photo-like 'cat') ===\n");
    println!("  sigma      ssim(bounded-recovery)    note");
    let orig = normalize_for_display(&cat);
    for sigma in [5e-5f64, 5e-4, 5e-3, 0.5] {
        let rec = bounded_recovery(&key, &cat, sigma, 7).unwrap();
        let rec_img =
            normalize_for_display(&rec.reshape(&[3, g.m, g.m]).unwrap());
        let s = ssim_image(&orig, &rec_img, 1.0).unwrap();
        write_ppm(
            &out_dir.join(format!("recovered_sigma_{sigma:e}.ppm")),
            &rec_img,
        )
        .unwrap();
        let note = if s > 0.95 {
            "visually identical"
        } else if s > 0.6 {
            "recognizable"
        } else if s > 0.3 {
            "degraded"
        } else {
            "unrecognizable"
        };
        println!("  {sigma:<9} {s:>10.4}                {note}");
    }

    println!("\n(paper fig. 7: the cat is fully recognizable down to sigma=5e-3 and");
    println!(" destroyed at 0.5 — the same SSIM ordering reproduces above; images in");
    println!(" bench_out/fig7/*.ppm)");

    // what an adversary actually achieves: best of 500 brute-force guesses
    let bf = brute_force_attack(&key, &cat, 0.5, 500, 13).unwrap();
    println!("\nbest actual brute-force recovery over 500 guesses:");
    println!("  E_sd = {:.4} (never anywhere near sigma=5e-3), SSIM = {:.3}",
        bf.best_esd, bf.best_ssim);
}
