//! Fig. 4(b): morphing scale factor κ vs privacy effectiveness (SSIM).
//!
//! Sweeps κ on photo-like images and prints the SSIM(original, morphed)
//! series — the paper's trade-off curve: larger cores (smaller κ) scramble
//! more, SSIM falls toward the unrelated-image floor; tiny cores leave
//! local structure and SSIM stays high. Also reports the provider-side
//! morph cost at each κ (the other axis of the trade-off, eq. 16).
//!
//! Run: `cargo bench --bench bench_fig4b`

use mole::bench::{bench_auto, fmt_dur, table_header, table_row};
use mole::data::images::{normalize_for_display, photo_like};
use mole::morph::MorphKey;
use mole::ssim::ssim_image;
use mole::tensor::Tensor;
use mole::{d2r, Geometry};
use std::time::Duration;

fn main() {
    mole::logging::init();
    let g = Geometry::SMALL;
    println!("=== Fig. 4(b): kappa vs SSIM (photo-like images, {}x{}x{}) ===\n",
        g.alpha, g.m, g.m);

    // two "photos", as in the paper's figure
    let photos = [photo_like(3, g.m, 101), photo_like(3, g.m, 202)];

    let widths = [8, 6, 12, 12, 14, 12];
    table_header(
        &["kappa", "q", "ssim(img1)", "ssim(img2)", "macs/img", "morph(b=8)"],
        &widths,
    );
    // kappa must divide alpha*m^2 = 768
    for &kappa in &[768usize, 192, 48, 16, 4, 1] {
        let key = MorphKey::generate(g, kappa, 7).unwrap();
        let mut ssims = Vec::new();
        for img in &photos {
            let rows = d2r::unroll(img.clone().reshape(&[1, 3, g.m, g.m]).unwrap()).unwrap();
            let morphed = key.morph(&rows).unwrap();
            let morphed_img = normalize_for_display(
                &d2r::roll(morphed, 3, g.m).unwrap().reshape(&[3, g.m, g.m]).unwrap(),
            );
            ssims.push(ssim_image(img, &morphed_img, 1.0).unwrap());
        }
        let batch = {
            let mut data = Vec::new();
            for img in photos.iter().cycle().take(8) {
                data.extend_from_slice(img.data());
            }
            Tensor::new(&[8, g.d_len()], data).unwrap()
        };
        let r = bench_auto("morph", Duration::from_millis(300), || {
            key.morph(&batch).unwrap()
        });
        table_row(
            &[
                kappa.to_string(),
                key.q().to_string(),
                format!("{:.4}", ssims[0]),
                format!("{:.4}", ssims[1]),
                format!("{}", key.macs_per_row()),
                fmt_dur(r.mean),
            ],
            &widths,
        );
    }

    println!("\npaper shape: SSIM falls monotonically as kappa decreases (bigger core =");
    println!("stronger mixing = better privacy), while provider MACs grow as alpha*m^2*q.");

    // one paper-scale data point: CIFAR geometry at kappa_mc
    let cg = Geometry::CIFAR_VGG16;
    let key = MorphKey::generate(cg, 96, 7).unwrap(); // q=32 (fast demo point)
    let img = photo_like(3, cg.m, 303);
    let rows = d2r::unroll(img.clone().reshape(&[1, 3, cg.m, cg.m]).unwrap()).unwrap();
    let morphed_img = normalize_for_display(
        &d2r::roll(key.morph(&rows).unwrap(), 3, cg.m)
            .unwrap()
            .reshape(&[3, cg.m, cg.m])
            .unwrap(),
    );
    println!(
        "\nCIFAR-geometry point (32x32, kappa=96, q=32): ssim = {:.4}",
        ssim_image(&img, &morphed_img, 1.0).unwrap()
    );
}
