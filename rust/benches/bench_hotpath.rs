//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! GEMM kernel, block-diagonal morph, C^ac construction, d2r build, and
//! the XLA train/infer step. Used to find and verify optimizations.
//!
//! Run: `cargo bench --bench bench_hotpath`

use mole::augconv::{build_aug_conv, ChannelPerm};
use mole::bench::{bench, bench_auto, fmt_dur};
use mole::coordinator::trainer::{init_params, Trainer, Variant};
use mole::manifest::Manifest;
use mole::morph::MorphKey;
use mole::rng::Rng;
use mole::runtime::Engine;
use mole::tensor::Tensor;
use mole::Geometry;
use std::path::Path;
use std::time::Duration;

fn gflops(macs: f64, secs: f64) -> f64 {
    2.0 * macs / secs / 1e9
}

fn main() {
    mole::logging::init();
    let mut rng = Rng::new(1);

    println!("=== GEMM kernel (rust, single core) ===");
    for &(m, k, n) in &[(64usize, 768usize, 768usize), (256, 256, 4096), (768, 768, 4096)] {
        let a = Tensor::new(&[m, k], rng.normal_vec(m * k, 1.0)).unwrap();
        let b = Tensor::new(&[k, n], rng.normal_vec(k * n, 1.0)).unwrap();
        let r = bench_auto("gemm", Duration::from_millis(800), || {
            mole::linalg::gemm(&a, &b).unwrap()
        });
        println!(
            "  [{m:>4}x{k:>4}]x[{k:>4}x{n:>5}]  {}  {:.2} GFLOP/s",
            fmt_dur(r.mean),
            gflops((m * k * n) as f64, r.mean.as_secs_f64())
        );
    }

    let g = Geometry::SMALL;
    println!("\n=== provider morph (batch 64) ===");
    let rows = Tensor::new(&[64, g.d_len()], rng.normal_vec(64 * g.d_len(), 1.0)).unwrap();
    for &kappa in &[16usize, 3, 1] {
        let key = MorphKey::generate(g, kappa, 2).unwrap();
        let r = bench("morph", 3, 30, || key.morph(&rows).unwrap());
        let macs = 64.0 * key.macs_per_row() as f64;
        println!(
            "  kappa={kappa:<3} q={:<4} {}  {:.2} GFLOP/s  ({:.0} img/s)",
            key.q(),
            fmt_dur(r.mean),
            gflops(macs, r.mean.as_secs_f64()),
            r.throughput(64.0)
        );
    }

    println!("\n=== C^ac construction (block GEMM + shuffle) ===");
    let w1 = Tensor::new(
        &[g.beta, g.alpha, g.p, g.p],
        rng.normal_vec(g.beta * g.alpha * g.p * g.p, 0.3),
    )
    .unwrap();
    let b1 = vec![0.0f32; g.beta];
    for &kappa in &[16usize, 1] {
        let key = MorphKey::generate(g, kappa, 3).unwrap();
        let perm = ChannelPerm::generate(g.beta, 3);
        let r = bench("cac", 1, 8, || build_aug_conv(&w1, &b1, &key, &perm).unwrap());
        let macs = (g.d_len() * key.q() * g.f_len() / key.kappa() * key.kappa()) as f64;
        println!(
            "  kappa={kappa:<3} {}  ({:.2} GFLOP/s over {:.2} GMACs)",
            fmt_dur(r.mean),
            gflops(macs, r.mean.as_secs_f64()),
            macs / 1e9
        );
    }

    println!("\n=== d2r C-matrix build ===");
    let r = bench("d2r", 1, 10, || mole::d2r::build_c_matrix(&w1, &g).unwrap());
    println!("  build_c_matrix(small)  {}", fmt_dur(r.mean));

    println!("\n=== XLA artifacts (PJRT CPU) ===");
    let engine = Engine::new(Manifest::load(Path::new("artifacts")).unwrap()).unwrap();
    let mut trainer = Trainer::new_base(&engine, Variant::Base, 1).unwrap();
    let x = Tensor::new(&[64, 3, 16, 16], rng.normal_vec(64 * 768, 0.5)).unwrap();
    let y: Vec<i32> = (0..64).map(|i| (i % 10) as i32).collect();
    trainer.step(&x, &y, 0.01).unwrap(); // compile
    let r = bench("train_base", 1, 10, || trainer.step(&x, &y, 0.01).unwrap());
    println!("  train_step_base(b64)   {}  ({:.0} img/s)", fmt_dur(r.mean), r.throughput(64.0));

    let key = MorphKey::generate(g, 16, 4).unwrap();
    let perm = ChannelPerm::generate(g.beta, 4);
    let layer = build_aug_conv(&w1, &b1, &key, &perm).unwrap();
    let mut at =
        Trainer::new_aug(&engine, layer.matrix().clone(), layer.bias().to_vec(), 1).unwrap();
    let t_rows = key.morph(&rows).unwrap();
    at.step(&t_rows, &y, 0.01).unwrap();
    let r = bench("train_aug", 1, 10, || at.step(&t_rows, &y, 0.01).unwrap());
    println!("  train_step_aug(b64)    {}  ({:.0} img/s)", fmt_dur(r.mean), r.throughput(64.0));

    let mut args: Vec<mole::runtime::Arg> = vec![
        mole::runtime::Arg::T(layer.matrix().clone()),
        mole::runtime::Arg::T(Tensor::new(&[g.beta], layer.bias().to_vec()).unwrap()),
    ];
    for p in init_params(&engine.manifest().aug_params, &mut rng) {
        args.push(mole::runtime::Arg::T(p));
    }
    args.push(mole::runtime::Arg::T(Tensor::new(&[32, g.d_len()],
        rng.normal_vec(32 * g.d_len(), 0.5)).unwrap()));
    engine.exec("infer_aug_small_b32", &args).unwrap();
    let r = bench("infer", 2, 20, || engine.exec("infer_aug_small_b32", &args).unwrap());
    println!("  infer_aug(b32)         {}  ({:.0} img/s)", fmt_dur(r.mean), r.throughput(32.0));
}
