//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! the full backend matrix (ref / parallel / simd / parallel+simd) on the
//! GEMM kernel, the block-diagonal morph at SMALL and VGG-16/CIFAR
//! geometry, the Aug-Conv C^ac build at both geometries, plus the engine
//! train/infer step.
//!
//! Besides the stdout tables, results are serialized to
//! `BENCH_hotpath.json` at the repo root (schema `mole-bench-v1`, see
//! `mole::bench::Report`) with per-backend GFLOP/s and p50/p95/p99 so
//! perf deltas are machine-diffable (`scripts/perf_compare.sh`).
//!
//! Run: `cargo bench --bench bench_hotpath`
//! CI smoke: `MOLE_BENCH_BUDGET_MS=120 cargo bench --bench bench_hotpath`

use mole::augconv::{build_aug_conv, build_aug_conv_from_c_on, ChannelPerm};
use mole::backend::{cpu_features, Backend, ParallelBackend, RefBackend, SimdBackend};
use mole::bench::{bench, bench_auto, budget, fmt_dur, scaled, BenchResult, Report};
use mole::coordinator::trainer::{init_params, Trainer, Variant};
use mole::json::Value;
use mole::manifest::Manifest;
use mole::morph::MorphKey;
use mole::rng::Rng;
use mole::runtime::Engine;
use mole::tensor::Tensor;
use mole::Geometry;
use std::path::Path;

fn gflops(macs: f64, secs: f64) -> f64 {
    2.0 * macs / secs / 1e9
}

/// Push one timed row: geometry + GFLOP/s + (for non-ref backends) the
/// measured speedup over the reference time from the same section.
fn push_row(
    report: &mut Report,
    r: &BenchResult,
    backend: &str,
    geometry: &str,
    macs: f64,
    ref_secs: Option<f64>,
) {
    let secs = r.mean.as_secs_f64();
    let mut row = Report::row(r, backend);
    row.insert("geometry".into(), Value::Str(geometry.to_string()));
    if macs > 0.0 {
        row.insert("gflops".into(), Value::Num(gflops(macs, secs)));
    }
    if let Some(rs) = ref_secs {
        row.insert("speedup_vs_ref".into(), Value::Num(rs / secs));
    }
    report.push(row);
}

fn main() {
    mole::logging::init();
    let mut rng = Rng::new(1);
    let refb = RefBackend::new();
    let parb = ParallelBackend::new(0);
    let simdb = SimdBackend::new();
    let parsimdb = ParallelBackend::with_simd(0);
    let backends: [(&str, &dyn Backend); 4] = [
        ("ref", &refb),
        ("parallel", &parb),
        ("simd", &simdb),
        ("parallel+simd", &parsimdb),
    ];
    let mut report = Report::new("hotpath");
    println!(
        "cpu: {} ({}), simd kernel: {}",
        std::env::consts::ARCH,
        cpu_features(),
        simdb.describe()
    );

    println!("\n=== GEMM kernel: backend matrix ===");
    for &(m, k, n) in &[(64usize, 768usize, 768usize), (256, 256, 4096), (768, 768, 4096)] {
        let a = Tensor::new(&[m, k], rng.normal_vec(m * k, 1.0)).unwrap();
        let b = Tensor::new(&[k, n], rng.normal_vec(k * n, 1.0)).unwrap();
        let geometry = format!("{m}x{k}x{n}");
        let macs = (m * k * n) as f64;
        let mut ref_secs = None;
        for (name, be) in backends {
            let r = bench_auto("gemm", budget(600), || be.gemm(&a, &b).unwrap());
            let secs = r.mean.as_secs_f64();
            println!(
                "  [{m:>4}x{k:>4}]x[{k:>4}x{n:>5}] {name:>13}  {}  {:.2} GFLOP/s{}",
                fmt_dur(r.mean),
                gflops(macs, secs),
                match ref_secs {
                    Some(rs) => format!("  ({:.2}x vs ref)", rs / secs),
                    None => String::new(),
                }
            );
            push_row(&mut report, &r, name, &geometry, macs, ref_secs);
            if name == "ref" {
                ref_secs = Some(secs);
            }
        }
    }

    let g = Geometry::SMALL;
    println!("\n=== provider morph / blockdiag (batch 64, SMALL): backend matrix ===");
    let rows = Tensor::new(&[64, g.d_len()], rng.normal_vec(64 * g.d_len(), 1.0)).unwrap();
    for &kappa in &[16usize, 3, 1] {
        let key = MorphKey::generate(g, kappa, 2).unwrap();
        let macs = 64.0 * key.macs_per_row() as f64;
        let geometry = format!("b64_kappa{kappa}_q{}", key.q());
        let mut ref_secs = None;
        for (name, be) in backends {
            let r = bench("morph", 3, scaled(30), || key.morph_on(be, &rows).unwrap());
            let secs = r.mean.as_secs_f64();
            println!(
                "  kappa={kappa:<3} q={:<4} {name:>13} {}  {:.2} GFLOP/s  ({:.0} img/s)",
                key.q(),
                fmt_dur(r.mean),
                gflops(macs, secs),
                r.throughput(64.0)
            );
            push_row(&mut report, &r, name, &geometry, macs, ref_secs);
            if name == "ref" {
                ref_secs = Some(secs);
            }
        }
    }

    // Raw eq. 2/4 hot path at the paper's VGG-16/CIFAR geometry:
    // [64, 3072] rows against a shared [96, 96] core — the flattened
    // [64·32, 96]x[96, 96] GEMM every backend now routes through its own
    // microkernel.
    println!("\n=== blockdiag apply, VGG-16/CIFAR geometry (batch 64, q=96) ===");
    {
        let cg = Geometry::CIFAR_VGG16;
        let q = 96usize;
        let kappa = cg.d_len() / q;
        let cifar_rows =
            Tensor::new(&[64, cg.d_len()], rng.normal_vec(64 * cg.d_len(), 1.0)).unwrap();
        let core = Tensor::new(&[q, q], rng.normal_vec(q * q, 0.5)).unwrap();
        let macs = (64 * kappa * q * q) as f64;
        let geometry = format!("b64_kappa{kappa}_q{q}");
        let mut ref_secs = None;
        for (name, be) in backends {
            let r = bench("blockdiag_cifar", 2, scaled(20), || {
                be.apply_blockdiag(&cifar_rows, &core).unwrap()
            });
            let secs = r.mean.as_secs_f64();
            println!(
                "  {name:>13} {}  {:.2} GFLOP/s  ({:.0} img/s)",
                fmt_dur(r.mean),
                gflops(macs, secs),
                r.throughput(64.0)
            );
            push_row(&mut report, &r, name, &geometry, macs, ref_secs);
            if name == "ref" {
                ref_secs = Some(secs);
            }
        }
    }

    println!("\n=== C^ac construction, SMALL geometry (block GEMM + shuffle) ===");
    let w1 = Tensor::new(
        &[g.beta, g.alpha, g.p, g.p],
        rng.normal_vec(g.beta * g.alpha * g.p * g.p, 0.3),
    )
    .unwrap();
    let b1 = vec![0.0f32; g.beta];
    let c_small = mole::d2r::build_c_matrix(&w1, &g).unwrap();
    for &kappa in &[16usize, 1] {
        let key = MorphKey::generate(g, kappa, 3).unwrap();
        let perm = ChannelPerm::generate(g.beta, 3);
        let macs = (g.d_len() * key.q() * g.f_len() / key.kappa() * key.kappa()) as f64;
        let geometry = format!("kappa{kappa}_q{}", key.q());
        let mut ref_secs = None;
        for (name, be) in backends {
            let r = bench("cac_small", 1, scaled(8), || {
                build_aug_conv_from_c_on(be, &c_small, &key, &perm).unwrap()
            });
            let secs = r.mean.as_secs_f64();
            println!(
                "  kappa={kappa:<3} {name:>13} {}  ({:.2} GFLOP/s)",
                fmt_dur(r.mean),
                gflops(macs, secs)
            );
            push_row(&mut report, &r, name, &geometry, macs, ref_secs);
            if name == "ref" {
                ref_secs = Some(secs);
            }
        }
    }

    // The acceptance-criteria case: the Aug-Conv build at the paper's
    // VGG-16/CIFAR geometry (d_len=3072, f_len=65536) in its kappa=32
    // setting (q=96): all 32 block-row GEMMs of M'^-1 x C_blk. B panels
    // are synthetic (the timing is bound by the dense M'^-1 operand;
    // using random panels avoids materializing the ~800 MB real C).
    println!("\n=== C^ac build, VGG-16/CIFAR geometry (kappa=32, q=96) ===");
    {
        let cg = Geometry::CIFAR_VGG16;
        let q = 96usize;
        let kappa = cg.d_len() / q;
        // smoke mode shrinks the f dimension; the recorded geometry string
        // reflects what actually ran, so JSONs from different modes never
        // silently compare
        let f_len = if mole::bench::short_budget() { cg.f_len() / 8 } else { cg.f_len() };
        let core_inv = Tensor::new(&[q, q], rng.normal_vec(q * q, 0.5)).unwrap();
        let c_block = Tensor::new(&[q, f_len], rng.normal_vec(q * f_len, 0.5)).unwrap();
        let macs = (kappa * q * q * f_len) as f64;
        let geometry = format!("kappa{kappa}_q{q}_f{f_len}");
        let build = |be: &dyn Backend| -> Tensor {
            let mut out = Tensor::zeros(&[q, f_len]);
            for _blk in 0..kappa {
                // every block multiplies same-size panels: identical work
                // to the real build without the 800 MB C matrix
                be.gemm_into(&core_inv, &c_block, &mut out, false).unwrap();
            }
            out
        };
        let o_ref = build(&refb);
        let mut ref_secs = None;
        for (name, be) in backends {
            let r = bench("cac_cifar", 0, scaled(2), || build(be));
            let secs = r.mean.as_secs_f64();
            // agreement check against ref (bitwise for parallel; FMA
            // kernels differ only by fused rounding — tiny rel err)
            let got = build(be);
            let rel = o_ref.max_abs_diff(&got).unwrap()
                / o_ref.data().iter().map(|v| v.abs() as f64).fold(1e-12, f64::max);
            assert!(rel <= 1e-5, "{name} diverges from ref: rel err {rel}");
            println!(
                "  {name:>13} {}  ({:.2} GFLOP/s){}",
                fmt_dur(r.mean),
                gflops(macs, secs),
                match ref_secs {
                    Some(rs) => format!("  {:.2}x vs ref, rel err {rel:.1e}", rs / secs),
                    None => String::new(),
                }
            );
            push_row(&mut report, &r, name, &geometry, macs, ref_secs);
            if name == "ref" {
                ref_secs = Some(secs);
            }
        }
    }

    println!("\n=== d2r C-matrix build ===");
    let r = bench("d2r", 1, scaled(10), || mole::d2r::build_c_matrix(&w1, &g).unwrap());
    println!("  build_c_matrix(small)  {}", fmt_dur(r.mean));
    push_row(&mut report, &r, mole::backend::active().name(), "small", 0.0, None);

    println!("\n=== engine train/infer steps (backend: {}) ===", mole::backend::active().name());
    let engine = Engine::new(Manifest::load(Path::new("artifacts")).unwrap()).unwrap();
    println!("  engine: {}", engine.kind());
    let active = mole::backend::active().name();
    let mut trainer = Trainer::new_base(&engine, Variant::Base, 1).unwrap();
    let x = Tensor::new(&[64, 3, 16, 16], rng.normal_vec(64 * 768, 0.5)).unwrap();
    let y: Vec<i32> = (0..64).map(|i| (i % 10) as i32).collect();
    trainer.step(&x, &y, 0.01).unwrap(); // warm caches / compile
    let r = bench("train_base", 1, scaled(10), || trainer.step(&x, &y, 0.01).unwrap());
    println!("  train_step_base(b64)   {}  ({:.0} img/s)", fmt_dur(r.mean), r.throughput(64.0));
    push_row(&mut report, &r, active, "b64_small", 0.0, None);

    let key = MorphKey::generate(g, 16, 4).unwrap();
    let perm = ChannelPerm::generate(g.beta, 4);
    let layer = build_aug_conv(&w1, &b1, &key, &perm).unwrap();
    let mut at =
        Trainer::new_aug(&engine, layer.matrix().clone(), layer.bias().to_vec(), 1).unwrap();
    let t_rows = key.morph(&rows).unwrap();
    at.step(&t_rows, &y, 0.01).unwrap();
    let r = bench("train_aug", 1, scaled(10), || at.step(&t_rows, &y, 0.01).unwrap());
    println!("  train_step_aug(b64)    {}  ({:.0} img/s)", fmt_dur(r.mean), r.throughput(64.0));
    push_row(&mut report, &r, active, "b64_small", 0.0, None);

    let mut args: Vec<mole::runtime::Arg> = vec![
        mole::runtime::Arg::T(layer.matrix().clone()),
        mole::runtime::Arg::T(Tensor::new(&[g.beta], layer.bias().to_vec()).unwrap()),
    ];
    for p in init_params(&engine.manifest().aug_params, &mut rng) {
        args.push(mole::runtime::Arg::T(p));
    }
    args.push(mole::runtime::Arg::T(Tensor::new(&[32, g.d_len()],
        rng.normal_vec(32 * g.d_len(), 0.5)).unwrap()));
    engine.exec("infer_aug_small_b32", &args).unwrap();
    let r = bench("infer", 2, scaled(20), || engine.exec("infer_aug_small_b32", &args).unwrap());
    println!("  infer_aug(b32)         {}  ({:.0} img/s)", fmt_dur(r.mean), r.throughput(32.0));
    push_row(&mut report, &r, active, "b32_small", 0.0, None);

    let path = report.write().expect("write BENCH_hotpath.json");
    println!("\nwrote {} ({} rows)", path.display(), report.len());
}
