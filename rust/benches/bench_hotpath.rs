//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! backend comparison (ref vs parallel) on the GEMM kernel, the
//! block-diagonal morph, the Aug-Conv C^ac build at both SMALL and
//! VGG-16/CIFAR geometry, plus the engine train/infer step.
//!
//! Run: `cargo bench --bench bench_hotpath`

use mole::augconv::{build_aug_conv, build_aug_conv_from_c_on, ChannelPerm};
use mole::backend::{Backend, ParallelBackend, RefBackend};
use mole::bench::{bench, bench_auto, fmt_dur};
use mole::coordinator::trainer::{init_params, Trainer, Variant};
use mole::manifest::Manifest;
use mole::morph::MorphKey;
use mole::rng::Rng;
use mole::runtime::Engine;
use mole::tensor::Tensor;
use mole::Geometry;
use std::path::Path;
use std::time::Duration;

fn gflops(macs: f64, secs: f64) -> f64 {
    2.0 * macs / secs / 1e9
}

fn main() {
    mole::logging::init();
    let mut rng = Rng::new(1);
    let refb = RefBackend::new();
    let parb = ParallelBackend::new(0);
    let backends: [(&str, &dyn Backend); 2] = [("ref", &refb), ("parallel", &parb)];

    println!("=== GEMM kernel: ref vs parallel ===");
    for &(m, k, n) in &[(64usize, 768usize, 768usize), (256, 256, 4096), (768, 768, 4096)] {
        let a = Tensor::new(&[m, k], rng.normal_vec(m * k, 1.0)).unwrap();
        let b = Tensor::new(&[k, n], rng.normal_vec(k * n, 1.0)).unwrap();
        let mut means = Vec::new();
        for (name, be) in backends {
            let r = bench_auto("gemm", Duration::from_millis(600), || {
                be.gemm(&a, &b).unwrap()
            });
            println!(
                "  [{m:>4}x{k:>4}]x[{k:>4}x{n:>5}] {name:>9}  {}  {:.2} GFLOP/s",
                fmt_dur(r.mean),
                gflops((m * k * n) as f64, r.mean.as_secs_f64())
            );
            means.push(r.mean.as_secs_f64());
        }
        println!("           parallel speedup: {:.2}x", means[0] / means[1]);
    }

    let g = Geometry::SMALL;
    println!("\n=== provider morph (batch 64): ref vs parallel ===");
    let rows = Tensor::new(&[64, g.d_len()], rng.normal_vec(64 * g.d_len(), 1.0)).unwrap();
    for &kappa in &[16usize, 3, 1] {
        let key = MorphKey::generate(g, kappa, 2).unwrap();
        let macs = 64.0 * key.macs_per_row() as f64;
        for (name, be) in backends {
            let r = bench("morph", 3, 30, || key.morph_on(be, &rows).unwrap());
            println!(
                "  kappa={kappa:<3} q={:<4} {name:>9} {}  {:.2} GFLOP/s  ({:.0} img/s)",
                key.q(),
                fmt_dur(r.mean),
                gflops(macs, r.mean.as_secs_f64()),
                r.throughput(64.0)
            );
        }
    }

    println!("\n=== C^ac construction, SMALL geometry (block GEMM + shuffle) ===");
    let w1 = Tensor::new(
        &[g.beta, g.alpha, g.p, g.p],
        rng.normal_vec(g.beta * g.alpha * g.p * g.p, 0.3),
    )
    .unwrap();
    let b1 = vec![0.0f32; g.beta];
    let c_small = mole::d2r::build_c_matrix(&w1, &g).unwrap();
    for &kappa in &[16usize, 1] {
        let key = MorphKey::generate(g, kappa, 3).unwrap();
        let perm = ChannelPerm::generate(g.beta, 3);
        let macs = (g.d_len() * key.q() * g.f_len() / key.kappa() * key.kappa()) as f64;
        let mut means = Vec::new();
        for (name, be) in backends {
            let r = bench("cac", 1, 8, || {
                build_aug_conv_from_c_on(be, &c_small, &key, &perm).unwrap()
            });
            println!(
                "  kappa={kappa:<3} {name:>9} {}  ({:.2} GFLOP/s)",
                fmt_dur(r.mean),
                gflops(macs, r.mean.as_secs_f64())
            );
            means.push(r.mean.as_secs_f64());
        }
        println!("           parallel speedup: {:.2}x", means[0] / means[1]);
    }

    // The acceptance-criteria case: the Aug-Conv build at the paper's
    // VGG-16/CIFAR geometry (d_len=3072, f_len=65536) in its kappa=32
    // setting (q=96): all 32 block-row GEMMs of M'^-1 x C_blk. B panels
    // are synthetic (the timing is bound by the dense M'^-1 operand;
    // using random panels avoids materializing the ~800 MB real C).
    println!("\n=== C^ac build, VGG-16/CIFAR geometry (kappa=32, q=96) ===");
    {
        let cg = Geometry::CIFAR_VGG16;
        let q = 96usize;
        let kappa = cg.d_len() / q;
        let f_len = cg.f_len();
        let core_inv = Tensor::new(&[q, q], rng.normal_vec(q * q, 0.5)).unwrap();
        let c_block = Tensor::new(&[q, f_len], rng.normal_vec(q * f_len, 0.5)).unwrap();
        let macs = (kappa * q * q * f_len) as f64;
        let build = |be: &dyn Backend| -> Tensor {
            let mut out = Tensor::zeros(&[q, f_len]);
            for _blk in 0..kappa {
                // every block multiplies same-size panels: identical work
                // to the real build without the 800 MB C matrix
                be.gemm_into(&core_inv, &c_block, &mut out, false).unwrap();
            }
            out
        };
        let r_ref = bench("cac_cifar_ref", 0, 2, || build(&refb));
        let r_par = bench("cac_cifar_par", 0, 2, || build(&parb));
        // identical-output check (≤1e-5 rel err; bitwise by construction)
        let (o_ref, o_par) = (build(&refb), build(&parb));
        let rel = o_ref.max_abs_diff(&o_par).unwrap()
            / o_ref.data().iter().map(|v| v.abs() as f64).fold(1e-12, f64::max);
        assert!(rel <= 1e-5, "backend outputs diverge: rel err {rel}");
        let speedup = r_ref.mean.as_secs_f64() / r_par.mean.as_secs_f64();
        println!(
            "  ref      {}  ({:.2} GFLOP/s)",
            fmt_dur(r_ref.mean),
            gflops(macs, r_ref.mean.as_secs_f64())
        );
        println!(
            "  parallel {}  ({:.2} GFLOP/s)",
            fmt_dur(r_par.mean),
            gflops(macs, r_par.mean.as_secs_f64())
        );
        println!("  parallel speedup: {speedup:.2}x (outputs identical, rel err {rel:.1e})");
    }

    println!("\n=== d2r C-matrix build ===");
    let r = bench("d2r", 1, 10, || mole::d2r::build_c_matrix(&w1, &g).unwrap());
    println!("  build_c_matrix(small)  {}", fmt_dur(r.mean));

    println!("\n=== engine train/infer steps ===");
    let engine = Engine::new(Manifest::load(Path::new("artifacts")).unwrap()).unwrap();
    println!("  engine: {}", engine.kind());
    let mut trainer = Trainer::new_base(&engine, Variant::Base, 1).unwrap();
    let x = Tensor::new(&[64, 3, 16, 16], rng.normal_vec(64 * 768, 0.5)).unwrap();
    let y: Vec<i32> = (0..64).map(|i| (i % 10) as i32).collect();
    trainer.step(&x, &y, 0.01).unwrap(); // warm caches / compile
    let r = bench("train_base", 1, 10, || trainer.step(&x, &y, 0.01).unwrap());
    println!("  train_step_base(b64)   {}  ({:.0} img/s)", fmt_dur(r.mean), r.throughput(64.0));

    let key = MorphKey::generate(g, 16, 4).unwrap();
    let perm = ChannelPerm::generate(g.beta, 4);
    let layer = build_aug_conv(&w1, &b1, &key, &perm).unwrap();
    let mut at =
        Trainer::new_aug(&engine, layer.matrix().clone(), layer.bias().to_vec(), 1).unwrap();
    let t_rows = key.morph(&rows).unwrap();
    at.step(&t_rows, &y, 0.01).unwrap();
    let r = bench("train_aug", 1, 10, || at.step(&t_rows, &y, 0.01).unwrap());
    println!("  train_step_aug(b64)    {}  ({:.0} img/s)", fmt_dur(r.mean), r.throughput(64.0));

    let mut args: Vec<mole::runtime::Arg> = vec![
        mole::runtime::Arg::T(layer.matrix().clone()),
        mole::runtime::Arg::T(Tensor::new(&[g.beta], layer.bias().to_vec()).unwrap()),
    ];
    for p in init_params(&engine.manifest().aug_params, &mut rng) {
        args.push(mole::runtime::Arg::T(p));
    }
    args.push(mole::runtime::Arg::T(Tensor::new(&[32, g.d_len()],
        rng.normal_vec(32 * g.d_len(), 0.5)).unwrap()));
    engine.exec("infer_aug_small_b32", &args).unwrap();
    let r = bench("infer", 2, 20, || engine.exec("infer_aug_small_b32", &args).unwrap());
    println!("  infer_aug(b32)         {}  ({:.0} img/s)", fmt_dur(r.mean), r.throughput(32.0));
}
