//! Paper-number pinning: every quantitative claim the paper makes that is
//! derivable from geometry must fall out of our implementation. This is
//! the table-level regression suite for §4.2/§4.3 (the experiment-level
//! §4.4 lives in coordinator::experiment + bench_accuracy).

use mole::overhead::{self, catalog, OverheadReport};
use mole::security::{self, SecurityReport};
use mole::Geometry;

const CIFAR: Geometry = Geometry::CIFAR_VGG16;

#[test]
fn abstract_numbers_attack_probability() {
    // "the attack success probability for the adversary is 7.9x10^-90"
    // — this is P_r,bf = (64!)^-1 for VGG-16's beta = 64.
    let p = security::rand_brute_force(&CIFAR);
    let sci = p.scientific();
    assert!(
        sci.starts_with("7.9e-90") || sci.starts_with("8.0e-90"),
        "P_r,bf = {sci}, paper quotes 7.9e-90"
    );
}

#[test]
fn abstract_numbers_data_transmission() {
    // "data transmission overhead is 5.12%" — O_data/(dataset) under the
    // paper's (alpha m^2)^2 formula with CIFAR's 60k images.
    let r = OverheadReport::analyze(&catalog::vgg16_cifar(), 1, 60_000);
    assert!((r.paper_data_ratio - 0.0512).abs() < 1e-6, "{}", r.paper_data_ratio);
}

#[test]
fn section42_brute_force_exponents() {
    // N = 3072^2 at kappa=1; P <= 2^-(N-1)*1 - 1 with sigma=0.5
    let p = security::brute_force_bound(&CIFAR, 1, 0.5);
    assert!((p.log2 + 3072.0f64 * 3072.0).abs() < 2.0);
    // paper: "~2^-9x10^6"
    assert!(p.log2 < -9.0e6 && p.log2 > -9.9e6);
}

#[test]
fn section42_reversing_exponents() {
    // kappa=1: P_M,ar <= 2^-3072x2048 (paper's rounding)
    let p = security::aug_conv_reversing_bound(&CIFAR, 1, 0.5);
    let paper = -(3072.0f64 * 2048.0);
    assert!(
        (p.log2 - paper).abs() / paper.abs() < 0.001,
        "log2 {} vs paper {paper}",
        p.log2
    );
    // MC setting: 2^-1728 (alpha*beta*p^2 = 3*64*9)
    let p = security::aug_conv_reversing_bound(&CIFAR, 3, 0.5);
    assert!((p.log2 + 1728.0).abs() < 2.0, "{}", p.log2);
}

#[test]
fn section42_kappa_mc_and_dt_pairs() {
    // kappa_mc = alpha m^2 / n^2 = 3 (eq. 13)
    assert_eq!(CIFAR.kappa_mc(), 3);
    // "the attack requires 3,072 D^r-T^r pairs" (eq. 15, kappa = 1)
    assert_eq!(security::dt_pairs_required(&CIFAR, 1), 3072);
}

#[test]
fn section43_formula_values() {
    // eq. 16/17 raw values at the paper geometry
    assert_eq!(overhead::provider_macs_per_image(&CIFAR, 1), 3072 * 3072);
    assert_eq!(
        overhead::developer_extra_macs(&CIFAR),
        (32 * 32 - 9) * 3 * 64 * 32 * 32
    );
    // ResNet-152 "10x" (with the strided-stem n_out = 112)
    let r = OverheadReport::analyze(&catalog::resnet152_imagenet(), 1, 1_281_167);
    assert!(r.dev_overhead_ratio > 8.0 && r.dev_overhead_ratio < 13.0);
}

#[test]
fn full_reports_print() {
    // smoke the human-readable reports (they feed EXPERIMENTS.md)
    SecurityReport::analyze(CIFAR, 1, 0.5).print();
    SecurityReport::analyze(CIFAR, 3, 0.5).print();
    OverheadReport::analyze(&catalog::vgg16_cifar(), 1, 60_000).print();
}

#[test]
fn known_discrepancies_documented() {
    // The paper's "9%" computational overhead is NOT derivable from
    // VGG-16/CIFAR MACs: eq. 17 gives ~200M extra MACs vs ~313M total
    // (= ~64%). We pin the audited value so any future change that
    // "fixes" it silently is caught, and EXPERIMENTS.md documents it.
    let r = OverheadReport::analyze(&catalog::vgg16_cifar(), 1, 60_000);
    assert!(
        (r.dev_overhead_ratio - 0.637).abs() < 0.05,
        "audited VGG16/CIFAR ratio changed: {}",
        r.dev_overhead_ratio
    );
    // And the audited C^ac is 64/3 larger than the paper's (alpha m^2)^2.
    assert!((r.audited_data_ratio / r.paper_data_ratio - 64.0 / 3.0).abs() < 1e-9);
}
