//! Authenticated admin plane, end to end: the conformance scripts, the
//! table-driven negative-auth matrix, and rotate-under-load driven
//! entirely through MAC-authenticated admin sessions while a forged
//! client hammers the same server.
//!
//! Everything here runs against a **live** `Server` over real TCP and
//! builds its frames — valid and hostile — from the shared
//! [`mole::testkit::conformance`] driver, so the suites and the CI
//! smoke forge frames identically.

use mole::coordinator::batcher::BatcherConfig;
use mole::coordinator::client::{ClientConfig, MoleClient};
use mole::coordinator::registry::{demo_entry_from_keys, ModelRegistry, RegisteredModel};
use mole::coordinator::server::{ServeConfig, Server};
use mole::coordinator::{AdminClient, Message};
use mole::keys::KeyBundle;
use mole::manifest::Manifest;
use mole::rng::Rng;
use mole::runtime::{Arg, SharedEngine};
use mole::tensor::Tensor;
use mole::testkit::conformance::{AdminSigner, Driver, Expect, Step};
use mole::{Error, Geometry};
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Duration;

const KAPPA: usize = 16;
const SEED: u64 = 4242;

fn manifest() -> Manifest {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Manifest::load(&dir).unwrap()
}

fn epoch_keys() -> (KeyBundle, KeyBundle) {
    let root = KeyBundle::generate(Geometry::SMALL, KAPPA, SEED).unwrap();
    let rotated = root.rotate(SEED + 1).unwrap();
    (root, rotated)
}

fn entry(m: &Manifest, keys: &KeyBundle) -> RegisteredModel {
    demo_entry_from_keys(m, "alpha", keys, SEED).unwrap()
}

/// A live credential-gated server hosting `alpha@0`, plus the engine it
/// runs on (for bitwise reference inference) and the valid credential.
fn start_authed_server() -> (Server, SharedEngine, [u8; 32]) {
    let m = manifest();
    let engine = SharedEngine::new(m.clone());
    let (root, _) = epoch_keys();
    let cred = root.admin_credential();
    let registry = ModelRegistry::new(
        engine.clone(),
        BatcherConfig {
            max_batch: 8,
            timeout: Duration::from_millis(2),
            ..BatcherConfig::default()
        },
    );
    registry.register(entry(&m, &root)).unwrap();
    let server = Server::bind(
        registry,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            session_workers: 8,
            admin_credential: Some(cred),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    (server, engine, cred)
}

/// Reference: one row through the batch-1 artifact, per epoch.
fn single_row_logits(engine: &SharedEngine, e: &RegisteredModel, row: &[f32]) -> Vec<f32> {
    let mut args: Vec<Arg> = vec![
        Arg::T(e.layer.matrix().clone()),
        Arg::T(Tensor::new(&[e.layer.bias().len()], e.layer.bias().to_vec()).unwrap()),
    ];
    for p in &e.params {
        args.push(Arg::T(p.clone()));
    }
    args.push(Arg::T(Tensor::new(&[1, row.len()], row.to_vec()).unwrap()));
    engine.exec("infer_aug_small_b1", &args).unwrap()[0].data().to_vec()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Tentpole acceptance: the conformance scripts. A valid authenticated
/// round dispatches; every hostile variation — forged MAC, stale
/// (byte-identical replay) counter, bit-flipped payload, downgrade to
/// bare verbs — is refused with the typed `Fault::AdminAuth` *before*
/// any dispatch, and the session is cut.
#[test]
fn conformance_scripts_pin_the_auth_plane() {
    let (server, _engine, cred) = start_authed_server();
    let addr = server.local_addr();

    // --- valid script: challenge → status → drain-refused (verb-level
    // error keeps the session alive) → status again → clean close
    let mut d = Driver::connect(addr).unwrap();
    let nonce = d.challenge().unwrap();
    let mut signer = AdminSigner::new(cred, nonce);
    d.play(&[
        Step::Send(signer.seal(&Message::AdminStatus)),
        Step::Expect(Expect::Ok("alpha@0 state=active")),
        // draining a nonexistent epoch: authenticated, dispatched,
        // refused at the registry — a Generic fault, NOT an auth fault
        Step::Send(signer.seal(&Message::AdminDrain { model: "alpha".into(), epoch: 7 })),
        Step::Expect(Expect::GenericFault("no epoch 7")),
        Step::Send(signer.seal(&Message::AdminStatus)),
        Step::Expect(Expect::Ok("alpha@0 state=active")),
        Step::Send(Message::EndOfData),
        Step::Expect(Expect::EndOfData),
        Step::Expect(Expect::Eof),
    ])
    .unwrap();

    // --- forged MAC: one flipped MAC bit, otherwise perfect
    let mut d = Driver::connect(addr).unwrap();
    let nonce = d.challenge().unwrap();
    let mut signer = AdminSigner::new(cred, nonce);
    d.play(&[
        Step::Send(signer.mac_flipped(&Message::AdminStatus)),
        Step::Expect(Expect::AuthFault("MAC verification failed")),
        Step::Expect(Expect::Eof), // session cut after an auth failure
    ])
    .unwrap();

    // --- byte-identical replay: valid MAC, stale counter
    let mut d = Driver::connect(addr).unwrap();
    let nonce = d.challenge().unwrap();
    let mut signer = AdminSigner::new(cred, nonce);
    d.play(&[
        Step::Send(signer.seal(&Message::AdminStatus)),
        Step::Expect(Expect::Ok("alpha@0")),
        Step::Send(signer.replay()),
        Step::Expect(Expect::AuthFault("anti-replay")),
        Step::Expect(Expect::Eof),
    ])
    .unwrap();

    // --- bit-flipped payload: MAC no longer covers the bytes
    let mut d = Driver::connect(addr).unwrap();
    let nonce = d.challenge().unwrap();
    let mut signer = AdminSigner::new(cred, nonce);
    d.play(&[
        Step::Send(signer.tampered(&Message::AdminDrain { model: "alpha".into(), epoch: 0 })),
        Step::Expect(Expect::AuthFault("MAC verification failed")),
        Step::Expect(Expect::Eof),
    ])
    .unwrap();

    // --- downgrade inside an authenticated session: a bare verb after
    // the challenge is refused without dispatch
    let mut d = Driver::connect(addr).unwrap();
    d.challenge().unwrap();
    d.play(&[
        Step::Send(Message::AdminStatus),
        Step::Expect(Expect::AuthFault("must be authenticated")),
        Step::Expect(Expect::Eof),
    ])
    .unwrap();

    // --- cross-session replay: a frame sealed under session A's nonce
    // never verifies under session B's
    let mut a = Driver::connect(addr).unwrap();
    let nonce_a = a.challenge().unwrap();
    let mut signer_a = AdminSigner::new(cred, nonce_a);
    let stolen = signer_a.seal(&Message::AdminStatus);
    let mut b = Driver::connect(addr).unwrap();
    let nonce_b = b.challenge().unwrap();
    assert_ne!(nonce_a, nonce_b, "challenge nonces must be unique per session");
    b.play(&[
        Step::Send(stolen),
        Step::Expect(Expect::AuthFault("MAC verification failed")),
        Step::Expect(Expect::Eof),
    ])
    .unwrap();

    // --- raw garbage on the admin plane: no panic, typed rejection
    let mut d = Driver::connect(addr).unwrap();
    d.challenge().unwrap();
    d.raw(b"ML\xFFgarbage-after-the-magic").unwrap();
    match d.recv() {
        Ok(Message::Fault { .. }) | Err(_) => {}
        other => panic!("expected fault or cut, got {other:?}"),
    }

    // none of the hostile sessions dispatched anything: alpha@0 is
    // still the only lane and still active
    let mut admin = AdminClient::connect_with_credential(addr, cred).unwrap();
    let status = admin.status().unwrap();
    assert!(status.contains("alpha@0 state=active"), "{status}");
    assert_eq!(status.lines().count(), 1, "unexpected lane appeared: {status}");
    admin.finish().unwrap();

    server.stop();
}

/// Satellite: table-driven negative-auth matrix. Every cell pins the
/// exact typed `Error` the client surfaces AND leaves the registry
/// untouched. Cells run against a credential-gated server; the last
/// cell against a credential-free one.
#[test]
fn negative_auth_matrix() {
    let (server, _engine, cred) = start_authed_server();
    let addr = server.local_addr();

    // the credential-free sibling for the "authenticated frame when
    // auth is not configured" cell
    let m = manifest();
    let registry = ModelRegistry::new(
        SharedEngine::new(m.clone()),
        BatcherConfig {
            max_batch: 8,
            timeout: Duration::from_millis(2),
            ..BatcherConfig::default()
        },
    );
    registry.register(entry(&m, &epoch_keys().0)).unwrap();
    let plain_server = Server::bind(
        registry,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            session_workers: 2,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let plain_addr = plain_server.local_addr();

    type Cell = (&'static str, fn(SocketAddr, SocketAddr, [u8; 32]) -> Error);

    fn wrong_credential(addr: SocketAddr, _: SocketAddr, _cred: [u8; 32]) -> Error {
        let mut admin =
            AdminClient::connect_with_credential(addr, [0x5C; 32]).unwrap();
        admin.drain("alpha", 0).unwrap_err()
    }
    fn replayed_frame(addr: SocketAddr, _: SocketAddr, cred: [u8; 32]) -> Error {
        let mut d = Driver::connect(addr).unwrap();
        let nonce = d.challenge().unwrap();
        let mut signer = AdminSigner::new(cred, nonce);
        d.send(&signer.seal(&Message::AdminStatus)).unwrap();
        d.expect(&Expect::Ok("alpha@0")).unwrap();
        d.send(&signer.replay()).unwrap();
        match d.recv().unwrap() {
            Message::Fault { fault, .. } => fault.into_error(),
            other => panic!("expected Fault, got {other:?}"),
        }
    }
    fn reordered_counter(addr: SocketAddr, _: SocketAddr, cred: [u8; 32]) -> Error {
        let mut d = Driver::connect(addr).unwrap();
        let nonce = d.challenge().unwrap();
        let signer = AdminSigner::new(cred, nonce);
        // counters may skip forward (5 after nothing) but never move back
        d.send(&signer.seal_at(5, &Message::AdminStatus)).unwrap();
        d.expect(&Expect::Ok("alpha@0")).unwrap();
        d.send(&signer.seal_at(3, &Message::AdminStatus)).unwrap();
        match d.recv().unwrap() {
            Message::Fault { fault, .. } => fault.into_error(),
            other => panic!("expected Fault, got {other:?}"),
        }
    }
    fn tampered_payload(addr: SocketAddr, _: SocketAddr, cred: [u8; 32]) -> Error {
        let mut d = Driver::connect(addr).unwrap();
        let nonce = d.challenge().unwrap();
        let mut signer = AdminSigner::new(cred, nonce);
        d.send(&signer.tampered(&Message::AdminDrain { model: "alpha".into(), epoch: 0 }))
            .unwrap();
        match d.recv().unwrap() {
            Message::Fault { fault, .. } => fault.into_error(),
            other => panic!("expected Fault, got {other:?}"),
        }
    }
    fn unauthenticated_when_configured(
        addr: SocketAddr,
        _: SocketAddr,
        _cred: [u8; 32],
    ) -> Error {
        // the legacy loopback path, verbatim — refused because the
        // server has a credential installed
        let mut admin = AdminClient::connect(addr).unwrap();
        admin.status().unwrap_err()
    }
    fn authenticated_when_not_configured(
        _: SocketAddr,
        plain_addr: SocketAddr,
        cred: [u8; 32],
    ) -> Error {
        match AdminClient::connect_with_credential(plain_addr, cred) {
            Err(e) => e,
            Ok(_) => panic!("authenticated handshake succeeded without a server credential"),
        }
    }

    let cells: &[Cell] = &[
        ("wrong credential", wrong_credential),
        ("replayed frame", replayed_frame),
        ("reordered counter", reordered_counter),
        ("tampered payload", tampered_payload),
        ("unauthenticated frame, auth configured", unauthenticated_when_configured),
        ("authenticated frame, auth not configured", authenticated_when_not_configured),
    ];
    let pinned_msg: &[&str] = &[
        "MAC verification failed",
        "anti-replay",
        "anti-replay",
        "MAC verification failed",
        "must be authenticated",
        "not configured",
    ];
    for ((name, cell), want) in cells.iter().zip(pinned_msg) {
        let err = cell(addr, plain_addr, cred);
        // every cell is the same typed variant with its pinned message —
        // never a Generic fault, never a connection reset
        match &err {
            Error::AdminAuth(msg) => {
                assert!(msg.contains(want), "cell {name:?}: {msg:?} !~ {want:?}")
            }
            other => panic!("cell {name:?}: expected Error::AdminAuth, got {other:?}"),
        }
    }

    // no cell dispatched: both registries still hold exactly alpha@0,
    // active (the drains above never ran)
    let mut admin = AdminClient::connect_with_credential(addr, cred).unwrap();
    let status = admin.status().unwrap();
    assert_eq!(status.trim(), status.trim().lines().next().unwrap(), "{status}");
    assert!(status.contains("alpha@0 state=active"), "{status}");
    admin.finish().unwrap();
    let mut admin = AdminClient::connect(plain_addr).unwrap();
    let status = admin.status().unwrap();
    assert!(status.contains("alpha@0 state=active"), "{status}");
    admin.finish().unwrap();

    server.stop();
    plain_server.stop();
}

/// Satellite: rotate-under-load through the authenticated path. The
/// lifecycle barrier harness runs with every admin verb MAC-sealed,
/// while a concurrent forged-credential client is refused over and over
/// — and the in-flight inference stream is answered completely and
/// bitwise-correctly throughout.
#[test]
fn authed_rotate_under_load_with_forged_peer() {
    const CLIENTS: usize = 3;
    const PER_PHASE: usize = 3;

    let (server, engine, cred) = start_authed_server();
    let addr = server.local_addr();
    let m = manifest();
    let (root, rotated) = epoch_keys();

    // the rotated epoch's vault, readable by the server
    let vault = std::env::temp_dir().join(format!("mole_admin_auth_vault_{SEED}.key"));
    rotated.save(&vault).unwrap();

    let rotate_start = Arc::new(Barrier::new(CLIENTS + 1));
    let rotate_done = Arc::new(Barrier::new(CLIENTS + 1));

    let client_rows = |client_id: u64, phase: u64, n: usize, d_len: usize| -> Vec<Vec<f32>> {
        let mut rng = Rng::new(0xAA01 ^ (client_id * 7919) ^ (phase * 104729));
        (0..n).map(|_| rng.normal_vec(d_len, 0.5)).collect()
    };

    // the forger: a wrong-credential admin client hammering the server
    // for the whole run; every attempt must die typed, none may dispatch
    let stop = Arc::new(AtomicBool::new(false));
    let refused = Arc::new(AtomicU64::new(0));
    let forger = {
        let (stop, refused) = (stop.clone(), refused.clone());
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let mut admin =
                    AdminClient::connect_with_credential(addr, [0xEE; 32]).unwrap();
                // try the most damaging verbs: drain the live lane,
                // register a rogue model
                let err = admin.drain("alpha", 0).unwrap_err();
                assert!(matches!(err, Error::AdminAuth(_)), "{err}");
                let mut admin =
                    AdminClient::connect_with_credential(addr, [0xEE; 32]).unwrap();
                let err = admin.register("evil", "", 16, 1, 1).unwrap_err();
                assert!(matches!(err, Error::AdminAuth(_)), "{err}");
                refused.fetch_add(2, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(2));
            }
        })
    };

    let mut threads = Vec::new();
    for c in 0..CLIENTS as u64 {
        let (b1, b2) = (rotate_start.clone(), rotate_done.clone());
        threads.push(std::thread::spawn(move || {
            let mut client =
                MoleClient::connect_with(addr, ClientConfig::pinned("alpha", 0)).unwrap();
            assert_eq!(client.server_info().unwrap().epoch, 0);
            let d = client.d_len();
            let mut rng = Rng::new(0xAA01 ^ (c * 7919) ^ 104729);
            let rows1: Vec<Vec<f32>> =
                (0..PER_PHASE).map(|_| rng.normal_vec(d, 0.5)).collect();
            let got1 = client.infer_batch(&rows1).unwrap();
            b1.wait();
            b2.wait();
            let mut rng = Rng::new(0xAA01 ^ (c * 7919) ^ (2 * 104729));
            let rows2: Vec<Vec<f32>> =
                (0..PER_PHASE).map(|_| rng.normal_vec(d, 0.5)).collect();
            let got2 = client.infer_batch(&rows2).unwrap();
            client.finish().unwrap();
            (got1, got2)
        }));
    }

    rotate_start.wait();
    // the live rollover, entirely MAC-authenticated
    let mut admin = AdminClient::connect_with_credential(addr, cred).unwrap();
    let detail = admin
        .register("alpha", vault.to_str().unwrap(), KAPPA, SEED, SEED)
        .unwrap();
    assert!(detail.contains("registered alpha@1"), "{detail}");
    let detail = admin.drain("alpha", 0).unwrap();
    assert!(detail.contains("successor 1"), "{detail}");
    rotate_done.wait();

    let results: Vec<_> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    stop.store(true, Ordering::Relaxed);
    forger.join().unwrap();
    std::fs::remove_file(&vault).ok();

    // bitwise ground truth per epoch
    let (e0, e1) = (entry(&m, &root), entry(&m, &rotated));
    let d_len = m.geometry("small").unwrap().d_len();
    for (c, (got1, got2)) in results.iter().enumerate() {
        assert_eq!(got1.len(), PER_PHASE);
        assert_eq!(got2.len(), PER_PHASE);
        for (i, row) in client_rows(c as u64, 1, PER_PHASE, d_len).iter().enumerate() {
            assert_eq!(
                bits(&got1[i]),
                bits(&single_row_logits(&engine, &e0, row)),
                "client {c} phase-1 row {i} wrong on epoch 0"
            );
        }
        for (i, row) in client_rows(c as u64, 2, PER_PHASE, d_len).iter().enumerate() {
            assert_eq!(
                bits(&got2[i]),
                bits(&single_row_logits(&engine, &e1, row)),
                "client {c} phase-2 row {i} wrong on epoch 1"
            );
        }
    }

    // the forger really ran, was always refused, and dispatched nothing
    assert!(refused.load(Ordering::Relaxed) > 0, "forger never got a turn");
    let status = admin.status().unwrap();
    assert!(!status.contains("evil"), "forged register dispatched: {status}");
    assert!(status.contains("alpha@0 state=draining successor=1"), "{status}");
    assert!(status.contains("alpha@1 state=active"), "{status}");
    admin.finish().unwrap();

    // zero lost or duplicated responses on the wire
    assert_eq!(
        server.metrics().responses.get(),
        (2 * CLIENTS * PER_PHASE) as u64,
        "a response was lost or duplicated"
    );

    server.stop();
}
