//! Authenticated admin plane, end to end: the conformance scripts, the
//! table-driven negative-auth matrix, and rotate-under-load driven
//! entirely through MAC-authenticated admin sessions while a forged
//! client hammers the same server.
//!
//! Everything here runs against a **live** `Server` over real TCP and
//! builds its frames — valid and hostile — from the shared
//! [`mole::testkit::conformance`] driver, so the suites and the CI
//! smoke forge frames identically.

use mole::coordinator::batcher::BatcherConfig;
use mole::coordinator::client::{ClientConfig, MoleClient};
use mole::coordinator::registry::{demo_entry_from_keys, ModelRegistry, RegisteredModel};
use mole::coordinator::server::{ServeConfig, Server};
use mole::coordinator::{AdminClient, Message, OperatorTable};
use mole::keys::KeyBundle;
use mole::manifest::Manifest;
use mole::rng::Rng;
use mole::runtime::{Arg, SharedEngine};
use mole::tensor::Tensor;
use mole::testkit::conformance::{AdminSigner, Driver, Expect, Step};
use mole::{Error, Geometry};
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Duration;

const KAPPA: usize = 16;
const SEED: u64 = 4242;

fn manifest() -> Manifest {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Manifest::load(&dir).unwrap()
}

fn epoch_keys() -> (KeyBundle, KeyBundle) {
    let root = KeyBundle::generate(Geometry::SMALL, KAPPA, SEED).unwrap();
    let rotated = root.rotate(SEED + 1).unwrap();
    (root, rotated)
}

fn entry(m: &Manifest, keys: &KeyBundle) -> RegisteredModel {
    demo_entry_from_keys(m, "alpha", keys, SEED).unwrap()
}

/// A live credential-gated server hosting `alpha@0`, plus the engine it
/// runs on (for bitwise reference inference) and the valid credential.
fn start_authed_server() -> (Server, SharedEngine, [u8; 32]) {
    let m = manifest();
    let engine = SharedEngine::new(m.clone());
    let (root, _) = epoch_keys();
    let cred = root.admin_credential();
    let registry = ModelRegistry::new(
        engine.clone(),
        BatcherConfig {
            max_batch: 8,
            timeout: Duration::from_millis(2),
            ..BatcherConfig::default()
        },
    );
    registry.register(entry(&m, &root)).unwrap();
    let server = Server::bind(
        registry,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            session_workers: 8,
            admin_credential: Some(cred),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    (server, engine, cred)
}

/// Reference: one row through the batch-1 artifact, per epoch.
fn single_row_logits(engine: &SharedEngine, e: &RegisteredModel, row: &[f32]) -> Vec<f32> {
    let mut args: Vec<Arg> = vec![
        Arg::T(e.layer.matrix().clone()),
        Arg::T(Tensor::new(&[e.layer.bias().len()], e.layer.bias().to_vec()).unwrap()),
    ];
    for p in &e.params {
        args.push(Arg::T(p.clone()));
    }
    args.push(Arg::T(Tensor::new(&[1, row.len()], row.to_vec()).unwrap()));
    engine.exec("infer_aug_small_b1", &args).unwrap()[0].data().to_vec()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Tentpole acceptance: the conformance scripts. A valid authenticated
/// round dispatches; every hostile variation — forged MAC, stale
/// (byte-identical replay) counter, bit-flipped payload, downgrade to
/// bare verbs — is refused with the typed `Fault::AdminAuth` *before*
/// any dispatch, and the session is cut.
#[test]
fn conformance_scripts_pin_the_auth_plane() {
    let (server, _engine, cred) = start_authed_server();
    let addr = server.local_addr();

    // --- valid script: challenge → status → drain-refused (verb-level
    // error keeps the session alive) → status again → clean close.
    // Every reply on the authenticated session arrives **sealed** (v8)
    // and is opened/verified before matching.
    let mut d = Driver::connect(addr).unwrap();
    let nonce = d.challenge().unwrap();
    let mut signer = AdminSigner::new(cred, nonce);
    d.send(&signer.seal(&Message::AdminStatus)).unwrap();
    d.expect_sealed(&signer, &Expect::Ok("alpha@0 state=active")).unwrap();
    // draining a nonexistent epoch: authenticated, dispatched, refused
    // at the registry — a Generic fault, NOT an auth fault, and still
    // sealed like every reply to an authenticated verb
    d.send(&signer.seal(&Message::AdminDrain { model: "alpha".into(), epoch: 7 }))
        .unwrap();
    d.expect_sealed(&signer, &Expect::GenericFault("no epoch 7")).unwrap();
    d.send(&signer.seal(&Message::AdminStatus)).unwrap();
    d.expect_sealed(&signer, &Expect::Ok("alpha@0 state=active")).unwrap();
    d.play(&[
        Step::Send(Message::EndOfData),
        Step::Expect(Expect::EndOfData),
        Step::Expect(Expect::Eof),
    ])
    .unwrap();

    // --- forged MAC: one flipped MAC bit, otherwise perfect
    let mut d = Driver::connect(addr).unwrap();
    let nonce = d.challenge().unwrap();
    let mut signer = AdminSigner::new(cred, nonce);
    d.play(&[
        Step::Send(signer.mac_flipped(&Message::AdminStatus)),
        Step::Expect(Expect::AuthFault("MAC verification failed")),
        Step::Expect(Expect::Eof), // session cut after an auth failure
    ])
    .unwrap();

    // --- byte-identical replay: valid MAC, stale counter (the refusal
    // itself is a cleartext fault: there is no authenticated verb to
    // answer)
    let mut d = Driver::connect(addr).unwrap();
    let nonce = d.challenge().unwrap();
    let mut signer = AdminSigner::new(cred, nonce);
    d.send(&signer.seal(&Message::AdminStatus)).unwrap();
    d.expect_sealed(&signer, &Expect::Ok("alpha@0")).unwrap();
    d.play(&[
        Step::Send(signer.replay()),
        Step::Expect(Expect::AuthFault("anti-replay")),
        Step::Expect(Expect::Eof),
    ])
    .unwrap();

    // --- bit-flipped payload: MAC no longer covers the bytes
    let mut d = Driver::connect(addr).unwrap();
    let nonce = d.challenge().unwrap();
    let mut signer = AdminSigner::new(cred, nonce);
    d.play(&[
        Step::Send(signer.tampered(&Message::AdminDrain { model: "alpha".into(), epoch: 0 })),
        Step::Expect(Expect::AuthFault("MAC verification failed")),
        Step::Expect(Expect::Eof),
    ])
    .unwrap();

    // --- downgrade inside an authenticated session: a bare verb after
    // the challenge is refused without dispatch
    let mut d = Driver::connect(addr).unwrap();
    d.challenge().unwrap();
    d.play(&[
        Step::Send(Message::AdminStatus),
        Step::Expect(Expect::AuthFault("must be authenticated")),
        Step::Expect(Expect::Eof),
    ])
    .unwrap();

    // --- cross-session replay: a frame sealed under session A's nonce
    // never verifies under session B's
    let mut a = Driver::connect(addr).unwrap();
    let nonce_a = a.challenge().unwrap();
    let mut signer_a = AdminSigner::new(cred, nonce_a);
    let stolen = signer_a.seal(&Message::AdminStatus);
    let mut b = Driver::connect(addr).unwrap();
    let nonce_b = b.challenge().unwrap();
    assert_ne!(nonce_a, nonce_b, "challenge nonces must be unique per session");
    b.play(&[
        Step::Send(stolen),
        Step::Expect(Expect::AuthFault("MAC verification failed")),
        Step::Expect(Expect::Eof),
    ])
    .unwrap();

    // --- raw garbage on the admin plane: no panic, typed rejection
    let mut d = Driver::connect(addr).unwrap();
    d.challenge().unwrap();
    d.raw(b"ML\xFFgarbage-after-the-magic").unwrap();
    match d.recv() {
        Ok(Message::Fault { .. }) | Err(_) => {}
        other => panic!("expected fault or cut, got {other:?}"),
    }

    // none of the hostile sessions dispatched anything: alpha@0 is
    // still the only lane and still active
    let mut admin = AdminClient::connect_with_credential(addr, cred).unwrap();
    let status = admin.status().unwrap();
    assert!(status.contains("alpha@0 state=active"), "{status}");
    assert_eq!(status.lines().count(), 1, "unexpected lane appeared: {status}");
    admin.finish().unwrap();

    server.stop();
}

/// Satellite: table-driven negative-auth matrix. Every cell pins the
/// exact typed `Error` the client surfaces AND leaves the registry
/// untouched. Cells run against a credential-gated server; the last
/// cell against a credential-free one.
#[test]
fn negative_auth_matrix() {
    let (server, _engine, cred) = start_authed_server();
    let addr = server.local_addr();

    // the credential-free sibling for the "authenticated frame when
    // auth is not configured" cell
    let m = manifest();
    let registry = ModelRegistry::new(
        SharedEngine::new(m.clone()),
        BatcherConfig {
            max_batch: 8,
            timeout: Duration::from_millis(2),
            ..BatcherConfig::default()
        },
    );
    registry.register(entry(&m, &epoch_keys().0)).unwrap();
    let plain_server = Server::bind(
        registry,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            session_workers: 2,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let plain_addr = plain_server.local_addr();

    // the operator-roster sibling for the revoked / wrong-operator
    // cells: vault roster [ada, mallory], mallory revoked live before
    // the cells run; "ghost" is a derivable label that was never added
    let mut roster_vault = epoch_keys().0;
    roster_vault.add_operator("ada").unwrap();
    roster_vault.add_operator("mallory").unwrap();
    let table = Arc::new(OperatorTable::from_bundle(&roster_vault));
    let m2 = manifest();
    let registry = ModelRegistry::new(
        SharedEngine::new(m2.clone()),
        BatcherConfig {
            max_batch: 8,
            timeout: Duration::from_millis(2),
            ..BatcherConfig::default()
        },
    );
    registry.register(entry(&m2, &epoch_keys().0)).unwrap();
    let ops_server = Server::bind(
        registry,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            session_workers: 2,
            operators: Some(table.clone()),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    table.revoke("mallory").unwrap();

    struct Ctx {
        addr: SocketAddr,
        plain_addr: SocketAddr,
        ops_addr: SocketAddr,
        cred: [u8; 32],
        mallory: [u8; 32],
        ghost: [u8; 32],
    }
    let ctx = Ctx {
        addr,
        plain_addr,
        ops_addr: ops_server.local_addr(),
        cred,
        mallory: roster_vault.operator_credential("mallory"),
        ghost: roster_vault.operator_credential("ghost"),
    };

    type Cell = (&'static str, fn(&Ctx) -> Error);

    fn wrong_credential(ctx: &Ctx) -> Error {
        let mut admin =
            AdminClient::connect_with_credential(ctx.addr, [0x5C; 32]).unwrap();
        admin.drain("alpha", 0).unwrap_err()
    }
    fn replayed_frame(ctx: &Ctx) -> Error {
        let mut d = Driver::connect(ctx.addr).unwrap();
        let nonce = d.challenge().unwrap();
        let mut signer = AdminSigner::new(ctx.cred, nonce);
        d.send(&signer.seal(&Message::AdminStatus)).unwrap();
        d.expect_sealed(&signer, &Expect::Ok("alpha@0")).unwrap();
        d.send(&signer.replay()).unwrap();
        match d.recv().unwrap() {
            Message::Fault { fault, .. } => fault.into_error(),
            other => panic!("expected Fault, got {other:?}"),
        }
    }
    fn reordered_counter(ctx: &Ctx) -> Error {
        let mut d = Driver::connect(ctx.addr).unwrap();
        let nonce = d.challenge().unwrap();
        let signer = AdminSigner::new(ctx.cred, nonce);
        // counters may skip forward (5 after nothing) but never move back
        d.send(&signer.seal_at(5, &Message::AdminStatus)).unwrap();
        d.expect_sealed_at(&signer, 5, &Expect::Ok("alpha@0")).unwrap();
        d.send(&signer.seal_at(3, &Message::AdminStatus)).unwrap();
        match d.recv().unwrap() {
            Message::Fault { fault, .. } => fault.into_error(),
            other => panic!("expected Fault, got {other:?}"),
        }
    }
    fn tampered_payload(ctx: &Ctx) -> Error {
        let mut d = Driver::connect(ctx.addr).unwrap();
        let nonce = d.challenge().unwrap();
        let mut signer = AdminSigner::new(ctx.cred, nonce);
        d.send(&signer.tampered(&Message::AdminDrain { model: "alpha".into(), epoch: 0 }))
            .unwrap();
        match d.recv().unwrap() {
            Message::Fault { fault, .. } => fault.into_error(),
            other => panic!("expected Fault, got {other:?}"),
        }
    }
    fn unauthenticated_when_configured(ctx: &Ctx) -> Error {
        // the legacy loopback path, verbatim — refused because the
        // server has a credential installed
        let mut admin = AdminClient::connect(ctx.addr).unwrap();
        admin.status().unwrap_err()
    }
    fn authenticated_when_not_configured(ctx: &Ctx) -> Error {
        match AdminClient::connect_with_credential(ctx.plain_addr, ctx.cred) {
            Err(e) => e,
            Ok(_) => panic!("authenticated handshake succeeded without a server credential"),
        }
    }
    fn revoked_credential(ctx: &Ctx) -> Error {
        // mallory's credential was live once; after the live revoke her
        // frames die with a refusal that *names* the revocation (she
        // held a real credential — telling her so leaks nothing)
        let mut admin =
            AdminClient::connect_with_credential(ctx.ops_addr, ctx.mallory).unwrap();
        admin.drain("alpha", 0).unwrap_err()
    }
    fn wrong_operator_credential(ctx: &Ctx) -> Error {
        // a correctly-derived credential for a label that was never in
        // the roster: anonymous MAC failure, indistinguishable from a
        // random forgery
        let mut admin =
            AdminClient::connect_with_credential(ctx.ops_addr, ctx.ghost).unwrap();
        admin.register("evil", "", 16, 1, 1).unwrap_err()
    }
    /// A MITM "server": completes the admin handshake, then answers the
    /// first sealed verb via `answer(nonce, sealed_verb_frame)`.
    fn mitm_admin<F>(ctx: &Ctx, answer: F) -> Error
    where
        F: FnOnce([u8; 32], Message) -> Message + Send + 'static,
    {
        use mole::coordinator::protocol::{read_message, write_message};
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let mitm_addr = listener.local_addr().unwrap();
        let mitm = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let nonce = [0x4D; 32];
            match read_message(&mut s).unwrap() {
                Message::AdminHello => {}
                other => panic!("MITM expected AdminHello, got {other:?}"),
            }
            write_message(&mut s, &Message::AdminChallenge { nonce }).unwrap();
            let verb = read_message(&mut s).unwrap();
            write_message(&mut s, &answer(nonce, verb)).unwrap();
            // hold the socket open until the client has judged the reply
            let _ = read_message(&mut s);
        });
        let mut admin =
            AdminClient::connect_with_credential(mitm_addr, ctx.cred).unwrap();
        let err = admin.status().unwrap_err();
        drop(admin);
        mitm.join().unwrap();
        err
    }
    fn forged_reply(ctx: &Ctx) -> Error {
        // the pre-v8 hole, replayed verbatim: a cleartext AdminOk in
        // place of the sealed reply must die typed at the client
        mitm_admin(ctx, |_nonce, _verb| Message::AdminOk {
            detail: "you have been drained, trust me".into(),
        })
    }
    fn replayed_reply(ctx: &Ctx) -> Error {
        // a perfectly-sealed reply answering the WRONG request counter
        // (a replay from earlier in the session): refused by the
        // counter-binding check, not the MAC
        let cred = ctx.cred;
        mitm_admin(ctx, move |nonce, _verb| {
            let stale = AdminSigner::new(cred, nonce);
            stale.seal_reply_at(7, &Message::AdminOk { detail: "stale ok".into() })
        })
    }

    let cells: &[Cell] = &[
        ("wrong credential", wrong_credential),
        ("replayed frame", replayed_frame),
        ("reordered counter", reordered_counter),
        ("tampered payload", tampered_payload),
        ("unauthenticated frame, auth configured", unauthenticated_when_configured),
        ("authenticated frame, auth not configured", authenticated_when_not_configured),
        ("revoked operator credential", revoked_credential),
        ("wrong-operator credential", wrong_operator_credential),
        ("forged cleartext reply", forged_reply),
        ("replayed sealed reply", replayed_reply),
    ];
    let pinned_msg: &[&str] = &[
        "MAC verification failed",
        "anti-replay",
        "anti-replay",
        "MAC verification failed",
        "must be authenticated",
        "not configured",
        "was revoked",
        "MAC verification failed",
        "forged or downgraded",
        "does not answer request",
    ];
    for ((name, cell), want) in cells.iter().zip(pinned_msg) {
        let err = cell(&ctx);
        // every cell is the same typed variant with its pinned message —
        // never a Generic fault, never a connection reset
        match &err {
            Error::AdminAuth(msg) => {
                assert!(msg.contains(want), "cell {name:?}: {msg:?} !~ {want:?}")
            }
            other => panic!("cell {name:?}: expected Error::AdminAuth, got {other:?}"),
        }
    }

    // no cell dispatched: all three registries still hold exactly
    // alpha@0, active (the drains and rogue registers above never ran)
    let mut admin = AdminClient::connect_with_credential(addr, cred).unwrap();
    let status = admin.status().unwrap();
    assert_eq!(status.trim(), status.trim().lines().next().unwrap(), "{status}");
    assert!(status.contains("alpha@0 state=active"), "{status}");
    admin.finish().unwrap();
    let mut admin = AdminClient::connect(plain_addr).unwrap();
    let status = admin.status().unwrap();
    assert!(status.contains("alpha@0 state=active"), "{status}");
    admin.finish().unwrap();
    // the surviving operator still works after mallory's revocation —
    // and sees the untouched registry
    let ada = roster_vault.operator_credential("ada");
    let mut admin =
        AdminClient::connect_with_credential(ctx.ops_addr, ada).unwrap();
    let status = admin.status().unwrap();
    assert!(status.contains("alpha@0 state=active"), "{status}");
    assert!(!status.contains("evil"), "ghost register dispatched: {status}");
    admin.finish().unwrap();
    assert_eq!(table.live_labels(), vec!["ada".to_string()]);
    assert_eq!(table.revoked_labels(), vec!["mallory".to_string()]);

    server.stop();
    plain_server.stop();
    ops_server.stop();
}

/// Tentpole: live revocation over real TCP. Two operators hold
/// concurrent authenticated sessions; one revokes the other through the
/// wire (`AdminRevoke`) and the revocation lands on the victim's very
/// next frame — no restart, no grace period — while the survivor keeps
/// driving the registry. Every verb lands attributed in the 0600 audit
/// log.
#[test]
fn live_revocation_over_tcp_with_audit() {
    let m = manifest();
    let engine = SharedEngine::new(m.clone());
    let mut vault = epoch_keys().0;
    vault.add_operator("ada").unwrap();
    vault.add_operator("grace").unwrap();
    let table = Arc::new(OperatorTable::from_bundle(&vault));
    let registry = ModelRegistry::new(
        engine,
        BatcherConfig {
            max_batch: 8,
            timeout: Duration::from_millis(2),
            ..BatcherConfig::default()
        },
    );
    registry.register(entry(&m, &vault)).unwrap();
    let audit_path = std::env::temp_dir()
        .join(format!("mole_admin_audit_e2e_{}.log", std::process::id()));
    std::fs::remove_file(&audit_path).ok();
    let server = Server::bind(
        registry,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            session_workers: 4,
            operators: Some(table.clone()),
            audit_log: Some(audit_path.clone()),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let addr = server.local_addr();
    let ada = vault.operator_credential("ada");
    let grace = vault.operator_credential("grace");

    // two concurrent authenticated sessions, one per operator
    let mut a = AdminClient::connect_with_credential(addr, ada).unwrap();
    let mut g = AdminClient::connect_with_credential(addr, grace).unwrap();
    assert!(g.status().unwrap().contains("alpha@0"), "grace must start live");

    // ada revokes grace over the wire — mid-run, no restart
    let detail = a.revoke_operator("grace").unwrap();
    assert!(detail.contains("grace"), "{detail}");

    // grace's ALREADY-OPEN session dies typed on its next frame…
    let err = g.status().unwrap_err();
    match &err {
        Error::AdminAuth(msg) => assert!(msg.contains("was revoked"), "{msg}"),
        other => panic!("expected AdminAuth, got {other:?}"),
    }
    // …and a fresh handshake under the revoked credential fails the same
    let mut g2 = AdminClient::connect_with_credential(addr, grace).unwrap();
    let err = g2.status().unwrap_err();
    assert!(
        matches!(&err, Error::AdminAuth(m) if m.contains("was revoked")),
        "{err}"
    );

    // the survivor still drives the registry, and its replies still
    // verify (sealed under ada's own credential)
    assert!(a.status().unwrap().contains("alpha@0 state=active"));
    a.finish().unwrap();
    server.stop();

    // audit log: attributed, append-only, secret-tight permissions
    let text = std::fs::read_to_string(&audit_path).unwrap();
    assert!(text.contains("operator=\"grace\" verb=status outcome=ok"), "{text}");
    assert!(text.contains("operator=\"ada\" verb=revoke outcome=ok"), "{text}");
    assert!(text.contains("operator=\"(unauthenticated)\""), "{text}");
    #[cfg(unix)]
    {
        use std::os::unix::fs::PermissionsExt;
        let mode =
            std::fs::metadata(&audit_path).unwrap().permissions().mode() & 0o777;
        assert_eq!(mode, 0o600, "audit log must be 0600");
    }
    std::fs::remove_file(&audit_path).ok();
}

/// Satellite: rotate-under-load through the authenticated path. The
/// lifecycle barrier harness runs with every admin verb MAC-sealed,
/// while a concurrent forged-credential client is refused over and over
/// — and the in-flight inference stream is answered completely and
/// bitwise-correctly throughout.
#[test]
fn authed_rotate_under_load_with_forged_peer() {
    const CLIENTS: usize = 3;
    const PER_PHASE: usize = 3;

    let (server, engine, cred) = start_authed_server();
    let addr = server.local_addr();
    let m = manifest();
    let (root, rotated) = epoch_keys();

    // the rotated epoch's vault, readable by the server
    let vault = std::env::temp_dir().join(format!("mole_admin_auth_vault_{SEED}.key"));
    rotated.save(&vault).unwrap();

    let rotate_start = Arc::new(Barrier::new(CLIENTS + 1));
    let rotate_done = Arc::new(Barrier::new(CLIENTS + 1));

    let client_rows = |client_id: u64, phase: u64, n: usize, d_len: usize| -> Vec<Vec<f32>> {
        let mut rng = Rng::new(0xAA01 ^ (client_id * 7919) ^ (phase * 104729));
        (0..n).map(|_| rng.normal_vec(d_len, 0.5)).collect()
    };

    // the forger: a wrong-credential admin client hammering the server
    // for the whole run; every attempt must die typed, none may dispatch
    let stop = Arc::new(AtomicBool::new(false));
    let refused = Arc::new(AtomicU64::new(0));
    let forger = {
        let (stop, refused) = (stop.clone(), refused.clone());
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let mut admin =
                    AdminClient::connect_with_credential(addr, [0xEE; 32]).unwrap();
                // try the most damaging verbs: drain the live lane,
                // register a rogue model
                let err = admin.drain("alpha", 0).unwrap_err();
                assert!(matches!(err, Error::AdminAuth(_)), "{err}");
                let mut admin =
                    AdminClient::connect_with_credential(addr, [0xEE; 32]).unwrap();
                let err = admin.register("evil", "", 16, 1, 1).unwrap_err();
                assert!(matches!(err, Error::AdminAuth(_)), "{err}");
                refused.fetch_add(2, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(2));
            }
        })
    };

    let mut threads = Vec::new();
    for c in 0..CLIENTS as u64 {
        let (b1, b2) = (rotate_start.clone(), rotate_done.clone());
        threads.push(std::thread::spawn(move || {
            let mut client =
                MoleClient::connect_with(addr, ClientConfig::pinned("alpha", 0)).unwrap();
            assert_eq!(client.server_info().unwrap().epoch, 0);
            let d = client.d_len();
            let mut rng = Rng::new(0xAA01 ^ (c * 7919) ^ 104729);
            let rows1: Vec<Vec<f32>> =
                (0..PER_PHASE).map(|_| rng.normal_vec(d, 0.5)).collect();
            let got1 = client.infer_batch(&rows1).unwrap();
            b1.wait();
            b2.wait();
            let mut rng = Rng::new(0xAA01 ^ (c * 7919) ^ (2 * 104729));
            let rows2: Vec<Vec<f32>> =
                (0..PER_PHASE).map(|_| rng.normal_vec(d, 0.5)).collect();
            let got2 = client.infer_batch(&rows2).unwrap();
            client.finish().unwrap();
            (got1, got2)
        }));
    }

    rotate_start.wait();
    // the live rollover, entirely MAC-authenticated
    let mut admin = AdminClient::connect_with_credential(addr, cred).unwrap();
    let detail = admin
        .register("alpha", vault.to_str().unwrap(), KAPPA, SEED, SEED)
        .unwrap();
    assert!(detail.contains("registered alpha@1"), "{detail}");
    let detail = admin.drain("alpha", 0).unwrap();
    assert!(detail.contains("successor 1"), "{detail}");
    rotate_done.wait();

    let results: Vec<_> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    stop.store(true, Ordering::Relaxed);
    forger.join().unwrap();
    std::fs::remove_file(&vault).ok();

    // bitwise ground truth per epoch
    let (e0, e1) = (entry(&m, &root), entry(&m, &rotated));
    let d_len = m.geometry("small").unwrap().d_len();
    for (c, (got1, got2)) in results.iter().enumerate() {
        assert_eq!(got1.len(), PER_PHASE);
        assert_eq!(got2.len(), PER_PHASE);
        for (i, row) in client_rows(c as u64, 1, PER_PHASE, d_len).iter().enumerate() {
            assert_eq!(
                bits(&got1[i]),
                bits(&single_row_logits(&engine, &e0, row)),
                "client {c} phase-1 row {i} wrong on epoch 0"
            );
        }
        for (i, row) in client_rows(c as u64, 2, PER_PHASE, d_len).iter().enumerate() {
            assert_eq!(
                bits(&got2[i]),
                bits(&single_row_logits(&engine, &e1, row)),
                "client {c} phase-2 row {i} wrong on epoch 1"
            );
        }
    }

    // the forger really ran, was always refused, and dispatched nothing
    assert!(refused.load(Ordering::Relaxed) > 0, "forger never got a turn");
    let status = admin.status().unwrap();
    assert!(!status.contains("evil"), "forged register dispatched: {status}");
    assert!(status.contains("alpha@0 state=draining successor=1"), "{status}");
    assert!(status.contains("alpha@1 state=active"), "{status}");
    admin.finish().unwrap();

    // zero lost or duplicated responses on the wire
    assert_eq!(
        server.metrics().responses.get(),
        (2 * CLIENTS * PER_PHASE) as u64,
        "a response was lost or duplicated"
    );

    server.stop();
}
