//! Loopback end-to-end tests for the concurrent TCP serving layer:
//! a real `TcpListener` on port 0, many concurrent pipelined client
//! sessions, and the hard invariant that micro-batched serving is
//! **bitwise identical** to one-at-a-time inference.

use mole::coordinator::batcher::BatcherConfig;
use mole::coordinator::loadgen::{run, LoadgenConfig};
use mole::coordinator::protocol::{read_message, write_message, Message};
use mole::coordinator::server::{demo_model, ServeConfig, Server, ServingClient};
use mole::manifest::Manifest;
use mole::rng::Rng;
use mole::runtime::{Arg, SharedEngine};
use mole::tensor::Tensor;
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Duration;

const KAPPA: usize = 16;
const SEED: u64 = 4242;

fn manifest() -> Manifest {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Manifest::load(&dir).unwrap()
}

fn start_server(max_batch: usize, timeout_ms: u64) -> (Server, SharedEngine) {
    let m = manifest();
    let engine = SharedEngine::new(m.clone());
    let (model, fingerprint) = demo_model(&m, KAPPA, SEED).unwrap();
    let server = Server::bind(
        engine.clone(),
        model,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            session_workers: 8,
            batcher: BatcherConfig {
                max_batch,
                timeout: Duration::from_millis(timeout_ms),
                ..BatcherConfig::default()
            },
            kappa: KAPPA,
            fingerprint,
        },
    )
    .unwrap();
    (server, engine)
}

/// Reference: run one row through the batch-1 artifact directly on the
/// shared engine — the "one-at-a-time inference" the batcher must match.
/// (`model` is a fresh `demo_model(KAPPA, SEED)` — bitwise identical to
/// the one the server is holding.)
fn single_row_logits(
    engine: &SharedEngine,
    model: &mole::coordinator::batcher::ServingModel,
    row: &[f32],
) -> Vec<f32> {
    let mut args: Vec<Arg> = vec![
        Arg::T(model.cac.clone()),
        Arg::T(Tensor::new(&[model.bias.len()], model.bias.clone()).unwrap()),
    ];
    for p in &model.params {
        args.push(Arg::T(p.clone()));
    }
    args.push(Arg::T(Tensor::new(&[1, row.len()], row.to_vec()).unwrap()));
    let out = engine.exec("infer_aug_small_b1", &args).unwrap();
    out[0].data().to_vec()
}

fn client_rows(client_id: u64, n: usize, d_len: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(0xE2E ^ (client_id * 7919));
    (0..n).map(|_| rng.normal_vec(d_len, 0.5)).collect()
}

/// N concurrent pipelined TCP clients; every batched response must be
/// bitwise identical to the same row pushed through the batch-1 artifact
/// alone. Exercises cross-connection coalescing, out-of-order completion
/// and the id → logits pairing end to end.
#[test]
fn batched_tcp_serving_is_bitwise_identical_to_single() {
    const CLIENTS: u64 = 6;
    const PER_CLIENT: usize = 4;
    let (server, engine) = start_server(8, 20);
    let addr = server.local_addr();

    let mut threads = Vec::new();
    for c in 0..CLIENTS {
        threads.push(std::thread::spawn(move || {
            let mut client = ServingClient::connect(addr).unwrap();
            assert_eq!(client.hello.kappa, KAPPA);
            assert!(!client.hello.fingerprint.is_empty());
            let rows = client_rows(c, PER_CLIENT, client.d_len());
            // pipeline everything before reading: the server sees a burst
            for (i, row) in rows.iter().enumerate() {
                client.send_request(i as u64, row).unwrap();
            }
            let mut got: HashMap<u64, Vec<f32>> = HashMap::new();
            for _ in 0..PER_CLIENT {
                let (id, logits) = client.recv_response().unwrap();
                assert!(got.insert(id, logits).is_none(), "duplicate id {id}");
            }
            client.finish().unwrap();
            got
        }));
    }
    let per_client: Vec<HashMap<u64, Vec<f32>>> =
        threads.into_iter().map(|t| t.join().unwrap()).collect();

    let d_len = engine.manifest().geometry("small").unwrap().d_len();
    let (reference_model, _) = demo_model(engine.manifest(), KAPPA, SEED).unwrap();
    for (c, got) in per_client.iter().enumerate() {
        let rows = client_rows(c as u64, PER_CLIENT, d_len);
        for (i, row) in rows.iter().enumerate() {
            let want = single_row_logits(&engine, &reference_model, row);
            let have = &got[&(i as u64)];
            let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let have_bits: Vec<u32> = have.iter().map(|v| v.to_bits()).collect();
            assert_eq!(
                want_bits, have_bits,
                "client {c} row {i}: batched logits differ from single-row inference"
            );
        }
    }

    let m = server.metrics();
    let total = (CLIENTS as usize * PER_CLIENT) as u64;
    assert_eq!(m.responses.get(), total);
    assert_eq!(m.connections.get(), CLIENTS);
    assert_eq!(m.faults.get(), 0);
    assert!(m.bytes_in.get() > 0 && m.bytes_out.get() > 0);
    assert!(
        m.batches.get() < total,
        "pipelined burst produced no coalescing at all (batches={})",
        m.batches.get()
    );
    server.stop();
}

/// A malformed frame faults its own session; other sessions and the
/// server keep working, and a row of the wrong length faults only that
/// request.
#[test]
fn bad_frames_fault_the_session_not_the_server() {
    let (server, _engine) = start_server(8, 2);
    let addr = server.local_addr();

    // session 1: garbage after the handshake
    {
        let mut sock = std::net::TcpStream::connect(addr).unwrap();
        match read_message(&mut sock).unwrap() {
            Message::Hello { .. } => {}
            other => panic!("expected Hello, got {other:?}"),
        }
        use std::io::Write;
        sock.write_all(b"XXXXXXXXXXXX").unwrap();
        sock.flush().unwrap();
        // server answers Fault (then EndOfData) and ends the session
        match read_message(&mut sock).unwrap() {
            Message::Fault { msg } => assert!(msg.contains("magic"), "{msg}"),
            other => panic!("expected Fault, got {other:?}"),
        }
    }

    // session 2: wrong row length faults the request, not the session
    {
        let mut client = ServingClient::connect(addr).unwrap();
        let d = client.d_len();
        client.send_request(1, &[0.0; 3]).unwrap();
        let err = client.recv_response().unwrap_err();
        assert!(err.to_string().contains("request 1"), "{err}");
        assert!(err.to_string().contains("infer row len 3"), "{err}");
        // same session still serves a correct request
        client.send_request(2, &vec![0.1; d]).unwrap();
        let (id, logits) = client.recv_response().unwrap();
        assert_eq!(id, 2);
        assert!(!logits.is_empty());
        client.finish().unwrap();
    }

    assert!(server.metrics().faults.get() >= 2);
    server.stop();
}

/// The loadgen driver against a live server: all requests answered, no
/// errors, latency recorded per request, clean shutdown counts intact.
#[test]
fn loadgen_drives_the_server_cleanly() {
    let (server, _engine) = start_server(32, 4);
    let report = run(&LoadgenConfig {
        addr: server.local_addr().to_string(),
        connections: 4,
        requests_per_conn: 16,
        pipeline: 4,
        seed: 9,
    })
    .unwrap();
    assert_eq!(report.ok, 64);
    assert_eq!(report.errors, 0);
    assert_eq!(report.latency.count(), 64);
    assert!(report.throughput_rps() > 0.0);
    assert!(report.bytes_out > 0);
    let line = report.report();
    assert!(line.contains("ok=64") && line.contains("errors=0"), "{line}");
    assert_eq!(server.metrics().responses.get(), 64);
    server.stop();
}

/// `EndOfData` handshake: the server flushes in-flight responses before
/// confirming, so a client that sends its close immediately after its
/// last request still gets every response.
#[test]
fn end_of_data_flushes_in_flight_responses() {
    let (server, _engine) = start_server(8, 10);
    let mut sock = std::net::TcpStream::connect(server.local_addr()).unwrap();
    let hello = read_message(&mut sock).unwrap();
    let d = match hello {
        Message::Hello { geometry, .. } => geometry.d_len(),
        other => panic!("expected Hello, got {other:?}"),
    };
    let mut rng = Rng::new(77);
    for id in 0..5u64 {
        let row = Tensor::new(&[d], rng.normal_vec(d, 0.5)).unwrap();
        write_message(&mut sock, &Message::InferRequest { id, row }).unwrap();
    }
    // close immediately — responses are still pending server-side
    write_message(&mut sock, &Message::EndOfData).unwrap();
    let mut seen = 0;
    loop {
        match read_message(&mut sock).unwrap() {
            Message::InferResponse { .. } => seen += 1,
            Message::EndOfData => break,
            other => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(seen, 5, "EndOfData must not race ahead of in-flight responses");
    server.stop();
}
