//! Loopback end-to-end tests for the multi-tenant TCP serving layer:
//! a real `TcpListener` on port 0, a registry serving two models across
//! key epochs, many concurrent pipelined `MoleClient` sessions, and the
//! hard invariant that per-model micro-batched serving is **bitwise
//! identical** to one-at-a-time inference on the same lane.

use mole::coordinator::batcher::BatcherConfig;
use mole::coordinator::client::{ClientConfig, MoleClient};
use mole::coordinator::loadgen::{run, LoadgenConfig};
use mole::coordinator::protocol::read_message;
use mole::coordinator::registry::{demo_entry_from_keys, ModelRegistry, RegisteredModel};
use mole::coordinator::server::{ServeConfig, Server};
use mole::coordinator::{Fault, Message, EPOCH_LATEST};
use mole::keys::KeyBundle;
use mole::manifest::Manifest;
use mole::rng::Rng;
use mole::runtime::{Arg, SharedEngine};
use mole::tensor::Tensor;
use mole::Geometry;
use std::io::Write;
use std::path::PathBuf;
use std::time::Duration;

const KAPPA: usize = 16;
const ALPHA_SEED: u64 = 4242;
const BETA_SEED: u64 = 777;

fn manifest() -> Manifest {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Manifest::load(&dir).unwrap()
}

/// The three lanes every test serves: `alpha` mid-rollover (epochs 0 and
/// 1 side by side) and `beta` already rotated to epoch 1.
fn entries(m: &Manifest) -> Vec<RegisteredModel> {
    let alpha_root = KeyBundle::generate(Geometry::SMALL, KAPPA, ALPHA_SEED).unwrap();
    let alpha_next = alpha_root.rotate(ALPHA_SEED + 1).unwrap();
    let beta = KeyBundle::generate(Geometry::SMALL, KAPPA, BETA_SEED)
        .unwrap()
        .rotate(BETA_SEED + 1)
        .unwrap();
    vec![
        demo_entry_from_keys(m, "alpha", &alpha_root, ALPHA_SEED).unwrap(),
        demo_entry_from_keys(m, "alpha", &alpha_next, ALPHA_SEED).unwrap(),
        demo_entry_from_keys(m, "beta", &beta, BETA_SEED).unwrap(),
    ]
}

fn start_server(max_batch: usize, timeout_ms: u64) -> (Server, SharedEngine) {
    let m = manifest();
    let engine = SharedEngine::new(m.clone());
    let registry = ModelRegistry::new(
        engine.clone(),
        BatcherConfig {
            max_batch,
            timeout: Duration::from_millis(timeout_ms),
            ..BatcherConfig::default()
        },
    );
    for e in entries(&m) {
        registry.register(e).unwrap();
    }
    let server = Server::bind(
        registry,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            session_workers: 8,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    (server, engine)
}

/// Reference: run one row through the batch-1 artifact directly on the
/// shared engine — the "one-at-a-time inference" each lane must match.
/// (`entry` is rebuilt from the same keys — bitwise identical to the one
/// the server registered.)
fn single_row_logits(engine: &SharedEngine, entry: &RegisteredModel, row: &[f32]) -> Vec<f32> {
    let mut args: Vec<Arg> = vec![
        Arg::T(entry.layer.matrix().clone()),
        Arg::T(Tensor::new(&[entry.layer.bias().len()], entry.layer.bias().to_vec()).unwrap()),
    ];
    for p in &entry.params {
        args.push(Arg::T(p.clone()));
    }
    args.push(Arg::T(Tensor::new(&[1, row.len()], row.to_vec()).unwrap()));
    let out = engine.exec("infer_aug_small_b1", &args).unwrap();
    out[0].data().to_vec()
}

fn client_rows(client_id: u64, n: usize, d_len: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(0xE2E ^ (client_id * 7919));
    (0..n).map(|_| rng.normal_vec(d_len, 0.5)).collect()
}

/// Six concurrent pipelined clients spread over three lanes (two models,
/// different key epochs, served by one `Server`); every batched response
/// must be bitwise identical to the same row pushed through the batch-1
/// artifact with that lane's model. Exercises per-lane coalescing,
/// epoch pinning, out-of-order completion and the id → logits pairing
/// end to end.
#[test]
fn multi_model_batched_serving_is_bitwise_identical_to_single() {
    const PER_CLIENT: usize = 4;
    // (requested model, epoch) per client, two clients per lane
    const LANES: [(&str, u32); 6] =
        [("alpha", 0), ("alpha", 1), ("beta", 1), ("alpha", 0), ("alpha", 1), ("beta", 1)];
    let (server, engine) = start_server(8, 20);
    let addr = server.local_addr();

    let mut threads = Vec::new();
    for (c, (model, epoch)) in LANES.iter().enumerate() {
        let (model, epoch) = (*model, *epoch); // own the lane pin ('static)
        threads.push(std::thread::spawn(move || {
            let mut client =
                MoleClient::connect_with(addr, ClientConfig::pinned(model, epoch)).unwrap();
            let info = client.server_info().unwrap().clone();
            assert_eq!(info.model, model);
            assert_eq!(info.epoch, epoch);
            assert_eq!(info.kappa, KAPPA);
            assert!(!info.fingerprint.is_empty());
            let rows = client_rows(c as u64, PER_CLIENT, client.d_len());
            // pipeline the whole batch: the server sees a burst
            let logits = client.infer_batch(&rows).unwrap();
            client.finish().unwrap();
            logits
        }));
    }
    let per_client: Vec<Vec<Vec<f32>>> =
        threads.into_iter().map(|t| t.join().unwrap()).collect();

    // rebuild each lane's entry and compare bitwise
    let m = manifest();
    let d_len = m.geometry("small").unwrap().d_len();
    let reference = entries(&m);
    let lane_entry = |model: &str, epoch: u32| {
        reference.iter().find(|e| e.name == model && e.epoch == epoch).unwrap()
    };
    for (c, got) in per_client.iter().enumerate() {
        let (model, epoch) = LANES[c];
        let entry = lane_entry(model, epoch);
        let rows = client_rows(c as u64, PER_CLIENT, d_len);
        for (i, row) in rows.iter().enumerate() {
            let want = single_row_logits(&engine, entry, row);
            let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let have_bits: Vec<u32> = got[i].iter().map(|v| v.to_bits()).collect();
            assert_eq!(
                want_bits, have_bits,
                "client {c} ({model}@{epoch}) row {i}: batched logits differ from \
                 single-row inference"
            );
        }
    }

    // different lanes genuinely differ (different key epochs ⇒ different
    // C^ac): a row answered by alpha@0 and alpha@1 must not agree
    let row = &client_rows(0, 1, d_len)[0];
    assert_ne!(
        single_row_logits(&engine, lane_entry("alpha", 0), row),
        single_row_logits(&engine, lane_entry("alpha", 1), row),
        "epoch rotation did not change the served model"
    );

    let sm = server.metrics();
    let total = (LANES.len() * PER_CLIENT) as u64;
    assert_eq!(sm.responses.get(), total);
    assert_eq!(sm.connections.get(), LANES.len() as u64);
    assert_eq!(sm.faults.get(), 0);
    assert!(sm.bytes_in.get() > 0 && sm.bytes_out.get() > 0);
    // per-lane traffic accounting + per-lane coalescing: each lane saw
    // its 8 rows in fewer than 8 batches
    for lane in server.registry().lanes() {
        let lm = &lane.handle().metrics;
        assert_eq!(lm.responses.get(), 2 * PER_CLIENT as u64, "{}", lane.name());
        assert!(
            lm.batches.get() < 2 * PER_CLIENT as u64,
            "lane {}@{} produced no coalescing at all (batches={})",
            lane.name(),
            lane.epoch(),
            lm.batches.get()
        );
    }
    server.stop();
}

/// One connection can mix traffic for several lanes: explicit
/// `send_request_to` routing answers from the addressed model/epoch.
#[test]
fn per_request_routing_crosses_lanes() {
    let (server, engine) = start_server(8, 2);
    let mut client = MoleClient::connect(server.local_addr()).unwrap();
    // default session lane = first registered model at latest epoch
    let info = client.server_info().unwrap().clone();
    assert_eq!((info.model.as_str(), info.epoch), ("alpha", 1));

    let m = manifest();
    let reference = entries(&m);
    let row = client_rows(7, 1, client.d_len()).remove(0);
    client.send_request_to(1, "alpha", 0, &row).unwrap();
    client.send_request_to(2, "beta", EPOCH_LATEST, &row).unwrap();
    client.send_request(3, &row).unwrap(); // session lane: alpha@1
    let mut got = std::collections::HashMap::new();
    for _ in 0..3 {
        let (id, logits) = client.recv_response().unwrap();
        got.insert(id, logits);
    }
    client.finish().unwrap();

    let expect = |name: &str, epoch: u32| {
        let e = reference.iter().find(|e| e.name == name && e.epoch == epoch).unwrap();
        single_row_logits(&engine, e, &row)
    };
    assert_eq!(got[&1], expect("alpha", 0));
    assert_eq!(got[&2], expect("beta", 1));
    assert_eq!(got[&3], expect("alpha", 1));
    server.stop();
}

/// Unknown models/epochs fault the handshake (typed, not a hang or a
/// decode error), and a v1-style `Hello` gets the version-mismatch
/// `Fault` required by the negotiation contract.
#[test]
fn unknown_models_and_old_peers_get_typed_faults() {
    let (server, _engine) = start_server(8, 2);
    let addr = server.local_addr();

    // unknown model name
    let err = MoleClient::connect_with(addr, ClientConfig::model("nope")).unwrap_err();
    assert!(err.to_string().contains("unknown model"), "{err}");
    // known model, unknown epoch
    let err = MoleClient::connect_with(addr, ClientConfig::pinned("alpha", 9)).unwrap_err();
    assert!(err.to_string().contains("no epoch 9"), "{err}");

    // legacy v1 Hello (starts with α=3 where the version belongs): the
    // server must answer with a Fault naming the mismatch
    let mut sock = std::net::TcpStream::connect(addr).unwrap();
    sock.write_all(&mole::testkit::net::legacy_v1_hello_frame()).unwrap();
    sock.flush().unwrap();
    match read_message(&mut sock).unwrap() {
        Message::Fault { fault: Fault::Generic { msg }, .. } => {
            assert!(msg.contains("version mismatch"), "{msg}");
            assert!(msg.contains("v3") && msg.contains("v6"), "{msg}");
        }
        other => panic!("expected a generic Fault frame, got {other:?}"),
    }

    server.stop();
}

/// A malformed frame faults its own session; other sessions and the
/// server keep working, and a row of the wrong length faults only that
/// request.
#[test]
fn bad_frames_fault_the_session_not_the_server() {
    let (server, _engine) = start_server(8, 2);
    let addr = server.local_addr();

    // session 1: garbage instead of a Hello
    {
        let mut sock = std::net::TcpStream::connect(addr).unwrap();
        sock.write_all(b"XXXXXXXXXXXX").unwrap();
        sock.flush().unwrap();
        // server answers a typed Fault and ends the session
        match read_message(&mut sock).unwrap() {
            Message::Fault { fault, .. } => {
                assert!(fault.to_string().contains("magic"), "{fault}")
            }
            other => panic!("expected a Fault frame, got {other:?}"),
        }
    }

    // session 2: wrong row length faults the request, not the session;
    // a bad per-request model faults that request only
    {
        let mut client = MoleClient::connect(addr).unwrap();
        let d = client.d_len();
        client.send_request(1, &[0.0; 3]).unwrap();
        let err = client.recv_response().unwrap_err();
        assert!(err.to_string().contains("request 1"), "{err}");
        assert!(err.to_string().contains("infer row len 3"), "{err}");
        client.send_request_to(2, "ghost", EPOCH_LATEST, &vec![0.1; d]).unwrap();
        let err = client.recv_response().unwrap_err();
        assert!(err.to_string().contains("request 2"), "{err}");
        assert!(err.to_string().contains("unknown model"), "{err}");
        // same session still serves a correct request
        client.send_request(3, &vec![0.1; d]).unwrap();
        let (id, logits) = client.recv_response().unwrap();
        assert_eq!(id, 3);
        assert!(!logits.is_empty());
        client.finish().unwrap();
    }

    assert!(server.metrics().faults.get() >= 3);
    server.stop();
}

/// The loadgen driver against a live multi-model server: all requests
/// answered from the pinned lane, no errors, latency recorded per
/// request, clean shutdown counts intact.
#[test]
fn loadgen_drives_the_server_cleanly() {
    let (server, _engine) = start_server(32, 4);
    let report = run(&LoadgenConfig {
        addr: server.local_addr().to_string(),
        connections: 4,
        requests_per_conn: 16,
        pipeline: 4,
        rate: 0.0,
        seed: 9,
        model: "beta".to_string(),
        epoch: 1,
    })
    .unwrap();
    assert_eq!(report.ok, 64);
    assert_eq!(report.errors, 0);
    assert_eq!(report.latency.count(), 64);
    assert!(report.throughput_rps() > 0.0);
    assert!(report.bytes_out > 0);
    let line = report.report();
    assert!(line.contains("ok=64") && line.contains("errors=0"), "{line}");
    assert_eq!(server.metrics().responses.get(), 64);
    // all traffic landed on the pinned lane
    let beta = server.registry().resolve("beta", 1).unwrap();
    assert_eq!(beta.handle().metrics.responses.get(), 64);
    let alpha = server.registry().resolve("alpha", EPOCH_LATEST).unwrap();
    assert_eq!(alpha.handle().metrics.responses.get(), 0);
    // pinning an epoch the registry does not serve fails every request
    let report = run(&LoadgenConfig {
        addr: server.local_addr().to_string(),
        connections: 1,
        requests_per_conn: 4,
        pipeline: 1,
        rate: 0.0,
        seed: 9,
        model: "beta".to_string(),
        epoch: 0,
    })
    .unwrap();
    assert_eq!(report.ok, 0);
    assert!(report.errors > 0);
    server.stop();
}

/// `EndOfData` handshake: the server flushes in-flight responses before
/// confirming, so a client that sends its close immediately after its
/// last request still gets every response.
#[test]
fn end_of_data_flushes_in_flight_responses() {
    let (server, _engine) = start_server(8, 10);
    let mut client = MoleClient::connect(server.local_addr()).unwrap();
    let d = client.d_len();
    let mut rng = Rng::new(77);
    for id in 0..5u64 {
        client.send_request(id, &rng.normal_vec(d, 0.5)).unwrap();
    }
    // close immediately — responses are still pending server-side
    let drained = client.finish().unwrap();
    assert_eq!(drained, 5, "EndOfData must not race ahead of in-flight responses");
    server.stop();
}
