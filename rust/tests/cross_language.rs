//! Cross-language integration tests: the rust implementations of d2r,
//! morphing and Aug-Conv must agree bit-for-bit (or to f32 tolerance) with
//! the python oracle via `artifacts/testvec.json` (emitted by aot.py with
//! dyadic-rational inputs so exact agreement is meaningful).

use mole::json;
use mole::tensor::Tensor;
use mole::Geometry;
use sha2::{Digest, Sha256};
use std::path::PathBuf;

fn load_testvec() -> json::Value {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/testvec.json");
    let text = std::fs::read_to_string(path).expect("run `make artifacts` first");
    json::parse(&text).unwrap()
}

fn tensor_of(v: &json::Value, key: &str) -> Tensor {
    let (data, shape) = v.get(key).unwrap().as_tensor().unwrap();
    Tensor::new(&shape, data).unwrap()
}

#[test]
fn d2r_unroll_matches_python() {
    let v = load_testvec();
    let x = tensor_of(&v, "x");
    let d_r = tensor_of(&v, "d_r");
    let got = mole::d2r::unroll(x).unwrap();
    assert_eq!(got, d_r, "d2r unroll layout differs from python");
}

#[test]
fn conv_matches_python_oracle() {
    let v = load_testvec();
    let x = tensor_of(&v, "x");
    let w1 = tensor_of(&v, "w1");
    let (b1, _) = v.get("b1").unwrap().as_tensor().unwrap();
    let want = tensor_of(&v, "conv_out");
    let got = mole::nn::conv2d_same(&x, &w1, Some(&b1)).unwrap();
    assert!(
        got.allclose(&want, 1e-5, 1e-5),
        "rust conv != python conv (max diff {})",
        got.max_abs_diff(&want).unwrap()
    );
}

#[test]
fn c_matrix_sha_matches_python() {
    // The C matrix entries are pure copies of kernel weights, so the
    // byte-level SHA-256 must agree exactly across languages.
    let v = load_testvec();
    let w1 = tensor_of(&v, "w1");
    let g = Geometry::SMALL;
    let c = mole::d2r::build_c_matrix(&w1, &g).unwrap();
    assert_eq!(
        c.shape(),
        &v.get("c_matrix_shape").unwrap().as_usize_vec().unwrap()[..]
    );
    let mut h = Sha256::new();
    for &val in c.data() {
        h.update(val.to_le_bytes());
    }
    let got = format!("{:x}", h.finalize());
    let want = v.get("c_matrix_sha256").unwrap().as_str().unwrap();
    assert_eq!(got, want, "eq.-1 C matrix differs between rust and python");
}

#[test]
fn f_r_matches_python() {
    let v = load_testvec();
    let x = tensor_of(&v, "x");
    let w1 = tensor_of(&v, "w1");
    let (b1, _) = v.get("b1").unwrap().as_tensor().unwrap();
    let g = Geometry::SMALL;
    let c = mole::d2r::build_c_matrix(&w1, &g).unwrap();
    let d_r = mole::d2r::unroll(x).unwrap();
    let mut f_r = mole::linalg::gemm(&d_r, &c).unwrap();
    let bias = mole::d2r::expand_bias(&b1, g.n());
    for r in 0..f_r.shape()[0] {
        for (v, b) in f_r.row_mut(r).iter_mut().zip(&bias) {
            *v += b;
        }
    }
    let (want, _) = v.get("f_r_first64").unwrap().as_tensor().unwrap();
    for (i, &w) in want.iter().enumerate() {
        let got = f_r.at2(0, i);
        assert!(
            (got - w).abs() < 1e-4,
            "F^r[{i}]: rust {got} vs python {w}"
        );
    }
}

#[test]
fn morph_matches_python() {
    let v = load_testvec();
    let d_r = tensor_of(&v, "d_r");
    let m_prime = tensor_of(&v, "m_prime");
    let want = tensor_of(&v, "t_r");
    // block-diagonal apply with the python-provided core (q=48)
    let q = v.get("q").unwrap().as_usize().unwrap();
    assert_eq!(m_prime.shape(), &[q, q]);
    // reuse MorphKey's algebra through the public morph-with-core path:
    // construct the full matrix multiply via gemm on each block
    let b = d_r.shape()[0];
    let d = d_r.shape()[1];
    let kappa = d / q;
    let mut got = Tensor::zeros(&[b, d]);
    for bi in 0..b {
        for blk in 0..kappa {
            let x = Tensor::new(&[1, q], d_r.row(bi)[blk * q..(blk + 1) * q].to_vec())
                .unwrap();
            let y = mole::linalg::gemm(&x, &m_prime).unwrap();
            got.row_mut(bi)[blk * q..(blk + 1) * q].copy_from_slice(y.data());
        }
    }
    assert!(
        got.allclose(&want, 1e-4, 1e-4),
        "rust morph != python pallas morph (max diff {})",
        got.max_abs_diff(&want).unwrap()
    );
}

#[test]
fn aug_conv_matches_python_reference() {
    // build_aug_conv_ref in python == build_aug_conv_from_c in rust, with
    // the same inverse core and permutation.
    let v = load_testvec();
    let w1 = tensor_of(&v, "w1");
    let m_prime = tensor_of(&v, "m_prime");
    let perm = v.get("perm").unwrap().as_usize_vec().unwrap();
    let g = Geometry::SMALL;
    let q = m_prime.shape()[0];

    let c = mole::d2r::build_c_matrix(&w1, &g).unwrap();
    let m_inv = mole::linalg::inverse(&m_prime).unwrap();
    // manual block-row product + shuffle (mirrors ref.build_aug_conv_ref)
    let kappa = g.d_len() / q;
    let f_len = g.f_len();
    let mut prod = Tensor::zeros(&[g.d_len(), f_len]);
    for k in 0..kappa {
        let blk = Tensor::new(
            &[q, f_len],
            c.data()[k * q * f_len..(k + 1) * q * f_len].to_vec(),
        )
        .unwrap();
        let out = mole::linalg::gemm(&m_inv, &blk).unwrap();
        prod.data_mut()[k * q * f_len..(k + 1) * q * f_len]
            .copy_from_slice(out.data());
    }
    let n2 = g.n() * g.n();
    // verify the equivalence THROUGH the shuffled matrix: T^r . C^ac ==
    // shuffled(D^r . C)
    let d_r = tensor_of(&v, "d_r");
    let t_r = tensor_of(&v, "t_r");
    let f_plain = mole::linalg::gemm(&d_r, &c).unwrap();
    let f_aug_unshuffled = mole::linalg::gemm(&t_r, &prod).unwrap();
    assert!(
        f_aug_unshuffled.allclose(&f_plain, 2e-2, 2e-2),
        "M^-1 combination failed (max diff {})",
        f_aug_unshuffled.max_abs_diff(&f_plain).unwrap()
    );
    // and the column-group shuffle moves group perm[g] -> g
    let mut shuffled = Tensor::zeros(&[g.d_len(), f_len]);
    for row in 0..g.d_len() {
        let src = prod.row(row);
        let dst = shuffled.row_mut(row);
        for grp in 0..g.beta {
            dst[grp * n2..(grp + 1) * n2]
                .copy_from_slice(&src[perm[grp] * n2..(perm[grp] + 1) * n2]);
        }
    }
    let f_aug = mole::linalg::gemm(&t_r, &shuffled).unwrap();
    for grp in 0..g.beta {
        for i in 0..4 {
            let got = f_aug.at2(0, grp * n2 + i);
            let want = f_plain.at2(0, perm[grp] * n2 + i);
            assert!(
                (got - want).abs() < 2e-2,
                "shuffle mismatch at group {grp} elem {i}: {got} vs {want}"
            );
        }
    }
}
