//! End-to-end tests for the bulk delivery plane (protocol v7): a real
//! `TcpListener` fronting a [`ChunkStore`] through the evented server's
//! `DatasetHello` detach path, pulled by the real striped/resumable
//! client. The invariants pinned here are the PR's acceptance bar:
//!
//! * striped (N=4) and unstriped pulls are **bitwise identical**;
//! * a transfer killed at a deterministic chunk boundary resumes from
//!   its journal with **zero re-fetches of verified chunks** (proved by
//!   the store's per-chunk serve counters, not by trusting the report);
//! * past the session budget, bulk pulls shed with the typed
//!   `Fault::Overloaded` — they can't starve inference lanes;
//! * a byzantine server (corrupt chunk payload, lying chunk index —
//!   forged via `testkit::conformance::hostile_delivery`) is survived
//!   by the single automatic retry or surfaced typed, never delivered.

use mole::coordinator::batcher::BatcherConfig;
use mole::coordinator::delivery::{self, ChunkStore, PullOptions, VecSink, KILL_MARKER};
use mole::coordinator::protocol::{read_message, write_message, Message};
use mole::coordinator::registry::ModelRegistry;
use mole::coordinator::server::{ServeConfig, Server};
use mole::coordinator::DeliveryClient;
use mole::manifest::Manifest;
use mole::rng::Rng;
use mole::runtime::SharedEngine;
use mole::testkit::conformance::hostile_delivery;
use mole::testkit::net::pipe_pair;
use mole::{Error, Result};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;

/// Deterministic mixed-content blob: zero stretches + noise, so both
/// compressed and plain chunks occur.
fn mixed_blob(len: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(len);
    while out.len() < len {
        if rng.below(3) == 0 {
            let n = (128 + rng.below(512)).min(len - out.len());
            out.extend(std::iter::repeat(rng.below(4) as u8).take(n));
        } else {
            let n = (1 + rng.below(256)).min(len - out.len());
            for _ in 0..n {
                out.push(rng.below(256) as u8);
            }
        }
    }
    out
}

/// A pure delivery server: empty model registry (built-in manifest
/// contract, no lanes) + the dataset on the evented accept path.
fn start_delivery_server(store: Arc<ChunkStore>, max_sessions: usize) -> Server {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = SharedEngine::new(Manifest::builtin(&dir));
    let registry = ModelRegistry::new(engine, BatcherConfig::default());
    Server::bind(
        registry,
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            max_sessions,
            admin_enabled: false,
            dataset: Some(store),
            ..ServeConfig::default()
        },
    )
    .unwrap()
}

fn tcp_connector(addr: std::net::SocketAddr) -> impl Fn() -> Result<TcpStream> + Sync {
    move || {
        let sock = TcpStream::connect(addr)?;
        sock.set_nodelay(true).ok();
        Ok(sock)
    }
}

#[test]
fn striped_pull_is_bitwise_identical_to_unstriped() {
    let data = mixed_blob(300_000, 0x5EED);
    let store = Arc::new(ChunkStore::from_bytes("corpus", &data, 16 * 1024, true).unwrap());
    let n = store.num_chunks();
    assert!(n >= 16, "want a multi-chunk dataset, got {n}");
    let server = start_delivery_server(store.clone(), 64);
    let connect = tcp_connector(server.local_addr());

    // unstriped
    let sink = VecSink::new(data.len());
    let opts = PullOptions { dataset_id: "corpus".into(), stripes: 1, ..Default::default() };
    let r1 = delivery::pull(&connect, &opts, |_, off, raw| sink.put(off, raw)).unwrap();
    let unstriped = sink.into_inner();
    assert_eq!(unstriped, data, "unstriped pull lost bytes");
    assert_eq!(r1.fetched_chunks, n);
    assert_eq!(r1.retried_chunks, 0);
    assert!(store.fetch_counts().iter().all(|&c| c == 1));

    // striped N=4: same bytes, one more serve per chunk
    let sink = VecSink::new(data.len());
    let opts = PullOptions { dataset_id: "corpus".into(), stripes: 4, ..Default::default() };
    let r4 = delivery::pull(&connect, &opts, |_, off, raw| sink.put(off, raw)).unwrap();
    assert_eq!(r4.stripes, 4, "4 stripes requested, {} ran", r4.stripes);
    let striped = sink.into_inner();
    assert_eq!(striped, unstriped, "striped != unstriped");
    assert!(store.fetch_counts().iter().all(|&c| c == 2));
    // chunk payloads dominate the inbound byte count both ways
    assert!(r1.bytes_in as usize > data.len() / 2);
    assert!(r4.bytes_in as usize > data.len() / 2);
    server.stop();
}

#[test]
fn kill_at_chunk_boundary_then_resume_refetches_nothing_verified() {
    const KILL_AT: usize = 7;
    let data = mixed_blob(180_000, 0xD00D);
    let store = Arc::new(ChunkStore::from_bytes("resume-me", &data, 8 * 1024, true).unwrap());
    let n = store.num_chunks();
    assert!(n > KILL_AT + 4);
    let server = start_delivery_server(store.clone(), 64);
    let connect = tcp_connector(server.local_addr());

    let dir = std::env::temp_dir().join(format!("mole-delivery-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let jpath = dir.join("resume-me.journal");
    std::fs::remove_file(&jpath).ok();

    // run 1: deterministic kill after KILL_AT verified chunks
    let sink = VecSink::new(data.len());
    let opts = PullOptions {
        dataset_id: "resume-me".into(),
        stripes: 1,
        journal: Some(jpath.clone()),
        resume: true,
        kill_after: Some(KILL_AT),
        expect_signer: None,
    };
    let err = delivery::pull(&connect, &opts, |_, off, raw| sink.put(off, raw)).unwrap_err();
    assert!(err.to_string().contains(KILL_MARKER), "unexpected error: {err}");
    assert!(jpath.exists(), "journal must survive the kill");

    // run 2: resume, striped across 4 connections
    let opts = PullOptions {
        dataset_id: "resume-me".into(),
        stripes: 4,
        journal: Some(jpath.clone()),
        resume: true,
        kill_after: None,
        expect_signer: None,
    };
    let report = delivery::pull(&connect, &opts, |_, off, raw| sink.put(off, raw)).unwrap();
    assert_eq!(report.resumed_chunks, KILL_AT, "journal chunks resumed");
    assert_eq!(report.fetched_chunks, n - KILL_AT, "only the remainder fetched");
    assert_eq!(sink.into_inner(), data, "kill+resume lost bytes");
    assert!(!jpath.exists(), "journal removed after completion");

    // the acceptance invariant: zero re-fetches of *verified* chunks.
    // Stripe 1 verifies in order, so the journaled set is 0..KILL_AT;
    // those were served exactly once across both runs. Unverified
    // chunks may have been served in the killed run's already-written
    // request batch and again on resume — at most twice, at least once.
    for (i, &c) in store.fetch_counts().iter().enumerate() {
        if i < KILL_AT {
            assert_eq!(c, 1, "verified chunk {i} was re-fetched ({c} serves)");
        } else {
            assert!((1..=2).contains(&c), "chunk {i} served {c} times");
        }
    }
    server.stop();
    std::fs::remove_dir_all(&dir).ok();
}

/// Bulk pulls ride the same session budget as inference: with one live
/// delivery session holding the only slot, the next connect sheds with
/// the typed `Fault::Overloaded` at the `DatasetHello` handshake.
#[test]
fn bulk_pull_past_session_budget_sheds_typed() {
    let data = mixed_blob(64 * 1024, 0xFEED);
    let store = Arc::new(ChunkStore::from_bytes("budget", &data, 8 * 1024, false).unwrap());
    let server = start_delivery_server(store, 1);
    let addr = server.local_addr();

    // session 1 holds the only budget slot (handshake completed, so the
    // slot is held by the detached delivery thread)
    let mut first = DeliveryClient::connect(addr, "budget").unwrap();
    assert_eq!(first.manifest().unwrap().chunks.len(), 8);

    // session 2 must be shed typed, not parked
    let mut shed = false;
    for _ in 0..50 {
        match DeliveryClient::connect(addr, "budget") {
            Err(Error::Overloaded { retry_after_ms }) => {
                assert!(retry_after_ms > 0);
                shed = true;
                break;
            }
            // accept raced a driver tick; try again
            Ok(_) | Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
        }
    }
    assert!(shed, "second bulk session was never shed with Fault::Overloaded");
    first.finish().unwrap();
    server.stop();
}

// ---------------------------------------------------------------------------
// byzantine servers (hostile frames from testkit::conformance)
// ---------------------------------------------------------------------------

/// A scripted delivery server over a duplex pipe: echoes the handshake
/// and manifest honestly, then answers each `ChunkRequest` with the
/// next queued reply script.
fn scripted_server(
    store: Arc<ChunkStore>,
    mut chunk_replies: Vec<Vec<Message>>,
) -> mole::testkit::net::Pipe {
    let (client, mut srv) = pipe_pair();
    std::thread::spawn(move || {
        // DatasetHello echo
        match read_message(&mut srv) {
            Ok(Message::DatasetHello { version, .. }) => {
                write_message(
                    &mut srv,
                    &Message::DatasetHello {
                        version,
                        dataset_id: store.dataset_id().to_string(),
                    },
                )
                .unwrap();
            }
            other => panic!("scripted server: expected DatasetHello, got {other:?}"),
        }
        loop {
            match read_message(&mut srv) {
                Ok(Message::ManifestRequest { .. }) => {
                    write_message(&mut srv, &store.manifest().to_message()).unwrap();
                }
                Ok(Message::ChunkRequest { .. }) => {
                    if chunk_replies.is_empty() {
                        panic!("scripted server: unscripted ChunkRequest");
                    }
                    for msg in chunk_replies.remove(0) {
                        write_message(&mut srv, &msg).unwrap();
                    }
                }
                Ok(Message::DeliveryDone) => {
                    write_message(&mut srv, &Message::DeliveryDone).unwrap();
                    return;
                }
                Ok(other) => panic!("scripted server: unexpected {other:?}"),
                Err(_) => return, // client hung up after a typed failure
            }
        }
    });
    client
}

#[test]
fn corrupt_chunk_survives_via_single_retry_and_counts() {
    let data = mixed_blob(20_000, 0xC0DE);
    let store = Arc::new(ChunkStore::from_bytes("hostile", &data, 4 * 1024, true).unwrap());
    // first answer: chunk 0 corrupted, rest honest; retry answer: honest
    let n = store.num_chunks() as u64;
    let mut first: Vec<Message> =
        vec![hostile_delivery::corrupted_chunk(&store, 0).unwrap()];
    for i in 1..n {
        first.push(store.chunk_frame(i).unwrap());
    }
    let retry = vec![store.chunk_frame(0).unwrap()];
    let mut stream = scripted_server(store.clone(), vec![first, retry]);

    let id = delivery::open_delivery(&mut stream, "hostile").unwrap();
    assert_eq!(id, "hostile");
    let manifest = delivery::request_manifest(&mut stream, "hostile").unwrap();
    let sink = VecSink::new(data.len());
    let retried = delivery::fetch_range(&mut stream, &manifest, 0, n as u32, |i, raw| {
        sink.put(manifest.offsets()[i as usize], raw)
    })
    .unwrap();
    assert_eq!(retried, 1, "exactly one automatic retry");
    assert_eq!(sink.into_inner(), data, "retried transfer must still be exact");
    delivery::finish_delivery(&mut stream).unwrap();
}

#[test]
fn persistently_corrupt_chunk_fails_typed_after_one_retry() {
    let data = mixed_blob(12_000, 0xBAD);
    let store = Arc::new(ChunkStore::from_bytes("hostile", &data, 4 * 1024, false).unwrap());
    let corrupt = || hostile_delivery::corrupted_chunk(&store, 0).unwrap();
    let mut stream = scripted_server(store.clone(), vec![vec![corrupt()], vec![corrupt()]]);

    delivery::open_delivery(&mut stream, "hostile").unwrap();
    let manifest = delivery::request_manifest(&mut stream, "hostile").unwrap();
    let err = delivery::fetch_range(&mut stream, &manifest, 0, 1, |_, _| Ok(()))
        .unwrap_err();
    match err {
        Error::ChunkCorrupt { chunk, ref want, ref got } => {
            assert_eq!(chunk, 0);
            assert_ne!(want, got, "digests in the typed error must differ");
        }
        other => panic!("expected ChunkCorrupt, got {other:?}"),
    }
}

#[test]
fn lying_chunk_index_is_a_hard_protocol_error_no_retry() {
    let data = mixed_blob(12_000, 0x11E);
    let store = Arc::new(ChunkStore::from_bytes("hostile", &data, 4 * 1024, false).unwrap());
    // request chunk 0, server answers with chunk 1's frame relabeled as
    // chunk 1 (truthful data, lying about which index was asked for)
    let lie = hostile_delivery::lying_index_chunk(&store, 1, 1).unwrap();
    let mut stream = scripted_server(store.clone(), vec![vec![lie]]);

    delivery::open_delivery(&mut stream, "hostile").unwrap();
    let manifest = delivery::request_manifest(&mut stream, "hostile").unwrap();
    let mut delivered = 0usize;
    let err = delivery::fetch_range(&mut stream, &manifest, 0, 1, |_, _| {
        delivered += 1;
        Ok(())
    })
    .unwrap_err();
    assert!(
        matches!(err, Error::Protocol(ref m) if m.contains("index lied")),
        "expected lying-index protocol error, got {err:?}"
    );
    assert_eq!(delivered, 0, "no bytes may be delivered from a lying frame");
}
