//! Lifecycle end-to-end tests: the live registry under real traffic.
//!
//! The rotate-under-load scenario is the race the fixed-at-startup
//! design never had to face: pipelined clients on `alpha@0` while the
//! admin surface registers `alpha@1` and drains `alpha@0` mid-run. The
//! harness makes it deterministic with barriers (phase 1 strictly
//! before the rotation, phase 2 strictly after), so every assertion is
//! exact: zero lost or duplicated responses, every response bitwise
//! equal to single-row inference on whichever epoch served it, the
//! drained lane's batcher flushed before retire, and retire refused
//! while the queue is non-empty.

use mole::coordinator::batcher::BatcherConfig;
use mole::coordinator::client::{ClientConfig, MoleClient};
use mole::coordinator::registry::{demo_entry_from_keys, ModelRegistry, RegisteredModel};
use mole::coordinator::server::{ServeConfig, Server};
use mole::coordinator::{AdminClient, LaneState, EPOCH_LATEST};
use mole::keys::KeyBundle;
use mole::manifest::Manifest;
use mole::rng::Rng;
use mole::runtime::{Arg, SharedEngine};
use mole::tensor::Tensor;
use mole::{Error, Geometry};
use std::path::PathBuf;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

const KAPPA: usize = 16;
const SEED: u64 = 9090;

fn manifest() -> Manifest {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Manifest::load(&dir).unwrap()
}

/// The two epochs of the rollover, reconstructible bitwise: the server
/// builds its lanes from the same `(keys, trunk_seed)` pair.
fn epoch_keys() -> (KeyBundle, KeyBundle) {
    let root = KeyBundle::generate(Geometry::SMALL, KAPPA, SEED).unwrap();
    let rotated = root.rotate(SEED + 1).unwrap();
    (root, rotated)
}

fn entry(m: &Manifest, keys: &KeyBundle) -> RegisteredModel {
    demo_entry_from_keys(m, "alpha", keys, SEED).unwrap()
}

/// Reference: one row through the batch-1 artifact — what every served
/// response must match bitwise, per epoch.
fn single_row_logits(engine: &SharedEngine, e: &RegisteredModel, row: &[f32]) -> Vec<f32> {
    let mut args: Vec<Arg> = vec![
        Arg::T(e.layer.matrix().clone()),
        Arg::T(Tensor::new(&[e.layer.bias().len()], e.layer.bias().to_vec()).unwrap()),
    ];
    for p in &e.params {
        args.push(Arg::T(p.clone()));
    }
    args.push(Arg::T(Tensor::new(&[1, row.len()], row.to_vec()).unwrap()));
    engine.exec("infer_aug_small_b1", &args).unwrap()[0].data().to_vec()
}

fn client_rows(client_id: u64, phase: u64, n: usize, d_len: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(0x11FE ^ (client_id * 7919) ^ (phase * 104729));
    (0..n).map(|_| rng.normal_vec(d_len, 0.5)).collect()
}

/// Bit-exact view of logits (f32 `==` would let ±0.0 slip through).
fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Satellite 1: rotate under load. N pipelined clients on `alpha@0`
/// while the admin surface registers `alpha@1` (from a rotated vault
/// file) and drains `alpha@0`; drained-epoch clients re-resolve through
/// the typed draining fault; nothing is lost, duplicated, or wrong.
#[test]
fn rotate_under_load_loses_nothing() {
    const CLIENTS: usize = 4;
    const PER_PHASE: usize = 4;

    let m = manifest();
    let engine = SharedEngine::new(m.clone());
    let (root, rotated) = epoch_keys();
    let registry = ModelRegistry::new(
        engine.clone(),
        BatcherConfig {
            max_batch: 8,
            timeout: Duration::from_millis(5),
            ..BatcherConfig::default()
        },
    );
    registry.register(entry(&m, &root)).unwrap();
    let server = Server::bind(
        registry,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            session_workers: 8,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let addr = server.local_addr();

    // the rotated epoch's vault, written where the server can read it
    let vault = std::env::temp_dir().join(format!("mole_lifecycle_vault_{SEED}.key"));
    rotated.save(&vault).unwrap();

    // phase barriers: everyone finishes phase 1 → admin rotates →
    // everyone runs phase 2. Deterministic by construction.
    let rotate_start = Arc::new(Barrier::new(CLIENTS + 1));
    let rotate_done = Arc::new(Barrier::new(CLIENTS + 1));

    let mut threads = Vec::new();
    for c in 0..CLIENTS as u64 {
        let (b1, b2) = (rotate_start.clone(), rotate_done.clone());
        threads.push(std::thread::spawn(move || {
            let mut client =
                MoleClient::connect_with(addr, ClientConfig::pinned("alpha", 0)).unwrap();
            assert_eq!(client.server_info().unwrap().epoch, 0);
            let d = client.d_len();
            // phase 1: strictly before the rotation — epoch 0 serves
            let rows1 = client_rows(c, 1, PER_PHASE, d);
            let got1 = client.infer_batch(&rows1).unwrap();
            assert_eq!(client.drain_redirects(), 0);
            b1.wait();
            b2.wait();
            // phase 2: strictly after the drain — every request is
            // refused typed and transparently re-sent to epoch 1
            let rows2 = client_rows(c, 2, PER_PHASE, d);
            let got2 = client.infer_batch(&rows2).unwrap();
            let redirects = client.drain_redirects();
            client.finish().unwrap();
            (got1, got2, redirects)
        }));
    }

    rotate_start.wait();
    // live rollover via the admin surface, against the running server
    let mut admin = AdminClient::connect(addr).unwrap();
    let detail = admin
        .register("alpha", vault.to_str().unwrap(), KAPPA, SEED, SEED)
        .unwrap();
    assert!(detail.contains("registered alpha@1"), "{detail}");
    let detail = admin.drain("alpha", 0).unwrap();
    assert!(detail.contains("successor 1"), "{detail}");
    rotate_done.wait();

    let results: Vec<_> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    std::fs::remove_file(&vault).ok();

    // bitwise ground truth per epoch, rebuilt from the same keys
    let (e0, e1) = (entry(&m, &root), entry(&m, &rotated));
    let d_len = m.geometry("small").unwrap().d_len();
    // sanity: the two epochs really serve different models
    let probe = &client_rows(0, 1, 1, d_len)[0];
    assert_ne!(
        single_row_logits(&engine, &e0, probe),
        single_row_logits(&engine, &e1, probe),
        "rotation did not change the served model"
    );
    for (c, (got1, got2, redirects)) in results.iter().enumerate() {
        // zero lost/duplicated: infer_batch yields exactly one response
        // per row, id-matched
        assert_eq!(got1.len(), PER_PHASE);
        assert_eq!(got2.len(), PER_PHASE);
        // phase 1 rows answered by epoch 0, bitwise
        for (i, row) in client_rows(c as u64, 1, PER_PHASE, d_len).iter().enumerate() {
            assert_eq!(
                bits(&got1[i]),
                bits(&single_row_logits(&engine, &e0, row)),
                "client {c} phase-1 row {i} not bitwise-equal on epoch 0"
            );
        }
        // phase 2 rows re-resolved to epoch 1, bitwise
        for (i, row) in client_rows(c as u64, 2, PER_PHASE, d_len).iter().enumerate() {
            assert_eq!(
                bits(&got2[i]),
                bits(&single_row_logits(&engine, &e1, row)),
                "client {c} phase-2 row {i} not bitwise-equal on epoch 1"
            );
        }
        // every phase-2 request was pipelined before the first fault
        // came back, so each one took exactly one typed redirect
        assert_eq!(*redirects, PER_PHASE as u64, "client {c}");
    }

    // per-lane accounting: epoch 0 answered exactly the phase-1 rows
    // (its tail flushed — nothing abandoned), epoch 1 the phase-2 rows
    let lane0 = server
        .registry()
        .lanes()
        .into_iter()
        .find(|l| l.epoch() == 0)
        .unwrap();
    let lane1 = server.registry().resolve("alpha", 1).unwrap();
    assert_eq!(lane0.state(), LaneState::Draining);
    assert_eq!(lane0.handle().metrics.responses.get(), (CLIENTS * PER_PHASE) as u64);
    assert_eq!(lane1.handle().metrics.responses.get(), (CLIENTS * PER_PHASE) as u64);
    assert_eq!(lane0.handle().in_flight(), 0, "drained lane still holds requests");
    assert_eq!(
        server.metrics().responses.get(),
        (2 * CLIENTS * PER_PHASE) as u64,
        "a response was lost or duplicated on the wire"
    );
    // the refusals were real: one typed fault per phase-2 request
    assert_eq!(server.metrics().faults.get(), (CLIENTS * PER_PHASE) as u64);

    // rollover completes: retire the flushed lane, live
    let detail = admin.retire("alpha", 0).unwrap();
    assert!(detail.contains("retired alpha@0"), "{detail}");
    let status = admin.status().unwrap();
    assert!(status.contains("alpha@0 state=retired successor=1"), "{status}");
    assert!(status.contains("alpha@1 state=active"), "{status}");
    admin.finish().unwrap();

    // a late client pinned to the retired epoch re-resolves at the
    // handshake (typed retired fault → successor) and still gets
    // bitwise-correct service from epoch 1
    let mut late =
        MoleClient::connect_with(addr, ClientConfig::pinned("alpha", 0)).unwrap();
    assert_eq!(late.server_info().unwrap().epoch, 1);
    assert_eq!(late.drain_redirects(), 1);
    let row = client_rows(99, 3, 1, d_len).remove(0);
    assert_eq!(
        bits(&late.infer(&row).unwrap()),
        bits(&single_row_logits(&engine, &e1, &row))
    );
    late.finish().unwrap();

    server.stop();
}

/// Acceptance: no lane can be retired while its batcher queue is
/// non-empty — and the tail it holds is flushed, bitwise-correct,
/// before a retire is allowed through. Deterministic: a long fixed hold
/// window parks the submitted rows, so the in-flight window is seconds
/// wide while the lifecycle verbs run in microseconds.
#[test]
fn retire_refused_until_batcher_tail_flushes() {
    let m = manifest();
    let engine = SharedEngine::new(m.clone());
    let (root, rotated) = epoch_keys();
    let registry = ModelRegistry::new(
        engine.clone(),
        BatcherConfig {
            max_batch: 32,
            timeout: Duration::from_millis(600),
            adaptive: false,
            ..BatcherConfig::default()
        },
    );
    registry.register(entry(&m, &root)).unwrap();
    registry.register(entry(&m, &rotated)).unwrap();
    let e0 = entry(&m, &root);
    let d_len = m.geometry("small").unwrap().d_len();

    // park three rows in epoch 0's hold window
    let lane0 = registry.resolve("alpha", 0).unwrap();
    let rows = client_rows(7, 1, 3, d_len);
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    for (i, row) in rows.iter().enumerate() {
        let tx = done_tx.clone();
        lane0.submit_with(row, move |r| tx.send((i, r)).unwrap()).unwrap();
    }
    drop(done_tx);
    assert_eq!(lane0.handle().in_flight(), 3);

    // drain: new work refused typed, parked work untouched
    assert_eq!(registry.drain("alpha", 0).unwrap(), 1);
    assert!(matches!(
        registry.resolve("alpha", 0),
        Err(Error::Draining { successor: 1, .. })
    ));
    assert!(matches!(
        lane0.submit_with(&rows[0], |_| {}),
        Err(Error::Draining { successor: 1, .. })
    ));

    // the acceptance gate: retire must refuse while the queue holds rows
    let err = registry.retire("alpha", 0).unwrap_err();
    assert!(err.to_string().contains("in flight"), "{err}");
    assert!(err.to_string().contains("3"), "{err}");

    // the tail flushes at the window deadline — every parked row
    // answered, bitwise-equal to single-row inference on epoch 0
    let mut flushed = 0;
    for (i, result) in done_rx {
        assert_eq!(
            bits(&result.unwrap()),
            bits(&single_row_logits(&engine, &e0, &rows[i])),
            "parked row {i} lost or wrong at flush"
        );
        flushed += 1;
    }
    assert_eq!(flushed, 3, "drained lane dropped part of its tail");

    // in-flight hits zero (reply guards drop just after delivery)
    let t0 = Instant::now();
    while lane0.handle().in_flight() > 0 {
        assert!(t0.elapsed() < Duration::from_secs(2), "in-flight never drained");
        std::thread::sleep(Duration::from_millis(1));
    }
    // now — and only now — retire goes through
    registry.retire("alpha", 0).unwrap();
    assert_eq!(lane0.state(), LaneState::Retired);
    assert!(lane0.handle().is_closed());
    assert!(matches!(
        lane0.submit_with(&rows[0], |_| {}),
        Err(Error::Retired { successor: 1, .. })
    ));
    assert!(matches!(
        registry.resolve("alpha", 0),
        Err(Error::Retired { successor: 1, .. })
    ));
    // epoch 1 is untouched by its sibling's teardown
    let lane1 = registry.resolve("alpha", EPOCH_LATEST).unwrap();
    assert_eq!(lane1.epoch(), 1);
    let row = &client_rows(8, 1, 1, d_len)[0];
    assert_eq!(lane1.infer(row).unwrap().len(), 10);
}

/// The admin surface can be disabled: a server bound with
/// `admin_enabled: false` refuses admin frames with a typed fault.
#[test]
fn disabled_admin_surface_refuses_typed() {
    let m = manifest();
    let registry = ModelRegistry::new(
        SharedEngine::new(m.clone()),
        BatcherConfig {
            max_batch: 8,
            timeout: Duration::from_millis(2),
            ..BatcherConfig::default()
        },
    );
    registry.register(entry(&m, &epoch_keys().0)).unwrap();
    let server = Server::bind(
        registry,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            session_workers: 2,
            admin_enabled: false,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut admin = AdminClient::connect(server.local_addr()).unwrap();
    let err = admin.status().unwrap_err();
    assert!(err.to_string().contains("disabled"), "{err}");
    // serving traffic is unaffected
    let mut client = MoleClient::connect(server.local_addr()).unwrap();
    let d = client.d_len();
    assert_eq!(client.infer(&vec![0.1; d]).unwrap().len(), 10);
    client.finish().unwrap();
    server.stop();
}
