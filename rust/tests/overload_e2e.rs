//! Overload end-to-end tests: the serving plane under more demand than
//! its budgets admit. The invariant under test is the PR's headline —
//! **overload is answered, never parked**: every refused connection and
//! every refused request comes back as a typed `Overloaded` fault with
//! an actionable `retry_after_ms`, nothing blocks indefinitely, and the
//! plane recovers by itself once the backlog drains.
//!
//! The final test is a budget-scaled soak: `MOLE_SOAK_CONNS` sets the
//! connection count (default 64 so CI stays fast; run with
//! `MOLE_SOAK_CONNS=10000` for the full event-loop scaling check). It
//! asserts the two non-negotiables under load: zero lost responses and
//! logits bitwise identical to single-row inference.

use mole::coordinator::batcher::BatcherConfig;
use mole::coordinator::client::MoleClient;
use mole::coordinator::loadgen::{run as run_loadgen, LoadgenConfig};
use mole::coordinator::registry::{demo_entry_from_keys, ModelRegistry, RegisteredModel};
use mole::coordinator::server::{ServeConfig, Server};
use mole::coordinator::{Fault, EPOCH_LATEST};
use mole::keys::KeyBundle;
use mole::manifest::Manifest;
use mole::rng::Rng;
use mole::runtime::{Arg, SharedEngine};
use mole::tensor::Tensor;
use mole::testkit::conformance::{Driver, Expect};
use mole::Error;
use mole::Geometry;
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

const KAPPA: usize = 16;
const OMEGA_SEED: u64 = 31337;

fn manifest() -> Manifest {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Manifest::load(&dir).unwrap()
}

fn omega_entry(m: &Manifest) -> RegisteredModel {
    let keys = KeyBundle::generate(Geometry::SMALL, KAPPA, OMEGA_SEED).unwrap();
    demo_entry_from_keys(m, "omega", &keys, OMEGA_SEED).unwrap()
}

/// One-model server with explicit serving + batcher budgets — the tests
/// here shrink them to force deterministic sheds.
fn start_server(serve: ServeConfig, batcher: BatcherConfig) -> (Server, SharedEngine) {
    let m = manifest();
    let engine = SharedEngine::new(m.clone());
    let registry = ModelRegistry::new(engine.clone(), batcher);
    registry.register(omega_entry(&m)).unwrap();
    let server = Server::bind(registry, serve).unwrap();
    (server, engine)
}

/// Reference logits: the same row through the batch-1 artifact directly
/// on the shared engine (what every served response must match bitwise).
fn single_row_logits(engine: &SharedEngine, entry: &RegisteredModel, row: &[f32]) -> Vec<f32> {
    let mut args: Vec<Arg> = vec![
        Arg::T(entry.layer.matrix().clone()),
        Arg::T(Tensor::new(&[entry.layer.bias().len()], entry.layer.bias().to_vec()).unwrap()),
    ];
    for p in &entry.params {
        args.push(Arg::T(p.clone()));
    }
    args.push(Arg::T(Tensor::new(&[1, row.len()], row.to_vec()).unwrap()));
    let out = engine.exec("infer_aug_small_b1", &args).unwrap();
    out[0].data().to_vec()
}

fn rows(seed: u64, n: usize, d_len: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(0x0E2E ^ seed);
    (0..n).map(|_| rng.normal_vec(d_len, 0.5)).collect()
}

/// Session-budget sheds at accept: with `max_sessions = 2` the third
/// concurrent connection is refused **typed** — `Error::Overloaded` with
/// a sane backoff hint, not a hang, not a connection reset — every
/// single time; and once a session closes, admission reopens without any
/// operator action.
#[test]
fn accept_budget_sheds_typed_and_recovers() {
    let (server, _engine) = start_server(
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            session_workers: 2,
            max_sessions: 2,
            ..ServeConfig::default()
        },
        BatcherConfig::default(),
    );
    let addr = server.local_addr();

    let c1 = MoleClient::connect(addr).unwrap();
    let mut c2 = MoleClient::connect(addr).unwrap();

    // budget full: every further connect is a typed shed. The hint is
    // now *derived* from shed pressure (pending fill + consecutive-shed
    // burst), not the old flat 100 ms: with both sessions fully
    // handshaked the pending queue is empty and the burst (3 < 8 sheds)
    // hasn't doubled anything yet, so each hint is exactly the 25 ms
    // floor — and always inside the documented [1, 1000] contract.
    for attempt in 0..3 {
        match MoleClient::connect(addr) {
            Err(Error::Overloaded { retry_after_ms }) => {
                assert!(
                    (1..=1000).contains(&retry_after_ms),
                    "attempt {attempt}: hint {retry_after_ms} ms out of contract"
                );
                assert_eq!(
                    retry_after_ms, 25,
                    "attempt {attempt}: idle-pending short burst should hint the 25 ms floor"
                );
            }
            Err(other) => panic!("attempt {attempt}: expected typed Overloaded, got {other}"),
            Ok(_) => panic!("attempt {attempt}: connect admitted past max_sessions=2"),
        }
    }
    assert_eq!(server.metrics().accept_shed.get(), 3);
    // a shed is flow control, not a protocol fault
    assert_eq!(server.metrics().faults.get(), 0);

    // free one slot; the server notices the close on its own and reopens
    // admission — poll (bounded) rather than trusting a fixed sleep
    drop(c1);
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut readmitted = loop {
        match MoleClient::connect(addr) {
            Ok(c) => break c,
            Err(Error::Overloaded { .. }) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => panic!("admission never reopened after a session closed: {e}"),
        }
    };

    // both admitted sessions actually serve
    let d = c2.d_len();
    let row = rows(1, 1, d).remove(0);
    assert!(!c2.infer(&row).unwrap().is_empty());
    assert!(!readmitted.infer(&row).unwrap().is_empty());
    c2.finish().unwrap();
    readmitted.finish().unwrap();
    server.stop();
}

/// The `shed_accept` drain-cap edge. Below `SHED_DRAIN_CAP` (32)
/// concurrent drains, a shed peer that already wrote bytes still
/// receives the typed `Overloaded` fault and a clean FIN — the detached
/// drainer reads the peer's unread bytes so `close(2)` never answers
/// RST and destroys the fault frame in flight. Past the cap the close is
/// documented to be abrupt: an over-cap shed resolves promptly as
/// *either* the typed fault or a connection reset — that disjunction is
/// the contract — and never as a hang.
#[test]
fn shed_drain_cap_typed_below_abrupt_above() {
    use std::io::{Read as _, Write as _};
    let (server, _engine) = start_server(
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            session_workers: 1,
            max_sessions: 1,
            ..ServeConfig::default()
        },
        BatcherConfig::default(),
    );
    let addr = server.local_addr();
    let _occupant = MoleClient::connect(addr).unwrap();

    // Below the cap: a well-behaved peer whose handshake bytes sit
    // unread in the server's receive queue still gets the typed fault,
    // then a clean EOF — never a reset.
    let mut d = Driver::connect(addr).unwrap();
    d.raw(&[0u8; 64]).unwrap();
    d.expect(&Expect::OverloadFault).unwrap().expect(&Expect::Eof).unwrap();

    // Saturate the drain-thread cap: each holder is shed, writes bytes,
    // and then neither reads nor closes — its drainer sits in a blocked
    // read for up to the full 250 ms SHED_DRAIN_WINDOW.
    const CAP: usize = 32; // = server::SHED_DRAIN_CAP
    const EXTRAS: usize = 8;
    let mut holders = Vec::with_capacity(CAP);
    for _ in 0..CAP {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&[0u8; 64]).unwrap();
        holders.push(s);
    }

    // Over the cap: each extra shed races the holders' drain slots, so
    // it lands typed (a slot freed, or the FIN outran our bytes) or
    // abruptly reset — but a bounded read always resolves it.
    let mut typed = 0usize;
    let mut reset = 0usize;
    for i in 0..EXTRAS {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&[0u8; 64]).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut buf = Vec::new();
        match s.read_to_end(&mut buf) {
            Ok(_) => {
                assert!(!buf.is_empty(), "extra {i}: clean EOF without a fault frame");
                typed += 1;
            }
            Err(e) => {
                assert!(
                    matches!(
                        e.kind(),
                        std::io::ErrorKind::ConnectionReset | std::io::ErrorKind::BrokenPipe
                    ),
                    "extra {i}: shed must resolve as typed fault or reset, got {e}"
                );
                reset += 1;
            }
        }
    }
    assert_eq!(typed + reset, EXTRAS, "every over-cap shed resolved, none hung");

    // every refused connection was counted as a shed, typed or abrupt,
    // and none of them registered as a protocol fault
    assert_eq!(server.metrics().accept_shed.get() as usize, 1 + CAP + EXTRAS);
    assert_eq!(server.metrics().faults.get(), 0);
    drop(holders);
    server.stop();
}

/// Lane-backlog sheds are **request**-scoped: with `queue_bound = 1` and
/// the single queue slot pinned by a stalled in-process request, a TCP
/// request is answered `Fault::Overloaded` (correct id, sane hint) —
/// and the same session keeps serving once the backlog drains. The stall
/// is a completion callback blocked on a channel, so the shed is
/// deterministic, not a timing accident.
#[test]
fn lane_backlog_sheds_requests_typed_not_sessions() {
    let (server, _engine) = start_server(
        ServeConfig { addr: "127.0.0.1:0".to_string(), ..ServeConfig::default() },
        BatcherConfig {
            max_batch: 4,
            timeout: Duration::from_millis(2),
            queue_bound: 1,
            ..BatcherConfig::default()
        },
    );
    let mut client = MoleClient::connect(server.local_addr()).unwrap();
    let d = client.d_len();
    let test_rows = rows(2, 3, d);

    // sanity: the lane serves when idle
    assert!(!client.infer(&test_rows[0]).unwrap().is_empty());

    // pin the queue slot: the completion blocks on `gate`, holding the
    // in-flight gauge at 1 (== queue_bound) until released
    let lane = server.registry().resolve("omega", EPOCH_LATEST).unwrap();
    let handle = lane.handle().clone();
    // the sanity request's in-flight guard drops on the worker thread a
    // moment after the client sees its response — settle first
    let deadline = Instant::now() + Duration::from_secs(10);
    while handle.in_flight() > 0 {
        assert!(Instant::now() < deadline, "sanity request never left the gauge");
        std::thread::sleep(Duration::from_millis(1));
    }
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    handle
        .submit_with(&test_rows[1], move |_| {
            let _ = gate_rx.recv();
        })
        .unwrap();
    assert_eq!(handle.in_flight(), 1);

    // the TCP request is shed typed, tagged with its own id
    client.send_request(42, &test_rows[2]).unwrap();
    let (id, outcome) = client.recv_outcome().unwrap();
    assert_eq!(id, 42, "shed must be attributed to the request that hit the bound");
    match outcome {
        Err(Fault::Overloaded { retry_after_ms }) => {
            assert!((1..=1000).contains(&retry_after_ms), "hint {retry_after_ms} ms");
        }
        other => panic!("expected Fault::Overloaded, got {other:?}"),
    }
    assert_eq!(handle.metrics.overloaded.get(), 1);

    // drain the backlog; admission reopens on the SAME session — the
    // shed faulted one request, not the connection
    gate_tx.send(()).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    while handle.in_flight() > 0 {
        assert!(Instant::now() < deadline, "stalled request never drained");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(!client.infer(&test_rows[2]).unwrap().is_empty());
    client.finish().unwrap();
    server.stop();
}

/// Open-loop loadgen (satellite 1): with a fixed arrival rate the driver
/// measures two latency distributions — raw (actual send → response) and
/// corrected (**intended** send → response). Corrected must dominate raw
/// (a send can only happen at or after its schedule slot), and a
/// closed-loop run must report the two as identical, because there the
/// intended time IS the send time.
#[test]
fn open_loop_reports_raw_and_corrected_latency() {
    let (server, _engine) = start_server(
        ServeConfig { addr: "127.0.0.1:0".to_string(), ..ServeConfig::default() },
        BatcherConfig {
            max_batch: 8,
            timeout: Duration::from_millis(2),
            ..BatcherConfig::default()
        },
    );
    let addr = server.local_addr().to_string();
    let base = LoadgenConfig {
        addr,
        connections: 2,
        requests_per_conn: 16,
        pipeline: 4,
        rate: 0.0,
        seed: 5,
        model: "omega".to_string(),
        epoch: EPOCH_LATEST,
    };

    // closed loop: corrected == raw sample for sample
    let closed = run_loadgen(&base).unwrap();
    assert_eq!(closed.ok, 32);
    assert_eq!(closed.errors, 0);
    assert_eq!(closed.latency.count(), closed.corrected.count());
    assert_eq!(closed.latency.summary(), closed.corrected.summary());
    assert_eq!(closed.offered_rps, 0.0);

    // open loop at 400 req/s across 2 connections
    let open = run_loadgen(&LoadgenConfig { rate: 400.0, ..base }).unwrap();
    assert_eq!(open.ok, 32);
    assert_eq!(open.errors, 0);
    assert_eq!(open.corrected.count(), 32, "every request needs a corrected sample");
    assert_eq!(open.offered_rps, 400.0);
    let (raw_p50, _, raw_p99) = open.latency.summary().unwrap();
    let (cor_p50, _, cor_p99) = open.corrected.summary().unwrap();
    assert!(
        cor_p50 >= raw_p50 && cor_p99 >= raw_p99,
        "corrected ({cor_p50}/{cor_p99}us) must dominate raw ({raw_p50}/{raw_p99}us): \
         intended send times never come after actual ones"
    );
    let line = open.report();
    assert!(line.contains("corrected_us"), "{line}");
    assert!(line.contains("offered=400"), "{line}");
    server.stop();
}

/// Budget-scaled soak: `MOLE_SOAK_CONNS` concurrent sessions (default 64
/// for CI; documented full run 10 000), each pipelining 8 requests.
/// Asserts the serving plane's two hard guarantees hold at scale: zero
/// lost responses (every request answered exactly once) and logits
/// bitwise identical to single-row inference on the same model.
#[test]
fn soak_zero_lost_responses_bitwise_identical() {
    let conns: usize = std::env::var("MOLE_SOAK_CONNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    const PER_CONN: usize = 8;
    // cap simultaneously-live client threads so a 10k run doesn't need
    // 10k OS threads on the *client* side (the server is evented and
    // holds them all; the cap only staggers arrivals)
    let wave = conns.min(128);

    let (server, engine) = start_server(
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            session_workers: 8,
            max_sessions: conns + 16,
            max_pending: 256,
            ..ServeConfig::default()
        },
        BatcherConfig {
            max_batch: 32,
            timeout: Duration::from_millis(2),
            adaptive: true,
            ..BatcherConfig::default()
        },
    );
    let addr = server.local_addr();
    let m = manifest();
    let d_len = m.geometry("small").unwrap().d_len();
    // every connection sends the same row set so the bitwise reference
    // is computed once, not conns× (soak cost lives on the wire)
    let shared_rows = std::sync::Arc::new(rows(0x50AC, PER_CONN, d_len));

    let mut answered = 0u64;
    let mut all: Vec<Vec<Vec<f32>>> = Vec::with_capacity(conns);
    let mut remaining = conns;
    while remaining > 0 {
        let batch = remaining.min(wave);
        remaining -= batch;
        let mut threads = Vec::with_capacity(batch);
        for _ in 0..batch {
            let rows = shared_rows.clone();
            threads.push(std::thread::spawn(move || {
                let mut client = MoleClient::connect(addr).unwrap();
                let logits = client.infer_batch(&rows).unwrap();
                client.finish().unwrap();
                logits
            }));
        }
        for t in threads {
            let logits = t.join().unwrap();
            answered += logits.len() as u64;
            all.push(logits);
        }
    }

    // zero lost responses: every request answered, none double-counted
    let total = (conns * PER_CONN) as u64;
    assert_eq!(answered, total, "lost responses under soak");
    assert_eq!(server.metrics().responses.get(), total);
    assert_eq!(server.metrics().connections.get(), conns as u64);
    assert_eq!(server.metrics().faults.get(), 0);

    // bitwise identity vs single-row inference
    let entry = omega_entry(&m);
    let reference: Vec<Vec<u32>> = shared_rows
        .iter()
        .map(|r| single_row_logits(&engine, &entry, r).iter().map(|v| v.to_bits()).collect())
        .collect();
    for (c, logits) in all.iter().enumerate() {
        for (i, got) in logits.iter().enumerate() {
            let bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits, reference[i], "conn {c} row {i}: batched logits drifted");
        }
    }
    server.stop();
}
