//! Gateway end-to-end tests: a fleet of three serving processes behind
//! one `Gateway`, driven through a real rotation under load.
//!
//! The acceptance scenario reuses the barrier harness of
//! `lifecycle_e2e.rs` — phase 1 strictly before the rollover, phase 2
//! strictly after — so every assertion is exact: zero lost responses,
//! every logits vector bitwise-equal to single-row inference on
//! whichever epoch served it, and (the fleet-specific part) one backend
//! deliberately killed mid-drain and reported as **failed in that
//! node's ack line**, while the other nodes' acks stay individually
//! green — a partial fan-out is never collapsed into one bool.

use mole::coordinator::batcher::BatcherConfig;
use mole::coordinator::client::{ClientConfig, MoleClient};
use mole::coordinator::gateway::{EpochSelector, Gateway, GatewayConfig, ShardSpec};
use mole::coordinator::registry::{demo_entry_from_keys, ModelRegistry, RegisteredModel};
use mole::coordinator::server::{ServeConfig, Server};
use mole::coordinator::AdminClient;
use mole::keys::KeyBundle;
use mole::manifest::Manifest;
use mole::rng::Rng;
use mole::runtime::{Arg, SharedEngine};
use mole::tensor::Tensor;
use mole::Geometry;
use std::path::PathBuf;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

const KAPPA: usize = 16;
const SEED: u64 = 4242;
/// Shared operator credential: the gateway's inbound gate and its
/// outbound per-backend identity, and every backend's admin gate.
const CRED: [u8; 32] = [0x5A; 32];

fn manifest() -> Manifest {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Manifest::load(&dir).unwrap()
}

fn epoch_keys() -> (KeyBundle, KeyBundle) {
    let root = KeyBundle::generate(Geometry::SMALL, KAPPA, SEED).unwrap();
    let rotated = root.rotate(SEED + 1).unwrap();
    (root, rotated)
}

fn entry(m: &Manifest, keys: &KeyBundle) -> RegisteredModel {
    demo_entry_from_keys(m, "alpha", keys, SEED).unwrap()
}

/// Reference: one row through the batch-1 artifact — what every served
/// response must match bitwise, per epoch, no matter which backend the
/// gateway picked.
fn single_row_logits(engine: &SharedEngine, e: &RegisteredModel, row: &[f32]) -> Vec<f32> {
    let mut args: Vec<Arg> = vec![
        Arg::T(e.layer.matrix().clone()),
        Arg::T(Tensor::new(&[e.layer.bias().len()], e.layer.bias().to_vec()).unwrap()),
    ];
    for p in &e.params {
        args.push(Arg::T(p.clone()));
    }
    args.push(Arg::T(Tensor::new(&[1, row.len()], row.to_vec()).unwrap()));
    engine.exec("infer_aug_small_b1", &args).unwrap()[0].data().to_vec()
}

fn client_rows(client_id: u64, phase: u64, n: usize, d_len: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(0x6A7E ^ (client_id * 7919) ^ (phase * 104729));
    (0..n).map(|_| rng.normal_vec(d_len, 0.5)).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// One in-process backend serving `alpha@0`, admin plane gated on the
/// shared credential (so the gateway's fan-out can authenticate to it).
fn spawn_backend(m: &Manifest, engine: &SharedEngine, root: &KeyBundle) -> Server {
    let registry = ModelRegistry::new(
        engine.clone(),
        BatcherConfig {
            max_batch: 8,
            timeout: Duration::from_millis(5),
            ..BatcherConfig::default()
        },
    );
    registry.register(entry(m, root)).unwrap();
    Server::bind(
        registry,
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            session_workers: 4,
            admin_credential: Some(CRED),
            ..ServeConfig::default()
        },
    )
    .unwrap()
}

fn gateway_over(backends: Vec<String>, credential: Option<[u8; 32]>) -> Gateway {
    Gateway::bind(GatewayConfig {
        addr: "127.0.0.1:0".to_string(),
        shards: vec![ShardSpec::new("alpha", EpochSelector::Any, backends).unwrap()],
        probe_interval: Duration::from_millis(100),
        connect_timeout: Duration::from_millis(500),
        credential,
        workers: 2,
    })
    .unwrap()
}

/// The ack line for one node in a fan-out / fleet-status detail.
fn node_line<'a>(detail: &'a str, addr: &str) -> &'a str {
    detail
        .lines()
        .find(|l| l.starts_with(&format!("node {addr} ")))
        .unwrap_or_else(|| panic!("no ack line for {addr} in:\n{detail}"))
}

/// Acceptance: rotate under load across three backend processes behind
/// the gateway, one node killed mid-drain. Zero lost responses, bitwise
/// correctness per epoch, and the dead node reported failed **per node**
/// in both the fan-out ack and `fleet-status`.
#[test]
fn fleet_rotate_under_load_with_node_killed_mid_drain() {
    const CLIENTS: usize = 3;
    const PER_PHASE: usize = 4;

    let m = manifest();
    let engine = SharedEngine::new(m.clone());
    let (root, rotated) = epoch_keys();

    let mut servers: Vec<Option<Server>> =
        (0..3).map(|_| Some(spawn_backend(&m, &engine, &root))).collect();
    let addrs: Vec<String> =
        servers.iter().map(|s| s.as_ref().unwrap().local_addr().to_string()).collect();
    let gw = gateway_over(addrs.clone(), Some(CRED));
    let gw_addr = gw.local_addr();

    // the rotated epoch's vault: the register fan-out carries this path
    // and every backend loads it from its own filesystem
    let vault = std::env::temp_dir().join(format!("mole_gateway_vault_{SEED}.key"));
    rotated.save(&vault).unwrap();

    let rotate_start = Arc::new(Barrier::new(CLIENTS + 1));
    let rotate_done = Arc::new(Barrier::new(CLIENTS + 1));

    let mut threads = Vec::new();
    for c in 0..CLIENTS as u64 {
        let (b1, b2) = (rotate_start.clone(), rotate_done.clone());
        threads.push(std::thread::spawn(move || {
            // phase 1: strictly before the rollover — epoch 0 serves,
            // reached through whichever replica the gateway picked
            let mut client =
                MoleClient::connect_with(gw_addr, ClientConfig::pinned("alpha", 0)).unwrap();
            assert_eq!(client.server_info().unwrap().epoch, 0);
            let d = client.d_len();
            let rows1 = client_rows(c, 1, PER_PHASE, d);
            let got1 = client.infer_batch(&rows1).unwrap();
            assert_eq!(client.drain_redirects(), 0);
            // close before the rollover so no spliced session straddles
            // the deliberate backend kill
            client.finish().unwrap();
            b1.wait();
            b2.wait();
            // phase 2: strictly after the drain — a fresh session pinned
            // to the drained epoch is refused typed by the backend, the
            // fault passes through the gateway untouched, and the client
            // re-resolves to epoch 1 exactly as it would un-fronted
            let mut client =
                MoleClient::connect_with(gw_addr, ClientConfig::pinned("alpha", 0)).unwrap();
            assert_eq!(client.server_info().unwrap().epoch, 1);
            let rows2 = client_rows(c, 2, PER_PHASE, d);
            let got2 = client.infer_batch(&rows2).unwrap();
            let redirects = client.drain_redirects();
            client.finish().unwrap();
            (got1, got2, redirects)
        }));
    }

    rotate_start.wait();
    // live rollover through the gateway's sealed fleet admin plane
    let mut admin = AdminClient::connect_with_credential(gw_addr, CRED).unwrap();
    let detail = admin
        .register("alpha", vault.to_str().unwrap(), KAPPA, SEED, SEED)
        .unwrap();
    assert_eq!(detail.lines().count(), 3, "{detail}");
    for addr in &addrs {
        let line = node_line(&detail, addr);
        assert!(line.contains("ok: registered alpha@1"), "{line}");
    }
    // kill one node mid-drain: register reached it, drain will not
    let victim = addrs[1].clone();
    servers[1].take().unwrap().stop();
    let detail = admin.drain("alpha", 0).unwrap();
    assert_eq!(detail.lines().count(), 3, "{detail}");
    for addr in &addrs {
        let line = node_line(&detail, addr);
        if *addr == victim {
            assert!(line.contains("failed:"), "dead node not reported failed: {line}");
        } else {
            assert!(line.contains("ok:") && line.contains("successor 1"), "{line}");
        }
    }
    rotate_done.wait();

    let results: Vec<_> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    std::fs::remove_file(&vault).ok();

    // bitwise ground truth per epoch, rebuilt from the same keys
    let (e0, e1) = (entry(&m, &root), entry(&m, &rotated));
    let d_len = m.geometry("small").unwrap().d_len();
    for (c, (got1, got2, redirects)) in results.iter().enumerate() {
        assert_eq!(got1.len(), PER_PHASE, "client {c} lost phase-1 responses");
        assert_eq!(got2.len(), PER_PHASE, "client {c} lost phase-2 responses");
        for (i, row) in client_rows(c as u64, 1, PER_PHASE, d_len).iter().enumerate() {
            assert_eq!(
                bits(&got1[i]),
                bits(&single_row_logits(&engine, &e0, row)),
                "client {c} phase-1 row {i} not bitwise-equal on epoch 0"
            );
        }
        for (i, row) in client_rows(c as u64, 2, PER_PHASE, d_len).iter().enumerate() {
            assert_eq!(
                bits(&got2[i]),
                bits(&single_row_logits(&engine, &e1, row)),
                "client {c} phase-2 row {i} not bitwise-equal on epoch 1"
            );
        }
        // the phase-2 handshake took exactly one typed redirect
        assert_eq!(*redirects, 1, "client {c}");
    }

    // fleet-status: per-node, never collapsed. The probe marks the
    // killed node down (poll briefly — its cadence is 100ms); its last
    // ack stays the failed drain, the others' the successful one.
    let deadline = Instant::now() + Duration::from_secs(5);
    let status = loop {
        let status = admin.fleet_status().unwrap();
        if node_line(&status, &victim).contains(" down ") {
            break status;
        }
        assert!(
            Instant::now() < deadline,
            "probe never marked the killed node down:\n{status}"
        );
        std::thread::sleep(Duration::from_millis(50));
    };
    assert_eq!(status.lines().count(), 3, "{status}");
    assert!(node_line(&status, &victim).contains("down last: failed:"), "{status}");
    for addr in addrs.iter().filter(|a| **a != victim) {
        assert!(node_line(&status, addr).contains("up last: ok:"), "{status}");
    }

    // the rollover completes on the surviving fleet; the dead node is
    // still reported per node, still failed
    let detail = admin.retire("alpha", 0).unwrap();
    assert_eq!(detail.lines().count(), 3, "{detail}");
    for addr in &addrs {
        let line = node_line(&detail, addr);
        if *addr == victim {
            assert!(line.contains("failed:"), "{line}");
        } else {
            assert!(line.contains("ok: retired alpha@0"), "{line}");
        }
    }
    admin.finish().unwrap();

    // a late client pinned to the retired epoch re-resolves through the
    // gateway and is served bitwise-correctly by epoch 1
    let mut late =
        MoleClient::connect_with(gw_addr, ClientConfig::pinned("alpha", 0)).unwrap();
    assert_eq!(late.server_info().unwrap().epoch, 1);
    let row = client_rows(99, 3, 1, d_len).remove(0);
    assert_eq!(
        bits(&late.infer(&row).unwrap()),
        bits(&single_row_logits(&engine, &e1, &row))
    );
    late.finish().unwrap();

    gw.stop();
    for s in servers.into_iter().flatten() {
        s.stop();
    }
}

/// The gateway's refusals are all typed: no credential ⇒ no admin plane
/// at all (sealed or bare), unrouteable models are named, bulk delivery
/// is pointed at a backend — while routed serving traffic is spliced
/// verbatim and bitwise-correct.
#[test]
fn gateway_refusals_are_typed_and_routing_is_verbatim() {
    let m = manifest();
    let engine = SharedEngine::new(m.clone());
    let (root, _) = epoch_keys();
    let server = spawn_backend(&m, &engine, &root);
    let backend_addr = server.local_addr();
    let gw = gateway_over(vec![backend_addr.to_string()], None);
    let gw_addr = gw.local_addr();

    // no credential configured: the sealed handshake is refused typed…
    let err = AdminClient::connect_with_credential(gw_addr, CRED).unwrap_err();
    assert!(err.to_string().contains("no admin credential"), "{err}");
    // …and bare admin verbs are refused too — the gateway never proxies
    // an unsealed admin frame to a backend
    let err = AdminClient::connect(gw_addr).unwrap().status().unwrap_err();
    assert!(err.to_string().contains("AdminHello"), "{err}");

    // a model outside the shard map is refused with its name
    let err =
        MoleClient::connect_with(gw_addr, ClientConfig::pinned("ghost", 0)).unwrap_err();
    assert!(err.to_string().contains("no shard for ghost@0"), "{err}");

    // fleet-status straight at a serving process: refused typed — a lone
    // node has no fleet view
    let mut direct = AdminClient::connect_with_credential(backend_addr, CRED).unwrap();
    let err = direct.fleet_status().unwrap_err();
    assert!(err.to_string().contains("mole gateway"), "{err}");
    direct.finish().unwrap();

    // routed serving traffic is untouched: bitwise equal through the
    // splice to single-row inference on the backend's epoch
    let e0 = entry(&m, &root);
    let d_len = m.geometry("small").unwrap().d_len();
    let mut client =
        MoleClient::connect_with(gw_addr, ClientConfig::pinned("alpha", 0)).unwrap();
    let rows = client_rows(1, 1, 3, d_len);
    let got = client.infer_batch(&rows).unwrap();
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(bits(&got[i]), bits(&single_row_logits(&engine, &e0, row)));
    }
    client.finish().unwrap();

    gw.stop();
    server.stop();
}
