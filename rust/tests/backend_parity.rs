//! Backend parity: the parallel backend must produce outputs identical to
//! the reference backend across random shapes — including the κ-block-
//! diagonal morph cases — plus tensor/linalg shape-error behaviour.
//!
//! "Identical" here is *bitwise*: the parallel backend runs the same
//! blocked kernel per row, only on different threads, so there is no
//! tolerance to hide behind.

use mole::backend::{Backend, ParallelBackend, RefBackend};
use mole::morph::MorphKey;
use mole::tensor::Tensor;
use mole::testkit::{forall, gen};
use mole::Geometry;

#[test]
fn prop_parallel_gemm_equals_ref() {
    forall(
        11,
        24,
        |rng| {
            let m = gen::usize_in(rng, 1, 150);
            let k = gen::usize_in(rng, 1, 200);
            let n = gen::usize_in(rng, 1, 180);
            let threads = gen::one_of(rng, &[0usize, 2, 3, 7]);
            let a = gen::tensor(rng, &[m, k], 1.0);
            let b = gen::tensor(rng, &[k, n], 1.0);
            (a, b, threads)
        },
        |(a, b, threads)| {
            let want = RefBackend::new().gemm(a, b).map_err(|e| e.to_string())?;
            let got = ParallelBackend::new(*threads)
                .gemm(a, b)
                .map_err(|e| e.to_string())?;
            if got == want {
                Ok(())
            } else {
                Err(format!(
                    "parallel({threads}) output differs (max diff {})",
                    got.max_abs_diff(&want).unwrap()
                ))
            }
        },
    );
}

#[test]
fn prop_parallel_gemm_accumulate_equals_ref() {
    forall(
        12,
        12,
        |rng| {
            let m = gen::usize_in(rng, 1, 80);
            let k = gen::usize_in(rng, 1, 80);
            let n = gen::usize_in(rng, 1, 80);
            let a = gen::tensor(rng, &[m, k], 1.0);
            let b = gen::tensor(rng, &[k, n], 1.0);
            let seed_c = gen::tensor(rng, &[m, n], 1.0);
            (a, b, seed_c)
        },
        |(a, b, seed_c)| {
            let mut want = seed_c.clone();
            RefBackend::new()
                .gemm_into(a, b, &mut want, true)
                .map_err(|e| e.to_string())?;
            let mut got = seed_c.clone();
            ParallelBackend::new(4)
                .gemm_into(a, b, &mut got, true)
                .map_err(|e| e.to_string())?;
            if got == want {
                Ok(())
            } else {
                Err("accumulating gemm differs across backends".into())
            }
        },
    );
}

/// κ-block-diagonal parity over every κ the SMALL geometry admits in the
/// paper's settings, driven through the real MorphKey path.
#[test]
fn prop_blockdiag_and_morph_parity() {
    forall(
        13,
        10,
        |rng| {
            let kappa = gen::one_of(rng, &[1usize, 3, 16, 48, 256]);
            let batch = gen::usize_in(rng, 1, 9);
            let seed = rng.next_u64();
            let rows = gen::tensor(rng, &[batch, 768], 1.0);
            (kappa, seed, rows)
        },
        |(kappa, seed, rows)| {
            let refb = RefBackend::new();
            let parb = ParallelBackend::new(0);
            // raw kernel parity
            let q = 768 / kappa;
            let core = {
                let mut r = mole::rng::Rng::new(*seed);
                gen::tensor(&mut r, &[q, q], 0.5)
            };
            let want = refb.apply_blockdiag(rows, &core).map_err(|e| e.to_string())?;
            let got = parb.apply_blockdiag(rows, &core).map_err(|e| e.to_string())?;
            if got != want {
                return Err(format!("blockdiag differs at kappa={kappa}"));
            }
            // and through the MorphKey API (explicit backends)
            let key = MorphKey::generate(Geometry::SMALL, *kappa, *seed)
                .map_err(|e| e.to_string())?;
            let a = key.morph_on(&refb, rows).map_err(|e| e.to_string())?;
            let b = key.morph_on(&parb, rows).map_err(|e| e.to_string())?;
            if a != b {
                return Err(format!("morph differs at kappa={kappa}"));
            }
            let ua = key.unmorph_on(&refb, &a).map_err(|e| e.to_string())?;
            let ub = key.unmorph_on(&parb, &b).map_err(|e| e.to_string())?;
            if ua != ub {
                return Err(format!("unmorph differs at kappa={kappa}"));
            }
            Ok(())
        },
    );
}

/// The C^ac construction — the acceptance-criteria hot path — agrees
/// across backends through the public build API.
#[test]
fn aug_conv_build_parity() {
    use mole::augconv::{build_aug_conv_from_c_on, ChannelPerm};
    let g = Geometry::SMALL;
    let mut rng = mole::rng::Rng::new(31);
    let w1 = Tensor::new(
        &[g.beta, g.alpha, g.p, g.p],
        rng.normal_vec(g.beta * g.alpha * g.p * g.p, 0.4),
    )
    .unwrap();
    let c = mole::d2r::build_c_matrix(&w1, &g).unwrap();
    for kappa in [3usize, 16] {
        let key = MorphKey::generate(g, kappa, 17).unwrap();
        let perm = ChannelPerm::generate(g.beta, 17);
        let a = build_aug_conv_from_c_on(&RefBackend::new(), &c, &key, &perm).unwrap();
        let b = build_aug_conv_from_c_on(&ParallelBackend::new(0), &c, &key, &perm).unwrap();
        assert_eq!(a, b, "C^ac differs across backends at kappa={kappa}");
    }
}

// ---------------------------------------------------------------------------
// shape-error behaviour (Tensor + backend surfaces)
// ---------------------------------------------------------------------------

#[test]
fn tensor_shape_errors() {
    // construction
    assert!(Tensor::new(&[2, 3], vec![0.0; 5]).is_err());
    assert!(Tensor::new(&[0, 3], vec![]).is_ok()); // empty is legal
    // reshape must conserve elements
    let t = Tensor::zeros(&[4, 4]);
    assert!(t.clone().reshape(&[2, 9]).is_err());
    assert!(t.clone().reshape(&[2, 8]).is_ok());
    // elementwise ops demand equal shapes
    let mut a = Tensor::zeros(&[3]);
    assert!(a.add_assign(&Tensor::zeros(&[4])).is_err());
    assert!(a.sub_assign(&Tensor::zeros(&[2])).is_err());
    assert!(a.rms_diff(&Tensor::zeros(&[5])).is_err());
    assert!(a.max_abs_diff(&Tensor::zeros(&[5])).is_err());
    // allclose returns false (not panic) on shape mismatch
    assert!(!Tensor::zeros(&[2]).allclose(&Tensor::zeros(&[3]), 1.0, 1.0));
}

#[test]
fn backend_shape_errors_are_uniform() {
    for be in [
        Box::new(RefBackend::new()) as Box<dyn Backend>,
        Box::new(ParallelBackend::new(2)) as Box<dyn Backend>,
    ] {
        // inner-dim mismatch
        assert!(be.gemm(&Tensor::zeros(&[2, 3]), &Tensor::zeros(&[4, 2])).is_err());
        // non-2d operands
        assert!(be.gemm(&Tensor::zeros(&[2]), &Tensor::zeros(&[2, 2])).is_err());
        // gemm_into output shape
        let mut c = Tensor::zeros(&[3, 3]);
        assert!(be
            .gemm_into(&Tensor::zeros(&[2, 2]), &Tensor::zeros(&[2, 2]), &mut c, false)
            .is_err());
        // blockdiag divisibility + squareness
        assert!(be
            .apply_blockdiag(&Tensor::zeros(&[1, 10]), &Tensor::zeros(&[3, 3]))
            .is_err());
        assert!(be
            .apply_blockdiag(&Tensor::zeros(&[1, 10]), &Tensor::zeros(&[2, 5]))
            .is_err());
    }
}

#[test]
fn morph_rejects_wrong_row_length() {
    let key = MorphKey::generate(Geometry::SMALL, 16, 3).unwrap();
    let bad = Tensor::zeros(&[2, 100]);
    assert!(key.morph(&bad).is_err());
    let bad3d = Tensor::zeros(&[2, 768]).reshape(&[2, 24, 32]).unwrap();
    assert!(key.morph(&bad3d).is_err());
}
