//! Backend parity: every backend must produce outputs in exact agreement
//! with the reference backend across random shapes — including the
//! κ-block-diagonal morph cases — plus tensor/linalg shape-error
//! behaviour.
//!
//! "Exact" has two regimes, classified **per backend instance**:
//!
//! * **Bitwise** — backends that preserve the reference per-element
//!   accumulation chain with plain mul+add: `parallel` (same kernel, just
//!   threaded), `simd` on its portable microkernel, and `parallel+simd`
//!   over the portable microkernel. No tolerance at all.
//! * **FMA drift, pinned ≤ max(4, √k) ULP at the output's scale** — the
//!   AVX2/NEON microkernels, whose *only* numeric deviation is the fused
//!   multiply-add rounding of each k-step (same association order). Each
//!   step differs by ≤ ½ ULP of that step's *product*, accumulating as a
//!   random walk over the k-length chain, so the bound is measured with
//!   `testkit::max_ulp_at_scale` (ULPs at the reference output's
//!   max-magnitude element — raw elementwise ULP distance explodes when
//!   a chain cancels to near zero) and scales with √k. Still a pinned
//!   deterministic bound, never an "allclose" epsilon.
//!
//! The classification comes from `SimdBackend::is_vectorized()` on the
//! instance under test, so the suite is correct on every target — on a
//! machine with no vector ISA (or under `MOLE_SIMD=off`) the simd rows
//! collapse into the bitwise regime and still run.

use mole::backend::{Backend, ParallelBackend, RefBackend, SimdBackend};
use mole::morph::MorphKey;
use mole::tensor::Tensor;
use mole::testkit::{forall, gen, max_ulp_at_scale};
use mole::Geometry;

/// How close a backend's output must sit to the reference output.
#[derive(Debug, Clone, Copy)]
enum Expect {
    Bitwise,
    /// FMA-only deviation: ≤ max(4, √k) ULP at the output tensor's
    /// max-magnitude scale, where k is the reduction chain length.
    FmaUlp,
}

/// Pinned drift bound for a k-length FMA chain vs the mul-then-add
/// reference: random-walk accumulation of ≤ ½-ULP-per-step product
/// roundings. √k sits 3–5× above empirically measured worst cases; the
/// floor of 4 covers short chains.
fn fma_bound(chain_len: usize) -> f64 {
    (chain_len as f64).sqrt().max(4.0)
}

/// Check one output against the reference under the backend's regime.
/// `chain_len` is the per-element reduction length (GEMM/blockdiag k).
fn check(
    label: &str,
    expect: Expect,
    chain_len: usize,
    got: &Tensor,
    want: &Tensor,
) -> Result<(), String> {
    match expect {
        Expect::Bitwise => {
            if got == want {
                Ok(())
            } else {
                Err(format!(
                    "{label}: bitwise mismatch (max abs diff {})",
                    got.max_abs_diff(want).unwrap()
                ))
            }
        }
        Expect::FmaUlp => {
            let worst = max_ulp_at_scale(got, want);
            let bound = fma_bound(chain_len);
            if worst <= bound {
                Ok(())
            } else {
                Err(format!(
                    "{label}: {worst:.1} ULP-at-scale from ref (bound {bound:.1}, k={chain_len})"
                ))
            }
        }
    }
}

/// The full backend matrix: every non-reference backend with its expected
/// agreement regime. The detected-ISA simd rows get `FmaUlp` only when a
/// vector ISA is actually driving them.
fn matrix() -> Vec<(String, Box<dyn Backend>, Expect)> {
    let mut v: Vec<(String, Box<dyn Backend>, Expect)> = vec![
        ("parallel(0)".into(), Box::new(ParallelBackend::new(0)), Expect::Bitwise),
        ("parallel(3)".into(), Box::new(ParallelBackend::new(3)), Expect::Bitwise),
        ("simd(portable)".into(), Box::new(SimdBackend::portable()), Expect::Bitwise),
        (
            "parallel+simd(portable)".into(),
            Box::new(ParallelBackend::over_simd(0, SimdBackend::portable())),
            Expect::Bitwise,
        ),
    ];
    let det = SimdBackend::new();
    let expect = if det.is_vectorized() { Expect::FmaUlp } else { Expect::Bitwise };
    v.push((det.describe(), Box::new(det), expect));
    v.push((
        format!("parallel+{}", det.describe()),
        Box::new(ParallelBackend::over_simd(0, det)),
        expect,
    ));
    v
}

#[test]
fn prop_backend_matrix_gemm_parity() {
    let backends = matrix();
    forall(
        11,
        24,
        |rng| {
            let m = gen::usize_in(rng, 1, 150);
            let k = gen::usize_in(rng, 1, 200);
            let n = gen::usize_in(rng, 1, 180);
            let a = gen::tensor(rng, &[m, k], 1.0);
            let b = gen::tensor(rng, &[k, n], 1.0);
            (a, b)
        },
        |(a, b)| {
            let k = a.shape()[1];
            let want = RefBackend::new().gemm(a, b).map_err(|e| e.to_string())?;
            for (label, be, expect) in &backends {
                let got = be.gemm(a, b).map_err(|e| e.to_string())?;
                check(label, *expect, k, &got, &want)?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_backend_matrix_accumulate_parity() {
    let backends = matrix();
    forall(
        12,
        12,
        |rng| {
            let m = gen::usize_in(rng, 1, 80);
            let k = gen::usize_in(rng, 1, 80);
            let n = gen::usize_in(rng, 1, 80);
            let a = gen::tensor(rng, &[m, k], 1.0);
            let b = gen::tensor(rng, &[k, n], 1.0);
            let seed_c = gen::tensor(rng, &[m, n], 1.0);
            (a, b, seed_c)
        },
        |(a, b, seed_c)| {
            let k = a.shape()[1];
            let mut want = seed_c.clone();
            RefBackend::new()
                .gemm_into(a, b, &mut want, true)
                .map_err(|e| e.to_string())?;
            for (label, be, expect) in &backends {
                let mut got = seed_c.clone();
                be.gemm_into(a, b, &mut got, true).map_err(|e| e.to_string())?;
                check(label, *expect, k, &got, &want)?;
            }
            Ok(())
        },
    );
}

/// Row-splitting must be invisible: `parallel+simd` is *bitwise* equal to
/// single-threaded `simd` with the same microkernel — whatever ISA was
/// detected — because the small-GEMM cutover depends only on (k, n).
#[test]
fn prop_parallel_simd_bitwise_equals_simd() {
    let simd = SimdBackend::new();
    forall(
        14,
        16,
        |rng| {
            let m = gen::usize_in(rng, 1, 120);
            let k = gen::usize_in(rng, 1, 300);
            let n = gen::usize_in(rng, 1, 300);
            let threads = gen::one_of(rng, &[0usize, 2, 5]);
            let a = gen::tensor(rng, &[m, k], 1.0);
            let b = gen::tensor(rng, &[k, n], 1.0);
            (a, b, threads)
        },
        |(a, b, threads)| {
            let want = simd.gemm(a, b).map_err(|e| e.to_string())?;
            let got = ParallelBackend::over_simd(*threads, simd)
                .gemm(a, b)
                .map_err(|e| e.to_string())?;
            if got == want {
                Ok(())
            } else {
                Err(format!(
                    "parallel({threads})+{} diverged from single-threaded simd",
                    simd.isa().name()
                ))
            }
        },
    );
}

/// κ-block-diagonal parity over every κ the SMALL geometry admits in the
/// paper's settings, driven through the real MorphKey path — the eq. 2/4
/// hot path every backend now routes through its own microkernel.
#[test]
fn prop_blockdiag_and_morph_parity() {
    let backends = matrix();
    forall(
        13,
        10,
        |rng| {
            let kappa = gen::one_of(rng, &[1usize, 3, 16, 48, 256]);
            let batch = gen::usize_in(rng, 1, 9);
            let seed = rng.next_u64();
            let rows = gen::tensor(rng, &[batch, 768], 1.0);
            (kappa, seed, rows)
        },
        |(kappa, seed, rows)| {
            let refb = RefBackend::new();
            // raw kernel parity
            let q = 768 / kappa;
            let core = {
                let mut r = mole::rng::Rng::new(*seed);
                gen::tensor(&mut r, &[q, q], 0.5)
            };
            let want = refb.apply_blockdiag(rows, &core).map_err(|e| e.to_string())?;
            let key = MorphKey::generate(Geometry::SMALL, *kappa, *seed)
                .map_err(|e| e.to_string())?;
            let m_ref = key.morph_on(&refb, rows).map_err(|e| e.to_string())?;
            let u_ref = key.unmorph_on(&refb, &m_ref).map_err(|e| e.to_string())?;
            for (label, be, expect) in &backends {
                let got = be.apply_blockdiag(rows, &core).map_err(|e| e.to_string())?;
                // per-element chain length is the block size q
                check(&format!("{label} blockdiag kappa={kappa}"), *expect, q, &got, &want)?;
                // and through the MorphKey API (explicit backends)
                let m_be = key.morph_on(be.as_ref(), rows).map_err(|e| e.to_string())?;
                check(&format!("{label} morph kappa={kappa}"), *expect, q, &m_be, &m_ref)?;
                // unmorph the *reference* morph so every backend inverts
                // the same operand
                let u_be = key.unmorph_on(be.as_ref(), &m_ref).map_err(|e| e.to_string())?;
                check(&format!("{label} unmorph kappa={kappa}"), *expect, q, &u_be, &u_ref)?;
            }
            Ok(())
        },
    );
}

/// The C^ac construction — the acceptance-criteria hot path — agrees
/// across the whole backend matrix through the public build API.
#[test]
fn aug_conv_build_parity() {
    use mole::augconv::{build_aug_conv_from_c_on, ChannelPerm};
    let g = Geometry::SMALL;
    let backends = matrix();
    let mut rng = mole::rng::Rng::new(31);
    let w1 = Tensor::new(
        &[g.beta, g.alpha, g.p, g.p],
        rng.normal_vec(g.beta * g.alpha * g.p * g.p, 0.4),
    )
    .unwrap();
    let c = mole::d2r::build_c_matrix(&w1, &g).unwrap();
    for kappa in [3usize, 16] {
        let key = MorphKey::generate(g, kappa, 17).unwrap();
        let perm = ChannelPerm::generate(g.beta, 17);
        let want = build_aug_conv_from_c_on(&RefBackend::new(), &c, &key, &perm).unwrap();
        for (label, be, expect) in &backends {
            let got = build_aug_conv_from_c_on(be.as_ref(), &c, &key, &perm).unwrap();
            // the build is q×q blocks of M'^-1 times C row-blocks: chain q
            check(
                &format!("{label} C^ac kappa={kappa}"),
                *expect,
                key.q(),
                got.matrix(),
                want.matrix(),
            )
            .unwrap();
            assert_eq!(got.bias(), want.bias(), "{label} C^ac bias kappa={kappa}");
        }
    }
}

/// The `MOLE_SIMD=off` escape hatch: construction under the env var picks
/// the portable microkernel, which is bitwise-identical to the reference
/// backend. (Other tests in this binary never *set* the var, and a
/// concurrently constructed backend that races into portable mode still
/// passes its — then trivially satisfied — ULP bound, so this is safe
/// under the parallel test runner.)
#[test]
fn mole_simd_off_forces_portable_kernel() {
    let prev = std::env::var("MOLE_SIMD").ok();
    std::env::set_var("MOLE_SIMD", "off");
    let forced = SimdBackend::new();
    // restore rather than remove: CI's forced-fallback matrix row sets
    // the var process-wide and later tests must still see it
    match prev {
        Some(v) => std::env::set_var("MOLE_SIMD", v),
        None => std::env::remove_var("MOLE_SIMD"),
    }
    assert!(!forced.is_vectorized());
    assert_eq!(forced.describe(), "simd(portable)");

    let mut rng = mole::rng::Rng::new(47);
    let a = Tensor::new(&[33, 257], rng.normal_vec(33 * 257, 1.0)).unwrap();
    let b = Tensor::new(&[257, 190], rng.normal_vec(257 * 190, 1.0)).unwrap();
    let want = RefBackend::new().gemm(&a, &b).unwrap();
    let got = forced.gemm(&a, &b).unwrap();
    assert_eq!(got, want, "forced-portable simd must be bitwise ref");
}

// ---------------------------------------------------------------------------
// shape-error behaviour (Tensor + backend surfaces)
// ---------------------------------------------------------------------------

#[test]
fn tensor_shape_errors() {
    // construction
    assert!(Tensor::new(&[2, 3], vec![0.0; 5]).is_err());
    assert!(Tensor::new(&[0, 3], vec![]).is_ok()); // empty is legal
    // reshape must conserve elements
    let t = Tensor::zeros(&[4, 4]);
    assert!(t.clone().reshape(&[2, 9]).is_err());
    assert!(t.clone().reshape(&[2, 8]).is_ok());
    // elementwise ops demand equal shapes
    let mut a = Tensor::zeros(&[3]);
    assert!(a.add_assign(&Tensor::zeros(&[4])).is_err());
    assert!(a.sub_assign(&Tensor::zeros(&[2])).is_err());
    assert!(a.rms_diff(&Tensor::zeros(&[5])).is_err());
    assert!(a.max_abs_diff(&Tensor::zeros(&[5])).is_err());
    // allclose returns false (not panic) on shape mismatch
    assert!(!Tensor::zeros(&[2]).allclose(&Tensor::zeros(&[3]), 1.0, 1.0));
}

#[test]
fn backend_shape_errors_are_uniform() {
    for be in [
        Box::new(RefBackend::new()) as Box<dyn Backend>,
        Box::new(ParallelBackend::new(2)) as Box<dyn Backend>,
        Box::new(SimdBackend::new()) as Box<dyn Backend>,
        Box::new(SimdBackend::portable()) as Box<dyn Backend>,
        Box::new(ParallelBackend::with_simd(2)) as Box<dyn Backend>,
    ] {
        // inner-dim mismatch
        assert!(be.gemm(&Tensor::zeros(&[2, 3]), &Tensor::zeros(&[4, 2])).is_err());
        // non-2d operands
        assert!(be.gemm(&Tensor::zeros(&[2]), &Tensor::zeros(&[2, 2])).is_err());
        // gemm_into output shape
        let mut c = Tensor::zeros(&[3, 3]);
        assert!(be
            .gemm_into(&Tensor::zeros(&[2, 2]), &Tensor::zeros(&[2, 2]), &mut c, false)
            .is_err());
        // blockdiag divisibility + squareness
        assert!(be
            .apply_blockdiag(&Tensor::zeros(&[1, 10]), &Tensor::zeros(&[3, 3]))
            .is_err());
        assert!(be
            .apply_blockdiag(&Tensor::zeros(&[1, 10]), &Tensor::zeros(&[2, 5]))
            .is_err());
    }
}

#[test]
fn morph_rejects_wrong_row_length() {
    let key = MorphKey::generate(Geometry::SMALL, 16, 3).unwrap();
    let bad = Tensor::zeros(&[2, 100]);
    assert!(key.morph(&bad).is_err());
    let bad3d = Tensor::zeros(&[2, 768]).reshape(&[2, 24, 32]).unwrap();
    assert!(key.morph(&bad3d).is_err());
}
