//! Property-based invariant sweeps (testkit) across the MoLe algebra —
//! the offline stand-in for proptest (DESIGN.md §5).

use mole::augconv::{build_aug_conv, ChannelPerm};
use mole::morph::MorphKey;
use mole::rng::Rng;
use mole::ssim::ssim_plane;
use mole::tensor::Tensor;
use mole::testkit::{forall, gen};
use mole::{d2r, linalg, Geometry};

/// ∀ seed, κ | κ divides αm²: unmorph(morph(x)) ≈ x and morph ≠ identity.
#[test]
fn prop_morph_roundtrip() {
    forall(
        1,
        12,
        |rng| {
            let kappa = gen::one_of(rng, &[1usize, 3, 16, 48, 256]);
            let seed = rng.next_u64();
            let rows = gen::tensor(rng, &[2, 768], 1.0);
            (kappa, seed, rows)
        },
        |(kappa, seed, rows)| {
            let key = MorphKey::generate(Geometry::SMALL, *kappa, *seed)
                .map_err(|e| e.to_string())?;
            let t = key.morph(rows).map_err(|e| e.to_string())?;
            let back = key.unmorph(&t).map_err(|e| e.to_string())?;
            if !back.allclose(rows, 5e-2, 5e-2) {
                return Err(format!(
                    "roundtrip diff {}",
                    back.max_abs_diff(rows).unwrap()
                ));
            }
            if t.rms_diff(rows).unwrap() < 0.05 {
                return Err("morph is a near-identity".into());
            }
            Ok(())
        },
    );
}

/// ∀ geometry: D^r·C == unroll(conv(D)) — eq. 1 holds for random kernels.
#[test]
fn prop_d2r_equals_conv() {
    forall(
        2,
        10,
        |rng| {
            let alpha = gen::usize_in(rng, 1, 3);
            let beta = gen::usize_in(rng, 1, 4);
            let m = gen::one_of(rng, &[4usize, 6, 8]);
            let p = gen::one_of(rng, &[1usize, 3]);
            let g = Geometry::new(alpha, m, beta, p);
            let w = gen::tensor(rng, &[beta, alpha, p, p], 0.5);
            let x = gen::tensor(rng, &[2, alpha, m, m], 1.0);
            (g, w, x)
        },
        |(g, w, x)| {
            let c = d2r::build_c_matrix(w, g).map_err(|e| e.to_string())?;
            let got = linalg::gemm(&d2r::unroll(x.clone()).unwrap(), &c)
                .map_err(|e| e.to_string())?;
            let want = d2r::unroll(
                mole::nn::conv2d_same(x, w, None).map_err(|e| e.to_string())?,
            )
            .unwrap();
            if got.allclose(&want, 1e-3, 1e-3) {
                Ok(())
            } else {
                Err(format!("max diff {}", got.max_abs_diff(&want).unwrap()))
            }
        },
    );
}

/// ∀ seed: the Aug-Conv equivalence (eq. 5) holds through the full
/// build path (key gen → C matrix → inverse combination → shuffle).
#[test]
fn prop_aug_conv_equivalence() {
    forall(
        3,
        8,
        |rng| {
            let kappa = gen::one_of(rng, &[3usize, 16]);
            let seed = rng.next_u64();
            (kappa, seed)
        },
        |(kappa, seed)| {
            let g = Geometry::SMALL;
            let mut rng = Rng::new(*seed);
            let w1 = gen::tensor(&mut rng, &[g.beta, g.alpha, g.p, g.p], 0.4);
            let b1: Vec<f32> = rng.normal_vec(g.beta, 0.1);
            let key = MorphKey::generate(g, *kappa, *seed).map_err(|e| e.to_string())?;
            let perm = ChannelPerm::generate(g.beta, *seed);
            let layer =
                build_aug_conv(&w1, &b1, &key, &perm).map_err(|e| e.to_string())?;
            let x = gen::tensor(&mut rng, &[2, g.alpha, g.m, g.m], 1.0);
            let t = key
                .morph(&d2r::unroll(x.clone()).unwrap())
                .map_err(|e| e.to_string())?;
            let f_aug = layer.forward(&t).map_err(|e| e.to_string())?;
            let f_plain = mole::nn::conv2d_same(&x, &w1, Some(&b1)).unwrap();
            let want = perm.apply_features(&f_plain).unwrap();
            if f_aug.allclose(&want, 0.1, 0.1) {
                Ok(())
            } else {
                Err(format!(
                    "equivalence diff {}",
                    f_aug.max_abs_diff(&want).unwrap()
                ))
            }
        },
    );
}

/// ∀ n, seed: LU inverse residual ‖A·A⁻¹ − I‖_max stays tiny for
/// diagonally-lifted random matrices (the morph-core family).
#[test]
fn prop_lu_inverse_residual() {
    forall(
        4,
        12,
        |rng| {
            let n = gen::usize_in(rng, 2, 96);
            let mut a = gen::tensor(rng, &[n, n], 0.5);
            for i in 0..n {
                let v = a.at2(i, i) + 3.0;
                a.set2(i, i, v);
            }
            a
        },
        |a| {
            let n = a.shape()[0];
            let inv = linalg::inverse(a).map_err(|e| e.to_string())?;
            let prod = linalg::gemm(a, &inv).unwrap();
            if prod.allclose(&Tensor::eye(n), 1e-3, 1e-3) {
                Ok(())
            } else {
                Err(format!(
                    "residual {}",
                    prod.max_abs_diff(&Tensor::eye(n)).unwrap()
                ))
            }
        },
    );
}

/// ∀ image pair: SSIM ∈ [-1, 1], symmetric, and 1 iff identical.
#[test]
fn prop_ssim_bounds_and_symmetry() {
    forall(
        5,
        10,
        |rng| {
            let a = gen::tensor(rng, &[16, 16], 0.3);
            let b = gen::tensor(rng, &[16, 16], 0.3);
            (a, b)
        },
        |(a, b)| {
            let ab = ssim_plane(a, b, 1.0).map_err(|e| e.to_string())?;
            let ba = ssim_plane(b, a, 1.0).unwrap();
            let aa = ssim_plane(a, a, 1.0).unwrap();
            if !(-1.0..=1.0 + 1e-9).contains(&ab) {
                return Err(format!("ssim out of range: {ab}"));
            }
            if (ab - ba).abs() > 1e-9 {
                return Err(format!("asymmetric: {ab} vs {ba}"));
            }
            if (aa - 1.0).abs() > 1e-9 {
                return Err(format!("ssim(a,a) = {aa}"));
            }
            Ok(())
        },
    );
}

/// ∀ perm: feature shuffle + inverse shuffle is identity; shuffle of
/// column groups in C^ac matches feature-space shuffle (commutation).
#[test]
fn prop_channel_shuffle_commutes() {
    forall(
        6,
        8,
        |rng| rng.next_u64(),
        |&seed| {
            let g = Geometry::SMALL;
            let mut rng = Rng::new(seed);
            let perm = ChannelPerm::generate(g.beta, seed);
            let f = gen::tensor(&mut rng, &[2, g.beta, g.n(), g.n()], 1.0);
            let back = perm
                .inverse()
                .apply_features(&perm.apply_features(&f).unwrap())
                .unwrap();
            if back == f {
                Ok(())
            } else {
                Err("shuffle roundtrip broke".into())
            }
        },
    );
}

/// ∀ kappa: eq.-16/17 accounting is internally consistent:
/// aug_conv_macs = conv1_macs + dev_extra, provider macs = αm²·q.
#[test]
fn prop_overhead_accounting_consistent() {
    use mole::overhead;
    forall(
        7,
        10,
        |rng| {
            let alpha = gen::usize_in(rng, 1, 4);
            let m = gen::one_of(rng, &[8usize, 16, 32]);
            let beta = gen::one_of(rng, &[8usize, 16, 64]);
            let p = gen::one_of(rng, &[1usize, 3, 5]);
            Geometry::new(alpha, m, beta, p)
        },
        |g| {
            if overhead::aug_conv_macs(g)
                != overhead::conv1_macs(g) + overhead::developer_extra_macs(g)
            {
                return Err("eq.17 accounting broke".into());
            }
            for kappa in [1usize, g.kappa_mc().max(1)] {
                if g.d_len() % kappa != 0 {
                    continue;
                }
                let q = g.d_len() / kappa;
                if overhead::provider_macs_per_image(g, kappa) != g.d_len() * q {
                    return Err("eq.16 accounting broke".into());
                }
            }
            Ok(())
        },
    );
}
