//! End-to-end integration tests over the full stack: provider → protocol →
//! developer → PJRT training/serving, plus failure injection.

use mole::coordinator::batcher::{BatcherConfig, ServingHandle, ServingModel};
use mole::coordinator::developer::run_tcp_session;
use mole::coordinator::provider::{ProviderNode, StreamPlan};
use mole::coordinator::MoleClient;
use mole::data::synth::{generate, SynthSpec};
use mole::keys::KeyBundle;
use mole::manifest::Manifest;
use mole::rng::Rng;
use mole::runtime::Engine;
use mole::tensor::Tensor;
use mole::Geometry;
use std::path::PathBuf;
use std::time::Duration;

fn artifacts() -> Manifest {
    Manifest::load(&PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap()
}

fn small_dataset(seed: u64) -> mole::data::Dataset {
    generate(&SynthSpec {
        geometry: Geometry::SMALL,
        num_classes: 4,
        train_per_class: 64,
        test_per_class: 32,
        noise: 0.06,
        max_shift: 1,
        seed,
    })
}

/// The full delivery + train + serve path in one test: a provider streams
/// morphed batches over TCP, the developer trains, and the trained model
/// then serves morphed inference through the batcher with sensible
/// accuracy on held-out data.
#[test]
fn deliver_train_serve_roundtrip() {
    let engine = Engine::new(artifacts()).unwrap();
    let dataset = small_dataset(3);
    let test = dataset.test.clone();
    let keys = KeyBundle::generate(Geometry::SMALL, 16, 99).unwrap();
    let provider = std::sync::Arc::new(ProviderNode::new(keys, dataset).unwrap());

    let outcome = run_tcp_session(
        provider.clone(),
        &engine,
        StreamPlan { num_batches: 120, batch_size: 64 },
        0.03, // gentle lr: short-run stability (see experiment.rs test note)
        5,
    )
    .unwrap();
    assert_eq!(outcome.steps, 120);
    assert!(outcome.losses[119] < outcome.losses[0] * 0.7);

    // hand the trained model to the serving worker
    let handle = ServingHandle::start(
        artifacts(),
        ServingModel {
            cac: outcome.cac.clone(),
            bias: outcome.bias.clone(),
            params: outcome.params.clone(),
        },
        BatcherConfig {
            max_batch: 8,
            timeout: Duration::from_millis(1),
            ..BatcherConfig::default()
        },
    )
    .unwrap();

    // morph test images through the provider key and classify; stride so
    // all classes appear (the synthetic split is class-ordered)
    let key = provider.morph_key();
    let per = 768;
    let mut correct = 0;
    let n = 64usize;
    let stride = test.len() / n;
    for j in 0..n {
        let i = j * stride;
        let img = Tensor::new(&[1, 3, 16, 16], test.images.data()[i * per..][..per].to_vec())
            .unwrap();
        let row = key.morph(&mole::d2r::unroll(img).unwrap()).unwrap();
        let logits = handle.infer(row.row(0)).unwrap();
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if pred == test.labels[i] as usize {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    assert!(acc > 0.5, "served accuracy {acc} (chance 0.25)");
}

/// Protocol failure injection: a client that speaks the serving flow at
/// a training provider (its first frame after the handshake is a `Hello`
/// / `InferRequest`, never the expected `Conv1Weights`) gets rejected
/// with a typed error — the provider neither hangs nor panics.
#[test]
fn protocol_violations_are_rejected() {
    let dataset = small_dataset(5);
    let keys = KeyBundle::generate(Geometry::SMALL, 16, 11).unwrap();
    let provider = std::sync::Arc::new(ProviderNode::new(keys, dataset).unwrap());

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let p = provider.clone();
    let h = std::thread::spawn(move || {
        let (sock, _) = listener.accept().unwrap();
        p.run_session(sock, StreamPlan { num_batches: 1, batch_size: 64 }, 1)
    });

    // a serving-mode client: sends its own Hello where the provider
    // expects Conv1Weights (out-of-order message type on the wire)
    let result = MoleClient::connect(addr);
    let res = h.join().unwrap();
    assert!(res.is_err(), "provider accepted an out-of-order message");
    // and the client recognizes the peer as a training provider (its
    // Hello carries no model name) instead of limping into infer()
    let err = result.err().expect("serving handshake against a provider must fail");
    assert!(err.to_string().contains("provider"), "{err}");
}

/// Key isolation: two providers with different seeds produce different
/// fingerprints, different morphs, and a developer trained against one
/// C^ac cannot decode data morphed under the other key.
#[test]
fn different_keys_do_not_interoperate() {
    let ka = KeyBundle::generate(Geometry::SMALL, 16, 1).unwrap();
    let kb = KeyBundle::generate(Geometry::SMALL, 16, 2).unwrap();
    assert_ne!(ka.fingerprint(), kb.fingerprint());
    let mka = ka.morph_key().unwrap();
    let mkb = kb.morph_key().unwrap();
    let mut rng = Rng::new(3);
    let rows = Tensor::new(&[2, 768], rng.normal_vec(2 * 768, 1.0)).unwrap();
    let ta = mka.morph(&rows).unwrap();
    // unmorphing with the wrong key must NOT recover the data
    let back_wrong = mkb.unmorph(&ta).unwrap();
    assert!(back_wrong.rms_diff(&rows).unwrap() > 0.1);
    let back_right = mka.unmorph(&ta).unwrap();
    assert!(back_right.allclose(&rows, 1e-2, 1e-2));
}

/// The engine rejects artifact/arg mismatches instead of corrupting state,
/// and keeps working afterwards.
#[test]
fn engine_survives_bad_calls() {
    let engine = Engine::new(artifacts()).unwrap();
    assert!(engine.exec("no_such_artifact", &[]).is_err());
    let bad = Tensor::zeros(&[1, 1]);
    assert!(engine
        .exec("morph_apply_small_q48_b8", &[bad.clone().into(), bad.into()])
        .is_err());
    // still healthy
    let mut rng = Rng::new(1);
    let d = Tensor::new(&[8, 768], rng.normal_vec(8 * 768, 1.0)).unwrap();
    let core = Tensor::new(&[48, 48], rng.normal_vec(48 * 48, 1.0)).unwrap();
    let out = engine
        .exec("morph_apply_small_q48_b8", &[d.into(), core.into()])
        .unwrap();
    assert_eq!(out[0].shape(), &[8, 768]);
}

/// Morph keys regenerate identically from vault files (disk round trip
/// through KeyBundle) and morph identically via both the rust path and the
/// XLA artifact.
#[test]
fn vault_roundtrip_preserves_morph_behaviour() {
    let dir = std::env::temp_dir().join("mole_it_vault.key");
    let keys = KeyBundle::generate(Geometry::SMALL, 16, 77).unwrap();
    keys.save(&dir).unwrap();
    let loaded = KeyBundle::load(&dir).unwrap();
    std::fs::remove_file(&dir).ok();

    let k1 = keys.morph_key().unwrap();
    let k2 = loaded.morph_key().unwrap();
    let mut rng = Rng::new(5);
    let rows = Tensor::new(&[8, 768], rng.normal_vec(8 * 768, 1.0)).unwrap();
    let t1 = k1.morph(&rows).unwrap();
    let t2 = k2.morph(&rows).unwrap();
    assert_eq!(t1, t2);

    let engine = Engine::new(artifacts()).unwrap();
    let out = engine
        .exec(
            "morph_apply_small_q48_b8",
            &[rows.into(), k2.core().clone().into()],
        )
        .unwrap();
    assert!(out[0].allclose(&t1, 1e-4, 1e-4));
}
