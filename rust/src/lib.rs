//! # MoLe — Morphed Learning
//!
//! A full-system reproduction of *"Towards Efficient and Secure Delivery of
//! Data for Training and Inference with Privacy-Preserving"* (Shen, Liu,
//! Chen, Li): data morphing + Augmented Convolutional (Aug-Conv) layers.
//!
//! Layer map (bottom to top):
//! * **Compute backends ([`backend`])** — the pluggable dense-kernel
//!   layer every hot path dispatches through: `RefBackend` (cache-blocked
//!   single-threaded oracle), `SimdBackend` (packed-panel AVX2/NEON
//!   microkernels with a mandatory portable fallback, FMA drift pinned
//!   to ≤ max(4, √k) ULP at the output's scale vs ref) and
//!   `ParallelBackend` (row-panel scoped threads over a
//!   pluggable inner kernel — `parallel` or `parallel+simd` — bitwise
//!   identical to its inner kernel). Selected via the `[backend]` config
//!   section, `MOLE_BACKEND`, or auto (parallel+simd on multi-core with
//!   a vector ISA). Future GPU/sharded backends plug in here.
//! * **Linear algebra ([`linalg`], [`tensor`])** — tensor GEMM entry
//!   points delegating to the active backend, plus LU / inversion /
//!   norms.
//! * **Runtime ([`runtime`], [`manifest`])** — one `Engine` surface with
//!   two implementations: the default pure-Rust *interpreter* (executes
//!   every artifact kind against in-crate ops; no files, no external
//!   deps) and, behind the `pjrt` cargo feature, the PJRT/XLA path that
//!   runs the AOT-lowered HLO artifacts from `python/` (`make
//!   artifacts`). The manifest falls back to a built-in contract when no
//!   `artifacts/` directory exists, so the default build is
//!   self-contained.
//! * **Key vault ([`keys`])** — the provider's secret bundle (morph seed,
//!   κ, channel permutation) with **key epochs**: `KeyBundle::rotate` /
//!   [`keys::rotate_file`] advance to fresh material while recording
//!   fingerprint lineage, so epoch N and N+1 can serve side by side
//!   during rollover. The vault also derives the **per-operator
//!   admin-plane credentials** (labeled HMACs over the secrets plus an
//!   operator label, in-tree SHA-256 in [`hash`]) that authenticate
//!   `mole admin` against a credential-gated server, and vault files can
//!   travel inside an ed25519-signed envelope ([`sign`]) so a tampered
//!   vault is refused at load.
//! * **Delivery system ([`coordinator`])** — the Fig.-1 protocol between
//!   data provider and developer (versioned wire frames with model/epoch
//!   routing and typed lifecycle faults), training on morphed streams,
//!   and the multi-tenant serving path: a **live**
//!   [`coordinator::ModelRegistry`] of named models × key epochs — each
//!   an adaptive micro-batcher lane over a shared `Send + Sync` engine,
//!   moving through the Active → Draining → Retired rollover lifecycle —
//!   fronted by a concurrent TCP server (`mole serve`) with an admin
//!   surface ([`coordinator::admin`], `mole admin`) for runtime
//!   register/drain/retire — loopback-gated by default, or MAC-
//!   authenticated (challenge–response, anti-replay counters) once a
//!   vault-derived credential is installed — plus the matching
//!   multi-connection load driver (`mole loadgen`).
//! * **Bulk delivery plane ([`coordinator::delivery`])** — protocol-v7
//!   chunked morphed-dataset transfer: per-chunk SHA-256 manifests,
//!   hash-while-decode verification with a single automatic retry,
//!   crash-resumable journaled pulls, and striping across parallel
//!   connections (`mole push-dataset` / `mole pull-dataset`); bulk
//!   sessions ride the same accept budget as serving, so overload sheds
//!   typed instead of starving inference.
//! * **Client SDK ([`coordinator::client`])** — the typed
//!   [`coordinator::MoleClient`] (connect / `infer` / `infer_batch` /
//!   `stream_training`) and [`coordinator::DeliveryClient`] plus the
//!   provider-side session endpoint; no consumer
//!   outside the coordinator touches raw protocol frames.
//!
//! Quick orientation:
//! * [`morph`] — morphing matrix **M** (block-diagonal, core **M′**) and
//!   its application to d2r rows (paper §3.2).
//! * [`d2r`] — data-to-row unrolling and the convolution matrix **C**
//!   (paper §3.1, eq. 1).
//! * [`augconv`] — **C**^ac = **M**⁻¹·**C** + feature channel
//!   randomization (paper §3.3).
//! * [`attacks`] / [`security`] — §4.2's three attacks, operational and
//!   theoretical.
//! * [`overhead`] / [`baselines`] — §4.3 and Table 1.

pub mod attacks;
pub mod augconv;
pub mod backend;
pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod d2r;
pub mod data;
pub mod error;
pub mod hash;
pub mod json;
pub mod keys;
pub mod linalg;
pub mod logging;
pub mod manifest;
pub mod metrics;
pub mod morph;
pub mod nn;
pub mod overhead;
pub mod rng;
pub mod runtime;
pub mod security;
pub mod sign;
pub mod ssim;
pub mod tensor;
pub mod testkit;

pub use error::{Error, Result};

/// Geometry of the replaceable first convolutional layer (paper §3).
///
/// Mirrors `python/compile/geometry.py`; the authoritative instance used at
/// runtime is parsed from `artifacts/manifest.json` so the two languages
/// cannot drift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Geometry {
    /// Input channels (α).
    pub alpha: usize,
    /// Input spatial size (m × m).
    pub m: usize,
    /// Output channels of the first layer (β).
    pub beta: usize,
    /// Kernel size (p × p), SAME zero padding.
    pub p: usize,
}

impl Geometry {
    pub const fn new(alpha: usize, m: usize, beta: usize, p: usize) -> Self {
        Self { alpha, m, beta, p }
    }

    /// SAME padding ⇒ output spatial size n == m.
    pub const fn n(&self) -> usize {
        self.m
    }

    /// Length of the d2r row vector D^r = α·m².
    pub const fn d_len(&self) -> usize {
        self.alpha * self.m * self.m
    }

    /// Length of the feature row vector F^r = β·n².
    pub const fn f_len(&self) -> usize {
        self.beta * self.n() * self.n()
    }

    /// Largest κ for the minimal-cost setting, eq. 13: κ_mc = αm²/n².
    pub const fn kappa_mc(&self) -> usize {
        self.d_len() / (self.n() * self.n())
    }

    /// Morphing core size q = αm²/κ (eq. 3); κ must divide αm².
    pub fn q_for_kappa(&self, kappa: usize) -> Result<usize> {
        if kappa == 0 || self.d_len() % kappa != 0 {
            return Err(Error::Geometry(format!(
                "kappa={kappa} does not divide alpha*m^2={}",
                self.d_len()
            )));
        }
        Ok(self.d_len() / kappa)
    }

    /// The trainable small configuration (16×16×3, β=16).
    pub const SMALL: Geometry = Geometry::new(3, 16, 16, 3);
    /// The paper's analysis configuration: CIFAR + VGG-16 first layer.
    pub const CIFAR_VGG16: Geometry = Geometry::new(3, 32, 64, 3);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_paper_numbers() {
        let g = Geometry::CIFAR_VGG16;
        assert_eq!(g.d_len(), 3072); // αm² = 3·32²
        assert_eq!(g.f_len(), 65536); // βn² = 64·32²
        assert_eq!(g.kappa_mc(), 3); // eq. 13: 3·1024/1024
        assert_eq!(g.q_for_kappa(1).unwrap(), 3072);
        assert_eq!(g.q_for_kappa(3).unwrap(), 1024);
        assert!(g.q_for_kappa(5).is_err());
    }

    #[test]
    fn geometry_small() {
        let g = Geometry::SMALL;
        assert_eq!(g.d_len(), 768);
        assert_eq!(g.f_len(), 4096);
        assert_eq!(g.kappa_mc(), 3);
        assert_eq!(g.q_for_kappa(16).unwrap(), 48);
    }
}
