//! Tiny self-contained logger (the default build carries no external
//! crates, so there is no `log`/`env_logger` facade).
//!
//! Level comes from `MOLE_LOG` (error|warn|info|debug|trace), default
//! `info`. Timestamps are seconds since logger init. Call sites use
//! [`info`]/[`debug`]/[`warn`] with a preformatted message:
//!
//! ```
//! mole::logging::info(&format!("compiled in {:.1}ms", 1.25));
//! ```

use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, ordered from most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

struct Logger {
    start: Instant,
    max: Level,
}

static LOGGER: OnceLock<Logger> = OnceLock::new();

fn logger() -> &'static Logger {
    LOGGER.get_or_init(|| {
        let max = match std::env::var("MOLE_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        };
        Logger { start: Instant::now(), max }
    })
}

/// Install the logger (idempotent; lazily initialized on first use, so
/// calling this is optional — it just pins the start timestamp).
pub fn init() {
    let _ = logger();
}

/// Whether `level` is currently emitted (lets hot paths skip formatting).
pub fn enabled(level: Level) -> bool {
    level <= logger().max
}

/// Emit one log line at `level`.
pub fn log(level: Level, msg: &str) {
    let l = logger();
    if level <= l.max {
        let t = l.start.elapsed().as_secs_f64();
        eprintln!("[{t:9.3}s {:5} mole] {msg}", level.label());
    }
}

pub fn error(msg: &str) {
    log(Level::Error, msg);
}

pub fn warn(msg: &str) {
    log(Level::Warn, msg);
}

pub fn info(msg: &str) {
    log(Level::Info, msg);
}

pub fn debug(msg: &str) {
    log(Level::Debug, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        info("logging smoke test");
    }

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Info);
        assert!(Level::Debug > Level::Info);
        // default level emits info but not debug/trace
        assert!(enabled(Level::Error));
    }
}
