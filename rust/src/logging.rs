//! Tiny `log` backend (env_logger is not in the offline vendor set).
//!
//! Level comes from `MOLE_LOG` (error|warn|info|debug|trace), default
//! `info`. Timestamps are seconds since logger init.

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

struct MoleLogger {
    start: Instant,
    level: Level,
}

impl log::Log for MoleLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = self.start.elapsed().as_secs_f64();
            eprintln!(
                "[{t:9.3}s {:5} {}] {}",
                record.level(),
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent; subsequent calls are no-ops).
pub fn init() {
    let level = match std::env::var("MOLE_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    let logger = Box::new(MoleLogger { start: Instant::now(), level });
    if log::set_boxed_logger(logger).is_ok() {
        log::set_max_level(LevelFilter::Trace);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
