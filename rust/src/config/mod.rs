//! Configuration system: a TOML-subset parser (sections, key = value,
//! strings / ints / floats / bools, `#` comments) plus the typed configs
//! for the launcher. The offline vendor set has neither serde nor toml,
//! so this is self-contained.

use crate::{Error, Geometry, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Parsed raw config: section → key → raw string value.
#[derive(Debug, Clone, Default)]
pub struct RawConfig {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl RawConfig {
    /// Parse TOML-subset text.
    pub fn parse(src: &str) -> Result<Self> {
        let mut cfg = RawConfig::default();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let mut v = v.trim().to_string();
            if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
                v = v[1..v.len() - 1].to_string();
            }
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v);
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).unwrap_or(default)
    }

    pub fn get_usize(&self, section: &str, key: &str, default: usize) -> Result<usize> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Config(format!("[{section}] {key} = {v:?} is not an integer"))
            }),
        }
    }

    pub fn get_u64(&self, section: &str, key: &str, default: u64) -> Result<u64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Config(format!("[{section}] {key} = {v:?} is not an integer"))
            }),
        }
    }

    pub fn get_f64(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Config(format!("[{section}] {key} = {v:?} is not a number"))
            }),
        }
    }

    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(section, key) {
            None => Ok(default),
            Some("true") => Ok(true),
            Some("false") => Ok(false),
            Some(v) => Err(Error::Config(format!(
                "[{section}] {key} = {v:?} is not a bool"
            ))),
        }
    }

    /// Names of the sections nested under `prefix` (e.g. with sections
    /// `[serving.models.alpha]` and `[serving.models.beta]`,
    /// `section_names_under("serving.models")` yields
    /// `["alpha", "beta"]`). Sorted (BTreeMap order), so derived
    /// structures are deterministic.
    pub fn section_names_under(&self, prefix: &str) -> Vec<String> {
        let pat = format!("{prefix}.");
        self.sections
            .keys()
            .filter_map(|k| k.strip_prefix(&pat))
            .filter(|rest| !rest.is_empty())
            .map(|rest| rest.to_string())
            .collect()
    }
}

/// One `[serving.models.NAME]` entry: a named serving model for the
/// multi-tenant registry (`mole serve` builds its demo stack from it).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    /// Morphing scale factor κ for this model's keys.
    pub kappa: usize,
    /// Key-material seed (root epoch).
    pub seed: u64,
    /// How many consecutive key epochs to serve (>= 1). `epochs = 2`
    /// registers the root bundle and one rotation — the mid-rollover
    /// shape where epoch N and N+1 run side by side.
    pub epochs: u32,
}

/// One `[gateway.shards.NAME]` entry: which backends serve which
/// epochs of a model behind `mole gateway`. Kept stringly here — the
/// epoch selector grammar (`"*"` / `"N"` / `"N-M"`) is owned by
/// [`crate::coordinator::gateway::EpochSelector::parse`], which the
/// gateway runs at bind so a typo fails startup, not a session.
///
/// Shards match in section-name order (the parser sorts sections), so
/// name them to order them (`alpha0`, `alpha1`, …) when one model needs
/// several — an explicit `model` key routes a section whose name is
/// not the model.
#[derive(Debug, Clone, PartialEq)]
pub struct GatewayShardSpec {
    /// Model routed to this shard (`model` key; defaults to the
    /// section name).
    pub model: String,
    /// Epoch selector source text (`epochs` key; default `"*"`).
    pub epochs: String,
    /// Comma-separated `backends` list, split and trimmed.
    pub backends: Vec<String>,
}

/// Full launcher configuration with defaults matching the repo layout.
#[derive(Debug, Clone)]
pub struct MoleConfig {
    /// Directory holding the AOT artifacts + manifest.json.
    pub artifacts_dir: String,
    /// First-layer geometry name ("small" | "cifar").
    pub geometry: Geometry,
    /// Morphing scale factor κ.
    pub kappa: usize,
    /// Key-material seed.
    pub seed: u64,
    /// Provider listen / developer connect address.
    pub addr: String,
    /// Micro-batcher: max batch size (must be an artifact batch size).
    pub max_batch: usize,
    /// Micro-batcher: max queue wait before a partial batch is flushed.
    pub batch_timeout_ms: u64,
    /// Micro-batcher: floor of the adaptive hold window, in µs.
    pub min_batch_timeout_us: u64,
    /// Micro-batcher: adapt the hold window to observed fill levels.
    pub adaptive_batching: bool,
    /// Micro-batcher: per-lane submit-queue bound (in-flight rows).
    /// Requests past the bound are shed with a typed
    /// `Fault::Overloaded` instead of queueing without limit.
    pub queue_bound: usize,
    /// Serving: session-driver shards (threads running the readiness
    /// event loop; each multiplexes many sessions).
    pub serve_workers: usize,
    /// Serving: max concurrently open sessions (serving + admin).
    /// Connections past the budget are refused with a session-scoped
    /// `Fault::Overloaded` and closed.
    pub max_sessions: usize,
    /// Serving: max accepted-but-not-yet-adopted connections (the
    /// bounded accept queue between the acceptor and the drivers).
    pub max_pending: usize,
    /// Serving: accept `Admin*` frames (live register / drain / retire /
    /// status). Off, the registry is fixed at startup.
    pub admin_enabled: bool,
    /// Serving: path to an admin-credential file (64 hex chars, the
    /// `mole keygen --credential-out` output). Empty = no credential:
    /// the admin plane keeps the legacy loopback-only gate. Non-empty =
    /// every admin frame must be MAC-authenticated against the loaded
    /// credential, and non-loopback admin peers become legal.
    pub admin_credential_file: String,
    /// Serving: path to a key vault whose **operator roster** gates the
    /// admin plane (per-operator credentials, `mole operator add|
    /// revoke|list`). Supersedes [`MoleConfig::admin_credential_file`]
    /// when both are set: each admin frame is attributed to the operator
    /// whose credential sealed it, and operators can be revoked live.
    /// The vault may be a signed (`MOLESIG1`) envelope; combine with
    /// [`MoleConfig::vault_signer_file`] to refuse unsigned or
    /// re-signed vaults.
    pub admin_vault_file: String,
    /// Serving: append-only admin audit log path (created `0600`).
    /// Every authenticated admin verb — and every refused frame — is
    /// recorded attributed to its operator label. Empty = no audit log.
    pub audit_log_file: String,
    /// Keys: path to an ed25519 verifying-key file (the `mole
    /// sign-keygen --pub` output). Non-empty pins every vault load that
    /// honors it (`serve --admin-vault`, `mole operator`): a vault that
    /// is unsigned, tampered, or signed by any other key is refused.
    pub vault_signer_file: String,
    /// Training: steps / learning rate.
    pub train_steps: usize,
    pub lr: f64,
    /// Dataset seed + per-class sample counts for the synthetic corpus.
    pub data_seed: u64,
    pub train_per_class: usize,
    pub test_per_class: usize,
    /// Compute backend for the hot-path linalg: "auto" | "ref" |
    /// "parallel" | "simd" | "parallel+simd" (see [`crate::backend`];
    /// auto resolves to parallel+simd on multi-core machines with a
    /// vector ISA).
    pub backend: String,
    /// Worker threads for parallel backends (0 = one per core).
    pub backend_threads: usize,
    /// Models the serving registry hosts (`[serving.models.NAME]`
    /// sections; defaults to one `demo_model` entry built from the
    /// top-level κ/seed when none are configured).
    pub models: Vec<ModelSpec>,
    /// Gateway: listen address for `mole gateway`.
    pub gateway_listen: String,
    /// Gateway: backend health-probe cadence, in ms.
    pub gateway_probe_interval_ms: u64,
    /// Gateway: per-backend dial timeout (data path, probes, fan-out).
    pub gateway_connect_timeout_ms: u64,
    /// Gateway: operator-credential file. Doubles as the inbound admin
    /// gate (sealed sessions terminate at the gateway) and the outbound
    /// credential the gateway authenticates to each backend with. Empty
    /// = the gateway refuses all admin frames typed.
    pub gateway_credential_file: String,
    /// Gateway shard map (`[gateway.shards.MODEL]` sections, matched in
    /// order). Empty = `mole gateway` refuses to start.
    pub gateway_shards: Vec<GatewayShardSpec>,
}

impl Default for MoleConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: "artifacts".to_string(),
            geometry: Geometry::SMALL,
            kappa: 16,
            seed: 20190506,
            addr: "127.0.0.1:7433".to_string(),
            max_batch: 32,
            batch_timeout_ms: 2,
            min_batch_timeout_us: 200,
            adaptive_batching: true,
            queue_bound: 1024,
            serve_workers: 8,
            max_sessions: 1024,
            max_pending: 128,
            admin_enabled: true,
            admin_credential_file: String::new(),
            admin_vault_file: String::new(),
            audit_log_file: String::new(),
            vault_signer_file: String::new(),
            train_steps: 300,
            lr: 0.05,
            data_seed: 7,
            train_per_class: 320,
            test_per_class: 64,
            backend: "auto".to_string(),
            backend_threads: 0,
            models: vec![ModelSpec {
                name: "demo_model".to_string(),
                kappa: 16,
                seed: 20190506,
                epochs: 1,
            }],
            gateway_listen: "127.0.0.1:7600".to_string(),
            gateway_probe_interval_ms: 500,
            gateway_connect_timeout_ms: 1000,
            gateway_credential_file: String::new(),
            gateway_shards: Vec::new(),
        }
    }
}

impl MoleConfig {
    /// Build from a raw config (missing keys fall back to defaults).
    pub fn from_raw(raw: &RawConfig) -> Result<Self> {
        let d = MoleConfig::default();
        let geometry = match raw.get_or("mole", "geometry", "small") {
            "small" => Geometry::SMALL,
            "cifar" => Geometry::CIFAR_VGG16,
            other => {
                return Err(Error::Config(format!("unknown geometry {other:?}")))
            }
        };
        let kappa = raw.get_usize("mole", "kappa", d.kappa)?;
        let seed = raw.get_u64("mole", "seed", d.seed)?;
        let mut models = Vec::new();
        for name in raw.section_names_under("serving.models") {
            let section = format!("serving.models.{name}");
            let epochs = raw.get_u64(&section, "epochs", 1)? as u32;
            if epochs == 0 {
                return Err(Error::Config(format!("[{section}] epochs must be >= 1")));
            }
            models.push(ModelSpec {
                name,
                kappa: raw.get_usize(&section, "kappa", kappa)?,
                seed: raw.get_u64(&section, "seed", seed)?,
                epochs,
            });
        }
        if models.is_empty() {
            models.push(ModelSpec { name: "demo_model".to_string(), kappa, seed, epochs: 1 });
        }
        let mut gateway_shards = Vec::new();
        for name in raw.section_names_under("gateway.shards") {
            let section = format!("gateway.shards.{name}");
            let backends: Vec<String> = raw
                .get_or(&section, "backends", "")
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            if backends.is_empty() {
                return Err(Error::Config(format!(
                    "[{section}] needs a non-empty comma-separated `backends` list"
                )));
            }
            gateway_shards.push(GatewayShardSpec {
                model: raw.get_or(&section, "model", &name).to_string(),
                epochs: raw.get_or(&section, "epochs", "*").to_string(),
                backends,
            });
        }
        Ok(Self {
            artifacts_dir: raw.get_or("mole", "artifacts_dir", &d.artifacts_dir).to_string(),
            geometry,
            kappa,
            seed,
            addr: raw.get_or("net", "addr", &d.addr).to_string(),
            max_batch: raw.get_usize("serving", "max_batch", d.max_batch)?,
            batch_timeout_ms: raw.get_u64("serving", "batch_timeout_ms", d.batch_timeout_ms)?,
            min_batch_timeout_us: raw.get_u64(
                "serving",
                "min_timeout_us",
                d.min_batch_timeout_us,
            )?,
            adaptive_batching: raw.get_bool("serving", "adaptive", d.adaptive_batching)?,
            queue_bound: raw.get_usize("serving", "queue_bound", d.queue_bound)?,
            serve_workers: raw.get_usize("serving", "workers", d.serve_workers)?,
            max_sessions: raw.get_usize("serving", "max_sessions", d.max_sessions)?,
            max_pending: raw.get_usize("serving", "max_pending", d.max_pending)?,
            admin_enabled: raw.get_bool("serving", "admin", d.admin_enabled)?,
            admin_credential_file: raw
                .get_or("serving", "admin_credential_file", &d.admin_credential_file)
                .to_string(),
            admin_vault_file: raw
                .get_or("serving", "admin_vault_file", &d.admin_vault_file)
                .to_string(),
            audit_log_file: raw
                .get_or("serving", "audit_log_file", &d.audit_log_file)
                .to_string(),
            vault_signer_file: raw
                .get_or("keys", "signer_file", &d.vault_signer_file)
                .to_string(),
            train_steps: raw.get_usize("train", "steps", d.train_steps)?,
            lr: raw.get_f64("train", "lr", d.lr)?,
            data_seed: raw.get_u64("data", "seed", d.data_seed)?,
            train_per_class: raw.get_usize("data", "train_per_class", d.train_per_class)?,
            test_per_class: raw.get_usize("data", "test_per_class", d.test_per_class)?,
            backend: raw.get_or("backend", "kind", &d.backend).to_string(),
            backend_threads: raw.get_usize("backend", "threads", d.backend_threads)?,
            models,
            gateway_listen: raw.get_or("gateway", "listen", &d.gateway_listen).to_string(),
            gateway_probe_interval_ms: raw.get_u64(
                "gateway",
                "probe_interval_ms",
                d.gateway_probe_interval_ms,
            )?,
            gateway_connect_timeout_ms: raw.get_u64(
                "gateway",
                "connect_timeout_ms",
                d.gateway_connect_timeout_ms,
            )?,
            gateway_credential_file: raw
                .get_or("gateway", "credential_file", &d.gateway_credential_file)
                .to_string(),
            gateway_shards,
        })
    }

    /// Load from file, or defaults when the path doesn't exist.
    pub fn load_or_default(path: &Path) -> Result<Self> {
        if path.exists() {
            Self::from_raw(&RawConfig::load(path)?)
        } else {
            Ok(Self::default())
        }
    }

    /// Activate the configured compute backend for this process (no-op if
    /// a backend was already selected by env var or first use).
    pub fn install_backend(&self) -> Result<()> {
        crate::backend::install(&self.backend, self.backend_threads)
    }

    /// The micro-batcher policy encoded by the `[serving]` section.
    pub fn batcher(&self) -> crate::coordinator::BatcherConfig {
        crate::coordinator::BatcherConfig {
            max_batch: self.max_batch,
            timeout: std::time::Duration::from_millis(self.batch_timeout_ms),
            min_timeout: std::time::Duration::from_micros(self.min_batch_timeout_us),
            adaptive: self.adaptive_batching,
            queue_bound: self.queue_bound,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# MoLe sample config
[mole]
geometry = "small"
kappa = 3
seed = 99

[serving]
max_batch = 8
batch_timeout_ms = 5
min_timeout_us = 150
adaptive = false
queue_bound = 64
workers = 4
max_sessions = 50
max_pending = 9
admin = false

[train]
steps = 10
lr = 0.1
"#;

    #[test]
    fn parse_sections_and_types() {
        let raw = RawConfig::parse(SAMPLE).unwrap();
        assert_eq!(raw.get("mole", "geometry"), Some("small"));
        assert_eq!(raw.get_usize("mole", "kappa", 0).unwrap(), 3);
        assert_eq!(raw.get_usize("serving", "max_batch", 0).unwrap(), 8);
        assert_eq!(raw.get_f64("train", "lr", 0.0).unwrap(), 0.1);
        assert_eq!(raw.get("nope", "x"), None);
        assert_eq!(raw.get_bool("mole", "missing", true).unwrap(), true);
    }

    #[test]
    fn typed_config_defaults_and_overrides() {
        let cfg = MoleConfig::from_raw(&RawConfig::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.kappa, 3);
        assert_eq!(cfg.train_steps, 10);
        assert_eq!(cfg.max_batch, 8);
        assert_eq!(cfg.min_batch_timeout_us, 150);
        assert!(!cfg.adaptive_batching);
        assert_eq!(cfg.serve_workers, 4);
        assert_eq!(cfg.max_sessions, 50);
        assert_eq!(cfg.max_pending, 9);
        // backpressure bounds default sane when absent
        assert_eq!(MoleConfig::default().queue_bound, 1024);
        assert_eq!(MoleConfig::default().max_sessions, 1024);
        assert_eq!(MoleConfig::default().max_pending, 128);
        assert!(!cfg.admin_enabled);
        // admin defaults on when the key is absent, with no credential
        assert!(MoleConfig::default().admin_enabled);
        assert!(MoleConfig::default().admin_credential_file.is_empty());
        assert!(cfg.admin_credential_file.is_empty());
        // a configured credential file parses through
        let raw = RawConfig::parse(
            "[serving]\nadmin_credential_file = \"ops/admin.cred\"\n",
        )
        .unwrap();
        let with_cred = MoleConfig::from_raw(&raw).unwrap();
        assert_eq!(with_cred.admin_credential_file, "ops/admin.cred");
        // the v8 admin-plane keys: operator vault, audit log, signer pin
        assert!(MoleConfig::default().admin_vault_file.is_empty());
        assert!(MoleConfig::default().audit_log_file.is_empty());
        assert!(MoleConfig::default().vault_signer_file.is_empty());
        let raw = RawConfig::parse(
            "[serving]\nadmin_vault_file = \"ops/provider.key\"\n\
             audit_log_file = \"ops/admin-audit.log\"\n\
             [keys]\nsigner_file = \"ops/vault-signer.pub\"\n",
        )
        .unwrap();
        let with_ops = MoleConfig::from_raw(&raw).unwrap();
        assert_eq!(with_ops.admin_vault_file, "ops/provider.key");
        assert_eq!(with_ops.audit_log_file, "ops/admin-audit.log");
        assert_eq!(with_ops.vault_signer_file, "ops/vault-signer.pub");
        // default kept where unspecified
        assert_eq!(cfg.addr, "127.0.0.1:7433");
        assert_eq!(cfg.geometry, Geometry::SMALL);
        // the [serving] section round-trips into a batcher policy
        let b = cfg.batcher();
        assert_eq!(b.max_batch, 8);
        assert_eq!(b.timeout, std::time::Duration::from_millis(5));
        assert_eq!(b.min_timeout, std::time::Duration::from_micros(150));
        assert!(!b.adaptive);
        assert_eq!(b.queue_bound, 64);
    }

    #[test]
    fn bad_values_rejected() {
        let raw = RawConfig::parse("[mole]\nkappa = banana\n").unwrap();
        assert!(MoleConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[mole]\ngeometry = \"weird\"\n").unwrap();
        assert!(MoleConfig::from_raw(&raw).is_err());
        assert!(RawConfig::parse("keyonly\n").is_err());
    }

    #[test]
    fn backend_section() {
        let raw =
            RawConfig::parse("[backend]\nkind = \"parallel\"\nthreads = 4\n").unwrap();
        let cfg = MoleConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.backend, "parallel");
        assert_eq!(cfg.backend_threads, 4);
        // default is auto with per-core threads
        assert_eq!(MoleConfig::default().backend, "auto");
        assert_eq!(MoleConfig::default().backend_threads, 0);
        // unknown kinds surface as config errors on install
        let bad = MoleConfig { backend: "quantum".into(), ..MoleConfig::default() };
        assert!(bad.install_backend().is_err());
    }

    #[test]
    fn serving_models_table() {
        // no table ⇒ one demo_model entry from the top-level kappa/seed
        let cfg = MoleConfig::from_raw(&RawConfig::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(
            cfg.models,
            vec![ModelSpec { name: "demo_model".into(), kappa: 3, seed: 99, epochs: 1 }]
        );

        let src = r#"
[mole]
kappa = 16
seed = 5

[serving.models.alpha]
seed = 11

[serving.models.beta]
kappa = 48
seed = 22
epochs = 2
"#;
        let raw = RawConfig::parse(src).unwrap();
        assert_eq!(raw.section_names_under("serving.models"), ["alpha", "beta"]);
        assert!(raw.section_names_under("nope").is_empty());
        let cfg = MoleConfig::from_raw(&raw).unwrap();
        assert_eq!(
            cfg.models,
            vec![
                // missing keys inherit the top-level [mole] values
                ModelSpec { name: "alpha".into(), kappa: 16, seed: 11, epochs: 1 },
                ModelSpec { name: "beta".into(), kappa: 48, seed: 22, epochs: 2 },
            ]
        );

        // epochs = 0 is rejected
        let raw =
            RawConfig::parse("[serving.models.x]\nepochs = 0\n").unwrap();
        assert!(MoleConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn gateway_table() {
        // absent ⇒ defaults, and an empty shard map (the gateway itself
        // refuses to start on one — config just reports what was written)
        let cfg = MoleConfig::from_raw(&RawConfig::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.gateway_listen, "127.0.0.1:7600");
        assert_eq!(cfg.gateway_probe_interval_ms, 500);
        assert_eq!(cfg.gateway_connect_timeout_ms, 1000);
        assert!(cfg.gateway_credential_file.is_empty());
        assert!(cfg.gateway_shards.is_empty());

        let src = r#"
[gateway]
listen = "0.0.0.0:7700"
probe_interval_ms = 250
connect_timeout_ms = 400
credential_file = "ops/gateway.cred"

[gateway.shards.alpha]
epochs = "0-3"
backends = "127.0.0.1:7433, 127.0.0.1:7434 ,127.0.0.1:7435"

[gateway.shards.beta]
backends = "127.0.0.1:7436"

# a second alpha shard: section names must be unique and order the
# match (sorted), so the catch-all names itself last and routes via
# the explicit model key
[gateway.shards.zz-alpha-rest]
model = "alpha"
backends = "127.0.0.1:7437"
"#;
        let cfg = MoleConfig::from_raw(&RawConfig::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.gateway_listen, "0.0.0.0:7700");
        assert_eq!(cfg.gateway_probe_interval_ms, 250);
        assert_eq!(cfg.gateway_connect_timeout_ms, 400);
        assert_eq!(cfg.gateway_credential_file, "ops/gateway.cred");
        assert_eq!(
            cfg.gateway_shards,
            vec![
                GatewayShardSpec {
                    model: "alpha".into(),
                    epochs: "0-3".into(),
                    // comma-split and whitespace-trimmed
                    backends: vec![
                        "127.0.0.1:7433".into(),
                        "127.0.0.1:7434".into(),
                        "127.0.0.1:7435".into(),
                    ],
                },
                // epochs defaults to the match-everything selector
                GatewayShardSpec {
                    model: "beta".into(),
                    epochs: "*".into(),
                    backends: vec!["127.0.0.1:7436".into()],
                },
                // explicit model key overrides the section name
                GatewayShardSpec {
                    model: "alpha".into(),
                    epochs: "*".into(),
                    backends: vec!["127.0.0.1:7437".into()],
                },
            ]
        );

        // a shard with no backends is a config error, not a silent
        // zero-replica shard
        let raw = RawConfig::parse("[gateway.shards.x]\nepochs = \"*\"\n").unwrap();
        assert!(MoleConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[gateway.shards.x]\nbackends = \" , \"\n").unwrap();
        assert!(MoleConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn comments_and_blank_lines() {
        let raw = RawConfig::parse("# top\n\n[a]\nx = 1 # trailing\n").unwrap();
        assert_eq!(raw.get("a", "x"), Some("1"));
    }
}
