//! SHA-256 / SHA-512 (FIPS 180-4) and HMAC-SHA256 (RFC 2104) — in-tree
//! so the default build has no external dependencies.
//!
//! Used for key-vault fingerprints/integrity ([`crate::keys`]), the
//! vault-derived admin credential and its per-frame MACs
//! ([`crate::coordinator::admin`]), the ed25519 signer/verifier
//! ([`crate::sign`], which RFC 8032 defines over SHA-512), and the
//! cross-language C-matrix checksum test. Not a general crypto library:
//! only the primitives the repo needs, with streaming [`Sha256`] /
//! [`Sha512`] APIs mirroring the subset of the `sha2` crate the code
//! previously used, plus [`hmac_sha256`] and the constant-time tag
//! comparison [`ct_eq`].

/// Round constants: fractional parts of the cube roots of the first 64
/// primes.
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4,
    0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe,
    0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f,
    0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
    0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
    0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116,
    0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7,
    0xc67178f2,
];

/// Initial hash state: fractional parts of the square roots of the first
/// 8 primes.
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
    0x5be0cd19,
];

/// Streaming SHA-256 hasher.
#[derive(Clone)]
pub struct Sha256 {
    h: [u32; 8],
    /// Bytes buffered toward the next 64-byte block.
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length in bytes.
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Self {
        Self { h: H0, buf: [0u8; 64], buf_len: 0, total: 0 }
    }

    /// Absorb more message bytes.
    pub fn update(&mut self, data: impl AsRef<[u8]>) {
        let mut data = data.as_ref();
        self.total = self.total.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let want = 64 - self.buf_len;
            let take = want.min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let (block, rest) = data.split_at(64);
            self.compress(block.try_into().unwrap());
            data = rest;
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    /// Finish and return the 32-byte digest.
    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total.wrapping_mul(8);
        // padding: 0x80, zeros, 8-byte big-endian bit length
        self.update([0x80u8]);
        while self.buf_len != 56 {
            self.update([0u8]);
        }
        // write the length directly into the buffer (update would count it)
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        self.compress(&block);
        let mut out = [0u8; 32];
        for (i, &h) in self.h.iter().enumerate() {
            out[i * 4..(i + 1) * 4].copy_from_slice(&h.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes(chunk.try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.h;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.h[0] = self.h[0].wrapping_add(a);
        self.h[1] = self.h[1].wrapping_add(b);
        self.h[2] = self.h[2].wrapping_add(c);
        self.h[3] = self.h[3].wrapping_add(d);
        self.h[4] = self.h[4].wrapping_add(e);
        self.h[5] = self.h[5].wrapping_add(f);
        self.h[6] = self.h[6].wrapping_add(g);
        self.h[7] = self.h[7].wrapping_add(h);
    }
}

/// One-shot digest.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

/// One-shot digest as lowercase hex.
pub fn sha256_hex(data: &[u8]) -> String {
    to_hex(&sha256(data))
}

/// SHA-512 round constants: fractional parts of the cube roots of the
/// first 80 primes.
const K512: [u64; 80] = [
    0x428a2f98d728ae22, 0x7137449123ef65cd, 0xb5c0fbcfec4d3b2f, 0xe9b5dba58189dbbc,
    0x3956c25bf348b538, 0x59f111f1b605d019, 0x923f82a4af194f9b, 0xab1c5ed5da6d8118,
    0xd807aa98a3030242, 0x12835b0145706fbe, 0x243185be4ee4b28c, 0x550c7dc3d5ffb4e2,
    0x72be5d74f27b896f, 0x80deb1fe3b1696b1, 0x9bdc06a725c71235, 0xc19bf174cf692694,
    0xe49b69c19ef14ad2, 0xefbe4786384f25e3, 0x0fc19dc68b8cd5b5, 0x240ca1cc77ac9c65,
    0x2de92c6f592b0275, 0x4a7484aa6ea6e483, 0x5cb0a9dcbd41fbd4, 0x76f988da831153b5,
    0x983e5152ee66dfab, 0xa831c66d2db43210, 0xb00327c898fb213f, 0xbf597fc7beef0ee4,
    0xc6e00bf33da88fc2, 0xd5a79147930aa725, 0x06ca6351e003826f, 0x142929670a0e6e70,
    0x27b70a8546d22ffc, 0x2e1b21385c26c926, 0x4d2c6dfc5ac42aed, 0x53380d139d95b3df,
    0x650a73548baf63de, 0x766a0abb3c77b2a8, 0x81c2c92e47edaee6, 0x92722c851482353b,
    0xa2bfe8a14cf10364, 0xa81a664bbc423001, 0xc24b8b70d0f89791, 0xc76c51a30654be30,
    0xd192e819d6ef5218, 0xd69906245565a910, 0xf40e35855771202a, 0x106aa07032bbd1b8,
    0x19a4c116b8d2d0c8, 0x1e376c085141ab53, 0x2748774cdf8eeb99, 0x34b0bcb5e19b48a8,
    0x391c0cb3c5c95a63, 0x4ed8aa4ae3418acb, 0x5b9cca4f7763e373, 0x682e6ff3d6b2b8a3,
    0x748f82ee5defb2fc, 0x78a5636f43172f60, 0x84c87814a1f0ab72, 0x8cc702081a6439ec,
    0x90befffa23631e28, 0xa4506cebde82bde9, 0xbef9a3f7b2c67915, 0xc67178f2e372532b,
    0xca273eceea26619c, 0xd186b8c721c0c207, 0xeada7dd6cde0eb1e, 0xf57d4f7fee6ed178,
    0x06f067aa72176fba, 0x0a637dc5a2c898a6, 0x113f9804bef90dae, 0x1b710b35131c471b,
    0x28db77f523047d84, 0x32caab7b40c72493, 0x3c9ebe0a15c9bebc, 0x431d67c49c100d4c,
    0x4cc5d4becb3e42b6, 0x597f299cfc657e2a, 0x5fcb6fab3ad6faec, 0x6c44198c4a475817,
];

/// SHA-512 initial state: fractional parts of the square roots of the
/// first 8 primes.
const H0_512: [u64; 8] = [
    0x6a09e667f3bcc908, 0xbb67ae8584caa73b, 0x3c6ef372fe94f82b, 0xa54ff53a5f1d36f1,
    0x510e527fade682d1, 0x9b05688c2b3e6c1f, 0x1f83d9abfb41bd6b, 0x5be0cd19137e2179,
];

/// Streaming SHA-512 hasher (FIPS 180-4). Added for the in-tree ed25519
/// ([`crate::sign`]), which RFC 8032 defines over SHA-512; same
/// structure as [`Sha256`] with 128-byte blocks and u64 words.
#[derive(Clone)]
pub struct Sha512 {
    h: [u64; 8],
    /// Bytes buffered toward the next 128-byte block.
    buf: [u8; 128],
    buf_len: usize,
    /// Total message length in bytes (u128 length field on the wire; a
    /// u64 byte count is far beyond anything this repo hashes).
    total: u64,
}

impl Default for Sha512 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha512 {
    pub fn new() -> Self {
        Self { h: H0_512, buf: [0u8; 128], buf_len: 0, total: 0 }
    }

    /// Absorb more message bytes.
    pub fn update(&mut self, data: impl AsRef<[u8]>) {
        let mut data = data.as_ref();
        self.total = self.total.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let want = 128 - self.buf_len;
            let take = want.min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 128 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 128 {
            let (block, rest) = data.split_at(128);
            self.compress(block.try_into().unwrap());
            data = rest;
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    /// Finish and return the 64-byte digest.
    pub fn finalize(mut self) -> [u8; 64] {
        let bit_len = (self.total as u128).wrapping_mul(8);
        self.update([0x80u8]);
        while self.buf_len != 112 {
            self.update([0u8]);
        }
        // write the 16-byte big-endian bit length directly (update would
        // count it into the total)
        self.buf[112..128].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        self.compress(&block);
        let mut out = [0u8; 64];
        for (i, &h) in self.h.iter().enumerate() {
            out[i * 8..(i + 1) * 8].copy_from_slice(&h.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 128]) {
        let mut w = [0u64; 80];
        for (i, chunk) in block.chunks_exact(8).enumerate() {
            w[i] = u64::from_be_bytes(chunk.try_into().unwrap());
        }
        for i in 16..80 {
            let s0 = w[i - 15].rotate_right(1) ^ w[i - 15].rotate_right(8) ^ (w[i - 15] >> 7);
            let s1 = w[i - 2].rotate_right(19) ^ w[i - 2].rotate_right(61) ^ (w[i - 2] >> 6);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.h;
        for i in 0..80 {
            let s1 = e.rotate_right(14) ^ e.rotate_right(18) ^ e.rotate_right(41);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K512[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(28) ^ a.rotate_right(34) ^ a.rotate_right(39);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.h[0] = self.h[0].wrapping_add(a);
        self.h[1] = self.h[1].wrapping_add(b);
        self.h[2] = self.h[2].wrapping_add(c);
        self.h[3] = self.h[3].wrapping_add(d);
        self.h[4] = self.h[4].wrapping_add(e);
        self.h[5] = self.h[5].wrapping_add(f);
        self.h[6] = self.h[6].wrapping_add(g);
        self.h[7] = self.h[7].wrapping_add(h);
    }
}

/// One-shot SHA-512 digest.
pub fn sha512(data: &[u8]) -> [u8; 64] {
    let mut h = Sha512::new();
    h.update(data);
    h.finalize()
}

/// Lowercase hex of arbitrary bytes.
pub fn to_hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// Parse hex (upper or lower case, even length) back into bytes.
pub fn from_hex(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    let digit = |c: u8| -> Option<u8> {
        match c {
            b'0'..=b'9' => Some(c - b'0'),
            b'a'..=b'f' => Some(c - b'a' + 10),
            b'A'..=b'F' => Some(c - b'A' + 10),
            _ => None,
        }
    };
    s.as_bytes()
        .chunks_exact(2)
        .map(|p| Some(digit(p[0])? << 4 | digit(p[1])?))
        .collect()
}

/// HMAC-SHA256 block size (RFC 2104: the hash's input block, not its
/// output).
const HMAC_BLOCK: usize = 64;

/// One-shot HMAC-SHA256 (RFC 2104): keys longer than one block are
/// hashed first, shorter ones zero-padded.
pub fn hmac_sha256(key: &[u8], msg: &[u8]) -> [u8; 32] {
    let mut k = [0u8; HMAC_BLOCK];
    if key.len() > HMAC_BLOCK {
        k[..32].copy_from_slice(&sha256(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut inner = Sha256::new();
    inner.update(k.map(|b| b ^ 0x36));
    inner.update(msg);
    let mut outer = Sha256::new();
    outer.update(k.map(|b| b ^ 0x5c));
    outer.update(inner.finalize());
    outer.finalize()
}

/// Constant-time equality for MAC/tag comparison: every byte pair is
/// XOR-folded into one accumulator, so the running time does not depend
/// on *where* two equal-length inputs first differ (lengths are public;
/// a length mismatch returns early).
pub fn ct_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut acc = 0u8;
    for (x, y) in a.iter().zip(b.iter()) {
        acc |= x ^ y;
    }
    acc == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    // Known-answer vectors cross-checked against python hashlib.
    #[test]
    fn fips_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b"The quick brown fox jumps over the lazy dog"),
            "d7a8fbb307d7809469ca9abcb0082e4f8d5651e46d3cdb762d02d0bf37c9e592"
        );
    }

    // FIPS 180-4 / NIST example vectors for SHA-512 (one-block,
    // two-block, empty), cross-checked against python hashlib.
    #[test]
    fn sha512_fips_vectors() {
        assert_eq!(
            to_hex(&sha512(b"")),
            "cf83e1357eefb8bdf1542850d66d8007d620e4050b5715dc83f4a921d36ce9ce\
             47d0d13c5d85f2b0ff8318d2877eec2f63b931bd47417a81a538327af927da3e"
        );
        assert_eq!(
            to_hex(&sha512(b"abc")),
            "ddaf35a193617abacc417349ae20413112e6fa4e89a97ea20a9eeee64b55d39a\
             2192992a274fc1a836ba3c23a3feebbd454d4423643ce80e2a9ac94fa54ca49f"
        );
        assert_eq!(
            to_hex(&sha512(
                b"abcdefghbcdefghicdefghijdefghijkefghijklfghijklmghijklmnhijklmno\
                  ijklmnopjklmnopqklmnopqrlmnopqrsmnopqrstnopqrstu"
            )),
            "8e959b75dae313da8cf4f72814fc143f8f7779c6eb9f7fa17299aeadb6889018\
             501d289e4900f7e4331b99dec4b5433ac7d329eeb6dd26545e96e55b874be909"
        );
    }

    #[test]
    fn sha512_padding_boundaries_and_streaming() {
        // 111/112/128 bytes straddle SHA-512's one-vs-two padding-block
        // boundary (length field starts at offset 112 of a 128B block)
        for n in [111usize, 112, 128, 129] {
            let data = vec![b'a'; n];
            let mut h = Sha512::new();
            for chunk in data.chunks(23) {
                h.update(chunk);
            }
            assert_eq!(h.finalize(), sha512(&data), "length {n}");
        }
        assert_ne!(sha512(b"a"), sha512(b"b"));
    }

    #[test]
    fn padding_boundaries() {
        // 55/56/64 bytes straddle the one-vs-two padding-block boundary
        assert_eq!(
            sha256_hex(&[b'a'; 55]),
            "9f4390f8d30c2dd92ec9f095b65e2b9ae9b0a925a5258e241c9f1e910f734318"
        );
        assert_eq!(
            sha256_hex(&[b'a'; 56]),
            "b35439a4ac6f0948b6d6f9e3c6af0f5f590ce20f1bde7090ef7970686ec6738a"
        );
        assert_eq!(
            sha256_hex(&[b'a'; 64]),
            "ffe054fe7ae0cb6dc65c3af9b61d5209f439851db43d0ba5997337df154668eb"
        );
    }

    // RFC 4231 test cases 1, 2 and 6 (short key, ASCII key, key longer
    // than the block size).
    #[test]
    fn hmac_rfc4231_vectors() {
        assert_eq!(
            to_hex(&hmac_sha256(&[0x0b; 20], b"Hi There")),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
        assert_eq!(
            to_hex(&hmac_sha256(b"Jefe", b"what do ya want for nothing?")),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
        assert_eq!(
            to_hex(&hmac_sha256(
                &[0xaa; 131],
                b"Test Using Larger Than Block-Size Key - Hash Key First"
            )),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn hmac_key_sensitivity() {
        // exactly one block, one under, one over: the padding boundaries
        for n in [63usize, 64, 65] {
            let a = hmac_sha256(&vec![1u8; n], b"msg");
            let b = hmac_sha256(&vec![2u8; n], b"msg");
            assert_ne!(a, b, "key length {n}");
            assert_eq!(a, hmac_sha256(&vec![1u8; n], b"msg"));
        }
        assert_ne!(hmac_sha256(b"k", b"a"), hmac_sha256(b"k", b"b"));
    }

    #[test]
    fn ct_eq_semantics() {
        assert!(ct_eq(b"same bytes", b"same bytes"));
        assert!(!ct_eq(b"same bytes", b"same bytez"));
        assert!(!ct_eq(b"short", b"longer than"));
        assert!(ct_eq(b"", b""));
    }

    #[test]
    fn hex_roundtrip() {
        let bytes = sha256(b"roundtrip");
        assert_eq!(from_hex(&to_hex(&bytes)).unwrap(), bytes.to_vec());
        assert_eq!(from_hex("00ffAB"), Some(vec![0x00, 0xff, 0xab]));
        assert_eq!(from_hex("abc"), None); // odd length
        assert_eq!(from_hex("zz"), None); // non-hex
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data = vec![b'x'; 1000];
        let mut h = Sha256::new();
        // uneven chunk sizes exercise the buffering path
        for chunk in data.chunks(37) {
            h.update(chunk);
        }
        assert_eq!(
            to_hex(&h.finalize()),
            "44f8354494a5ba03ba1792a8d3e9c534c47a9181980fde7a3f44b06ef2ae7c7f"
        );
        assert_eq!(sha256(&data), sha256(&data));
    }
}
