//! Key vault — secure storage of the provider's secrets (paper §3.2/§3.3:
//! "the privacy-preserving feature … relies on the secure storage of M
//! [and] the detailed channel order used for rand").
//!
//! Stored material: the morph seed + κ (the core is regenerated
//! deterministically — see [`crate::morph::MorphKey::from_seed`]), the
//! channel permutation, the geometry, and a SHA-256 fingerprint binding
//! them together. The binary format is versioned and integrity-checked;
//! the vault file is chmod 0600 on unix. Keys never cross the delivery
//! protocol — only `T^r` and `C^ac` do (§4.1 HBC surface).

use crate::augconv::ChannelPerm;
use crate::hash::{to_hex, Sha256};
use crate::morph::MorphKey;
use crate::{Error, Geometry, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"MOLEKEY1";

/// The provider's secret bundle for one delivery session.
#[derive(Debug, Clone)]
pub struct KeyBundle {
    pub geometry: Geometry,
    pub kappa: usize,
    pub morph_seed: u64,
    pub perm: ChannelPerm,
}

impl KeyBundle {
    /// Generate a fresh bundle (morph key material + channel permutation).
    pub fn generate(geometry: Geometry, kappa: usize, seed: u64) -> Result<Self> {
        // validate kappa against the geometry before accepting it
        geometry.q_for_kappa(kappa)?;
        let perm = ChannelPerm::generate(geometry.beta, seed);
        Ok(Self { geometry, kappa, morph_seed: seed, perm })
    }

    /// Materialize the morph key (regenerates the core from the seed; the
    /// condition-number gate makes this deterministic).
    pub fn morph_key(&self) -> Result<MorphKey> {
        MorphKey::from_seed(self.geometry, self.kappa, self.morph_seed)
    }

    /// SHA-256 fingerprint over all key material (hex). Used to detect
    /// tampering and to name sessions without revealing secrets.
    pub fn fingerprint(&self) -> String {
        let mut h = Sha256::new();
        h.update(MAGIC);
        h.update(self.encode_body());
        to_hex(&h.finalize())
    }

    fn encode_body(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for v in [
            self.geometry.alpha as u64,
            self.geometry.m as u64,
            self.geometry.beta as u64,
            self.geometry.p as u64,
            self.kappa as u64,
            self.morph_seed,
            self.perm.beta() as u64,
        ] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for &p in self.perm.as_slice() {
            out.extend_from_slice(&(p as u32).to_le_bytes());
        }
        out
    }

    /// Serialize to the versioned vault format: MAGIC | body | SHA-256.
    pub fn to_bytes(&self) -> Vec<u8> {
        let body = self.encode_body();
        let mut out = Vec::with_capacity(8 + body.len() + 32);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&body);
        let mut h = Sha256::new();
        h.update(MAGIC);
        h.update(&body);
        out.extend_from_slice(&h.finalize());
        out
    }

    /// Deserialize + integrity-check.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 8 + 7 * 8 + 32 || &bytes[..8] != MAGIC {
            return Err(Error::Key("bad vault magic or truncated file".into()));
        }
        let (payload, digest) = bytes.split_at(bytes.len() - 32);
        let mut h = Sha256::new();
        h.update(payload);
        if h.finalize().as_slice() != digest {
            return Err(Error::Key("vault integrity check failed".into()));
        }
        let body = &payload[8..];
        let u = |i: usize| -> u64 {
            u64::from_le_bytes(body[i * 8..(i + 1) * 8].try_into().unwrap())
        };
        let geometry = Geometry::new(u(0) as usize, u(1) as usize, u(2) as usize, u(3) as usize);
        let kappa = u(4) as usize;
        let morph_seed = u(5);
        let beta = u(6) as usize;
        let perm_bytes = &body[7 * 8..];
        if perm_bytes.len() != beta * 4 {
            return Err(Error::Key("vault permutation length mismatch".into()));
        }
        let perm: Vec<usize> = perm_bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as usize)
            .collect();
        Ok(Self {
            geometry,
            kappa,
            morph_seed,
            perm: ChannelPerm::from_vec(perm)?,
        })
    }

    /// Save to a vault file (0600 on unix).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(&self.to_bytes())?;
        #[cfg(unix)]
        {
            use std::os::unix::fs::PermissionsExt;
            std::fs::set_permissions(path, std::fs::Permissions::from_mode(0o600))?;
        }
        Ok(())
    }

    /// Load from a vault file.
    pub fn load(path: &Path) -> Result<Self> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut bytes)?;
        Self::from_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bundle() -> KeyBundle {
        KeyBundle::generate(Geometry::SMALL, 16, 1234).unwrap()
    }

    #[test]
    fn roundtrip_bytes() {
        let b = bundle();
        let parsed = KeyBundle::from_bytes(&b.to_bytes()).unwrap();
        assert_eq!(parsed.geometry, b.geometry);
        assert_eq!(parsed.kappa, b.kappa);
        assert_eq!(parsed.morph_seed, b.morph_seed);
        assert_eq!(parsed.perm, b.perm);
    }

    #[test]
    fn tamper_detected() {
        let b = bundle();
        let mut bytes = b.to_bytes();
        // flip a bit in the seed field
        bytes[8 + 5 * 8] ^= 1;
        assert!(matches!(KeyBundle::from_bytes(&bytes), Err(Error::Key(_))));
        // truncation
        assert!(KeyBundle::from_bytes(&bytes[..10]).is_err());
        // bad magic
        let mut bytes = b.to_bytes();
        bytes[0] = b'X';
        assert!(KeyBundle::from_bytes(&bytes).is_err());
    }

    #[test]
    fn fingerprint_binds_material() {
        let a = bundle();
        let b = KeyBundle::generate(Geometry::SMALL, 16, 1235).unwrap();
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.fingerprint().len(), 64);
        // same material, same fingerprint
        let a2 = KeyBundle::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(a.fingerprint(), a2.fingerprint());
    }

    #[test]
    fn save_load_file() {
        let b = bundle();
        let path = std::env::temp_dir().join("mole_vault_test.key");
        b.save(&path).unwrap();
        #[cfg(unix)]
        {
            use std::os::unix::fs::PermissionsExt;
            let mode = std::fs::metadata(&path).unwrap().permissions().mode();
            assert_eq!(mode & 0o777, 0o600);
        }
        let loaded = KeyBundle::load(&path).unwrap();
        assert_eq!(loaded.fingerprint(), b.fingerprint());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn morph_key_is_deterministic() {
        let b = bundle();
        let k1 = b.morph_key().unwrap();
        let k2 = b.morph_key().unwrap();
        assert_eq!(k1.core(), k2.core());
        assert_eq!(k1.q(), 48);
    }

    #[test]
    fn invalid_kappa_rejected() {
        assert!(KeyBundle::generate(Geometry::SMALL, 7, 1).is_err());
    }
}
