//! Key vault — secure storage of the provider's secrets (paper §3.2/§3.3:
//! "the privacy-preserving feature … relies on the secure storage of M
//! [and] the detailed channel order used for rand").
//!
//! Stored material: the morph seed + κ (the core is regenerated
//! deterministically — see [`crate::morph::MorphKey::from_seed`]), the
//! channel permutation, the geometry, the key **epoch** with its
//! rotation lineage, and a SHA-256 fingerprint binding them together.
//! The binary format is versioned and integrity-checked; the vault file
//! is chmod 0600 on unix. Keys never cross the delivery protocol — only
//! `T^r` and `C^ac` do (§4.1 HBC surface).
//!
//! ## Epochs and rotation
//!
//! A provider re-morphs its corpus under fresh key material by calling
//! [`KeyBundle::rotate`]: the rotated bundle keeps the geometry and κ,
//! draws a new morph seed + channel permutation, increments the epoch,
//! and records the parent's fingerprint. The lineage lets a serving
//! registry host epoch N and N+1 side by side during rollover and lets
//! auditors walk a vault chain back to its root (the parent
//! fingerprint is empty only at epoch 0).
//!
//! ## The admin credentials
//!
//! The vault also anchors the **admin-plane credentials**: labeled
//! HMAC-SHA256 derivations over the bundle's secret material (morph
//! seed, credential seed, permutation, epoch). They are what `mole
//! serve` checks admin-frame MACs against and what `mole keygen` /
//! `mole operator add` print for distribution. Because the derivations
//! run over the *secrets* — not the public SHA-256 fingerprint that
//! crosses the wire in `Hello` — knowing a lane's fingerprint yields
//! nothing about any credential, and rotating the vault re-derives them
//! all. Two kinds exist:
//!
//! * [`KeyBundle::admin_credential`] — the legacy shared credential
//!   (one per server, vault v3 era). Still derived identically, so
//!   pre-v4 deployments keep working.
//! * [`KeyBundle::operator_credential`] — one **independent** credential
//!   per named operator in the vault's v4 operator table
//!   ([`KeyBundle::add_operator`] / [`KeyBundle::revoke_operator`]).
//!   Each folds the operator label into the HMAC key, so no operator's
//!   credential is computable from another's, revocation is per-label,
//!   and the serving side can attribute every admin verb to the label
//!   whose credential sealed it.
//!
//! ## Signed vaults (`MOLESIG1`)
//!
//! A vault (any version) can travel inside an ed25519-signed envelope:
//! `MOLESIG1 | pubkey(32) | sig(64) | inner vault bytes`, produced by
//! [`KeyBundle::save_signed`] with a [`crate::sign::SigningKey`]. On
//! load the signature is verified **before** the inner bytes are
//! decoded, so a tampered vault is refused at load, not at first use —
//! and when the consumer pins the publisher's verifying key
//! ([`KeyBundle::load_verified`]), distribution needs no pre-shared
//! secret at all. An envelope whose embedded key is *not* pinned still
//! proves integrity (the bytes match some signer) but not origin; see
//! the README threat model.

use crate::augconv::ChannelPerm;
use crate::hash::{from_hex, hmac_sha256, to_hex, Sha256};
use crate::morph::MorphKey;
use crate::sign::{SigningKey, VerifyingKey, PUBLIC_KEY_LEN, SIGNATURE_LEN};
use crate::{Error, Geometry, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Legacy (pre-epoch) vault magic; still loadable, never written.
const MAGIC_V1: &[u8; 8] = b"MOLEKEY1";
/// Legacy epoch/lineage magic (pre-credential); still loadable, never
/// written.
const MAGIC_V2: &[u8; 8] = b"MOLEKEY2";
/// Legacy single-credential magic (pre-operator-table); still loadable,
/// never written.
const MAGIC_V3: &[u8; 8] = b"MOLEKEY3";
/// Current vault magic: adds the named operator table.
const MAGIC_V4: &[u8; 8] = b"MOLEKEY4";

/// Magic of the ed25519-signed vault envelope:
/// `MOLESIG1 | pubkey(32) | sig(64) | inner vault bytes`.
pub const SIG_MAGIC: &[u8; 8] = b"MOLESIG1";
/// Envelope header length (magic + pubkey + signature).
const SIG_HEADER_LEN: usize = 8 + PUBLIC_KEY_LEN + SIGNATURE_LEN;

/// Domain-separation label for deriving the credential seed from the
/// morph seed (legacy vaults carry no explicit seed; this keeps the
/// derivation deterministic across formats).
const CRED_SEED_LABEL: &[u8] = b"mole-admin-cred-seed-v1";
/// Domain-separation label for the (legacy shared) admin credential.
const CRED_LABEL: &[u8] = b"mole-admin-credential-v1";
/// Domain-separation label for per-operator credentials.
const OPERATOR_CRED_LABEL: &[u8] = b"mole-operator-credential-v1";

/// Longest accepted operator label (bytes). Labels name people in audit
/// lines and CLI output, not paragraphs.
pub const MAX_OPERATOR_LABEL: usize = 64;

/// The provider's secret bundle for one delivery session.
#[derive(Debug, Clone)]
pub struct KeyBundle {
    pub geometry: Geometry,
    pub kappa: usize,
    pub morph_seed: u64,
    pub perm: ChannelPerm,
    /// Rotation generation: 0 for freshly generated bundles, +1 per
    /// [`KeyBundle::rotate`].
    pub epoch: u32,
    /// Fingerprint of the bundle this one was rotated from ("" at the
    /// root epoch). Binds the rotation chain into every fingerprint.
    pub parent_fingerprint: String,
    /// Seed of the admin-credential derivation (vault v3 field). Drawn
    /// deterministically from the morph seed on generate/rotate — and
    /// re-drawn on every rotation, so a rotated vault's credential never
    /// matches its parent's.
    pub cred_seed: u64,
    /// Named operator table (vault v4 field): each label derives an
    /// independent admin credential via
    /// [`KeyBundle::operator_credential`]. Sorted lexicographically so
    /// the encoding (and thus the fingerprint) is canonical.
    pub operators: Vec<String>,
}

/// Reject labels that would garble audit lines or CLI output: empty,
/// over [`MAX_OPERATOR_LABEL`] bytes, or containing whitespace /
/// control / non-ASCII characters.
fn validate_operator_label(label: &str) -> Result<()> {
    if label.is_empty() {
        return Err(Error::Key("operator label must not be empty".into()));
    }
    if label.len() > MAX_OPERATOR_LABEL {
        return Err(Error::Key(format!(
            "operator label {:?} is {} bytes, max {MAX_OPERATOR_LABEL}",
            label,
            label.len()
        )));
    }
    if !label.bytes().all(|b| b.is_ascii_graphic()) {
        return Err(Error::Key(format!(
            "operator label {label:?} must be printable ASCII without spaces"
        )));
    }
    Ok(())
}

/// Deterministic credential seed for a given morph seed (labeled, so it
/// shares no structure with the morph material it accompanies).
fn derive_cred_seed(morph_seed: u64) -> u64 {
    let mut h = Sha256::new();
    h.update(CRED_SEED_LABEL);
    h.update(morph_seed.to_le_bytes());
    u64::from_le_bytes(h.finalize()[..8].try_into().unwrap())
}

impl KeyBundle {
    /// Generate a fresh root bundle (epoch 0, no lineage).
    pub fn generate(geometry: Geometry, kappa: usize, seed: u64) -> Result<Self> {
        // validate kappa against the geometry before accepting it
        geometry.q_for_kappa(kappa)?;
        let perm = ChannelPerm::generate(geometry.beta, seed);
        Ok(Self {
            geometry,
            kappa,
            morph_seed: seed,
            perm,
            epoch: 0,
            parent_fingerprint: String::new(),
            cred_seed: derive_cred_seed(seed),
            operators: Vec::new(),
        })
    }

    /// Rotate to the next key epoch: same geometry and κ, fresh morph
    /// seed and channel permutation, `epoch + 1`, and this bundle's
    /// fingerprint recorded as the parent. The rotated bundle morphs
    /// differently (new M and rand order), so a provider re-morphs its
    /// corpus under it while servers keep serving the old epoch until
    /// rollover completes.
    pub fn rotate(&self, new_seed: u64) -> Result<Self> {
        if new_seed == self.morph_seed {
            return Err(Error::Key(
                "rotation must use fresh seed material (got the current seed)".into(),
            ));
        }
        let epoch = self.epoch.checked_add(1).ok_or_else(|| {
            Error::Key("key epoch counter exhausted (u32::MAX rotations)".into())
        })?;
        Ok(Self {
            geometry: self.geometry,
            kappa: self.kappa,
            morph_seed: new_seed,
            perm: ChannelPerm::generate(self.geometry.beta, new_seed),
            epoch,
            parent_fingerprint: self.fingerprint(),
            cred_seed: derive_cred_seed(new_seed),
            // the roster survives rotation, but every credential it
            // derives changes with the new seed material and epoch
            operators: self.operators.clone(),
        })
    }

    /// Add a named operator to the table. The label must be fresh,
    /// non-empty printable ASCII (≤ [`MAX_OPERATOR_LABEL`] bytes); the
    /// table stays sorted so the vault encoding is canonical.
    pub fn add_operator(&mut self, label: &str) -> Result<()> {
        validate_operator_label(label)?;
        if self.operators.iter().any(|l| l == label) {
            return Err(Error::Key(format!(
                "operator {label:?} already exists in this vault"
            )));
        }
        self.operators.push(label.to_string());
        self.operators.sort();
        Ok(())
    }

    /// Remove a named operator from the table. Their credential stops
    /// deriving from this vault; a serving process reloading (or told
    /// live via `mole admin revoke-operator`) stops accepting it.
    pub fn revoke_operator(&mut self, label: &str) -> Result<()> {
        let before = self.operators.len();
        self.operators.retain(|l| l != label);
        if self.operators.len() == before {
            return Err(Error::Key(format!(
                "operator {label:?} does not exist in this vault"
            )));
        }
        Ok(())
    }

    /// Materialize the morph key (regenerates the core from the seed; the
    /// condition-number gate makes this deterministic).
    pub fn morph_key(&self) -> Result<MorphKey> {
        MorphKey::from_seed(self.geometry, self.kappa, self.morph_seed)
    }

    /// SHA-256 fingerprint over all key material including the epoch and
    /// rotation lineage (hex). Used to detect tampering and to name
    /// sessions without revealing secrets; two epochs of the same root
    /// never share a fingerprint. Public: it crosses the wire in `Hello`
    /// frames — the preimage resistance of SHA-256 is what keeps the
    /// secrets (and the admin credential derived from them) unreachable
    /// from it.
    ///
    /// Fingerprints are **format-versioned**: they hash the current
    /// magic + body, so a vault-format bump (v2 → v3 added the
    /// credential seed, v3 → v4 the operator table) renames every
    /// bundle — a `parent_fingerprint` recorded by an older release
    /// will not equal the parent's post-upgrade `fingerprint()`.
    /// Runtime routing never depends on this (lanes resolve by
    /// `(model, epoch)`); audit walks across a format boundary must
    /// recompute under the recording release. Editing the operator
    /// table also renames the vault — deliberate, so an audit trail
    /// records roster changes as material changes.
    pub fn fingerprint(&self) -> String {
        let mut h = Sha256::new();
        h.update(MAGIC_V4);
        h.update(self.encode_body());
        to_hex(&h.finalize())
    }

    /// The vault-derived **shared** admin-plane credential: a labeled
    /// HMAC-SHA256 over the bundle's secret material (morph seed,
    /// credential seed, permutation, epoch). This is the legacy
    /// one-per-server secret between `mole keygen`/`mole admin` and a
    /// credential-gated `mole serve`; rotation re-derives it, so an old
    /// epoch's credential dies with the rollover. Deliberately computed
    /// over [`KeyBundle::encode_secret_core`] (the v3-era byte layout),
    /// so editing the v4 operator table does **not** shift the shared
    /// credential and an upgraded vault authenticates exactly like its
    /// v3 ancestor.
    pub fn admin_credential(&self) -> [u8; 32] {
        hmac_sha256(&self.encode_secret_core(), CRED_LABEL)
    }

    /// The independent credential for one named operator: HMAC-SHA256
    /// keyed by `cred_seed ‖ epoch ‖ label` over the operator-credential
    /// domain label. Folding the label into the *key* (not the message)
    /// means no operator can derive a colleague's credential from their
    /// own, and folding the epoch means every credential dies with a
    /// rotation just like the shared one. Pure derivation: callable for
    /// labels not (or no longer) in the table — the serving side
    /// enforces roster membership, not this function.
    pub fn operator_credential(&self, label: &str) -> [u8; 32] {
        let mut key = Vec::with_capacity(16 + label.len());
        key.extend_from_slice(&self.cred_seed.to_le_bytes());
        key.extend_from_slice(&(self.epoch as u64).to_le_bytes());
        key.extend_from_slice(label.as_bytes());
        hmac_sha256(&key, OPERATOR_CRED_LABEL)
    }

    /// The full roster with derived credentials — what a serving
    /// process installs as its live operator table.
    pub fn operator_credentials(&self) -> Vec<(String, [u8; 32])> {
        self.operators
            .iter()
            .map(|l| (l.clone(), self.operator_credential(l)))
            .collect()
    }

    /// Hex form of [`KeyBundle::admin_credential`] — the distribution
    /// format (`mole keygen` output, `[serving] admin_credential_file`).
    pub fn admin_credential_hex(&self) -> String {
        to_hex(&self.admin_credential())
    }

    /// The v3-era byte layout: fixed fields, lineage, permutation — the
    /// **secret core** without the operator table. This is the HMAC
    /// input for [`KeyBundle::admin_credential`], frozen so upgrading a
    /// vault to v4 (or editing its roster) never shifts the shared
    /// credential installed on existing servers.
    fn encode_secret_core(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for v in [
            self.geometry.alpha as u64,
            self.geometry.m as u64,
            self.geometry.beta as u64,
            self.geometry.p as u64,
            self.kappa as u64,
            self.morph_seed,
            self.epoch as u64,
            self.cred_seed,
            self.perm.beta() as u64,
        ] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&(self.parent_fingerprint.len() as u32).to_le_bytes());
        out.extend_from_slice(self.parent_fingerprint.as_bytes());
        for &p in self.perm.as_slice() {
            out.extend_from_slice(&(p as u32).to_le_bytes());
        }
        out
    }

    /// Full v4 body: the secret core followed by the operator table
    /// (u32 count, then u32 length + UTF-8 label per operator).
    fn encode_body(&self) -> Vec<u8> {
        let mut out = self.encode_secret_core();
        out.extend_from_slice(&(self.operators.len() as u32).to_le_bytes());
        for label in &self.operators {
            out.extend_from_slice(&(label.len() as u32).to_le_bytes());
            out.extend_from_slice(label.as_bytes());
        }
        out
    }

    /// Serialize to the versioned vault format: MAGIC | body | SHA-256.
    pub fn to_bytes(&self) -> Vec<u8> {
        let body = self.encode_body();
        let mut out = Vec::with_capacity(8 + body.len() + 32);
        out.extend_from_slice(MAGIC_V4);
        out.extend_from_slice(&body);
        let mut h = Sha256::new();
        h.update(MAGIC_V4);
        h.update(&body);
        out.extend_from_slice(&h.finalize());
        out
    }

    /// Serialize inside the `MOLESIG1` envelope: the full vault bytes
    /// signed by `signer`, with the verifying key embedded so any
    /// reader can check integrity (pin the key to also get origin).
    pub fn signed_bytes(&self, signer: &SigningKey) -> Vec<u8> {
        let inner = self.to_bytes();
        let sig = signer.sign(&inner);
        let mut out = Vec::with_capacity(SIG_HEADER_LEN + inner.len());
        out.extend_from_slice(SIG_MAGIC);
        out.extend_from_slice(signer.verifying_key().as_bytes());
        out.extend_from_slice(&sig);
        out.extend_from_slice(&inner);
        out
    }

    /// Deserialize + integrity-check. Reads the current `MOLEKEY4`
    /// format plus the legacy `MOLEKEY3` (no operator table),
    /// `MOLEKEY2` (no credential seed; re-derived from the morph seed)
    /// and `MOLEKEY1` layouts (which additionally map to epoch 0 with
    /// no lineage) — and any of those wrapped in a `MOLESIG1` signed
    /// envelope, whose signature is verified (against the embedded key)
    /// before the inner vault is decoded.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        Ok(Self::from_bytes_verified(bytes, None)?.0)
    }

    /// Like [`KeyBundle::from_bytes`], but returns the envelope's
    /// verifying key (when signed) and enforces an optional pin:
    /// with `expect` set, an unsigned vault or one signed by any other
    /// key is refused — at load, before any field is decoded.
    pub fn from_bytes_verified(
        bytes: &[u8],
        expect: Option<&VerifyingKey>,
    ) -> Result<(Self, Option<VerifyingKey>)> {
        if bytes.len() >= 8 && &bytes[..8] == SIG_MAGIC {
            if bytes.len() < SIG_HEADER_LEN + 8 + 32 {
                return Err(Error::Key("signed vault envelope truncated".into()));
            }
            let pubkey: [u8; PUBLIC_KEY_LEN] = bytes[8..8 + PUBLIC_KEY_LEN].try_into().unwrap();
            let sig: [u8; SIGNATURE_LEN] =
                bytes[8 + PUBLIC_KEY_LEN..SIG_HEADER_LEN].try_into().unwrap();
            let inner = &bytes[SIG_HEADER_LEN..];
            let signer = VerifyingKey(pubkey);
            signer.verify(inner, &sig).map_err(|_| {
                Error::Key(
                    "vault signature verification failed (tampered or re-signed envelope)"
                        .into(),
                )
            })?;
            if let Some(want) = expect {
                if want != &signer {
                    return Err(Error::Key(format!(
                        "vault signed by {}, expected signer {}",
                        signer.to_hex(),
                        want.to_hex()
                    )));
                }
            }
            return Ok((Self::from_unsigned_bytes(inner)?, Some(signer)));
        }
        if expect.is_some() {
            return Err(Error::Key(
                "vault is unsigned but a signer pin is configured".into(),
            ));
        }
        Ok((Self::from_unsigned_bytes(bytes)?, None))
    }

    fn from_unsigned_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 8 + 32 {
            return Err(Error::Key("bad vault magic or truncated file".into()));
        }
        let version = match &bytes[..8] {
            m if m == MAGIC_V4 => 4,
            m if m == MAGIC_V3 => 3,
            m if m == MAGIC_V2 => 2,
            m if m == MAGIC_V1 => 1,
            _ => return Err(Error::Key("bad vault magic or truncated file".into())),
        };
        let (payload, digest) = bytes.split_at(bytes.len() - 32);
        let mut h = Sha256::new();
        h.update(payload);
        if h.finalize().as_slice() != digest {
            return Err(Error::Key("vault integrity check failed".into()));
        }
        let body = &payload[8..];
        match version {
            4 => Self::decode_body_v4(body),
            3 => Self::decode_body_v3(body),
            2 => Self::decode_body_v2(body),
            _ => Self::decode_body_v1(body),
        }
    }

    fn decode_body_v4(body: &[u8]) -> Result<Self> {
        let fixed = 9 * 8;
        if body.len() < fixed + 4 {
            return Err(Error::Key("vault body truncated".into()));
        }
        let u = |i: usize| -> u64 {
            u64::from_le_bytes(body[i * 8..(i + 1) * 8].try_into().unwrap())
        };
        let geometry = Geometry::new(u(0) as usize, u(1) as usize, u(2) as usize, u(3) as usize);
        let kappa = u(4) as usize;
        let morph_seed = u(5);
        let epoch = u(6) as u32;
        let cred_seed = u(7);
        let beta = u(8) as usize;
        let (parent_fingerprint, rest) = Self::decode_lineage(&body[fixed..])?;
        let perm_len = beta
            .checked_mul(4)
            .ok_or_else(|| Error::Key("vault permutation length overflows".into()))?;
        if rest.len() < perm_len.saturating_add(4) {
            return Err(Error::Key("vault body truncated".into()));
        }
        let perm = Self::decode_perm(&rest[..perm_len], beta)?;
        let mut ops = &rest[perm_len..];
        let n_ops = u32::from_le_bytes(ops[..4].try_into().unwrap()) as usize;
        ops = &ops[4..];
        let mut operators = Vec::new();
        for _ in 0..n_ops {
            if ops.len() < 4 {
                return Err(Error::Key("vault operator table truncated".into()));
            }
            let len = u32::from_le_bytes(ops[..4].try_into().unwrap()) as usize;
            let end = 4usize
                .checked_add(len)
                .ok_or_else(|| Error::Key("vault operator label length overflows".into()))?;
            if ops.len() < end {
                return Err(Error::Key("vault operator table truncated".into()));
            }
            let label = String::from_utf8(ops[4..end].to_vec())
                .map_err(|_| Error::Key("vault operator label is not utf-8".into()))?;
            operators.push(label);
            ops = &ops[end..];
        }
        if !ops.is_empty() {
            return Err(Error::Key(
                "vault has trailing bytes after the operator table".into(),
            ));
        }
        Ok(Self {
            geometry,
            kappa,
            morph_seed,
            perm,
            epoch,
            parent_fingerprint,
            cred_seed,
            operators,
        })
    }

    fn decode_body_v3(body: &[u8]) -> Result<Self> {
        let fixed = 9 * 8;
        if body.len() < fixed + 4 {
            return Err(Error::Key("vault body truncated".into()));
        }
        let u = |i: usize| -> u64 {
            u64::from_le_bytes(body[i * 8..(i + 1) * 8].try_into().unwrap())
        };
        let geometry = Geometry::new(u(0) as usize, u(1) as usize, u(2) as usize, u(3) as usize);
        let kappa = u(4) as usize;
        let morph_seed = u(5);
        let epoch = u(6) as u32;
        let cred_seed = u(7);
        let beta = u(8) as usize;
        let (parent_fingerprint, rest) = Self::decode_lineage(&body[fixed..])?;
        let perm = Self::decode_perm(rest, beta)?;
        Ok(Self {
            geometry,
            kappa,
            morph_seed,
            perm,
            epoch,
            parent_fingerprint,
            cred_seed,
            operators: Vec::new(),
        })
    }

    fn decode_body_v2(body: &[u8]) -> Result<Self> {
        let fixed = 8 * 8;
        if body.len() < fixed + 4 {
            return Err(Error::Key("vault body truncated".into()));
        }
        let u = |i: usize| -> u64 {
            u64::from_le_bytes(body[i * 8..(i + 1) * 8].try_into().unwrap())
        };
        let geometry = Geometry::new(u(0) as usize, u(1) as usize, u(2) as usize, u(3) as usize);
        let kappa = u(4) as usize;
        let morph_seed = u(5);
        let epoch = u(6) as u32;
        let beta = u(7) as usize;
        let (parent_fingerprint, rest) = Self::decode_lineage(&body[fixed..])?;
        let perm = Self::decode_perm(rest, beta)?;
        Ok(Self {
            geometry,
            kappa,
            morph_seed,
            perm,
            epoch,
            parent_fingerprint,
            cred_seed: derive_cred_seed(morph_seed),
            operators: Vec::new(),
        })
    }

    fn decode_body_v1(body: &[u8]) -> Result<Self> {
        let fixed = 7 * 8;
        if body.len() < fixed {
            return Err(Error::Key("vault body truncated".into()));
        }
        let u = |i: usize| -> u64 {
            u64::from_le_bytes(body[i * 8..(i + 1) * 8].try_into().unwrap())
        };
        let geometry = Geometry::new(u(0) as usize, u(1) as usize, u(2) as usize, u(3) as usize);
        let morph_seed = u(5);
        let perm = Self::decode_perm(&body[fixed..], u(6) as usize)?;
        Ok(Self {
            geometry,
            kappa: u(4) as usize,
            morph_seed,
            perm,
            epoch: 0,
            parent_fingerprint: String::new(),
            cred_seed: derive_cred_seed(morph_seed),
            operators: Vec::new(),
        })
    }

    /// Shared v2/v3/v4 lineage decode: u32 length + UTF-8 fingerprint,
    /// returning the remaining bytes.
    fn decode_lineage(bytes: &[u8]) -> Result<(String, &[u8])> {
        if bytes.len() < 4 {
            return Err(Error::Key("vault lineage field truncated".into()));
        }
        let fp_len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
        let fp_end = 4usize
            .checked_add(fp_len)
            .ok_or_else(|| Error::Key("vault lineage length overflows".into()))?;
        if bytes.len() < fp_end {
            return Err(Error::Key("vault lineage field truncated".into()));
        }
        let fp = String::from_utf8(bytes[4..fp_end].to_vec())
            .map_err(|_| Error::Key("vault lineage field is not utf-8".into()))?;
        Ok((fp, &bytes[fp_end..]))
    }

    fn decode_perm(perm_bytes: &[u8], beta: usize) -> Result<ChannelPerm> {
        if perm_bytes.len() != beta.checked_mul(4).unwrap_or(usize::MAX) {
            return Err(Error::Key("vault permutation length mismatch".into()));
        }
        ChannelPerm::from_vec(
            perm_bytes
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as usize)
                .collect(),
        )
    }

    /// Save to a vault file (0600 on unix, applied at create so the
    /// secrets never sit behind a umask-default mode).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = create_secret_file(path)?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    /// Save inside the `MOLESIG1` signed envelope (same 0600-at-create
    /// discipline — the envelope still wraps secret material).
    pub fn save_signed(&self, path: &Path, signer: &SigningKey) -> Result<()> {
        let mut f = create_secret_file(path)?;
        f.write_all(&self.signed_bytes(signer))?;
        Ok(())
    }

    /// Load from a vault file.
    pub fn load(path: &Path) -> Result<Self> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut bytes)?;
        Self::from_bytes(&bytes)
    }

    /// Load with signature pinning (see
    /// [`KeyBundle::from_bytes_verified`]); the returned key is the
    /// envelope's signer when the file was signed.
    pub fn load_verified(
        path: &Path,
        expect: Option<&VerifyingKey>,
    ) -> Result<(Self, Option<VerifyingKey>)> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut bytes)?;
        Self::from_bytes_verified(&bytes, expect)
    }
}

/// Rotate a vault **file** to the next key epoch: load, rotate (fresh
/// seed — `morph_seed + 1` when `new_seed` is `None` — and permutation,
/// lineage recorded), save to `out`. Returns `(old, rotated)` so
/// callers can report the epoch/fingerprint transition.
///
/// This is the offline half of the live rollover: the rotated vault is
/// what `mole admin register --vault` hands to a running server, which
/// loads it from its own filesystem and starts the new epoch's lane
/// next to the old one.
pub fn rotate_file(
    vault: &Path,
    new_seed: Option<u64>,
    out: &Path,
) -> Result<(KeyBundle, KeyBundle)> {
    let keys = KeyBundle::load(vault)?;
    let seed = new_seed.unwrap_or_else(|| keys.morph_seed.wrapping_add(1));
    let rotated = keys.rotate(seed)?;
    rotated.save(out)?;
    Ok((keys, rotated))
}

/// Create a secret-holding file with 0600 applied **at create time**
/// (unix): creating with the umask default and chmod'ing afterwards
/// would leave a window where another local user can open the file and
/// keep the fd — exactly the multi-user-host scenario the admin
/// credential exists for.
pub(crate) fn create_secret_file(path: &Path) -> Result<std::fs::File> {
    let mut opts = std::fs::OpenOptions::new();
    opts.write(true).create(true).truncate(true);
    #[cfg(unix)]
    {
        use std::os::unix::fs::OpenOptionsExt;
        opts.mode(0o600);
    }
    let f = opts.open(path)?;
    // mode() only applies to newly created files; re-assert on rewrite
    #[cfg(unix)]
    {
        use std::os::unix::fs::PermissionsExt;
        f.set_permissions(std::fs::Permissions::from_mode(0o600))?;
    }
    Ok(f)
}

/// Write an admin credential to a file (lowercase hex + newline, 0600
/// on unix from the moment it exists) — the distribution format
/// `mole keygen --credential-out` produces and `[serving]
/// admin_credential_file` / `mole admin --credential` consume.
pub fn save_credential_file(cred: &[u8; 32], path: &Path) -> Result<()> {
    let mut f = create_secret_file(path)?;
    f.write_all(to_hex(cred).as_bytes())?;
    f.write_all(b"\n")?;
    Ok(())
}

/// Load an admin credential file (64 hex chars, surrounding whitespace
/// tolerated).
pub fn load_credential_file(path: &Path) -> Result<[u8; 32]> {
    let text = std::fs::read_to_string(path)?;
    let cred = from_hex(text.trim()).ok_or_else(|| {
        Error::Key(format!("credential file {path:?} is not hex"))
    })?;
    cred.as_slice().try_into().map_err(|_| {
        Error::Key(format!(
            "credential file {path:?} holds {} bytes, expected 32",
            cred.len()
        ))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bundle() -> KeyBundle {
        KeyBundle::generate(Geometry::SMALL, 16, 1234).unwrap()
    }

    /// Hand-encode the legacy MOLEKEY1 layout for back-compat coverage.
    fn v1_bytes(b: &KeyBundle) -> Vec<u8> {
        let mut body = Vec::new();
        for v in [
            b.geometry.alpha as u64,
            b.geometry.m as u64,
            b.geometry.beta as u64,
            b.geometry.p as u64,
            b.kappa as u64,
            b.morph_seed,
            b.perm.beta() as u64,
        ] {
            body.extend_from_slice(&v.to_le_bytes());
        }
        for &p in b.perm.as_slice() {
            body.extend_from_slice(&(p as u32).to_le_bytes());
        }
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC_V1);
        out.extend_from_slice(&body);
        let mut h = Sha256::new();
        h.update(MAGIC_V1);
        h.update(&body);
        out.extend_from_slice(&h.finalize());
        out
    }

    #[test]
    fn roundtrip_bytes() {
        let b = bundle();
        let parsed = KeyBundle::from_bytes(&b.to_bytes()).unwrap();
        assert_eq!(parsed.geometry, b.geometry);
        assert_eq!(parsed.kappa, b.kappa);
        assert_eq!(parsed.morph_seed, b.morph_seed);
        assert_eq!(parsed.perm, b.perm);
        assert_eq!(parsed.epoch, 0);
        assert_eq!(parsed.parent_fingerprint, "");
    }

    #[test]
    fn legacy_v1_vault_still_loads() {
        let b = bundle();
        let loaded = KeyBundle::from_bytes(&v1_bytes(&b)).unwrap();
        assert_eq!(loaded.geometry, b.geometry);
        assert_eq!(loaded.kappa, b.kappa);
        assert_eq!(loaded.morph_seed, b.morph_seed);
        assert_eq!(loaded.perm, b.perm);
        assert_eq!(loaded.epoch, 0);
        assert_eq!(loaded.parent_fingerprint, "");
        // re-saving upgrades to the current format without changing the
        // material (fingerprints agree because epoch 0 + empty lineage +
        // the same derived credential seed)
        assert_eq!(loaded.fingerprint(), b.fingerprint());
        assert_eq!(loaded.admin_credential(), b.admin_credential());
        assert_eq!(&loaded.to_bytes()[..8], MAGIC_V4);
        assert!(loaded.operators.is_empty());
        // tampered legacy bytes are still caught
        let mut bad = v1_bytes(&b);
        bad[8 + 5 * 8] ^= 1;
        assert!(matches!(KeyBundle::from_bytes(&bad), Err(Error::Key(_))));
    }

    /// Hand-encode the legacy MOLEKEY2 layout (no credential seed) for
    /// back-compat coverage.
    fn v2_bytes(b: &KeyBundle) -> Vec<u8> {
        let mut body = Vec::new();
        for v in [
            b.geometry.alpha as u64,
            b.geometry.m as u64,
            b.geometry.beta as u64,
            b.geometry.p as u64,
            b.kappa as u64,
            b.morph_seed,
            b.epoch as u64,
            b.perm.beta() as u64,
        ] {
            body.extend_from_slice(&v.to_le_bytes());
        }
        body.extend_from_slice(&(b.parent_fingerprint.len() as u32).to_le_bytes());
        body.extend_from_slice(b.parent_fingerprint.as_bytes());
        for &p in b.perm.as_slice() {
            body.extend_from_slice(&(p as u32).to_le_bytes());
        }
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC_V2);
        out.extend_from_slice(&body);
        let mut h = Sha256::new();
        h.update(MAGIC_V2);
        h.update(&body);
        out.extend_from_slice(&h.finalize());
        out
    }

    #[test]
    fn legacy_v2_vault_still_loads() {
        let root = bundle();
        let b = root.rotate(4242).unwrap();
        let loaded = KeyBundle::from_bytes(&v2_bytes(&b)).unwrap();
        assert_eq!(loaded.morph_seed, b.morph_seed);
        assert_eq!(loaded.epoch, 1);
        assert_eq!(loaded.parent_fingerprint, b.parent_fingerprint);
        assert_eq!(loaded.perm, b.perm);
        // the credential seed is re-derived from the morph seed, so the
        // upgraded bundle is byte-identical to a natively-v3 rotation
        assert_eq!(loaded.cred_seed, b.cred_seed);
        assert_eq!(loaded.fingerprint(), b.fingerprint());
        assert_eq!(loaded.admin_credential(), b.admin_credential());
        // tampered v2 bytes are still caught
        let mut bad = v2_bytes(&b);
        bad[8 + 5 * 8] ^= 1;
        assert!(matches!(KeyBundle::from_bytes(&bad), Err(Error::Key(_))));
    }

    /// Hand-encode the legacy MOLEKEY3 layout (no operator table) for
    /// back-compat coverage — what every pre-v4 release wrote.
    fn v3_bytes(b: &KeyBundle) -> Vec<u8> {
        let mut body = Vec::new();
        for v in [
            b.geometry.alpha as u64,
            b.geometry.m as u64,
            b.geometry.beta as u64,
            b.geometry.p as u64,
            b.kappa as u64,
            b.morph_seed,
            b.epoch as u64,
            b.cred_seed,
            b.perm.beta() as u64,
        ] {
            body.extend_from_slice(&v.to_le_bytes());
        }
        body.extend_from_slice(&(b.parent_fingerprint.len() as u32).to_le_bytes());
        body.extend_from_slice(b.parent_fingerprint.as_bytes());
        for &p in b.perm.as_slice() {
            body.extend_from_slice(&(p as u32).to_le_bytes());
        }
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC_V3);
        out.extend_from_slice(&body);
        let mut h = Sha256::new();
        h.update(MAGIC_V3);
        h.update(&body);
        out.extend_from_slice(&h.finalize());
        out
    }

    #[test]
    fn legacy_v3_vault_still_loads() {
        let b = bundle().rotate(4242).unwrap();
        let loaded = KeyBundle::from_bytes(&v3_bytes(&b)).unwrap();
        assert_eq!(loaded.morph_seed, b.morph_seed);
        assert_eq!(loaded.epoch, 1);
        assert_eq!(loaded.cred_seed, b.cred_seed);
        assert_eq!(loaded.parent_fingerprint, b.parent_fingerprint);
        assert_eq!(loaded.perm, b.perm);
        assert!(loaded.operators.is_empty());
        // the upgrade path: the shared credential is frozen on the v3
        // byte layout, so a v3 vault authenticates unchanged after
        // re-saving as v4
        assert_eq!(loaded.admin_credential(), b.admin_credential());
        assert_eq!(&loaded.to_bytes()[..8], MAGIC_V4);
        assert_eq!(
            KeyBundle::from_bytes(&loaded.to_bytes())
                .unwrap()
                .admin_credential(),
            b.admin_credential()
        );
        // tampered v3 bytes are still caught
        let mut bad = v3_bytes(&b);
        bad[8 + 7 * 8] ^= 1;
        assert!(matches!(KeyBundle::from_bytes(&bad), Err(Error::Key(_))));
    }

    #[test]
    fn operator_table_roundtrips_and_derives_independent_credentials() {
        let mut b = bundle();
        b.add_operator("ada").unwrap();
        b.add_operator("grace").unwrap();
        // duplicate, empty, oversized, and unprintable labels die typed
        assert!(matches!(b.add_operator("ada"), Err(Error::Key(_))));
        assert!(matches!(b.add_operator(""), Err(Error::Key(_))));
        assert!(matches!(b.add_operator(&"x".repeat(65)), Err(Error::Key(_))));
        assert!(matches!(b.add_operator("two words"), Err(Error::Key(_))));
        // roundtrip preserves the (sorted) roster
        let parsed = KeyBundle::from_bytes(&b.to_bytes()).unwrap();
        assert_eq!(parsed.operators, vec!["ada".to_string(), "grace".to_string()]);
        // credentials: deterministic, pairwise distinct, distinct from
        // the shared credential, and epoch-bound
        assert_eq!(parsed.operator_credential("ada"), b.operator_credential("ada"));
        assert_ne!(b.operator_credential("ada"), b.operator_credential("grace"));
        assert_ne!(b.operator_credential("ada"), b.admin_credential());
        let rotated = b.rotate(777).unwrap();
        assert_eq!(rotated.operators, b.operators, "roster survives rotation");
        assert_ne!(
            rotated.operator_credential("ada"),
            b.operator_credential("ada"),
            "credentials die with the epoch"
        );
        // the shared credential ignores roster edits (frozen v3 core)…
        let before = b.admin_credential();
        b.revoke_operator("grace").unwrap();
        assert_eq!(b.admin_credential(), before);
        assert_eq!(b.operators, vec!["ada".to_string()]);
        assert!(matches!(b.revoke_operator("grace"), Err(Error::Key(_))));
        // …but the fingerprint records roster changes as material changes
        assert_ne!(
            KeyBundle::from_bytes(&b.to_bytes()).unwrap().fingerprint(),
            parsed.fingerprint()
        );
        // hostile operator-table bytes: truncated table dies typed
        let mut bytes = b.to_bytes();
        let cut = bytes.len() - 32 - 2;
        bytes.truncate(cut);
        assert!(KeyBundle::from_bytes(&bytes).is_err());
    }

    #[test]
    fn signed_vault_envelope_verifies_before_decode() {
        let signer = crate::sign::SigningKey::from_seed([42u8; 32]);
        let mut b = bundle();
        b.add_operator("ada").unwrap();
        let signed = b.signed_bytes(&signer);
        assert_eq!(&signed[..8], SIG_MAGIC);
        // verified load recovers the bundle and the signer
        let (loaded, key) = KeyBundle::from_bytes_verified(&signed, None).unwrap();
        assert_eq!(loaded.fingerprint(), b.fingerprint());
        assert_eq!(key, Some(signer.verifying_key()));
        // pinning the right signer passes, the wrong one is refused
        KeyBundle::from_bytes_verified(&signed, Some(&signer.verifying_key())).unwrap();
        let other = crate::sign::SigningKey::from_seed([43u8; 32]);
        let err = KeyBundle::from_bytes_verified(&signed, Some(&other.verifying_key()))
            .unwrap_err();
        assert!(err.to_string().contains("expected signer"), "{err}");
        // an unsigned vault under a pin is refused
        let err =
            KeyBundle::from_bytes_verified(&b.to_bytes(), Some(&signer.verifying_key()))
                .unwrap_err();
        assert!(err.to_string().contains("unsigned"), "{err}");
        // tampering anywhere — inner payload, signature, embedded key —
        // is refused at load with the signature error, before decode
        for offset in [8, 8 + 32, SIG_HEADER_LEN + 8 + 5 * 8, signed.len() - 1] {
            let mut bad = signed.clone();
            bad[offset] ^= 1;
            let err = KeyBundle::from_bytes(&bad).unwrap_err();
            assert!(
                err.to_string().contains("signature verification failed"),
                "offset {offset}: {err}"
            );
        }
        // a re-signed envelope (attacker swaps in their own key + sig)
        // still *loads* unpinned — integrity, not origin — but dies
        // against a pinned signer; this is exactly what the README
        // threat model promises
        let resigned = {
            let mut out = Vec::new();
            out.extend_from_slice(SIG_MAGIC);
            out.extend_from_slice(other.verifying_key().as_bytes());
            out.extend_from_slice(&other.sign(&b.to_bytes()));
            out.extend_from_slice(&b.to_bytes());
            out
        };
        assert!(KeyBundle::from_bytes(&resigned).is_ok());
        assert!(
            KeyBundle::from_bytes_verified(&resigned, Some(&signer.verifying_key())).is_err()
        );
        // truncated envelope dies typed, not by panic
        assert!(KeyBundle::from_bytes(&signed[..20]).is_err());
        // file roundtrip with 0600
        let path = std::env::temp_dir().join("mole_signed_vault_test.key");
        b.save_signed(&path, &signer).unwrap();
        #[cfg(unix)]
        {
            use std::os::unix::fs::PermissionsExt;
            let mode = std::fs::metadata(&path).unwrap().permissions().mode();
            assert_eq!(mode & 0o777, 0o600);
        }
        let (loaded, _) = KeyBundle::load_verified(&path, Some(&signer.verifying_key())).unwrap();
        assert_eq!(loaded.fingerprint(), b.fingerprint());
        assert_eq!(KeyBundle::load(&path).unwrap().fingerprint(), b.fingerprint());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn admin_credential_derivation() {
        let a = bundle();
        // deterministic, 32 bytes, hex form matches
        assert_eq!(a.admin_credential(), bundle().admin_credential());
        assert_eq!(a.admin_credential_hex().len(), 64);
        assert_eq!(
            a.admin_credential_hex(),
            to_hex(&a.admin_credential())
        );
        // distinct key material ⇒ distinct credential
        let b = KeyBundle::generate(Geometry::SMALL, 16, 1235).unwrap();
        assert_ne!(a.admin_credential(), b.admin_credential());
        // rotation re-derives the credential along with everything else
        let r = a.rotate(5678).unwrap();
        assert_ne!(r.admin_credential(), a.admin_credential());
        assert_ne!(r.cred_seed, a.cred_seed);
        // the credential is not the (public) fingerprint, nor derivable
        // by hashing it the obvious way
        assert_ne!(a.admin_credential_hex(), a.fingerprint());
        let fp_hash = crate::hash::sha256(a.fingerprint().as_bytes());
        assert_ne!(a.admin_credential(), fp_hash);
    }

    #[test]
    fn credential_file_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join("mole_cred_file_test.cred");
        let cred = bundle().admin_credential();
        save_credential_file(&cred, &path).unwrap();
        #[cfg(unix)]
        {
            use std::os::unix::fs::PermissionsExt;
            let mode = std::fs::metadata(&path).unwrap().permissions().mode();
            assert_eq!(mode & 0o777, 0o600);
        }
        assert_eq!(load_credential_file(&path).unwrap(), cred);
        // whitespace tolerated, garbage rejected typed
        std::fs::write(&path, format!("  {}\n\n", to_hex(&cred))).unwrap();
        assert_eq!(load_credential_file(&path).unwrap(), cred);
        std::fs::write(&path, "not-hex-at-all").unwrap();
        assert!(matches!(load_credential_file(&path), Err(Error::Key(_))));
        std::fs::write(&path, "abcd").unwrap(); // hex, wrong length
        let err = load_credential_file(&path).unwrap_err();
        assert!(err.to_string().contains("expected 32"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rotation_advances_epoch_and_lineage() {
        let root = bundle();
        let r1 = root.rotate(5678).unwrap();
        assert_eq!(r1.epoch, 1);
        assert_eq!(r1.parent_fingerprint, root.fingerprint());
        assert_eq!(r1.geometry, root.geometry);
        assert_eq!(r1.kappa, root.kappa);
        assert_ne!(r1.morph_seed, root.morph_seed);
        assert_ne!(r1.fingerprint(), root.fingerprint());
        // rotation actually changes the morph: same rows, different T^r
        let mut rng = crate::rng::Rng::new(9);
        let rows = crate::tensor::Tensor::new(&[2, 768], rng.normal_vec(2 * 768, 1.0)).unwrap();
        let t0 = root.morph_key().unwrap().morph(&rows).unwrap();
        let t1 = r1.morph_key().unwrap().morph(&rows).unwrap();
        assert!(t0.rms_diff(&t1).unwrap() > 0.1, "rotation left the morph unchanged");
        // chain: epoch 2 points at epoch 1, not the root
        let r2 = r1.rotate(9999).unwrap();
        assert_eq!(r2.epoch, 2);
        assert_eq!(r2.parent_fingerprint, r1.fingerprint());
        assert_ne!(r2.parent_fingerprint, root.fingerprint());
        // reusing the current seed is rejected
        assert!(matches!(r1.rotate(r1.morph_seed), Err(Error::Key(_))));
    }

    #[test]
    fn rotated_bundle_roundtrips_with_lineage() {
        let root = bundle();
        let r1 = root.rotate(31337).unwrap();
        let parsed = KeyBundle::from_bytes(&r1.to_bytes()).unwrap();
        assert_eq!(parsed.epoch, 1);
        assert_eq!(parsed.parent_fingerprint, root.fingerprint());
        assert_eq!(parsed.fingerprint(), r1.fingerprint());
        assert_eq!(parsed.morph_seed, r1.morph_seed);
        assert_eq!(parsed.perm, r1.perm);
    }

    #[test]
    fn tamper_detected() {
        let b = bundle().rotate(77).unwrap();
        let mut bytes = b.to_bytes();
        // flip a bit in the seed field
        bytes[8 + 5 * 8] ^= 1;
        assert!(matches!(KeyBundle::from_bytes(&bytes), Err(Error::Key(_))));
        // flip a bit in the epoch field: lineage is integrity-protected too
        let mut bytes = b.to_bytes();
        bytes[8 + 6 * 8] ^= 1;
        assert!(matches!(KeyBundle::from_bytes(&bytes), Err(Error::Key(_))));
        // flip a bit in the credential seed: the v3 field is
        // integrity-protected too
        let mut bytes = b.to_bytes();
        bytes[8 + 7 * 8] ^= 1;
        assert!(matches!(KeyBundle::from_bytes(&bytes), Err(Error::Key(_))));
        // flip a bit inside the parent fingerprint
        let mut bytes = b.to_bytes();
        bytes[8 + 9 * 8 + 4] ^= 1;
        assert!(matches!(KeyBundle::from_bytes(&bytes), Err(Error::Key(_))));
        // truncation
        assert!(KeyBundle::from_bytes(&b.to_bytes()[..10]).is_err());
        // bad magic
        let mut bytes = b.to_bytes();
        bytes[0] = b'X';
        assert!(KeyBundle::from_bytes(&bytes).is_err());
    }

    #[test]
    fn fingerprint_binds_material() {
        let a = bundle();
        let b = KeyBundle::generate(Geometry::SMALL, 16, 1235).unwrap();
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.fingerprint().len(), 64);
        // same material, same fingerprint
        let a2 = KeyBundle::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(a.fingerprint(), a2.fingerprint());
        // epoch participates in the fingerprint: identical seed/perm at a
        // different epoch must not collide
        let mut forged = a.clone();
        forged.epoch = 1;
        assert_ne!(forged.fingerprint(), a.fingerprint());
    }

    #[test]
    fn save_load_file() {
        let b = bundle().rotate(4321).unwrap();
        let path = std::env::temp_dir().join("mole_vault_test.key");
        b.save(&path).unwrap();
        #[cfg(unix)]
        {
            use std::os::unix::fs::PermissionsExt;
            let mode = std::fs::metadata(&path).unwrap().permissions().mode();
            assert_eq!(mode & 0o777, 0o600);
        }
        let loaded = KeyBundle::load(&path).unwrap();
        assert_eq!(loaded.fingerprint(), b.fingerprint());
        assert_eq!(loaded.epoch, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rotate_file_advances_the_vault() {
        let dir = std::env::temp_dir();
        let v0 = dir.join("mole_rotate_file_v0.key");
        let v1 = dir.join("mole_rotate_file_v1.key");
        bundle().save(&v0).unwrap();
        let (old, rotated) = rotate_file(&v0, None, &v1).unwrap();
        assert_eq!(old.epoch, 0);
        assert_eq!(rotated.epoch, 1);
        assert_eq!(rotated.morph_seed, old.morph_seed + 1);
        assert_eq!(rotated.parent_fingerprint, old.fingerprint());
        // the written vault round-trips to the rotated bundle
        let loaded = KeyBundle::load(&v1).unwrap();
        assert_eq!(loaded.fingerprint(), rotated.fingerprint());
        // the source vault is untouched (rotate-out, not in-place)
        assert_eq!(KeyBundle::load(&v0).unwrap().epoch, 0);
        // explicit seed wins; reusing the current seed is refused
        let (_, r2) = rotate_file(&v1, Some(999), &v1).unwrap();
        assert_eq!((r2.epoch, r2.morph_seed), (2, 999));
        assert!(rotate_file(&v1, Some(999), &v1).is_err());
        std::fs::remove_file(&v0).ok();
        std::fs::remove_file(&v1).ok();
    }

    #[test]
    fn morph_key_is_deterministic() {
        let b = bundle();
        let k1 = b.morph_key().unwrap();
        let k2 = b.morph_key().unwrap();
        assert_eq!(k1.core(), k2.core());
        assert_eq!(k1.q(), 48);
    }

    #[test]
    fn invalid_kappa_rejected() {
        assert!(KeyBundle::generate(Geometry::SMALL, 7, 1).is_err());
    }
}
