//! Key vault — secure storage of the provider's secrets (paper §3.2/§3.3:
//! "the privacy-preserving feature … relies on the secure storage of M
//! [and] the detailed channel order used for rand").
//!
//! Stored material: the morph seed + κ (the core is regenerated
//! deterministically — see [`crate::morph::MorphKey::from_seed`]), the
//! channel permutation, the geometry, the key **epoch** with its
//! rotation lineage, and a SHA-256 fingerprint binding them together.
//! The binary format is versioned and integrity-checked; the vault file
//! is chmod 0600 on unix. Keys never cross the delivery protocol — only
//! `T^r` and `C^ac` do (§4.1 HBC surface).
//!
//! ## Epochs and rotation
//!
//! A provider re-morphs its corpus under fresh key material by calling
//! [`KeyBundle::rotate`]: the rotated bundle keeps the geometry and κ,
//! draws a new morph seed + channel permutation, increments the epoch,
//! and records the parent's fingerprint. The lineage lets a serving
//! registry host epoch N and N+1 side by side during rollover and lets
//! auditors walk a vault chain back to its root (the parent
//! fingerprint is empty only at epoch 0).
//!
//! ## The admin credential
//!
//! The vault also anchors the **admin-plane credential**
//! ([`KeyBundle::admin_credential`]): a labeled HMAC-SHA256 derivation
//! over the bundle's secret material (morph seed, credential seed,
//! permutation, epoch). It is what `mole serve` checks admin-frame MACs
//! against and what `mole keygen` prints for distribution. Because the
//! derivation runs over the *secrets* — not the public SHA-256
//! fingerprint that crosses the wire in `Hello` — knowing a lane's
//! fingerprint yields nothing about its credential, and rotating the
//! vault re-derives the credential along with everything else. The v3
//! vault format records the credential seed explicitly so the
//! derivation is pinned byte-for-byte by the stored material.

use crate::augconv::ChannelPerm;
use crate::hash::{from_hex, hmac_sha256, to_hex, Sha256};
use crate::morph::MorphKey;
use crate::{Error, Geometry, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Legacy (pre-epoch) vault magic; still loadable, never written.
const MAGIC_V1: &[u8; 8] = b"MOLEKEY1";
/// Legacy epoch/lineage magic (pre-credential); still loadable, never
/// written.
const MAGIC_V2: &[u8; 8] = b"MOLEKEY2";
/// Current vault magic: adds the admin-credential seed.
const MAGIC_V3: &[u8; 8] = b"MOLEKEY3";

/// Domain-separation label for deriving the credential seed from the
/// morph seed (legacy vaults carry no explicit seed; this keeps the
/// derivation deterministic across formats).
const CRED_SEED_LABEL: &[u8] = b"mole-admin-cred-seed-v1";
/// Domain-separation label for the admin credential itself.
const CRED_LABEL: &[u8] = b"mole-admin-credential-v1";

/// The provider's secret bundle for one delivery session.
#[derive(Debug, Clone)]
pub struct KeyBundle {
    pub geometry: Geometry,
    pub kappa: usize,
    pub morph_seed: u64,
    pub perm: ChannelPerm,
    /// Rotation generation: 0 for freshly generated bundles, +1 per
    /// [`KeyBundle::rotate`].
    pub epoch: u32,
    /// Fingerprint of the bundle this one was rotated from ("" at the
    /// root epoch). Binds the rotation chain into every fingerprint.
    pub parent_fingerprint: String,
    /// Seed of the admin-credential derivation (vault v3 field). Drawn
    /// deterministically from the morph seed on generate/rotate — and
    /// re-drawn on every rotation, so a rotated vault's credential never
    /// matches its parent's.
    pub cred_seed: u64,
}

/// Deterministic credential seed for a given morph seed (labeled, so it
/// shares no structure with the morph material it accompanies).
fn derive_cred_seed(morph_seed: u64) -> u64 {
    let mut h = Sha256::new();
    h.update(CRED_SEED_LABEL);
    h.update(morph_seed.to_le_bytes());
    u64::from_le_bytes(h.finalize()[..8].try_into().unwrap())
}

impl KeyBundle {
    /// Generate a fresh root bundle (epoch 0, no lineage).
    pub fn generate(geometry: Geometry, kappa: usize, seed: u64) -> Result<Self> {
        // validate kappa against the geometry before accepting it
        geometry.q_for_kappa(kappa)?;
        let perm = ChannelPerm::generate(geometry.beta, seed);
        Ok(Self {
            geometry,
            kappa,
            morph_seed: seed,
            perm,
            epoch: 0,
            parent_fingerprint: String::new(),
            cred_seed: derive_cred_seed(seed),
        })
    }

    /// Rotate to the next key epoch: same geometry and κ, fresh morph
    /// seed and channel permutation, `epoch + 1`, and this bundle's
    /// fingerprint recorded as the parent. The rotated bundle morphs
    /// differently (new M and rand order), so a provider re-morphs its
    /// corpus under it while servers keep serving the old epoch until
    /// rollover completes.
    pub fn rotate(&self, new_seed: u64) -> Result<Self> {
        if new_seed == self.morph_seed {
            return Err(Error::Key(
                "rotation must use fresh seed material (got the current seed)".into(),
            ));
        }
        let epoch = self.epoch.checked_add(1).ok_or_else(|| {
            Error::Key("key epoch counter exhausted (u32::MAX rotations)".into())
        })?;
        Ok(Self {
            geometry: self.geometry,
            kappa: self.kappa,
            morph_seed: new_seed,
            perm: ChannelPerm::generate(self.geometry.beta, new_seed),
            epoch,
            parent_fingerprint: self.fingerprint(),
            cred_seed: derive_cred_seed(new_seed),
        })
    }

    /// Materialize the morph key (regenerates the core from the seed; the
    /// condition-number gate makes this deterministic).
    pub fn morph_key(&self) -> Result<MorphKey> {
        MorphKey::from_seed(self.geometry, self.kappa, self.morph_seed)
    }

    /// SHA-256 fingerprint over all key material including the epoch and
    /// rotation lineage (hex). Used to detect tampering and to name
    /// sessions without revealing secrets; two epochs of the same root
    /// never share a fingerprint. Public: it crosses the wire in `Hello`
    /// frames — the preimage resistance of SHA-256 is what keeps the
    /// secrets (and the admin credential derived from them) unreachable
    /// from it.
    ///
    /// Fingerprints are **format-versioned**: they hash the current
    /// magic + body, so a vault-format bump (v2 → v3 added the
    /// credential seed) renames every bundle — a `parent_fingerprint`
    /// recorded by an older release will not equal the parent's
    /// post-upgrade `fingerprint()`. Runtime routing never depends on
    /// this (lanes resolve by `(model, epoch)`); audit walks across a
    /// format boundary must recompute under the recording release.
    pub fn fingerprint(&self) -> String {
        let mut h = Sha256::new();
        h.update(MAGIC_V3);
        h.update(self.encode_body());
        to_hex(&h.finalize())
    }

    /// The vault-derived admin-plane credential: a labeled HMAC-SHA256
    /// over the bundle's **secret** material (morph seed, credential
    /// seed, permutation, epoch — everything the vault stores). This is
    /// the shared secret between `mole keygen`/`mole admin` and a
    /// credential-gated `mole serve`; rotation re-derives it, so an old
    /// epoch's credential dies with the rollover.
    pub fn admin_credential(&self) -> [u8; 32] {
        hmac_sha256(&self.encode_body(), CRED_LABEL)
    }

    /// Hex form of [`KeyBundle::admin_credential`] — the distribution
    /// format (`mole keygen` output, `[serving] admin_credential_file`).
    pub fn admin_credential_hex(&self) -> String {
        to_hex(&self.admin_credential())
    }

    fn encode_body(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for v in [
            self.geometry.alpha as u64,
            self.geometry.m as u64,
            self.geometry.beta as u64,
            self.geometry.p as u64,
            self.kappa as u64,
            self.morph_seed,
            self.epoch as u64,
            self.cred_seed,
            self.perm.beta() as u64,
        ] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&(self.parent_fingerprint.len() as u32).to_le_bytes());
        out.extend_from_slice(self.parent_fingerprint.as_bytes());
        for &p in self.perm.as_slice() {
            out.extend_from_slice(&(p as u32).to_le_bytes());
        }
        out
    }

    /// Serialize to the versioned vault format: MAGIC | body | SHA-256.
    pub fn to_bytes(&self) -> Vec<u8> {
        let body = self.encode_body();
        let mut out = Vec::with_capacity(8 + body.len() + 32);
        out.extend_from_slice(MAGIC_V3);
        out.extend_from_slice(&body);
        let mut h = Sha256::new();
        h.update(MAGIC_V3);
        h.update(&body);
        out.extend_from_slice(&h.finalize());
        out
    }

    /// Deserialize + integrity-check. Reads the current `MOLEKEY3`
    /// format plus the legacy `MOLEKEY2` (no credential seed; re-derived
    /// from the morph seed) and `MOLEKEY1` layouts (which additionally
    /// map to epoch 0 with no lineage).
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 8 + 32 {
            return Err(Error::Key("bad vault magic or truncated file".into()));
        }
        let version = match &bytes[..8] {
            m if m == MAGIC_V3 => 3,
            m if m == MAGIC_V2 => 2,
            m if m == MAGIC_V1 => 1,
            _ => return Err(Error::Key("bad vault magic or truncated file".into())),
        };
        let (payload, digest) = bytes.split_at(bytes.len() - 32);
        let mut h = Sha256::new();
        h.update(payload);
        if h.finalize().as_slice() != digest {
            return Err(Error::Key("vault integrity check failed".into()));
        }
        let body = &payload[8..];
        match version {
            3 => Self::decode_body_v3(body),
            2 => Self::decode_body_v2(body),
            _ => Self::decode_body_v1(body),
        }
    }

    fn decode_body_v3(body: &[u8]) -> Result<Self> {
        let fixed = 9 * 8;
        if body.len() < fixed + 4 {
            return Err(Error::Key("vault body truncated".into()));
        }
        let u = |i: usize| -> u64 {
            u64::from_le_bytes(body[i * 8..(i + 1) * 8].try_into().unwrap())
        };
        let geometry = Geometry::new(u(0) as usize, u(1) as usize, u(2) as usize, u(3) as usize);
        let kappa = u(4) as usize;
        let morph_seed = u(5);
        let epoch = u(6) as u32;
        let cred_seed = u(7);
        let beta = u(8) as usize;
        let (parent_fingerprint, rest) = Self::decode_lineage(&body[fixed..])?;
        let perm = Self::decode_perm(rest, beta)?;
        Ok(Self {
            geometry,
            kappa,
            morph_seed,
            perm,
            epoch,
            parent_fingerprint,
            cred_seed,
        })
    }

    fn decode_body_v2(body: &[u8]) -> Result<Self> {
        let fixed = 8 * 8;
        if body.len() < fixed + 4 {
            return Err(Error::Key("vault body truncated".into()));
        }
        let u = |i: usize| -> u64 {
            u64::from_le_bytes(body[i * 8..(i + 1) * 8].try_into().unwrap())
        };
        let geometry = Geometry::new(u(0) as usize, u(1) as usize, u(2) as usize, u(3) as usize);
        let kappa = u(4) as usize;
        let morph_seed = u(5);
        let epoch = u(6) as u32;
        let beta = u(7) as usize;
        let (parent_fingerprint, rest) = Self::decode_lineage(&body[fixed..])?;
        let perm = Self::decode_perm(rest, beta)?;
        Ok(Self {
            geometry,
            kappa,
            morph_seed,
            perm,
            epoch,
            parent_fingerprint,
            cred_seed: derive_cred_seed(morph_seed),
        })
    }

    fn decode_body_v1(body: &[u8]) -> Result<Self> {
        let fixed = 7 * 8;
        if body.len() < fixed {
            return Err(Error::Key("vault body truncated".into()));
        }
        let u = |i: usize| -> u64 {
            u64::from_le_bytes(body[i * 8..(i + 1) * 8].try_into().unwrap())
        };
        let geometry = Geometry::new(u(0) as usize, u(1) as usize, u(2) as usize, u(3) as usize);
        let morph_seed = u(5);
        let perm = Self::decode_perm(&body[fixed..], u(6) as usize)?;
        Ok(Self {
            geometry,
            kappa: u(4) as usize,
            morph_seed,
            perm,
            epoch: 0,
            parent_fingerprint: String::new(),
            cred_seed: derive_cred_seed(morph_seed),
        })
    }

    /// Shared v2/v3 lineage decode: u32 length + UTF-8 fingerprint,
    /// returning the remaining (permutation) bytes.
    fn decode_lineage(bytes: &[u8]) -> Result<(String, &[u8])> {
        let fp_len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
        let fp_end = 4usize
            .checked_add(fp_len)
            .ok_or_else(|| Error::Key("vault lineage length overflows".into()))?;
        if bytes.len() < fp_end {
            return Err(Error::Key("vault lineage field truncated".into()));
        }
        let fp = String::from_utf8(bytes[4..fp_end].to_vec())
            .map_err(|_| Error::Key("vault lineage field is not utf-8".into()))?;
        Ok((fp, &bytes[fp_end..]))
    }

    fn decode_perm(perm_bytes: &[u8], beta: usize) -> Result<ChannelPerm> {
        if perm_bytes.len() != beta.checked_mul(4).unwrap_or(usize::MAX) {
            return Err(Error::Key("vault permutation length mismatch".into()));
        }
        ChannelPerm::from_vec(
            perm_bytes
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as usize)
                .collect(),
        )
    }

    /// Save to a vault file (0600 on unix, applied at create so the
    /// secrets never sit behind a umask-default mode).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = create_secret_file(path)?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    /// Load from a vault file.
    pub fn load(path: &Path) -> Result<Self> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut bytes)?;
        Self::from_bytes(&bytes)
    }
}

/// Rotate a vault **file** to the next key epoch: load, rotate (fresh
/// seed — `morph_seed + 1` when `new_seed` is `None` — and permutation,
/// lineage recorded), save to `out`. Returns `(old, rotated)` so
/// callers can report the epoch/fingerprint transition.
///
/// This is the offline half of the live rollover: the rotated vault is
/// what `mole admin register --vault` hands to a running server, which
/// loads it from its own filesystem and starts the new epoch's lane
/// next to the old one.
pub fn rotate_file(
    vault: &Path,
    new_seed: Option<u64>,
    out: &Path,
) -> Result<(KeyBundle, KeyBundle)> {
    let keys = KeyBundle::load(vault)?;
    let seed = new_seed.unwrap_or_else(|| keys.morph_seed.wrapping_add(1));
    let rotated = keys.rotate(seed)?;
    rotated.save(out)?;
    Ok((keys, rotated))
}

/// Create a secret-holding file with 0600 applied **at create time**
/// (unix): creating with the umask default and chmod'ing afterwards
/// would leave a window where another local user can open the file and
/// keep the fd — exactly the multi-user-host scenario the admin
/// credential exists for.
fn create_secret_file(path: &Path) -> Result<std::fs::File> {
    let mut opts = std::fs::OpenOptions::new();
    opts.write(true).create(true).truncate(true);
    #[cfg(unix)]
    {
        use std::os::unix::fs::OpenOptionsExt;
        opts.mode(0o600);
    }
    let f = opts.open(path)?;
    // mode() only applies to newly created files; re-assert on rewrite
    #[cfg(unix)]
    {
        use std::os::unix::fs::PermissionsExt;
        f.set_permissions(std::fs::Permissions::from_mode(0o600))?;
    }
    Ok(f)
}

/// Write an admin credential to a file (lowercase hex + newline, 0600
/// on unix from the moment it exists) — the distribution format
/// `mole keygen --credential-out` produces and `[serving]
/// admin_credential_file` / `mole admin --credential` consume.
pub fn save_credential_file(cred: &[u8; 32], path: &Path) -> Result<()> {
    let mut f = create_secret_file(path)?;
    f.write_all(to_hex(cred).as_bytes())?;
    f.write_all(b"\n")?;
    Ok(())
}

/// Load an admin credential file (64 hex chars, surrounding whitespace
/// tolerated).
pub fn load_credential_file(path: &Path) -> Result<[u8; 32]> {
    let text = std::fs::read_to_string(path)?;
    let cred = from_hex(text.trim()).ok_or_else(|| {
        Error::Key(format!("credential file {path:?} is not hex"))
    })?;
    cred.as_slice().try_into().map_err(|_| {
        Error::Key(format!(
            "credential file {path:?} holds {} bytes, expected 32",
            cred.len()
        ))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bundle() -> KeyBundle {
        KeyBundle::generate(Geometry::SMALL, 16, 1234).unwrap()
    }

    /// Hand-encode the legacy MOLEKEY1 layout for back-compat coverage.
    fn v1_bytes(b: &KeyBundle) -> Vec<u8> {
        let mut body = Vec::new();
        for v in [
            b.geometry.alpha as u64,
            b.geometry.m as u64,
            b.geometry.beta as u64,
            b.geometry.p as u64,
            b.kappa as u64,
            b.morph_seed,
            b.perm.beta() as u64,
        ] {
            body.extend_from_slice(&v.to_le_bytes());
        }
        for &p in b.perm.as_slice() {
            body.extend_from_slice(&(p as u32).to_le_bytes());
        }
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC_V1);
        out.extend_from_slice(&body);
        let mut h = Sha256::new();
        h.update(MAGIC_V1);
        h.update(&body);
        out.extend_from_slice(&h.finalize());
        out
    }

    #[test]
    fn roundtrip_bytes() {
        let b = bundle();
        let parsed = KeyBundle::from_bytes(&b.to_bytes()).unwrap();
        assert_eq!(parsed.geometry, b.geometry);
        assert_eq!(parsed.kappa, b.kappa);
        assert_eq!(parsed.morph_seed, b.morph_seed);
        assert_eq!(parsed.perm, b.perm);
        assert_eq!(parsed.epoch, 0);
        assert_eq!(parsed.parent_fingerprint, "");
    }

    #[test]
    fn legacy_v1_vault_still_loads() {
        let b = bundle();
        let loaded = KeyBundle::from_bytes(&v1_bytes(&b)).unwrap();
        assert_eq!(loaded.geometry, b.geometry);
        assert_eq!(loaded.kappa, b.kappa);
        assert_eq!(loaded.morph_seed, b.morph_seed);
        assert_eq!(loaded.perm, b.perm);
        assert_eq!(loaded.epoch, 0);
        assert_eq!(loaded.parent_fingerprint, "");
        // re-saving upgrades to the current format without changing the
        // material (fingerprints agree because epoch 0 + empty lineage +
        // the same derived credential seed)
        assert_eq!(loaded.fingerprint(), b.fingerprint());
        assert_eq!(loaded.admin_credential(), b.admin_credential());
        assert_eq!(&loaded.to_bytes()[..8], MAGIC_V3);
        // tampered legacy bytes are still caught
        let mut bad = v1_bytes(&b);
        bad[8 + 5 * 8] ^= 1;
        assert!(matches!(KeyBundle::from_bytes(&bad), Err(Error::Key(_))));
    }

    /// Hand-encode the legacy MOLEKEY2 layout (no credential seed) for
    /// back-compat coverage.
    fn v2_bytes(b: &KeyBundle) -> Vec<u8> {
        let mut body = Vec::new();
        for v in [
            b.geometry.alpha as u64,
            b.geometry.m as u64,
            b.geometry.beta as u64,
            b.geometry.p as u64,
            b.kappa as u64,
            b.morph_seed,
            b.epoch as u64,
            b.perm.beta() as u64,
        ] {
            body.extend_from_slice(&v.to_le_bytes());
        }
        body.extend_from_slice(&(b.parent_fingerprint.len() as u32).to_le_bytes());
        body.extend_from_slice(b.parent_fingerprint.as_bytes());
        for &p in b.perm.as_slice() {
            body.extend_from_slice(&(p as u32).to_le_bytes());
        }
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC_V2);
        out.extend_from_slice(&body);
        let mut h = Sha256::new();
        h.update(MAGIC_V2);
        h.update(&body);
        out.extend_from_slice(&h.finalize());
        out
    }

    #[test]
    fn legacy_v2_vault_still_loads() {
        let root = bundle();
        let b = root.rotate(4242).unwrap();
        let loaded = KeyBundle::from_bytes(&v2_bytes(&b)).unwrap();
        assert_eq!(loaded.morph_seed, b.morph_seed);
        assert_eq!(loaded.epoch, 1);
        assert_eq!(loaded.parent_fingerprint, b.parent_fingerprint);
        assert_eq!(loaded.perm, b.perm);
        // the credential seed is re-derived from the morph seed, so the
        // upgraded bundle is byte-identical to a natively-v3 rotation
        assert_eq!(loaded.cred_seed, b.cred_seed);
        assert_eq!(loaded.fingerprint(), b.fingerprint());
        assert_eq!(loaded.admin_credential(), b.admin_credential());
        // tampered v2 bytes are still caught
        let mut bad = v2_bytes(&b);
        bad[8 + 5 * 8] ^= 1;
        assert!(matches!(KeyBundle::from_bytes(&bad), Err(Error::Key(_))));
    }

    #[test]
    fn admin_credential_derivation() {
        let a = bundle();
        // deterministic, 32 bytes, hex form matches
        assert_eq!(a.admin_credential(), bundle().admin_credential());
        assert_eq!(a.admin_credential_hex().len(), 64);
        assert_eq!(
            a.admin_credential_hex(),
            to_hex(&a.admin_credential())
        );
        // distinct key material ⇒ distinct credential
        let b = KeyBundle::generate(Geometry::SMALL, 16, 1235).unwrap();
        assert_ne!(a.admin_credential(), b.admin_credential());
        // rotation re-derives the credential along with everything else
        let r = a.rotate(5678).unwrap();
        assert_ne!(r.admin_credential(), a.admin_credential());
        assert_ne!(r.cred_seed, a.cred_seed);
        // the credential is not the (public) fingerprint, nor derivable
        // by hashing it the obvious way
        assert_ne!(a.admin_credential_hex(), a.fingerprint());
        let fp_hash = crate::hash::sha256(a.fingerprint().as_bytes());
        assert_ne!(a.admin_credential(), fp_hash);
    }

    #[test]
    fn credential_file_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join("mole_cred_file_test.cred");
        let cred = bundle().admin_credential();
        save_credential_file(&cred, &path).unwrap();
        #[cfg(unix)]
        {
            use std::os::unix::fs::PermissionsExt;
            let mode = std::fs::metadata(&path).unwrap().permissions().mode();
            assert_eq!(mode & 0o777, 0o600);
        }
        assert_eq!(load_credential_file(&path).unwrap(), cred);
        // whitespace tolerated, garbage rejected typed
        std::fs::write(&path, format!("  {}\n\n", to_hex(&cred))).unwrap();
        assert_eq!(load_credential_file(&path).unwrap(), cred);
        std::fs::write(&path, "not-hex-at-all").unwrap();
        assert!(matches!(load_credential_file(&path), Err(Error::Key(_))));
        std::fs::write(&path, "abcd").unwrap(); // hex, wrong length
        let err = load_credential_file(&path).unwrap_err();
        assert!(err.to_string().contains("expected 32"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rotation_advances_epoch_and_lineage() {
        let root = bundle();
        let r1 = root.rotate(5678).unwrap();
        assert_eq!(r1.epoch, 1);
        assert_eq!(r1.parent_fingerprint, root.fingerprint());
        assert_eq!(r1.geometry, root.geometry);
        assert_eq!(r1.kappa, root.kappa);
        assert_ne!(r1.morph_seed, root.morph_seed);
        assert_ne!(r1.fingerprint(), root.fingerprint());
        // rotation actually changes the morph: same rows, different T^r
        let mut rng = crate::rng::Rng::new(9);
        let rows = crate::tensor::Tensor::new(&[2, 768], rng.normal_vec(2 * 768, 1.0)).unwrap();
        let t0 = root.morph_key().unwrap().morph(&rows).unwrap();
        let t1 = r1.morph_key().unwrap().morph(&rows).unwrap();
        assert!(t0.rms_diff(&t1).unwrap() > 0.1, "rotation left the morph unchanged");
        // chain: epoch 2 points at epoch 1, not the root
        let r2 = r1.rotate(9999).unwrap();
        assert_eq!(r2.epoch, 2);
        assert_eq!(r2.parent_fingerprint, r1.fingerprint());
        assert_ne!(r2.parent_fingerprint, root.fingerprint());
        // reusing the current seed is rejected
        assert!(matches!(r1.rotate(r1.morph_seed), Err(Error::Key(_))));
    }

    #[test]
    fn rotated_bundle_roundtrips_with_lineage() {
        let root = bundle();
        let r1 = root.rotate(31337).unwrap();
        let parsed = KeyBundle::from_bytes(&r1.to_bytes()).unwrap();
        assert_eq!(parsed.epoch, 1);
        assert_eq!(parsed.parent_fingerprint, root.fingerprint());
        assert_eq!(parsed.fingerprint(), r1.fingerprint());
        assert_eq!(parsed.morph_seed, r1.morph_seed);
        assert_eq!(parsed.perm, r1.perm);
    }

    #[test]
    fn tamper_detected() {
        let b = bundle().rotate(77).unwrap();
        let mut bytes = b.to_bytes();
        // flip a bit in the seed field
        bytes[8 + 5 * 8] ^= 1;
        assert!(matches!(KeyBundle::from_bytes(&bytes), Err(Error::Key(_))));
        // flip a bit in the epoch field: lineage is integrity-protected too
        let mut bytes = b.to_bytes();
        bytes[8 + 6 * 8] ^= 1;
        assert!(matches!(KeyBundle::from_bytes(&bytes), Err(Error::Key(_))));
        // flip a bit in the credential seed: the v3 field is
        // integrity-protected too
        let mut bytes = b.to_bytes();
        bytes[8 + 7 * 8] ^= 1;
        assert!(matches!(KeyBundle::from_bytes(&bytes), Err(Error::Key(_))));
        // flip a bit inside the parent fingerprint
        let mut bytes = b.to_bytes();
        bytes[8 + 9 * 8 + 4] ^= 1;
        assert!(matches!(KeyBundle::from_bytes(&bytes), Err(Error::Key(_))));
        // truncation
        assert!(KeyBundle::from_bytes(&b.to_bytes()[..10]).is_err());
        // bad magic
        let mut bytes = b.to_bytes();
        bytes[0] = b'X';
        assert!(KeyBundle::from_bytes(&bytes).is_err());
    }

    #[test]
    fn fingerprint_binds_material() {
        let a = bundle();
        let b = KeyBundle::generate(Geometry::SMALL, 16, 1235).unwrap();
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.fingerprint().len(), 64);
        // same material, same fingerprint
        let a2 = KeyBundle::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(a.fingerprint(), a2.fingerprint());
        // epoch participates in the fingerprint: identical seed/perm at a
        // different epoch must not collide
        let mut forged = a.clone();
        forged.epoch = 1;
        assert_ne!(forged.fingerprint(), a.fingerprint());
    }

    #[test]
    fn save_load_file() {
        let b = bundle().rotate(4321).unwrap();
        let path = std::env::temp_dir().join("mole_vault_test.key");
        b.save(&path).unwrap();
        #[cfg(unix)]
        {
            use std::os::unix::fs::PermissionsExt;
            let mode = std::fs::metadata(&path).unwrap().permissions().mode();
            assert_eq!(mode & 0o777, 0o600);
        }
        let loaded = KeyBundle::load(&path).unwrap();
        assert_eq!(loaded.fingerprint(), b.fingerprint());
        assert_eq!(loaded.epoch, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rotate_file_advances_the_vault() {
        let dir = std::env::temp_dir();
        let v0 = dir.join("mole_rotate_file_v0.key");
        let v1 = dir.join("mole_rotate_file_v1.key");
        bundle().save(&v0).unwrap();
        let (old, rotated) = rotate_file(&v0, None, &v1).unwrap();
        assert_eq!(old.epoch, 0);
        assert_eq!(rotated.epoch, 1);
        assert_eq!(rotated.morph_seed, old.morph_seed + 1);
        assert_eq!(rotated.parent_fingerprint, old.fingerprint());
        // the written vault round-trips to the rotated bundle
        let loaded = KeyBundle::load(&v1).unwrap();
        assert_eq!(loaded.fingerprint(), rotated.fingerprint());
        // the source vault is untouched (rotate-out, not in-place)
        assert_eq!(KeyBundle::load(&v0).unwrap().epoch, 0);
        // explicit seed wins; reusing the current seed is refused
        let (_, r2) = rotate_file(&v1, Some(999), &v1).unwrap();
        assert_eq!((r2.epoch, r2.morph_seed), (2, 999));
        assert!(rotate_file(&v1, Some(999), &v1).is_err());
        std::fs::remove_file(&v0).ok();
        std::fs::remove_file(&v1).ok();
    }

    #[test]
    fn morph_key_is_deterministic() {
        let b = bundle();
        let k1 = b.morph_key().unwrap();
        let k2 = b.morph_key().unwrap();
        assert_eq!(k1.core(), k2.core());
        assert_eq!(k1.q(), 48);
    }

    #[test]
    fn invalid_kappa_rejected() {
        assert!(KeyBundle::generate(Geometry::SMALL, 7, 1).is_err());
    }
}
