//! The reference single-threaded backend: the original cache-blocked
//! axpy GEMM kernel, unchanged semantics. Every other backend is tested
//! for exact agreement against this one.

use super::Backend;

/// Block sizes tuned for ~32 KiB L1 / 1 MiB L2 on the test machine
/// (see EXPERIMENTS.md §Perf for the sweep).
const MC: usize = 64; // rows of A per block
const KC: usize = 256; // depth per block
const NC: usize = 1024; // columns of B per block

/// Cache-blocked single-threaded GEMM.
///
/// Row-major C = A·B implemented as an axpy-style rank-1-per-k update
/// inside L1-sized blocks: for each (i, k) the inner loop is
/// `c_row[j] += a_ik * b_row[j]`, which LLVM vectorizes to FMA lanes under
/// `-C target-cpu=native`. Blocking keeps the active B panel in L2.
#[derive(Debug, Clone, Copy, Default)]
pub struct RefBackend;

impl RefBackend {
    pub fn new() -> Self {
        RefBackend
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn gemm_slices(
        &self,
        m: usize,
        k: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        accumulate: bool,
    ) {
        gemm_kernel(m, k, n, a, b, c, accumulate);
    }
}

/// The shared micro-kernel: `c[m,n] (+)= a[m,k]·b[k,n]`, all row-major.
/// Also the work unit the parallel backend hands to each thread (with `a`
/// and `c` sliced to a row panel), which is what keeps outputs bitwise
/// identical across backends.
pub(crate) fn gemm_kernel(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    accumulate: bool,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if !accumulate {
        for v in c.iter_mut() {
            *v = 0.0;
        }
    }
    for jc in (0..n).step_by(NC) {
        let nb = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kb = KC.min(k - pc);
            for ic in (0..m).step_by(MC) {
                let mb = MC.min(m - ic);
                // micro block: axpy over rows
                for i in ic..ic + mb {
                    let a_row = &a[i * k + pc..i * k + pc + kb];
                    let c_row = &mut c[i * n + jc..i * n + jc + nb];
                    for (dk, &aik) in a_row.iter().enumerate() {
                        if aik == 0.0 {
                            continue; // morphing matrices are block-sparse
                        }
                        let b_row = &b[(pc + dk) * n + jc..(pc + dk) * n + jc + nb];
                        for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
    }
}
