//! Explicitly vectorized packed-panel GEMM backend.
//!
//! [`SimdBackend`] implements the classic three-level blocked GEMM
//! (BLIS-style): operands are packed into contiguous, lane-aligned panels
//! — A into `MR`-row panels, B into `NR`-column panels — and an `MR×NR`
//! register-tile microkernel walks the `KC`-deep panels with f32×8 lane
//! arithmetic. Three microkernels exist:
//!
//! * **AVX2+FMA** (`x86_64`, behind `is_x86_feature_detected!`): a 6×16
//!   tile held in twelve 8-lane ymm accumulators, `_mm256_fmadd_ps` per
//!   k-step.
//! * **NEON** (`aarch64`): a 6×8 tile in twelve 4-lane q-register
//!   accumulators, `vfmaq_f32` per k-step.
//! * **Portable** (every target, and the `MOLE_SIMD=off` escape hatch): a
//!   4×8 tile of unrolled scalar mul+add the compiler can keep in
//!   registers. This fallback is *mandatory*: the backend exists and
//!   passes the parity suite on targets with no vector ISA at all.
//!
//! ## Numerics contract
//!
//! Every microkernel **loads the live C tile, accumulates the k-steps in
//! increasing-k order onto it, and stores it back** — partial tiles go
//! through a scratch pre-seeded with the live C values. That means the
//! per-element accumulation chain is `((c₀ + t₁) + t₂) + …` in plain
//! k-order for every blocking parameter, exactly the chain the reference
//! kernel produces. Consequences the parity suite pins:
//!
//! * the portable microkernel (plain mul+add) is **bitwise identical** to
//!   [`super::RefBackend`] on finite data;
//! * the AVX2/NEON microkernels differ from the reference *only* by the
//!   fused multiply-add rounding of each step — same association order —
//!   a drift pinned to ≤ max(4, √k) ULP at the output's max-magnitude
//!   scale in `tests/backend_parity.rs`, never "allclose"-loose.
//!
//! Runtime selection: [`SimdBackend::new`] probes the CPU once; setting
//! `MOLE_SIMD=off` (or `0` / `portable`) forces the portable microkernel,
//! which is how CI exercises the fallback path on vector-capable runners.

use super::Backend;

/// Depth of one packed panel pair (k-blocking).
const KC: usize = 256;
/// Rows of A packed per L2 block.
const MC: usize = 96;
/// Columns of B packed per outer block.
const NC: usize = 1024;
/// Below this B-panel footprint (`k·n` elements) packing costs more than
/// it saves; fall through to the reference cache-blocked kernel. The
/// threshold depends only on (k, n), never m, so splitting rows across
/// threads (the `parallel+simd` composition) cannot change which kernel
/// a row meets — outputs stay bitwise identical under row-panel fan-out.
const SMALL_KN: usize = 1024;

/// The instruction set a [`SimdBackend`] instance drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// x86-64 AVX2 + FMA (8-lane f32, fused multiply-add).
    Avx2,
    /// AArch64 NEON (4-lane f32, fused multiply-add).
    Neon,
    /// Unrolled scalar tile — the mandatory every-target fallback.
    Portable,
}

impl Isa {
    /// Microkernel tile rows (MR).
    fn mr(self) -> usize {
        match self {
            Isa::Avx2 | Isa::Neon => 6,
            Isa::Portable => 4,
        }
    }

    /// Microkernel tile columns (NR).
    fn nr(self) -> usize {
        match self {
            Isa::Avx2 => 16,
            Isa::Neon | Isa::Portable => 8,
        }
    }

    /// Short name for logs and `BENCH_*.json` metadata.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
            Isa::Portable => "portable",
        }
    }
}

/// Probe the CPU for the best available microkernel, honouring the
/// `MOLE_SIMD=off|0|portable` escape hatch.
fn detect_isa() -> Isa {
    if matches!(
        std::env::var("MOLE_SIMD").as_deref(),
        Ok("off") | Ok("0") | Ok("portable")
    ) {
        return Isa::Portable;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Isa::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Isa::Neon;
        }
    }
    Isa::Portable
}

/// Runtime-detected CPU vector features, for bench metadata and logs
/// (independent of which backend is active).
pub fn cpu_features() -> String {
    let mut feats: Vec<&'static str> = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            feats.push("avx2");
        }
        if is_x86_feature_detected!("fma") {
            feats.push("fma");
        }
        if is_x86_feature_detected!("avx512f") {
            feats.push("avx512f");
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            feats.push("neon");
        }
    }
    if feats.is_empty() {
        "none".to_string()
    } else {
        feats.join(",")
    }
}

/// Packed-panel SIMD GEMM backend. See the module docs for the kernel
/// structure and the numerics contract.
#[derive(Debug, Clone, Copy)]
pub struct SimdBackend {
    isa: Isa,
}

impl Default for SimdBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl SimdBackend {
    /// Auto-detect the best microkernel for this CPU (respects the
    /// `MOLE_SIMD=off` escape hatch).
    pub fn new() -> Self {
        SimdBackend { isa: detect_isa() }
    }

    /// Force the portable (unrolled-scalar) microkernel — what
    /// `MOLE_SIMD=off` selects, constructible directly for deterministic
    /// tests.
    pub fn portable() -> Self {
        SimdBackend { isa: Isa::Portable }
    }

    pub fn isa(&self) -> Isa {
        self.isa
    }

    /// True when a real vector ISA (AVX2/NEON) was detected — i.e. the
    /// outputs may differ from [`super::RefBackend`] by FMA rounding
    /// (≤ max(4, √k) ULP at the output's scale); the portable kernel is
    /// bitwise identical instead.
    pub fn is_vectorized(&self) -> bool {
        self.isa != Isa::Portable
    }
}

impl Backend for SimdBackend {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn describe(&self) -> String {
        format!("simd({})", self.isa.name())
    }

    fn gemm_slices(
        &self,
        m: usize,
        k: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        accumulate: bool,
    ) {
        packed_gemm(self.isa, m, k, n, a, b, c, accumulate);
    }
}

/// Pack an `mb×kb` sub-block of row-major `a` into `MR`-row panels:
/// panel `p` holds rows `ic+p·mr ..`, laid out k-major (`kk·mr + r`) so
/// the microkernel reads `mr` A values per k-step from one cache line.
/// Rows past `mb` pad with zeros (their products land in discarded lanes).
#[allow(clippy::too_many_arguments)]
fn pack_a(
    a: &[f32],
    lda: usize,
    ic: usize,
    mb: usize,
    pc: usize,
    kb: usize,
    mr: usize,
    buf: &mut [f32],
) {
    let panels = mb.div_ceil(mr);
    for p in 0..panels {
        let dst = &mut buf[p * kb * mr..(p + 1) * kb * mr];
        for kk in 0..kb {
            for r in 0..mr {
                let row = p * mr + r;
                dst[kk * mr + r] = if row < mb {
                    a[(ic + row) * lda + pc + kk]
                } else {
                    0.0
                };
            }
        }
    }
}

/// Pack a `kb×nb` sub-block of row-major `b` into `NR`-column panels:
/// panel `t` holds columns `jc+t·nr ..`, laid out k-major (`kk·nr + c`)
/// so each k-step is one (or two) contiguous lane loads. Columns past
/// `nb` pad with zeros.
#[allow(clippy::too_many_arguments)]
fn pack_b(
    b: &[f32],
    ldb: usize,
    pc: usize,
    kb: usize,
    jc: usize,
    nb: usize,
    nr: usize,
    buf: &mut [f32],
) {
    let panels = nb.div_ceil(nr);
    for t in 0..panels {
        let dst = &mut buf[t * kb * nr..(t + 1) * kb * nr];
        for kk in 0..kb {
            let src_row = (pc + kk) * ldb + jc + t * nr;
            let cols = nr.min(nb - t * nr);
            let d = &mut dst[kk * nr..kk * nr + nr];
            d[..cols].copy_from_slice(&b[src_row..src_row + cols]);
            for v in &mut d[cols..] {
                *v = 0.0;
            }
        }
    }
}

/// The packed-panel GEMM driver: `c[m,n] (+)= a[m,k]·b[k,n]`, row-major.
#[allow(clippy::too_many_arguments)]
pub(crate) fn packed_gemm(
    isa: Isa,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    accumulate: bool,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if !accumulate {
        for v in c.iter_mut() {
            *v = 0.0;
        }
    }
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    if k * n < SMALL_KN {
        // tiny B panel: packing overhead dominates. The reference kernel
        // accumulates in the same k-order, so this switch is invisible to
        // the portable-parity guarantee (c is already zeroed above).
        super::reference::gemm_kernel(m, k, n, a, b, c, true);
        return;
    }
    let (mr, nr) = (isa.mr(), isa.nr());
    let mut apack = vec![0.0f32; MC.div_ceil(mr) * mr * KC];
    let mut bpack = vec![0.0f32; NC.div_ceil(nr) * nr * KC];
    let mut scratch = vec![0.0f32; mr * nr];
    for jc in (0..n).step_by(NC) {
        let nb = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kb = KC.min(k - pc);
            pack_b(b, n, pc, kb, jc, nb, nr, &mut bpack);
            for ic in (0..m).step_by(MC) {
                let mb = MC.min(m - ic);
                pack_a(a, k, ic, mb, pc, kb, mr, &mut apack);
                for (t, jr) in (0..nb).step_by(nr).enumerate() {
                    let nbr = nr.min(nb - jr);
                    let bp = &bpack[t * kb * nr..];
                    for (p, ir) in (0..mb).step_by(mr).enumerate() {
                        let mbr = mr.min(mb - ir);
                        let ap = &apack[p * kb * mr..];
                        let c0 = (ic + ir) * n + jc + jr;
                        if mbr == mr && nbr == nr {
                            // SAFETY: full tile — mr rows of nr elements
                            // at stride n starting at c0 are in bounds,
                            // and ap/bp hold kb·mr / kb·nr packed values.
                            unsafe {
                                run_tile(isa, kb, ap.as_ptr(), bp.as_ptr(), c[c0..].as_mut_ptr(), n);
                            }
                        } else {
                            // partial tile: seed the scratch with the live
                            // C values so the accumulation chain per
                            // element is identical to the full-tile path.
                            scratch.fill(0.0);
                            for i in 0..mbr {
                                scratch[i * nr..i * nr + nbr]
                                    .copy_from_slice(&c[c0 + i * n..c0 + i * n + nbr]);
                            }
                            // SAFETY: scratch is exactly mr·nr with
                            // stride nr; panels as above.
                            unsafe {
                                run_tile(isa, kb, ap.as_ptr(), bp.as_ptr(), scratch.as_mut_ptr(), nr);
                            }
                            for i in 0..mbr {
                                c[c0 + i * n..c0 + i * n + nbr]
                                    .copy_from_slice(&scratch[i * nr..i * nr + nbr]);
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Dispatch one register tile. Callers guarantee `a` holds `kc·MR` packed
/// values, `b` holds `kc·NR`, and `c` addresses an `MR×NR` tile at row
/// stride `ldc`.
unsafe fn run_tile(isa: Isa, kc: usize, a: *const f32, b: *const f32, c: *mut f32, ldc: usize) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => tile_avx2(kc, a, b, c, ldc),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => tile_neon(kc, a, b, c, ldc),
        _ => tile_portable(kc, a, b, c, ldc),
    }
}

/// 6×16 AVX2+FMA tile: twelve ymm accumulators (2 per row), one
/// broadcast + two fused multiply-adds per (row, k-step). Loads the live
/// C tile first so the k-chain continues across KC blocks unchanged.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn tile_avx2(kc: usize, a: *const f32, b: *const f32, c: *mut f32, ldc: usize) {
    use std::arch::x86_64::*;
    let mut acc = [[_mm256_setzero_ps(); 2]; 6];
    for (i, row) in acc.iter_mut().enumerate() {
        row[0] = _mm256_loadu_ps(c.add(i * ldc));
        row[1] = _mm256_loadu_ps(c.add(i * ldc + 8));
    }
    let mut ap = a;
    let mut bp = b;
    for _ in 0..kc {
        let b0 = _mm256_loadu_ps(bp);
        let b1 = _mm256_loadu_ps(bp.add(8));
        for (i, row) in acc.iter_mut().enumerate() {
            let ai = _mm256_set1_ps(*ap.add(i));
            row[0] = _mm256_fmadd_ps(ai, b0, row[0]);
            row[1] = _mm256_fmadd_ps(ai, b1, row[1]);
        }
        ap = ap.add(6);
        bp = bp.add(16);
    }
    for (i, row) in acc.iter().enumerate() {
        _mm256_storeu_ps(c.add(i * ldc), row[0]);
        _mm256_storeu_ps(c.add(i * ldc + 8), row[1]);
    }
}

/// 6×8 NEON tile: twelve 4-lane q-register accumulators, `vfmaq_f32` per
/// (row, k-step). Same load-accumulate-store C discipline as AVX2.
#[cfg(target_arch = "aarch64")]
unsafe fn tile_neon(kc: usize, a: *const f32, b: *const f32, c: *mut f32, ldc: usize) {
    use std::arch::aarch64::*;
    let mut acc = [[vdupq_n_f32(0.0); 2]; 6];
    for (i, row) in acc.iter_mut().enumerate() {
        row[0] = vld1q_f32(c.add(i * ldc));
        row[1] = vld1q_f32(c.add(i * ldc + 4));
    }
    let mut ap = a;
    let mut bp = b;
    for _ in 0..kc {
        let b0 = vld1q_f32(bp);
        let b1 = vld1q_f32(bp.add(4));
        for (i, row) in acc.iter_mut().enumerate() {
            let ai = vdupq_n_f32(*ap.add(i));
            row[0] = vfmaq_f32(row[0], ai, b0);
            row[1] = vfmaq_f32(row[1], ai, b1);
        }
        ap = ap.add(6);
        bp = bp.add(8);
    }
    for (i, row) in acc.iter().enumerate() {
        vst1q_f32(c.add(i * ldc), row[0]);
        vst1q_f32(c.add(i * ldc + 4), row[1]);
    }
}

/// 4×8 portable tile: unrolled scalar mul+add (no fusion, no lane tricks)
/// in increasing-k order — bitwise identical to the reference kernel's
/// per-element chain, which is what the forced-fallback parity tests pin.
unsafe fn tile_portable(kc: usize, a: *const f32, b: *const f32, c: *mut f32, ldc: usize) {
    let mut acc = [[0.0f32; 8]; 4];
    for (i, row) in acc.iter_mut().enumerate() {
        for (j, v) in row.iter_mut().enumerate() {
            *v = *c.add(i * ldc + j);
        }
    }
    let mut ap = a;
    let mut bp = b;
    for _ in 0..kc {
        for (i, row) in acc.iter_mut().enumerate() {
            let ai = *ap.add(i);
            for (j, v) in row.iter_mut().enumerate() {
                *v += ai * *bp.add(j);
            }
        }
        ap = ap.add(4);
        bp = bp.add(8);
    }
    for (i, row) in acc.iter().enumerate() {
        for (j, v) in row.iter().enumerate() {
            *c.add(i * ldc + j) = *v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::RefBackend;
    use crate::rng::Rng;

    fn ref_gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], acc: bool, c: &mut [f32]) {
        RefBackend::new().gemm_slices(m, k, n, a, b, c, acc);
    }

    /// Portable packed kernel == reference kernel, bitwise, across shapes
    /// that hit the small-path, full tiles, edge tiles and multiple KC
    /// blocks.
    #[test]
    fn portable_is_bitwise_ref() {
        let be = SimdBackend::portable();
        let mut r = Rng::new(71);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (4, 8, 8),       // exact tiles, small path
            (7, 40, 130),    // edge tiles both dims
            (64, 300, 96),   // two KC blocks
            (97, 513, 200),  // everything ragged
        ] {
            let a: Vec<f32> = r.normal_vec(m * k, 1.0);
            let b: Vec<f32> = r.normal_vec(k * n, 1.0);
            for acc in [false, true] {
                let seed: Vec<f32> = r.normal_vec(m * n, 1.0);
                let mut want = seed.clone();
                ref_gemm(m, k, n, &a, &b, acc, &mut want);
                let mut got = seed;
                be.gemm_slices(m, k, n, &a, &b, &mut got, acc);
                assert_eq!(
                    got, want,
                    "portable != ref at ({m},{k},{n}) accumulate={acc}"
                );
            }
        }
    }

    /// The detected kernel (whatever this machine offers) stays within
    /// the pinned FMA-drift bound of the reference chain: ≤ max(4, √k)
    /// ULP measured at the output's max-magnitude scale. (Raw
    /// elementwise ULP distance is the wrong measure here — a k-step
    /// chain that cancels to near zero puts the same absolute drift
    /// hundreds of the tiny result's own ULPs away.)
    #[test]
    fn detected_kernel_close_to_ref() {
        let be = SimdBackend::new();
        let mut r = Rng::new(72);
        let (m, k, n) = (37, 220, 150);
        let a: Vec<f32> = r.normal_vec(m * k, 1.0);
        let b: Vec<f32> = r.normal_vec(k * n, 1.0);
        let mut want = vec![0.0f32; m * n];
        ref_gemm(m, k, n, &a, &b, false, &mut want);
        let mut got = vec![0.0f32; m * n];
        be.gemm_slices(m, k, n, &a, &b, &mut got, false);
        let scale = want.iter().fold(0.0f32, |mx, &x| mx.max(x.abs()));
        let unit = crate::testkit::ulp_at(scale) as f64;
        let worst = got
            .iter()
            .zip(&want)
            .map(|(&g, &w)| (g as f64 - w as f64).abs() / unit)
            .fold(0.0, f64::max);
        let bound = (k as f64).sqrt().max(4.0);
        assert!(
            worst <= bound,
            "simd({}) drifted {worst:.1} ULP-at-scale from ref (bound {bound:.1})",
            be.isa().name()
        );
    }

    #[test]
    fn portable_never_vectorized() {
        let be = SimdBackend::portable();
        assert!(!be.is_vectorized());
        assert_eq!(be.isa().name(), "portable");
        assert_eq!(be.name(), "simd");
        assert_eq!(be.describe(), "simd(portable)");
    }

    #[test]
    fn cpu_features_reports_something() {
        // shape only: non-empty, comma-joined lowercase tokens or "none"
        let f = cpu_features();
        assert!(!f.is_empty());
        assert!(f.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == ','));
    }

    /// Zero-sized operands are a no-op (and still honour !accumulate).
    #[test]
    fn degenerate_shapes() {
        let be = SimdBackend::new();
        let mut c = vec![7.0f32; 6];
        be.gemm_slices(2, 0, 3, &[], &[], &mut c, false);
        assert_eq!(c, vec![0.0; 6]);
        be.gemm_slices(0, 5, 0, &[], &[], &mut [], true);
    }
}
