//! Pluggable compute backends for the hot-path linear algebra.
//!
//! Every dense kernel MoLe runs in anger — the Aug-Conv **M′**⁻¹·**C**
//! construction, batched d2r morphing, the attack solves, the interpreter
//! engine's training/inference GEMMs — dispatches through the [`Backend`]
//! trait so implementations can be swapped without touching the callers:
//!
//! * [`RefBackend`] — the cache-blocked single-threaded scalar kernel
//!   (the original `linalg::gemm` code, moved here verbatim; the
//!   semantics oracle every other backend is tested against).
//! * [`SimdBackend`] — packed-panel GEMM microkernels with explicit
//!   f32-lane arithmetic (AVX2/FMA behind `is_x86_feature_detected!`,
//!   NEON on aarch64, a portable unrolled-scalar fallback everywhere —
//!   forced with `MOLE_SIMD=off`). Accumulation order is preserved, so
//!   the portable kernel is bitwise identical to [`RefBackend`] and the
//!   FMA kernels drift ≤ max(4, √k) ULP at the output's scale (fused
//!   rounding only, no reassociation).
//! * [`ParallelBackend`] — a pluggable inner kernel fanned out over row
//!   panels with `std::thread::scope`: `"parallel"` wraps the reference
//!   kernel (bit-for-bit with [`RefBackend`]), `"parallel+simd"` wraps
//!   [`SimdBackend`] (bit-for-bit with single-threaded simd).
//!
//! Selection: the first selection wins for the whole process. The `mole`
//! launcher resolves `--backend` flag > `MOLE_BACKEND` env var > the
//! `[backend]` config section and calls [`install`]; library/test use
//! that never installs falls back lazily at first GEMM to `MOLE_BACKEND`
//! or the auto default ([`auto`]: `parallel+simd` on multi-core machines
//! with a vector ISA, degrading to `parallel`, `simd`, or `ref`).
//! `linalg::gemm`/`gemm_into` delegate to [`active`], so code that does
//! not care about backends keeps calling the same free functions it
//! always did.
//!
//! Future backends (GPU, sharded serving) plug in by implementing the
//! trait and registering a name in [`by_name`].

mod parallel;
mod reference;
mod simd;

pub use parallel::ParallelBackend;
pub use reference::RefBackend;
pub use simd::{cpu_features, Isa, SimdBackend};

use crate::linalg::Lu;
use crate::tensor::Tensor;
use crate::{Error, Result};
use std::sync::OnceLock;

/// A dense-compute implementation. All methods must be semantically
/// equivalent to [`RefBackend`], and every implementation must keep the
/// per-element accumulation order (f32 addition is not associative).
/// The parity suite asserts exact agreement — bitwise for order-preserving
/// scalar kernels (parallel, simd-portable), and a pinned drift of
/// ≤ max(4, √k) ULP at the output's scale for FMA microkernels whose
/// only deviation is fused rounding.
pub trait Backend: Send + Sync {
    /// Short identifier ("ref", "parallel", "simd", "parallel+simd") for
    /// selection, logs and benches.
    fn name(&self) -> &'static str;

    /// Human-readable description with composition/ISA/thread detail
    /// (e.g. `parallel(8t)+simd(avx2)`) for logs and `BENCH_*.json`
    /// metadata. Defaults to [`Self::name`].
    fn describe(&self) -> String {
        self.name().to_string()
    }

    /// Raw-slice GEMM: row-major `c[m,n] = a[m,k]·b[k,n]` when
    /// `accumulate` is false, `c += a·b` when true.
    #[allow(clippy::too_many_arguments)]
    fn gemm_slices(
        &self,
        m: usize,
        k: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        accumulate: bool,
    );

    /// `C = A·B` for 2-D tensors.
    fn gemm(&self, a: &Tensor, b: &Tensor) -> Result<Tensor> {
        let (m, k, n) = gemm_dims(a, b)?;
        let mut c = Tensor::zeros(&[m, n]);
        // the buffer is freshly zeroed: accumulate=true skips a second
        // clearing pass over m*n with bitwise-identical results
        self.gemm_slices(m, k, n, a.data(), b.data(), c.data_mut(), true);
        Ok(c)
    }

    /// GEMM into an existing output tensor; `accumulate` selects
    /// `C += A·B` (true) vs `C = A·B` (false) explicitly.
    fn gemm_into(&self, a: &Tensor, b: &Tensor, c: &mut Tensor, accumulate: bool) -> Result<()> {
        let (m, k, n) = gemm_dims(a, b)?;
        if c.shape() != [m, n] {
            return Err(Error::Shape(format!(
                "gemm_into output {:?} != [{m}, {n}]",
                c.shape()
            )));
        }
        self.gemm_slices(m, k, n, a.data(), b.data(), c.data_mut(), accumulate);
        Ok(())
    }

    /// Batched block-diagonal apply — the morphing hot path (eq. 2/4).
    ///
    /// `rows` is [B, κ·q], `core` is [q, q]; each q-block of each row is
    /// multiplied by the shared core: `out_blk = in_blk · core`.
    ///
    /// Every q-block of every row is an independent row-vector × core
    /// product, and the blocks are contiguous in memory — so the whole
    /// batch is exactly one `[B·κ, q] × [q, q]` GEMM over the same
    /// buffers. The default dispatches through the backend's **own**
    /// [`Backend::gemm_slices`] microkernel (parallel/SIMD backends get
    /// their fan-out and lanes for free; no backend silently drops to a
    /// scalar single-threaded path).
    fn apply_blockdiag(&self, rows: &Tensor, core: &Tensor) -> Result<Tensor> {
        let (b, q, kappa) = blockdiag_dims(rows, core)?;
        let mut out = Tensor::zeros(&[b, rows.shape()[1]]);
        // out is freshly zeroed: accumulate=true skips a second clearing
        // pass with bitwise-identical results
        self.gemm_slices(b * kappa, q, q, rows.data(), core.data(), out.data_mut(), true);
        Ok(out)
    }

    /// Linear solve through an existing LU decomposition (the D-T pair
    /// attack and condition estimation paths).
    fn lu_solve(&self, lu: &Lu, rhs: &[f32]) -> Result<Vec<f32>> {
        lu.solve(rhs)
    }
}

/// Validate GEMM operand shapes, returning (m, k, n).
pub(crate) fn gemm_dims(a: &Tensor, b: &Tensor) -> Result<(usize, usize, usize)> {
    if a.ndim() != 2 || b.ndim() != 2 {
        return Err(Error::Shape("gemm wants 2-D tensors".into()));
    }
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    if k != k2 {
        return Err(Error::Shape(format!(
            "gemm inner dims mismatch: [{m},{k}] x [{k2},{n}]"
        )));
    }
    Ok((m, k, n))
}

/// Validate block-diagonal operand shapes, returning (batch, q, kappa).
pub(crate) fn blockdiag_dims(rows: &Tensor, core: &Tensor) -> Result<(usize, usize, usize)> {
    if rows.ndim() != 2 || core.ndim() != 2 || core.shape()[0] != core.shape()[1] {
        return Err(Error::Shape(format!(
            "apply_blockdiag wants rows [B, d] and a square core, got {:?} / {:?}",
            rows.shape(),
            core.shape()
        )));
    }
    let q = core.shape()[0];
    let d = rows.shape()[1];
    if q == 0 || d % q != 0 {
        return Err(Error::Shape(format!(
            "apply_blockdiag: core size {q} does not divide row length {d}"
        )));
    }
    Ok((rows.shape()[0], q, d / q))
}

static ACTIVE: OnceLock<Box<dyn Backend>> = OnceLock::new();

/// The process-wide backend. First use wins: [`install`] (config), the
/// `MOLE_BACKEND` env var, or the auto default.
pub fn active() -> &'static dyn Backend {
    ACTIVE
        .get_or_init(|| match std::env::var("MOLE_BACKEND") {
            Ok(name) => by_name(&name, 0).unwrap_or_else(|_| {
                crate::logging::warn(&format!(
                    "MOLE_BACKEND={name:?} is not a backend; using auto"
                ));
                auto()
            }),
            Err(_) => auto(),
        })
        .as_ref()
}

/// Install the process-wide backend from a config selection. Returns an
/// error for unknown names; if a backend was already activated (first
/// GEMM already ran) the existing one is kept — including its thread
/// count — and the ignored request is logged.
pub fn install(kind: &str, threads: usize) -> Result<()> {
    let chosen = by_name(kind, threads)?;
    let name = chosen.name();
    if ACTIVE.set(chosen).is_err() {
        crate::logging::warn(&format!(
            "backend {name:?} (threads={threads}) requested but {:?} was already \
             activated; request ignored",
            active().name()
        ));
    }
    Ok(())
}

/// Construct a backend by name:
/// "ref" | "parallel" | "simd" | "parallel+simd" | "auto".
/// `threads` is the worker count for parallel backends (0 = one per core).
/// Unknown names — including mistyped composites like "parallel+gpu" —
/// are hard errors, never a silent fall-through to auto.
pub fn by_name(kind: &str, threads: usize) -> Result<Box<dyn Backend>> {
    match kind {
        "ref" | "reference" | "single" => Ok(Box::new(RefBackend::new())),
        "parallel" | "par" => Ok(Box::new(ParallelBackend::new(threads))),
        "simd" => Ok(Box::new(SimdBackend::new())),
        "parallel+simd" | "par+simd" | "simd+parallel" => {
            Ok(Box::new(ParallelBackend::with_simd(threads)))
        }
        "auto" | "" => Ok(auto()),
        other if other.contains('+') => Err(Error::Config(format!(
            "unknown composite backend {other:?} (the only composite is \"parallel+simd\")"
        ))),
        other => Err(Error::Config(format!(
            "unknown backend {other:?} (expected ref|parallel|simd|parallel+simd|auto)"
        ))),
    }
}

/// The automatic default: row-parallel over the SIMD microkernel on
/// multi-core machines with a vector ISA, degrading to plain `parallel`
/// (no vector ISA, or `MOLE_SIMD=off`), single-threaded `simd`
/// (one core, vector ISA), or `ref` (neither).
pub fn auto() -> Box<dyn Backend> {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let simd = SimdBackend::new();
    match (cores > 1, simd.is_vectorized()) {
        (true, true) => Box::new(ParallelBackend::over_simd(0, simd)),
        (true, false) => Box::new(ParallelBackend::new(0)),
        (false, true) => Box::new(simd),
        (false, false) => Box::new(RefBackend::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for kk in 0..k {
                    s += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                }
                c[i * n + j] = s as f32;
            }
        }
        c
    }

    fn backends() -> Vec<Box<dyn Backend>> {
        vec![
            Box::new(RefBackend::new()),
            Box::new(ParallelBackend::new(0)),
            Box::new(ParallelBackend::new(3)),
            Box::new(SimdBackend::new()),
            Box::new(SimdBackend::portable()),
            Box::new(ParallelBackend::with_simd(0)),
            Box::new(ParallelBackend::over_simd(3, SimdBackend::portable())),
        ]
    }

    #[test]
    fn both_backends_match_naive() {
        let mut r = Rng::new(2);
        for be in backends() {
            for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 7), (17, 33, 9), (70, 300, 130)] {
                let a: Vec<f32> = r.normal_vec(m * k, 1.0);
                let b: Vec<f32> = r.normal_vec(k * n, 1.0);
                let want = naive(m, k, n, &a, &b);
                let mut got = vec![0.0f32; m * n];
                be.gemm_slices(m, k, n, &a, &b, &mut got, false);
                for (g, w) in got.iter().zip(&want) {
                    assert!(
                        (g - w).abs() < 1e-3 + 1e-4 * w.abs(),
                        "{}: {g} vs {w}",
                        be.name()
                    );
                }
            }
        }
    }

    #[test]
    fn accumulate_flag_is_explicit() {
        for be in backends() {
            let a = Tensor::full(&[2, 2], 1.0);
            let b = Tensor::eye(2);
            let mut c = Tensor::full(&[2, 2], 10.0);
            be.gemm_into(&a, &b, &mut c, true).unwrap();
            assert_eq!(c.data(), &[11.0, 11.0, 11.0, 11.0], "{} acc", be.name());
            be.gemm_into(&a, &b, &mut c, false).unwrap();
            assert_eq!(c.data(), &[1.0, 1.0, 1.0, 1.0], "{} overwrite", be.name());
        }
    }

    #[test]
    fn gemm_shape_errors() {
        for be in backends() {
            let a = Tensor::zeros(&[2, 3]);
            let bad = Tensor::zeros(&[4, 5]);
            assert!(be.gemm(&a, &bad).is_err());
            let b = Tensor::zeros(&[3, 5]);
            assert_eq!(be.gemm(&a, &b).unwrap().shape(), &[2, 5]);
            let mut small = Tensor::zeros(&[2, 4]);
            assert!(be.gemm_into(&a, &b, &mut small, false).is_err());
        }
    }

    #[test]
    fn blockdiag_matches_full_gemm() {
        let mut r = Rng::new(5);
        let (bsz, q, kappa) = (3usize, 8usize, 4usize);
        let rows = Tensor::new(&[bsz, q * kappa], r.normal_vec(bsz * q * kappa, 1.0)).unwrap();
        let core = Tensor::new(&[q, q], r.normal_vec(q * q, 1.0)).unwrap();
        // dense equivalent: block-diagonal matrix multiply
        let mut full = Tensor::zeros(&[q * kappa, q * kappa]);
        for blk in 0..kappa {
            for i in 0..q {
                for j in 0..q {
                    full.set2(blk * q + i, blk * q + j, core.at2(i, j));
                }
            }
        }
        let reference = RefBackend::new().gemm(&rows, &full).unwrap();
        for be in backends() {
            let got = be.apply_blockdiag(&rows, &core).unwrap();
            assert!(
                got.allclose(&reference, 1e-4, 1e-4),
                "{} blockdiag mismatch",
                be.name()
            );
        }
    }

    #[test]
    fn blockdiag_shape_errors() {
        let be = RefBackend::new();
        let rows = Tensor::zeros(&[2, 10]);
        let core = Tensor::zeros(&[3, 3]); // 3 does not divide 10
        assert!(be.apply_blockdiag(&rows, &core).is_err());
        let rect = Tensor::zeros(&[2, 5]);
        assert!(be.apply_blockdiag(&rows, &rect).is_err());
    }

    #[test]
    fn by_name_selection() {
        assert_eq!(by_name("ref", 0).unwrap().name(), "ref");
        assert_eq!(by_name("parallel", 2).unwrap().name(), "parallel");
        assert_eq!(by_name("simd", 0).unwrap().name(), "simd");
        assert_eq!(by_name("parallel+simd", 2).unwrap().name(), "parallel+simd");
        assert_eq!(by_name("par+simd", 0).unwrap().name(), "parallel+simd");
        assert!(by_name("gpu", 0).is_err());
        let _ = by_name("auto", 0).unwrap();
        // active() is callable and stable
        assert_eq!(active().name(), active().name());
    }

    /// Mistyped composite names are hard, typed errors — never a silent
    /// fall-through to the auto default.
    #[test]
    fn unknown_composites_rejected() {
        for bad in ["parallel+gpu", "simd+avx2", "ref+simd", "parallel+"] {
            let err = by_name(bad, 0).unwrap_err().to_string();
            assert!(
                err.contains("composite") && err.contains("parallel+simd"),
                "{bad}: unexpected error {err:?}"
            );
        }
        let err = by_name("quantum", 0).unwrap_err().to_string();
        assert!(err.contains("ref|parallel|simd|parallel+simd|auto"), "{err}");
    }

    /// The trait-default blockdiag must dispatch through the backend's
    /// OWN gemm microkernel — a backend that only implements
    /// `gemm_slices` sees the call (this is what keeps parallel/SIMD
    /// backends from silently degrading to a scalar path).
    #[test]
    fn default_blockdiag_uses_own_gemm_kernel() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        struct Counting {
            calls: AtomicUsize,
        }
        impl Backend for Counting {
            fn name(&self) -> &'static str {
                "counting"
            }
            fn gemm_slices(
                &self,
                m: usize,
                k: usize,
                n: usize,
                a: &[f32],
                b: &[f32],
                c: &mut [f32],
                accumulate: bool,
            ) {
                self.calls.fetch_add(1, Ordering::Relaxed);
                RefBackend::new().gemm_slices(m, k, n, a, b, c, accumulate);
            }
        }

        let be = Counting { calls: AtomicUsize::new(0) };
        let mut r = Rng::new(6);
        let rows = Tensor::new(&[3, 32], r.normal_vec(96, 1.0)).unwrap();
        let core = Tensor::new(&[8, 8], r.normal_vec(64, 1.0)).unwrap();
        let got = be.apply_blockdiag(&rows, &core).unwrap();
        assert_eq!(be.calls.load(Ordering::Relaxed), 1, "blockdiag bypassed gemm_slices");
        // and the flattened [B·κ, q] GEMM is the same computation
        let want = RefBackend::new().apply_blockdiag(&rows, &core).unwrap();
        assert_eq!(got, want);
    }
}
