//! Row-panel parallel backend: an inner GEMM kernel fanned out over
//! contiguous row chunks with `std::thread::scope` — no thread pool, no
//! extra dependencies. Rows of C are written by exactly one thread each
//! and every row is computed by the identical inner kernel with the
//! identical accumulation order, so outputs are bitwise identical to
//! running that inner kernel single-threaded.
//!
//! The inner kernel is pluggable: the original cache-blocked scalar
//! kernel ([`super::RefBackend`]'s, name `"parallel"`) or the packed-panel
//! SIMD kernel ([`super::SimdBackend`], name `"parallel+simd"` — the
//! [`super::auto`] default on multi-core machines with a vector ISA).

use super::reference::gemm_kernel;
use super::{Backend, SimdBackend};

/// Below this many multiply-accumulates the scoped-thread setup costs more
/// than it saves; fall through to the single-threaded inner kernel.
const MIN_PAR_FLOPS: usize = 1 << 18;

/// The per-thread GEMM kernel a [`ParallelBackend`] fans out.
#[derive(Debug, Clone, Copy)]
enum Inner {
    /// The reference cache-blocked scalar kernel.
    Blocked,
    /// The packed-panel SIMD kernel (whatever ISA it detected).
    Simd(SimdBackend),
}

impl Inner {
    #[allow(clippy::too_many_arguments)]
    fn gemm(self, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32], acc: bool) {
        match self {
            Inner::Blocked => gemm_kernel(m, k, n, a, b, c, acc),
            Inner::Simd(s) => s.gemm_slices(m, k, n, a, b, c, acc),
        }
    }
}

/// Multi-threaded backend over a pluggable inner kernel.
#[derive(Debug, Clone, Copy)]
pub struct ParallelBackend {
    threads: usize,
    inner: Inner,
}

impl ParallelBackend {
    /// Row-parallel over the reference scalar kernel (the historical
    /// `"parallel"` backend). `threads = 0` means one worker per core.
    pub fn new(threads: usize) -> Self {
        ParallelBackend { threads, inner: Inner::Blocked }
    }

    /// Row-parallel over the auto-detected SIMD kernel
    /// (`"parallel+simd"`).
    pub fn with_simd(threads: usize) -> Self {
        Self::over_simd(threads, SimdBackend::new())
    }

    /// Row-parallel over an explicit SIMD backend — lets tests force the
    /// portable microkernel deterministically.
    pub fn over_simd(threads: usize, simd: SimdBackend) -> Self {
        ParallelBackend { threads, inner: Inner::Simd(simd) }
    }

    fn worker_count(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }
}

impl Backend for ParallelBackend {
    fn name(&self) -> &'static str {
        match self.inner {
            Inner::Blocked => "parallel",
            Inner::Simd(_) => "parallel+simd",
        }
    }

    fn describe(&self) -> String {
        let t = self.worker_count();
        match self.inner {
            Inner::Blocked => format!("parallel({t}t)"),
            Inner::Simd(s) => format!("parallel({t}t)+{}", s.describe()),
        }
    }

    fn gemm_slices(
        &self,
        m: usize,
        k: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        accumulate: bool,
    ) {
        let inner = self.inner;
        let workers = self.worker_count().min(m);
        if workers <= 1 || m * k * n < MIN_PAR_FLOPS {
            inner.gemm(m, k, n, a, b, c, accumulate);
            return;
        }
        let rows_per = m.div_ceil(workers);
        std::thread::scope(|s| {
            let mut row0 = 0usize;
            for chunk in c.chunks_mut(rows_per * n) {
                let rows = chunk.len() / n;
                let a_part = &a[row0 * k..(row0 + rows) * k];
                s.spawn(move || inner.gemm(rows, k, n, a_part, b, chunk, accumulate));
                row0 += rows;
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::RefBackend;
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    /// Parallel output must be *bitwise* equal to the reference kernel:
    /// each row is computed by the same code with the same accumulation
    /// order, just on a different thread.
    #[test]
    fn bitwise_identical_to_ref() {
        let mut r = Rng::new(9);
        let (m, k, n) = (37, 64, 129);
        let a = Tensor::new(&[m, k], r.normal_vec(m * k, 1.0)).unwrap();
        let b = Tensor::new(&[k, n], r.normal_vec(k * n, 1.0)).unwrap();
        let want = RefBackend::new().gemm(&a, &b).unwrap();
        for threads in [2usize, 3, 8] {
            let got = ParallelBackend::new(threads).gemm(&a, &b).unwrap();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    /// Same bitwise guarantee for the SIMD inner kernel: the row split
    /// must be invisible.
    #[test]
    fn simd_inner_bitwise_identical_to_simd() {
        let mut r = Rng::new(19);
        let (m, k, n) = (41, 128, 260);
        let a = Tensor::new(&[m, k], r.normal_vec(m * k, 1.0)).unwrap();
        let b = Tensor::new(&[k, n], r.normal_vec(k * n, 1.0)).unwrap();
        for simd in [SimdBackend::new(), SimdBackend::portable()] {
            let want = simd.gemm(&a, &b).unwrap();
            for threads in [2usize, 5] {
                let got = ParallelBackend::over_simd(threads, simd).gemm(&a, &b).unwrap();
                assert_eq!(got, want, "threads={threads} isa={}", simd.isa().name());
            }
        }
    }

    #[test]
    fn more_threads_than_rows() {
        let mut r = Rng::new(10);
        let a = Tensor::new(&[2, 600], r.normal_vec(1200, 1.0)).unwrap();
        let b = Tensor::new(&[600, 700], r.normal_vec(600 * 700, 1.0)).unwrap();
        let want = RefBackend::new().gemm(&a, &b).unwrap();
        let got = ParallelBackend::new(16).gemm(&a, &b).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn names_track_inner_kernel() {
        assert_eq!(ParallelBackend::new(2).name(), "parallel");
        assert_eq!(ParallelBackend::with_simd(2).name(), "parallel+simd");
        assert!(ParallelBackend::with_simd(2).describe().starts_with("parallel(2t)+simd("));
    }
}
