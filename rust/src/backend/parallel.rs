//! Row-panel parallel backend: the reference micro-kernel fanned out over
//! contiguous row chunks with `std::thread::scope` — no thread pool, no
//! extra dependencies. Rows of C are written by exactly one thread each
//! and every row is computed with the identical blocked accumulation
//! order as [`super::RefBackend`], so outputs are bitwise identical.

use super::reference::{blockdiag_rows, gemm_kernel};
use super::{blockdiag_dims, Backend};
use crate::tensor::Tensor;
use crate::Result;

/// Below this many multiply-accumulates the scoped-thread setup costs more
/// than it saves; fall through to the single-threaded kernel.
const MIN_PAR_FLOPS: usize = 1 << 18;

/// Multi-threaded backend over the reference kernel.
#[derive(Debug, Clone, Copy)]
pub struct ParallelBackend {
    threads: usize,
}

impl ParallelBackend {
    /// `threads = 0` means one worker per available core.
    pub fn new(threads: usize) -> Self {
        ParallelBackend { threads }
    }

    fn worker_count(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }
}

impl Backend for ParallelBackend {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn gemm_slices(
        &self,
        m: usize,
        k: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        accumulate: bool,
    ) {
        let workers = self.worker_count().min(m);
        if workers <= 1 || m * k * n < MIN_PAR_FLOPS {
            gemm_kernel(m, k, n, a, b, c, accumulate);
            return;
        }
        let rows_per = m.div_ceil(workers);
        std::thread::scope(|s| {
            let mut row0 = 0usize;
            for chunk in c.chunks_mut(rows_per * n) {
                let rows = chunk.len() / n;
                let a_part = &a[row0 * k..(row0 + rows) * k];
                s.spawn(move || gemm_kernel(rows, k, n, a_part, b, chunk, accumulate));
                row0 += rows;
            }
        });
    }

    fn apply_blockdiag(&self, rows: &Tensor, core: &Tensor) -> Result<Tensor> {
        let (bsz, q, kappa) = blockdiag_dims(rows, core)?;
        let d = rows.shape()[1];
        let mut out = Tensor::zeros(&[bsz, d]);
        let workers = self.worker_count().min(bsz);
        if workers <= 1 || bsz * kappa * q * q < MIN_PAR_FLOPS {
            blockdiag_rows(rows.data(), core.data(), q, d, out.data_mut());
            return Ok(out);
        }
        let per = bsz.div_ceil(workers);
        let src = rows.data();
        let core_data = core.data();
        std::thread::scope(|s| {
            let mut b0 = 0usize;
            for chunk in out.data_mut().chunks_mut(per * d) {
                let nb = chunk.len() / d;
                let src_part = &src[b0 * d..(b0 + nb) * d];
                s.spawn(move || blockdiag_rows(src_part, core_data, q, d, chunk));
                b0 += nb;
            }
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::RefBackend;
    use crate::rng::Rng;

    /// Parallel output must be *bitwise* equal to the reference kernel:
    /// each row is computed by the same code with the same accumulation
    /// order, just on a different thread.
    #[test]
    fn bitwise_identical_to_ref() {
        let mut r = Rng::new(9);
        let (m, k, n) = (37, 64, 129);
        let a = Tensor::new(&[m, k], r.normal_vec(m * k, 1.0)).unwrap();
        let b = Tensor::new(&[k, n], r.normal_vec(k * n, 1.0)).unwrap();
        let want = RefBackend::new().gemm(&a, &b).unwrap();
        for threads in [2usize, 3, 8] {
            let got = ParallelBackend::new(threads).gemm(&a, &b).unwrap();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn more_threads_than_rows() {
        let mut r = Rng::new(10);
        let a = Tensor::new(&[2, 600], r.normal_vec(1200, 1.0)).unwrap();
        let b = Tensor::new(&[600, 700], r.normal_vec(600 * 700, 1.0)).unwrap();
        let want = RefBackend::new().gemm(&a, &b).unwrap();
        let got = ParallelBackend::new(16).gemm(&a, &b).unwrap();
        assert_eq!(got, want);
    }
}
