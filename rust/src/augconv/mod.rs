//! Augmented Convolutional layer (paper §3.3).
//!
//! The provider combines the inverse morphing matrix with the developer's
//! first-layer convolution matrix:  **C**^ac = **M**⁻¹ · **C**  (so that
//! T^r·C^ac = D^r·C, eq. 5), then applies *feature channel randomization*:
//! the β groups of n² contiguous columns are shuffled with a secret
//! permutation — the `rand()` that defeats the reverse-convolution attack.
//!
//! Because **M**⁻¹ is block diagonal (core **M′**⁻¹), the product is
//! computed block-row-wise: κ GEMMs of [q, q] × [q, βn²] instead of one
//! (αm²)² multiplication.

use crate::backend::Backend;
use crate::d2r;
use crate::morph::MorphKey;
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::{Error, Geometry, Result};

/// A constructed Aug-Conv layer: the matrix the provider ships to the
/// developer, plus the permuted bias. Contains **no key material** — this
/// is exactly the artifact the HBC adversary sees (§4.1).
#[derive(Debug, Clone)]
pub struct AugConvLayer {
    geometry: Geometry,
    /// C^ac, [αm², βn²].
    matrix: Tensor,
    /// First-layer bias in the *shuffled* channel order, [β].
    bias: Vec<f32>,
}

/// The provider-side secret accompanying an [`AugConvLayer`]: the channel
/// permutation (stored in the key vault next to the morph key).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChannelPerm {
    perm: Vec<usize>,
}

impl ChannelPerm {
    /// Fisher–Yates permutation of the β output channels.
    pub fn generate(beta: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xC0FFEE);
        Self { perm: rng.permutation(beta) }
    }

    pub fn from_vec(perm: Vec<usize>) -> Result<Self> {
        let mut seen = vec![false; perm.len()];
        for &p in &perm {
            if p >= perm.len() || seen[p] {
                return Err(Error::Key("invalid channel permutation".into()));
            }
            seen[p] = true;
        }
        Ok(Self { perm })
    }

    pub fn as_slice(&self) -> &[usize] {
        &self.perm
    }

    pub fn beta(&self) -> usize {
        self.perm.len()
    }

    /// Inverse permutation (maps shuffled channel → original channel).
    pub fn inverse(&self) -> ChannelPerm {
        let mut inv = vec![0usize; self.perm.len()];
        for (i, &p) in self.perm.iter().enumerate() {
            inv[p] = i;
        }
        ChannelPerm { perm: inv }
    }

    /// Apply to a feature tensor [B, β, n, n]: output channel g takes
    /// original channel perm[g] — matching the column-group shuffle.
    pub fn apply_features(&self, f: &Tensor) -> Result<Tensor> {
        if f.ndim() != 4 || f.shape()[1] != self.perm.len() {
            return Err(Error::Shape(format!(
                "apply_features wants [B, {}, n, n], got {:?}",
                self.perm.len(),
                f.shape()
            )));
        }
        let (b, c, h, w) = (f.shape()[0], f.shape()[1], f.shape()[2], f.shape()[3]);
        let mut out = Tensor::zeros(&[b, c, h, w]);
        let plane = h * w;
        for bi in 0..b {
            for g in 0..c {
                let src = &f.data()[(bi * c + self.perm[g]) * plane..][..plane];
                out.data_mut()[(bi * c + g) * plane..][..plane].copy_from_slice(src);
            }
        }
        Ok(out)
    }
}

/// Build an Aug-Conv layer from the developer's first-layer weights and
/// the provider's morph key (the full §3.3 pipeline).
///
/// * `w1` — OIHW kernel [β, α, p, p] *received from the developer* (Fig. 1:
///   the developer pre-trains on a public dataset and sends layer 1).
/// * `b1` — first-layer bias [β].
/// * `key` — the provider's secret morph key.
/// * `perm` — the provider's secret channel permutation.
pub fn build_aug_conv(
    w1: &Tensor,
    b1: &[f32],
    key: &MorphKey,
    perm: &ChannelPerm,
) -> Result<AugConvLayer> {
    let g = *key.geometry();
    if b1.len() != g.beta || perm.beta() != g.beta {
        return Err(Error::Shape(format!(
            "bias/perm size {} / {} != beta {}",
            b1.len(),
            perm.beta(),
            g.beta
        )));
    }
    let c = d2r::build_c_matrix(w1, &g)?;
    let shuffled = build_aug_conv_from_c(&c, key, perm)?;
    // permute the bias with the same order
    let bias: Vec<f32> = perm.as_slice().iter().map(|&p| b1[p]).collect();
    Ok(AugConvLayer { geometry: g, matrix: shuffled, bias })
}

/// Core combination step, exposed for the attack harness: C^ac from an
/// existing C matrix (block-row GEMM + column-group shuffle), on the
/// process-wide active backend.
pub fn build_aug_conv_from_c(c: &Tensor, key: &MorphKey, perm: &ChannelPerm) -> Result<Tensor> {
    build_aug_conv_from_c_on(crate::backend::active(), c, key, perm)
}

/// [`build_aug_conv_from_c`] on an explicit backend (the hot-path bench
/// compares backends on exactly this build).
pub fn build_aug_conv_from_c_on(
    be: &dyn Backend,
    c: &Tensor,
    key: &MorphKey,
    perm: &ChannelPerm,
) -> Result<Tensor> {
    let g = *key.geometry();
    if c.shape() != [g.d_len(), g.f_len()] {
        return Err(Error::Shape(format!(
            "C shape {:?} != [{}, {}]",
            c.shape(),
            g.d_len(),
            g.f_len()
        )));
    }
    let q = key.q();
    let f_len = g.f_len();
    let mut prod = Tensor::zeros(&[g.d_len(), f_len]);
    // M^{-1} is block-diagonal: row-block k of the product is
    // M'^{-1} x C[kq..(k+1)q, :]
    let core_inv = key.core_inv();
    for blk in 0..key.kappa() {
        let a = core_inv.data();
        let b = &c.data()[blk * q * f_len..(blk + 1) * q * f_len];
        let out = &mut prod.data_mut()[blk * q * f_len..(blk + 1) * q * f_len];
        // prod is freshly zeroed: accumulate=true avoids re-clearing
        be.gemm_slices(q, q, f_len, a, b, out, true);
    }
    // feature channel randomization: shuffle the beta column groups
    let n2 = g.n() * g.n();
    let mut shuffled = Tensor::zeros(&[g.d_len(), f_len]);
    for row in 0..g.d_len() {
        let src = prod.row(row);
        let dst = shuffled.row_mut(row);
        for grp in 0..g.beta {
            let s = perm.as_slice()[grp];
            dst[grp * n2..(grp + 1) * n2].copy_from_slice(&src[s * n2..(s + 1) * n2]);
        }
    }
    Ok(shuffled)
}

impl AugConvLayer {
    /// Assemble from parts (e.g. after receiving over the wire).
    pub fn from_parts(geometry: Geometry, matrix: Tensor, bias: Vec<f32>) -> Result<Self> {
        if matrix.shape() != [geometry.d_len(), geometry.f_len()] {
            return Err(Error::Shape(format!(
                "C^ac shape {:?} != [{}, {}]",
                matrix.shape(),
                geometry.d_len(),
                geometry.f_len()
            )));
        }
        if bias.len() != geometry.beta {
            return Err(Error::Shape("bias size mismatch".into()));
        }
        Ok(Self { geometry, matrix, bias })
    }

    pub fn geometry(&self) -> &Geometry {
        &self.geometry
    }

    /// The C^ac matrix [αm², βn²].
    pub fn matrix(&self) -> &Tensor {
        &self.matrix
    }

    /// The (permuted) first-layer bias [β].
    pub fn bias(&self) -> &[f32] {
        &self.bias
    }

    /// Forward on morphed rows: F = reshape(T^r · C^ac) + bias — the pure
    /// rust reference for what the AOT artifact computes (eq. 5).
    pub fn forward(&self, t_rows: &Tensor) -> Result<Tensor> {
        let g = &self.geometry;
        if t_rows.ndim() != 2 || t_rows.shape()[1] != g.d_len() {
            return Err(Error::Shape(format!(
                "forward wants [B, {}], got {:?}",
                g.d_len(),
                t_rows.shape()
            )));
        }
        let f_r = crate::linalg::gemm(t_rows, &self.matrix)?;
        let b = t_rows.shape()[0];
        let n = g.n();
        let mut f = f_r.reshape(&[b, g.beta, n, n])?;
        for bi in 0..b {
            for ch in 0..g.beta {
                let bias = self.bias[ch];
                let plane = &mut f.data_mut()[(bi * g.beta + ch) * n * n..][..n * n];
                for v in plane {
                    *v += bias;
                }
            }
        }
        Ok(f)
    }

    /// Transfer size in bytes (the §4.3 data-transmission overhead:
    /// O_data = (αm²)·(βn²) matrix elements, plus the bias).
    pub fn transfer_bytes(&self) -> usize {
        (self.matrix.numel() + self.bias.len()) * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::conv2d_same;

    fn setup(kappa: usize, seed: u64) -> (Geometry, Tensor, Vec<f32>, MorphKey, ChannelPerm) {
        let g = Geometry::SMALL;
        let mut rng = Rng::new(seed);
        let w1 = Tensor::new(
            &[g.beta, g.alpha, g.p, g.p],
            rng.normal_vec(g.beta * g.alpha * g.p * g.p, 0.5),
        )
        .unwrap();
        let b1: Vec<f32> = rng.normal_vec(g.beta, 0.1);
        let key = MorphKey::generate(g, kappa, seed).unwrap();
        let perm = ChannelPerm::generate(g.beta, seed);
        (g, w1, b1, key, perm)
    }

    #[test]
    fn perm_validation() {
        assert!(ChannelPerm::from_vec(vec![0, 2, 1]).is_ok());
        assert!(ChannelPerm::from_vec(vec![0, 0, 1]).is_err());
        assert!(ChannelPerm::from_vec(vec![0, 3, 1]).is_err());
    }

    #[test]
    fn perm_inverse_roundtrip() {
        let p = ChannelPerm::generate(16, 9);
        let inv = p.inverse();
        let mut rng = Rng::new(0);
        let f = Tensor::new(&[2, 16, 3, 3], rng.normal_vec(2 * 16 * 9, 1.0)).unwrap();
        let shuffled = p.apply_features(&f).unwrap();
        let back = inv.apply_features(&shuffled).unwrap();
        assert_eq!(back, f);
    }

    /// Paper eq. 5: T^r·C^ac equals the original conv features (up to the
    /// secret channel permutation) — the central equivalence of MoLe.
    #[test]
    fn equivalence_theorem() {
        for (kappa, seed) in [(16usize, 1u64), (3, 2), (1, 3)] {
            let (g, w1, b1, key, perm) = setup(kappa, seed);
            let layer = build_aug_conv(&w1, &b1, &key, &perm).unwrap();

            let mut rng = Rng::new(seed + 100);
            let x =
                Tensor::new(&[2, g.alpha, g.m, g.m], rng.normal_vec(2 * g.d_len(), 1.0))
                    .unwrap();
            // provider: morph
            let d_rows = d2r::unroll(x.clone()).unwrap();
            let t_rows = key.morph(&d_rows).unwrap();
            // developer: aug-conv forward on morphed data
            let f_aug = layer.forward(&t_rows).unwrap();
            // ground truth: direct conv on original data, channels permuted
            let f_plain = conv2d_same(&x, &w1, Some(&b1)).unwrap();
            let f_expected = perm.apply_features(&f_plain).unwrap();
            assert!(
                f_aug.allclose(&f_expected, 5e-2, 5e-2),
                "kappa={kappa}: equivalence violated (max diff {})",
                f_aug.max_abs_diff(&f_expected).unwrap()
            );
        }
    }

    /// Without the right key the features are garbage — sanity check that
    /// the equivalence is not vacuous.
    #[test]
    fn wrong_key_breaks_equivalence() {
        let (g, w1, b1, key, perm) = setup(16, 5);
        let layer = build_aug_conv(&w1, &b1, &key, &perm).unwrap();
        let wrong_key = MorphKey::generate(g, 16, 999).unwrap();

        let mut rng = Rng::new(6);
        let x = Tensor::new(&[1, g.alpha, g.m, g.m], rng.normal_vec(g.d_len(), 1.0))
            .unwrap();
        let d_rows = d2r::unroll(x.clone()).unwrap();
        let t_wrong = wrong_key.morph(&d_rows).unwrap();
        let f_aug = layer.forward(&t_wrong).unwrap();
        let f_plain = conv2d_same(&x, &w1, Some(&b1)).unwrap();
        let f_expected = perm.apply_features(&f_plain).unwrap();
        assert!(
            !f_aug.allclose(&f_expected, 5e-2, 5e-2),
            "wrong morph key still produced equivalent features"
        );
    }

    #[test]
    fn bias_is_permuted() {
        let (_, w1, b1, key, perm) = setup(16, 7);
        let layer = build_aug_conv(&w1, &b1, &key, &perm).unwrap();
        for (g_idx, &src) in perm.as_slice().iter().enumerate() {
            assert_eq!(layer.bias()[g_idx], b1[src]);
        }
    }

    #[test]
    fn transfer_bytes_matches_odata() {
        let (g, w1, b1, key, perm) = setup(16, 8);
        let layer = build_aug_conv(&w1, &b1, &key, &perm).unwrap();
        // O_data: the whole C^ac = alpha*m^2 x beta*n^2 elements (§4.3)
        assert_eq!(
            layer.transfer_bytes(),
            (g.d_len() * g.f_len() + g.beta) * 4
        );
    }

    #[test]
    fn from_parts_validates() {
        let g = Geometry::SMALL;
        assert!(AugConvLayer::from_parts(g, Tensor::zeros(&[10, 10]), vec![0.0; 16]).is_err());
        assert!(AugConvLayer::from_parts(
            g,
            Tensor::zeros(&[g.d_len(), g.f_len()]),
            vec![0.0; 3]
        )
        .is_err());
        assert!(AugConvLayer::from_parts(
            g,
            Tensor::zeros(&[g.d_len(), g.f_len()]),
            vec![0.0; g.beta]
        )
        .is_ok());
    }
}
