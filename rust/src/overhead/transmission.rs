//! Transmission-overhead bench (paper §4.3, the 5.12 % figure) over the
//! **real** delivery plane.
//!
//! Three result rows, emitted as `BENCH_overhead.json` (schema
//! `mole-overhead-v1`, validated by `scripts/check_bench_schema.py`):
//!
//! 1. `cifar_vgg16_paper_formula` — the paper's analytic number: the
//!    one-off C^ac shipment under the paper's O_data = (αm²)² formula
//!    against the raw CIFAR dataset (60 000 × 3072 f32 rows), which is
//!    exactly 3072/60000 = **5.12 %** (see [`super`] for the audited-size
//!    discrepancy discussion).
//! 2. `delivery_measured` — an actual chunked, hash-manifested, striped
//!    transfer through [`crate::coordinator::delivery`] over an
//!    in-memory duplex pipe, with both directions byte-counted: the
//!    measured wire framing (frame headers, manifest, chunk requests)
//!    as a percentage on top of the raw payload.
//! 3. `cifar_vgg16_extrapolated` — (1) and (2) combined: what delivering
//!    the full morphed CIFAR corpus plus C^ac would put on the wire,
//!    raw·(1 + framing) + O_data·4 bytes.
//!
//! The probe payload scales down under `MOLE_BENCH_BUDGET_MS`
//! ([`crate::bench::short_budget`]) so the CI smoke lane stays fast.

use crate::bench;
use crate::coordinator::delivery::{self, ChunkStore, PullOptions, VecSink};
use crate::json::Value;
use crate::rng::Rng;
use crate::{Geometry, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// CIFAR-10 train+test images — the corpus behind the paper's 5.12 %.
pub const CIFAR_IMAGES: usize = 60_000;

/// One result row of `BENCH_overhead.json`.
#[derive(Debug, Clone)]
pub struct TransmissionRow {
    pub name: String,
    pub geometry: Option<String>,
    /// Payload bytes the developer actually needs.
    pub raw_bytes: u64,
    /// Bytes on the wire (or modeled on the wire) to deliver them.
    pub delivered_bytes: u64,
    /// `(delivered − raw) / raw`, percent.
    pub overhead_pct: f64,
    /// Measured delivery-plane framing share, percent.
    pub framing_pct: Option<f64>,
    /// The paper's analytic figure for this row, percent.
    pub paper_pct: Option<f64>,
    pub chunk_count: Option<u64>,
    pub stripes: Option<u64>,
}

impl TransmissionRow {
    fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Value::Str(self.name.clone()));
        if let Some(g) = &self.geometry {
            m.insert("geometry".into(), Value::Str(g.clone()));
        }
        m.insert("raw_bytes".into(), Value::Num(self.raw_bytes as f64));
        m.insert("delivered_bytes".into(), Value::Num(self.delivered_bytes as f64));
        m.insert("overhead_pct".into(), Value::Num(self.overhead_pct));
        if let Some(f) = self.framing_pct {
            m.insert("framing_pct".into(), Value::Num(f));
        }
        if let Some(p) = self.paper_pct {
            m.insert("paper_pct".into(), Value::Num(p));
        }
        if let Some(c) = self.chunk_count {
            m.insert("chunk_count".into(), Value::Num(c as f64));
        }
        if let Some(s) = self.stripes {
            m.insert("stripes".into(), Value::Num(s as f64));
        }
        Value::Obj(m)
    }
}

/// Byte counts of one measured delivery-plane transfer.
#[derive(Debug, Clone, Copy)]
pub struct MeasuredTransfer {
    pub raw_bytes: u64,
    pub wire_bytes_in: u64,
    pub wire_bytes_out: u64,
    pub chunk_count: u64,
    pub stripes: u64,
}

impl MeasuredTransfer {
    /// Wire bytes beyond the raw payload, percent: frame headers,
    /// manifest, chunk requests, the `DeliveryDone` close — both
    /// directions counted.
    pub fn framing_pct(&self) -> f64 {
        let wire = (self.wire_bytes_in + self.wire_bytes_out) as f64;
        (wire / self.raw_bytes as f64 - 1.0) * 100.0
    }
}

/// Run one real striped pull of `payload_bytes` of incompressible data
/// through the delivery plane (in-memory duplex pipes, one server
/// session per connection) and count every wire byte both ways. The
/// reassembled payload is verified bit-exact before the numbers are
/// trusted.
pub fn measure_framing(
    payload_bytes: usize,
    chunk_size: usize,
    stripes: usize,
) -> Result<MeasuredTransfer> {
    let mut rng = Rng::new(0x0512);
    let data: Vec<u8> = (0..payload_bytes).map(|_| rng.below(256) as u8).collect();
    let store =
        Arc::new(ChunkStore::from_bytes("overhead-probe", &data, chunk_size, false)?);

    let sink = VecSink::new(data.len());
    let connect = || -> Result<crate::testkit::net::Pipe> {
        let (client, mut server) = crate::testkit::net::pipe_pair();
        let store = store.clone();
        std::thread::spawn(move || {
            let _ = delivery::run_delivery_session(&mut server, &store);
        });
        Ok(client)
    };
    let report = delivery::pull(
        connect,
        &PullOptions { stripes, ..PullOptions::default() },
        |_, offset, raw| sink.put(offset, raw),
    )?;
    if sink.into_inner() != data {
        return Err(crate::Error::Runtime(
            "overhead probe: reassembled payload differs from source".into(),
        ));
    }
    Ok(MeasuredTransfer {
        raw_bytes: data.len() as u64,
        wire_bytes_in: report.bytes_in,
        wire_bytes_out: report.bytes_out,
        chunk_count: report.manifest.chunks.len() as u64,
        stripes: report.stripes as u64,
    })
}

/// Row 1: the paper's analytic 5.12 % at VGG-16/CIFAR geometry.
pub fn paper_row(images: usize) -> TransmissionRow {
    let g = Geometry::CIFAR_VGG16;
    let raw = (images * g.d_len() * 4) as u64;
    let extra = (super::paper_o_data_elements(&g) * 4) as u64;
    TransmissionRow {
        name: "cifar_vgg16_paper_formula".into(),
        geometry: Some("cifar_vgg16".into()),
        raw_bytes: raw,
        delivered_bytes: raw + extra,
        overhead_pct: extra as f64 / raw as f64 * 100.0,
        framing_pct: None,
        paper_pct: Some(5.12),
        chunk_count: None,
        stripes: None,
    }
}

/// Row 2: the measured delivery-plane framing.
pub fn measured_row(m: &MeasuredTransfer) -> TransmissionRow {
    let delivered = m.wire_bytes_in + m.wire_bytes_out;
    TransmissionRow {
        name: "delivery_measured".into(),
        geometry: None,
        raw_bytes: m.raw_bytes,
        delivered_bytes: delivered,
        overhead_pct: m.framing_pct(),
        framing_pct: Some(m.framing_pct()),
        paper_pct: None,
        chunk_count: Some(m.chunk_count),
        stripes: Some(m.stripes),
    }
}

/// Row 3: the paper's one-off C^ac cost plus the measured framing,
/// extrapolated to the full morphed CIFAR corpus.
pub fn extrapolated_row(images: usize, framing_pct: f64) -> TransmissionRow {
    let g = Geometry::CIFAR_VGG16;
    let raw = (images * g.d_len() * 4) as u64;
    let extra = (super::paper_o_data_elements(&g) * 4) as u64;
    let delivered = raw as f64 * (1.0 + framing_pct / 100.0) + extra as f64;
    TransmissionRow {
        name: "cifar_vgg16_extrapolated".into(),
        geometry: Some("cifar_vgg16".into()),
        raw_bytes: raw,
        delivered_bytes: delivered as u64,
        overhead_pct: (delivered - raw as f64) / raw as f64 * 100.0,
        framing_pct: Some(framing_pct),
        paper_pct: Some(5.12),
        chunk_count: None,
        stripes: None,
    }
}

/// The full three-row report.
#[derive(Debug, Clone)]
pub struct TransmissionReport {
    pub rows: Vec<TransmissionRow>,
}

impl TransmissionReport {
    /// Measure and assemble: one real transfer, then the analytic and
    /// extrapolated rows around it.
    pub fn analyze(payload_bytes: usize, chunk_size: usize, stripes: usize) -> Result<Self> {
        let m = measure_framing(payload_bytes, chunk_size, stripes)?;
        Ok(Self {
            rows: vec![
                paper_row(CIFAR_IMAGES),
                measured_row(&m),
                extrapolated_row(CIFAR_IMAGES, m.framing_pct()),
            ],
        })
    }

    /// The full document (schema `mole-overhead-v1`); same envelope shape
    /// as [`crate::bench::Report`] so tooling shares the cpu/threads keys.
    pub fn to_json(&self) -> Value {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut cpu = BTreeMap::new();
        cpu.insert("arch".into(), Value::Str(std::env::consts::ARCH.to_string()));
        cpu.insert("cores".into(), Value::Num(cores as f64));
        cpu.insert("features".into(), Value::Str(crate::backend::cpu_features()));
        let mut top = BTreeMap::new();
        top.insert("schema".into(), Value::Str("mole-overhead-v1".into()));
        top.insert("bench".into(), Value::Str("overhead".into()));
        top.insert("threads".into(), Value::Num(cores as f64));
        top.insert("cpu".into(), Value::Obj(cpu));
        top.insert(
            "results".into(),
            Value::Arr(self.rows.iter().map(|r| r.to_json()).collect()),
        );
        Value::Obj(top)
    }

    /// Write `BENCH_overhead.json` into [`bench::out_dir`]; returns the path.
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        let path = bench::out_dir().join("BENCH_overhead.json");
        std::fs::write(&path, crate::json::write(&self.to_json()) + "\n")?;
        Ok(path)
    }

    pub fn print(&self) {
        for r in &self.rows {
            let extras = [
                r.framing_pct.map(|f| format!("framing {f:.3}%")),
                r.paper_pct.map(|p| format!("paper {p:.2}%")),
                r.chunk_count.map(|c| format!("{c} chunks")),
                r.stripes.map(|s| format!("{s} stripe(s)")),
            ]
            .into_iter()
            .flatten()
            .collect::<Vec<_>>()
            .join(", ");
            println!(
                "  {:<28} raw {:>12} B -> wire {:>12} B  overhead {:>7.3}%  [{}]",
                r.name, r.raw_bytes, r.delivered_bytes, r.overhead_pct, extras
            );
        }
    }
}

/// Probe payload for the bench binary: 4 MiB normally, 256 KiB under
/// the CI smoke budget.
pub fn default_probe_bytes() -> usize {
    if bench::short_budget() {
        256 * 1024
    } else {
        4 * 1024 * 1024
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tentpole acceptance number: the paper row is exactly
    /// 3072/60000 = 5.12 % at VGG-16/CIFAR geometry.
    #[test]
    fn paper_row_pins_five_point_one_two() {
        let r = paper_row(CIFAR_IMAGES);
        assert!((r.overhead_pct - 5.12).abs() < 1e-9, "got {}", r.overhead_pct);
        assert_eq!(r.raw_bytes, 60_000 * 3072 * 4);
        assert_eq!(r.delivered_bytes - r.raw_bytes, 3072 * 3072 * 4);
    }

    /// A real (small) striped transfer: framing exists, is modest, and
    /// the byte counters reconcile with the manifest.
    #[test]
    fn measured_framing_is_small_and_positive() {
        let m = measure_framing(96 * 1024, 8 * 1024, 2).unwrap();
        assert_eq!(m.chunk_count, 12);
        assert_eq!(m.stripes, 2);
        assert!(m.wire_bytes_in > m.raw_bytes, "chunk payloads ride inbound");
        let f = m.framing_pct();
        assert!(f > 0.0 && f < 15.0, "framing {f:.3}% out of range");
    }

    #[test]
    fn extrapolated_row_is_paper_plus_framing() {
        let r = extrapolated_row(CIFAR_IMAGES, 0.8);
        assert!((r.overhead_pct - (5.12 + 0.8)).abs() < 1e-6, "got {}", r.overhead_pct);
        assert!(r.delivered_bytes > r.raw_bytes);
    }

    /// Round-trip the writer shape: schema id, envelope keys, all three
    /// rows with their required keys typed right.
    #[test]
    fn report_schema_shape() {
        let rep = TransmissionReport::analyze(32 * 1024, 4 * 1024, 2).unwrap();
        let doc = crate::json::parse(&crate::json::write(&rep.to_json())).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "mole-overhead-v1");
        assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), "overhead");
        assert!(doc.get("threads").unwrap().as_usize().unwrap() >= 1);
        assert!(!doc.get("cpu").unwrap().get("arch").unwrap().as_str().unwrap().is_empty());
        let rows = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        for row in rows {
            assert!(!row.get("name").unwrap().as_str().unwrap().is_empty());
            assert!(row.get("raw_bytes").unwrap().as_f64().unwrap() > 0.0);
            assert!(row.get("delivered_bytes").unwrap().as_f64().unwrap() > 0.0);
            assert!(row.get("overhead_pct").unwrap().as_f64().unwrap() > 0.0);
        }
        assert!(
            (rows[0].get("overhead_pct").unwrap().as_f64().unwrap() - 5.12).abs() < 1e-9
        );
        assert_eq!(rows[1].get("chunk_count").unwrap().as_usize().unwrap(), 8);
        assert_eq!(rows[1].get("stripes").unwrap().as_usize().unwrap(), 2);
    }
}
