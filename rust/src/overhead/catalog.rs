//! Network catalogs: audited per-layer MAC counts for the architectures
//! the paper quotes (VGG-16 on CIFAR and ImageNet, ResNet-152 on
//! ImageNet). Only convolution + dense layers carry MACs; pooling/ReLU
//! are free in this accounting (standard practice).

use crate::Geometry;

/// One MAC-bearing layer.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub name: String,
    /// MACs for a single input image.
    pub macs: u64,
}

/// A network as a list of layers + its first-layer geometry.
#[derive(Debug, Clone)]
pub struct NetworkSpec {
    pub name: String,
    pub first_layer: Geometry,
    /// Output spatial size of the first layer (differs from m when the
    /// first conv is strided, e.g. ResNet's 7×7/2 stem: n_out = 112).
    pub first_layer_n_out: usize,
    pub layers: Vec<LayerSpec>,
}

impl NetworkSpec {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    pub fn depth(&self) -> usize {
        self.layers.len()
    }
}

/// MACs of a conv layer: in·k²·out·oh·ow.
fn conv_macs(cin: u64, k: u64, cout: u64, oh: u64, ow: u64) -> u64 {
    cin * k * k * cout * oh * ow
}

/// VGG-16 configuration D conv stack: (out_channels, layers) per block.
const VGG16_BLOCKS: [(u64, u64); 5] =
    [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];

/// VGG-16 adapted to 32×32 CIFAR inputs (conv stack + 512→512→10 heads,
/// the standard CIFAR adaptation; ~313M MACs).
pub fn vgg16_cifar() -> NetworkSpec {
    let mut layers = Vec::new();
    let mut cin = 3u64;
    let mut size = 32u64;
    for (b, &(cout, reps)) in VGG16_BLOCKS.iter().enumerate() {
        for r in 0..reps {
            layers.push(LayerSpec {
                name: format!("conv{}_{}", b + 1, r + 1),
                macs: conv_macs(cin, 3, cout, size, size),
            });
            cin = cout;
        }
        size /= 2; // 2x2 maxpool
    }
    layers.push(LayerSpec { name: "fc1".into(), macs: 512 * 512 });
    layers.push(LayerSpec { name: "fc2".into(), macs: 512 * 10 });
    NetworkSpec {
        name: "VGG-16/CIFAR".into(),
        first_layer: Geometry::CIFAR_VGG16,
        first_layer_n_out: 32,
        layers,
    }
}

/// VGG-16 at the original 224×224 ImageNet resolution (~15.47G MACs).
pub fn vgg16_imagenet() -> NetworkSpec {
    let mut layers = Vec::new();
    let mut cin = 3u64;
    let mut size = 224u64;
    for (b, &(cout, reps)) in VGG16_BLOCKS.iter().enumerate() {
        for r in 0..reps {
            layers.push(LayerSpec {
                name: format!("conv{}_{}", b + 1, r + 1),
                macs: conv_macs(cin, 3, cout, size, size),
            });
            cin = cout;
        }
        size /= 2;
    }
    layers.push(LayerSpec { name: "fc1".into(), macs: 25088 * 4096 });
    layers.push(LayerSpec { name: "fc2".into(), macs: 4096 * 4096 });
    layers.push(LayerSpec { name: "fc3".into(), macs: 4096 * 1000 });
    NetworkSpec {
        name: "VGG-16/ImageNet".into(),
        first_layer: Geometry::new(3, 224, 64, 3),
        first_layer_n_out: 224,
        layers,
    }
}

/// ResNet-152 bottleneck stage: (blocks, mid_channels, out_channels, size).
const R152_STAGES: [(u64, u64, u64, u64); 4] = [
    (3, 64, 256, 56),
    (8, 128, 512, 28),
    (36, 256, 1024, 14),
    (3, 512, 2048, 7),
];

/// ResNet-152 at 224×224 (~11.3G MACs, audited bottleneck-by-bottleneck).
pub fn resnet152_imagenet() -> NetworkSpec {
    let mut layers = Vec::new();
    // stem: 7x7/2, 64 out, 112x112
    layers.push(LayerSpec {
        name: "conv1".into(),
        macs: conv_macs(3, 7, 64, 112, 112),
    });
    let mut cin = 64u64;
    for (s, &(blocks, mid, cout, size)) in R152_STAGES.iter().enumerate() {
        for b in 0..blocks {
            // 1x1 reduce, 3x3, 1x1 expand (output spatial = `size`; the
            // stride-2 reduction in the first block of stages 2-4 is
            // approximated at the stage's output size, standard accounting)
            layers.push(LayerSpec {
                name: format!("res{}_{}_1x1a", s + 2, b + 1),
                macs: conv_macs(cin, 1, mid, size, size),
            });
            layers.push(LayerSpec {
                name: format!("res{}_{}_3x3", s + 2, b + 1),
                macs: conv_macs(mid, 3, mid, size, size),
            });
            layers.push(LayerSpec {
                name: format!("res{}_{}_1x1b", s + 2, b + 1),
                macs: conv_macs(mid, 1, cout, size, size),
            });
            if b == 0 {
                layers.push(LayerSpec {
                    name: format!("res{}_down", s + 2),
                    macs: conv_macs(cin, 1, cout, size, size),
                });
            }
            cin = cout;
        }
    }
    layers.push(LayerSpec { name: "fc".into(), macs: 2048 * 1000 });
    NetworkSpec {
        name: "ResNet-152/ImageNet".into(),
        // first layer: 7x7/2, 64 channels on 224x224 -> n_out = 112.
        first_layer: Geometry::new(3, 224, 64, 7),
        first_layer_n_out: 112,
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_cifar_total_is_canonical() {
        let net = vgg16_cifar();
        let g = net.total_macs() as f64 / 1e6;
        // canonical ~313M MACs for VGG-16 conv stack at 32x32
        assert!((g - 313.0).abs() < 20.0, "VGG-16/CIFAR = {g:.1}M MACs");
        assert_eq!(net.depth(), 13 + 2);
    }

    #[test]
    fn vgg16_imagenet_total_is_canonical() {
        let net = vgg16_imagenet();
        let g = net.total_macs() as f64 / 1e9;
        // canonical 15.3-15.5G MACs
        assert!((g - 15.4).abs() < 0.3, "VGG-16/ImageNet = {g:.2}G MACs");
    }

    #[test]
    fn resnet152_total_is_canonical() {
        let net = resnet152_imagenet();
        let g = net.total_macs() as f64 / 1e9;
        // canonical ~11.3G MACs (torchvision reports 11.56 GFLOPs MAC-counted)
        assert!((g - 11.3).abs() < 1.0, "ResNet-152 = {g:.2}G MACs");
        // 152 weighted conv layers + fc + downsamples
        assert!(net.depth() > 150);
    }

    #[test]
    fn first_conv_macs_match_geometry_formula() {
        let net = vgg16_cifar();
        let g = net.first_layer;
        assert_eq!(
            net.layers[0].macs,
            crate::overhead::conv1_macs(&g) as u64
        );
    }
}
