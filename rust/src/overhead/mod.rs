//! Overhead accounting (paper §4.3 + Table 1).
//!
//! Reproduces the paper's computational- and transmission-overhead numbers
//! from geometry alone:
//!
//! * provider compute per image (eq. 16, zero blocks omitted): the audited
//!   block-diagonal count is κ·q² = αm²·q MACs per image (the paper prints
//!   `αq²`, which coincides at κ = α);
//! * developer compute overhead (eq. 17): (m²−p²)·αβn² extra MACs from
//!   replacing the p×p conv with the dense d2r GEMM;
//! * transmission overhead: the paper's eq. states O_data = (αm²)² "equals
//!   the number of elements in C^ac" and derives 5.12 % for CIFAR — note
//!   that C^ac actually has αm²·βn² elements; we reproduce the paper's
//!   formula *and* report the audited size (see EXPERIMENTS.md for the
//!   discrepancy discussion).
//!
//! [`catalog`] carries per-layer MAC counts for VGG-16 (CIFAR + ImageNet)
//! and ResNet-152 so ratios like the ResNet "10×" are reproduced from
//! audited per-layer numbers, not assumed. [`transmission`] grounds the
//! 5.12 % figure in a *measured* transfer over the real delivery plane
//! and emits `BENCH_overhead.json` (schema `mole-overhead-v1`).

pub mod catalog;
pub mod transmission;

use crate::Geometry;
use catalog::NetworkSpec;

/// Morphing MACs per image on the provider (block-diagonal, zeros omitted):
/// κ blocks × q² = αm²·q.
pub fn provider_macs_per_image(g: &Geometry, kappa: usize) -> usize {
    let q = g.d_len() / kappa;
    g.d_len() * q
}

/// Eq. 17: extra developer MACs per image from the Aug-Conv replacement:
/// (m² − p²)·α·β·n².
pub fn developer_extra_macs(g: &Geometry) -> usize {
    developer_extra_macs_n(g, g.n())
}

/// Eq. 17 with an explicit first-layer output size (strided stems such as
/// ResNet's 7×7/2 have n ≠ m).
pub fn developer_extra_macs_n(g: &Geometry, n_out: usize) -> usize {
    (g.m * g.m - g.p * g.p) * g.alpha * g.beta * n_out * n_out
}

/// MACs of the *original* first convolutional layer: αp²·βn².
pub fn conv1_macs(g: &Geometry) -> usize {
    g.alpha * g.p * g.p * g.beta * g.n() * g.n()
}

/// MACs of the Aug-Conv layer (dense [1, αm²] × [αm², βn²]).
pub fn aug_conv_macs(g: &Geometry) -> usize {
    g.d_len() * g.f_len()
}

/// Audited C^ac size: αm² × βn² elements (what actually crosses the wire).
pub fn c_ac_elements(g: &Geometry) -> usize {
    g.d_len() * g.f_len()
}

/// The paper's §4.3 O_data formula: (αm²)² elements — the number behind
/// the quoted 5.12 % (3072² / (60000·3072) = 3072/60000).
pub fn paper_o_data_elements(g: &Geometry) -> usize {
    g.d_len() * g.d_len()
}

/// Full overhead report for a (network, dataset, κ) configuration.
#[derive(Debug, Clone)]
pub struct OverheadReport {
    pub network: String,
    pub geometry: Geometry,
    pub kappa: usize,
    pub dataset_images: usize,
    /// Total network MACs per image (catalog).
    pub network_macs: u64,
    /// Developer-side extra MACs per image (eq. 17).
    pub dev_extra_macs: u64,
    /// Developer computational overhead ratio vs the audited network MACs.
    pub dev_overhead_ratio: f64,
    /// Provider-side morphing MACs per image (eq. 16 audited form).
    pub provider_macs: u64,
    /// Provider morphing as a fraction of one network forward pass.
    pub provider_ratio: f64,
    /// Paper-formula O_data = (αm²)² and its dataset ratio (the 5.12 %).
    pub paper_o_data: u64,
    pub paper_data_ratio: f64,
    /// Audited C^ac elements and dataset ratio.
    pub c_ac_elements: u64,
    pub audited_data_ratio: f64,
}

impl OverheadReport {
    pub fn analyze(net: &NetworkSpec, kappa: usize, dataset_images: usize) -> Self {
        let g = net.first_layer;
        let network_macs = net.total_macs();
        let dev_extra = developer_extra_macs_n(&g, net.first_layer_n_out) as u64;
        let provider = provider_macs_per_image(&g, kappa) as u64;
        let cac = c_ac_elements(&g) as u64;
        let paper_od = paper_o_data_elements(&g) as u64;
        let dataset_elems = (dataset_images * g.d_len()) as f64;
        Self {
            network: net.name.clone(),
            geometry: g,
            kappa,
            dataset_images,
            network_macs,
            dev_extra_macs: dev_extra,
            dev_overhead_ratio: dev_extra as f64 / network_macs as f64,
            provider_macs: provider,
            provider_ratio: provider as f64 / network_macs as f64,
            paper_o_data: paper_od,
            paper_data_ratio: paper_od as f64 / dataset_elems,
            c_ac_elements: cac,
            audited_data_ratio: cac as f64 / dataset_elems,
        }
    }

    pub fn print(&self) {
        println!(
            "{}: kappa={} network={:.3}G MACs/img",
            self.network,
            self.kappa,
            self.network_macs as f64 / 1e9
        );
        println!(
            "  developer overhead: +{:.3}G MACs/img = {:.1}% of network  [eq. 17]",
            self.dev_extra_macs as f64 / 1e9,
            self.dev_overhead_ratio * 100.0
        );
        println!(
            "  provider morphing:  {:.3}M MACs/img = {:.3}% of network  [eq. 16]",
            self.provider_macs as f64 / 1e6,
            self.provider_ratio * 100.0
        );
        println!(
            "  data transmission:  paper O_data=(am^2)^2 {:.1}M elems = {:.2}% of dataset; \
             audited C^ac {:.1}M elems = {:.1}%",
            self.paper_o_data as f64 / 1e6,
            self.paper_data_ratio * 100.0,
            self.c_ac_elements as f64 / 1e6,
            self.audited_data_ratio * 100.0
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::overhead::catalog;

    #[test]
    fn formulas_cifar_vgg16() {
        let g = Geometry::CIFAR_VGG16;
        // eq. 17: (1024-9)*3*64*1024 = 199,557,120
        assert_eq!(developer_extra_macs(&g), (1024 - 9) * 3 * 64 * 1024);
        // audited C^ac: 3072 * 65536
        assert_eq!(c_ac_elements(&g), 3072 * 65536);
        // paper O_data: 3072^2
        assert_eq!(paper_o_data_elements(&g), 3072 * 3072);
        // provider at MS (κ=1): 3072^2; at κ=3: 3072*1024
        assert_eq!(provider_macs_per_image(&g, 1), 3072 * 3072);
        assert_eq!(provider_macs_per_image(&g, 3), 3072 * 1024);
        // conv1 + extra = aug-conv total
        assert_eq!(conv1_macs(&g), 3 * 9 * 64 * 1024);
        assert_eq!(aug_conv_macs(&g), conv1_macs(&g) + developer_extra_macs(&g));
    }

    /// §4.3: "O_data is 5.12% to the whole dataset" — exact under the
    /// paper's (αm²)² formula: 3072²/(60000·3072) = 3072/60000 = 5.12 %.
    #[test]
    fn paper_five_point_one_two_percent() {
        let net = catalog::vgg16_cifar();
        let r = OverheadReport::analyze(&net, 1, 60_000);
        assert!(
            (r.paper_data_ratio - 0.0512).abs() < 1e-6,
            "paper data overhead {:.5}",
            r.paper_data_ratio
        );
        // audited C^ac is beta*n^2/d_len = 21.33x larger
        assert!((r.audited_data_ratio / r.paper_data_ratio - 64.0 / 3.0).abs() < 1e-6);
    }

    /// eq. 17 ratio vs our audited VGG-16-CIFAR MAC count. The paper quotes
    /// 9 %, which is not derivable from VGG-16's CIFAR MACs (313M); the
    /// audited ratio is ~64 %. Documented in EXPERIMENTS.md §Discrepancies.
    #[test]
    fn audited_vgg16_cifar_ratio() {
        let net = catalog::vgg16_cifar();
        let r = OverheadReport::analyze(&net, 1, 60_000);
        assert!(
            r.dev_overhead_ratio > 0.4 && r.dev_overhead_ratio < 0.9,
            "dev overhead {:.4}",
            r.dev_overhead_ratio
        );
    }

    /// §4.3: "10 times for ResNet-152 network on ImageNet dataset" — this
    /// one *is* derivable: (224²−49)·3·64·112² / 11.3G ≈ 10.7×.
    #[test]
    fn paper_resnet_ten_x() {
        let net = catalog::resnet152_imagenet();
        let r = OverheadReport::analyze(&net, 1, 1_281_167);
        assert!(
            r.dev_overhead_ratio > 8.0 && r.dev_overhead_ratio < 13.0,
            "dev overhead {:.2} not ~10x",
            r.dev_overhead_ratio
        );
    }

    /// §4.3: "For large dataset like ImageNet, O_data is merely 1%" under
    /// the paper formula: (3·224²)²/(1.28M·3·224²) = 150528/1.28M ≈ 11.7 %…
    /// the paper's 1 % needs the JPEG-compressed dataset size; with raw
    /// elements the ratio is ~12 %. Assert the formula value.
    #[test]
    fn paper_imagenet_o_data() {
        let net = catalog::resnet152_imagenet();
        let r = OverheadReport::analyze(&net, 1, 1_281_167);
        let want = 150_528.0 / 1_281_167.0;
        assert!(
            (r.paper_data_ratio - want).abs() < 1e-4,
            "paper data overhead {:.4} want {want:.4}",
            r.paper_data_ratio
        );
    }

    #[test]
    fn provider_ratio_shrinks_with_kappa() {
        let net = catalog::vgg16_cifar();
        let r1 = OverheadReport::analyze(&net, 1, 60_000);
        let r3 = OverheadReport::analyze(&net, 3, 60_000);
        assert!(r3.provider_macs * 3 == r1.provider_macs);
        assert!(r3.provider_ratio < r1.provider_ratio);
    }
}
