//! Deterministic random number generation.
//!
//! The crates.io `rand` facade is not available in this offline build, so
//! this module provides the small set of distributions MoLe needs:
//! uniform f32/f64, standard normal (for He init and noise), integer
//! ranges, Fisher–Yates permutations (the paper's `rand()` channel
//! shuffle), and non-zero uniform entries (morphing core **M′**, §3.2:
//! "all of its elements are random and non-zero").
//!
//! The generator is xoshiro256** seeded through SplitMix64 — the standard
//! pairing recommended by the xoshiro authors; deterministic across
//! platforms, which the cross-language test vectors and the key vault
//! (`keys`) rely on.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/sequential seeds decorrelate.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire reduction).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity — generation is not on the request path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of iid N(0, std²) f32 values.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * std).collect()
    }

    /// Uniform *non-zero* value in [-1, 1] \ (-eps, eps) — morphing-core
    /// entries per §3.2.
    pub fn nonzero_unit(&mut self, eps: f32) -> f32 {
        loop {
            let v = self.f32_range(-1.0, 1.0);
            if v.abs() >= eps {
                return v;
            }
        }
    }

    /// Fisher–Yates permutation of 0..n — the paper's `rand()` channel
    /// order (§3.3).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            p.swap(i, j);
        }
        p
    }

    /// Sample k distinct indices from 0..n (partial Fisher–Yates).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut p: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            p.swap(i, j);
        }
        p.truncate(k);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let a = Rng::new(1).next_u64();
        let b = Rng::new(2).next_u64();
        assert_ne!(a, b);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Rng::new(5);
        for n in [1, 2, 5, 64] {
            let p = r.permutation(n);
            let mut seen = vec![false; n];
            for &i in &p {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
    }

    #[test]
    fn permutation_is_uniformish() {
        // position of element 0 across many draws should be uniform
        let mut r = Rng::new(9);
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            let p = r.permutation(4);
            counts[p.iter().position(|&v| v == 0).unwrap()] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn nonzero_unit_respects_eps() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            let v = r.nonzero_unit(0.05);
            assert!(v.abs() >= 0.05 && v.abs() <= 1.0);
        }
    }

    #[test]
    fn choose_distinct() {
        let mut r = Rng::new(17);
        let k = r.choose(100, 10);
        assert_eq!(k.len(), 10);
        let mut s = k.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
    }
}
