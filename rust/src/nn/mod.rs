//! Reference neural-network ops (pure rust, forward only).
//!
//! These are *oracles and baselines*, not the training path: training and
//! serving run through the AOT-compiled XLA artifacts ([`crate::runtime`]).
//! They exist to (a) validate the d2r algebra against direct convolution,
//! (b) drive the feature-transmission baseline (§Table 1, [13]) which must
//! compute the first k layers on the provider side, and (c) provide a
//! CPU-only sanity path in tests where the PJRT client is too heavy.

use crate::tensor::Tensor;
use crate::{Error, Result};

/// SAME-padded 3×3-style cross-correlation, NCHW × OIHW → NCHW.
pub fn conv2d_same(x: &Tensor, w: &Tensor, bias: Option<&[f32]>) -> Result<Tensor> {
    if x.ndim() != 4 || w.ndim() != 4 {
        return Err(Error::Shape("conv2d_same wants 4-D tensors".into()));
    }
    let (bs, alpha, m, m2) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (beta, alpha2, p, p2) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    if m != m2 || p != p2 || alpha != alpha2 {
        return Err(Error::Shape(format!(
            "conv2d_same: x {:?} w {:?}",
            x.shape(),
            w.shape()
        )));
    }
    if let Some(b) = bias {
        if b.len() != beta {
            return Err(Error::Shape(format!("bias len {} != beta {beta}", b.len())));
        }
    }
    let off = (p - 1) / 2;
    let mut out = Tensor::zeros(&[bs, beta, m, m]);
    for bi in 0..bs {
        for j in 0..beta {
            let base_b = bias.map(|b| b[j]).unwrap_or(0.0);
            for oy in 0..m {
                for ox in 0..m {
                    let mut acc = base_b as f64;
                    for i in 0..alpha {
                        for a in 0..p {
                            let iy = oy as isize + a as isize - off as isize;
                            if iy < 0 || iy >= m as isize {
                                continue;
                            }
                            for bb in 0..p {
                                let ix = ox as isize + bb as isize - off as isize;
                                if ix < 0 || ix >= m as isize {
                                    continue;
                                }
                                acc += x.at4(bi, i, iy as usize, ix as usize) as f64
                                    * w.at4(j, i, a, bb) as f64;
                            }
                        }
                    }
                    out.set4(bi, j, oy, ox, acc as f32);
                }
            }
        }
    }
    Ok(out)
}

/// In-place ReLU.
pub fn relu(x: &mut Tensor) {
    for v in x.data_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// 2×2 max-pool with stride 2 (NCHW). Spatial dims must be even.
pub fn maxpool2(x: &Tensor) -> Result<Tensor> {
    if x.ndim() != 4 || x.shape()[2] % 2 != 0 || x.shape()[3] % 2 != 0 {
        return Err(Error::Shape(format!("maxpool2: bad shape {:?}", x.shape())));
    }
    let (bs, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let mut out = Tensor::zeros(&[bs, c, h / 2, w / 2]);
    for bi in 0..bs {
        for ci in 0..c {
            for oy in 0..h / 2 {
                for ox in 0..w / 2 {
                    let v = x
                        .at4(bi, ci, 2 * oy, 2 * ox)
                        .max(x.at4(bi, ci, 2 * oy, 2 * ox + 1))
                        .max(x.at4(bi, ci, 2 * oy + 1, 2 * ox))
                        .max(x.at4(bi, ci, 2 * oy + 1, 2 * ox + 1));
                    out.set4(bi, ci, oy, ox, v);
                }
            }
        }
    }
    Ok(out)
}

/// Dense layer y = x·W + b for 2-D activations [B, in] × [in, out].
pub fn dense(x: &Tensor, w: &Tensor, b: &[f32]) -> Result<Tensor> {
    let mut y = crate::linalg::gemm(x, w)?;
    if b.len() != y.shape()[1] {
        return Err(Error::Shape(format!(
            "dense bias {} != out {}",
            b.len(),
            y.shape()[1]
        )));
    }
    let cols = y.shape()[1];
    for r in 0..y.shape()[0] {
        for (v, bv) in y.row_mut(r).iter_mut().zip(b) {
            *v += bv;
        }
        let _ = cols;
    }
    Ok(y)
}

/// Row-wise softmax.
pub fn softmax(x: &Tensor) -> Result<Tensor> {
    if x.ndim() != 2 {
        return Err(Error::Shape("softmax wants [B, C]".into()));
    }
    let mut out = x.clone();
    for r in 0..out.shape()[0] {
        let row = out.row_mut(r);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    Ok(out)
}

/// Row-wise argmax (predicted class ids).
pub fn argmax_rows(x: &Tensor) -> Vec<usize> {
    (0..x.shape()[0])
        .map(|r| {
            x.row(r)
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

/// Additive Gaussian noise (the feature-transmission baseline's defence
/// mechanism — [13] adds noise to extracted features).
pub fn add_gaussian_noise(x: &mut Tensor, std: f32, rng: &mut crate::rng::Rng) {
    for v in x.data_mut() {
        *v += rng.normal_f32() * std;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel with weight 1 is the identity map
        let mut r = Rng::new(0);
        let x = Tensor::new(&[1, 2, 4, 4], r.normal_vec(32, 1.0)).unwrap();
        let mut w = Tensor::zeros(&[2, 2, 1, 1]);
        w.set4(0, 0, 0, 0, 1.0);
        w.set4(1, 1, 0, 0, 1.0);
        let y = conv2d_same(&x, &w, None).unwrap();
        assert!(y.allclose(&x, 1e-6, 1e-6));
    }

    #[test]
    fn conv_known_values() {
        // 3x3 all-ones kernel over a constant image: interior = 9, corner = 4
        let x = Tensor::full(&[1, 1, 4, 4], 1.0);
        let w = Tensor::full(&[1, 1, 3, 3], 1.0);
        let y = conv2d_same(&x, &w, None).unwrap();
        assert_eq!(y.at4(0, 0, 1, 1), 9.0);
        assert_eq!(y.at4(0, 0, 0, 0), 4.0);
        assert_eq!(y.at4(0, 0, 0, 1), 6.0);
    }

    #[test]
    fn conv_bias() {
        let x = Tensor::zeros(&[1, 1, 2, 2]);
        let w = Tensor::zeros(&[2, 1, 1, 1]);
        let y = conv2d_same(&x, &w, Some(&[1.5, -2.0])).unwrap();
        assert_eq!(y.at4(0, 0, 0, 0), 1.5);
        assert_eq!(y.at4(0, 1, 1, 1), -2.0);
    }

    #[test]
    fn relu_clamps() {
        let mut t = Tensor::new(&[3], vec![-1.0, 0.0, 2.0]).unwrap();
        relu(&mut t);
        assert_eq!(t.data(), &[0.0, 0.0, 2.0]);
    }

    #[test]
    fn maxpool_picks_max() {
        let x = Tensor::new(
            &[1, 1, 2, 4],
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0],
        )
        .unwrap();
        let y = maxpool2(&x).unwrap();
        assert_eq!(y.shape(), &[1, 1, 1, 2]);
        assert_eq!(y.data(), &[6.0, 8.0]);
        assert!(maxpool2(&Tensor::zeros(&[1, 1, 3, 3])).is_err());
    }

    #[test]
    fn dense_and_softmax() {
        let x = Tensor::new(&[1, 2], vec![1.0, 2.0]).unwrap();
        let w = Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let y = dense(&x, &w, &[0.5, -0.5]).unwrap();
        assert_eq!(y.data(), &[1.5, 1.5]);
        let s = softmax(&y).unwrap();
        assert!((s.data()[0] - 0.5).abs() < 1e-6);
        let sum: f32 = s.data().iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
    }

    #[test]
    fn argmax_rows_basic() {
        let x = Tensor::new(&[2, 3], vec![0.0, 2.0, 1.0, 5.0, -1.0, 3.0]).unwrap();
        assert_eq!(argmax_rows(&x), vec![1, 0]);
    }

    #[test]
    fn noise_changes_values_with_right_scale() {
        let mut r = Rng::new(3);
        let mut t = Tensor::zeros(&[10_000]);
        add_gaussian_noise(&mut t, 2.0, &mut r);
        let var: f64 = t.data().iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            / t.numel() as f64;
        assert!((var - 4.0).abs() < 0.3, "var={var}");
    }
}
