//! Neural-network ops (pure rust).
//!
//! [`conv2d_same`] is the scalar *oracle* every faster path is validated
//! against; [`conv2d_same_gemm`] is the production path: im2col + a
//! [`crate::backend`] GEMM, which is what the interpreter engine
//! ([`crate::runtime`]) runs for training and serving when no PJRT
//! artifacts are available. The im2col/col2im primitives are shared with
//! the interpreter's backward pass.

use crate::backend::Backend;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Gather SAME-padded p×p receptive fields of `x` [B, C, m, m] into a
/// matrix [B·m², C·p²] whose row r = (b·m + oy)·m + ox holds the patch
/// feeding output pixel (oy, ox), in (channel, krow, kcol) order —
/// matching the OIHW kernel layout flattened by [`kernel_matrix`].
pub(crate) fn im2col(x: &Tensor, p: usize) -> Result<Tensor> {
    if x.ndim() != 4 || x.shape()[2] != x.shape()[3] {
        return Err(Error::Shape(format!("im2col wants [B, C, m, m], got {:?}", x.shape())));
    }
    let (bs, ch, m) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let off = (p - 1) / 2;
    let patch = ch * p * p;
    let mut cols = Tensor::zeros(&[bs * m * m, patch]);
    let xd = x.data();
    let cd = cols.data_mut();
    for b in 0..bs {
        for oy in 0..m {
            for ox in 0..m {
                let row = ((b * m + oy) * m + ox) * patch;
                for i in 0..ch {
                    for a in 0..p {
                        let iy = oy as isize + a as isize - off as isize;
                        if iy < 0 || iy >= m as isize {
                            continue; // zero padding: cols is pre-zeroed
                        }
                        let src = ((b * ch + i) * m + iy as usize) * m;
                        let dst = row + (i * p + a) * p;
                        for bb in 0..p {
                            let ix = ox as isize + bb as isize - off as isize;
                            if ix < 0 || ix >= m as isize {
                                continue;
                            }
                            cd[dst + bb] = xd[src + ix as usize];
                        }
                    }
                }
            }
        }
    }
    Ok(cols)
}

/// Scatter-add the reverse of [`im2col`]: fold `dcols` [B·m², C·p²] back
/// into an image-shaped gradient [B, C, m, m] (out-of-bounds taps drop,
/// mirroring the zero padding).
pub(crate) fn col2im_add(dcols: &Tensor, bs: usize, ch: usize, m: usize, p: usize) -> Result<Tensor> {
    let patch = ch * p * p;
    if dcols.shape() != [bs * m * m, patch] {
        return Err(Error::Shape(format!(
            "col2im wants [{}, {patch}], got {:?}",
            bs * m * m,
            dcols.shape()
        )));
    }
    let off = (p - 1) / 2;
    let mut dx = Tensor::zeros(&[bs, ch, m, m]);
    let dd = dcols.data();
    let xd = dx.data_mut();
    for b in 0..bs {
        for oy in 0..m {
            for ox in 0..m {
                let row = ((b * m + oy) * m + ox) * patch;
                for i in 0..ch {
                    for a in 0..p {
                        let iy = oy as isize + a as isize - off as isize;
                        if iy < 0 || iy >= m as isize {
                            continue;
                        }
                        let dst = ((b * ch + i) * m + iy as usize) * m;
                        let src = row + (i * p + a) * p;
                        for bb in 0..p {
                            let ix = ox as isize + bb as isize - off as isize;
                            if ix < 0 || ix >= m as isize {
                                continue;
                            }
                            xd[dst + ix as usize] += dd[src + bb];
                        }
                    }
                }
            }
        }
    }
    Ok(dx)
}

/// Flatten an OIHW kernel [β, C, p, p] into the [C·p², β] matrix that
/// multiplies [`im2col`] patches.
pub(crate) fn kernel_matrix(w: &Tensor) -> Tensor {
    let (beta, ch, p) = (w.shape()[0], w.shape()[1], w.shape()[2]);
    let patch = ch * p * p;
    let mut wm = Tensor::zeros(&[patch, beta]);
    let wd = w.data();
    let md = wm.data_mut();
    for j in 0..beta {
        for r in 0..patch {
            md[r * beta + j] = wd[j * patch + r];
        }
    }
    wm
}

/// [B·m², C] column matrix → NCHW [B, C, m, m] (+ optional channel bias)
/// — the output-side layout transform of the im2col convolution, shared
/// with the interpreter's forward/backward passes.
pub(crate) fn cols_to_nchw(
    ycol: &Tensor,
    bs: usize,
    ch: usize,
    m: usize,
    bias: Option<&[f32]>,
) -> Result<Tensor> {
    if ycol.shape() != [bs * m * m, ch] {
        return Err(Error::Shape(format!(
            "cols_to_nchw wants [{}, {ch}], got {:?}",
            bs * m * m,
            ycol.shape()
        )));
    }
    let mut out = Tensor::zeros(&[bs, ch, m, m]);
    let yd = ycol.data();
    let od = out.data_mut();
    for b in 0..bs {
        for py in 0..m {
            for px in 0..m {
                let row = ((b * m + py) * m + px) * ch;
                for j in 0..ch {
                    let v = yd[row + j] + bias.map(|bv| bv[j]).unwrap_or(0.0);
                    od[((b * ch + j) * m + py) * m + px] = v;
                }
            }
        }
    }
    Ok(out)
}

/// SAME-padded cross-correlation via im2col + backend GEMM — numerically
/// the f32-accumulation counterpart of [`conv2d_same`], and the layer the
/// interpreter engine trains/serves through.
pub fn conv2d_same_gemm(
    be: &dyn Backend,
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
) -> Result<Tensor> {
    if x.ndim() != 4 || w.ndim() != 4 || x.shape()[1] != w.shape()[1] {
        return Err(Error::Shape(format!(
            "conv2d_same_gemm: x {:?} w {:?}",
            x.shape(),
            w.shape()
        )));
    }
    let (bs, m) = (x.shape()[0], x.shape()[2]);
    let beta = w.shape()[0];
    if let Some(b) = bias {
        if b.len() != beta {
            return Err(Error::Shape(format!("bias len {} != beta {beta}", b.len())));
        }
    }
    let cols = im2col(x, w.shape()[2])?;
    let wm = kernel_matrix(w);
    let y_col = be.gemm(&cols, &wm)?; // [B*m*m, beta]
    cols_to_nchw(&y_col, bs, beta, m, bias)
}

/// SAME-padded 3×3-style cross-correlation, NCHW × OIHW → NCHW.
pub fn conv2d_same(x: &Tensor, w: &Tensor, bias: Option<&[f32]>) -> Result<Tensor> {
    if x.ndim() != 4 || w.ndim() != 4 {
        return Err(Error::Shape("conv2d_same wants 4-D tensors".into()));
    }
    let (bs, alpha, m, m2) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (beta, alpha2, p, p2) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    if m != m2 || p != p2 || alpha != alpha2 {
        return Err(Error::Shape(format!(
            "conv2d_same: x {:?} w {:?}",
            x.shape(),
            w.shape()
        )));
    }
    if let Some(b) = bias {
        if b.len() != beta {
            return Err(Error::Shape(format!("bias len {} != beta {beta}", b.len())));
        }
    }
    let off = (p - 1) / 2;
    let mut out = Tensor::zeros(&[bs, beta, m, m]);
    for bi in 0..bs {
        for j in 0..beta {
            let base_b = bias.map(|b| b[j]).unwrap_or(0.0);
            for oy in 0..m {
                for ox in 0..m {
                    let mut acc = base_b as f64;
                    for i in 0..alpha {
                        for a in 0..p {
                            let iy = oy as isize + a as isize - off as isize;
                            if iy < 0 || iy >= m as isize {
                                continue;
                            }
                            for bb in 0..p {
                                let ix = ox as isize + bb as isize - off as isize;
                                if ix < 0 || ix >= m as isize {
                                    continue;
                                }
                                acc += x.at4(bi, i, iy as usize, ix as usize) as f64
                                    * w.at4(j, i, a, bb) as f64;
                            }
                        }
                    }
                    out.set4(bi, j, oy, ox, acc as f32);
                }
            }
        }
    }
    Ok(out)
}

/// In-place ReLU.
pub fn relu(x: &mut Tensor) {
    for v in x.data_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// 2×2 max-pool with stride 2 (NCHW). Spatial dims must be even.
pub fn maxpool2(x: &Tensor) -> Result<Tensor> {
    if x.ndim() != 4 || x.shape()[2] % 2 != 0 || x.shape()[3] % 2 != 0 {
        return Err(Error::Shape(format!("maxpool2: bad shape {:?}", x.shape())));
    }
    let (bs, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let mut out = Tensor::zeros(&[bs, c, h / 2, w / 2]);
    for bi in 0..bs {
        for ci in 0..c {
            for oy in 0..h / 2 {
                for ox in 0..w / 2 {
                    let v = x
                        .at4(bi, ci, 2 * oy, 2 * ox)
                        .max(x.at4(bi, ci, 2 * oy, 2 * ox + 1))
                        .max(x.at4(bi, ci, 2 * oy + 1, 2 * ox))
                        .max(x.at4(bi, ci, 2 * oy + 1, 2 * ox + 1));
                    out.set4(bi, ci, oy, ox, v);
                }
            }
        }
    }
    Ok(out)
}

/// Dense layer y = x·W + b for 2-D activations [B, in] × [in, out].
pub fn dense(x: &Tensor, w: &Tensor, b: &[f32]) -> Result<Tensor> {
    let mut y = crate::linalg::gemm(x, w)?;
    if b.len() != y.shape()[1] {
        return Err(Error::Shape(format!(
            "dense bias {} != out {}",
            b.len(),
            y.shape()[1]
        )));
    }
    let cols = y.shape()[1];
    for r in 0..y.shape()[0] {
        for (v, bv) in y.row_mut(r).iter_mut().zip(b) {
            *v += bv;
        }
        let _ = cols;
    }
    Ok(y)
}

/// Row-wise softmax.
pub fn softmax(x: &Tensor) -> Result<Tensor> {
    if x.ndim() != 2 {
        return Err(Error::Shape("softmax wants [B, C]".into()));
    }
    let mut out = x.clone();
    for r in 0..out.shape()[0] {
        let row = out.row_mut(r);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    Ok(out)
}

/// Row-wise argmax (predicted class ids).
pub fn argmax_rows(x: &Tensor) -> Vec<usize> {
    (0..x.shape()[0])
        .map(|r| {
            x.row(r)
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

/// Additive Gaussian noise (the feature-transmission baseline's defence
/// mechanism — [13] adds noise to extracted features).
pub fn add_gaussian_noise(x: &mut Tensor, std: f32, rng: &mut crate::rng::Rng) {
    for v in x.data_mut() {
        *v += rng.normal_f32() * std;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel with weight 1 is the identity map
        let mut r = Rng::new(0);
        let x = Tensor::new(&[1, 2, 4, 4], r.normal_vec(32, 1.0)).unwrap();
        let mut w = Tensor::zeros(&[2, 2, 1, 1]);
        w.set4(0, 0, 0, 0, 1.0);
        w.set4(1, 1, 0, 0, 1.0);
        let y = conv2d_same(&x, &w, None).unwrap();
        assert!(y.allclose(&x, 1e-6, 1e-6));
    }

    #[test]
    fn conv_known_values() {
        // 3x3 all-ones kernel over a constant image: interior = 9, corner = 4
        let x = Tensor::full(&[1, 1, 4, 4], 1.0);
        let w = Tensor::full(&[1, 1, 3, 3], 1.0);
        let y = conv2d_same(&x, &w, None).unwrap();
        assert_eq!(y.at4(0, 0, 1, 1), 9.0);
        assert_eq!(y.at4(0, 0, 0, 0), 4.0);
        assert_eq!(y.at4(0, 0, 0, 1), 6.0);
    }

    #[test]
    fn conv_bias() {
        let x = Tensor::zeros(&[1, 1, 2, 2]);
        let w = Tensor::zeros(&[2, 1, 1, 1]);
        let y = conv2d_same(&x, &w, Some(&[1.5, -2.0])).unwrap();
        assert_eq!(y.at4(0, 0, 0, 0), 1.5);
        assert_eq!(y.at4(0, 1, 1, 1), -2.0);
    }

    #[test]
    fn relu_clamps() {
        let mut t = Tensor::new(&[3], vec![-1.0, 0.0, 2.0]).unwrap();
        relu(&mut t);
        assert_eq!(t.data(), &[0.0, 0.0, 2.0]);
    }

    #[test]
    fn maxpool_picks_max() {
        let x = Tensor::new(
            &[1, 1, 2, 4],
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0],
        )
        .unwrap();
        let y = maxpool2(&x).unwrap();
        assert_eq!(y.shape(), &[1, 1, 1, 2]);
        assert_eq!(y.data(), &[6.0, 8.0]);
        assert!(maxpool2(&Tensor::zeros(&[1, 1, 3, 3])).is_err());
    }

    #[test]
    fn dense_and_softmax() {
        let x = Tensor::new(&[1, 2], vec![1.0, 2.0]).unwrap();
        let w = Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let y = dense(&x, &w, &[0.5, -0.5]).unwrap();
        assert_eq!(y.data(), &[1.5, 1.5]);
        let s = softmax(&y).unwrap();
        assert!((s.data()[0] - 0.5).abs() < 1e-6);
        let sum: f32 = s.data().iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
    }

    #[test]
    fn argmax_rows_basic() {
        let x = Tensor::new(&[2, 3], vec![0.0, 2.0, 1.0, 5.0, -1.0, 3.0]).unwrap();
        assert_eq!(argmax_rows(&x), vec![1, 0]);
    }

    #[test]
    fn gemm_conv_matches_scalar_oracle() {
        let mut r = Rng::new(21);
        for &(bs, ch, m, beta, p) in
            &[(1usize, 1usize, 4usize, 1usize, 3usize), (2, 3, 8, 4, 3), (1, 2, 5, 3, 1), (2, 2, 6, 2, 5)]
        {
            let x = Tensor::new(&[bs, ch, m, m], r.normal_vec(bs * ch * m * m, 1.0)).unwrap();
            let w =
                Tensor::new(&[beta, ch, p, p], r.normal_vec(beta * ch * p * p, 0.5)).unwrap();
            let bias: Vec<f32> = r.normal_vec(beta, 0.1);
            let want = conv2d_same(&x, &w, Some(&bias)).unwrap();
            for be in [
                &crate::backend::RefBackend::new() as &dyn Backend,
                &crate::backend::ParallelBackend::new(2) as &dyn Backend,
                &crate::backend::SimdBackend::new() as &dyn Backend,
                &crate::backend::SimdBackend::portable() as &dyn Backend,
                &crate::backend::ParallelBackend::with_simd(2) as &dyn Backend,
            ] {
                let got = conv2d_same_gemm(be, &x, &w, Some(&bias)).unwrap();
                assert!(
                    got.allclose(&want, 1e-4, 1e-4),
                    "conv mismatch on {} at ({bs},{ch},{m},{beta},{p}): {}",
                    be.name(),
                    got.max_abs_diff(&want).unwrap()
                );
            }
        }
    }

    #[test]
    fn im2col_col2im_adjoint() {
        // <im2col(x), y> == <x, col2im(y)> — the pair is a true adjoint,
        // which is exactly what the conv backward pass relies on.
        let mut r = Rng::new(22);
        let (bs, ch, m, p) = (2usize, 3usize, 6usize, 3usize);
        let x = Tensor::new(&[bs, ch, m, m], r.normal_vec(bs * ch * m * m, 1.0)).unwrap();
        let cols = im2col(&x, p).unwrap();
        let y = Tensor::new(cols.shape(), r.normal_vec(cols.numel(), 1.0)).unwrap();
        let lhs: f64 = cols
            .data()
            .iter()
            .zip(y.data())
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum();
        let back = col2im_add(&y, bs, ch, m, p).unwrap();
        let rhs: f64 = x
            .data()
            .iter()
            .zip(back.data())
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum();
        assert!((lhs - rhs).abs() < 1e-2 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn noise_changes_values_with_right_scale() {
        let mut r = Rng::new(3);
        let mut t = Tensor::zeros(&[10_000]);
        add_gaussian_noise(&mut t, 2.0, &mut r);
        let var: f64 = t.data().iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            / t.numel() as f64;
        assert!((var - 4.0).abs() < 0.3, "var={var}");
    }
}
