//! Minimal CLI argument parsing (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positional args.

use crate::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} {v:?}: not an integer"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} {v:?}: not an integer"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} {v:?}: not a number"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_options_flags() {
        // note: a bare `--name value` pair is greedy — flags must either
        // use `--flag` at the end or precede another `--` token
        let a = parse(&["train", "x", "--steps", "10", "--lr=0.5", "--verbose"]);
        assert_eq!(a.positional, vec!["train", "x"]);
        assert_eq!(a.get_usize("steps", 0).unwrap(), 10);
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse(&["--n", "abc"]);
        assert!(a.get_usize("n", 1).is_err());
        assert_eq!(a.get_usize("m", 7).unwrap(), 7);
        assert_eq!(a.get_or("mode", "fast"), "fast");
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--a", "--b"]);
        assert!(a.flag("a") && a.flag("b"));
    }
}
