//! `artifacts/manifest.json` — the contract between the python AOT
//! pipeline and the rust runtime: artifact paths + signatures, geometry
//! constants, and parameter-initialization shapes.

use crate::json::{self, Value};
use crate::{Error, Geometry, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// dtype of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => Err(Error::Manifest(format!("unsupported dtype {other:?}"))),
        }
    }
}

/// Shape + dtype of one artifact input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    /// Path relative to the artifacts directory.
    pub path: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    /// "morph" | "augconv_forward" | "infer_base" | … (see aot.py).
    pub kind: String,
    /// Batch size baked into the executable (0 when not applicable).
    pub batch: usize,
    /// Number of model-parameter inputs (train/infer artifacts).
    pub n_params: usize,
}

/// Parameter-initialization spec (mirrors model.base_param_shapes).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "he" | "zero".
    pub init: String,
    pub fan_in: usize,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub geometries: BTreeMap<String, Geometry>,
    pub train_batch: usize,
    pub infer_batches: Vec<usize>,
    pub eq_batch: usize,
    pub num_classes: usize,
    pub momentum: f64,
    pub base_params: Vec<ParamSpec>,
    pub aug_params: Vec<ParamSpec>,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

fn parse_sigs(v: &Value) -> Result<Vec<TensorSig>> {
    v.as_arr()?
        .iter()
        .map(|e| {
            Ok(TensorSig {
                shape: e.get("shape")?.as_usize_vec()?,
                dtype: DType::parse(e.get("dtype")?.as_str()?)?,
            })
        })
        .collect()
}

fn parse_params(v: &Value) -> Result<Vec<ParamSpec>> {
    v.as_arr()?
        .iter()
        .map(|e| {
            Ok(ParamSpec {
                name: e.get("name")?.as_str()?.to_string(),
                shape: e.get("shape")?.as_usize_vec()?,
                init: e.get("init")?.as_str()?.to_string(),
                fan_in: e.get("fan_in")?.as_usize()?,
            })
        })
        .collect()
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {path:?} (run `make artifacts` first): {e}"
            ))
        })?;
        let v = json::parse(&text)?;
        let version = v.get("version")?.as_usize()?;
        if version != 1 {
            return Err(Error::Manifest(format!("unsupported version {version}")));
        }

        let mut geometries = BTreeMap::new();
        for (name, g) in v.get("geometries")?.as_obj()? {
            geometries.insert(
                name.clone(),
                Geometry::new(
                    g.get("alpha")?.as_usize()?,
                    g.get("m")?.as_usize()?,
                    g.get("beta")?.as_usize()?,
                    g.get("p")?.as_usize()?,
                ),
            );
        }

        let mut artifacts = BTreeMap::new();
        for (name, e) in v.get("artifacts")?.as_obj()? {
            let entry = ArtifactEntry {
                name: name.clone(),
                path: e.get("path")?.as_str()?.to_string(),
                inputs: parse_sigs(e.get("inputs")?)?,
                outputs: parse_sigs(e.get("outputs")?)?,
                kind: e
                    .get("kind")
                    .and_then(|k| Ok(k.as_str()?.to_string()))
                    .unwrap_or_default(),
                batch: e.get("batch").and_then(|b| b.as_usize()).unwrap_or(0),
                n_params: e.get("n_params").and_then(|b| b.as_usize()).unwrap_or(0),
            };
            artifacts.insert(name.clone(), entry);
        }

        Ok(Self {
            dir: dir.to_path_buf(),
            geometries,
            train_batch: v.get("train_batch")?.as_usize()?,
            infer_batches: v.get("infer_batches")?.as_usize_vec()?,
            eq_batch: v.get("eq_batch")?.as_usize()?,
            num_classes: v.get("num_classes")?.as_usize()?,
            momentum: v.get("momentum")?.as_f64()?,
            base_params: parse_params(v.get("base_params")?)?,
            aug_params: parse_params(v.get("aug_params")?)?,
            artifacts,
        })
    }

    /// Look up an artifact by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("no artifact {name:?}")))
    }

    /// Absolute path of an artifact's HLO file.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.path))
    }

    /// The geometry by manifest name ("small" / "cifar").
    pub fn geometry(&self, name: &str) -> Result<Geometry> {
        self.geometries
            .get(name)
            .copied()
            .ok_or_else(|| Error::Manifest(format!("no geometry {name:?}")))
    }

    /// morph_apply artifact name for (geometry, q, batch).
    pub fn morph_artifact(geo_name: &str, q: usize, batch: usize) -> String {
        format!("morph_apply_{geo_name}_q{q}_b{batch}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // tests run from the crate root
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(&artifacts_dir()).expect("run `make artifacts` first");
        assert_eq!(m.geometry("small").unwrap(), Geometry::SMALL);
        assert_eq!(m.geometry("cifar").unwrap(), Geometry::CIFAR_VGG16);
        assert_eq!(m.train_batch, 64);
        assert_eq!(m.base_params.len(), 10);
        assert_eq!(m.aug_params.len(), 8);
        // w1 comes first in base params and is absent from aug params
        assert_eq!(m.base_params[0].name, "w1");
        assert_eq!(m.aug_params[0].name, "w2");
    }

    #[test]
    fn artifact_signatures_consistent() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let g = m.geometry("small").unwrap();
        let a = m.artifact(&Manifest::morph_artifact("small", 48, 64)).unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![64, g.d_len()]);
        assert_eq!(a.inputs[1].shape, vec![48, 48]);
        assert_eq!(a.outputs[0].shape, vec![64, g.d_len()]);
        assert!(m.artifact_path(&a.name).unwrap().exists());

        let t = m.artifact("train_step_aug_small_b64").unwrap();
        // cac, b1p, 8 params, 8 momenta, t_r, y, lr = 21 inputs
        assert_eq!(t.inputs.len(), 21);
        assert_eq!(t.outputs.len(), 18);
        assert_eq!(t.n_params, 8);
        assert_eq!(t.inputs[20].shape, Vec::<usize>::new()); // lr scalar
        assert_eq!(t.inputs[19].dtype, DType::I32); // labels
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert!(m.artifact("nonexistent").is_err());
        assert!(m.geometry("huge").is_err());
    }
}
