//! The artifact manifest — the contract between the AOT pipeline and the
//! rust runtime: artifact names + signatures, geometry constants, and
//! parameter-initialization shapes.
//!
//! Two sources, one type:
//! * **Disk** — `artifacts/manifest.json` written by `python -m
//!   compile.aot` alongside the HLO text files ([`Manifest::from_disk`] is
//!   true; required for the `pjrt` execution path).
//! * **Built-in** — [`Manifest::builtin`], the same contract synthesized
//!   in code (kept in lock-step with `python/compile/aot.py`), which the
//!   dependency-free interpreter engine runs against when no artifacts
//!   directory exists. [`Manifest::load`] falls back to it automatically.

use crate::json::{self, Value};
use crate::{Error, Geometry, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// dtype of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => Err(Error::Manifest(format!("unsupported dtype {other:?}"))),
        }
    }
}

/// Shape + dtype of one artifact input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    /// Path relative to the artifacts directory.
    pub path: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    /// "morph" | "augconv_forward" | "infer_base" | … (see aot.py).
    pub kind: String,
    /// Batch size baked into the executable (0 when not applicable).
    pub batch: usize,
    /// Number of model-parameter inputs (train/infer artifacts).
    pub n_params: usize,
}

/// Parameter-initialization spec (mirrors model.base_param_shapes).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "he" | "zero".
    pub init: String,
    pub fan_in: usize,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub geometries: BTreeMap<String, Geometry>,
    pub train_batch: usize,
    pub infer_batches: Vec<usize>,
    pub eq_batch: usize,
    pub num_classes: usize,
    pub momentum: f64,
    pub base_params: Vec<ParamSpec>,
    pub aug_params: Vec<ParamSpec>,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    /// True when parsed from `manifest.json` (HLO files exist on disk);
    /// false for the built-in interpreter contract.
    from_disk: bool,
}

fn parse_sigs(v: &Value) -> Result<Vec<TensorSig>> {
    v.as_arr()?
        .iter()
        .map(|e| {
            Ok(TensorSig {
                shape: e.get("shape")?.as_usize_vec()?,
                dtype: DType::parse(e.get("dtype")?.as_str()?)?,
            })
        })
        .collect()
}

fn parse_params(v: &Value) -> Result<Vec<ParamSpec>> {
    v.as_arr()?
        .iter()
        .map(|e| {
            Ok(ParamSpec {
                name: e.get("name")?.as_str()?.to_string(),
                shape: e.get("shape")?.as_usize_vec()?,
                init: e.get("init")?.as_str()?.to_string(),
                fan_in: e.get("fan_in")?.as_usize()?,
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json` when it exists, otherwise return the
    /// [`Manifest::builtin`] contract for the interpreter engine. Parse
    /// errors in an *existing* manifest.json are still reported.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        if !path.exists() {
            crate::logging::info(&format!(
                "no manifest at {path:?}; using the built-in interpreter contract"
            ));
            return Ok(Self::builtin(dir));
        }
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!("cannot read {path:?}: {e}"))
        })?;
        let v = json::parse(&text)?;
        let version = v.get("version")?.as_usize()?;
        if version != 1 {
            return Err(Error::Manifest(format!("unsupported version {version}")));
        }

        let mut geometries = BTreeMap::new();
        for (name, g) in v.get("geometries")?.as_obj()? {
            geometries.insert(
                name.clone(),
                Geometry::new(
                    g.get("alpha")?.as_usize()?,
                    g.get("m")?.as_usize()?,
                    g.get("beta")?.as_usize()?,
                    g.get("p")?.as_usize()?,
                ),
            );
        }

        let mut artifacts = BTreeMap::new();
        for (name, e) in v.get("artifacts")?.as_obj()? {
            let entry = ArtifactEntry {
                name: name.clone(),
                path: e.get("path")?.as_str()?.to_string(),
                inputs: parse_sigs(e.get("inputs")?)?,
                outputs: parse_sigs(e.get("outputs")?)?,
                kind: e
                    .get("kind")
                    .and_then(|k| Ok(k.as_str()?.to_string()))
                    .unwrap_or_default(),
                batch: e.get("batch").and_then(|b| b.as_usize()).unwrap_or(0),
                n_params: e.get("n_params").and_then(|b| b.as_usize()).unwrap_or(0),
            };
            artifacts.insert(name.clone(), entry);
        }

        Ok(Self {
            dir: dir.to_path_buf(),
            geometries,
            train_batch: v.get("train_batch")?.as_usize()?,
            infer_batches: v.get("infer_batches")?.as_usize_vec()?,
            eq_batch: v.get("eq_batch")?.as_usize()?,
            num_classes: v.get("num_classes")?.as_usize()?,
            momentum: v.get("momentum")?.as_f64()?,
            base_params: parse_params(v.get("base_params")?)?,
            aug_params: parse_params(v.get("aug_params")?)?,
            artifacts,
            from_disk: true,
        })
    }

    /// Whether HLO artifact files back this manifest on disk (required
    /// for the `pjrt` engine; the interpreter does not care).
    pub fn from_disk(&self) -> bool {
        self.from_disk
    }

    /// The built-in contract, kept in lock-step with
    /// `python/compile/aot.py::emit_all` (the `loads_real_manifest` /
    /// `artifact_signatures_consistent` tests pin the invariants both
    /// sides rely on).
    pub fn builtin(dir: &Path) -> Self {
        let small = Geometry::SMALL;
        let cifar = Geometry::CIFAR_VGG16;
        let mut geometries = BTreeMap::new();
        geometries.insert("small".to_string(), small);
        geometries.insert("cifar".to_string(), cifar);

        let train_batch = 64usize;
        let infer_batches = vec![1usize, 8, 32];
        let eq_batch = 8usize;
        let num_classes = 10usize;

        // VGG-small stack (python/compile/model.py::base_param_shapes)
        let (c2, c3, f1) = (16usize, 32usize, 64usize);
        let flat = c3 * (small.m / 4) * (small.m / 4);
        let spec = |name: &str, shape: Vec<usize>, init: &str, fan_in: usize| ParamSpec {
            name: name.to_string(),
            shape,
            init: init.to_string(),
            fan_in,
        };
        let base_params = vec![
            spec("w1", vec![small.beta, small.alpha, small.p, small.p], "he", small.alpha * small.p * small.p),
            spec("b1", vec![small.beta], "zero", 0),
            spec("w2", vec![c2, small.beta, 3, 3], "he", small.beta * 9),
            spec("b2", vec![c2], "zero", 0),
            spec("w3", vec![c3, c2, 3, 3], "he", c2 * 9),
            spec("b3", vec![c3], "zero", 0),
            spec("wf1", vec![flat, f1], "he", flat),
            spec("bf1", vec![f1], "zero", 0),
            spec("wf2", vec![f1, num_classes], "he", f1),
            spec("bf2", vec![num_classes], "zero", 0),
        ];
        let aug_params: Vec<ParamSpec> = base_params[2..].to_vec();

        let f32sig = |shape: Vec<usize>| TensorSig { shape, dtype: DType::F32 };
        let i32sig = |shape: Vec<usize>| TensorSig { shape, dtype: DType::I32 };
        let psigs = |specs: &[ParamSpec]| -> Vec<TensorSig> {
            specs.iter().map(|s| f32sig(s.shape.clone())).collect()
        };

        let mut artifacts = BTreeMap::new();
        let mut add = |name: String, inputs: Vec<TensorSig>, outputs: Vec<TensorSig>, kind: &str, batch: usize, n_params: usize| {
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    path: format!("{name}.hlo.txt"),
                    name,
                    inputs,
                    outputs,
                    kind: kind.to_string(),
                    batch,
                    n_params,
                },
            );
        };

        // morphing (both geometries, same q/batch grid as aot.py)
        for (geo_name, geo, qs, bs) in [
            ("small", small, vec![48usize, 256, 768], vec![8usize, train_batch]),
            ("cifar", cifar, vec![96usize, 1024, 3072], vec![8usize]),
        ] {
            for &q in &qs {
                for &b in &bs {
                    add(
                        Self::morph_artifact(geo_name, q, b),
                        vec![f32sig(vec![b, geo.d_len()]), f32sig(vec![q, q])],
                        vec![f32sig(vec![b, geo.d_len()])],
                        "morph",
                        b,
                        0,
                    );
                }
            }
        }

        // Aug-Conv forward (serving / equivalence checks)
        for b in [eq_batch, 32] {
            add(
                format!("augconv_forward_small_b{b}"),
                vec![
                    f32sig(vec![b, small.d_len()]),
                    f32sig(vec![small.d_len(), small.f_len()]),
                    f32sig(vec![small.beta]),
                ],
                vec![f32sig(vec![b, small.beta, small.n(), small.n()])],
                "augconv_forward",
                b,
                0,
            );
        }

        // inference
        let nb = base_params.len();
        let na = aug_params.len();
        for &b in &infer_batches {
            let mut inputs = psigs(&base_params);
            inputs.push(f32sig(vec![b, small.alpha, small.m, small.m]));
            add(
                format!("infer_base_small_b{b}"),
                inputs,
                vec![f32sig(vec![b, num_classes])],
                "infer_base",
                b,
                nb,
            );
            let mut inputs = vec![
                f32sig(vec![small.d_len(), small.f_len()]),
                f32sig(vec![small.beta]),
            ];
            inputs.extend(psigs(&aug_params));
            inputs.push(f32sig(vec![b, small.d_len()]));
            add(
                format!("infer_aug_small_b{b}"),
                inputs,
                vec![f32sig(vec![b, num_classes])],
                "infer_aug",
                b,
                na,
            );
        }

        // evaluation (loss, acc on one labelled train-size batch)
        let bt = train_batch;
        let scalars = vec![f32sig(vec![]), f32sig(vec![])];
        let mut inputs = psigs(&base_params);
        inputs.push(f32sig(vec![bt, small.alpha, small.m, small.m]));
        inputs.push(i32sig(vec![bt]));
        add(format!("eval_base_small_b{bt}"), inputs, scalars.clone(), "eval_base", bt, nb);
        let mut inputs = vec![
            f32sig(vec![small.d_len(), small.f_len()]),
            f32sig(vec![small.beta]),
        ];
        inputs.extend(psigs(&aug_params));
        inputs.push(f32sig(vec![bt, small.d_len()]));
        inputs.push(i32sig(vec![bt]));
        add(format!("eval_aug_small_b{bt}"), inputs, scalars.clone(), "eval_aug", bt, na);

        // training steps: params, momenta, x, y, lr -> params', momenta', loss, acc
        let mut inputs = psigs(&base_params);
        inputs.extend(psigs(&base_params));
        inputs.push(f32sig(vec![bt, small.alpha, small.m, small.m]));
        inputs.push(i32sig(vec![bt]));
        inputs.push(f32sig(vec![]));
        let mut outputs = psigs(&base_params);
        outputs.extend(psigs(&base_params));
        outputs.extend(scalars.clone());
        add(format!("train_step_base_small_b{bt}"), inputs, outputs, "train_step_base", bt, nb);

        let mut inputs = vec![
            f32sig(vec![small.d_len(), small.f_len()]),
            f32sig(vec![small.beta]),
        ];
        inputs.extend(psigs(&aug_params));
        inputs.extend(psigs(&aug_params));
        inputs.push(f32sig(vec![bt, small.d_len()]));
        inputs.push(i32sig(vec![bt]));
        inputs.push(f32sig(vec![]));
        let mut outputs = psigs(&aug_params);
        outputs.extend(psigs(&aug_params));
        outputs.extend(scalars);
        add(format!("train_step_aug_small_b{bt}"), inputs, outputs, "train_step_aug", bt, na);

        Self {
            dir: dir.to_path_buf(),
            geometries,
            train_batch,
            infer_batches,
            eq_batch,
            num_classes,
            momentum: 0.9,
            base_params,
            aug_params,
            artifacts,
            from_disk: false,
        }
    }

    /// Look up an artifact by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("no artifact {name:?}")))
    }

    /// Absolute path of an artifact's HLO file.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.path))
    }

    /// The geometry by manifest name ("small" / "cifar").
    pub fn geometry(&self, name: &str) -> Result<Geometry> {
        self.geometries
            .get(name)
            .copied()
            .ok_or_else(|| Error::Manifest(format!("no geometry {name:?}")))
    }

    /// morph_apply artifact name for (geometry, q, batch).
    pub fn morph_artifact(geo_name: &str, q: usize, batch: usize) -> String {
        format!("morph_apply_{geo_name}_q{q}_b{batch}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // tests run from the crate root
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_manifest_with_builtin_fallback() {
        // with no manifest.json on disk this is the builtin contract;
        // with AOT artifacts present the parsed file must agree
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert_eq!(m.geometry("small").unwrap(), Geometry::SMALL);
        assert_eq!(m.geometry("cifar").unwrap(), Geometry::CIFAR_VGG16);
        assert_eq!(m.train_batch, 64);
        assert_eq!(m.base_params.len(), 10);
        assert_eq!(m.aug_params.len(), 8);
        // w1 comes first in base params and is absent from aug params
        assert_eq!(m.base_params[0].name, "w1");
        assert_eq!(m.aug_params[0].name, "w2");
    }

    #[test]
    fn artifact_signatures_consistent() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let g = m.geometry("small").unwrap();
        let a = m.artifact(&Manifest::morph_artifact("small", 48, 64)).unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![64, g.d_len()]);
        assert_eq!(a.inputs[1].shape, vec![48, 48]);
        assert_eq!(a.outputs[0].shape, vec![64, g.d_len()]);
        if m.from_disk() {
            // HLO text files only accompany an on-disk manifest
            assert!(m.artifact_path(&a.name).unwrap().exists());
        }

        let t = m.artifact("train_step_aug_small_b64").unwrap();
        // cac, b1p, 8 params, 8 momenta, t_r, y, lr = 21 inputs
        assert_eq!(t.inputs.len(), 21);
        assert_eq!(t.outputs.len(), 18);
        assert_eq!(t.n_params, 8);
        assert_eq!(t.inputs[20].shape, Vec::<usize>::new()); // lr scalar
        assert_eq!(t.inputs[19].dtype, DType::I32); // labels

        // train outputs echo the param specs, then loss + acc scalars
        assert_eq!(t.outputs[0].shape, m.aug_params[0].shape);
        assert_eq!(t.outputs[16].shape, Vec::<usize>::new());
    }

    #[test]
    fn builtin_matches_aot_grid() {
        let m = Manifest::builtin(&artifacts_dir());
        assert!(!m.from_disk());
        // the full morph grid exists for both geometries
        for (geo, q, b) in [
            ("small", 48usize, 8usize),
            ("small", 256, 64),
            ("small", 768, 64),
            ("cifar", 96, 8),
            ("cifar", 3072, 8),
        ] {
            assert!(
                m.artifact(&Manifest::morph_artifact(geo, q, b)).is_ok(),
                "missing morph artifact {geo} q={q} b={b}"
            );
        }
        for b in [1usize, 8, 32] {
            assert!(m.artifact(&format!("infer_aug_small_b{b}")).is_ok());
            assert!(m.artifact(&format!("infer_base_small_b{b}")).is_ok());
        }
        assert!(m.artifact("eval_base_small_b64").is_ok());
        assert!(m.artifact("train_step_base_small_b64").is_ok());
        // wf1 input size is the flattened pool output: 32 * (16/4)^2
        let wf1 = &m.base_params[6];
        assert_eq!(wf1.shape, vec![512, 64]);
        assert_eq!(m.num_classes, 10);
        assert!((m.momentum - 0.9).abs() < 1e-12);
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert!(m.artifact("nonexistent").is_err());
        assert!(m.geometry("huge").is_err());
    }
}
