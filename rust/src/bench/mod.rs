//! Bench harness (criterion is unavailable offline — DESIGN.md §5).
//!
//! Warmup + N timed trials with mean / p50 / p99 and a throughput helper;
//! benches print aligned table rows so `cargo bench` output maps 1:1 onto
//! the paper's tables and figures.

use std::time::{Duration, Instant};

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub trials: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
}

impl BenchResult {
    /// Items/second at `items` per invocation.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean.as_secs_f64()
    }
}

/// Time `f` with `warmup` untimed runs and `trials` timed runs.
pub fn bench<R>(name: &str, warmup: usize, trials: usize, mut f: impl FnMut() -> R) -> BenchResult {
    assert!(trials > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times: Vec<Duration> = Vec::with_capacity(trials);
    for _ in 0..trials {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    let mean = times.iter().sum::<Duration>() / trials as u32;
    let p50 = times[trials / 2];
    let p99 = times[(trials * 99 / 100).min(trials - 1)];
    BenchResult { name: name.to_string(), trials, mean, p50, p99 }
}

/// Auto-pick trial count so the bench takes roughly `budget`.
pub fn bench_auto<R>(name: &str, budget: Duration, mut f: impl FnMut() -> R) -> BenchResult {
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().max(Duration::from_micros(1));
    let trials = (budget.as_secs_f64() / one.as_secs_f64()).clamp(3.0, 1000.0) as usize;
    bench(name, 1, trials, f)
}

/// Pretty duration for table cells.
pub fn fmt_dur(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}µs")
    } else if us < 1e6 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.2}s", us / 1e6)
    }
}

/// Print a table header + separator.
pub fn table_header(cols: &[&str], widths: &[usize]) {
    let mut line = String::new();
    let mut sep = String::new();
    for (c, w) in cols.iter().zip(widths) {
        line.push_str(&format!("{c:>w$} ", w = w));
        sep.push_str(&format!("{:->w$} ", "", w = w));
    }
    println!("{line}");
    println!("{sep}");
}

/// Print one row of table cells right-aligned to widths.
pub fn table_row(cells: &[String], widths: &[usize]) {
    let mut line = String::new();
    for (c, w) in cells.iter().zip(widths) {
        line.push_str(&format!("{c:>w$} ", w = w));
    }
    println!("{line}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 10, || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.mean > Duration::ZERO);
        assert!(r.p99 >= r.p50);
        assert_eq!(r.trials, 10);
        assert!(r.throughput(10_000.0) > 0.0);
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with('s'));
    }
}
