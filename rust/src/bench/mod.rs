//! Bench harness (criterion is unavailable offline — DESIGN.md §5).
//!
//! Warmup + N timed trials with mean / p50 / p95 / p99 and a throughput
//! helper; benches print aligned table rows so `cargo bench` output maps
//! 1:1 onto the paper's tables and figures.
//!
//! The [`Report`] builder additionally serializes results through the
//! in-tree [`crate::json`] writer into `BENCH_<name>.json` at the repo
//! root (schema `mole-bench-v1`), so perf regressions are diffable by
//! machines — `scripts/perf_compare.sh` joins two such files — instead of
//! by eyeballing stdout tables. `MOLE_BENCH_OUT_DIR` redirects the output
//! directory; `MOLE_BENCH_BUDGET_MS` puts bench binaries in short-budget
//! (CI smoke) mode.

use crate::json::Value;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub trials: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
}

impl BenchResult {
    /// Items/second at `items` per invocation.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean.as_secs_f64()
    }
}

/// Time `f` with `warmup` untimed runs and `trials` timed runs.
pub fn bench<R>(name: &str, warmup: usize, trials: usize, mut f: impl FnMut() -> R) -> BenchResult {
    assert!(trials > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times: Vec<Duration> = Vec::with_capacity(trials);
    for _ in 0..trials {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    let mean = times.iter().sum::<Duration>() / trials as u32;
    let p50 = times[percentile_index(trials, 50)];
    let p95 = times[percentile_index(trials, 95)];
    let p99 = times[percentile_index(trials, 99)];
    BenchResult { name: name.to_string(), trials, mean, p50, p95, p99 }
}

/// Index of the nearest-rank percentile in a sorted sample of `n`
/// observations: `rank = ceil(p/100 · n)` clamped into `[1, n]`, then
/// 0-based. The same formula [`crate::metrics::Histogram`] uses, so a
/// bench p50 and a serving-histogram p50 mean the same order statistic —
/// the old p50 here was `times[n / 2]` (the *upper* median, unclamped),
/// which at the tiny trial counts of `MOLE_BENCH_BUDGET_MS` smoke runs
/// recorded a different, larger statistic than p95/p99's clamped form.
pub fn percentile_index(n: usize, p: usize) -> usize {
    debug_assert!(n > 0 && p >= 1 && p <= 100);
    let rank = (p * n).div_ceil(100);
    rank.clamp(1, n) - 1
}

/// Auto-pick trial count so the bench takes roughly `budget`.
pub fn bench_auto<R>(name: &str, budget: Duration, mut f: impl FnMut() -> R) -> BenchResult {
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().max(Duration::from_micros(1));
    let trials = (budget.as_secs_f64() / one.as_secs_f64()).clamp(3.0, 1000.0) as usize;
    bench(name, 1, trials, f)
}

/// True when `MOLE_BENCH_BUDGET_MS` is set: bench binaries shrink their
/// per-section budgets, trial counts and sweep sizes to smoke-test size
/// (the CI bench-smoke job sets it; local runs normally don't).
pub fn short_budget() -> bool {
    std::env::var_os("MOLE_BENCH_BUDGET_MS").is_some()
}

/// Per-section time budget: `MOLE_BENCH_BUDGET_MS` when set, else
/// `default_ms`.
pub fn budget(default_ms: u64) -> Duration {
    let ms = std::env::var("MOLE_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(default_ms);
    Duration::from_millis(ms.max(1))
}

/// Scale a trial/request count down under [`short_budget`]: returns
/// `full` normally, `max(1, full / 8)` in smoke mode.
pub fn scaled(full: usize) -> usize {
    if short_budget() {
        (full / 8).max(1)
    } else {
        full
    }
}

/// Machine-readable bench report (schema `mole-bench-v1`).
///
/// Collect rows with [`Report::push`] — start each from
/// [`Report::row`] for timed results, or build a [`BTreeMap`] by hand for
/// throughput-style entries — then [`Report::write`] emits
/// `BENCH_<bench>.json` with CPU/thread metadata attached:
///
/// ```json
/// {"schema": "mole-bench-v1", "bench": "hotpath",
///  "threads": 8, "cpu": {"arch": "x86_64", "cores": 8, "features": "avx2,fma"},
///  "results": [{"name": "gemm", "backend": "simd", "geometry": "64x768x768",
///               "trials": 40, "mean_us": ..., "p50_us": ..., "p95_us": ...,
///               "p99_us": ..., "gflops": ...}, ...]}
/// ```
#[derive(Debug, Clone)]
pub struct Report {
    bench: String,
    results: Vec<Value>,
}

impl Report {
    pub fn new(bench: &str) -> Self {
        Report { bench: bench.to_string(), results: Vec::new() }
    }

    /// Schema row for a timed result: name/backend/trials plus
    /// mean/p50/p95/p99 in microseconds. Extend with bench-specific keys
    /// (`gflops`, `geometry`, `speedup_vs_ref`, …) before pushing.
    pub fn row(r: &BenchResult, backend: &str) -> BTreeMap<String, Value> {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Value::Str(r.name.clone()));
        m.insert("backend".into(), Value::Str(backend.to_string()));
        m.insert("trials".into(), Value::Num(r.trials as f64));
        m.insert("mean_us".into(), Value::Num(us(r.mean)));
        m.insert("p50_us".into(), Value::Num(us(r.p50)));
        m.insert("p95_us".into(), Value::Num(us(r.p95)));
        m.insert("p99_us".into(), Value::Num(us(r.p99)));
        m
    }

    pub fn push(&mut self, row: BTreeMap<String, Value>) {
        self.results.push(Value::Obj(row));
    }

    pub fn len(&self) -> usize {
        self.results.len()
    }

    pub fn is_empty(&self) -> bool {
        self.results.is_empty()
    }

    /// The full document as a JSON value.
    pub fn to_json(&self) -> Value {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut cpu = BTreeMap::new();
        cpu.insert("arch".into(), Value::Str(std::env::consts::ARCH.to_string()));
        cpu.insert("cores".into(), Value::Num(cores as f64));
        cpu.insert("features".into(), Value::Str(crate::backend::cpu_features()));
        let mut top = BTreeMap::new();
        top.insert("schema".into(), Value::Str("mole-bench-v1".into()));
        top.insert("bench".into(), Value::Str(self.bench.clone()));
        top.insert("threads".into(), Value::Num(cores as f64));
        top.insert("cpu".into(), Value::Obj(cpu));
        top.insert("results".into(), Value::Arr(self.results.clone()));
        Value::Obj(top)
    }

    /// Write `BENCH_<bench>.json` into [`out_dir`]; returns the path.
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        let path = out_dir().join(format!("BENCH_{}.json", self.bench));
        std::fs::write(&path, crate::json::write(&self.to_json()) + "\n")?;
        Ok(path)
    }
}

/// Where `BENCH_*.json` files land: `MOLE_BENCH_OUT_DIR` when set, else
/// the repo root (one level above the cargo manifest).
pub fn out_dir() -> std::path::PathBuf {
    std::env::var_os("MOLE_BENCH_OUT_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/..")))
}

fn us(d: Duration) -> f64 {
    d.as_secs_f64() * 1e6
}

/// Pretty duration for table cells.
pub fn fmt_dur(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}µs")
    } else if us < 1e6 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.2}s", us / 1e6)
    }
}

/// Print a table header + separator.
pub fn table_header(cols: &[&str], widths: &[usize]) {
    let mut line = String::new();
    let mut sep = String::new();
    for (c, w) in cols.iter().zip(widths) {
        line.push_str(&format!("{c:>w$} ", w = w));
        sep.push_str(&format!("{:->w$} ", "", w = w));
    }
    println!("{line}");
    println!("{sep}");
}

/// Print one row of table cells right-aligned to widths.
pub fn table_row(cells: &[String], widths: &[usize]) {
    let mut line = String::new();
    for (c, w) in cells.iter().zip(widths) {
        line.push_str(&format!("{c:>w$} ", w = w));
    }
    println!("{line}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 10, || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.mean > Duration::ZERO);
        assert!(r.p99 >= r.p95);
        assert!(r.p95 >= r.p50);
        assert_eq!(r.trials, 10);
        assert!(r.throughput(10_000.0) > 0.0);
    }

    #[test]
    fn percentile_indices_pinned() {
        // nearest-rank: rank = ceil(p/100 · n), 0-based after clamp.
        // Pinned per ISSUE 10 for the trial counts smoke runs produce.
        for (n, i50, i95, i99) in [
            (1, 0, 0, 0),
            (2, 0, 1, 1),
            (4, 1, 3, 3),
            (5, 2, 4, 4),
            (100, 49, 94, 98),
        ] {
            assert_eq!(percentile_index(n, 50), i50, "p50 @ n={n}");
            assert_eq!(percentile_index(n, 95), i95, "p95 @ n={n}");
            assert_eq!(percentile_index(n, 99), i99, "p99 @ n={n}");
        }
        // the old p50 form `n / 2` (upper median) disagreed at every
        // even n — e.g. n=4 gave index 2, nearest-rank gives 1
        assert_ne!(percentile_index(4, 50), 4 / 2);
        // and always in bounds, p100 = max sample
        for n in 1..=128 {
            for p in 1..=100 {
                assert!(percentile_index(n, p) < n);
            }
            assert_eq!(percentile_index(n, 100), n - 1);
        }
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with('s'));
    }

    #[test]
    fn report_schema_shape() {
        let mut rep = Report::new("unit");
        assert!(rep.is_empty());
        let r = BenchResult {
            name: "gemm".into(),
            trials: 7,
            mean: Duration::from_micros(120),
            p50: Duration::from_micros(110),
            p95: Duration::from_micros(180),
            p99: Duration::from_micros(200),
        };
        let mut row = Report::row(&r, "simd");
        row.insert("gflops".into(), Value::Num(12.5));
        rep.push(row);
        assert_eq!(rep.len(), 1);

        // round-trip through the writer and check every schema key+type
        let doc = crate::json::parse(&crate::json::write(&rep.to_json())).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "mole-bench-v1");
        assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), "unit");
        assert!(doc.get("threads").unwrap().as_usize().unwrap() >= 1);
        let cpu = doc.get("cpu").unwrap();
        assert!(!cpu.get("arch").unwrap().as_str().unwrap().is_empty());
        assert!(cpu.get("cores").unwrap().as_usize().unwrap() >= 1);
        assert!(!cpu.get("features").unwrap().as_str().unwrap().is_empty());
        let rows = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        let row = &rows[0];
        assert_eq!(row.get("name").unwrap().as_str().unwrap(), "gemm");
        assert_eq!(row.get("backend").unwrap().as_str().unwrap(), "simd");
        assert_eq!(row.get("trials").unwrap().as_usize().unwrap(), 7);
        for key in ["mean_us", "p50_us", "p95_us", "p99_us", "gflops"] {
            assert!(row.get(key).unwrap().as_f64().unwrap() > 0.0, "{key}");
        }
        assert!((row.get("p95_us").unwrap().as_f64().unwrap() - 180.0).abs() < 1e-9);
    }

    #[test]
    fn report_writes_to_override_dir() {
        let dir = std::env::temp_dir().join(format!("mole_bench_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::env::set_var("MOLE_BENCH_OUT_DIR", &dir);
        let path = Report::new("unitwrite").write().unwrap();
        std::env::remove_var("MOLE_BENCH_OUT_DIR");
        assert_eq!(path, dir.join("BENCH_unitwrite.json"));
        let doc = crate::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), "unitwrite");
        assert!(doc.get("results").unwrap().as_arr().unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn budget_defaults_without_env() {
        // Note: doesn't set the env var (parallel tests share the
        // process); the default path is the only deterministic one here.
        assert_eq!(budget(250), Duration::from_millis(250));
    }
}
