//! Crate-wide error type.

/// Unified error for the MoLe crate.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Geometry constraint violated (κ divisibility, shape mismatch …).
    #[error("geometry error: {0}")]
    Geometry(String),

    /// Shape mismatch in tensor/linalg operations.
    #[error("shape error: {0}")]
    Shape(String),

    /// A matrix that must be invertible is (numerically) singular.
    #[error("singular matrix: {0}")]
    Singular(String),

    /// Key-vault / key-material errors (missing key, bad magic, tamper).
    #[error("key error: {0}")]
    Key(String),

    /// Delivery-protocol framing or state-machine violations.
    #[error("protocol error: {0}")]
    Protocol(String),

    /// Artifact manifest problems (missing artifact, bad signature).
    #[error("manifest error: {0}")]
    Manifest(String),

    /// PJRT runtime failures (compile, execute, literal conversion).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// JSON parse errors (mini parser in [`crate::json`]).
    #[error("json error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    /// Configuration file / CLI argument errors.
    #[error("config error: {0}")]
    Config(String),

    /// Anything I/O.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// Errors bubbled up from the xla crate.
    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Shape("expected [2,3] got [3,2]".into());
        assert!(e.to_string().contains("[2,3]"));
        let e = Error::Json { offset: 12, msg: "bad token".into() };
        assert!(e.to_string().contains("12"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
