//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the
//! default build carries no external crates, so there is no `thiserror`).

/// Unified error for the MoLe crate.
#[derive(Debug)]
pub enum Error {
    /// Geometry constraint violated (κ divisibility, shape mismatch …).
    Geometry(String),

    /// Shape mismatch in tensor/linalg operations.
    Shape(String),

    /// A matrix that must be invertible is (numerically) singular.
    Singular(String),

    /// Key-vault / key-material errors (missing key, bad magic, tamper).
    Key(String),

    /// Delivery-protocol framing or state-machine violations.
    Protocol(String),

    /// Peer speaks a different protocol version (negotiated in `Hello`).
    /// Kept distinct from [`Error::Protocol`] so sessions can answer with
    /// a typed `Fault` instead of a generic decode error.
    Version { got: u32, want: u32 },

    /// A serving lane refused new work: its key epoch is draining
    /// (rollover in progress). `successor` is the epoch to re-resolve
    /// to; `u32::MAX` (the latest-epoch sentinel) means "ask for the
    /// newest". Servers answer this with a typed `Fault::Draining` so
    /// clients can retry transparently instead of failing on a string.
    Draining { model: String, epoch: u32, successor: u32 },

    /// A serving lane is gone for good: its key epoch was retired after
    /// rollover completed. Same `successor` semantics as
    /// [`Error::Draining`].
    Retired { model: String, epoch: u32, successor: u32 },

    /// The serving plane shed this request (or connection) under load:
    /// the session budget, pending-accept budget, or a lane's bounded
    /// submit queue was full. Carries the server's backoff hint so
    /// clients can retry politely instead of hammering a saturated
    /// endpoint. Servers answer this with the typed `Fault::Overloaded`
    /// — never by silently parking the request.
    Overloaded { retry_after_ms: u64 },

    /// Admin-plane authentication failure: forged/absent MAC, replayed
    /// or reordered frame counter, unauthenticated admin frame on a
    /// credential-gated server, or an authenticated handshake against a
    /// server with no credential configured. Kept distinct from
    /// [`Error::Protocol`] so the wire can answer with the typed
    /// `Fault::AdminAuth` and tests can pin the exact refusal.
    AdminAuth(String),

    /// A bulk-delivery chunk failed its manifest integrity check: the
    /// SHA-256 computed while decoding the received bytes does not match
    /// the per-chunk hash the manifest promised (bit rot, truncation, or
    /// a lying sender). Carries the chunk index and both digests (hex)
    /// so a retry loop can name exactly what it is re-fetching. The
    /// delivery client retries a corrupt chunk once automatically before
    /// surfacing this.
    ChunkCorrupt { chunk: u64, want: String, got: String },

    /// Artifact manifest problems (missing artifact, bad signature).
    Manifest(String),

    /// Runtime failures (interpreter or PJRT: compile, execute, dispatch).
    Runtime(String),

    /// JSON parse errors (mini parser in [`crate::json`]).
    Json { offset: usize, msg: String },

    /// Configuration file / CLI argument errors.
    Config(String),

    /// Anything I/O.
    Io(std::io::Error),

    /// Errors bubbled up from the xla crate (`pjrt` feature builds).
    Xla(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Geometry(m) => write!(f, "geometry error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Singular(m) => write!(f, "singular matrix: {m}"),
            Error::Key(m) => write!(f, "key error: {m}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Version { got, want } => write!(
                f,
                "protocol version mismatch: peer speaks v{got}, this build speaks v{want}"
            ),
            Error::Draining { model, epoch, successor } => {
                write!(f, "model {model:?} epoch {epoch} is draining; ")?;
                successor_hint(f, *successor)
            }
            Error::Retired { model, epoch, successor } => {
                write!(f, "model {model:?} epoch {epoch} is retired; ")?;
                successor_hint(f, *successor)
            }
            Error::Overloaded { retry_after_ms } => write!(
                f,
                "server overloaded: request shed, retry after {retry_after_ms} ms"
            ),
            Error::AdminAuth(m) => write!(f, "admin auth error: {m}"),
            Error::ChunkCorrupt { chunk, want, got } => write!(
                f,
                "chunk {chunk} corrupt: sha256 mismatch (manifest {want}, received {got})"
            ),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Json { offset, msg } => write!(f, "json error at byte {offset}: {msg}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

/// Shared tail for the lifecycle errors (`u32::MAX` is the wire's
/// latest-epoch sentinel; error.rs stays independent of the coordinator,
/// so the constant is not imported here).
fn successor_hint(f: &mut std::fmt::Formatter<'_>, successor: u32) -> std::fmt::Result {
    if successor == u32::MAX {
        write!(f, "re-resolve to the latest epoch")
    } else {
        write!(f, "re-resolve to epoch {successor}")
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Shape("expected [2,3] got [3,2]".into());
        assert!(e.to_string().contains("[2,3]"));
        let e = Error::Json { offset: 12, msg: "bad token".into() };
        assert!(e.to_string().contains("12"));
    }

    #[test]
    fn version_mismatch_display() {
        let e = Error::Version { got: 1, want: 2 };
        assert!(e.to_string().contains("v1"));
        assert!(e.to_string().contains("v2"));
    }

    #[test]
    fn lifecycle_display_names_the_successor() {
        let e = Error::Draining { model: "alpha".into(), epoch: 0, successor: 1 };
        assert!(e.to_string().contains("draining"), "{e}");
        assert!(e.to_string().contains("epoch 1"), "{e}");
        let e = Error::Retired { model: "alpha".into(), epoch: 2, successor: u32::MAX };
        assert!(e.to_string().contains("retired"), "{e}");
        assert!(e.to_string().contains("latest epoch"), "{e}");
    }

    #[test]
    fn overloaded_display_names_the_backoff() {
        let e = Error::Overloaded { retry_after_ms: 25 };
        assert!(e.to_string().contains("overloaded"), "{e}");
        assert!(e.to_string().contains("25 ms"), "{e}");
    }

    #[test]
    fn admin_auth_display() {
        let e = Error::AdminAuth("MAC verification failed".into());
        assert!(e.to_string().contains("admin auth"), "{e}");
        assert!(e.to_string().contains("MAC"), "{e}");
    }

    #[test]
    fn chunk_corrupt_display_names_chunk_and_digests() {
        let e = Error::ChunkCorrupt {
            chunk: 7,
            want: "aa11".into(),
            got: "bb22".into(),
        };
        assert!(e.to_string().contains("chunk 7"), "{e}");
        assert!(e.to_string().contains("aa11"), "{e}");
        assert!(e.to_string().contains("bb22"), "{e}");
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
