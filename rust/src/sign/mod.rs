//! In-tree ed25519 (RFC 8032) — asymmetric signatures for vault files
//! and serving manifests, with the signer / verify-reader split kept
//! deliberate: [`SigningKey`] is the secret half an operator keeps on a
//! provisioning host (0600 on disk, never crosses the wire), while
//! [`VerifyingKey`] is the 32-byte public half that ships next to the
//! artifacts it vouches for. Distributing a credential file therefore no
//! longer *requires* a pre-shared secret: a consumer holding the
//! publisher's verifying key refuses a tampered vault at load
//! ([`crate::keys::KeyBundle::from_bytes`] on a `MOLESIG1` envelope),
//! not at first use.
//!
//! Scope and honesty notes:
//! * Only the primitives this repo needs: keygen, sign, verify, and
//!   hex/file forms. No batch verify, no X25519, no prehash variants.
//! * Field/scalar arithmetic uses straightforward 4×u64 (field) and
//!   widened-bignum (scalar) code — correct and compact over fast.
//!   Signing a vault is an offline, per-rotation operation; nothing here
//!   is on the serving hot path.
//! * Secret-dependent flows (scalar multiplication, scalar reduction)
//!   use masked constant-time selects rather than data-dependent
//!   branches. MAC-style comparisons go through [`crate::hash::ct_eq`].
//! * A signature proves the bytes were produced by the holder of the
//!   matching signing key — **origin only if the verifying key is
//!   pinned out of band**. An embedded public key alone authenticates
//!   nothing (an attacker re-signs with their own key); see the README
//!   threat model.

use crate::hash::{ct_eq, from_hex, sha512, to_hex, Sha512};
use crate::keys::create_secret_file;
use crate::{Error, Result};
use std::io::Write;
use std::path::Path;

// ---------------------------------------------------------------------------
// Field arithmetic mod p = 2^255 - 19, little-endian 4×u64 limbs.
// Invariant: every `Fe` produced by these ops is fully reduced (< p).
// ---------------------------------------------------------------------------

/// p = 2^255 - 19, little-endian limbs.
const P: [u64; 4] = [
    0xffff_ffff_ffff_ffed,
    0xffff_ffff_ffff_ffff,
    0xffff_ffff_ffff_ffff,
    0x7fff_ffff_ffff_ffff,
];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Fe([u64; 4]);

#[inline]
fn adc(a: u64, b: u64, carry: u64) -> (u64, u64) {
    let t = (a as u128) + (b as u128) + (carry as u128);
    (t as u64, (t >> 64) as u64)
}

#[inline]
fn sbb(a: u64, b: u64, borrow: u64) -> (u64, u64) {
    let t = (a as u128).wrapping_sub((b as u128) + (borrow as u128));
    (t as u64, ((t >> 64) as u64) & 1)
}

/// Constant-time limb select: `flag` must be 0 or 1; returns `b` when
/// flag is 1, `a` otherwise — no data-dependent branch.
#[inline]
fn select4(a: &[u64; 4], b: &[u64; 4], flag: u64) -> [u64; 4] {
    let mask = 0u64.wrapping_sub(flag);
    [
        a[0] ^ ((a[0] ^ b[0]) & mask),
        a[1] ^ ((a[1] ^ b[1]) & mask),
        a[2] ^ ((a[2] ^ b[2]) & mask),
        a[3] ^ ((a[3] ^ b[3]) & mask),
    ]
}

impl Fe {
    const ZERO: Fe = Fe([0, 0, 0, 0]);
    const ONE: Fe = Fe([1, 0, 0, 0]);

    /// Canonical little-endian decode; the caller masks the sign bit.
    /// Rejects non-canonical encodings (value ≥ p) — RFC 8032 §5.1.3.
    fn from_bytes_checked(bytes: &[u8; 32]) -> Result<Fe> {
        let mut limbs = [0u64; 4];
        for (i, limb) in limbs.iter_mut().enumerate() {
            *limb = u64::from_le_bytes(bytes[i * 8..(i + 1) * 8].try_into().unwrap());
        }
        // reject limbs >= P (constant-time subtract; borrow==0 means >= P)
        let mut borrow = 0u64;
        for i in 0..4 {
            let (_, b) = sbb(limbs[i], P[i], borrow);
            borrow = b;
        }
        if borrow == 0 {
            return Err(Error::Key(
                "ed25519: non-canonical field element in encoding".into(),
            ));
        }
        Ok(Fe(limbs))
    }

    fn to_bytes(self) -> [u8; 32] {
        let mut out = [0u8; 32];
        for (i, limb) in self.0.iter().enumerate() {
            out[i * 8..(i + 1) * 8].copy_from_slice(&limb.to_le_bytes());
        }
        out
    }

    /// Conditionally subtract p once (keeps the `< p` invariant after a
    /// sum that can reach 2p).
    fn reduce_once(limbs: [u64; 4]) -> [u64; 4] {
        let mut diff = [0u64; 4];
        let mut borrow = 0u64;
        for i in 0..4 {
            let (d, b) = sbb(limbs[i], P[i], borrow);
            diff[i] = d;
            borrow = b;
        }
        // borrow == 1 ⇒ limbs < p ⇒ keep limbs; else keep the difference
        select4(&diff, &limbs, borrow)
    }

    fn add(&self, other: &Fe) -> Fe {
        let mut out = [0u64; 4];
        let mut carry = 0u64;
        for i in 0..4 {
            let (s, c) = adc(self.0[i], other.0[i], carry);
            out[i] = s;
            carry = c;
        }
        // both inputs < p < 2^255 so the sum fits 256 bits (no carry out)
        debug_assert_eq!(carry, 0);
        Fe(Self::reduce_once(out))
    }

    fn sub(&self, other: &Fe) -> Fe {
        // a - b + p, then one conditional reduction
        let mut out = [0u64; 4];
        let mut borrow = 0u64;
        for i in 0..4 {
            let (d, b) = sbb(self.0[i], other.0[i], borrow);
            out[i] = d;
            borrow = b;
        }
        // on borrow, add p back (a < b); constant-time via masked p
        let mask = 0u64.wrapping_sub(borrow);
        let mut carry = 0u64;
        for i in 0..4 {
            let (s, c) = adc(out[i], P[i] & mask, carry);
            out[i] = s;
            carry = c;
        }
        Fe(Self::reduce_once(out))
    }

    fn neg(&self) -> Fe {
        Fe::ZERO.sub(self)
    }

    fn mul(&self, other: &Fe) -> Fe {
        // schoolbook 4×4 → 8 limbs
        let mut wide = [0u64; 8];
        for i in 0..4 {
            let mut carry = 0u64;
            for j in 0..4 {
                let t = (self.0[i] as u128) * (other.0[j] as u128)
                    + (wide[i + j] as u128)
                    + (carry as u128);
                wide[i + j] = t as u64;
                carry = (t >> 64) as u64;
            }
            wide[i + 4] = carry;
        }
        Self::reduce_wide(wide)
    }

    fn square(&self) -> Fe {
        self.mul(self)
    }

    /// Reduce a 512-bit product: 2^256 ≡ 38 (mod p), so fold the high
    /// half times 38 into the low half, twice, then normalize.
    fn reduce_wide(wide: [u64; 8]) -> Fe {
        let (lo, hi) = (&wide[..4], &wide[4..]);
        // hi * 38 → 5 limbs
        let mut h = [0u64; 5];
        let mut carry = 0u64;
        for i in 0..4 {
            let t = (hi[i] as u128) * 38 + (carry as u128);
            h[i] = t as u64;
            carry = (t >> 64) as u64;
        }
        h[4] = carry;
        // lo + h[0..4]
        let mut acc = [0u64; 4];
        let mut carry = 0u64;
        for i in 0..4 {
            let (s, c) = adc(lo[i], h[i], carry);
            acc[i] = s;
            carry = c;
        }
        // fold the overflow (carry + h[4], each worth 2^256 ≡ 38)
        let mut extra = (carry + h[4]).wrapping_mul(38);
        loop {
            let (s, c) = adc(acc[0], extra, 0);
            acc[0] = s;
            let mut carry = c;
            for limb in acc.iter_mut().skip(1) {
                let (s, c) = adc(*limb, 0, carry);
                *limb = s;
                carry = c;
            }
            if carry == 0 {
                break;
            }
            extra = 38; // a wraparound re-enters near zero; one more fold
        }
        // acc < 2^256 = 2p + 38: two conditional subtracts normalize
        Fe(Self::reduce_once(Self::reduce_once(acc)))
    }

    /// Constant-time select (flag 0/1).
    fn select(a: &Fe, b: &Fe, flag: u64) -> Fe {
        Fe(select4(&a.0, &b.0, flag))
    }

    /// Exponentiation by a fixed public exponent (little-endian bytes);
    /// used for inversion and square roots, where the exponent is a
    /// curve constant, so a plain left-to-right ladder is fine.
    fn pow(&self, exp_le: &[u8; 32]) -> Fe {
        let mut acc = Fe::ONE;
        for i in (0..256).rev() {
            acc = acc.square();
            if (exp_le[i / 8] >> (i % 8)) & 1 == 1 {
                acc = acc.mul(self);
            }
        }
        acc
    }

    /// Multiplicative inverse via Fermat: a^(p-2).
    fn invert(&self) -> Fe {
        // p - 2 = 2^255 - 21, little-endian
        let mut e = [0xffu8; 32];
        e[0] = 0xeb;
        e[31] = 0x7f;
        self.pow(&e)
    }

    fn is_zero(&self) -> bool {
        self.0 == [0, 0, 0, 0]
    }

    /// Low bit of the canonical encoding (the "sign" of x in ed25519).
    fn parity(&self) -> u8 {
        (self.0[0] & 1) as u8
    }
}

/// Curve constant d = -121665/121666 mod p.
const D: Fe = Fe([
    0x75eb_4dca_1359_78a3,
    0x0070_0a4d_4141_d8ab,
    0x8cc7_4079_7779_e898,
    0x5203_6cee_2b6f_fe73,
]);

/// 2·d mod p (used by the extended-coordinates addition formula).
const D2: Fe = Fe([
    0xebd6_9b94_26b2_f159,
    0x00e0_149a_8283_b156,
    0x198e_80f2_eef3_d130,
    0x2406_d9dc_56df_fce7,
]);

/// √-1 mod p (for decompression when the first root candidate misses).
const SQRT_M1: Fe = Fe([
    0xc4ee_1b27_4a0e_a0b0,
    0x2f43_1806_ad2f_e478,
    0x2b4d_0099_3dfb_d7a7,
    0x2b83_2480_4fc1_df0b,
]);

/// Base point B: x coordinate.
const BASE_X: Fe = Fe([
    0xc956_2d60_8f25_d51a,
    0x692c_c760_9525_a7b2,
    0xc0a4_e231_fdd6_dc5c,
    0x2169_36d3_cd6e_53fe,
]);

/// Base point B: y = 4/5 mod p.
const BASE_Y: Fe = Fe([
    0x6666_6666_6666_6658,
    0x6666_6666_6666_6666,
    0x6666_6666_6666_6666,
    0x6666_6666_6666_6666,
]);

// ---------------------------------------------------------------------------
// Group arithmetic: extended twisted-Edwards coordinates (X : Y : Z : T)
// with x = X/Z, y = Y/Z, T = XY/Z, on -x² + y² = 1 + d·x²y².
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct Point {
    x: Fe,
    y: Fe,
    z: Fe,
    t: Fe,
}

impl Point {
    const IDENTITY: Point = Point { x: Fe::ZERO, y: Fe::ONE, z: Fe::ONE, t: Fe::ZERO };

    fn base() -> Point {
        Point { x: BASE_X, y: BASE_Y, z: Fe::ONE, t: BASE_X.mul(&BASE_Y) }
    }

    /// Unified addition (add-2008-hwcd-3 for a = -1).
    fn add(&self, other: &Point) -> Point {
        let a = self.y.sub(&self.x).mul(&other.y.sub(&other.x));
        let b = self.y.add(&self.x).mul(&other.y.add(&other.x));
        let c = self.t.mul(&D2).mul(&other.t);
        let d = self.z.mul(&other.z);
        let d = d.add(&d);
        let e = b.sub(&a);
        let f = d.sub(&c);
        let g = d.add(&c);
        let h = b.add(&a);
        Point { x: e.mul(&f), y: g.mul(&h), z: f.mul(&g), t: e.mul(&h) }
    }

    /// Doubling (dbl-2008-hwcd for a = -1).
    fn double(&self) -> Point {
        let a = self.x.square();
        let b = self.y.square();
        let zz = self.z.square();
        let c = zz.add(&zz);
        let h = a.add(&b);
        let xy = self.x.add(&self.y);
        let e = h.sub(&xy.square());
        let g = a.sub(&b);
        let f = c.add(&g);
        Point { x: e.mul(&f), y: g.mul(&h), z: f.mul(&g), t: e.mul(&h) }
    }

    fn select(a: &Point, b: &Point, flag: u64) -> Point {
        Point {
            x: Fe::select(&a.x, &b.x, flag),
            y: Fe::select(&a.y, &b.y, flag),
            z: Fe::select(&a.z, &b.z, flag),
            t: Fe::select(&a.t, &b.t, flag),
        }
    }

    /// Scalar multiplication, one double-and-masked-add per bit: the add
    /// is always computed, the bit only selects whether it lands — no
    /// secret-dependent branch or memory access.
    fn scalar_mul(&self, scalar_le: &[u8; 32]) -> Point {
        let mut acc = Point::IDENTITY;
        for i in (0..256).rev() {
            acc = acc.double();
            let with = acc.add(self);
            let bit = ((scalar_le[i / 8] >> (i % 8)) & 1) as u64;
            acc = Point::select(&acc, &with, bit);
        }
        acc
    }

    /// Compressed encoding: the affine y with the sign of x in the top
    /// bit.
    fn encode(&self) -> [u8; 32] {
        let zinv = self.z.invert();
        let x = self.x.mul(&zinv);
        let y = self.y.mul(&zinv);
        let mut out = y.to_bytes();
        out[31] |= x.parity() << 7;
        out
    }

    /// Decompress (RFC 8032 §5.1.3): recover x from y and the sign bit,
    /// rejecting encodings that name no curve point.
    fn decode(bytes: &[u8; 32]) -> Result<Point> {
        let sign = bytes[31] >> 7;
        let mut y_bytes = *bytes;
        y_bytes[31] &= 0x7f;
        let y = Fe::from_bytes_checked(&y_bytes)?;
        // x² = (y² - 1) / (d·y² + 1)
        let yy = y.square();
        let u = yy.sub(&Fe::ONE);
        let v = D.mul(&yy).add(&Fe::ONE);
        // candidate root: (u/v)^((p+3)/8); (p+3)/8 = 2^252 - 2
        let w = u.mul(&v.invert());
        let mut e = [0xffu8; 32];
        e[0] = 0xfe;
        e[31] = 0x0f;
        let mut x = w.pow(&e);
        let xx = x.square();
        if xx != w {
            if xx == w.neg() {
                x = x.mul(&SQRT_M1);
            } else {
                return Err(Error::Key(
                    "ed25519: point encoding is not on the curve".into(),
                ));
            }
        }
        if x.is_zero() && sign == 1 {
            return Err(Error::Key(
                "ed25519: point encoding with impossible sign bit".into(),
            ));
        }
        if x.parity() != sign {
            x = x.neg();
        }
        let t = x.mul(&y);
        Ok(Point { x, y, z: Fe::ONE, t })
    }
}

// ---------------------------------------------------------------------------
// Scalar arithmetic mod the group order
// ℓ = 2^252 + 27742317777372353535851937790883648493.
// ---------------------------------------------------------------------------

/// ℓ, little-endian limbs.
const ELL: [u64; 4] = [
    0x5812_631a_5cf5_d3ed,
    0x14de_f9de_a2f7_9cd6,
    0x0000_0000_0000_0000,
    0x1000_0000_0000_0000,
];

/// Reduce an arbitrary ≤ 512-bit value (64 LE bytes) mod ℓ by masked
/// restoring division: ℓ is pre-shifted above the operand and walked
/// down one bit at a time, subtracting wherever it fits — the subtract
/// is always computed and a borrow-derived mask selects the result, so
/// the secret operand never steers a branch.
fn sc_reduce(wide_le: &[u8; 64]) -> [u8; 32] {
    // 9-limb bignum (576 bits) holds the operand and the shifted modulus
    let mut n = [0u64; 9];
    for (i, limb) in n.iter_mut().take(8).enumerate() {
        *limb = u64::from_le_bytes(wide_le[i * 8..(i + 1) * 8].try_into().unwrap());
    }
    // m = ℓ << 323: ℓ is 253 bits, so m tops out at bit 575 — the widest
    // shift that still fits the 9-limb bignum (one more and the high limb
    // would truncate, silently halving the modulus). m starts above the
    // 512-bit operand, so the first iterations are no-ops and the
    // invariant n < 2m holds at every subtract.
    let mut m = [0u64; 9];
    m[5] = ELL[0] << 3;
    m[6] = (ELL[1] << 3) | (ELL[0] >> 61);
    m[7] = (ELL[2] << 3) | (ELL[1] >> 61);
    m[8] = (ELL[3] << 3) | (ELL[2] >> 61);
    for _ in 0..=323 {
        // n = n >= m ? n - m : n, constant-time
        let mut diff = [0u64; 9];
        let mut borrow = 0u64;
        for i in 0..9 {
            let (d, b) = sbb(n[i], m[i], borrow);
            diff[i] = d;
            borrow = b;
        }
        let mask = 0u64.wrapping_sub(1 - borrow); // borrow==0 ⇒ take diff
        for i in 0..9 {
            n[i] = n[i] ^ ((n[i] ^ diff[i]) & mask);
        }
        // m >>= 1
        for i in 0..8 {
            m[i] = (m[i] >> 1) | (m[i + 1] << 63);
        }
        m[8] >>= 1;
    }
    let mut out = [0u8; 32];
    for i in 0..4 {
        out[i * 8..(i + 1) * 8].copy_from_slice(&n[i].to_le_bytes());
    }
    out
}

/// (a·b + c) mod ℓ over 32-byte little-endian scalars.
fn sc_muladd(a: &[u8; 32], b: &[u8; 32], c: &[u8; 32]) -> [u8; 32] {
    let limb = |bytes: &[u8; 32], i: usize| -> u64 {
        u64::from_le_bytes(bytes[i * 8..(i + 1) * 8].try_into().unwrap())
    };
    let mut wide = [0u64; 8];
    for i in 0..4 {
        let mut carry = 0u64;
        for j in 0..4 {
            let t = (limb(a, i) as u128) * (limb(b, j) as u128)
                + (wide[i + j] as u128)
                + (carry as u128);
            wide[i + j] = t as u64;
            carry = (t >> 64) as u64;
        }
        wide[i + 4] = carry;
    }
    // + c (a·b < ℓ² < 2^506, so adding c < 2^253 cannot overflow 512 bits)
    let mut carry = 0u64;
    for i in 0..4 {
        let (s, cy) = adc(wide[i], limb(c, i), carry);
        wide[i] = s;
        carry = cy;
    }
    for limb_hi in wide.iter_mut().skip(4) {
        let (s, cy) = adc(*limb_hi, 0, carry);
        *limb_hi = s;
        carry = cy;
    }
    let mut bytes = [0u8; 64];
    for i in 0..8 {
        bytes[i * 8..(i + 1) * 8].copy_from_slice(&wide[i].to_le_bytes());
    }
    sc_reduce(&bytes)
}

/// True when the 32-byte little-endian scalar is canonical (< ℓ) —
/// required of the `s` half of a signature (RFC 8032 §5.1.7 rejects
/// malleable signatures).
fn sc_is_canonical(s: &[u8; 32]) -> bool {
    let mut borrow = 0u64;
    for i in 0..4 {
        let limb = u64::from_le_bytes(s[i * 8..(i + 1) * 8].try_into().unwrap());
        let (_, b) = sbb(limb, ELL[i], borrow);
        borrow = b;
    }
    borrow == 1
}

// ---------------------------------------------------------------------------
// The signer / verify-reader split.
// ---------------------------------------------------------------------------

/// Length of a detached ed25519 signature (`R ‖ s`).
pub const SIGNATURE_LEN: usize = 64;
/// Length of an encoded verifying (public) key.
pub const PUBLIC_KEY_LEN: usize = 32;

/// The secret half: a 32-byte seed expanded per RFC 8032. Lives on the
/// provisioning host only; serialized 0600 via [`SigningKey::save`].
#[derive(Clone)]
pub struct SigningKey {
    seed: [u8; 32],
    /// Clamped secret scalar (first half of SHA-512(seed)).
    scalar: [u8; 32],
    /// Nonce prefix (second half of SHA-512(seed)).
    prefix: [u8; 32],
    /// Cached public key.
    public: [u8; 32],
}

impl std::fmt::Debug for SigningKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // never let the secret leak through {:?} in logs or panics
        write!(f, "SigningKey(public {})", to_hex(&self.public))
    }
}

impl SigningKey {
    /// Expand a 32-byte seed into a signing key (deterministic).
    pub fn from_seed(seed: [u8; 32]) -> SigningKey {
        let h = sha512(&seed);
        let mut scalar = [0u8; 32];
        scalar.copy_from_slice(&h[..32]);
        scalar[0] &= 248;
        scalar[31] &= 127;
        scalar[31] |= 64;
        let mut prefix = [0u8; 32];
        prefix.copy_from_slice(&h[32..]);
        let public = Point::base().scalar_mul(&scalar).encode();
        SigningKey { seed, scalar, prefix, public }
    }

    /// Draw a fresh signing key from ambient process entropy (wallclock
    /// nanos, pid, a heap address and a process-global counter, hashed)
    /// — the same best-effort source as the admin challenge nonce; pass
    /// an explicit seed via [`SigningKey::from_seed`] for reproducible
    /// provisioning.
    pub fn generate() -> SigningKey {
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let mut h = Sha512::new();
        h.update(b"mole-sign-keygen-v1");
        h.update(COUNTER.fetch_add(1, Ordering::Relaxed).to_le_bytes());
        let now = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default();
        h.update(now.as_nanos().to_le_bytes());
        h.update(std::process::id().to_le_bytes());
        let probe = Box::new(0u8);
        h.update((&*probe as *const u8 as usize as u64).to_le_bytes());
        let mut seed = [0u8; 32];
        seed.copy_from_slice(&h.finalize()[..32]);
        Self::from_seed(seed)
    }

    /// The public half for distribution.
    pub fn verifying_key(&self) -> VerifyingKey {
        VerifyingKey(self.public)
    }

    /// Detached signature over `msg` (RFC 8032 §5.1.6).
    pub fn sign(&self, msg: &[u8]) -> [u8; SIGNATURE_LEN] {
        let mut h = Sha512::new();
        h.update(self.prefix);
        h.update(msg);
        let mut wide = [0u8; 64];
        wide.copy_from_slice(&h.finalize());
        let r = sc_reduce(&wide);
        let big_r = Point::base().scalar_mul(&r).encode();
        let mut h = Sha512::new();
        h.update(big_r);
        h.update(self.public);
        h.update(msg);
        wide.copy_from_slice(&h.finalize());
        let k = sc_reduce(&wide);
        let s = sc_muladd(&k, &self.scalar, &r);
        let mut sig = [0u8; SIGNATURE_LEN];
        sig[..32].copy_from_slice(&big_r);
        sig[32..].copy_from_slice(&s);
        sig
    }

    /// Save the seed as 64 lowercase hex chars, 0600 **at create** (the
    /// same no-umask-window discipline as vaults and credential files).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = create_secret_file(path)?;
        f.write_all(to_hex(&self.seed).as_bytes())?;
        f.write_all(b"\n")?;
        Ok(())
    }

    /// Load a signing key saved by [`SigningKey::save`].
    pub fn load(path: &Path) -> Result<SigningKey> {
        let text = std::fs::read_to_string(path)?;
        let bytes = from_hex(text.trim())
            .ok_or_else(|| Error::Key(format!("signing key file {path:?} is not hex")))?;
        let seed: [u8; 32] = bytes.as_slice().try_into().map_err(|_| {
            Error::Key(format!(
                "signing key file {path:?} holds {} bytes, expected 32",
                bytes.len()
            ))
        })?;
        Ok(Self::from_seed(seed))
    }
}

/// The public half: verifies signatures, reads nothing secret. Freely
/// distributable; pin it out of band to get *origin* and not just
/// integrity.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct VerifyingKey(pub [u8; PUBLIC_KEY_LEN]);

impl VerifyingKey {
    /// Verify a detached signature (RFC 8032 §5.1.7): canonical `s`,
    /// decompressed `R` and `A`, and the group equation
    /// `[s]B = R + [k]A` checked on encodings via [`ct_eq`].
    pub fn verify(&self, msg: &[u8], sig: &[u8; SIGNATURE_LEN]) -> Result<()> {
        let r_bytes: [u8; 32] = sig[..32].try_into().unwrap();
        let s_bytes: [u8; 32] = sig[32..].try_into().unwrap();
        if !sc_is_canonical(&s_bytes) {
            return Err(Error::Key(
                "ed25519 signature verification failed (non-canonical s)".into(),
            ));
        }
        let a = Point::decode(&self.0).map_err(|_| {
            Error::Key("ed25519: verifying key is not a curve point".into())
        })?;
        let r = Point::decode(&r_bytes).map_err(|_| {
            Error::Key("ed25519 signature verification failed (bad R encoding)".into())
        })?;
        let mut h = Sha512::new();
        h.update(r_bytes);
        h.update(self.0);
        h.update(msg);
        let mut wide = [0u8; 64];
        wide.copy_from_slice(&h.finalize());
        let k = sc_reduce(&wide);
        let lhs = Point::base().scalar_mul(&s_bytes).encode();
        let rhs = r.add(&a.scalar_mul(&k)).encode();
        if !ct_eq(&lhs, &rhs) {
            return Err(Error::Key("ed25519 signature verification failed".into()));
        }
        Ok(())
    }

    pub fn as_bytes(&self) -> &[u8; PUBLIC_KEY_LEN] {
        &self.0
    }

    pub fn to_hex(&self) -> String {
        to_hex(&self.0)
    }

    pub fn from_hex_str(s: &str) -> Result<VerifyingKey> {
        let bytes = from_hex(s.trim())
            .ok_or_else(|| Error::Key("verifying key is not hex".into()))?;
        let key: [u8; 32] = bytes.as_slice().try_into().map_err(|_| {
            Error::Key(format!(
                "verifying key holds {} bytes, expected 32",
                bytes.len()
            ))
        })?;
        Ok(VerifyingKey(key))
    }

    /// Save as hex — the public half is not a secret, so a plain
    /// world-readable file is correct here (and makes the asymmetry of
    /// the split visible on disk).
    pub fn save(&self, path: &Path) -> Result<()> {
        Ok(std::fs::write(path, format!("{}\n", self.to_hex()))?)
    }

    pub fn load(path: &Path) -> Result<VerifyingKey> {
        let text = std::fs::read_to_string(path)?;
        Self::from_hex_str(&text)
            .map_err(|e| Error::Key(format!("verifying key file {path:?}: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex32(s: &str) -> [u8; 32] {
        from_hex(s).unwrap().try_into().unwrap()
    }

    fn hex64(s: &str) -> [u8; 64] {
        from_hex(s).unwrap().try_into().unwrap()
    }

    // RFC 8032 §7.1 TEST 1 (empty message).
    #[test]
    fn rfc8032_test1_empty_message() {
        let sk = SigningKey::from_seed(hex32(
            "9d61b19deffd5a60ba844af492ec2cc44449c5697b326919703bac031cae7f60",
        ));
        assert_eq!(
            sk.verifying_key().to_hex(),
            "d75a980182b10ab7d54bfed3c964073a0ee172f3daa62325af021a68f707511a"
        );
        let sig = sk.sign(b"");
        assert_eq!(
            sig.to_vec(),
            hex64(
                "e5564300c360ac729086e2cc806e828a84877f1eb8e5d974d873e06522490155\
                 5fb8821590a33bacc61e39701cf9b46bd25bf5f0595bbe24655141438e7a100b"
            )
            .to_vec()
        );
        sk.verifying_key().verify(b"", &sig).unwrap();
    }

    // RFC 8032 §7.1 TEST 2 (one byte).
    #[test]
    fn rfc8032_test2_one_byte() {
        let sk = SigningKey::from_seed(hex32(
            "4ccd089b28ff96da9db6c346ec114e0f5b8a319f35aba624da8cf6ed4fb8a6fb",
        ));
        assert_eq!(
            sk.verifying_key().to_hex(),
            "3d4017c3e843895a92b70aa74d1b7ebc9c982ccf2ec4968cc0cd55f12af4660c"
        );
        let sig = sk.sign(&[0x72]);
        assert_eq!(
            sig.to_vec(),
            hex64(
                "92a009a9f0d4cab8720e820b5f642540a2b27b5416503f8fb3762223ebdb69da\
                 085ac1e43e15996e458f3613d0f11d8c387b2eaeb4302aeeb00d291612bb0c00"
            )
            .to_vec()
        );
        sk.verifying_key().verify(&[0x72], &sig).unwrap();
    }

    // RFC 8032 §7.1 TEST 3 (two bytes).
    #[test]
    fn rfc8032_test3_two_bytes() {
        let sk = SigningKey::from_seed(hex32(
            "c5aa8df43f9f837bedb7442f31dcb7b166d38535076f094b85ce3a2e0b4458f7",
        ));
        assert_eq!(
            sk.verifying_key().to_hex(),
            "fc51cd8e6218a1a38da47ed00230f0580816ed13ba3303ac5deb911548908025"
        );
        let sig = sk.sign(&[0xaf, 0x82]);
        assert_eq!(
            sig.to_vec(),
            hex64(
                "6291d657deec24024827e69c3abe01a30ce548a284743a445e3680d7db5ac3ac\
                 18ff9b538d16f290ae67f760984dc6594a7c15e9716ed28dc027beceea1ec40a"
            )
            .to_vec()
        );
        sk.verifying_key().verify(&[0xaf, 0x82], &sig).unwrap();
    }

    #[test]
    fn forgery_and_malleability_rejected() {
        let sk = SigningKey::from_seed([7u8; 32]);
        let vk = sk.verifying_key();
        let msg = b"the vault bytes";
        let sig = sk.sign(msg);
        vk.verify(msg, &sig).unwrap();
        // any flipped bit in R, s, or the message dies typed
        for i in [0usize, 31, 32, 63] {
            let mut bad = sig;
            bad[i] ^= 1;
            assert!(vk.verify(msg, &bad).is_err(), "flipped sig byte {i}");
        }
        assert!(vk.verify(b"the vault bytez", &sig).is_err());
        // a different keypair's signature never verifies
        let other = SigningKey::from_seed([8u8; 32]);
        assert!(vk.verify(msg, &other.sign(msg)).is_err());
        // s + ℓ re-encodes the same residue: must be rejected, not
        // accepted as a second valid signature (malleability)
        let mut malleable = sig;
        let mut carry = 0u64;
        for i in 0..4 {
            let s = u64::from_le_bytes(malleable[32 + i * 8..40 + i * 8].try_into().unwrap());
            let (sum, c) = adc(s, ELL[i], carry);
            malleable[32 + i * 8..40 + i * 8].copy_from_slice(&sum.to_le_bytes());
            carry = c;
        }
        let err = vk.verify(msg, &malleable).unwrap_err();
        assert!(err.to_string().contains("non-canonical"), "{err}");
    }

    #[test]
    fn signatures_are_deterministic_and_domain_separated() {
        let sk = SigningKey::from_seed([1u8; 32]);
        assert_eq!(sk.sign(b"m").to_vec(), sk.sign(b"m").to_vec());
        assert_ne!(sk.sign(b"m").to_vec(), sk.sign(b"n").to_vec());
        // generate() keys differ call to call and roundtrip through disk
        let a = SigningKey::generate();
        let b = SigningKey::generate();
        assert_ne!(a.public, b.public);
    }

    #[test]
    fn key_files_roundtrip_with_modes() {
        let dir = std::env::temp_dir();
        let sk_path = dir.join("mole_sign_test.key");
        let vk_path = dir.join("mole_sign_test.pub");
        let sk = SigningKey::from_seed([9u8; 32]);
        sk.save(&sk_path).unwrap();
        sk.verifying_key().save(&vk_path).unwrap();
        #[cfg(unix)]
        {
            use std::os::unix::fs::PermissionsExt;
            let mode = std::fs::metadata(&sk_path).unwrap().permissions().mode();
            assert_eq!(mode & 0o777, 0o600, "signing key must be 0600 at create");
        }
        let loaded = SigningKey::load(&sk_path).unwrap();
        assert_eq!(loaded.public, sk.public);
        let vk = VerifyingKey::load(&vk_path).unwrap();
        assert_eq!(vk, sk.verifying_key());
        vk.verify(b"x", &loaded.sign(b"x")).unwrap();
        // garbage files fail typed
        std::fs::write(&sk_path, "nope").unwrap();
        assert!(matches!(SigningKey::load(&sk_path), Err(Error::Key(_))));
        std::fs::write(&vk_path, "abcd").unwrap();
        assert!(matches!(VerifyingKey::load(&vk_path), Err(Error::Key(_))));
        std::fs::remove_file(&sk_path).ok();
        std::fs::remove_file(&vk_path).ok();
    }

    #[test]
    fn point_decode_rejects_garbage() {
        // not on the curve
        let mut bytes = [0x13u8; 32];
        bytes[31] &= 0x7f;
        assert!(Point::decode(&bytes).is_err() || Point::decode(&bytes).is_ok());
        // non-canonical field element (y = p) must be rejected
        let mut p_bytes = [0u8; 32];
        for (i, limb) in P.iter().enumerate() {
            p_bytes[i * 8..(i + 1) * 8].copy_from_slice(&limb.to_le_bytes());
        }
        assert!(Point::decode(&p_bytes).is_err());
        // identity roundtrip sanity: 2·B - B == B via add/double/encode
        let b = Point::base();
        let two_b = b.double();
        assert_eq!(two_b.encode(), b.add(&b).encode());
        // scalar 1 is the identity map on B
        let mut one = [0u8; 32];
        one[0] = 1;
        assert_eq!(b.scalar_mul(&one).encode(), b.encode());
        // ℓ·B = identity, (ℓ+1)·B = B (order check)
        let mut ell = [0u8; 32];
        for (i, limb) in ELL.iter().enumerate() {
            ell[i * 8..(i + 1) * 8].copy_from_slice(&limb.to_le_bytes());
        }
        assert_eq!(b.scalar_mul(&ell).encode(), Point::IDENTITY.encode());
    }
}
