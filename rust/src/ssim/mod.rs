//! Structural similarity (SSIM) index — Wang et al. 2004.
//!
//! The paper uses SSIM between the original image **D** and the morphed
//! image **T** to quantify privacy-preserving effectiveness (fig. 4(b)):
//! lower SSIM ⇒ less recognizable ⇒ better privacy. This implementation
//! follows the reference formulation: 11×11 Gaussian window (σ = 1.5),
//! K₁ = 0.01, K₂ = 0.03, per-window statistics averaged over the image.

use crate::tensor::Tensor;
use crate::{Error, Result};

const K1: f64 = 0.01;
const K2: f64 = 0.03;
const WIN: usize = 11;
const SIGMA: f64 = 1.5;

/// Precomputed 11×11 Gaussian window, normalized to sum 1.
fn gaussian_window() -> [f64; WIN * WIN] {
    let mut w = [0.0; WIN * WIN];
    let c = (WIN / 2) as f64;
    let mut sum = 0.0;
    for y in 0..WIN {
        for x in 0..WIN {
            let dy = y as f64 - c;
            let dx = x as f64 - c;
            let v = (-(dx * dx + dy * dy) / (2.0 * SIGMA * SIGMA)).exp();
            w[y * WIN + x] = v;
            sum += v;
        }
    }
    for v in &mut w {
        *v /= sum;
    }
    w
}

/// SSIM between two single-channel images [h, w] over a given dynamic
/// range `l` (e.g. 1.0 for [0,1]-scaled images).
pub fn ssim_plane(a: &Tensor, b: &Tensor, l: f64) -> Result<f64> {
    if a.ndim() != 2 || a.shape() != b.shape() {
        return Err(Error::Shape(format!(
            "ssim wants equal 2-D shapes, got {:?} vs {:?}",
            a.shape(),
            b.shape()
        )));
    }
    let (h, w) = (a.shape()[0], a.shape()[1]);
    if h < WIN || w < WIN {
        return Err(Error::Shape(format!(
            "image {h}x{w} smaller than the {WIN}x{WIN} SSIM window"
        )));
    }
    let win = gaussian_window();
    let c1 = (K1 * l) * (K1 * l);
    let c2 = (K2 * l) * (K2 * l);
    let mut total = 0.0;
    let mut count = 0usize;
    for oy in 0..=(h - WIN) {
        for ox in 0..=(w - WIN) {
            let (mut mu_a, mut mu_b) = (0.0f64, 0.0f64);
            for y in 0..WIN {
                for x in 0..WIN {
                    let g = win[y * WIN + x];
                    mu_a += g * a.at2(oy + y, ox + x) as f64;
                    mu_b += g * b.at2(oy + y, ox + x) as f64;
                }
            }
            let (mut var_a, mut var_b, mut cov) = (0.0f64, 0.0f64, 0.0f64);
            for y in 0..WIN {
                for x in 0..WIN {
                    let g = win[y * WIN + x];
                    let da = a.at2(oy + y, ox + x) as f64 - mu_a;
                    let db = b.at2(oy + y, ox + x) as f64 - mu_b;
                    var_a += g * da * da;
                    var_b += g * db * db;
                    cov += g * da * db;
                }
            }
            let s = ((2.0 * mu_a * mu_b + c1) * (2.0 * cov + c2))
                / ((mu_a * mu_a + mu_b * mu_b + c1) * (var_a + var_b + c2));
            total += s;
            count += 1;
        }
    }
    Ok(total / count as f64)
}

/// Mean SSIM over the channels of an NCHW image pair [α, m, m].
pub fn ssim_image(a: &Tensor, b: &Tensor, l: f64) -> Result<f64> {
    if a.ndim() != 3 || a.shape() != b.shape() {
        return Err(Error::Shape(format!(
            "ssim_image wants equal [C, H, W], got {:?} vs {:?}",
            a.shape(),
            b.shape()
        )));
    }
    let (c, h, w) = (a.shape()[0], a.shape()[1], a.shape()[2]);
    let mut total = 0.0;
    for ch in 0..c {
        let pa = Tensor::new(&[h, w], a.data()[ch * h * w..][..h * w].to_vec())?;
        let pb = Tensor::new(&[h, w], b.data()[ch * h * w..][..h * w].to_vec())?;
        total += ssim_plane(&pa, &pb, l)?;
    }
    Ok(total / c as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn natural_ish(h: usize, w: usize, seed: u64) -> Tensor {
        // smooth image: sum of a few low-frequency sinusoids
        let mut r = Rng::new(seed);
        let (f1, f2) = (r.f64() * 4.0 + 1.0, r.f64() * 4.0 + 1.0);
        let mut t = Tensor::zeros(&[h, w]);
        for y in 0..h {
            for x in 0..w {
                let v = 0.5
                    + 0.25 * (f1 * y as f64 / h as f64 * std::f64::consts::TAU).sin()
                    + 0.25 * (f2 * x as f64 / w as f64 * std::f64::consts::TAU).cos();
                t.set2(y, x, v as f32);
            }
        }
        t
    }

    #[test]
    fn identical_images_score_one() {
        let a = natural_ish(16, 16, 1);
        let s = ssim_plane(&a, &a, 1.0).unwrap();
        assert!((s - 1.0).abs() < 1e-9, "ssim(a,a)={s}");
    }

    #[test]
    fn unrelated_noise_scores_low() {
        let a = natural_ish(16, 16, 2);
        let mut r = Rng::new(3);
        let b = Tensor::new(&[16, 16], r.normal_vec(256, 0.5)).unwrap();
        let s = ssim_plane(&a, &b, 1.0).unwrap();
        assert!(s < 0.35, "ssim(a, noise)={s}");
    }

    #[test]
    fn small_perturbation_scores_high() {
        let a = natural_ish(16, 16, 4);
        let mut b = a.clone();
        let mut r = Rng::new(5);
        crate::nn::add_gaussian_noise(&mut b, 0.005, &mut r);
        let s = ssim_plane(&a, &b, 1.0).unwrap();
        assert!(s > 0.95, "ssim(a, a+tiny)={s}");
    }

    #[test]
    fn symmetric() {
        let a = natural_ish(16, 16, 6);
        let b = natural_ish(16, 16, 7);
        let ab = ssim_plane(&a, &b, 1.0).unwrap();
        let ba = ssim_plane(&b, &a, 1.0).unwrap();
        assert!((ab - ba).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_noise() {
        // more noise -> lower SSIM: the property fig. 4(b) relies on
        let a = natural_ish(16, 16, 8);
        let mut last = 1.1;
        for (i, std) in [0.01f32, 0.05, 0.2, 0.8].iter().enumerate() {
            let mut b = a.clone();
            let mut r = Rng::new(100 + i as u64);
            crate::nn::add_gaussian_noise(&mut b, *std, &mut r);
            let s = ssim_plane(&a, &b, 1.0).unwrap();
            assert!(s < last, "ssim not monotone: {s} !< {last} at std={std}");
            last = s;
        }
    }

    #[test]
    fn multichannel_averages() {
        let a = natural_ish(16, 16, 9);
        let mut data = a.data().to_vec();
        data.extend_from_slice(a.data());
        let img = Tensor::new(&[2, 16, 16], data).unwrap();
        let s = ssim_image(&img, &img, 1.0).unwrap();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn too_small_rejected() {
        let a = Tensor::zeros(&[4, 4]);
        assert!(ssim_plane(&a, &a, 1.0).is_err());
    }
}
