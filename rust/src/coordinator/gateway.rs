//! `mole gateway` — the fleet tier: one TCP front for N serving
//! processes.
//!
//! A single `mole serve` owns one registry and one engine; the gateway
//! is the layer that turns N of them into a fleet without changing a
//! single client. Three jobs:
//!
//! * **Shard routing.** Each serving session opens with `Hello
//!   { model, epoch, .. }`; the gateway decodes exactly that first frame,
//!   resolves it against its shard map (`[gateway.shards.MODEL]` config:
//!   an epoch selector `"*"` / `"N"` / `"N-M"` plus a replica list),
//!   connects to a healthy replica, replays the `Hello` verbatim, and
//!   from then on splices bytes both ways on the shared `poll(2)`
//!   reactor ([`super::reactor`]). The gateway never re-frames traffic
//!   past the first message — backend `Fault::Draining` / `Retired` /
//!   `Overloaded` frames reach the client untouched, so
//!   [`super::MoleClient`]'s redirect and backoff logic works unchanged
//!   behind the gateway.
//! * **Health.** A probe thread dials every backend each
//!   `probe_interval`: TCP connect (bounded), one `Hello`, one reply —
//!   a `Hello` *or any typed `Fault`* proves the peer is alive and
//!   speaking the protocol. An unresponsive backend is marked out and
//!   its shard's traffic respreads over the remaining replicas; same-
//!   shard load spreads round-robin. A connect failure on the data path
//!   marks the node out immediately (faster than the next probe tick)
//!   and the router retries the next replica, so one dead node costs at
//!   most one connect timeout, not an error surfaced to the client. A
//!   shard with **no** healthy replica answers the typed
//!   `Fault::Overloaded` — retryable, honest, never a silent hang.
//! * **Fleet admin.** With a credential configured the gateway
//!   terminates the operator's sealed admin session itself (same v8
//!   envelope — challenge nonce, per-frame MACs, sealed replies, see
//!   [`super::admin`]) and **fans every verb out** to the whole fleet,
//!   authenticating to each backend *as an operator* with the same
//!   credential. The reply aggregates one ack line per node — a partial
//!   failure is reported per node, never collapsed into one bool. The
//!   v9 `fleet-status` verb ([`Message::AdminFleetStatus`]) returns the
//!   probe view plus each node's last fan-out ack; serving processes
//!   refuse that verb typed, because a lone node has no fleet view.
//!
//! What the gateway does **not** authenticate: data-plane sessions.
//! Serving traffic is routed, not inspected — morphed rows are already
//! the paper's privacy boundary and the backends enforce their own
//! budgets. The admin plane is the opposite: nothing unsealed is ever
//! fanned out, and a gateway without a credential refuses `AdminHello`
//! outright (there is no loopback-legacy mode here — a gateway is by
//! definition a remote front).
//!
//! Bulk delivery (`DatasetHello`) is refused typed: chunked dataset
//! pulls are point-to-point with per-chunk integrity and a resume
//! journal keyed to one server's store — proxying them would only add a
//! copy. Clients pull datasets from a backend directly.

use super::admin::{fresh_nonce, AdminClient, OperatorTable};
use super::protocol::{
    read_message, seal_admin_reply, write_message, Fault, Message, FAULT_SESSION,
};
use super::reactor::{waker, Interest, Poller, Waker, WakeRx};
use crate::{Error, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Ceiling on one proxy poll round (drivers notice shutdown promptly).
const POLL_CAP: Duration = Duration::from_millis(250);
/// Per-direction splice buffer: big enough to stream batched tensors
/// without syscall churn, small enough that a stalled reader exerts
/// backpressure on its writer instead of buffering a session's world.
const PROXY_BUF: usize = 64 * 1024;
/// Concurrent routing handshakes in flight. Routing reads one frame and
/// dials one backend on a short-lived thread; past the cap new
/// connections are shed typed, mirroring the serving accept budget.
const ROUTE_CAP: usize = 256;
/// Backoff hint on gateway-side sheds (route cap, no healthy replica).
const GATEWAY_RETRY_MS: u64 = 500;
/// How long a routing thread waits for the client's first frame.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// Which epochs of a model a shard serves. Parsed from the config's
/// `epochs` key: `"*"` (any, including the [`EPOCH_LATEST`] sentinel),
/// `"4"` (exactly 4), `"2-5"` (inclusive range).
///
/// [`EPOCH_LATEST`] matches **only** the `"*"` selector: "latest" is
/// resolved by the backend registry, so a pinned-epoch shard cannot
/// claim it — it does not know what latest is.
///
/// [`EPOCH_LATEST`]: super::protocol::EPOCH_LATEST
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EpochSelector {
    Any,
    One(u32),
    Range(u32, u32),
}

impl EpochSelector {
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        if s == "*" {
            return Ok(Self::Any);
        }
        let bad = |k: &str| Error::Config(format!("bad epoch selector {s:?}: {k}"));
        if let Some((lo, hi)) = s.split_once('-') {
            let lo: u32 = lo.trim().parse().map_err(|_| bad("range start not a number"))?;
            let hi: u32 = hi.trim().parse().map_err(|_| bad("range end not a number"))?;
            if lo > hi {
                return Err(bad("range start above end"));
            }
            if hi == u32::MAX {
                return Err(bad("u32::MAX is the reserved latest-epoch sentinel"));
            }
            return Ok(Self::Range(lo, hi));
        }
        let n: u32 = s.parse().map_err(|_| bad("expected \"*\", \"N\" or \"N-M\""))?;
        if n == u32::MAX {
            return Err(bad("u32::MAX is the reserved latest-epoch sentinel"));
        }
        Ok(Self::One(n))
    }

    pub fn matches(&self, epoch: u32) -> bool {
        match self {
            Self::Any => true,
            Self::One(n) => epoch == *n,
            Self::Range(lo, hi) => (*lo..=*hi).contains(&epoch),
        }
    }
}

/// One shard: a model, the epochs it covers, and its replica set.
#[derive(Debug)]
pub struct ShardSpec {
    pub model: String,
    pub epochs: EpochSelector,
    pub backends: Vec<String>,
    /// Round-robin cursor over `backends` (skipping unhealthy ones).
    cursor: AtomicUsize,
}

impl ShardSpec {
    pub fn new(model: &str, epochs: EpochSelector, backends: Vec<String>) -> Result<Self> {
        if backends.is_empty() {
            return Err(Error::Config(format!("shard {model:?} has no backends")));
        }
        Ok(Self { model: model.to_string(), epochs, backends, cursor: AtomicUsize::new(0) })
    }
}

/// The (model, epoch) → replica-set map. First matching shard wins, in
/// config order, so an operator can pin `epochs = "0-3"` to old capacity
/// and let a trailing `epochs = "*"` shard catch the rest.
#[derive(Debug)]
pub struct ShardMap {
    shards: Vec<ShardSpec>,
}

impl ShardMap {
    pub fn new(shards: Vec<ShardSpec>) -> Result<Self> {
        if shards.is_empty() {
            return Err(Error::Config(
                "gateway needs at least one [gateway.shards.MODEL] entry".into(),
            ));
        }
        Ok(Self { shards })
    }

    /// The deduped union of every shard's backends, in first-seen order —
    /// the fleet that admin verbs fan out to and the probe loop watches.
    pub fn fleet(&self) -> Vec<String> {
        let mut seen = Vec::new();
        for s in &self.shards {
            for b in &s.backends {
                if !seen.contains(b) {
                    seen.push(b.clone());
                }
            }
        }
        seen
    }

    /// The shard serving `(model, epoch)`, if any.
    pub fn resolve(&self, model: &str, epoch: u32) -> Option<&ShardSpec> {
        self.shards.iter().find(|s| s.model == model && s.epochs.matches(epoch))
    }

    /// Healthy replicas for one shard in round-robin order, starting
    /// from the shard's advancing cursor: the router tries them in turn
    /// so a replica that fails to connect costs one timeout, not the
    /// session.
    fn replica_order(&self, shard: &ShardSpec, fleet: &FleetHealth) -> Vec<String> {
        let n = shard.backends.len();
        let start = shard.cursor.fetch_add(1, Ordering::Relaxed) % n;
        (0..n)
            .map(|i| &shard.backends[(start + i) % n])
            .filter(|b| fleet.is_healthy(b))
            .cloned()
            .collect()
    }
}

struct FleetNode {
    addr: String,
    healthy: AtomicBool,
    /// Ack of the last admin verb fanned out to this node ("-" before
    /// the first), shown in `fleet-status`.
    last_ack: Mutex<String>,
}

/// Live health + last-ack view of every backend, shared by the probe
/// thread, the routers, and the fleet admin sessions.
pub struct FleetHealth {
    nodes: Vec<FleetNode>,
}

impl FleetHealth {
    fn new(addrs: Vec<String>) -> Self {
        Self {
            nodes: addrs
                .into_iter()
                .map(|addr| FleetNode {
                    addr,
                    // optimistic until the first probe round (bind runs
                    // one synchronously, so a dead node is out before
                    // the gateway accepts traffic)
                    healthy: AtomicBool::new(true),
                    last_ack: Mutex::new("-".to_string()),
                })
                .collect(),
        }
    }

    pub fn is_healthy(&self, addr: &str) -> bool {
        self.nodes
            .iter()
            .find(|n| n.addr == addr)
            .map(|n| n.healthy.load(Ordering::SeqCst))
            .unwrap_or(false)
    }

    pub fn mark(&self, addr: &str, healthy: bool) {
        if let Some(n) = self.nodes.iter().find(|n| n.addr == addr) {
            if n.healthy.swap(healthy, Ordering::SeqCst) != healthy {
                crate::logging::info(&format!(
                    "gateway: backend {addr} marked {}",
                    if healthy { "in" } else { "out" }
                ));
            }
        }
    }

    fn record_ack(&self, addr: &str, ack: &str) {
        if let Some(n) = self.nodes.iter().find(|n| n.addr == addr) {
            *n.last_ack.lock().unwrap() = ack.to_string();
        }
    }

    /// The `fleet-status` detail: one line per node, never a summary
    /// bool. `up`/`down` is the probe view; `last:` is the most recent
    /// fan-out ack for that node.
    pub fn report(&self) -> String {
        self.nodes
            .iter()
            .map(|n| {
                format!(
                    "node {} {} last: {}",
                    n.addr,
                    if n.healthy.load(Ordering::SeqCst) { "up" } else { "down" },
                    n.last_ack.lock().unwrap()
                )
            })
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Gateway tuning — built from the `[gateway]` config table or directly
/// by tests.
#[derive(Debug)]
pub struct GatewayConfig {
    /// Listen address (`[gateway] listen`).
    pub addr: String,
    /// The shard map (`[gateway.shards.MODEL]` tables).
    pub shards: Vec<ShardSpec>,
    /// Health-probe cadence (`[gateway] probe_interval_ms`).
    pub probe_interval: Duration,
    /// Bound on each backend dial — routing threads block at most this
    /// long on a dead host (`[gateway] connect_timeout_ms`).
    pub connect_timeout: Duration,
    /// Inbound operator gate **and** outbound fan-out credential
    /// (`[gateway] credential_file`). `None` disables the admin plane.
    pub credential: Option<[u8; 32]>,
    /// Proxy driver shards.
    pub workers: usize,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            shards: Vec::new(),
            probe_interval: Duration::from_millis(500),
            connect_timeout: Duration::from_millis(1000),
            credential: None,
            workers: 2,
        }
    }
}

/// Everything a routing thread needs, shared behind one `Arc`.
struct RouterCtx {
    map: ShardMap,
    fleet: FleetHealth,
    credential: Option<[u8; 32]>,
    connect_timeout: Duration,
    routers: AtomicUsize,
    proxy_shards: Vec<Arc<ProxyShared>>,
    next_shard: AtomicUsize,
}

struct ProxyShared {
    inbox: Mutex<Vec<(TcpStream, TcpStream)>>,
    waker: Waker,
}

/// A running gateway: acceptor + routing threads + proxy drivers +
/// probe loop. [`Gateway::stop`] tears all of it down.
pub struct Gateway {
    local_addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    ctx: Arc<RouterCtx>,
    acceptor: Option<JoinHandle<()>>,
    probe: Option<JoinHandle<()>>,
    drivers: Vec<JoinHandle<()>>,
}

impl Gateway {
    pub fn bind(cfg: GatewayConfig) -> Result<Self> {
        let map = ShardMap::new(cfg.shards)?;
        let fleet = FleetHealth::new(map.fleet());
        let listener = TcpListener::bind(&cfg.addr)?;
        let local_addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));

        let workers = cfg.workers.max(1);
        let mut proxy_shards = Vec::with_capacity(workers);
        let mut drivers = Vec::with_capacity(workers);
        let mut rxs = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (wk, rx) = waker().map_err(Error::Io)?;
            proxy_shards.push(Arc::new(ProxyShared { inbox: Mutex::new(Vec::new()), waker: wk }));
            rxs.push(rx);
        }

        // one synchronous probe round before accepting anything: a
        // backend that is already dead never receives a first session
        for node in map.fleet() {
            let up = probe_backend(&node, cfg.connect_timeout);
            fleet.mark(&node, up);
        }

        let ctx = Arc::new(RouterCtx {
            map,
            fleet,
            credential: cfg.credential,
            connect_timeout: cfg.connect_timeout,
            routers: AtomicUsize::new(0),
            proxy_shards,
            next_shard: AtomicUsize::new(0),
        });

        for (i, rx) in rxs.into_iter().enumerate() {
            let shared = ctx.proxy_shards[i].clone();
            let shutdown = shutdown.clone();
            drivers.push(
                std::thread::Builder::new()
                    .name(format!("mole-gw-proxy-{i}"))
                    .spawn(move || ProxyDriver::new(shared, rx, shutdown).run())
                    .map_err(Error::Io)?,
            );
        }

        let probe = {
            let ctx = ctx.clone();
            let shutdown = shutdown.clone();
            let interval = cfg.probe_interval;
            std::thread::Builder::new()
                .name("mole-gw-probe".into())
                .spawn(move || {
                    while !shutdown.load(Ordering::SeqCst) {
                        // sleep in slices so stop() is never blocked on a
                        // long probe interval
                        let mut left = interval;
                        while left > Duration::ZERO && !shutdown.load(Ordering::SeqCst) {
                            let step = left.min(Duration::from_millis(50));
                            std::thread::sleep(step);
                            left = left.saturating_sub(step);
                        }
                        if shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        for node in ctx.map.fleet() {
                            let up = probe_backend(&node, ctx.connect_timeout);
                            ctx.fleet.mark(&node, up);
                        }
                    }
                })
                .map_err(Error::Io)?
        };

        let acceptor = {
            let ctx = ctx.clone();
            let shutdown = shutdown.clone();
            std::thread::Builder::new()
                .name("mole-gw-accept".into())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        let sock = match conn {
                            Ok(s) => s,
                            Err(e) => {
                                crate::logging::warn(&format!("gateway accept failed: {e}"));
                                continue;
                            }
                        };
                        sock.set_nodelay(true).ok();
                        if ctx.routers.fetch_add(1, Ordering::SeqCst) >= ROUTE_CAP {
                            ctx.routers.fetch_sub(1, Ordering::SeqCst);
                            refuse(
                                sock,
                                Fault::Overloaded { retry_after_ms: GATEWAY_RETRY_MS },
                            );
                            continue;
                        }
                        let ctx = ctx.clone();
                        let spawned = std::thread::Builder::new()
                            .name("mole-gw-route".into())
                            .spawn(move || {
                                route_session(sock, &ctx);
                                ctx.routers.fetch_sub(1, Ordering::SeqCst);
                            });
                        if let Err(e) = spawned {
                            ctx.routers.fetch_sub(1, Ordering::SeqCst);
                            crate::logging::warn(&format!("gateway route spawn failed: {e}"));
                        }
                    }
                })
                .map_err(Error::Io)?
        };

        Ok(Self {
            local_addr,
            shutdown,
            ctx,
            acceptor: Some(acceptor),
            probe: Some(probe),
            drivers,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The live fleet view (tests poll it; operators use `fleet-status`).
    pub fn fleet_report(&self) -> String {
        self.ctx.fleet.report()
    }

    /// Stop accepting, wake and join every thread. In-flight proxy
    /// sessions are dropped — stop the gateway after its clients.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.local_addr); // unblock accept()
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for s in &self.ctx.proxy_shards {
            s.waker.wake();
        }
        for d in self.drivers.drain(..) {
            let _ = d.join();
        }
        if let Some(p) = self.probe.take() {
            let _ = p.join();
        }
    }
}

/// Liveness probe: bounded connect, one `Hello`, one reply. A `Hello`
/// *or any typed `Fault`* is proof of life — the probe's empty model
/// name resolves to nothing on purpose, so the backend answers a typed
/// refusal without ever standing up a session. Dead TCP, a stalled
/// read, or unframed garbage is what "down" means.
fn probe_backend(addr: &str, timeout: Duration) -> bool {
    let Some(sa) = resolve_addr(addr) else { return false };
    let Ok(mut sock) = TcpStream::connect_timeout(&sa, timeout) else {
        return false;
    };
    sock.set_nodelay(true).ok();
    sock.set_read_timeout(Some(timeout)).ok();
    sock.set_write_timeout(Some(timeout)).ok();
    let hello = Message::Hello {
        version: super::protocol::PROTOCOL_VERSION,
        model: String::new(),
        epoch: 0,
        geometry: crate::Geometry::new(0, 0, 0, 0),
        kappa: 0,
        fingerprint: String::new(),
        num_batches: 0,
        batch_size: 0,
    };
    if write_message(&mut sock, &hello).is_err() {
        return false;
    }
    matches!(read_message(&mut sock), Ok(Message::Hello { .. } | Message::Fault { .. }))
}

fn resolve_addr(addr: &str) -> Option<SocketAddr> {
    use std::net::ToSocketAddrs;
    addr.to_socket_addrs().ok()?.next()
}

/// Best-effort typed refusal on a connection the gateway won't route.
fn refuse(mut sock: TcpStream, fault: Fault) {
    sock.set_write_timeout(Some(Duration::from_millis(250))).ok();
    let _ = write_message(&mut sock, &Message::Fault { of: FAULT_SESSION, fault });
    let _ = sock.shutdown(Shutdown::Write);
}

/// One routing handshake: read the client's first frame, decide where
/// the session belongs, and either hand the spliced pair to a proxy
/// driver, run the fleet admin session, or refuse typed.
fn route_session(mut sock: TcpStream, ctx: &RouterCtx) {
    sock.set_read_timeout(Some(HANDSHAKE_TIMEOUT)).ok();
    sock.set_write_timeout(Some(HANDSHAKE_TIMEOUT)).ok();
    let first = match read_message(&mut sock) {
        Ok(m) => m,
        Err(e) => {
            refuse(sock, Fault::from_error(&e));
            return;
        }
    };
    match first {
        Message::Hello { ref model, epoch, .. } => {
            let Some(shard) = ctx.map.resolve(model, epoch) else {
                refuse(
                    sock,
                    Fault::Generic {
                        msg: format!("gateway has no shard for {model}@{epoch}"),
                    },
                );
                return;
            };
            // try each healthy replica once; a failed dial marks the
            // node out right now instead of waiting for the next probe
            for addr in ctx.map.replica_order(shard, &ctx.fleet) {
                let Some(sa) = resolve_addr(&addr) else {
                    ctx.fleet.mark(&addr, false);
                    continue;
                };
                let backend = match TcpStream::connect_timeout(&sa, ctx.connect_timeout) {
                    Ok(b) => b,
                    Err(_) => {
                        ctx.fleet.mark(&addr, false);
                        continue;
                    }
                };
                backend.set_nodelay(true).ok();
                let mut backend = backend;
                if write_message(&mut backend, &first).is_err() {
                    ctx.fleet.mark(&addr, false);
                    continue;
                }
                // routed: timeouts off, sockets go evented
                sock.set_read_timeout(None).ok();
                sock.set_write_timeout(None).ok();
                let shard_idx =
                    ctx.next_shard.fetch_add(1, Ordering::Relaxed) % ctx.proxy_shards.len();
                let shared = &ctx.proxy_shards[shard_idx];
                shared.inbox.lock().unwrap().push((sock, backend));
                shared.waker.wake();
                return;
            }
            refuse(sock, Fault::Overloaded { retry_after_ms: GATEWAY_RETRY_MS });
        }
        Message::AdminHello => match ctx.credential {
            Some(cred) => {
                if let Err(e) = run_fleet_admin_session(&mut sock, cred, ctx) {
                    crate::logging::warn(&format!("gateway admin session ended: {e}"));
                }
            }
            None => refuse(
                sock,
                Fault::AdminAuth {
                    msg: "gateway has no admin credential configured; the fleet \
                          admin plane is disabled"
                        .into(),
                },
            ),
        },
        Message::DatasetHello { .. } => refuse(
            sock,
            Fault::Generic {
                msg: "bulk delivery does not traverse the gateway; pull datasets \
                      from a backend directly"
                    .into(),
            },
        ),
        Message::AdminRegister { .. }
        | Message::AdminDrain { .. }
        | Message::AdminRetire { .. }
        | Message::AdminStatus
        | Message::AdminRevoke { .. }
        | Message::AdminFleetStatus => refuse(
            sock,
            Fault::AdminAuth {
                msg: "gateway admin verbs must ride the authenticated plane \
                      (open with AdminHello)"
                    .into(),
            },
        ),
        other => refuse(
            sock,
            Fault::Generic {
                msg: format!(
                    "gateway sessions open with Hello or AdminHello, got tag {}",
                    other.wire_tag()
                ),
            },
        ),
    }
}

/// Fan one admin verb out to every fleet node as an authenticated
/// operator, recording each node's ack. The aggregate is **always** one
/// line per node — `ok:` or `failed:` — so a partial fan-out reads as
/// exactly that, never as a single collapsed success/failure.
fn fan_out(ctx: &RouterCtx, cred: [u8; 32], verb: &Message) -> String {
    let mut lines = Vec::new();
    for addr in ctx.map.fleet() {
        let outcome = fan_out_one(&addr, cred, ctx.connect_timeout, verb);
        let line = match outcome {
            Ok(detail) => {
                let first = detail.lines().next().unwrap_or("").to_string();
                ctx.fleet.record_ack(&addr, &format!("ok: {first}"));
                // multi-line details (status) stay grouped under their
                // node, continuation lines indented
                format!("node {addr} ok: {}", detail.replace('\n', "\n  "))
            }
            Err(e) => {
                ctx.fleet.record_ack(&addr, &format!("failed: {e}"));
                format!("node {addr} failed: {e}")
            }
        };
        lines.push(line);
    }
    lines.join("\n")
}

fn fan_out_one(
    addr: &str,
    cred: [u8; 32],
    timeout: Duration,
    verb: &Message,
) -> Result<String> {
    let sa = resolve_addr(addr)
        .ok_or_else(|| Error::Config(format!("unresolvable backend {addr:?}")))?;
    let sock = TcpStream::connect_timeout(&sa, timeout)?;
    sock.set_nodelay(true).ok();
    sock.set_read_timeout(Some(Duration::from_secs(10))).ok();
    sock.set_write_timeout(Some(Duration::from_secs(10))).ok();
    let mut admin = AdminClient::over(sock);
    admin.authenticate(cred)?;
    let detail = admin.request(verb)?;
    let _ = admin.finish();
    Ok(detail)
}

/// The gateway's side of an operator's sealed admin session. Protocol
/// v8 sealing reused verbatim ([`super::admin`] semantics: auth failure
/// answers the one legitimately-cleartext fault and ends the session;
/// verb failures answer sealed and keep it alive) — only the dispatch
/// differs: verbs fan out to the fleet, `fleet-status` answers from the
/// live health/ack view, and nothing here touches a registry because
/// the gateway has none.
fn run_fleet_admin_session(
    stream: &mut TcpStream,
    cred: [u8; 32],
    ctx: &RouterCtx,
) -> Result<()> {
    let table = OperatorTable::shared(cred);
    let nonce = fresh_nonce();
    write_message(stream, &Message::AdminChallenge { nonce })?;
    let mut last_counter = 0u64;
    loop {
        let frame = match read_message(stream) {
            Ok(Message::EndOfData) => {
                let _ = write_message(stream, &Message::EndOfData);
                return Ok(());
            }
            Ok(m) => m,
            Err(Error::Io(e)) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                return Ok(())
            }
            Err(e) => return Err(e),
        };
        let (_operator, op_cred, counter, inner) =
            match table.open_request(&nonce, last_counter, &frame) {
                Ok(opened) => opened,
                Err(e) => {
                    let _ = write_message(
                        stream,
                        &Message::Fault { of: FAULT_SESSION, fault: Fault::from_error(&e) },
                    );
                    return Err(e);
                }
            };
        last_counter = counter;
        let outcome: Result<String> = match &inner {
            Message::AdminFleetStatus => Ok(ctx.fleet.report()),
            verb @ (Message::AdminRegister { .. }
            | Message::AdminDrain { .. }
            | Message::AdminRetire { .. }
            | Message::AdminStatus
            | Message::AdminRevoke { .. }) => Ok(fan_out(ctx, cred, verb)),
            other => Err(Error::Protocol(format!(
                "fleet admin session got non-admin frame {other:?}"
            ))),
        };
        let reply = match outcome {
            Ok(detail) => {
                crate::logging::info(&format!(
                    "gateway admin: {}",
                    detail.lines().next().unwrap_or("")
                ));
                Message::AdminOk { detail }
            }
            Err(e) => Message::Fault { of: FAULT_SESSION, fault: Fault::from_error(&e) },
        };
        write_message(stream, &seal_admin_reply(&op_cred, &nonce, counter, &reply))?;
    }
}

// ---------------------------------------------------------------------------
// evented byte splice
// ---------------------------------------------------------------------------

/// One routed session: two sockets, two bounded per-direction buffers.
/// Bytes are forwarded verbatim — the proxy never re-frames.
struct Proxy {
    client: TcpStream,
    backend: TcpStream,
    /// client → backend bytes awaiting write.
    c2b: Vec<u8>,
    /// backend → client bytes awaiting write.
    b2c: Vec<u8>,
    c_eof: bool,
    b_eof: bool,
    c_shut: bool,
    b_shut: bool,
}

impl Proxy {
    fn new(client: TcpStream, backend: TcpStream) -> std::io::Result<Self> {
        client.set_nonblocking(true)?;
        backend.set_nonblocking(true)?;
        Ok(Self {
            client,
            backend,
            c2b: Vec::new(),
            b2c: Vec::new(),
            c_eof: false,
            b_eof: false,
            c_shut: false,
            b_shut: false,
        })
    }

    /// Move whatever can move without blocking, in both directions, and
    /// propagate half-closes. Returns false when the session is spent
    /// (both directions EOF and flushed) or dead (I/O error — teardown
    /// drops both sockets, which is all a byte proxy can honestly do).
    fn pump(&mut self) -> bool {
        // half-duplex forwarding is symmetric; run (read, write, FIN)
        // for each direction
        if !self.c_eof && self.c2b.len() < PROXY_BUF {
            match read_some(&mut self.client, &mut self.c2b) {
                Ok(eof) => self.c_eof |= eof,
                Err(_) => return false,
            }
        }
        if !self.c2b.is_empty() && write_some(&mut self.backend, &mut self.c2b).is_err() {
            return false;
        }
        if self.c_eof && self.c2b.is_empty() && !self.b_shut {
            let _ = self.backend.shutdown(Shutdown::Write);
            self.b_shut = true;
        }

        if !self.b_eof && self.b2c.len() < PROXY_BUF {
            match read_some(&mut self.backend, &mut self.b2c) {
                Ok(eof) => self.b_eof |= eof,
                Err(_) => return false,
            }
        }
        if !self.b2c.is_empty() && write_some(&mut self.client, &mut self.b2c).is_err() {
            return false;
        }
        if self.b_eof && self.b2c.is_empty() && !self.c_shut {
            let _ = self.client.shutdown(Shutdown::Write);
            self.c_shut = true;
        }

        !(self.c_eof && self.b_eof && self.c2b.is_empty() && self.b2c.is_empty())
    }

    /// (client interest, backend interest) for the next poll round;
    /// `None` means that socket has nothing to wait for right now.
    fn interests(&self) -> (Option<Interest>, Option<Interest>) {
        let side = |eof: bool, inbuf: &Vec<u8>, outbuf: &Vec<u8>| {
            let rd = !eof && inbuf.len() < PROXY_BUF;
            let wr = !outbuf.is_empty();
            match (rd, wr) {
                (true, true) => Some(Interest::BOTH),
                (true, false) => Some(Interest::READ),
                (false, true) => Some(Interest::WRITE),
                (false, false) => None,
            }
        };
        (side(self.c_eof, &self.c2b, &self.b2c), side(self.b_eof, &self.b2c, &self.c2b))
    }
}

/// Drain the socket into `buf` until `WouldBlock`, the buffer cap, or
/// EOF (returned as `Ok(true)`).
fn read_some(sock: &mut TcpStream, buf: &mut Vec<u8>) -> std::io::Result<bool> {
    let mut chunk = [0u8; 8192];
    while buf.len() < PROXY_BUF {
        match sock.read(&mut chunk) {
            Ok(0) => return Ok(true),
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(false)
}

/// Write as much of `buf` as the socket takes without blocking.
fn write_some(sock: &mut TcpStream, buf: &mut Vec<u8>) -> std::io::Result<()> {
    while !buf.is_empty() {
        match sock.write(buf) {
            Ok(0) => return Err(std::io::ErrorKind::WriteZero.into()),
            Ok(n) => {
                buf.drain(..n);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// One proxy driver: adopts routed pairs from its inbox, splices them
/// on a shared [`Poller`], tears down spent or broken sessions.
struct ProxyDriver {
    shared: Arc<ProxyShared>,
    wake_rx: WakeRx,
    shutdown: Arc<AtomicBool>,
    sessions: HashMap<u64, Proxy>,
    next_id: u64,
    poller: Poller,
}

impl ProxyDriver {
    fn new(shared: Arc<ProxyShared>, wake_rx: WakeRx, shutdown: Arc<AtomicBool>) -> Self {
        Self {
            shared,
            wake_rx,
            shutdown,
            sessions: HashMap::new(),
            next_id: 0,
            poller: Poller::new(),
        }
    }

    fn run(mut self) {
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                return; // drops every in-flight session
            }
            // adopt routed pairs; a first pump moves the replayed Hello's
            // reply without waiting a poll round
            let adopted = std::mem::take(&mut *self.shared.inbox.lock().unwrap());
            for (client, backend) in adopted {
                if let Ok(mut p) = Proxy::new(client, backend) {
                    if p.pump() {
                        let id = self.next_id;
                        self.next_id += 1;
                        self.sessions.insert(id, p);
                    }
                }
            }

            // interest list: slot 0 is the waker, then every socket that
            // has something to wait for
            let mut fds: Vec<(std::os::unix::io::RawFd, Interest)> =
                vec![(self.wake_rx.fd(), Interest::READ)];
            let mut who: Vec<u64> = Vec::new();
            for (&id, p) in &self.sessions {
                let (ci, bi) = p.interests();
                if let Some(i) = ci {
                    fds.push((p.client.as_raw_fd(), i));
                    who.push(id);
                }
                if let Some(i) = bi {
                    fds.push((p.backend.as_raw_fd(), i));
                    who.push(id);
                }
            }

            let events = match self.poller.wait(&fds, Some(POLL_CAP)) {
                Ok(ev) => ev,
                Err(e) => {
                    crate::logging::warn(&format!("gateway proxy poll failed: {e}"));
                    return;
                }
            };
            let mut dead: Vec<u64> = Vec::new();
            for ev in events {
                if ev.slot == 0 {
                    self.wake_rx.drain();
                    continue;
                }
                let id = who[ev.slot - 1];
                if dead.contains(&id) {
                    continue;
                }
                // pump handles readable/writable/hangup alike: reads see
                // the EOF or error a hangup implies, writes flush what
                // readiness allows
                if let Some(p) = self.sessions.get_mut(&id) {
                    if !p.pump() {
                        dead.push(id);
                    }
                }
            }
            for id in dead {
                self.sessions.remove(&id);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(model: &str, epochs: &str, backends: &[&str]) -> ShardSpec {
        ShardSpec::new(
            model,
            EpochSelector::parse(epochs).unwrap(),
            backends.iter().map(|s| s.to_string()).collect(),
        )
        .unwrap()
    }

    #[test]
    fn epoch_selectors_parse_and_match() {
        assert_eq!(EpochSelector::parse("*").unwrap(), EpochSelector::Any);
        assert_eq!(EpochSelector::parse("4").unwrap(), EpochSelector::One(4));
        assert_eq!(EpochSelector::parse(" 2-5 ").unwrap(), EpochSelector::Range(2, 5));
        assert!(EpochSelector::parse("5-2").is_err());
        assert!(EpochSelector::parse("x").is_err());
        assert!(EpochSelector::parse("").is_err());
        // the latest-epoch sentinel is reserved: only "*" may claim it
        assert!(EpochSelector::parse("4294967295").is_err());
        assert!(EpochSelector::parse("0-4294967295").is_err());

        let latest = super::super::protocol::EPOCH_LATEST;
        assert!(EpochSelector::Any.matches(latest));
        assert!(EpochSelector::Any.matches(0));
        assert!(EpochSelector::One(4).matches(4));
        assert!(!EpochSelector::One(4).matches(5));
        assert!(!EpochSelector::One(4).matches(latest));
        assert!(EpochSelector::Range(2, 5).matches(2));
        assert!(EpochSelector::Range(2, 5).matches(5));
        assert!(!EpochSelector::Range(2, 5).matches(6));
        assert!(!EpochSelector::Range(2, 5).matches(latest));
    }

    #[test]
    fn shard_map_routes_first_match_in_config_order() {
        let map = ShardMap::new(vec![
            shard("alpha", "0-1", &["n1", "n2"]),
            shard("alpha", "*", &["n3"]),
            shard("beta", "*", &["n1", "n4"]),
        ])
        .unwrap();
        assert_eq!(map.resolve("alpha", 0).unwrap().backends, vec!["n1", "n2"]);
        assert_eq!(map.resolve("alpha", 1).unwrap().backends, vec!["n1", "n2"]);
        assert_eq!(map.resolve("alpha", 2).unwrap().backends, vec!["n3"]);
        let latest = super::super::protocol::EPOCH_LATEST;
        assert_eq!(map.resolve("alpha", latest).unwrap().backends, vec!["n3"]);
        assert_eq!(map.resolve("beta", 7).unwrap().backends, vec!["n1", "n4"]);
        assert!(map.resolve("gamma", 0).is_none());
        // fleet is the deduped union in first-seen order
        assert_eq!(map.fleet(), vec!["n1", "n2", "n3", "n4"]);
    }

    #[test]
    fn replica_order_round_robins_and_skips_unhealthy() {
        let map = ShardMap::new(vec![shard("alpha", "*", &["n1", "n2", "n3"])]).unwrap();
        let fleet = FleetHealth::new(map.fleet());
        let s = map.resolve("alpha", 0).unwrap();
        // all healthy: successive routes start at rotating offsets but
        // always list every replica once
        let a = map.replica_order(s, &fleet);
        let b = map.replica_order(s, &fleet);
        assert_eq!(a.len(), 3);
        assert_eq!(b.len(), 3);
        assert_ne!(a[0], b[0], "cursor must advance between routes");
        // one node out: it vanishes from the candidate list entirely
        fleet.mark("n2", false);
        for _ in 0..6 {
            let order = map.replica_order(s, &fleet);
            assert_eq!(order.len(), 2);
            assert!(!order.contains(&"n2".to_string()));
        }
        // none healthy: empty order → the router sheds typed Overloaded
        fleet.mark("n1", false);
        fleet.mark("n3", false);
        assert!(map.replica_order(s, &fleet).is_empty());
        // recovery: the probe marks it back in and traffic respreads
        fleet.mark("n2", true);
        assert_eq!(map.replica_order(s, &fleet), vec!["n2"]);
    }

    #[test]
    fn fleet_report_is_per_node_never_collapsed() {
        let fleet = FleetHealth::new(vec!["n1".into(), "n2".into()]);
        fleet.mark("n2", false);
        fleet.record_ack("n1", "ok: drained alpha@0");
        let report = fleet.report();
        assert_eq!(report.lines().count(), 2);
        assert!(report.contains("node n1 up last: ok: drained alpha@0"), "{report}");
        assert!(report.contains("node n2 down last: -"), "{report}");
    }

    #[test]
    fn empty_shard_configs_are_refused() {
        assert!(ShardMap::new(Vec::new()).is_err());
        assert!(ShardSpec::new("alpha", EpochSelector::Any, Vec::new()).is_err());
    }
}
