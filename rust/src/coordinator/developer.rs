//! The developer node (paper Fig. 1, right side).
//!
//! Connects to a provider, sends its pre-trained first layer, receives the
//! Aug-Conv matrix and the morphed training stream, and trains the trunk
//! through the AOT artifacts — never seeing an original pixel. The same
//! node exposes the trained model for serving ([`super::batcher`]).

use super::protocol::{read_message, write_message, Message};
use super::trainer::Trainer;
use super::SessionInfo;
use crate::rng::Rng;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::{Error, Result};
use std::io::{Read, Write};

/// What a completed delivery-and-training session produced.
#[derive(Debug)]
pub struct TrainOutcome {
    pub session: SessionInfo,
    pub steps: usize,
    pub losses: Vec<f32>,
    pub accs: Vec<f32>,
    /// Trained trunk parameters (aug layout: conv2..fc2).
    pub params: Vec<Tensor>,
    /// The received Aug-Conv layer (for serving).
    pub cac: Tensor,
    pub bias: Vec<f32>,
    pub bytes_received: u64,
}

/// The developer node. Holds the engine + the pre-trained first layer it
/// offers to providers.
pub struct DeveloperNode<'e> {
    engine: &'e Engine,
    w1: Tensor,
    b1: Vec<f32>,
    lr: f32,
}

impl<'e> DeveloperNode<'e> {
    /// `w1`/`b1`: the first layer "trained on a public dataset" (Fig. 1).
    /// In the reproduction we He-initialize it from a seed — transfer
    /// quality of w1 affects absolute accuracy equally in all three
    /// groups, not the equivalence property under test.
    pub fn new(engine: &'e Engine, seed: u64, lr: f32) -> Result<Self> {
        let m = engine.manifest();
        let g = m.geometry("small")?;
        let mut rng = Rng::new(seed);
        let std = (2.0 / (g.alpha * g.p * g.p) as f64).sqrt() as f32;
        let w1 = Tensor::new(
            &[g.beta, g.alpha, g.p, g.p],
            rng.normal_vec(g.beta * g.alpha * g.p * g.p, std),
        )?;
        let b1 = vec![0.0; g.beta];
        Ok(Self { engine, w1, b1, lr })
    }

    pub fn first_layer(&self) -> (&Tensor, &[f32]) {
        (&self.w1, &self.b1)
    }

    /// Run the client side of a delivery session: handshake, ship layer 1,
    /// receive C^ac, train on the morphed stream.
    pub fn run_session<S: Read + Write>(&self, stream: &mut S, seed: u64) -> Result<TrainOutcome> {
        let mut bytes = 0u64;

        // 1. handshake
        let (geometry, kappa, fingerprint, num_batches, batch_size) =
            match read_message(stream)? {
                Message::Hello { geometry, kappa, fingerprint, num_batches, batch_size } => {
                    (geometry, kappa, fingerprint, num_batches, batch_size)
                }
                other => {
                    return Err(Error::Protocol(format!("expected Hello, got {other:?}")))
                }
            };
        let m = self.engine.manifest();
        if batch_size as usize != m.train_batch {
            return Err(Error::Protocol(format!(
                "provider batch size {batch_size} != artifact batch {}",
                m.train_batch
            )));
        }

        // 2. ship the pre-trained first layer
        bytes += write_message(
            stream,
            &Message::Conv1Weights { w1: self.w1.clone(), b1: self.b1.clone() },
        )? as u64;

        // 3. receive the Aug-Conv layer
        let (cac, bias) = match read_message(stream)? {
            Message::AugConv { matrix, bias } => (matrix, bias),
            other => {
                return Err(Error::Protocol(format!("expected AugConv, got {other:?}")))
            }
        };

        // 4. train on the morphed stream
        let mut trainer = Trainer::new_aug(self.engine, cac.clone(), bias.clone(), seed)?;
        let mut losses = Vec::new();
        let mut accs = Vec::new();
        let mut steps = 0usize;
        loop {
            match read_message(stream)? {
                Message::MorphedBatch { rows, labels, .. } => {
                    let (l, a) = trainer.step(&rows, &labels, self.lr)?;
                    losses.push(l);
                    accs.push(a);
                    steps += 1;
                    if steps % 50 == 0 {
                        crate::logging::info(&format!(
                            "developer: step {steps} loss={l:.4} acc={a:.3}"
                        ));
                    }
                }
                Message::EndOfData => break,
                Message::Fault { msg } => {
                    return Err(Error::Protocol(format!("provider fault: {msg}")))
                }
                other => {
                    return Err(Error::Protocol(format!("unexpected {other:?}")))
                }
            }
        }

        Ok(TrainOutcome {
            session: SessionInfo {
                geometry,
                kappa,
                fingerprint,
                num_batches: num_batches as usize,
                batch_size: batch_size as usize,
            },
            steps,
            losses,
            accs,
            params: trainer.params().to_vec(),
            cac,
            bias,
            bytes_received: bytes,
        })
    }
}

/// Convenience: run provider + developer over a localhost TCP socket pair
/// (the two-process deployment collapsed into two threads for tests,
/// benches and the `provider_developer` example).
pub fn run_tcp_session(
    provider: std::sync::Arc<super::provider::ProviderNode>,
    engine: &Engine,
    plan: super::provider::StreamPlan,
    lr: f32,
    seed: u64,
) -> Result<TrainOutcome> {
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let prov = provider;
    let handle = std::thread::spawn(move || -> Result<()> {
        let (mut sock, _) = listener.accept()?;
        sock.set_nodelay(true).ok();
        prov.run_session(&mut sock, plan, seed ^ 0xDA7A)?;
        Ok(())
    });

    let dev = DeveloperNode::new(engine, seed, lr)?;
    let mut sock = std::net::TcpStream::connect(addr)?;
    sock.set_nodelay(true).ok();
    let outcome = dev.run_session(&mut sock, seed);
    handle
        .join()
        .map_err(|_| Error::Protocol("provider thread panicked".into()))??;
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::provider::{ProviderNode, StreamPlan};
    use crate::data::synth::{generate, SynthSpec};
    use crate::keys::KeyBundle;
    use crate::manifest::Manifest;
    use crate::Geometry;
    use std::path::PathBuf;

    fn engine() -> Engine {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Engine::new(Manifest::load(&dir).unwrap()).unwrap()
    }

    /// End-to-end over TCP: handshake, C^ac transfer, morphed stream,
    /// training steps execute, loss is finite and generally decreasing.
    #[test]
    fn tcp_delivery_session_trains() {
        let eng = engine();
        let spec = SynthSpec {
            geometry: Geometry::SMALL,
            num_classes: 4,
            train_per_class: 64,
            test_per_class: 16,
            noise: 0.05,
            max_shift: 1,
            seed: 2,
        };
        let keys = KeyBundle::generate(Geometry::SMALL, 16, 42).unwrap();
        let provider =
            std::sync::Arc::new(ProviderNode::new(keys, generate(&spec)).unwrap());
        let outcome = run_tcp_session(
            provider,
            &eng,
            StreamPlan { num_batches: 8, batch_size: 64 },
            0.05,
            7,
        )
        .unwrap();
        assert_eq!(outcome.steps, 8);
        assert_eq!(outcome.losses.len(), 8);
        assert!(outcome.losses.iter().all(|l| l.is_finite()));
        // 4-class problem from scratch: after 8 steps the loss should at
        // least move below the initial value
        assert!(
            outcome.losses[7] < outcome.losses[0],
            "losses: {:?}",
            outcome.losses
        );
        assert_eq!(
            outcome.cac.shape(),
            &[Geometry::SMALL.d_len(), Geometry::SMALL.f_len()]
        );
        assert_eq!(outcome.session.kappa, 16);
    }
}
