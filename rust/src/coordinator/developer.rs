//! The developer node (paper Fig. 1, right side).
//!
//! Connects to a provider through the typed [`MoleClient`] training
//! flow: sends its pre-trained first layer, receives the Aug-Conv matrix
//! and the morphed training stream, and trains the trunk through the AOT
//! artifacts — never seeing an original pixel, and never touching a raw
//! protocol frame. The same node exposes the trained model for serving
//! (register the outcome with a [`super::registry::ModelRegistry`]).

use super::client::MoleClient;
use super::trainer::Trainer;
use super::SessionInfo;
use crate::rng::Rng;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::{Error, Result};
use std::io::{Read, Write};

/// What a completed delivery-and-training session produced.
#[derive(Debug)]
pub struct TrainOutcome {
    pub session: SessionInfo,
    pub steps: usize,
    pub losses: Vec<f32>,
    pub accs: Vec<f32>,
    /// Trained trunk parameters (aug layout: conv2..fc2).
    pub params: Vec<Tensor>,
    /// The received Aug-Conv layer (for serving).
    pub cac: Tensor,
    pub bias: Vec<f32>,
    pub bytes_received: u64,
}

/// The developer node. Holds the engine + the pre-trained first layer it
/// offers to providers.
pub struct DeveloperNode<'e> {
    engine: &'e Engine,
    w1: Tensor,
    b1: Vec<f32>,
    lr: f32,
}

impl<'e> DeveloperNode<'e> {
    /// `w1`/`b1`: the first layer "trained on a public dataset" (Fig. 1).
    /// In the reproduction we He-initialize it from a seed — transfer
    /// quality of w1 affects absolute accuracy equally in all three
    /// groups, not the equivalence property under test.
    pub fn new(engine: &'e Engine, seed: u64, lr: f32) -> Result<Self> {
        let m = engine.manifest();
        let g = m.geometry("small")?;
        let mut rng = Rng::new(seed);
        let std = (2.0 / (g.alpha * g.p * g.p) as f64).sqrt() as f32;
        let w1 = Tensor::new(
            &[g.beta, g.alpha, g.p, g.p],
            rng.normal_vec(g.beta * g.alpha * g.p * g.p, std),
        )?;
        let b1 = vec![0.0; g.beta];
        Ok(Self { engine, w1, b1, lr })
    }

    pub fn first_layer(&self) -> (&Tensor, &[f32]) {
        (&self.w1, &self.b1)
    }

    /// Run the client side of a delivery session: handshake, ship layer 1,
    /// receive C^ac, train on the morphed stream.
    pub fn run_session<S: Read + Write>(&self, stream: S, seed: u64) -> Result<TrainOutcome> {
        // 1. handshake (version-checked by the SDK)
        let mut client = MoleClient::training_over(stream)?;
        let session = client
            .session()
            .cloned()
            .expect("training_over always yields a provider session");
        let m = self.engine.manifest();
        if session.batch_size != m.train_batch {
            return Err(Error::Protocol(format!(
                "provider batch size {} != artifact batch {}",
                session.batch_size, m.train_batch
            )));
        }

        // 2./3. ship the first layer, receive the Aug-Conv layer
        let (cac, bias) = client.negotiate_aug_conv(&self.w1, &self.b1)?;

        // 4. train on the morphed stream
        let mut trainer = Trainer::new_aug(self.engine, cac.clone(), bias.clone(), seed)?;
        let mut losses = Vec::new();
        let mut accs = Vec::new();
        let lr = self.lr;
        let steps = client.stream_training(|_, rows, labels| {
            let (l, a) = trainer.step(rows, labels, lr)?;
            losses.push(l);
            accs.push(a);
            if losses.len() % 50 == 0 {
                crate::logging::info(&format!(
                    "developer: step {} loss={l:.4} acc={a:.3}",
                    losses.len()
                ));
            }
            Ok(())
        })?;

        Ok(TrainOutcome {
            session,
            steps,
            losses,
            accs,
            params: trainer.params().to_vec(),
            cac,
            bias,
            bytes_received: client.bytes_in(),
        })
    }
}

/// Convenience: run provider + developer over a localhost TCP socket pair
/// (the two-process deployment collapsed into two threads for tests,
/// benches and the `provider_developer` example).
pub fn run_tcp_session(
    provider: std::sync::Arc<super::provider::ProviderNode>,
    engine: &Engine,
    plan: super::provider::StreamPlan,
    lr: f32,
    seed: u64,
) -> Result<TrainOutcome> {
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let prov = provider;
    let handle = std::thread::spawn(move || -> Result<()> {
        let (sock, _) = listener.accept()?;
        sock.set_nodelay(true).ok();
        prov.run_session(sock, plan, seed ^ 0xDA7A)?;
        Ok(())
    });

    let dev = DeveloperNode::new(engine, seed, lr)?;
    let sock = std::net::TcpStream::connect(addr)?;
    sock.set_nodelay(true).ok();
    let outcome = dev.run_session(sock, seed);
    handle
        .join()
        .map_err(|_| Error::Protocol("provider thread panicked".into()))??;
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::provider::{ProviderNode, StreamPlan};
    use crate::data::synth::{generate, SynthSpec};
    use crate::keys::KeyBundle;
    use crate::manifest::Manifest;
    use crate::Geometry;
    use std::path::PathBuf;

    fn engine() -> Engine {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Engine::new(Manifest::load(&dir).unwrap()).unwrap()
    }

    /// End-to-end over TCP: handshake, C^ac transfer, morphed stream,
    /// training steps execute, loss is finite and generally decreasing.
    #[test]
    fn tcp_delivery_session_trains() {
        let eng = engine();
        let spec = SynthSpec {
            geometry: Geometry::SMALL,
            num_classes: 4,
            train_per_class: 64,
            test_per_class: 16,
            noise: 0.05,
            max_shift: 1,
            seed: 2,
        };
        let keys = KeyBundle::generate(Geometry::SMALL, 16, 42).unwrap();
        let provider =
            std::sync::Arc::new(ProviderNode::new(keys, generate(&spec)).unwrap());
        let outcome = run_tcp_session(
            provider,
            &eng,
            StreamPlan { num_batches: 8, batch_size: 64 },
            0.05,
            7,
        )
        .unwrap();
        assert_eq!(outcome.steps, 8);
        assert_eq!(outcome.losses.len(), 8);
        assert!(outcome.losses.iter().all(|l| l.is_finite()));
        // 4-class problem from scratch: after 8 steps the loss should at
        // least move below the initial value
        assert!(
            outcome.losses[7] < outcome.losses[0],
            "losses: {:?}",
            outcome.losses
        );
        assert_eq!(
            outcome.cac.shape(),
            &[Geometry::SMALL.d_len(), Geometry::SMALL.f_len()]
        );
        assert_eq!(outcome.session.kappa, 16);
        assert_eq!(outcome.session.epoch, 0);
        // bytes_received now reflects real wire input (C^ac dominates)
        assert!(outcome.bytes_received as usize > outcome.cac.numel() * 4);
    }
}
