//! Developer-side training driver: owns parameters + momenta as rust
//! tensors and advances them by executing the AOT `train_step_*` artifacts
//! through the PJRT engine. The paper's three §4.4 experiment groups are
//! the three [`Variant`]s.

use crate::data::Batch;
use crate::manifest::ParamSpec;
use crate::rng::Rng;
use crate::runtime::{Arg, Engine};
use crate::tensor::Tensor;
use crate::{Error, Geometry, Result};

/// The §4.4 experiment groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Original network on original images (group 1).
    Base,
    /// Aug-Conv first layer on morphed rows (group 2).
    Aug,
    /// Original network fed morphed images — the sanity-check control
    /// (group 3). Structurally identical to `Base` (same artifact); the
    /// caller feeds morphed pixels.
    NoAug,
}

impl Variant {
    pub fn name(&self) -> &'static str {
        match self {
            Variant::Base => "base",
            Variant::Aug => "aug",
            Variant::NoAug => "noaug",
        }
    }
}

/// Initialize parameters from the manifest spec (He / zero), f32.
pub fn init_params(specs: &[ParamSpec], rng: &mut Rng) -> Vec<Tensor> {
    specs
        .iter()
        .map(|s| {
            if s.init == "he" {
                let std = (2.0 / s.fan_in as f64).sqrt() as f32;
                Tensor::new(&s.shape, rng.normal_vec(s.numel(), std)).unwrap()
            } else {
                Tensor::zeros(&s.shape)
            }
        })
        .collect()
}

/// Summary of one training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub variant: &'static str,
    pub steps: usize,
    pub losses: Vec<f32>,
    pub accs: Vec<f32>,
    pub test_loss: f32,
    pub test_acc: f32,
    pub wall_secs: f64,
}

impl TrainReport {
    /// Mean training accuracy over the last `k` recorded steps.
    pub fn tail_train_acc(&self, k: usize) -> f32 {
        let n = self.accs.len();
        if n == 0 {
            return 0.0;
        }
        let k = k.min(n);
        self.accs[n - k..].iter().sum::<f32>() / k as f32
    }
}

/// The training state machine.
pub struct Trainer<'e> {
    engine: &'e Engine,
    variant: Variant,
    geometry: Geometry,
    params: Vec<Tensor>,
    momenta: Vec<Tensor>,
    /// Aug variant: the fixed Aug-Conv matrix + permuted bias.
    aug: Option<(Tensor, Vec<f32>)>,
    train_artifact: String,
    eval_artifact: String,
    batch: usize,
}

impl<'e> Trainer<'e> {
    /// Construct for the base/noaug groups (trainable conv1).
    pub fn new_base(engine: &'e Engine, variant: Variant, seed: u64) -> Result<Self> {
        if variant == Variant::Aug {
            return Err(Error::Config("use new_aug for the aug variant".into()));
        }
        let m = engine.manifest();
        let g = m.geometry("small")?;
        let mut rng = Rng::new(seed);
        let params = init_params(&m.base_params, &mut rng);
        let momenta = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        Ok(Self {
            engine,
            variant,
            geometry: g,
            params,
            momenta,
            aug: None,
            train_artifact: format!("train_step_base_small_b{}", m.train_batch),
            eval_artifact: format!("eval_base_small_b{}", m.train_batch),
            batch: m.train_batch,
        })
    }

    /// Construct for the Aug-Conv group: C^ac + permuted bias are fixed
    /// inputs, only the trunk (conv2…fc2) trains.
    pub fn new_aug(
        engine: &'e Engine,
        cac: Tensor,
        bias: Vec<f32>,
        seed: u64,
    ) -> Result<Self> {
        let m = engine.manifest();
        let g = m.geometry("small")?;
        if cac.shape() != [g.d_len(), g.f_len()] || bias.len() != g.beta {
            return Err(Error::Shape(format!(
                "aug trainer: C^ac {:?} bias {}",
                cac.shape(),
                bias.len()
            )));
        }
        let mut rng = Rng::new(seed);
        let params = init_params(&m.aug_params, &mut rng);
        let momenta = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        Ok(Self {
            engine,
            variant: Variant::Aug,
            geometry: g,
            params,
            momenta,
            aug: Some((cac, bias)),
            train_artifact: format!("train_step_aug_small_b{}", m.train_batch),
            eval_artifact: format!("eval_aug_small_b{}", m.train_batch),
            batch: m.train_batch,
        })
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    pub fn params(&self) -> &[Tensor] {
        &self.params
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Expected input for one step: images [B,α,m,m] for base/noaug, rows
    /// [B,αm²] for aug.
    fn check_x(&self, x: &Tensor) -> Result<()> {
        let g = &self.geometry;
        let want: Vec<usize> = match self.variant {
            Variant::Aug => vec![self.batch, g.d_len()],
            _ => vec![self.batch, g.alpha, g.m, g.m],
        };
        if x.shape() != want.as_slice() {
            return Err(Error::Shape(format!(
                "trainer x {:?}, want {:?}",
                x.shape(),
                want
            )));
        }
        Ok(())
    }

    fn fixed_args(&self) -> Vec<Arg> {
        match &self.aug {
            Some((cac, bias)) => vec![
                Arg::T(cac.clone()),
                Arg::T(Tensor::new(&[bias.len()], bias.clone()).unwrap()),
            ],
            None => vec![],
        }
    }

    /// One SGD+momentum step; returns (loss, acc) on the batch.
    pub fn step(&mut self, x: &Tensor, y: &[i32], lr: f32) -> Result<(f32, f32)> {
        self.check_x(x)?;
        if y.len() != self.batch {
            return Err(Error::Shape(format!("labels {} != batch {}", y.len(), self.batch)));
        }
        let mut args = self.fixed_args();
        for p in &self.params {
            args.push(Arg::T(p.clone()));
        }
        for v in &self.momenta {
            args.push(Arg::T(v.clone()));
        }
        args.push(Arg::T(x.clone()));
        args.push(Arg::I(y.to_vec()));
        args.push(Arg::S(lr));
        let mut out = self.engine.exec(&self.train_artifact, &args)?;
        let np = self.params.len();
        if out.len() != 2 * np + 2 {
            return Err(Error::Runtime(format!(
                "train_step returned {} outputs, expected {}",
                out.len(),
                2 * np + 2
            )));
        }
        let acc = out.pop().unwrap().data()[0];
        let loss = out.pop().unwrap().data()[0];
        let momenta: Vec<Tensor> = out.split_off(np);
        self.params = out;
        self.momenta = momenta;
        Ok((loss, acc))
    }

    /// Evaluate (loss, acc) on one labelled batch of the training size.
    pub fn eval_batch(&self, x: &Tensor, y: &[i32]) -> Result<(f32, f32)> {
        self.check_x(x)?;
        let mut args = self.fixed_args();
        for p in &self.params {
            args.push(Arg::T(p.clone()));
        }
        args.push(Arg::T(x.clone()));
        args.push(Arg::I(y.to_vec()));
        let out = self.engine.exec(&self.eval_artifact, &args)?;
        Ok((out[0].data()[0], out[1].data()[0]))
    }

    /// Evaluate over a whole split, chunked into training-size batches
    /// (remainder dropped). `transform` maps raw images to the variant's
    /// input (identity / morph / morph+unroll).
    pub fn evaluate(
        &self,
        data: &Batch,
        transform: &dyn Fn(Tensor) -> Result<Tensor>,
    ) -> Result<(f32, f32)> {
        let shape = data.images.shape();
        let per = shape[1] * shape[2] * shape[3];
        let n = data.len() / self.batch;
        if n == 0 {
            return Err(Error::Shape("test split smaller than one batch".into()));
        }
        let (mut tl, mut ta) = (0.0f64, 0.0f64);
        for c in 0..n {
            let lo = c * self.batch;
            let imgs = Tensor::new(
                &[self.batch, shape[1], shape[2], shape[3]],
                data.images.data()[lo * per..(lo + self.batch) * per].to_vec(),
            )?;
            let x = transform(imgs)?;
            let y = &data.labels[lo..lo + self.batch];
            let (l, a) = self.eval_batch(&x, y)?;
            tl += l as f64;
            ta += a as f64;
        }
        Ok(((tl / n as f64) as f32, (ta / n as f64) as f32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;
    use std::path::PathBuf;

    fn engine() -> Engine {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Engine::new(Manifest::load(&dir).unwrap()).unwrap()
    }

    #[test]
    fn init_params_statistics() {
        let m = Manifest::load(
            &PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        )
        .unwrap();
        let mut rng = Rng::new(1);
        let ps = init_params(&m.base_params, &mut rng);
        assert_eq!(ps.len(), 10);
        // he layers have ~std sqrt(2/fan), zero layers are zero
        let w1 = &ps[0];
        let std: f64 = (w1.data().iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            / w1.numel() as f64)
            .sqrt();
        let want = (2.0f64 / m.base_params[0].fan_in as f64).sqrt();
        assert!((std - want).abs() / want < 0.25, "std={std} want={want}");
        assert!(ps[1].data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn base_step_reduces_loss_on_fixed_batch() {
        let eng = engine();
        let mut t = Trainer::new_base(&eng, Variant::Base, 3).unwrap();
        let mut rng = Rng::new(4);
        let g = crate::Geometry::SMALL;
        let x = Tensor::new(&[64, g.alpha, g.m, g.m], rng.normal_vec(64 * g.d_len(), 0.5))
            .unwrap();
        let y: Vec<i32> = (0..64).map(|_| rng.below(10) as i32).collect();
        let (first, _) = t.step(&x, &y, 0.05).unwrap();
        let mut last = first;
        for _ in 0..8 {
            let (l, _) = t.step(&x, &y, 0.05).unwrap();
            last = l;
        }
        assert!(
            last < first * 0.8,
            "loss did not decrease: {first} -> {last}"
        );
        // eval agrees with the training batch after memorization begins
        let (el, ea) = t.eval_batch(&x, &y).unwrap();
        assert!(el.is_finite() && (0.0..=1.0).contains(&ea));
    }

    #[test]
    fn shape_validation() {
        let eng = engine();
        let mut t = Trainer::new_base(&eng, Variant::Base, 3).unwrap();
        let x = Tensor::zeros(&[2, 3, 16, 16]);
        assert!(t.step(&x, &[0, 1], 0.1).is_err());
        assert!(Trainer::new_base(&eng, Variant::Aug, 0).is_err());
    }
}
