//! Serving path: request router + dynamic batcher.
//!
//! Inference requests (morphed rows) arrive from many client threads; a
//! single worker drains the queue, forms a batch of at most `max_batch`
//! (or whatever arrived within `timeout` of the first request), routes it
//! to the smallest AOT executable whose baked batch size fits (padding the
//! remainder), executes through PJRT, and fans the logits back out.
//!
//! The PJRT client wraps raw pointers (`!Send` buffers), so the worker
//! *owns* its [`Engine`]; clients interact through an mpsc handle — this
//! is the standard single-executor / many-clients serving layout.

use crate::manifest::Manifest;
use crate::metrics::ServingMetrics;
use crate::runtime::{Arg, Engine};
use crate::tensor::Tensor;
use crate::{Error, Result};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batcher tuning.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Upper bound on a formed batch (≤ the largest artifact batch).
    pub max_batch: usize,
    /// How long to hold a partial batch after the first request arrives.
    pub timeout: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 32, timeout: Duration::from_millis(2) }
    }
}

/// The trained model state needed for `infer_aug_*`.
pub struct ServingModel {
    pub cac: Tensor,
    pub bias: Vec<f32>,
    /// Trunk params (aug layout, conv2..fc2).
    pub params: Vec<Tensor>,
}

struct Request {
    row: Vec<f32>,
    enqueued: Instant,
    reply: mpsc::SyncSender<Result<Vec<f32>>>,
}

/// Client handle to a running serving worker.
#[derive(Clone)]
pub struct ServingHandle {
    tx: mpsc::Sender<Request>,
    pub metrics: Arc<ServingMetrics>,
    d_len: usize,
    num_classes: usize,
}

impl ServingHandle {
    /// Spawn the worker. PJRT handles are not `Send`, so the worker thread
    /// constructs its own [`Engine`] from the (plain-data) manifest.
    pub fn start(manifest: Manifest, model: ServingModel, cfg: BatcherConfig) -> Result<Self> {
        let g = manifest.geometry("small")?;
        let mut sizes = manifest.infer_batches.clone();
        sizes.sort_unstable();
        let largest = *sizes.last().ok_or_else(|| Error::Config("no infer batches".into()))?;
        if cfg.max_batch > largest {
            return Err(Error::Config(format!(
                "max_batch {} exceeds largest artifact batch {largest}",
                cfg.max_batch
            )));
        }
        let num_classes = manifest.num_classes;
        let metrics = Arc::new(ServingMetrics::default());
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
        let worker_metrics = metrics.clone();
        let d_len = g.d_len();
        std::thread::Builder::new()
            .name("mole-serving".into())
            .spawn(move || {
                let engine = match Engine::new(manifest) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                worker_loop(engine, model, cfg, sizes, rx, worker_metrics, d_len, num_classes)
            })
            .map_err(Error::Io)?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("serving worker died during init".into()))??;
        Ok(Self { tx, metrics, d_len, num_classes })
    }

    /// Blocking inference on one morphed row. Thread-safe; clones of the
    /// handle share the queue.
    pub fn infer(&self, row: &[f32]) -> Result<Vec<f32>> {
        if row.len() != self.d_len {
            return Err(Error::Shape(format!(
                "infer row len {} != {}",
                row.len(),
                self.d_len
            )));
        }
        self.metrics.requests.inc();
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request { row: row.to_vec(), enqueued: Instant::now(), reply: reply_tx })
            .map_err(|_| Error::Protocol("serving worker gone".into()))?;
        let out = reply_rx
            .recv()
            .map_err(|_| Error::Protocol("serving worker dropped request".into()))??;
        self.metrics.responses.inc();
        Ok(out)
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    engine: Engine,
    model: ServingModel,
    cfg: BatcherConfig,
    sizes: Vec<usize>,
    rx: mpsc::Receiver<Request>,
    metrics: Arc<ServingMetrics>,
    d_len: usize,
    _num_classes: usize,
) {
    // Precompile all batch variants up front (off the request path).
    for &b in &sizes {
        if b <= cfg.max_batch || b == sizes[0] {
            let _ = engine.prepare(&format!("infer_aug_small_b{b}"));
        }
    }
    loop {
        // block for the first request of the batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all handles dropped
        };
        let deadline = Instant::now() + cfg.timeout;
        let mut pending = vec![first];
        while pending.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // route to the smallest executable that fits
        let count = pending.len();
        let bucket = *sizes
            .iter()
            .find(|&&b| b >= count)
            .unwrap_or(sizes.last().unwrap());
        let mut rows = vec![0.0f32; bucket * d_len];
        for (i, r) in pending.iter().enumerate() {
            rows[i * d_len..(i + 1) * d_len].copy_from_slice(&r.row);
            metrics.queue_latency.record(r.enqueued.elapsed());
        }
        metrics.batches.inc();
        metrics.batched_items.add(count as u64);
        metrics.padding_items.add((bucket - count) as u64);

        let mut args: Vec<Arg> = vec![
            Arg::T(model.cac.clone()),
            Arg::T(Tensor::new(&[model.bias.len()], model.bias.clone()).unwrap()),
        ];
        for p in &model.params {
            args.push(Arg::T(p.clone()));
        }
        args.push(Arg::T(Tensor::new(&[bucket, d_len], rows).unwrap()));

        let t0 = Instant::now();
        let result = engine.exec(&format!("infer_aug_small_b{bucket}"), &args);
        metrics.execute_latency.record(t0.elapsed());

        match result {
            Ok(out) => {
                let logits = &out[0];
                let nc = logits.shape()[1];
                for (i, r) in pending.into_iter().enumerate() {
                    let v = logits.data()[i * nc..(i + 1) * nc].to_vec();
                    metrics.total_latency.record(r.enqueued.elapsed());
                    let _ = r.reply.send(Ok(v));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for r in pending {
                    let _ = r.reply.send(Err(Error::Runtime(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::init_params;
    use crate::manifest::Manifest;
    use crate::rng::Rng;
    use std::path::PathBuf;

    fn handle(max_batch: usize, timeout_ms: u64) -> ServingHandle {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let manifest = Manifest::load(&dir).unwrap();
        let g = manifest.geometry("small").unwrap();
        let mut rng = Rng::new(11);
        let params = init_params(&manifest.aug_params, &mut rng);
        let model = ServingModel {
            cac: Tensor::new(
                &[g.d_len(), g.f_len()],
                rng.normal_vec(g.d_len() * g.f_len(), 0.02),
            )
            .unwrap(),
            bias: vec![0.0; g.beta],
            params,
        };
        ServingHandle::start(
            manifest,
            model,
            BatcherConfig { max_batch, timeout: Duration::from_millis(timeout_ms) },
        )
        .unwrap()
    }

    #[test]
    fn single_request_roundtrip() {
        let h = handle(8, 1);
        let mut rng = Rng::new(0);
        let row = rng.normal_vec(768, 1.0);
        let logits = h.infer(&row).unwrap();
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(h.metrics.responses.get(), 1);
        // wrong length rejected client-side
        assert!(h.infer(&[0.0; 3]).is_err());
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let h = handle(8, 20);
        let mut threads = Vec::new();
        for i in 0..8 {
            let h = h.clone();
            threads.push(std::thread::spawn(move || {
                let mut rng = Rng::new(i);
                let row = rng.normal_vec(768, 1.0);
                h.infer(&row).unwrap()
            }));
        }
        for t in threads {
            let logits = t.join().unwrap();
            assert_eq!(logits.len(), 10);
        }
        assert_eq!(h.metrics.responses.get(), 8);
        // with a 20ms window the 8 requests should land in very few batches
        assert!(
            h.metrics.batches.get() <= 4,
            "batches={}",
            h.metrics.batches.get()
        );
        assert!(h.metrics.mean_batch_size() >= 2.0);
    }

    #[test]
    fn identical_rows_identical_logits_regardless_of_batching() {
        let h = handle(8, 5);
        let mut rng = Rng::new(5);
        let row = rng.normal_vec(768, 1.0);
        let a = h.infer(&row).unwrap();
        let b = h.infer(&row).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }
}
