//! Serving path: request router + adaptive micro-batcher.
//!
//! Inference requests (morphed rows) arrive from many client threads and
//! TCP sessions; a single worker drains the queue, coalesces concurrent
//! rows into one Aug-Conv GEMM (amortizing the `C^ac` multiply across
//! requests), routes the batch to the smallest AOT executable whose baked
//! batch size fits (padding the remainder), executes it, and fans the
//! logits back out per request.
//!
//! Flushing is **size-or-deadline**: a batch goes out as soon as it holds
//! `max_batch` rows, or when the hold window expires after the first row
//! arrived. With [`BatcherConfig::adaptive`] the hold window adapts to
//! load (see [`AdaptiveWindow`]): light traffic shrinks it toward
//! `min_timeout` so singleton requests aren't taxed, bursts widen it back
//! toward `timeout` so batches fill.
//!
//! Execution goes through a [`SharedEngine`] (`Send + Sync`), so the
//! worker shares one engine with every other consumer in the process
//! instead of constructing its own. (The PJRT engine wraps a non-`Send`
//! client and is not shareable; serving always executes on the
//! interpreter engine.)
//!
//! Two entry points:
//! * [`ServingHandle::infer`] — blocking, one row in / logits out;
//! * [`ServingHandle::submit`] — asynchronous, completion delivered to an
//!   `mpsc` channel; this is what the TCP session layer uses to keep many
//!   requests per connection in flight (responses may complete out of
//!   order across batches).

use crate::manifest::Manifest;
use crate::metrics::ServingMetrics;
use crate::runtime::{Arg, SharedEngine};
use crate::tensor::Tensor;
use crate::{Error, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batcher tuning.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Upper bound on a formed batch (≤ the largest artifact batch).
    pub max_batch: usize,
    /// Longest hold for a partial batch after the first request arrives.
    pub timeout: Duration,
    /// Floor for the adaptive hold window.
    pub min_timeout: Duration,
    /// Adapt the hold window to the observed fill level (see
    /// [`AdaptiveWindow`]). When false the window is fixed at `timeout`.
    pub adaptive: bool,
    /// Bound on the lane's submit queue, measured on the in-flight gauge
    /// (requests accepted but not yet answered). An enqueue that would
    /// push the gauge past this bound is **shed** with the typed
    /// [`Error::Overloaded`] carrying a retry hint — never parked on an
    /// unbounded channel. This is the per-lane half of the serving
    /// plane's end-to-end backpressure (the accept path has its own
    /// session/pending budgets).
    pub queue_bound: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 32,
            timeout: Duration::from_millis(2),
            min_timeout: Duration::from_micros(200),
            adaptive: false,
            queue_bound: 1024,
        }
    }
}

/// The size-or-deadline flush policy's adaptive half: a multiplicative
/// increase / decrease controller on the hold window.
///
/// * a batch that fills to `max_batch` flushed on **size** — demand is
///   high, double the window (up to `timeout`) so future partial batches
///   get the best chance to fill;
/// * a deadline flush at ≤ ¼ fill — holding bought almost no coalescing,
///   halve the window (down to `min_timeout`) so light traffic pays the
///   minimum latency tax;
/// * anything in between holds the window steady.
///
/// Pure state machine, no clocks: drive it with [`AdaptiveWindow::on_batch`]
/// and read [`AdaptiveWindow::window`]. Unit-testable without threads.
#[derive(Debug, Clone)]
pub struct AdaptiveWindow {
    current: Duration,
    min: Duration,
    max: Duration,
}

impl AdaptiveWindow {
    pub fn new(cfg: &BatcherConfig) -> Self {
        let min = cfg.min_timeout.min(cfg.timeout);
        Self { current: cfg.timeout, min, max: cfg.timeout }
    }

    /// The hold window to apply to the next batch.
    pub fn window(&self) -> Duration {
        self.current
    }

    /// Record a flushed batch of `fill` rows under the `max_batch` cap.
    pub fn on_batch(&mut self, fill: usize, max_batch: usize) {
        // low-fill threshold is at least 1 so small max_batch configs can
        // still decay (with max_batch <= 3, `max_batch / 4` would be 0 and
        // the window could only ever ratchet up)
        if fill >= max_batch {
            self.current = (self.current * 2).min(self.max);
        } else if fill <= (max_batch / 4).max(1) {
            self.current = (self.current / 2).max(self.min);
        }
    }
}

/// The trained model state needed for `infer_aug_*`.
pub struct ServingModel {
    pub cac: Tensor,
    pub bias: Vec<f32>,
    /// Trunk params (aug layout, conv2..fc2).
    pub params: Vec<Tensor>,
}

/// An asynchronous completion delivered by [`ServingHandle::submit`].
pub struct Completion {
    pub id: u64,
    pub result: Result<Vec<f32>>,
}

type ReplyFn = Box<dyn FnOnce(Result<Vec<f32>>) + Send>;

/// A reply that ALWAYS fires: invoked normally by the worker, or — if
/// the request is destroyed unserved (a racer's enqueue landing as the
/// shutdown teardown drops the channel) — from `Drop` with a typed
/// error. An accepted request therefore never goes silent: the client
/// gets logits or a `Fault`, never a hang.
struct Reply(Option<ReplyFn>);

impl Reply {
    fn call(mut self, r: Result<Vec<f32>>) {
        if let Some(f) = self.0.take() {
            f(r);
        }
    }

    /// Disarm without firing — for paths where the caller reports the
    /// failure itself (a failed send already returns `Err`; firing the
    /// dropped reply too would answer the same request twice).
    fn defuse(mut self) {
        self.0.take();
    }
}

impl Drop for Reply {
    fn drop(&mut self) {
        if let Some(f) = self.0.take() {
            f(Err(Error::Protocol(
                "serving lane shut down before this request was scheduled".into(),
            )));
        }
    }
}

/// RAII in-flight marker: decrements the handle's counter when its
/// request is consumed — whether the reply ran (success or error) or the
/// request was dropped unserved (worker gone). Retire-time emptiness
/// checks depend on this never leaking.
struct InFlightGuard(Arc<AtomicU64>);

impl Drop for InFlightGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

struct Request {
    row: Vec<f32>,
    enqueued: Instant,
    reply: Reply,
    _guard: InFlightGuard,
}

/// What travels to the worker: a request, or the shutdown marker sent by
/// [`ServingHandle::shutdown`]. Channel FIFO guarantees every request
/// enqueued before the marker is executed before the worker exits — the
/// tail is flushed, never dropped.
enum Job {
    Req(Request),
    Shutdown,
}

/// Client handle to a running serving worker.
#[derive(Clone)]
pub struct ServingHandle {
    tx: mpsc::Sender<Job>,
    pub metrics: Arc<ServingMetrics>,
    /// Set by [`ServingHandle::shutdown`]; refuses new enqueues.
    closed: Arc<AtomicBool>,
    /// Requests enqueued whose replies have not yet been delivered.
    in_flight: Arc<AtomicU64>,
    worker: Arc<Mutex<Option<JoinHandle<()>>>>,
    cfg: BatcherConfig,
    d_len: usize,
    num_classes: usize,
}

impl ServingHandle {
    /// Spawn the worker over a fresh [`SharedEngine`] for `manifest`.
    pub fn start(manifest: Manifest, model: ServingModel, cfg: BatcherConfig) -> Result<Self> {
        Self::start_shared(SharedEngine::new(manifest), model, cfg)
    }

    /// Spawn the worker over an engine shared with the rest of the
    /// process (the TCP server, other batchers, eval paths …).
    pub fn start_shared(
        engine: SharedEngine,
        model: ServingModel,
        cfg: BatcherConfig,
    ) -> Result<Self> {
        Self::start_lane(engine, model, cfg, "serving")
    }

    /// Spawn the worker as a named lane: identical to
    /// [`ServingHandle::start_shared`] but the worker thread carries the
    /// label (the registry names lanes `model@epoch` so thread dumps of a
    /// multi-tenant server stay readable).
    pub fn start_lane(
        engine: SharedEngine,
        model: ServingModel,
        cfg: BatcherConfig,
        label: &str,
    ) -> Result<Self> {
        let manifest = engine.manifest();
        let g = manifest.geometry("small")?;
        let mut sizes = manifest.infer_batches.clone();
        sizes.sort_unstable();
        let largest = *sizes.last().ok_or_else(|| Error::Config("no infer batches".into()))?;
        if cfg.max_batch == 0 {
            return Err(Error::Config("max_batch must be >= 1".into()));
        }
        if cfg.max_batch > largest {
            return Err(Error::Config(format!(
                "max_batch {} exceeds largest artifact batch {largest}",
                cfg.max_batch
            )));
        }
        if cfg.queue_bound == 0 {
            return Err(Error::Config("queue_bound must be >= 1".into()));
        }
        let num_classes = manifest.num_classes;
        let metrics = Arc::new(ServingMetrics::default());
        let (tx, rx) = mpsc::channel::<Job>();
        let worker_metrics = metrics.clone();
        let d_len = g.d_len();
        // Precompile / validate all bucket executables off the request path.
        for &b in &sizes {
            if b <= cfg.max_batch || b == sizes[0] {
                engine.prepare(&format!("infer_aug_small_b{b}"))?;
            }
        }
        let worker_cfg = cfg.clone();
        let worker = std::thread::Builder::new()
            .name(format!("mole-lane-{label}"))
            .spawn(move || {
                worker_loop(engine, model, worker_cfg, sizes, rx, worker_metrics, d_len)
            })
            .map_err(Error::Io)?;
        Ok(Self {
            tx,
            metrics,
            closed: Arc::new(AtomicBool::new(false)),
            in_flight: Arc::new(AtomicU64::new(0)),
            worker: Arc::new(Mutex::new(Some(worker))),
            cfg,
            d_len,
            num_classes,
        })
    }

    /// Requests accepted but not yet answered (queued or mid-batch).
    /// Zero is the registry's retire precondition: a lane may only be
    /// torn down once its batcher queue is empty.
    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// True once [`ServingHandle::shutdown`] has run; enqueues are
    /// refused from that point on.
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Graceful lane teardown: stop accepting new requests, let the
    /// worker flush everything already enqueued (channel FIFO — the
    /// shutdown marker sorts after the tail), and join it. Idempotent;
    /// replies for the flushed tail are delivered normally.
    ///
    /// Robust against a dead worker: a panic on the worker thread (or on
    /// a previous caller that died holding the join-handle mutex) must
    /// not turn graceful shutdown into a second panic. The poisoned lock
    /// is recovered — the slot it guards is a plain `Option<JoinHandle>`
    /// with no invariant a panic can break — and the worker's own death
    /// surfaces as the typed [`Error::Runtime`] so operators see *why*
    /// the lane went down instead of a poison unwrap.
    pub fn shutdown(&self) -> Result<()> {
        self.closed.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Job::Shutdown);
        let mut slot =
            self.worker.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some(w) = slot.take() {
            if let Err(panic) = w.join() {
                let msg = panic
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "unknown panic payload".into());
                return Err(Error::Runtime(format!(
                    "serving worker died by panic: {msg}"
                )));
            }
        }
        Ok(())
    }

    /// The backoff hint stamped into [`Error::Overloaded`] when this
    /// lane sheds: roughly the time the current backlog needs to drain
    /// (queued batches × the active hold window), clamped to [1, 1000]
    /// ms so a hint is always actionable and never pins a client for
    /// more than a second.
    pub fn retry_after_ms(&self) -> u64 {
        let max_batch = self.cfg.max_batch.max(1) as u64;
        let backlog_batches = self.in_flight().div_ceil(max_batch);
        // the live adaptive window when the worker has stamped one, the
        // configured ceiling before first flush
        let window_us = match self.metrics.window_us.get() {
            0 => self.cfg.timeout.as_micros() as u64,
            w => w,
        };
        (backlog_batches.max(1) * window_us / 1000).clamp(1, 1000)
    }

    /// Blocking inference on one morphed row. Thread-safe; clones of the
    /// handle share the queue.
    pub fn infer(&self, row: &[f32]) -> Result<Vec<f32>> {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.enqueue(
            row,
            Instant::now(),
            Box::new(move |r| {
                let _ = reply_tx.send(r);
            }),
        )?;
        let out = reply_rx
            .recv()
            .map_err(|_| Error::Protocol("serving worker dropped request".into()))??;
        self.metrics.responses.inc();
        Ok(out)
    }

    /// Asynchronous inference: enqueue one row; the completion (tagged
    /// with `id`) is delivered to `done` when its batch executes.
    /// Completions for different ids may arrive out of order relative to
    /// submission — match on [`Completion::id`].
    pub fn submit(&self, id: u64, row: &[f32], done: mpsc::Sender<Completion>) -> Result<()> {
        self.submit_with(row, move |result| {
            let _ = done.send(Completion { id, result });
        })
    }

    /// Asynchronous inference with an arbitrary completion callback,
    /// invoked on the batcher worker thread when the row's batch
    /// executes. The TCP session layer uses this to write
    /// `InferResponse` frames straight into a connection's writer queue.
    pub fn submit_with<F>(&self, row: &[f32], reply: F) -> Result<()>
    where
        F: FnOnce(Result<Vec<f32>>) + Send + 'static,
    {
        let metrics = self.metrics.clone();
        self.enqueue(
            row,
            Instant::now(),
            Box::new(move |result| {
                // like the blocking path, only successes count as served
                if result.is_ok() {
                    metrics.responses.inc();
                }
                reply(result);
            }),
        )
    }

    fn enqueue(&self, row: &[f32], enqueued: Instant, reply: ReplyFn) -> Result<()> {
        if row.len() != self.d_len {
            return Err(Error::Shape(format!(
                "infer row len {} != {}",
                row.len(),
                self.d_len
            )));
        }
        if self.closed.load(Ordering::SeqCst) {
            return Err(Error::Protocol("serving lane is shut down".into()));
        }
        // Admission control on the in-flight gauge: past the bound the
        // request is shed typed with a backoff hint, never parked on the
        // channel. (The increment below can race a concurrent enqueue
        // past the bound by a few requests — the bound is a shedding
        // threshold, not a hard capacity invariant, so an off-by-few
        // under contention is harmless and keeps this lock-free.)
        if self.in_flight.load(Ordering::SeqCst) >= self.cfg.queue_bound as u64 {
            self.metrics.overloaded.inc();
            return Err(Error::Overloaded { retry_after_ms: self.retry_after_ms() });
        }
        self.metrics.requests.inc();
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        let guard = InFlightGuard(self.in_flight.clone());
        let job = Job::Req(Request {
            row: row.to_vec(),
            enqueued,
            reply: Reply(Some(reply)),
            _guard: guard,
        });
        match self.tx.send(job) {
            Ok(()) => Ok(()),
            Err(mpsc::SendError(job)) => {
                // this Err return IS the answer; defuse the reply so the
                // request is not also answered from Drop (double fault),
                // while the guard still un-counts it
                if let Job::Req(req) = job {
                    req.reply.defuse();
                }
                Err(Error::Protocol("serving worker gone".into()))
            }
        }
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Row length this model serves (α·m²).
    pub fn d_len(&self) -> usize {
        self.d_len
    }

    /// The lane's submit-queue bound (shedding threshold on the
    /// in-flight gauge).
    pub fn queue_bound(&self) -> usize {
        self.cfg.queue_bound
    }
}

fn worker_loop(
    engine: SharedEngine,
    model: ServingModel,
    cfg: BatcherConfig,
    sizes: Vec<usize>,
    rx: mpsc::Receiver<Job>,
    metrics: Arc<ServingMetrics>,
    d_len: usize,
) {
    let mut adaptive = AdaptiveWindow::new(&cfg);
    // The constant arg prefix (C^ac, bias, trunk params) is built once;
    // only the trailing rows tensor changes per batch. Cloning the
    // multi-megabyte C^ac on every flush would dominate small-batch
    // latency.
    let mut args: Vec<Arg> = Vec::with_capacity(model.params.len() + 3);
    args.push(Arg::T(model.cac.clone()));
    args.push(Arg::T(Tensor::new(&[model.bias.len()], model.bias.clone()).unwrap()));
    for p in &model.params {
        args.push(Arg::T(p.clone()));
    }
    args.push(Arg::T(Tensor::zeros(&[0]))); // rows slot, replaced per batch
    // Once the shutdown marker is seen, keep flushing whatever is still
    // queued (without holding new batches open for the window) and exit
    // when the queue is empty — the tail is served, never dropped.
    let mut shutting_down = false;
    loop {
        // block for the first request of the batch
        let first = if shutting_down {
            match rx.try_recv() {
                Ok(Job::Req(r)) => r,
                Ok(Job::Shutdown) => continue,
                Err(_) => return, // tail flushed
            }
        } else {
            match rx.recv() {
                Ok(Job::Req(r)) => r,
                Ok(Job::Shutdown) => {
                    shutting_down = true;
                    continue;
                }
                Err(_) => return, // all handles dropped
            }
        };
        let window = if cfg.adaptive { adaptive.window() } else { cfg.timeout };
        metrics.window_us.set(window.as_micros() as u64);
        let deadline = Instant::now() + window;
        let mut pending = vec![first];
        while pending.len() < cfg.max_batch {
            if shutting_down {
                // drain without waiting: the lane is closing
                match rx.try_recv() {
                    Ok(Job::Req(r)) => pending.push(r),
                    Ok(Job::Shutdown) | Err(_) => break,
                }
                continue;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Job::Req(r)) => pending.push(r),
                Ok(Job::Shutdown) => {
                    shutting_down = true;
                    break;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        adaptive.on_batch(pending.len(), cfg.max_batch);

        // route to the smallest executable that fits
        let count = pending.len();
        let bucket = *sizes
            .iter()
            .find(|&&b| b >= count)
            .unwrap_or(sizes.last().unwrap());
        let mut rows = vec![0.0f32; bucket * d_len];
        for (i, r) in pending.iter().enumerate() {
            rows[i * d_len..(i + 1) * d_len].copy_from_slice(&r.row);
            metrics.queue_latency.record(r.enqueued.elapsed());
        }
        metrics.batches.inc();
        metrics.batched_items.add(count as u64);
        metrics.padding_items.add((bucket - count) as u64);

        *args.last_mut().unwrap() = Arg::T(Tensor::new(&[bucket, d_len], rows).unwrap());

        let t0 = Instant::now();
        let result = engine.exec(&format!("infer_aug_small_b{bucket}"), &args);
        metrics.execute_latency.record(t0.elapsed());

        match result {
            Ok(out) => {
                let logits = &out[0];
                let nc = logits.shape()[1];
                for (i, r) in pending.into_iter().enumerate() {
                    let v = logits.data()[i * nc..(i + 1) * nc].to_vec();
                    metrics.total_latency.record(r.enqueued.elapsed());
                    r.reply.call(Ok(v));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for r in pending {
                    r.reply.call(Err(Error::Runtime(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::init_params;
    use crate::manifest::Manifest;
    use crate::rng::Rng;
    use std::path::PathBuf;

    fn handle(max_batch: usize, timeout_ms: u64) -> ServingHandle {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let manifest = Manifest::load(&dir).unwrap();
        let g = manifest.geometry("small").unwrap();
        let mut rng = Rng::new(11);
        let params = init_params(&manifest.aug_params, &mut rng);
        let model = ServingModel {
            cac: Tensor::new(
                &[g.d_len(), g.f_len()],
                rng.normal_vec(g.d_len() * g.f_len(), 0.02),
            )
            .unwrap(),
            bias: vec![0.0; g.beta],
            params,
        };
        ServingHandle::start(
            manifest,
            model,
            BatcherConfig {
                max_batch,
                timeout: Duration::from_millis(timeout_ms),
                ..BatcherConfig::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn single_request_roundtrip() {
        let h = handle(8, 1);
        let mut rng = Rng::new(0);
        let row = rng.normal_vec(768, 1.0);
        let logits = h.infer(&row).unwrap();
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(h.metrics.responses.get(), 1);
        // wrong length rejected client-side
        assert!(h.infer(&[0.0; 3]).is_err());
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let h = handle(8, 20);
        let mut threads = Vec::new();
        for i in 0..8 {
            let h = h.clone();
            threads.push(std::thread::spawn(move || {
                let mut rng = Rng::new(i);
                let row = rng.normal_vec(768, 1.0);
                h.infer(&row).unwrap()
            }));
        }
        for t in threads {
            let logits = t.join().unwrap();
            assert_eq!(logits.len(), 10);
        }
        assert_eq!(h.metrics.responses.get(), 8);
        // with a 20ms window the 8 requests should land in very few batches
        assert!(
            h.metrics.batches.get() <= 4,
            "batches={}",
            h.metrics.batches.get()
        );
        assert!(h.metrics.mean_batch_size() >= 2.0);
    }

    #[test]
    fn identical_rows_identical_logits_regardless_of_batching() {
        let h = handle(8, 5);
        let mut rng = Rng::new(5);
        let row = rng.normal_vec(768, 1.0);
        let a = h.infer(&row).unwrap();
        let b = h.infer(&row).unwrap();
        assert_eq!(a, b, "same row must produce bitwise-identical logits");
    }

    /// `submit` keeps many requests in flight from one thread; completions
    /// (possibly spread over several batches, finishing out of order
    /// relative to submission) must carry the right id → logits pairing.
    #[test]
    fn submit_pairs_ids_with_rows_across_batches() {
        let h = handle(8, 2);
        let mut rng = Rng::new(3);
        let rows: Vec<Vec<f32>> = (0..24).map(|_| rng.normal_vec(768, 1.0)).collect();

        // expected logits one at a time, before loading the queue
        let expect: Vec<Vec<f32>> = rows.iter().map(|r| h.infer(r).unwrap()).collect();

        let (done_tx, done_rx) = mpsc::channel();
        for (i, row) in rows.iter().enumerate() {
            h.submit(i as u64, row, done_tx.clone()).unwrap();
        }
        drop(done_tx);

        let mut got: Vec<Option<Vec<f32>>> = vec![None; rows.len()];
        let mut order = Vec::new();
        for c in done_rx {
            order.push(c.id);
            let slot = &mut got[c.id as usize];
            assert!(slot.is_none(), "duplicate completion for id {}", c.id);
            *slot = Some(c.result.unwrap());
        }
        assert!(order.len() == rows.len());
        for (i, g) in got.iter().enumerate() {
            assert_eq!(
                g.as_deref(),
                Some(expect[i].as_slice()),
                "id {i} paired with wrong logits"
            );
        }
        // 24 rows through max_batch=8 ⇒ at least 3 executed batches and
        // real coalescing
        assert!(h.metrics.batches.get() >= 3);
        assert!(h.metrics.mean_batch_size() > 1.0);
    }

    #[test]
    fn adaptive_window_policy() {
        let cfg = BatcherConfig {
            max_batch: 32,
            timeout: Duration::from_millis(4),
            min_timeout: Duration::from_micros(250),
            adaptive: true,
            ..BatcherConfig::default()
        };
        let mut w = AdaptiveWindow::new(&cfg);
        assert_eq!(w.window(), Duration::from_millis(4));
        // singleton deadline flushes decay toward the floor…
        for _ in 0..10 {
            w.on_batch(1, 32);
        }
        assert_eq!(w.window(), Duration::from_micros(250));
        // …mid-fill batches hold steady…
        w.on_batch(16, 32);
        assert_eq!(w.window(), Duration::from_micros(250));
        // …size flushes double back up, capped at the configured max.
        for _ in 0..10 {
            w.on_batch(32, 32);
        }
        assert_eq!(w.window(), Duration::from_millis(4));
        // degenerate config: floor above max clamps to max
        let odd = BatcherConfig {
            max_batch: 8,
            timeout: Duration::from_micros(100),
            min_timeout: Duration::from_millis(9),
            adaptive: true,
            ..BatcherConfig::default()
        };
        let w = AdaptiveWindow::new(&odd);
        assert_eq!(w.window(), Duration::from_micros(100));
        // small max_batch must still decay on singleton flushes (a
        // max_batch/4 == 0 threshold would be an up-only ratchet)
        let small = BatcherConfig {
            max_batch: 2,
            timeout: Duration::from_millis(4),
            min_timeout: Duration::from_micros(250),
            adaptive: true,
            ..BatcherConfig::default()
        };
        let mut w = AdaptiveWindow::new(&small);
        w.on_batch(2, 2); // full batch holds the ceiling
        for _ in 0..10 {
            w.on_batch(1, 2);
        }
        assert_eq!(w.window(), Duration::from_micros(250));
    }

    /// Satellite: window boundaries. The window must clamp exactly at
    /// `min_timeout` and `timeout`, and no halve/double sequence —
    /// including adversarial alternation — may push it outside
    /// `[min_timeout, timeout]` or strand it where it cannot recover.
    #[test]
    fn adaptive_window_boundary_clamps() {
        let cfg = BatcherConfig {
            max_batch: 32,
            timeout: Duration::from_millis(3),
            min_timeout: Duration::from_micros(300),
            adaptive: true,
            ..BatcherConfig::default()
        };
        // already at the ceiling: size flushes hold it there exactly
        let mut w = AdaptiveWindow::new(&cfg);
        for _ in 0..100 {
            w.on_batch(32, 32);
            assert_eq!(w.window(), Duration::from_millis(3));
        }
        // decay to the floor, then keep hammering: clamps exactly at min
        for _ in 0..100 {
            w.on_batch(1, 32);
            assert!(w.window() >= Duration::from_micros(300));
        }
        assert_eq!(w.window(), Duration::from_micros(300));
        // one doubling from the floor recovers (not stranded below a
        // power-of-two boundary)
        w.on_batch(32, 32);
        assert_eq!(w.window(), Duration::from_micros(600));

        // adversarial alternation cannot oscillate out of range
        let mut w = AdaptiveWindow::new(&cfg);
        for i in 0..1000 {
            w.on_batch(if i % 2 == 0 { 32 } else { 1 }, 32);
            assert!(
                w.window() >= Duration::from_micros(300)
                    && w.window() <= Duration::from_millis(3),
                "window {:?} escaped [min, max] at step {i}",
                w.window()
            );
        }

        // property: any seeded fill sequence stays in range
        crate::testkit::forall(
            0xADA,
            32,
            |rng| (0..64).map(|_| rng.below(33)).collect::<Vec<_>>(),
            |fills| {
                let mut w = AdaptiveWindow::new(&cfg);
                for &f in fills {
                    w.on_batch(f, 32);
                    if w.window() < cfg.min_timeout.min(cfg.timeout)
                        || w.window() > cfg.timeout
                    {
                        return Err(format!("window {:?} out of range", w.window()));
                    }
                }
                Ok(())
            },
        );
    }

    /// Graceful shutdown flushes the tail: requests enqueued before
    /// `shutdown()` are all answered (channel FIFO sorts them before the
    /// marker), the in-flight gauge returns to zero, and later enqueues
    /// are refused typed.
    #[test]
    fn shutdown_flushes_tail_then_refuses() {
        // a long hold window would park the tail; shutdown must override
        // it and flush immediately. `handle()` rebuilds the same seeded
        // model every call, so a fast twin supplies reference logits.
        let h = handle(8, 2_000);
        let reference = handle(8, 1);
        let mut rng = Rng::new(13);
        let rows: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(768, 1.0)).collect();
        let expect: Vec<Vec<f32>> =
            rows.iter().map(|r| reference.infer(r).unwrap()).collect();
        let (done_tx, done_rx) = mpsc::channel();
        for (i, row) in rows.iter().enumerate() {
            h.submit(i as u64, row, done_tx.clone()).unwrap();
        }
        drop(done_tx);
        assert!(h.in_flight() > 0, "tail not registered as in flight");
        let t0 = Instant::now();
        h.shutdown().unwrap();
        // every pre-shutdown request answered, correctly paired, fast
        let mut got = vec![None; rows.len()];
        for c in done_rx {
            got[c.id as usize] = Some(c.result.unwrap());
        }
        for (i, g) in got.iter().enumerate() {
            assert_eq!(g.as_deref(), Some(expect[i].as_slice()), "id {i} lost or wrong");
        }
        assert!(
            t0.elapsed() < Duration::from_millis(1_500),
            "shutdown waited out the hold window instead of flushing"
        );
        assert_eq!(h.in_flight(), 0);
        assert!(h.is_closed());
        // post-shutdown traffic is refused without panicking
        let err = h.infer(&rows[0]).unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
        // idempotent
        h.shutdown().unwrap();
    }

    /// Satellite: the bounded submit queue sheds typed. Requests past
    /// `queue_bound` on the in-flight gauge come back as
    /// [`Error::Overloaded`] with an actionable `retry_after_ms`, the
    /// shed counter moves, nothing hangs — and once the backlog drains,
    /// admission reopens without intervention.
    #[test]
    fn bounded_queue_sheds_typed_overload() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let manifest = Manifest::load(&dir).unwrap();
        let g = manifest.geometry("small").unwrap();
        let mut rng = Rng::new(17);
        let model = ServingModel {
            cac: Tensor::new(
                &[g.d_len(), g.f_len()],
                rng.normal_vec(g.d_len() * g.f_len(), 0.02),
            )
            .unwrap(),
            bias: vec![0.0; g.beta],
            params: init_params(&manifest.aug_params, &mut rng),
        };
        // a long hold window parks the first request, so later enqueues
        // pile onto the gauge deterministically
        let h = ServingHandle::start(
            manifest,
            model,
            BatcherConfig {
                max_batch: 4,
                timeout: Duration::from_millis(2_000),
                queue_bound: 3,
                ..BatcherConfig::default()
            },
        )
        .unwrap();
        assert_eq!(h.queue_bound(), 3);
        let row = rng.normal_vec(768, 1.0);
        let (done_tx, done_rx) = mpsc::channel();
        for i in 0..3u64 {
            h.submit(i, &row, done_tx.clone()).unwrap();
        }
        // gauge is at the bound: the 4th submit is shed typed, with a
        // sane hint, and is NOT left in flight
        let err = h.submit(3, &row, done_tx.clone()).unwrap_err();
        match err {
            Error::Overloaded { retry_after_ms } => {
                assert!((1..=1000).contains(&retry_after_ms), "{retry_after_ms}");
            }
            other => panic!("expected Overloaded, got {other}"),
        }
        assert_eq!(h.metrics.overloaded.get(), 1);
        assert_eq!(h.in_flight(), 3);
        // flush the backlog (max_batch 4 > 3 queued, so shutdown drains
        // in one batch); every admitted request is answered
        h.shutdown().unwrap();
        drop(done_tx);
        let mut served = 0;
        for c in done_rx {
            c.result.unwrap();
            served += 1;
        }
        assert_eq!(served, 3, "admitted requests must all be answered");
    }

    /// Satellite bugfix: a poisoned join-handle mutex must not turn
    /// graceful shutdown into a second panic. The mutex is poisoned the
    /// way any panicking holder would; shutdown recovers the lock (the
    /// guarded slot is a plain `Option` with no breakable invariant) and
    /// completes instead of dying on `.unwrap()`.
    #[test]
    fn shutdown_survives_poisoned_worker_mutex() {
        let h = handle(8, 1);
        // poison the join-handle mutex the way a panicking caller would
        let poisoner = h.clone();
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.worker.lock().unwrap();
            panic!("deliberate: poison the worker mutex");
        })
        .join();
        assert!(h.worker.lock().is_err(), "mutex should be poisoned");
        // old code: shutdown() panicked here on the poisoned unwrap
        h.shutdown().unwrap();
        assert!(h.is_closed());
    }

    /// The typed-worker-death half of the shutdown bugfix: when the
    /// worker thread itself dies by panic, `shutdown` joins it and
    /// returns [`Error::Runtime`] naming the panic instead of succeeding
    /// silently (or poisoning anything).
    #[test]
    fn shutdown_reports_worker_panic_typed() {
        let h = handle(8, 1);
        // replace the real worker with one that dies by panic — the
        // registry can't make the engine panic deterministically, but
        // the join/report path is identical
        let dead = std::thread::Builder::new()
            .name("mole-lane-doomed".into())
            .spawn(|| panic!("deliberate: worker died"))
            .unwrap();
        let real = h.worker.lock().unwrap().replace(dead).unwrap();
        let err = h.shutdown().unwrap_err();
        assert!(
            matches!(&err, Error::Runtime(m) if m.contains("worker died by panic")
                && m.contains("deliberate")),
            "{err}"
        );
        // idempotent after the report; join the displaced real worker
        h.shutdown().unwrap();
        real.join().unwrap();
    }

    #[test]
    fn adaptive_batcher_still_serves() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let manifest = Manifest::load(&dir).unwrap();
        let g = manifest.geometry("small").unwrap();
        let mut rng = Rng::new(21);
        let model = ServingModel {
            cac: Tensor::new(
                &[g.d_len(), g.f_len()],
                rng.normal_vec(g.d_len() * g.f_len(), 0.02),
            )
            .unwrap(),
            bias: vec![0.0; g.beta],
            params: init_params(&manifest.aug_params, &mut rng),
        };
        let h = ServingHandle::start(
            manifest,
            model,
            BatcherConfig {
                max_batch: 8,
                timeout: Duration::from_millis(2),
                min_timeout: Duration::from_micros(100),
                adaptive: true,
                ..BatcherConfig::default()
            },
        )
        .unwrap();
        let row = rng.normal_vec(768, 1.0);
        let a = h.infer(&row).unwrap();
        let b = h.infer(&row).unwrap();
        assert_eq!(a, b);
        // after singleton traffic the adaptive window must have decayed
        assert!(h.metrics.window_us.get() <= 2000);
    }
}
