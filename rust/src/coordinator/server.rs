//! The concurrent TCP serving layer (`mole serve`).
//!
//! A [`Server`] binds a `std::net::TcpListener` and accepts many
//! concurrent client sessions on a fixed thread pool. Each session runs
//! the serving half of the wire protocol ([`super::protocol`]):
//!
//! 1. the server opens with `Hello` (geometry, κ, key fingerprint, and
//!    the batcher's `max_batch` in the `batch_size` slot) so clients can
//!    size their morphed rows and verify they hold matching keys;
//! 2. the client streams `InferRequest { id, row }` frames — any number,
//!    pipelined as deep as it likes;
//! 3. the server routes every row into the shared adaptive micro-batcher
//!    ([`super::batcher`]), which coalesces rows from *all* sessions into
//!    single Aug-Conv GEMMs, and fans `InferResponse { id, logits }`
//!    frames back on the originating connection — possibly out of order
//!    across ids (clients match on `id`);
//! 4. the client closes with `EndOfData`; the server flushes every
//!    in-flight response, answers `EndOfData`, and ends the session.
//!
//! Per-request failures (bad row length, engine faults) come back as
//! `Fault` frames; framing violations fault the session but never the
//! server. All sessions execute against one `Send + Sync`
//! [`SharedEngine`] — no per-connection engine or model state.

use super::batcher::{BatcherConfig, ServingHandle, ServingModel};
use super::protocol::{read_message, write_message, Message};
use crate::coordinator::trainer::init_params;
use crate::manifest::Manifest;
use crate::metrics::ServingMetrics;
use crate::rng::Rng;
use crate::runtime::SharedEngine;
use crate::tensor::Tensor;
use crate::{Error, Geometry, Result};
use std::io::Read;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server tuning.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address, e.g. `127.0.0.1:7433` (`:0` picks a free port).
    pub addr: String,
    /// Session worker threads == max concurrently served connections
    /// (excess connections queue in the accept channel).
    pub session_workers: usize,
    /// Micro-batcher policy shared by all sessions.
    pub batcher: BatcherConfig,
    /// Advertised in `Hello` so clients can check key compatibility.
    pub kappa: usize,
    /// Key fingerprint advertised in `Hello`.
    pub fingerprint: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7433".to_string(),
            session_workers: 8,
            batcher: BatcherConfig::default(),
            kappa: 0,
            fingerprint: String::new(),
        }
    }
}

/// A running serving instance: acceptor thread + session pool + batcher.
pub struct Server {
    local_addr: SocketAddr,
    handle: ServingHandle,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    sessions: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind the listener and start serving `model` through `engine`.
    pub fn bind(engine: SharedEngine, model: ServingModel, cfg: ServeConfig) -> Result<Self> {
        let geometry = engine.manifest().geometry("small")?;
        let handle = ServingHandle::start_shared(engine, model, cfg.batcher.clone())?;
        let listener = TcpListener::bind(&cfg.addr)?;
        let local_addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let hello = Message::Hello {
            geometry,
            kappa: cfg.kappa,
            fingerprint: cfg.fingerprint.clone(),
            num_batches: 0,
            batch_size: cfg.batcher.max_batch as u32,
        };

        let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let workers = cfg.session_workers.max(1);
        let mut sessions = Vec::with_capacity(workers);
        for w in 0..workers {
            let conn_rx = conn_rx.clone();
            let handle = handle.clone();
            let hello = hello.clone();
            sessions.push(
                std::thread::Builder::new()
                    .name(format!("mole-session-{w}"))
                    .spawn(move || loop {
                        let sock = match conn_rx.lock().unwrap().recv() {
                            Ok(s) => s,
                            Err(_) => return, // acceptor gone: drain done
                        };
                        if let Err(e) = run_session(sock, &handle, &hello) {
                            crate::logging::warn(&format!("session ended with error: {e}"));
                        }
                    })
                    .map_err(Error::Io)?,
            );
        }

        let acceptor = {
            let shutdown = shutdown.clone();
            let metrics = handle.metrics.clone();
            std::thread::Builder::new()
                .name("mole-accept".into())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if shutdown.load(Ordering::SeqCst) {
                            return; // drops conn_tx → session pool drains
                        }
                        match conn {
                            Ok(sock) => {
                                sock.set_nodelay(true).ok();
                                metrics.connections.inc();
                                if conn_tx.send(sock).is_err() {
                                    return;
                                }
                            }
                            Err(e) => {
                                crate::logging::warn(&format!("accept failed: {e}"));
                            }
                        }
                    }
                })
                .map_err(Error::Io)?
        };

        Ok(Self { local_addr, handle, shutdown, acceptor: Some(acceptor), sessions })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn metrics(&self) -> &Arc<ServingMetrics> {
        &self.handle.metrics
    }

    /// The in-process handle (tests/benches can mix direct `infer` calls
    /// with TCP traffic; both share the batcher and the engine).
    pub fn handle(&self) -> &ServingHandle {
        &self.handle
    }

    /// Block until `n` responses have been served or `timeout` elapses;
    /// true iff the target was reached. Drives `mole serve
    /// --max-requests` (CI smoke) without signal handling.
    pub fn wait_for_responses(&self, n: u64, timeout: Duration) -> bool {
        let t0 = Instant::now();
        while self.handle.metrics.responses.get() < n {
            if t0.elapsed() > timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        true
    }

    /// Stop accepting, finish queued sessions, and join every thread.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // unblock the acceptor's blocking accept()
        let _ = TcpStream::connect(self.local_addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for s in self.sessions.drain(..) {
            let _ = s.join();
        }
    }
}

/// Counts protocol bytes as they stream in, so `bytes_in` reflects real
/// wire traffic (the 5.12%-overhead story is about these bytes).
struct CountingReader<R: Read> {
    inner: R,
    metrics: Arc<ServingMetrics>,
}

impl<R: Read> Read for CountingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.metrics.bytes_in.add(n as u64);
        Ok(n)
    }
}

/// One client session: reader (this thread) + writer thread, linked by a
/// message queue. In-flight batcher completions hold queue senders, so
/// the writer drains every pending response before `EndOfData`.
fn run_session(sock: TcpStream, handle: &ServingHandle, hello: &Message) -> Result<()> {
    let metrics = handle.metrics.clone();
    let mut writer_sock = sock.try_clone()?;
    let (out_tx, out_rx) = mpsc::channel::<Message>();

    let writer_metrics = metrics.clone();
    let writer = std::thread::Builder::new()
        .name("mole-session-writer".into())
        .spawn(move || {
            for msg in out_rx {
                match write_message(&mut writer_sock, &msg) {
                    Ok(n) => writer_metrics.bytes_out.add(n as u64),
                    Err(_) => return, // peer gone; reader will notice too
                }
            }
            // all senders dropped ⇒ every in-flight response is written
            let _ = write_message(&mut writer_sock, &Message::EndOfData);
            let _ = writer_sock.shutdown(Shutdown::Write);
        })
        .map_err(Error::Io)?;

    // greet before reading: clients size their rows from this
    out_tx
        .send(hello.clone())
        .map_err(|_| Error::Protocol("session writer died at handshake".into()))?;

    let mut reader = CountingReader { inner: sock, metrics: metrics.clone() };
    let result = loop {
        match read_message(&mut reader) {
            Ok(Message::InferRequest { id, row }) => {
                let tx = out_tx.clone();
                let m = metrics.clone();
                // row-length validation happens inside the batcher
                // (`enqueue`); a synchronous Err here faults this request
                // only, not the session
                let outcome = handle.submit_with(row.data(), move |result| {
                    let msg = match result {
                        Ok(logits) => Message::InferResponse { id, logits },
                        Err(e) => {
                            m.faults.inc();
                            Message::Fault { msg: format!("request {id}: {e}") }
                        }
                    };
                    let _ = tx.send(msg);
                });
                if let Err(e) = outcome {
                    metrics.faults.inc();
                    let _ =
                        out_tx.send(Message::Fault { msg: format!("request {id}: {e}") });
                }
            }
            Ok(Message::EndOfData) => break Ok(()),
            Ok(other) => {
                metrics.faults.inc();
                let _ = out_tx.send(Message::Fault {
                    msg: format!("serving session got unexpected {other:?}"),
                });
                break Err(Error::Protocol(format!(
                    "unexpected message in serving session: {other:?}"
                )));
            }
            // peer hung up without EndOfData: close quietly
            Err(Error::Io(e)) if e.kind() == std::io::ErrorKind::UnexpectedEof => break Ok(()),
            Err(e) => {
                metrics.faults.inc();
                let _ = out_tx.send(Message::Fault { msg: e.to_string() });
                break Err(e);
            }
        }
    };

    // Drop our sender; in-flight completions still hold clones, so the
    // writer exits only after the last response frame is on the wire.
    drop(out_tx);
    let _ = writer.join();
    result
}

/// Deterministic demo serving stack for `mole serve`, benches and tests:
/// real keys + a He-initialized first layer pushed through the provider's
/// `C^ac` construction, He-initialized trunk. Same `(kappa, seed)` ⇒
/// bitwise-identical model on every call.
pub fn demo_model(
    manifest: &Manifest,
    kappa: usize,
    seed: u64,
) -> Result<(ServingModel, String)> {
    let g = manifest.geometry("small")?;
    let keys = crate::keys::KeyBundle::generate(g, kappa, seed)?;
    let morph_key = keys.morph_key()?;
    let mut rng = Rng::new(seed ^ 0x5E57E);
    let std = (2.0 / (g.alpha * g.p * g.p) as f64).sqrt() as f32;
    let w1 = Tensor::new(
        &[g.beta, g.alpha, g.p, g.p],
        rng.normal_vec(g.beta * g.alpha * g.p * g.p, std),
    )?;
    let b1 = vec![0.0f32; g.beta];
    let layer = crate::augconv::build_aug_conv(&w1, &b1, &morph_key, &keys.perm)?;
    let model = ServingModel {
        cac: layer.matrix().clone(),
        bias: layer.bias().to_vec(),
        params: init_params(&manifest.aug_params, &mut rng),
    };
    Ok((model, keys.fingerprint()))
}

/// What a serving session's `Hello` told the client.
#[derive(Debug, Clone)]
pub struct ServingHello {
    pub geometry: Geometry,
    pub kappa: usize,
    pub fingerprint: String,
    pub max_batch: usize,
}

/// Thin client for one serving session (used by `mole loadgen`, tests
/// and benches). Requests pipeline freely; responses arrive tagged by id.
pub struct ServingClient {
    sock: TcpStream,
    pub hello: ServingHello,
}

impl ServingClient {
    pub fn connect<A: std::net::ToSocketAddrs>(addr: A) -> Result<Self> {
        let sock = TcpStream::connect(addr)?;
        sock.set_nodelay(true).ok();
        let mut me = Self {
            sock,
            hello: ServingHello {
                geometry: Geometry::SMALL,
                kappa: 0,
                fingerprint: String::new(),
                max_batch: 0,
            },
        };
        match read_message(&mut me.sock)? {
            Message::Hello { geometry, kappa, fingerprint, batch_size, .. } => {
                me.hello = ServingHello {
                    geometry,
                    kappa,
                    fingerprint,
                    max_batch: batch_size as usize,
                };
                Ok(me)
            }
            other => Err(Error::Protocol(format!("expected Hello, got {other:?}"))),
        }
    }

    /// Row length the server expects (α·m² of the advertised geometry).
    pub fn d_len(&self) -> usize {
        self.hello.geometry.d_len()
    }

    pub fn send_request(&mut self, id: u64, row: &[f32]) -> Result<usize> {
        let msg = Message::InferRequest {
            id,
            row: Tensor::new(&[row.len()], row.to_vec())?,
        };
        write_message(&mut self.sock, &msg)
    }

    /// Next `InferResponse`; `Fault` frames surface as `Err`.
    pub fn recv_response(&mut self) -> Result<(u64, Vec<f32>)> {
        match read_message(&mut self.sock)? {
            Message::InferResponse { id, logits } => Ok((id, logits)),
            Message::Fault { msg } => Err(Error::Protocol(format!("server fault: {msg}"))),
            other => Err(Error::Protocol(format!("expected InferResponse, got {other:?}"))),
        }
    }

    /// Graceful close: `EndOfData` out, drain stragglers until the
    /// server's `EndOfData` (or EOF) comes back.
    pub fn finish(mut self) -> Result<()> {
        write_message(&mut self.sock, &Message::EndOfData)?;
        loop {
            match read_message(&mut self.sock) {
                Ok(Message::EndOfData) => return Ok(()),
                Ok(Message::InferResponse { .. }) => continue, // late straggler
                Ok(other) => {
                    return Err(Error::Protocol(format!("at session end, got {other:?}")))
                }
                Err(Error::Io(e)) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                    return Ok(())
                }
                Err(e) => return Err(e),
            }
        }
    }
}
