//! The concurrent multi-tenant TCP serving layer (`mole serve`).
//!
//! A [`Server`] binds a `std::net::TcpListener`, accepts many concurrent
//! client sessions on a fixed thread pool, and routes every request to a
//! lane of its [`ModelRegistry`]. Each session runs the serving half of
//! the wire protocol ([`super::protocol`], v5 — client speaks first):
//!
//! 1. the client opens with `Hello` (protocol version + requested
//!    model/epoch); the server resolves it against the registry and
//!    answers with its own `Hello` (resolved model, epoch, geometry, κ,
//!    key fingerprint, and the lane's `max_batch` in the `batch_size`
//!    slot) — or a typed `Fault` for version mismatches and unknown
//!    models;
//! 2. the client streams `InferRequest { id, model, epoch, row }` frames
//!    — any number, pipelined as deep as it likes; empty `model` +
//!    latest-epoch sentinel route to the session lane, anything else is
//!    resolved per request, so one connection can mix models;
//! 3. each lane's adaptive micro-batcher ([`super::batcher`]) coalesces
//!    rows from *all* sessions into single Aug-Conv GEMMs and fans
//!    `InferResponse { id, logits }` frames back on the originating
//!    connection — possibly out of order across ids (clients match on
//!    `id`);
//! 4. the client closes with `EndOfData`; the server flushes every
//!    in-flight response, answers `EndOfData`, and ends the session.
//!
//! Per-request failures (bad row length, unknown model/epoch, engine
//! faults) come back as `Fault` frames; framing violations fault the
//! session but never the server. All lanes execute against one
//! `Send + Sync` [`SharedEngine`](crate::runtime::SharedEngine) — no
//! per-connection engine or model state.
//!
//! The registry is **live**: a connection that opens with an admin
//! frame instead of `Hello` becomes an admin session ([`super::admin`];
//! gated by [`ServeConfig::admin_enabled`] and either the loopback
//! check or — when [`ServeConfig::admin_credential`] is set — the
//! challenge–response MAC handshake) that can register, drain and
//! retire lanes while traffic is flowing.
//! Lifecycle refusals — a draining or retired lane, at handshake or on
//! any later request (the session lane is revalidated per request) —
//! answer with the typed `Fault::Draining`/`Fault::Retired` carrying
//! the successor epoch so clients re-resolve instead of failing.

use super::protocol::{
    read_message, write_message, Fault, Message, EPOCH_LATEST, FAULT_SESSION,
    PROTOCOL_VERSION,
};
use super::registry::{ModelLane, ModelRegistry};
use crate::metrics::ServingMetrics;
use crate::{Error, Result};
use std::io::Read;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server tuning.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address, e.g. `127.0.0.1:7433` (`:0` picks a free port).
    pub addr: String,
    /// Session worker threads == max concurrently served connections
    /// (excess connections queue in the accept channel).
    pub session_workers: usize,
    /// How long a freshly accepted connection may stay silent before its
    /// handshake is abandoned (bounds slow/loris peers and pre-v2/v4
    /// clients that wait for the server to speak first).
    pub handshake_timeout: Duration,
    /// How long an established session may sit idle (no frame at all)
    /// before it is closed. Session workers are a fixed pool, so an
    /// abandoned-but-open connection would otherwise hold a worker
    /// forever.
    pub idle_timeout: Duration,
    /// Accept `Admin*` frames (register/drain/retire/status). Off, the
    /// registry is fixed at bind time like a pre-lifecycle server.
    /// Defaults on — a deliberate tradeoff for the single-operator demo
    /// deployment. Access control depends on
    /// [`ServeConfig::admin_credential`]: with no credential, only
    /// loopback peers may speak bare admin verbs; with one, every admin
    /// frame must be MAC-authenticated (and remote admin becomes legal).
    pub admin_enabled: bool,
    /// Vault-derived admin credential
    /// ([`crate::keys::KeyBundle::admin_credential`], distributed via
    /// `mole keygen --credential-out` / `[serving]
    /// admin_credential_file`). `Some` switches the admin plane to
    /// challenge–response MAC authentication: bare admin verbs are
    /// refused typed from **any** peer (loopback included — the
    /// credential gate supersedes, never weakens, the loopback gate)
    /// and authenticated peers may be non-loopback. `None` keeps the
    /// legacy loopback-only gate.
    pub admin_credential: Option<[u8; 32]>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7433".to_string(),
            session_workers: 8,
            handshake_timeout: Duration::from_secs(10),
            idle_timeout: Duration::from_secs(300),
            admin_enabled: true,
            admin_credential: None,
        }
    }
}

/// A running serving instance: acceptor thread + session pool + one
/// batcher lane per registered `(model, epoch)`.
pub struct Server {
    local_addr: SocketAddr,
    registry: Arc<ModelRegistry>,
    metrics: Arc<ServingMetrics>,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    sessions: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind the listener and start serving every lane in `registry`.
    pub fn bind(registry: ModelRegistry, cfg: ServeConfig) -> Result<Self> {
        if registry.is_empty() {
            return Err(Error::Config("cannot serve an empty model registry".into()));
        }
        let registry = Arc::new(registry);
        let metrics = Arc::new(ServingMetrics::default());
        let listener = TcpListener::bind(&cfg.addr)?;
        let local_addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));

        let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let workers = cfg.session_workers.max(1);
        let mut sessions = Vec::with_capacity(workers);
        for w in 0..workers {
            let conn_rx = conn_rx.clone();
            let registry = registry.clone();
            let metrics = metrics.clone();
            let cfg = cfg.clone();
            sessions.push(
                std::thread::Builder::new()
                    .name(format!("mole-session-{w}"))
                    .spawn(move || loop {
                        let sock = match conn_rx.lock().unwrap().recv() {
                            Ok(s) => s,
                            Err(_) => return, // acceptor gone: drain done
                        };
                        if let Err(e) = run_session(sock, &registry, &metrics, &cfg) {
                            crate::logging::warn(&format!("session ended with error: {e}"));
                        }
                    })
                    .map_err(Error::Io)?,
            );
        }

        let acceptor = {
            let shutdown = shutdown.clone();
            let metrics = metrics.clone();
            std::thread::Builder::new()
                .name("mole-accept".into())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if shutdown.load(Ordering::SeqCst) {
                            return; // drops conn_tx → session pool drains
                        }
                        match conn {
                            Ok(sock) => {
                                sock.set_nodelay(true).ok();
                                metrics.connections.inc();
                                if conn_tx.send(sock).is_err() {
                                    return;
                                }
                            }
                            Err(e) => {
                                crate::logging::warn(&format!("accept failed: {e}"));
                            }
                        }
                    }
                })
                .map_err(Error::Io)?
        };

        Ok(Self {
            local_addr,
            registry,
            metrics,
            shutdown,
            acceptor: Some(acceptor),
            sessions,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Server-level metrics: connections, wire bytes, TCP-answered
    /// responses and faults. Per-lane batching/latency metrics live on
    /// each lane's [`super::batcher::ServingHandle`] (via
    /// [`Server::registry`]).
    pub fn metrics(&self) -> &Arc<ServingMetrics> {
        &self.metrics
    }

    /// The registry of running lanes (tests/benches can mix direct
    /// in-process `infer` calls with TCP traffic; both share the lanes
    /// and the engine).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Block until `n` responses have been answered over TCP or
    /// `timeout` elapses; true iff the target was reached. Drives `mole
    /// serve --max-requests` (CI smoke) without signal handling.
    pub fn wait_for_responses(&self, n: u64, timeout: Duration) -> bool {
        let t0 = Instant::now();
        while self.metrics.responses.get() < n {
            if t0.elapsed() > timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        true
    }

    /// Stop accepting, finish queued sessions, and join every thread.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // unblock the acceptor's blocking accept()
        let _ = TcpStream::connect(self.local_addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for s in self.sessions.drain(..) {
            let _ = s.join();
        }
    }
}

/// Counts protocol bytes as they stream in, so `bytes_in` reflects real
/// wire traffic (the 5.12%-overhead story is about these bytes).
struct CountingReader<R: Read> {
    inner: R,
    metrics: Arc<ServingMetrics>,
}

impl<R: Read> Read for CountingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.metrics.bytes_in.add(n as u64);
        Ok(n)
    }
}

/// Best-effort typed rejection during the handshake (before the writer
/// thread exists).
fn handshake_fault(sock: &mut TcpStream, metrics: &Arc<ServingMetrics>, fault: Fault) {
    metrics.faults.inc();
    if let Ok(n) = write_message(sock, &Message::Fault { of: FAULT_SESSION, fault }) {
        metrics.bytes_out.add(n as u64);
    }
    let _ = sock.shutdown(Shutdown::Both);
}

/// What the opening frame turned a fresh connection into.
enum Opening {
    /// A serving session bound to a resolved lane.
    Lane(Arc<ModelLane>),
    /// An unauthenticated (loopback-gated) admin session; the
    /// already-read first admin frame rides along.
    Admin(Message),
    /// An authenticated admin session (opened with `AdminHello` on a
    /// credential-gated server); the credential to verify against rides
    /// along. The challenge is issued by the session loop itself.
    AdminAuthed([u8; 32]),
    /// The peer went away silently (port probes, health checks).
    Probe,
}

/// Classify and answer the client's opening frame: a `Hello` resolves to
/// a session lane (version mismatches, unknown models and draining /
/// retired lanes answered with their typed `Fault`); an `AdminHello` on
/// a credential-gated server opens an authenticated admin session (any
/// peer address); a bare `Admin*` frame opens a legacy admin session
/// when no credential is configured (loopback peers only) and is
/// refused typed when one is; anything else faults.
fn handshake(
    sock: &mut TcpStream,
    registry: &Arc<ModelRegistry>,
    metrics: &Arc<ServingMetrics>,
    cfg: &ServeConfig,
) -> Result<Opening> {
    let timeout = cfg.handshake_timeout;
    sock.set_read_timeout(Some(timeout)).ok();
    let opening = {
        let mut reader =
            CountingReader { inner: &mut *sock, metrics: metrics.clone() };
        read_message(&mut reader)
    };
    let lane = match opening {
        Ok(Message::Hello { model, epoch, .. }) => {
            match registry.resolve(&model, epoch) {
                Ok(lane) => lane,
                Err(e) => {
                    handshake_fault(sock, metrics, Fault::from_error(&e));
                    return Err(e);
                }
            }
        }
        Ok(Message::AdminHello) => {
            if !cfg.admin_enabled {
                let msg = "admin surface is disabled on this server".to_string();
                handshake_fault(sock, metrics, Fault::Generic { msg: msg.clone() });
                return Err(Error::Protocol(msg));
            }
            match cfg.admin_credential {
                // credential gate on: any peer address may try; the MAC
                // decides, not the routing table
                Some(cred) => return Ok(Opening::AdminAuthed(cred)),
                None => {
                    let e = Error::AdminAuth(
                        "admin authentication is not configured on this server \
                         (no admin credential installed)"
                            .into(),
                    );
                    handshake_fault(sock, metrics, Fault::from_error(&e));
                    return Err(e);
                }
            }
        }
        Ok(
            msg @ (Message::AdminRegister { .. }
            | Message::AdminDrain { .. }
            | Message::AdminRetire { .. }
            | Message::AdminStatus),
        ) => {
            if !cfg.admin_enabled {
                let msg = "admin surface is disabled on this server".to_string();
                handshake_fault(sock, metrics, Fault::Generic { msg: msg.clone() });
                return Err(Error::Protocol(msg));
            }
            if cfg.admin_credential.is_some() {
                // downgrade attempt: with a credential installed, a bare
                // admin verb is never dispatched — loopback included
                let e = Error::AdminAuth(
                    "admin frames must be authenticated on this server \
                     (open with AdminHello and a credential)"
                        .into(),
                );
                handshake_fault(sock, metrics, Fault::from_error(&e));
                return Err(e);
            }
            let loopback =
                sock.peer_addr().map(|a| a.ip().is_loopback()).unwrap_or(false);
            if !loopback {
                let msg = "admin frames are accepted from loopback peers only".to_string();
                handshake_fault(sock, metrics, Fault::Generic { msg: msg.clone() });
                return Err(Error::Protocol(msg));
            }
            return Ok(Opening::Admin(msg));
        }
        Ok(Message::AdminAuthed { .. }) => {
            // sealed frame before any AdminHello: there is no session
            // nonce to verify against, so this cannot be dispatched
            let e = Error::AdminAuth(
                "authenticated admin frame before AdminHello (no challenge issued)"
                    .into(),
            );
            handshake_fault(sock, metrics, Fault::from_error(&e));
            return Err(e);
        }
        Ok(other) => {
            let msg = format!("serving sessions open with Hello, got {other:?}");
            handshake_fault(sock, metrics, Fault::Generic { msg: msg.clone() });
            return Err(Error::Protocol(msg));
        }
        // silent close before any frame: a probe, not a protocol error
        Err(Error::Io(e)) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Ok(Opening::Probe)
        }
        Err(Error::Io(e))
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut =>
        {
            let msg = format!(
                "handshake timed out after {timeout:?} (v{PROTOCOL_VERSION} clients \
                 send Hello first)"
            );
            handshake_fault(sock, metrics, Fault::Generic { msg: msg.clone() });
            return Err(Error::Protocol(msg));
        }
        Err(e) => {
            // includes Error::Version: tell the peer why, typed
            handshake_fault(sock, metrics, Fault::Generic { msg: e.to_string() });
            return Err(e);
        }
    };
    let hello = Message::Hello {
        version: PROTOCOL_VERSION,
        model: lane.name().to_string(),
        epoch: lane.epoch(),
        geometry: lane.geometry(),
        kappa: lane.kappa(),
        fingerprint: lane.fingerprint().to_string(),
        num_batches: 0,
        batch_size: registry.batcher().max_batch as u32,
    };
    let n = write_message(sock, &hello)?;
    metrics.bytes_out.add(n as u64);
    Ok(Opening::Lane(lane))
}

/// One client session: handshake, then reader (this thread) + writer
/// thread linked by a message queue. In-flight batcher completions hold
/// queue senders, so the writer drains every pending response before
/// `EndOfData`.
fn run_session(
    mut sock: TcpStream,
    registry: &Arc<ModelRegistry>,
    metrics: &Arc<ServingMetrics>,
    cfg: &ServeConfig,
) -> Result<()> {
    let session_lane = match handshake(&mut sock, registry, metrics, cfg)? {
        Opening::Lane(lane) => lane,
        Opening::Admin(first) => {
            sock.set_read_timeout(Some(cfg.idle_timeout)).ok();
            return super::admin::run_admin_session(sock, first, registry);
        }
        Opening::AdminAuthed(cred) => {
            sock.set_read_timeout(Some(cfg.idle_timeout)).ok();
            return super::admin::run_authed_admin_session(sock, registry, &cred);
        }
        Opening::Probe => return Ok(()),
    };
    // the fixed worker pool must not be held hostage by an abandoned
    // connection: an idle session (no frame at all) is eventually shed
    sock.set_read_timeout(Some(cfg.idle_timeout)).ok();

    let mut writer_sock = sock.try_clone()?;
    let (out_tx, out_rx) = mpsc::channel::<Message>();
    let writer_metrics = metrics.clone();
    let writer = std::thread::Builder::new()
        .name("mole-session-writer".into())
        .spawn(move || {
            for msg in out_rx {
                match write_message(&mut writer_sock, &msg) {
                    Ok(n) => writer_metrics.bytes_out.add(n as u64),
                    Err(_) => return, // peer gone; reader will notice too
                }
            }
            // all senders dropped ⇒ every in-flight response is written
            let _ = write_message(&mut writer_sock, &Message::EndOfData);
            let _ = writer_sock.shutdown(Shutdown::Write);
        })
        .map_err(Error::Io)?;

    let mut reader = CountingReader { inner: sock, metrics: metrics.clone() };
    let result = loop {
        match read_message(&mut reader) {
            Ok(Message::InferRequest { id, model, epoch, row }) => {
                metrics.requests.inc();
                // "" + latest ⇒ the lane negotiated at handshake —
                // **revalidated per request**: a drained/retired session
                // lane answers its typed lifecycle fault (with the
                // successor epoch) instead of serving, so rollover is
                // visible to pipelined sessions, not just new ones.
                // Anything else re-resolves per request. Resolve + submit
                // fold into one Result: any Err faults this request only,
                // never the session (row-length validation happens inside
                // the lane's batcher `enqueue`, the lifecycle check
                // inside the lane's state-checked `submit_with`).
                let tx = out_tx.clone();
                let m = metrics.clone();
                let outcome = if model.is_empty() && epoch == EPOCH_LATEST {
                    Ok(session_lane.clone())
                } else if model.is_empty() {
                    registry.resolve(session_lane.name(), epoch)
                } else {
                    registry.resolve(&model, epoch)
                }
                .and_then(|lane| {
                    lane.submit_with(row.data(), move |result| {
                        let msg = match result {
                            Ok(logits) => {
                                m.responses.inc();
                                Message::InferResponse { id, logits }
                            }
                            Err(e) => {
                                m.faults.inc();
                                Message::Fault {
                                    of: id,
                                    fault: Fault::Generic {
                                        msg: format!("request {id}: {e}"),
                                    },
                                }
                            }
                        };
                        let _ = tx.send(msg);
                    })
                });
                if let Err(e) = outcome {
                    metrics.faults.inc();
                    let fault = match e {
                        // lifecycle refusals keep their successor info
                        Error::Draining { .. } | Error::Retired { .. } => {
                            Fault::from_error(&e)
                        }
                        other => Fault::Generic { msg: format!("request {id}: {other}") },
                    };
                    let _ = out_tx.send(Message::Fault { of: id, fault });
                }
            }
            Ok(Message::EndOfData) => break Ok(()),
            Ok(other) => {
                metrics.faults.inc();
                let _ = out_tx.send(Message::Fault {
                    of: FAULT_SESSION,
                    fault: Fault::Generic {
                        msg: format!("serving session got unexpected {other:?}"),
                    },
                });
                break Err(Error::Protocol(format!(
                    "unexpected message in serving session: {other:?}"
                )));
            }
            // peer hung up without EndOfData: close quietly
            Err(Error::Io(e)) if e.kind() == std::io::ErrorKind::UnexpectedEof => break Ok(()),
            // idle timeout: flush what's in flight and shed the session
            Err(Error::Io(e))
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                let _ = out_tx.send(Message::Fault {
                    of: FAULT_SESSION,
                    fault: Fault::Generic {
                        msg: format!("session idle for {:?}, closing", cfg.idle_timeout),
                    },
                });
                break Err(Error::Protocol("session idle timeout".into()));
            }
            Err(e) => {
                metrics.faults.inc();
                let _ = out_tx.send(Message::Fault {
                    of: FAULT_SESSION,
                    fault: Fault::Generic { msg: e.to_string() },
                });
                break Err(e);
            }
        }
    };

    // Drop our sender; in-flight completions still hold clones, so the
    // writer exits only after the last response frame is on the wire.
    drop(out_tx);
    let _ = writer.join();
    result
}
