//! The concurrent multi-tenant TCP serving layer (`mole serve`) — an
//! **evented** session layer with end-to-end backpressure.
//!
//! A [`Server`] binds a `std::net::TcpListener` and splits the work
//! between one blocking acceptor thread and a small fixed set of
//! **session drivers** ([`ServeConfig::session_workers`] shards). Every
//! accepted connection is made nonblocking and adopted by one driver;
//! each driver multiplexes *all* of its sessions on one readiness loop
//! over the in-tree poller ([`super::reactor`]), with per-session read
//! and write buffers replacing the old blocking thread-per-session
//! `read_message`/`write_message` calls. A driver therefore serves
//! hundreds of concurrent sessions without holding a thread per
//! connection — and a stalled peer stalls only its own buffers, never a
//! thread another session needs.
//!
//! Each session runs the serving half of the wire protocol
//! ([`super::protocol`], v6 — client speaks first):
//!
//! 1. the client opens with `Hello` (protocol version + requested
//!    model/epoch); the server resolves it against the registry and
//!    answers with its own `Hello` (resolved model, epoch, geometry, κ,
//!    key fingerprint, and the lane's `max_batch` in the `batch_size`
//!    slot) — or a typed `Fault` for version mismatches and unknown
//!    models;
//! 2. the client streams `InferRequest { id, model, epoch, row }` frames
//!    — any number, pipelined as deep as it likes; empty `model` +
//!    latest-epoch sentinel route to the session lane, anything else is
//!    resolved per request, so one connection can mix models;
//! 3. each lane's adaptive micro-batcher ([`super::batcher`]) coalesces
//!    rows from *all* sessions into single Aug-Conv GEMMs; completions
//!    land on the owning driver's inbox (a [`super::reactor::Waker`]
//!    pulls it out of `poll`) and fan `InferResponse { id, logits }`
//!    frames back on the originating connection — possibly out of order
//!    across ids (clients match on `id`);
//! 4. the client closes with `EndOfData`; the server flushes every
//!    in-flight response, answers `EndOfData`, and ends the session.
//!
//! ## Backpressure — overload is answered, never parked
//!
//! Three explicit budgets stand between an open socket and a GEMM, and
//! blowing any of them produces the typed `Fault::Overloaded` (fault
//! kind 4, carrying a `retry_after_ms` backoff hint) instead of a silent
//! stall:
//!
//! * **session budget** ([`ServeConfig::max_sessions`]) — open sessions
//!   (serving + admin) across all drivers;
//! * **pending-accept budget** ([`ServeConfig::max_pending`]) — accepted
//!   connections not yet adopted by a driver (the old unbounded accept
//!   channel is gone);
//! * **per-lane submit queue**
//!   ([`super::batcher::BatcherConfig::queue_bound`]) — requests in
//!   flight inside one lane's batcher; a shed here is request-scoped
//!   (`of: id`), the connection survives.
//!
//! The first two are enforced by the acceptor: an over-budget connection
//! gets a best-effort session-scoped `Fault::Overloaded` and is closed —
//! the client sees a typed refusal in one round trip, not a connect that
//! hangs in a queue nobody drains.
//!
//! Per-request failures (bad row length, unknown model/epoch, engine
//! faults) come back as `Fault` frames; framing violations fault the
//! session but never the server. All lanes execute against one
//! `Send + Sync` [`SharedEngine`](crate::runtime::SharedEngine) — no
//! per-connection engine or model state.
//!
//! ## Bulk delivery sessions (protocol v7)
//!
//! A connection that opens with `DatasetHello` becomes a **bulk
//! delivery session** when a dataset is configured
//! ([`ServeConfig::dataset`]): like admin sessions it detaches onto a
//! blocking thread (`delivery::run_delivery_session`) **holding its
//! live-session slot**, so bulk pulls count against
//! [`ServeConfig::max_sessions`] and an over-budget pull is answered
//! `Fault::Overloaded` at accept instead of starving inference. With no
//! dataset configured the frame is refused typed.
//!
//! The registry is **live**: a connection that opens with an admin
//! frame instead of `Hello` becomes an admin session ([`super::admin`];
//! gated by [`ServeConfig::admin_enabled`] and either the loopback
//! check or — when [`ServeConfig::admin_credential`] is set — the
//! challenge–response MAC handshake). Admin sessions are rare,
//! long-lived and strictly request/response, so they **detach** from the
//! event loop onto a dedicated blocking thread (reusing the session
//! loops in [`super::admin`]) while still counting against
//! [`ServeConfig::max_sessions`].
//! Lifecycle refusals — a draining or retired lane, at handshake or on
//! any later request (the session lane is revalidated per request) —
//! answer with the typed `Fault::Draining`/`Fault::Retired` carrying
//! the successor epoch so clients re-resolve instead of failing.

use super::admin::{AdminGate, OperatorTable};
use super::audit::AuditLog;
use super::delivery::ChunkStore;
use super::protocol::{
    try_decode_frame, write_message, Fault, Message, EPOCH_LATEST, FAULT_SESSION,
    PROTOCOL_VERSION,
};
use super::reactor::{waker, Interest, Poller, WakeRx, Waker};
use super::registry::{ModelLane, ModelRegistry};
use crate::metrics::ServingMetrics;
use crate::{Error, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Floor of the accept-shed backoff hint: even a shed that races a
/// just-freed slot tells the client to wait at least this long.
const ACCEPT_RETRY_MIN_MS: u64 = 25;
/// Ceiling of the accept-shed backoff hint. Matches the documented
/// `retry_after_ms` contract of [1, 1000] ms — a gateway or SDK must
/// never be pinned out for more than a second by one hint.
const ACCEPT_RETRY_MAX_MS: u64 = 1000;
/// Every this-many *consecutive* sheds (no admit in between), the hint
/// doubles: a sustained storm is told to back off harder than a blip.
const ACCEPT_BURST_STEP: u64 = 8;

/// Backoff hint for a connection shed at accept, derived from live shed
/// pressure rather than a flat constant (the old fixed 100 ms taught
/// gateways nothing about *how* overloaded the listener was).
///
/// Two signals, both available on the accept path before any lane is
/// known:
///
/// * `pending` / `max_pending` — the depth of the pending-handshake
///   budget, the queue an accepted socket would join. The hint scales
///   linearly from [`ACCEPT_RETRY_MIN_MS`] (empty) to 250 ms (full).
/// * `shed_burst` — consecutive sheds since the last admit, a proxy for
///   the recent `accept_shed` rate. Each [`ACCEPT_BURST_STEP`] sheds
///   double the hint (capped at ×32) so a storm self-disperses instead
///   of re-arriving in lockstep.
///
/// The result is clamped to [[`ACCEPT_RETRY_MIN_MS`],
/// [`ACCEPT_RETRY_MAX_MS`]], inside the documented [1, 1000] ms
/// contract.
fn accept_retry_hint(pending: u64, max_pending: u64, shed_burst: u64) -> u64 {
    let fill = 225 * pending.min(max_pending) / max_pending.max(1);
    let doubling = (shed_burst / ACCEPT_BURST_STEP).min(5);
    ((ACCEPT_RETRY_MIN_MS + fill) << doubling).clamp(ACCEPT_RETRY_MIN_MS, ACCEPT_RETRY_MAX_MS)
}

/// How long a driver keeps serving open sessions after [`Server::stop`]
/// before dropping them. Bounds `stop()` even against a peer that never
/// sends `EndOfData` (the old thread-per-session server could wait out
/// the full idle timeout).
const SHUTDOWN_GRACE: Duration = Duration::from_secs(2);

/// Ceiling on one poll round, so drivers notice shutdown promptly even
/// with no session deadlines near.
const POLL_CAP: Duration = Duration::from_millis(250);

/// Server tuning.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address, e.g. `127.0.0.1:7433` (`:0` picks a free port).
    pub addr: String,
    /// Session-driver shards — threads running the readiness event loop.
    /// Each shard multiplexes many sessions, so this is a parallelism
    /// knob, **not** a concurrency ceiling (that is
    /// [`ServeConfig::max_sessions`]).
    pub session_workers: usize,
    /// How long a freshly accepted connection may go without completing
    /// its handshake before it is shed. The deadline is fixed at
    /// adoption and is **not** extended by trickled bytes, so slow-loris
    /// peers and pre-v2/v4 clients that wait for the server to speak
    /// first are strictly bounded.
    pub handshake_timeout: Duration,
    /// How long an established session may sit idle (no inbound bytes)
    /// before it is closed. Evented drivers don't burn a thread on an
    /// abandoned connection, but its session-budget slot and buffers
    /// would otherwise leak forever.
    pub idle_timeout: Duration,
    /// Max concurrently open sessions, serving + admin, across all
    /// drivers. Connections past the budget are answered with a
    /// session-scoped `Fault::Overloaded` and closed at accept.
    pub max_sessions: usize,
    /// Max accepted-but-not-yet-adopted connections (the bounded accept
    /// queue between the acceptor and the drivers). Past it, same typed
    /// shed as [`ServeConfig::max_sessions`].
    pub max_pending: usize,
    /// Accept `Admin*` frames (register/drain/retire/status). Off, the
    /// registry is fixed at bind time like a pre-lifecycle server.
    /// Defaults on — a deliberate tradeoff for the single-operator demo
    /// deployment. Access control depends on
    /// [`ServeConfig::admin_credential`]: with no credential, only
    /// loopback peers may speak bare admin verbs; with one, every admin
    /// frame must be MAC-authenticated (and remote admin becomes legal).
    pub admin_enabled: bool,
    /// Vault-derived admin credential
    /// ([`crate::keys::KeyBundle::admin_credential`], distributed via
    /// `mole keygen --credential-out` / `[serving]
    /// admin_credential_file`). `Some` switches the admin plane to
    /// challenge–response MAC authentication: bare admin verbs are
    /// refused typed from **any** peer (loopback included — the
    /// credential gate supersedes, never weakens, the loopback gate)
    /// and authenticated peers may be non-loopback. `None` keeps the
    /// legacy loopback-only gate.
    ///
    /// Since v8 this is the *legacy* spelling: at bind it becomes a
    /// one-entry [`OperatorTable`] under the label `"shared"`. When
    /// [`ServeConfig::operators`] is also set, the table wins and this
    /// field is ignored (per-operator attribution supersedes the shared
    /// secret).
    pub admin_credential: Option<[u8; 32]>,
    /// Per-operator credential table (vault roster, `mole operator
    /// add|revoke|list`, served via `mole serve --admin-vault`). `Some`
    /// turns on the same MAC authentication as
    /// [`ServeConfig::admin_credential`], but each frame is attributed
    /// to the operator whose credential sealed it and operators can be
    /// revoked **live** (`mole admin revoke-operator`) without a
    /// restart. Shared `Arc`: every session and the CLI see one table.
    pub operators: Option<Arc<OperatorTable>>,
    /// Append-only admin audit log path ([`AuditLog`], created `0600`).
    /// Every authenticated admin verb — and every refused frame — is
    /// recorded attributed to its operator label. `None` disables
    /// auditing.
    pub audit_log: Option<std::path::PathBuf>,
    /// Bulk dataset served to `DatasetHello` sessions (protocol v7,
    /// `mole push-dataset`). `None` refuses delivery handshakes typed.
    pub dataset: Option<Arc<ChunkStore>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7433".to_string(),
            session_workers: 8,
            handshake_timeout: Duration::from_secs(10),
            idle_timeout: Duration::from_secs(300),
            max_sessions: 1024,
            max_pending: 128,
            admin_enabled: true,
            admin_credential: None,
            operators: None,
            audit_log: None,
            dataset: None,
        }
    }
}

/// RAII slot in the live-session budget: claimed by the acceptor at
/// admission (so the budget check races with nothing downstream),
/// released wherever the session actually ends — driver teardown or
/// admin-thread exit. Mirrored onto the `sessions` gauge.
struct LiveSlot {
    live: Arc<AtomicU64>,
    metrics: Arc<ServingMetrics>,
}

impl LiveSlot {
    fn claim(live: &Arc<AtomicU64>, metrics: &Arc<ServingMetrics>) -> Self {
        live.fetch_add(1, Ordering::SeqCst);
        metrics.sessions.set(live.load(Ordering::SeqCst));
        Self { live: live.clone(), metrics: metrics.clone() }
    }
}

impl Drop for LiveSlot {
    fn drop(&mut self) {
        self.live.fetch_sub(1, Ordering::SeqCst);
        self.metrics.sessions.set(self.live.load(Ordering::SeqCst));
    }
}

/// RAII slot in the pending-accept budget; released when a driver adopts
/// the connection.
struct PendingSlot(Arc<AtomicU64>);

impl PendingSlot {
    fn claim(pending: &Arc<AtomicU64>) -> Self {
        pending.fetch_add(1, Ordering::SeqCst);
        Self(pending.clone())
    }
}

impl Drop for PendingSlot {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// What the acceptor and lane workers push at a driver. One mutex per
/// shard; every push is paired with a waker kick.
#[derive(Default)]
struct Inbox {
    /// Admitted connections awaiting adoption.
    adopt: Vec<(TcpStream, LiveSlot, PendingSlot)>,
    /// Batcher completions: (session token, ready-to-queue frame).
    completions: Vec<(u64, Message)>,
}

/// One driver shard's cross-thread handle.
struct DriverShared {
    inbox: Mutex<Inbox>,
    waker: Waker,
}

/// A running serving instance: acceptor thread + session-driver shards +
/// one batcher lane per registered `(model, epoch)`.
pub struct Server {
    local_addr: SocketAddr,
    registry: Arc<ModelRegistry>,
    metrics: Arc<ServingMetrics>,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    drivers: Vec<JoinHandle<()>>,
    driver_shared: Vec<Arc<DriverShared>>,
    admin_threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Server {
    /// Bind the listener and start serving every lane in `registry`.
    pub fn bind(registry: ModelRegistry, cfg: ServeConfig) -> Result<Self> {
        if registry.is_empty() && cfg.dataset.is_none() {
            // a pure delivery server (`mole push-dataset`) has no model
            // lanes; anything else needs at least one
            return Err(Error::Config("cannot serve an empty model registry".into()));
        }
        if cfg.max_sessions == 0 {
            return Err(Error::Config("max_sessions must be >= 1".into()));
        }
        if cfg.max_pending == 0 {
            return Err(Error::Config("max_pending must be >= 1".into()));
        }
        let registry = Arc::new(registry);
        let metrics = Arc::new(ServingMetrics::default());
        // normalize the two credential spellings into one gate, built
        // once and shared by every driver shard and detached session —
        // a revocation must be visible process-wide, so there can be
        // exactly one live table and one audit handle per instance
        let admin_gate = match (&cfg.operators, cfg.admin_credential) {
            (Some(table), _) => Some(table.clone()),
            (None, Some(cred)) => Some(Arc::new(OperatorTable::shared(cred))),
            (None, None) => None,
        }
        .map(|table| -> Result<Arc<AdminGate>> {
            let audit = match &cfg.audit_log {
                Some(path) => Some(Arc::new(AuditLog::open(path)?)),
                None => None,
            };
            Ok(Arc::new(AdminGate { table, audit }))
        })
        .transpose()?;
        let listener = TcpListener::bind(&cfg.addr)?;
        let local_addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let live = Arc::new(AtomicU64::new(0));
        let pending = Arc::new(AtomicU64::new(0));
        let admin_threads = Arc::new(Mutex::new(Vec::new()));

        let shards = cfg.session_workers.max(1);
        let mut driver_shared = Vec::with_capacity(shards);
        let mut drivers = Vec::with_capacity(shards);
        for w in 0..shards {
            let (wake, wake_rx) = waker().map_err(Error::Io)?;
            let shared =
                Arc::new(DriverShared { inbox: Mutex::new(Inbox::default()), waker: wake });
            driver_shared.push(shared.clone());
            let driver = Driver {
                cfg: cfg.clone(),
                registry: registry.clone(),
                metrics: metrics.clone(),
                shutdown: shutdown.clone(),
                shared,
                wake_rx,
                admin_threads: admin_threads.clone(),
                admin_gate: admin_gate.clone(),
                sessions: HashMap::new(),
                next_token: 0,
                poller: Poller::new(),
            };
            drivers.push(
                std::thread::Builder::new()
                    .name(format!("mole-driver-{w}"))
                    .spawn(move || driver.run())
                    .map_err(Error::Io)?,
            );
        }

        let acceptor = {
            let shutdown = shutdown.clone();
            let metrics = metrics.clone();
            let shards: Vec<Arc<DriverShared>> = driver_shared.clone();
            let max_sessions = cfg.max_sessions as u64;
            let max_pending = cfg.max_pending as u64;
            std::thread::Builder::new()
                .name("mole-accept".into())
                .spawn(move || {
                    let mut next = 0usize;
                    // consecutive sheds since the last admit — feeds the
                    // burst-doubling term of the retry hint
                    let mut shed_burst = 0u64;
                    for conn in listener.incoming() {
                        if shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        let sock = match conn {
                            Ok(s) => s,
                            Err(e) => {
                                crate::logging::warn(&format!("accept failed: {e}"));
                                continue;
                            }
                        };
                        sock.set_nodelay(true).ok();
                        metrics.connections.inc();
                        // end-to-end backpressure starts here: past
                        // either budget, the connection is *answered* —
                        // typed Overloaded, then closed — never queued
                        // silently
                        if live.load(Ordering::SeqCst) >= max_sessions
                            || pending.load(Ordering::SeqCst) >= max_pending
                        {
                            let hint = accept_retry_hint(
                                pending.load(Ordering::SeqCst),
                                max_pending,
                                shed_burst,
                            );
                            shed_burst += 1;
                            shed_accept(sock, hint, &metrics);
                            continue;
                        }
                        shed_burst = 0;
                        let slot = LiveSlot::claim(&live, &metrics);
                        let pend = PendingSlot::claim(&pending);
                        if sock.set_nonblocking(true).is_err() {
                            continue; // slot + pend released by drop
                        }
                        let shard = &shards[next % shards.len()];
                        next = next.wrapping_add(1);
                        shard.inbox.lock().unwrap().adopt.push((sock, slot, pend));
                        shard.waker.wake();
                    }
                })
                .map_err(Error::Io)?
        };

        Ok(Self {
            local_addr,
            registry,
            metrics,
            shutdown,
            acceptor: Some(acceptor),
            drivers,
            driver_shared,
            admin_threads,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Server-level metrics: connections, live/shed session counts, wire
    /// bytes, TCP-answered responses and faults. Per-lane
    /// batching/latency metrics live on each lane's
    /// [`super::batcher::ServingHandle`] (via [`Server::registry`]).
    pub fn metrics(&self) -> &Arc<ServingMetrics> {
        &self.metrics
    }

    /// The registry of running lanes (tests/benches can mix direct
    /// in-process `infer` calls with TCP traffic; both share the lanes
    /// and the engine).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Block until `n` responses have been answered over TCP or
    /// `timeout` elapses; true iff the target was reached. Drives `mole
    /// serve --max-requests` (CI smoke) without signal handling.
    pub fn wait_for_responses(&self, n: u64, timeout: Duration) -> bool {
        let t0 = Instant::now();
        while self.metrics.responses.get() < n {
            if t0.elapsed() > timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        true
    }

    /// Stop accepting, give open sessions a short grace window to finish
    /// their close handshake, and join every thread.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // unblock the acceptor's blocking accept()
        let _ = TcpStream::connect(self.local_addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for s in &self.driver_shared {
            s.waker.wake();
        }
        for d in self.drivers.drain(..) {
            let _ = d.join();
        }
        let admins = std::mem::take(&mut *self.admin_threads.lock().unwrap());
        for t in admins {
            let _ = t.join();
        }
    }
}

/// Shed sockets being drained right now (see [`shed_accept`]). A cap,
/// not a pool: each drain is a short-lived detached thread.
static SHED_DRAINS: AtomicUsize = AtomicUsize::new(0);
const SHED_DRAIN_CAP: usize = 32;
const SHED_DRAIN_WINDOW: Duration = Duration::from_millis(250);

/// Best-effort typed refusal of a connection the budgets won't admit:
/// one session-scoped `Fault::Overloaded` frame (bounded blocking write
/// — the socket was just accepted, its send buffer is empty, and a write
/// timeout backstops a pathological peer), then FIN.
///
/// The socket must NOT be closed while the peer's handshake bytes sit
/// unread in our receive queue: `close(2)` with unread data makes the
/// kernel answer RST, and an RST destroys the fault frame still in
/// flight — the client would see a connection reset instead of the
/// typed refusal. So after the FIN, the socket lingers on a detached
/// drainer that reads until the peer closes, bounded in threads
/// ([`SHED_DRAIN_CAP`]), time ([`SHED_DRAIN_WINDOW`]) and bytes. Past
/// the thread cap the close is abrupt — under a genuine shed storm an
/// occasional reset beats unbounded thread growth, and the well-behaved
/// retry path ([`accept_retry_hint`]) keeps storms self-limiting.
fn shed_accept(mut sock: TcpStream, retry_after_ms: u64, metrics: &Arc<ServingMetrics>) {
    metrics.accept_shed.inc();
    sock.set_write_timeout(Some(Duration::from_millis(250))).ok();
    let fault = Message::Fault {
        of: FAULT_SESSION,
        fault: Fault::Overloaded { retry_after_ms },
    };
    if let Ok(n) = write_message(&mut sock, &fault) {
        metrics.bytes_out.add(n as u64);
    }
    let _ = sock.shutdown(Shutdown::Write);
    if SHED_DRAINS.fetch_add(1, Ordering::SeqCst) < SHED_DRAIN_CAP {
        let spawned = std::thread::Builder::new()
            .name("mole-shed-drain".into())
            .spawn(move || {
                let deadline = Instant::now() + SHED_DRAIN_WINDOW;
                sock.set_read_timeout(Some(SHED_DRAIN_WINDOW)).ok();
                let mut buf = [0u8; 512];
                let mut budget = 16 * 1024usize;
                while budget > 0 && Instant::now() < deadline {
                    match sock.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => budget = budget.saturating_sub(n),
                    }
                }
                SHED_DRAINS.fetch_sub(1, Ordering::SeqCst);
            });
        if spawned.is_err() {
            SHED_DRAINS.fetch_sub(1, Ordering::SeqCst);
        }
    } else {
        SHED_DRAINS.fetch_sub(1, Ordering::SeqCst);
    }
}

/// One multiplexed connection's state inside a driver.
struct Session {
    sock: TcpStream,
    /// Holds this session's slot in the live budget until teardown.
    _slot: LiveSlot,
    /// Unparsed inbound bytes (frames are peeled off the front).
    rbuf: Vec<u8>,
    /// Outbound bytes not yet on the wire…
    wbuf: Vec<u8>,
    /// …of which the first `wpos` are already written.
    wpos: usize,
    /// The lane negotiated at handshake; `None` while handshaking.
    lane: Option<Arc<ModelLane>>,
    /// Handshake or idle deadline (handshake deadlines are fixed at
    /// adoption; idle deadlines renew on inbound bytes).
    deadline: Instant,
    /// Requests submitted to a batcher whose completions have not yet
    /// come back through the inbox. The `EndOfData` answer waits for
    /// zero — "flush every in-flight response" is this counter.
    inflight: u64,
    /// No more inbound frames will be processed (client `EndOfData`, or
    /// read-side EOF). The session still drains in-flight responses.
    rd_done: bool,
    /// An `EndOfData` answer is owed once `inflight` hits zero.
    eof: bool,
    eof_answered: bool,
    /// Flush `wbuf`, then close (set after a session-fatal fault or the
    /// `EndOfData` answer).
    closing: bool,
    /// Tear down now.
    dead: bool,
}

/// Append one frame to a session's write buffer. In-memory encode can
/// only fail on an over-`MAX_PAYLOAD` payload, which the serving plane
/// never constructs; if it somehow does, the session dies rather than
/// desync its framing.
fn queue_frame(sess: &mut Session, msg: &Message) {
    if write_message(&mut sess.wbuf, msg).is_err() {
        sess.dead = true;
    }
}

/// Write as much buffered output as the socket accepts right now.
fn flush(sess: &mut Session, metrics: &ServingMetrics) {
    while sess.wpos < sess.wbuf.len() {
        match sess.sock.write(&sess.wbuf[sess.wpos..]) {
            Ok(0) => {
                sess.dead = true;
                return;
            }
            Ok(n) => {
                sess.wpos += n;
                metrics.bytes_out.add(n as u64);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                sess.dead = true;
                return;
            }
        }
    }
    sess.wbuf.clear();
    sess.wpos = 0;
    if sess.closing {
        let _ = sess.sock.shutdown(Shutdown::Both);
        sess.dead = true;
    }
}

/// What a handshake frame asked the session to become (beyond staying a
/// serving session or dying).
enum Detach {
    /// Hand the connection to a blocking thread running the legacy
    /// (loopback-gated) admin loop; the first admin frame rides along.
    AdminPlain(Message),
    /// Same, for the authenticated admin loop; carries the instance's
    /// shared gate (operator table + audit log) so revocations made on
    /// one session bind every other.
    AdminAuthed(Arc<AdminGate>),
    /// Hand the connection to a blocking thread serving bulk delivery
    /// (`DatasetHello` already validated; the thread sends the echo).
    Delivery(Arc<ChunkStore>),
}

/// A blocking `Read + Write` view of a detached connection that replays
/// bytes the event loop had already buffered before handing the rest of
/// the stream through. Keeps a pipelining admin client from losing
/// frames at the detach boundary.
struct PrefixedStream {
    pre: std::io::Cursor<Vec<u8>>,
    sock: TcpStream,
}

impl Read for PrefixedStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.pre.read(buf)?;
        if n > 0 {
            return Ok(n);
        }
        self.sock.read(buf)
    }
}

impl Write for PrefixedStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.sock.write(buf)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.sock.flush()
    }
}

/// One session-driver shard: the readiness event loop.
struct Driver {
    cfg: ServeConfig,
    registry: Arc<ModelRegistry>,
    metrics: Arc<ServingMetrics>,
    shutdown: Arc<AtomicBool>,
    shared: Arc<DriverShared>,
    wake_rx: WakeRx,
    admin_threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
    admin_gate: Option<Arc<AdminGate>>,
    sessions: HashMap<u64, Session>,
    next_token: u64,
    poller: Poller,
}

impl Driver {
    fn run(mut self) {
        let mut shutdown_at: Option<Instant> = None;
        loop {
            // 1. inbox: adoptions from the acceptor, completions from
            //    lane workers
            let (adopt, completions) = {
                let mut inbox = self.shared.inbox.lock().unwrap();
                (std::mem::take(&mut inbox.adopt), std::mem::take(&mut inbox.completions))
            };
            for (sock, slot, pend) in adopt {
                self.adopt(sock, slot);
                drop(pend); // adopted: pending-accept slot freed
            }
            for (token, msg) in completions {
                // a completion for a torn-down session is dropped — the
                // peer is gone, and the lane's reply already fired
                if let Some(sess) = self.sessions.get_mut(&token) {
                    sess.inflight = sess.inflight.saturating_sub(1);
                    queue_frame(sess, &msg);
                }
            }

            // 2. shutdown: exit once every session finished its close
            //    handshake, or the grace window runs out
            if self.shutdown.load(Ordering::SeqCst) {
                let at = *shutdown_at.get_or_insert_with(|| Instant::now() + SHUTDOWN_GRACE);
                if self.sessions.is_empty() || Instant::now() >= at {
                    return;
                }
            }

            // 3. per-session bookkeeping: the EndOfData barrier (answer
            //    only once every in-flight response is queued), expired
            //    deadlines, and an opportunistic flush
            let now = Instant::now();
            for sess in self.sessions.values_mut() {
                if sess.dead {
                    continue;
                }
                if sess.eof && sess.inflight == 0 && !sess.eof_answered {
                    sess.eof_answered = true;
                    queue_frame(sess, &Message::EndOfData);
                    sess.closing = true;
                }
                if !sess.closing && now >= sess.deadline {
                    if sess.lane.is_none() {
                        self.metrics.faults.inc();
                        let timeout = self.cfg.handshake_timeout;
                        queue_frame(
                            sess,
                            &Message::Fault {
                                of: FAULT_SESSION,
                                fault: Fault::Generic {
                                    msg: format!(
                                        "handshake timed out after {timeout:?} \
                                         (v{PROTOCOL_VERSION} clients send Hello first)"
                                    ),
                                },
                            },
                        );
                    } else {
                        queue_frame(
                            sess,
                            &Message::Fault {
                                of: FAULT_SESSION,
                                fault: Fault::Generic {
                                    msg: format!(
                                        "session idle for {:?}, closing",
                                        self.cfg.idle_timeout
                                    ),
                                },
                            },
                        );
                    }
                    sess.closing = true;
                }
                if sess.wpos < sess.wbuf.len() || sess.closing {
                    flush(sess, &self.metrics);
                }
            }
            self.sessions.retain(|_, s| !s.dead);

            // 4. interest list: slot 0 is the waker, then every session
            //    that still wants socket readiness
            let mut fds = vec![(self.wake_rx.fd(), Interest::READ)];
            let mut tokens = vec![u64::MAX];
            let mut next_deadline: Option<Instant> = None;
            for (&tok, sess) in &self.sessions {
                next_deadline = Some(match next_deadline {
                    Some(d) => d.min(sess.deadline),
                    None => sess.deadline,
                });
                let wants_write = sess.wpos < sess.wbuf.len();
                let want = match (sess.rd_done || sess.closing, wants_write) {
                    (false, false) => Interest::READ,
                    (false, true) => Interest::BOTH,
                    (true, true) => Interest::WRITE,
                    // waiting only on batcher completions: the waker,
                    // not this socket, is the wake signal
                    (true, false) => continue,
                };
                fds.push((sess.sock.as_raw_fd(), want));
                tokens.push(tok);
            }

            let now = Instant::now();
            let mut timeout = POLL_CAP;
            if let Some(d) = next_deadline {
                timeout = timeout.min(d.saturating_duration_since(now));
            }
            if let Some(at) = shutdown_at {
                timeout = timeout.min(at.saturating_duration_since(now));
            }
            let events = match self.poller.wait(&fds, Some(timeout)) {
                Ok(ev) => ev,
                Err(e) => {
                    crate::logging::warn(&format!("session driver poll failed: {e}"));
                    std::thread::sleep(Duration::from_millis(10));
                    continue;
                }
            };

            // 5. readiness: reads may complete handshakes, submit
            //    requests, or detach admin sessions; writes drain wbufs
            let mut woke = false;
            for ev in events {
                if ev.slot == 0 {
                    woke = true;
                    continue;
                }
                let tok = tokens[ev.slot];
                if ev.readable || ev.hangup {
                    self.on_readable(tok);
                }
                if ev.writable {
                    if let Some(sess) = self.sessions.get_mut(&tok) {
                        flush(sess, &self.metrics);
                    }
                }
            }
            if woke {
                self.wake_rx.drain();
            }
            self.sessions.retain(|_, s| !s.dead);
        }
    }

    fn adopt(&mut self, sock: TcpStream, slot: LiveSlot) {
        let token = self.next_token;
        self.next_token = self.next_token.wrapping_add(1);
        self.sessions.insert(
            token,
            Session {
                sock,
                _slot: slot,
                rbuf: Vec::new(),
                wbuf: Vec::new(),
                wpos: 0,
                lane: None,
                deadline: Instant::now() + self.cfg.handshake_timeout,
                inflight: 0,
                rd_done: false,
                eof: false,
                eof_answered: false,
                closing: false,
                dead: false,
            },
        );
    }

    /// Drain the socket, peel complete frames, dispatch them. The
    /// session is taken out of the map for the duration so the borrow of
    /// `self` stays free for lane resolution and admin detach.
    fn on_readable(&mut self, token: u64) {
        let mut sess = match self.sessions.remove(&token) {
            Some(s) => s,
            None => return,
        };

        let mut tmp = [0u8; 16384];
        loop {
            match sess.sock.read(&mut tmp) {
                Ok(0) => {
                    // peer closed its sending half: no more bytes will
                    // arrive. Frames already buffered still get parsed
                    // below; `eof` is derived only after that, so a
                    // client that pipelines and closes loses nothing.
                    sess.rd_done = true;
                    break;
                }
                Ok(n) => {
                    self.metrics.bytes_in.add(n as u64);
                    sess.rbuf.extend_from_slice(&tmp[..n]);
                    if sess.lane.is_some() {
                        // idle deadlines renew on traffic; handshake
                        // deadlines deliberately don't (loris bound)
                        sess.deadline = Instant::now() + self.cfg.idle_timeout;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    sess.dead = true;
                    break;
                }
            }
        }

        let mut detach = None;
        let mut at = 0usize;
        // `eof` stops the parse after an explicit EndOfData frame (later
        // pipelined frames are ignored, as the blocking server did)
        while !sess.dead && !sess.closing && !sess.eof && detach.is_none() {
            match try_decode_frame(&sess.rbuf[at..]) {
                Ok(None) => break,
                Ok(Some((msg, used))) => {
                    at += used;
                    detach = self.handle_frame(token, &mut sess, msg);
                }
                Err(e) => {
                    self.metrics.faults.inc();
                    let fault = Fault::Generic { msg: e.to_string() };
                    queue_frame(&mut sess, &Message::Fault { of: FAULT_SESSION, fault });
                    sess.closing = true;
                }
            }
        }
        if at > 0 {
            sess.rbuf.drain(..at);
        }

        if let Some(kind) = detach {
            self.detach_admin(sess, kind);
            return;
        }

        // a probe (silent close before any handshake frame) dies
        // quietly; an established session whose peer closed without
        // EndOfData drains in-flight responses and answers EndOfData
        // best-effort (like the old writer thread did on a hangup)
        if sess.rd_done && sess.lane.is_none() {
            sess.dead = true;
        }
        if sess.rd_done && sess.lane.is_some() {
            sess.eof = true;
        }
        // the EndOfData barrier also runs in the main loop's bookkeeping
        // pass; do it eagerly here to save a poll round
        if !sess.dead {
            if sess.eof && sess.inflight == 0 && !sess.eof_answered {
                sess.eof_answered = true;
                queue_frame(&mut sess, &Message::EndOfData);
                sess.closing = true;
            }
            if sess.wpos < sess.wbuf.len() || sess.closing {
                flush(&mut sess, &self.metrics);
            }
        }
        if !sess.dead {
            self.sessions.insert(token, sess);
        }
    }

    /// Dispatch one decoded frame. `Some(_)` means the session leaves
    /// the event loop to become a blocking admin session.
    fn handle_frame(
        &mut self,
        token: u64,
        sess: &mut Session,
        msg: Message,
    ) -> Option<Detach> {
        if sess.lane.is_some() {
            self.handle_serving_frame(token, sess, msg);
            return None;
        }
        self.handle_handshake_frame(sess, msg)
    }

    /// The opening frame: a `Hello` resolves to a session lane (version
    /// mismatches, unknown models and draining/retired lanes answered
    /// with their typed `Fault`); an `AdminHello` on a credential-gated
    /// server detaches into an authenticated admin session (any peer
    /// address); a bare `Admin*` frame detaches into a legacy admin
    /// session when no credential is configured (loopback peers only)
    /// and is refused typed when one is; anything else faults.
    fn handle_handshake_frame(&mut self, sess: &mut Session, msg: Message) -> Option<Detach> {
        fn refuse(sess: &mut Session, metrics: &ServingMetrics, fault: Fault) {
            metrics.faults.inc();
            queue_frame(sess, &Message::Fault { of: FAULT_SESSION, fault });
            sess.closing = true;
        }
        match msg {
            Message::Hello { model, epoch, .. } => {
                match self.registry.resolve(&model, epoch) {
                    Ok(lane) => {
                        let hello = Message::Hello {
                            version: PROTOCOL_VERSION,
                            model: lane.name().to_string(),
                            epoch: lane.epoch(),
                            geometry: lane.geometry(),
                            kappa: lane.kappa(),
                            fingerprint: lane.fingerprint().to_string(),
                            num_batches: 0,
                            batch_size: self.registry.batcher().max_batch as u32,
                        };
                        queue_frame(sess, &hello);
                        sess.lane = Some(lane);
                        sess.deadline = Instant::now() + self.cfg.idle_timeout;
                    }
                    Err(e) => refuse(sess, &self.metrics, Fault::from_error(&e)),
                }
                None
            }
            Message::DatasetHello { dataset_id, .. } => {
                // decode already enforced the version; route to the
                // configured chunk store (empty id = "whatever you serve")
                match &self.cfg.dataset {
                    Some(store)
                        if dataset_id.is_empty() || dataset_id == store.dataset_id() =>
                    {
                        Some(Detach::Delivery(store.clone()))
                    }
                    Some(store) => {
                        let msg = format!(
                            "unknown dataset {dataset_id:?} (this server serves {:?})",
                            store.dataset_id()
                        );
                        refuse(sess, &self.metrics, Fault::Generic { msg });
                        None
                    }
                    None => {
                        let msg = "no bulk dataset is served here".to_string();
                        refuse(sess, &self.metrics, Fault::Generic { msg });
                        None
                    }
                }
            }
            Message::AdminHello => {
                if !self.cfg.admin_enabled {
                    let msg = "admin surface is disabled on this server".to_string();
                    refuse(sess, &self.metrics, Fault::Generic { msg });
                    return None;
                }
                match &self.admin_gate {
                    // credential gate on: any peer address may try; the
                    // MAC decides, not the routing table
                    Some(gate) => Some(Detach::AdminAuthed(gate.clone())),
                    None => {
                        let e = Error::AdminAuth(
                            "admin authentication is not configured on this server \
                             (no admin credential installed)"
                                .into(),
                        );
                        refuse(sess, &self.metrics, Fault::from_error(&e));
                        None
                    }
                }
            }
            first @ (Message::AdminRegister { .. }
            | Message::AdminDrain { .. }
            | Message::AdminRetire { .. }
            | Message::AdminRevoke { .. }
            | Message::AdminStatus) => {
                if !self.cfg.admin_enabled {
                    let msg = "admin surface is disabled on this server".to_string();
                    refuse(sess, &self.metrics, Fault::Generic { msg });
                    return None;
                }
                if self.admin_gate.is_some() {
                    // downgrade attempt: with a credential gate installed,
                    // a bare admin verb is never dispatched — loopback
                    // included
                    let e = Error::AdminAuth(
                        "admin frames must be authenticated on this server \
                         (open with AdminHello and a credential)"
                            .into(),
                    );
                    refuse(sess, &self.metrics, Fault::from_error(&e));
                    return None;
                }
                let loopback =
                    sess.sock.peer_addr().map(|a| a.ip().is_loopback()).unwrap_or(false);
                if !loopback {
                    let msg =
                        "admin frames are accepted from loopback peers only".to_string();
                    refuse(sess, &self.metrics, Fault::Generic { msg });
                    return None;
                }
                Some(Detach::AdminPlain(first))
            }
            Message::AdminAuthed { .. } => {
                // sealed frame before any AdminHello: there is no session
                // nonce to verify against, so this cannot be dispatched
                let e = Error::AdminAuth(
                    "authenticated admin frame before AdminHello (no challenge issued)"
                        .into(),
                );
                refuse(sess, &self.metrics, Fault::from_error(&e));
                None
            }
            other => {
                let msg = format!("serving sessions open with Hello, got {other:?}");
                refuse(sess, &self.metrics, Fault::Generic { msg });
                None
            }
        }
    }

    /// One frame on an established serving session.
    fn handle_serving_frame(&mut self, token: u64, sess: &mut Session, msg: Message) {
        match msg {
            Message::InferRequest { id, model, epoch, row } => {
                self.metrics.requests.inc();
                let session_lane = sess.lane.as_ref().expect("established session").clone();
                // "" + latest ⇒ the lane negotiated at handshake —
                // **revalidated per request**: a drained/retired session
                // lane answers its typed lifecycle fault (with the
                // successor epoch) instead of serving, so rollover is
                // visible to pipelined sessions, not just new ones.
                // Anything else re-resolves per request. Resolve + submit
                // fold into one Result: any Err faults this request only,
                // never the session (row-length validation and the
                // bounded-queue admission check happen inside the lane's
                // batcher `enqueue`, the lifecycle check inside the
                // lane's state-checked `submit_with`).
                let shared = self.shared.clone();
                let m = self.metrics.clone();
                let outcome = if model.is_empty() && epoch == EPOCH_LATEST {
                    Ok(session_lane)
                } else if model.is_empty() {
                    self.registry.resolve(session_lane.name(), epoch)
                } else {
                    self.registry.resolve(&model, epoch)
                }
                .and_then(|lane| {
                    lane.submit_with(row.data(), move |result| {
                        let msg = match result {
                            Ok(logits) => {
                                m.responses.inc();
                                Message::InferResponse { id, logits }
                            }
                            Err(e) => {
                                m.faults.inc();
                                Message::Fault {
                                    of: id,
                                    fault: Fault::Generic {
                                        msg: format!("request {id}: {e}"),
                                    },
                                }
                            }
                        };
                        shared.inbox.lock().unwrap().completions.push((token, msg));
                        shared.waker.wake();
                    })
                });
                match outcome {
                    Ok(()) => sess.inflight += 1,
                    Err(e) => {
                        self.metrics.faults.inc();
                        let fault = match e {
                            // lifecycle and overload refusals keep their
                            // typed payload (successor epoch / backoff
                            // hint); a shed request faults, the session
                            // lives on
                            Error::Draining { .. }
                            | Error::Retired { .. }
                            | Error::Overloaded { .. } => Fault::from_error(&e),
                            other => {
                                Fault::Generic { msg: format!("request {id}: {other}") }
                            }
                        };
                        queue_frame(sess, &Message::Fault { of: id, fault });
                    }
                }
            }
            Message::EndOfData => {
                sess.eof = true;
                sess.rd_done = true;
            }
            other => {
                self.metrics.faults.inc();
                queue_frame(
                    sess,
                    &Message::Fault {
                        of: FAULT_SESSION,
                        fault: Fault::Generic {
                            msg: format!("serving session got unexpected {other:?}"),
                        },
                    },
                );
                sess.closing = true;
            }
        }
    }

    /// Move a connection off the event loop onto a dedicated blocking
    /// thread: the admin session loops from [`super::admin`], or a bulk
    /// delivery serving loop ([`super::delivery`]). The session's
    /// live-budget slot rides along, so detached sessions count against
    /// `max_sessions` for their whole lifetime — which is exactly how
    /// bulk pulls end up shedding `Fault::Overloaded` at accept instead
    /// of starving inference.
    fn detach_admin(&mut self, sess: Session, kind: Detach) {
        let Session { sock, _slot: slot, rbuf, .. } = sess;
        if sock.set_nonblocking(false).is_err() {
            return; // connection unusable; slot freed by drop
        }
        sock.set_read_timeout(Some(self.cfg.idle_timeout)).ok();
        let mut stream = PrefixedStream { pre: std::io::Cursor::new(rbuf), sock };
        let registry = self.registry.clone();
        let name = match &kind {
            Detach::Delivery(_) => "mole-delivery-session",
            _ => "mole-admin-session",
        };
        let metrics = self.metrics.clone();
        let spawned = std::thread::Builder::new().name(name.into()).spawn(move || {
            let _slot = slot;
            let result = match kind {
                Detach::AdminPlain(first) => {
                    super::admin::run_admin_session(stream, first, &registry)
                }
                Detach::AdminAuthed(gate) => {
                    super::admin::run_authed_admin_session(stream, &registry, &gate)
                }
                Detach::Delivery(store) => {
                    super::delivery::run_delivery_session(&mut stream, &store).map(|bytes| {
                        metrics.bytes_out.add(bytes);
                    })
                }
            };
            if let Err(e) = result {
                crate::logging::warn(&format!("detached session ended with error: {e}"));
            }
        });
        match spawned {
            Ok(handle) => self.admin_threads.lock().unwrap().push(handle),
            Err(e) => crate::logging::warn(&format!("detached session spawn failed: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accept_hint_scales_with_pending_fill() {
        // empty pending queue → floor; full → floor + 225 = 250 ms
        assert_eq!(accept_retry_hint(0, 128, 0), ACCEPT_RETRY_MIN_MS);
        assert_eq!(accept_retry_hint(64, 128, 0), 25 + 112);
        assert_eq!(accept_retry_hint(128, 128, 0), 250);
        // pending can transiently exceed max_pending (race with release);
        // the fill term saturates instead of overshooting
        assert_eq!(accept_retry_hint(1000, 128, 0), 250);
    }

    #[test]
    fn accept_hint_doubles_per_burst_step_and_clamps() {
        let base = accept_retry_hint(128, 128, 0);
        assert_eq!(accept_retry_hint(128, 128, ACCEPT_BURST_STEP - 1), base);
        assert_eq!(accept_retry_hint(128, 128, ACCEPT_BURST_STEP), base * 2);
        assert_eq!(accept_retry_hint(128, 128, 2 * ACCEPT_BURST_STEP), ACCEPT_RETRY_MAX_MS);
        // doubling is capped, so even absurd bursts stay in contract
        for burst in [0, 7, 8, 100, u64::MAX] {
            for pending in [0, 1, 64, 128, u64::MAX] {
                let hint = accept_retry_hint(pending, 128, burst);
                assert!((ACCEPT_RETRY_MIN_MS..=ACCEPT_RETRY_MAX_MS).contains(&hint));
            }
        }
        // degenerate max_pending never divides by zero
        assert!(accept_retry_hint(5, 0, 0) >= ACCEPT_RETRY_MIN_MS);
    }
}
