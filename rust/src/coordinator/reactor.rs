//! Thin readiness poller for the evented session layer — the in-tree
//! answer to "no new dependencies".
//!
//! [`Poller::wait`] wraps `poll(2)` directly (one `extern "C"`
//! declaration against the libc every Rust binary already links; no
//! crates). It is deliberately **stateless**: callers hand it the full
//! interest list every call and get back per-slot readiness. For the
//! session counts this server targets (hundreds to low tens of
//! thousands) rebuilding a `pollfd` array per iteration is a few
//! microseconds — the simplicity is worth more than an epoll
//! registration cache, and `poll(2)` has no fd-count ceiling the way
//! `select(2)` does.
//!
//! [`Waker`] is the cross-thread kick: batcher completions land on lane
//! worker threads, which must pull a blocked session driver out of
//! `poll`. It is a nonblocking [`UnixStream`] pair (std — no `pipe(2)`
//! FFI needed): any thread [`Waker::wake`]s by writing one byte, the
//! driver registers the receiving end for readability and
//! [`WakeRx::drain`]s it on wakeup. A full socketpair buffer means a
//! wake is already pending, so `WouldBlock` on the write is success.

use std::io::{ErrorKind, Read, Write};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::time::Duration;

#[repr(C)]
struct PollFd {
    fd: RawFd,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: std::os::raw::c_ulong, timeout: std::os::raw::c_int)
        -> std::os::raw::c_int;
}

/// What a slot wants to be woken for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest { readable: true, writable: false };
    pub const WRITE: Interest = Interest { readable: false, writable: true };
    pub const BOTH: Interest = Interest { readable: true, writable: true };
}

/// Readiness reported for one polled slot (same index as the interest
/// list handed to [`Poller::wait`]).
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Index into the caller's interest list.
    pub slot: usize,
    pub readable: bool,
    pub writable: bool,
    /// Peer hung up / fd error — the slot should be torn down after one
    /// final read attempt (a hangup can still have bytes buffered).
    pub hangup: bool,
}

/// Stateless `poll(2)` front end. Reused only for its scratch buffers.
#[derive(Default)]
pub struct Poller {
    fds: Vec<PollFd>,
}

impl Poller {
    pub fn new() -> Self {
        Self::default()
    }

    /// Block until at least one slot is ready or `timeout` elapses
    /// (`None` = indefinitely). Returns the ready slots; an empty vec
    /// means timeout. `EINTR` is retried internally with a coarsely
    /// re-computed budget.
    pub fn wait(
        &mut self,
        interests: &[(RawFd, Interest)],
        timeout: Option<Duration>,
    ) -> std::io::Result<Vec<Event>> {
        self.fds.clear();
        for &(fd, want) in interests {
            let mut events = 0i16;
            if want.readable {
                events |= POLLIN;
            }
            if want.writable {
                events |= POLLOUT;
            }
            self.fds.push(PollFd { fd, events, revents: 0 });
        }
        // poll(2) caps its wait at i32::MAX ms (~24 days) — treat longer
        // as indefinite
        let mut budget_ms: i32 = match timeout {
            None => -1,
            Some(t) => t.as_millis().min(i32::MAX as u128) as i32,
        };
        let n = loop {
            let rc = unsafe {
                poll(self.fds.as_mut_ptr(), self.fds.len() as std::os::raw::c_ulong, budget_ms)
            };
            if rc >= 0 {
                break rc;
            }
            let err = std::io::Error::last_os_error();
            if err.kind() == ErrorKind::Interrupted {
                // good enough for a readiness loop: a signal mid-wait
                // restarts with the original budget; drivers re-compute
                // their deadlines on every iteration anyway
                let _ = budget_ms;
                continue;
            }
            return Err(err);
        };
        let mut out = Vec::with_capacity(n as usize);
        for (slot, pfd) in self.fds.iter().enumerate() {
            if pfd.revents == 0 {
                continue;
            }
            out.push(Event {
                slot,
                readable: pfd.revents & POLLIN != 0,
                writable: pfd.revents & POLLOUT != 0,
                hangup: pfd.revents & (POLLERR | POLLHUP | POLLNVAL) != 0,
            });
        }
        Ok(out)
    }
}

/// Sending half of the cross-thread wakeup channel. Cheap to clone;
/// every clone kicks the same driver.
#[derive(Clone)]
pub struct Waker {
    tx: std::sync::Arc<UnixStream>,
}

impl Waker {
    /// Pull the owning driver out of [`Poller::wait`]. Never blocks:
    /// a full buffer already means a pending wake.
    pub fn wake(&self) {
        match (&*self.tx).write(&[1u8]) {
            Ok(_) => {}
            Err(e) if e.kind() == ErrorKind::WouldBlock => {}
            Err(_) => {} // driver gone; nothing left to wake
        }
    }
}

/// Receiving half: register [`WakeRx::fd`] for readability and
/// [`WakeRx::drain`] after every poll round that reports it ready.
pub struct WakeRx {
    rx: UnixStream,
}

impl WakeRx {
    pub fn fd(&self) -> RawFd {
        self.rx.as_raw_fd()
    }

    /// Swallow every queued wake byte (level-triggered `poll` would
    /// otherwise spin on the readable socket).
    pub fn drain(&mut self) {
        let mut buf = [0u8; 64];
        loop {
            match self.rx.read(&mut buf) {
                Ok(0) => return, // all wakers dropped
                Ok(_) => continue,
                Err(_) => return, // WouldBlock: drained
            }
        }
    }
}

/// A connected waker pair: hand the [`Waker`] to completion callbacks /
/// the acceptor, keep the [`WakeRx`] on the driver.
pub fn waker() -> std::io::Result<(Waker, WakeRx)> {
    let (tx, rx) = UnixStream::pair()?;
    tx.set_nonblocking(true)?;
    rx.set_nonblocking(true)?;
    Ok((Waker { tx: std::sync::Arc::new(tx) }, WakeRx { rx }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn poll_reports_readable_when_bytes_arrive() {
        let (mut a, b) = UnixStream::pair().unwrap();
        b.set_nonblocking(true).unwrap();
        let mut poller = Poller::new();
        // nothing buffered: times out empty
        let t0 = Instant::now();
        let ev = poller
            .wait(&[(b.as_raw_fd(), Interest::READ)], Some(Duration::from_millis(20)))
            .unwrap();
        assert!(ev.is_empty(), "spurious readiness: {ev:?}");
        assert!(t0.elapsed() >= Duration::from_millis(15));
        // bytes arrive: readable, instantly
        a.write_all(b"x").unwrap();
        let ev = poller
            .wait(&[(b.as_raw_fd(), Interest::READ)], Some(Duration::from_millis(1000)))
            .unwrap();
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].slot, 0);
        assert!(ev[0].readable);
        assert!(!ev[0].hangup);
    }

    #[test]
    fn poll_reports_writable_and_multiplexes_slots() {
        let (mut a, b) = UnixStream::pair().unwrap();
        let (_c, d) = UnixStream::pair().unwrap();
        a.write_all(b"ping").unwrap();
        let mut poller = Poller::new();
        let ev = poller
            .wait(
                &[
                    (b.as_raw_fd(), Interest::BOTH), // readable AND writable
                    (d.as_raw_fd(), Interest::READ), // idle
                ],
                Some(Duration::from_millis(1000)),
            )
            .unwrap();
        assert_eq!(ev.len(), 1, "{ev:?}");
        assert_eq!(ev[0].slot, 0);
        assert!(ev[0].readable && ev[0].writable);
    }

    #[test]
    fn poll_reports_hangup_on_peer_close() {
        let (a, b) = UnixStream::pair().unwrap();
        drop(a);
        let mut poller = Poller::new();
        let ev = poller
            .wait(&[(b.as_raw_fd(), Interest::READ)], Some(Duration::from_millis(1000)))
            .unwrap();
        assert_eq!(ev.len(), 1);
        assert!(ev[0].hangup || ev[0].readable, "{:?}", ev[0]);
    }

    #[test]
    fn waker_unblocks_poll_from_another_thread() {
        let (wake, mut rx) = waker().unwrap();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            wake.wake();
            wake.wake(); // coalesces, must not block or error
        });
        let mut poller = Poller::new();
        let t0 = Instant::now();
        let ev =
            poller.wait(&[(rx.fd(), Interest::READ)], Some(Duration::from_secs(5))).unwrap();
        assert_eq!(ev.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(4), "woke by timeout, not waker");
        rx.drain();
        // drained: next wait times out quickly instead of spinning
        let ev = poller
            .wait(&[(rx.fd(), Interest::READ)], Some(Duration::from_millis(10)))
            .unwrap();
        assert!(ev.is_empty(), "wake bytes not drained: {ev:?}");
        t.join().unwrap();
    }

    #[test]
    fn waker_survives_many_wakes_without_blocking() {
        let (wake, mut rx) = waker().unwrap();
        // far past any socketpair buffer if each byte were required
        for _ in 0..100_000 {
            wake.wake();
        }
        rx.drain();
        let mut poller = Poller::new();
        let ev = poller
            .wait(&[(rx.fd(), Interest::READ)], Some(Duration::from_millis(10)))
            .unwrap();
        assert!(ev.is_empty());
    }
}
