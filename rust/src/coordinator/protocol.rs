//! Wire protocol: length-prefixed binary frames over any Read/Write.
//!
//! Frame layout: `b"ML"` | u8 msg-tag | u32 payload-len | payload.
//! Tensors encode as u8 ndim | u32 dims… | f32-LE data. The protocol
//! carries **only** the HBC-visible surface (§4.1): morphed rows T^r, the
//! Aug-Conv matrix C^ac, first-layer weights (public direction:
//! developer → provider), and inference traffic. Keys never appear here.
//!
//! ## Versioning and multi-tenant routing (v2+)
//!
//! `Hello` opens with an explicit `version` field and both `Hello` and
//! `InferRequest` carry `model` + `epoch` so one server can host many
//! named models across concurrent key epochs ([`super::registry`]).
//! Decoding a `Hello` whose version differs from [`PROTOCOL_VERSION`]
//! yields the typed [`Error::Version`]; sessions answer it with a
//! `Fault` frame instead of dying on a shape-dependent decode error.
//! (v1 `Hello` frames started with the geometry's α, which is 3 for
//! every shipped geometry, so legacy peers deterministically surface as
//! "peer speaks v3".)
//!
//! ## Lifecycle and admin frames (v4)
//!
//! `Fault` (tag 9) is typed: it names the request it answers (`of`,
//! [`FAULT_SESSION`] for session-scoped faults) and carries a [`Fault`]
//! detail. [`Fault::Draining`] / [`Fault::Retired`] tell a client which
//! **successor epoch** to re-resolve to when a serving lane stops
//! accepting work mid-rollover ([`super::registry`] lifecycle), so
//! rotation never surfaces as an opaque string error.
//!
//! The `Admin*` frames (tags 10–14) are the live-registry control
//! surface: register a `(model, epoch)` lane at runtime, drain an
//! epoch, retire it once its batcher is empty, and query status. Like
//! every other frame, they never carry key material: `AdminRegister`
//! names a **vault path local to the server**, which the server reads
//! itself. The tag-9 re-layout is why v4 was not a silent v2 extension:
//! a v2 peer would mis-parse the typed fault payload, so the handshake
//! rejects it typed instead (see [`PROTOCOL_VERSION`] for why v3 is
//! skipped).
//!
//! ## Authenticated admin plane (v5)
//!
//! v5 adds the credential-gated admin handshake (tags 15–17) and the
//! typed [`Fault::AdminAuth`] (fault kind 3). An authenticated admin
//! session opens with `AdminHello`; the server answers with an
//! `AdminChallenge` carrying a fresh 32-byte session **nonce**. Every
//! subsequent admin verb rides inside an `AdminAuthed` envelope: the
//! encoded inner frame (tag + payload), a strictly-increasing frame
//! **counter**, and an HMAC-SHA256 **MAC** keyed by the vault-derived
//! admin credential ([`crate::keys::KeyBundle::admin_credential`]) over
//! `label ‖ nonce ‖ counter ‖ inner-tag ‖ inner-payload`
//! ([`admin_mac`]). The nonce binds frames to one session (a frame
//! captured from another session never verifies) and the counter makes
//! byte-identical replays and reorders within a session die typed —
//! verified in constant time ([`crate::hash::ct_eq`]) **before** the
//! inner frame is even decoded ([`open_admin`]). As shipped in v5 the
//! MAC covered admin *commands* only; v8 extended it to replies (below).
//! It still provides no confidentiality and no wire encryption.
//!
//! ## Backpressure faults (v6)
//!
//! v6 adds [`Fault::Overloaded`] (fault kind 4): the serving plane shed
//! a request or refused a connection because an explicit budget was
//! full (session budget, pending-accept budget, or a lane's bounded
//! submit queue). The fault carries `retry_after_ms`, the server's
//! backoff hint; clients surface it as the typed
//! [`Error::Overloaded`] and well-behaved drivers (`mole loadgen`)
//! sleep that long before retrying. Overload is always *answered* —
//! a saturated v6 server never parks a request silently.
//!
//! ## Bulk delivery plane (v7)
//!
//! v7 adds the chunked morphed-dataset transfer frames (tags 18–23,
//! [`super::delivery`]). `DatasetHello` opens a bulk pull like `Hello`
//! opens a serving session — it leads with the protocol version (same
//! typed [`Error::Version`] rejection) and names the dataset. The
//! server answers with its own `DatasetHello`, then `ManifestRequest`
//! fetches the [`Message::Manifest`]: total rows plus one [`ChunkMeta`]
//! per chunk — raw length, wire length, an RLE-compression flag, and
//! the chunk's SHA-256 ([`crate::hash`]) over the *raw* bytes, which is
//! what makes resumable verified transfer possible. `ChunkRequest`
//! names an explicit `[first, first+count)` index range (a resumable
//! cursor re-requests only unverified indices; stripes partition the
//! range across connections) and the server streams one `Chunk` frame
//! per index. `DeliveryDone` is the flush handshake, both directions.
//! Chunk payloads are opaque bytes at this layer; integrity is checked
//! against the manifest hash *while decoding* on the client
//! ([`super::delivery::decode_chunk`]).
//!
//! ## Bidirectional admin auth, operator verbs, signed manifests (v8)
//!
//! v8 closes the v5 reply hole: the admin MAC preimage gains a
//! **direction byte** ([`DIR_REQUEST`] / [`DIR_REPLY`]) between the
//! counter and the inner tag, and the server seals its `AdminOk` /
//! `Fault` answers under the same session nonce at the *request's*
//! counter ([`seal_admin_reply`]). The client verifies constant-time
//! before decode ([`open_admin_reply`]), mirroring the request path —
//! a MITM can no longer forge an "ok" ack, and because requests and
//! replies authenticate under different direction bytes, a reflected
//! request never verifies as a reply (or vice versa) even at the same
//! counter. The wire layout of `AdminAuthed` (tag 17) is unchanged —
//! the direction byte exists only inside the MAC preimage.
//!
//! v8 also adds `AdminRevoke` (tag 24): revoke a named operator's
//! credential on the serving side, live — in-flight admin sessions
//! included. And [`Message::Manifest`] (tag 20) grows an optional
//! trailing ed25519 signature block ([`ManifestSig`]): the publisher's
//! verifying key plus a signature over the manifest's **unsigned**
//! encoding, so a puller that pins the publisher's key refuses a forged
//! or tampered manifest before fetching a single chunk (and the
//! journal-binding digest, computed over the unsigned encoding, is
//! stable whether or not the manifest travels signed).
//!
//! ## Fleet admin (v9)
//!
//! v9 adds `AdminFleetStatus` (tag 25, empty payload): a sealed,
//! gateway-only query that returns the aggregated per-node health and
//! ack state of every backend behind a `mole gateway`
//! ([`super::gateway`]). It rides the existing v8 sealing unchanged —
//! same MAC preimage, same direction bytes, same counters — because the
//! gateway terminates the operator's sealed session itself and then
//! re-authenticates *as an operator* to each backend with ordinary
//! `register`/`drain`/`retire`/`revoke-operator`/`status` verbs. A
//! backend that receives `AdminFleetStatus` directly refuses it typed:
//! fleet aggregation is the gateway's job, and a lone serving process
//! answering "fleet ok" would collapse per-node truth into one bool.

use crate::hash::{ct_eq, hmac_sha256};
use crate::tensor::Tensor;
use crate::{Error, Geometry, Result};
use std::io::{Read, Write};

const FRAME_MAGIC: [u8; 2] = *b"ML";
/// Guard against hostile / corrupt length fields (C^ac for CIFAR-VGG16 is
/// ~805 MB; cap frames at 1 GiB).
const MAX_PAYLOAD: usize = 1 << 30;

/// Wire protocol version carried in `Hello`. v2 added the version field
/// itself plus `model`/`epoch` routing on `Hello` and `InferRequest`;
/// v4 re-laid-out `Fault` (tag 9: `of` + typed fault kind) and added
/// the Admin frames (tags 10–14); v5 added the authenticated admin
/// handshake (tags 15–17: `AdminHello`/`AdminChallenge`/`AdminAuthed`)
/// and fault kind 3 (`AdminAuth`); v6 added fault kind 4
/// ([`Fault::Overloaded`], carrying `retry_after_ms`) — the typed
/// load-shed answer that replaced silent stalls under overload; v7
/// added the bulk-delivery frames (tags 18–23:
/// `DatasetHello`/`ManifestRequest`/`Manifest`/`ChunkRequest`/`Chunk`/
/// `DeliveryDone`) for chunked, hash-verified, resumable
/// morphed-dataset transfer; v8 added the admin-MAC **direction byte**
/// (replies now sealed too — [`seal_admin_reply`]/[`open_admin_reply`]),
/// the `AdminRevoke` operator-revocation verb (tag 24), and the
/// optional ed25519 signature block on `Manifest` frames
/// ([`ManifestSig`]); v9 added the fleet-status verb (tag 25,
/// [`Message::AdminFleetStatus`]) answered by the gateway tier with
/// per-node acks — serving processes refuse it typed.
/// **v3 is deliberately skipped**:
/// pre-versioning (v1) `Hello` frames began with the geometry's α = 3,
/// which decodes as "version 3" — a build claiming v3 could not tell a
/// legacy peer from a current one.
pub const PROTOCOL_VERSION: u32 = 9;

/// `epoch` sentinel meaning "the newest epoch the peer serves".
pub const EPOCH_LATEST: u32 = u32::MAX;

/// `Fault.of` sentinel: the fault concerns the whole session (handshake
/// rejection, framing violation), not one pipelined request id.
pub const FAULT_SESSION: u64 = u64::MAX;

/// Typed fault detail carried by `Message::Fault` (tag 9).
///
/// [`Fault::Draining`] and [`Fault::Retired`] are the serving-lifecycle
/// faults: the addressed `(model, epoch)` lane no longer accepts new
/// work, and `successor` is the epoch the client should re-resolve to
/// ([`EPOCH_LATEST`] when no concrete successor is active yet — ask for
/// the newest). [`crate::coordinator::MoleClient`] retries these
/// transparently.
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// Catch-all failure; `msg` is human-readable.
    Generic { msg: String },
    /// The lane's key epoch is draining (rollover in progress).
    Draining { model: String, epoch: u32, successor: u32 },
    /// The lane's key epoch was retired (rollover complete).
    Retired { model: String, epoch: u32, successor: u32 },
    /// Admin-plane authentication refusal (forged/missing MAC, replayed
    /// counter, unauthenticated frame on a credential-gated server, …).
    AdminAuth { msg: String },
    /// The server shed this request (or refused this connection) under
    /// load; retry no sooner than `retry_after_ms` milliseconds (v6).
    Overloaded { retry_after_ms: u64 },
}

impl Fault {
    /// Build the wire fault for an error (lifecycle errors map to their
    /// typed variants, everything else to [`Fault::Generic`]).
    pub fn from_error(e: &Error) -> Self {
        match e {
            Error::Draining { model, epoch, successor } => Fault::Draining {
                model: model.clone(),
                epoch: *epoch,
                successor: *successor,
            },
            Error::Retired { model, epoch, successor } => Fault::Retired {
                model: model.clone(),
                epoch: *epoch,
                successor: *successor,
            },
            Error::AdminAuth(msg) => Fault::AdminAuth { msg: msg.clone() },
            Error::Overloaded { retry_after_ms } => {
                Fault::Overloaded { retry_after_ms: *retry_after_ms }
            }
            other => Fault::Generic { msg: other.to_string() },
        }
    }

    /// The typed error a received fault surfaces as (inverse of
    /// [`Fault::from_error`] for the lifecycle variants; `Generic`
    /// becomes a protocol error carrying the peer's message).
    pub fn into_error(self) -> Error {
        match self {
            Fault::Generic { msg } => Error::Protocol(format!("peer fault: {msg}")),
            Fault::Draining { model, epoch, successor } => {
                Error::Draining { model, epoch, successor }
            }
            Fault::Retired { model, epoch, successor } => {
                Error::Retired { model, epoch, successor }
            }
            Fault::AdminAuth { msg } => Error::AdminAuth(msg),
            Fault::Overloaded { retry_after_ms } => Error::Overloaded { retry_after_ms },
        }
    }
}

impl std::fmt::Display for Fault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Fault::Generic { msg } => write!(f, "{msg}"),
            other => write!(f, "{}", other.clone().into_error()),
        }
    }
}

/// Per-chunk manifest entry for the bulk delivery plane (v7). The
/// SHA-256 is always over the chunk's **raw** (decompressed) bytes, so
/// a client verifies integrity *while* decoding — a corrupt compressed
/// stream and a corrupt plain chunk both surface as the same typed
/// [`Error::ChunkCorrupt`], and the hash stays stable whether or not
/// the server chose to compress.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkMeta {
    /// Decompressed chunk size in bytes.
    pub raw_len: u32,
    /// Size of the bytes actually carried in the `Chunk` frame
    /// (== `raw_len` when `compressed` is false).
    pub wire_len: u32,
    /// Whether the stored payload is byte-wise RLE compressed
    /// ([`super::delivery`]; only chosen when it shrinks the chunk).
    pub compressed: bool,
    /// SHA-256 over the raw bytes ([`crate::hash::sha256`]).
    pub sha256: [u8; 32],
}

/// Optional ed25519 signature block on a [`Message::Manifest`] (v8):
/// the publisher's verifying key and a detached signature over the
/// manifest's **unsigned** encoding (the frame payload with this block
/// absent), so signing never perturbs the digest that binds resume
/// journals. The embedded key alone proves integrity; origin requires
/// the puller to pin the expected key (`--expect-signer`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestSig {
    /// The publisher's ed25519 verifying key.
    pub signer: [u8; 32],
    /// Signature over the unsigned manifest encoding.
    pub sig: [u8; 64],
}

/// Protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Session handshake. Serving: client → server (requesting `model` /
    /// `epoch`, geometry fields zeroed), then server → client (resolved
    /// serving parameters). Training: provider → developer.
    Hello {
        /// Must equal [`PROTOCOL_VERSION`]; decode rejects anything else.
        version: u32,
        /// Model name ("" = the peer's default model).
        model: String,
        /// Key epoch ([`EPOCH_LATEST`] = newest).
        epoch: u32,
        geometry: Geometry,
        kappa: usize,
        fingerprint: String,
        num_batches: u32,
        batch_size: u32,
    },
    /// Developer's pre-trained first layer (developer → provider).
    Conv1Weights { w1: Tensor, b1: Vec<f32> },
    /// The Aug-Conv layer (provider → developer).
    AugConv { matrix: Tensor, bias: Vec<f32> },
    /// One morphed training batch (provider → developer).
    MorphedBatch { id: u64, rows: Tensor, labels: Vec<i32> },
    /// End of training-data stream.
    EndOfData,
    /// Serving: one morphed row in (client → developer). `model`/`epoch`
    /// override the session default negotiated in `Hello` ("" +
    /// [`EPOCH_LATEST`] = keep the session lane).
    InferRequest { id: u64, model: String, epoch: u32, row: Tensor },
    /// Serving: logits out.
    InferResponse { id: u64, logits: Vec<f32> },
    /// Generic acknowledgement.
    Ack { of: u64 },
    /// Error notification for request `of` ([`FAULT_SESSION`] = the
    /// whole session) with a typed [`Fault`] detail.
    Fault { of: u64, fault: Fault },
    /// Admin (loopback-only): register a `(model, epoch)` lane at
    /// runtime. `vault_path` names a key vault **on the server's own
    /// filesystem** (key material never crosses the wire); when empty,
    /// the server generates a root bundle from `(kappa, seed)`.
    /// `trunk_seed` must match the model's other epochs so rotation
    /// re-morphs the first layer without retraining the trunk.
    AdminRegister {
        model: String,
        vault_path: String,
        kappa: u32,
        seed: u64,
        trunk_seed: u64,
    },
    /// Admin: stop accepting new sessions/requests on `(model, epoch)`;
    /// subsequent traffic gets [`Fault::Draining`] with the successor.
    AdminDrain { model: String, epoch: u32 },
    /// Admin: retire a drained `(model, epoch)` lane. Refused while the
    /// lane's batcher still holds in-flight requests.
    AdminRetire { model: String, epoch: u32 },
    /// Admin: request a lane-per-line status report.
    AdminStatus,
    /// Admin success reply; `detail` is operator-readable.
    AdminOk { detail: String },
    /// Authenticated-admin handshake opener (client → server): request
    /// a session nonce. Only meaningful on a server with an admin
    /// credential configured; carries nothing.
    AdminHello,
    /// Authenticated-admin challenge (server → client): the fresh
    /// session nonce every subsequent [`Message::AdminAuthed`] MAC must
    /// cover.
    AdminChallenge { nonce: [u8; 32] },
    /// An admin verb sealed for the authenticated plane: the encoded
    /// inner frame plus a strictly-increasing per-session `counter` and
    /// an HMAC-SHA256 `mac` over `label ‖ nonce ‖ counter ‖ inner_tag ‖
    /// inner` ([`admin_mac`]). The inner bytes stay opaque until the MAC
    /// verifies ([`open_admin`]) — a forged or tampered envelope is
    /// refused before any decoding of its contents.
    AdminAuthed {
        counter: u64,
        mac: [u8; 32],
        inner_tag: u8,
        inner: Vec<u8>,
    },
    /// Bulk-delivery handshake (v7, both directions — client names the
    /// dataset it wants, server echoes what it serves). Opens with the
    /// protocol version exactly like [`Message::Hello`] so a
    /// wrong-version peer dies as the typed [`Error::Version`] at
    /// decode, before the rest of the payload is interpreted.
    DatasetHello {
        /// Must equal [`PROTOCOL_VERSION`]; decode rejects anything else.
        version: u32,
        dataset_id: String,
    },
    /// Request the chunk manifest for a dataset (client → server).
    ManifestRequest { dataset_id: String },
    /// The chunk manifest: everything a resumable, striped puller needs
    /// to plan, verify, and journal a transfer (server → client).
    Manifest {
        dataset_id: String,
        /// Total dataset rows (0 for an opaque byte blob).
        total_rows: u64,
        /// Rows per chunk (0 for an opaque byte blob).
        chunk_rows: u32,
        chunks: Vec<ChunkMeta>,
        /// Optional publisher signature over the unsigned encoding (v8).
        signature: Option<ManifestSig>,
    },
    /// Request chunks `[first, first + count)` (client → server). The
    /// server answers with `count` [`Message::Chunk`] frames in index
    /// order.
    ChunkRequest { first: u64, count: u32 },
    /// One delivered chunk (server → client). `compressed`/`raw_len`
    /// mirror the manifest entry so a chunk is decodable standalone;
    /// integrity is the manifest hash, checked while decoding.
    Chunk {
        index: u64,
        compressed: bool,
        raw_len: u32,
        data: Vec<u8>,
    },
    /// Bulk-delivery flush handshake: client sends it when done pulling,
    /// server echoes it and ends the session.
    DeliveryDone,
    /// Admin (v8): revoke a named operator's credential, live. The
    /// serving side drops the label from its operator table immediately
    /// — the revoked credential's next frame dies typed, in-flight
    /// sessions included. Only carries the label; credentials never
    /// cross the wire.
    AdminRevoke { label: String },
    /// Admin (v9): ask the **gateway** for the per-node health + last
    /// fan-out ack of every backend in its fleet. Empty payload; the
    /// reply is a sealed `AdminOk` whose detail carries one line per
    /// node, never a collapsed boolean. Serving processes are not the
    /// fleet — they refuse this verb typed.
    AdminFleetStatus,
}

impl Message {
    /// The message's wire tag — lets error paths name an unexpected
    /// frame by its on-the-wire identity instead of a `{:?}` dump.
    pub fn wire_tag(&self) -> u8 {
        self.tag()
    }

    fn tag(&self) -> u8 {
        match self {
            Message::Hello { .. } => 1,
            Message::Conv1Weights { .. } => 2,
            Message::AugConv { .. } => 3,
            Message::MorphedBatch { .. } => 4,
            Message::EndOfData => 5,
            Message::InferRequest { .. } => 6,
            Message::InferResponse { .. } => 7,
            Message::Ack { .. } => 8,
            Message::Fault { .. } => 9,
            Message::AdminRegister { .. } => 10,
            Message::AdminDrain { .. } => 11,
            Message::AdminRetire { .. } => 12,
            Message::AdminStatus => 13,
            Message::AdminOk { .. } => 14,
            Message::AdminHello => 15,
            Message::AdminChallenge { .. } => 16,
            Message::AdminAuthed { .. } => 17,
            Message::DatasetHello { .. } => 18,
            Message::ManifestRequest { .. } => 19,
            Message::Manifest { .. } => 20,
            Message::ChunkRequest { .. } => 21,
            Message::Chunk { .. } => 22,
            Message::DeliveryDone => 23,
            Message::AdminRevoke { .. } => 24,
            Message::AdminFleetStatus => 25,
        }
    }
}

// ---------------------------------------------------------------------------
// admin-plane MAC (v5, bidirectional since v8)
// ---------------------------------------------------------------------------

/// Domain-separation label for admin-frame MACs.
const ADMIN_MAC_LABEL: &[u8] = b"mole-admin-frame-v1";

/// Direction byte in the admin MAC preimage: client → server (v8).
pub const DIR_REQUEST: u8 = 0;
/// Direction byte in the admin MAC preimage: server → client (v8).
pub const DIR_REPLY: u8 = 1;

/// MAC for one authenticated admin frame: HMAC-SHA256 keyed by the
/// credential over `label ‖ nonce ‖ counter ‖ direction ‖ inner_tag ‖
/// inner`. Covering the tag and counter (not just the payload) means a
/// verb cannot be transplanted onto another verb's payload and a frame
/// cannot be replayed under a recycled counter; covering the direction
/// (v8) means a captured *request* envelope can never be reflected back
/// at the client as a *reply*, even at a matching counter.
pub fn admin_mac(
    credential: &[u8; 32],
    nonce: &[u8; 32],
    counter: u64,
    direction: u8,
    inner_tag: u8,
    inner: &[u8],
) -> [u8; 32] {
    let mut msg = Vec::with_capacity(ADMIN_MAC_LABEL.len() + 32 + 8 + 2 + inner.len());
    msg.extend_from_slice(ADMIN_MAC_LABEL);
    msg.extend_from_slice(nonce);
    msg.extend_from_slice(&counter.to_le_bytes());
    msg.push(direction);
    msg.push(inner_tag);
    msg.extend_from_slice(inner);
    hmac_sha256(credential, &msg)
}

/// Seal an admin verb for the authenticated plane (client → server,
/// [`DIR_REQUEST`]): encode it, stamp the caller's frame counter, and
/// MAC the envelope under `credential` and the session `nonce`.
pub fn seal_admin(
    credential: &[u8; 32],
    nonce: &[u8; 32],
    counter: u64,
    msg: &Message,
) -> Message {
    let inner_tag = msg.tag();
    let inner = encode(msg);
    let mac = admin_mac(credential, nonce, counter, DIR_REQUEST, inner_tag, &inner);
    Message::AdminAuthed { counter, mac, inner_tag, inner }
}

/// Seal a server answer for the authenticated plane (server → client,
/// [`DIR_REPLY`], v8). The reply is sealed **at the request's counter**
/// — not a fresh one — so the client can check, with one equality, that
/// this ack answers the verb it just sent: a replayed earlier ack, a
/// reordered one, and a reflected request all fail before decode.
pub fn seal_admin_reply(
    credential: &[u8; 32],
    nonce: &[u8; 32],
    request_counter: u64,
    msg: &Message,
) -> Message {
    let inner_tag = msg.tag();
    let inner = encode(msg);
    let mac = admin_mac(credential, nonce, request_counter, DIR_REPLY, inner_tag, &inner);
    Message::AdminAuthed { counter: request_counter, mac, inner_tag, inner }
}

/// Server-side verification of one [`Message::AdminAuthed`] envelope.
/// Order matters for both security and the typed errors the
/// conformance suite pins:
///
/// 1. the MAC is recomputed and compared **constant-time** — a forged
///    credential, bit-flipped payload, transplanted tag, or lying
///    counter all die here, before the inner bytes are decoded;
/// 2. the counter must be strictly greater than `last_counter` — a
///    byte-identical replay carries a *valid* MAC and dies here,
///    typed as a replay rather than a forgery;
/// 3. only then is the inner frame decoded (decode errors at this point
///    come from a correctly-authenticated peer and surface as their own
///    typed protocol errors).
///
/// Returns the verified counter (the caller's new high-water mark) and
/// the decoded inner message. Steps 1–2 fail as [`Error::AdminAuth`];
/// step 3 as whatever typed error the decoder reports.
pub fn open_admin(
    credential: &[u8; 32],
    nonce: &[u8; 32],
    last_counter: u64,
    frame: &Message,
) -> Result<(u64, Message)> {
    let (counter, mac, inner_tag, inner) = match frame {
        Message::AdminAuthed { counter, mac, inner_tag, inner } => {
            (*counter, mac, *inner_tag, inner.as_slice())
        }
        other => {
            return Err(Error::AdminAuth(format!(
                "expected an authenticated admin frame, got {other:?}"
            )))
        }
    };
    let want = admin_mac(credential, nonce, counter, DIR_REQUEST, inner_tag, inner);
    if !ct_eq(&want, mac) {
        return Err(Error::AdminAuth("admin frame MAC verification failed".into()));
    }
    if counter <= last_counter {
        return Err(Error::AdminAuth(format!(
            "anti-replay: frame counter {counter} is not above {last_counter} \
             (replayed or reordered admin frame)"
        )));
    }
    Ok((counter, decode(inner_tag, inner)?))
}

/// Client-side verification of a sealed server reply (v8). Mirrors
/// [`open_admin`]'s order — constant-time MAC first, freshness second,
/// decode last — with reply-specific rules:
///
/// 1. the MAC is recomputed under [`DIR_REPLY`] and compared
///    **constant-time** — a forged ack, a tampered detail string, and a
///    reflected request envelope (right MAC, wrong direction) all die
///    here, before the inner bytes are decoded;
/// 2. the reply's counter must **equal** the counter of the request it
///    answers — a replayed ack from an earlier verb in this session
///    carries a valid MAC for *its* counter and dies here, typed as a
///    replay;
/// 3. only then is the inner frame decoded.
pub fn open_admin_reply(
    credential: &[u8; 32],
    nonce: &[u8; 32],
    request_counter: u64,
    frame: &Message,
) -> Result<Message> {
    let (counter, mac, inner_tag, inner) = match frame {
        Message::AdminAuthed { counter, mac, inner_tag, inner } => {
            (*counter, mac, *inner_tag, inner.as_slice())
        }
        other => {
            return Err(Error::AdminAuth(format!(
                "expected a sealed admin reply, got cleartext frame tag {} \
                 (forged or downgraded reply)",
                other.tag()
            )))
        }
    };
    let want = admin_mac(credential, nonce, counter, DIR_REPLY, inner_tag, inner);
    if !ct_eq(&want, mac) {
        return Err(Error::AdminAuth("admin reply MAC verification failed".into()));
    }
    if counter != request_counter {
        return Err(Error::AdminAuth(format!(
            "anti-replay: reply counter {counter} does not answer request \
             {request_counter} (replayed or reordered admin reply)"
        )));
    }
    decode(inner_tag, inner)
}

// ---------------------------------------------------------------------------
// primitive encoders
// ---------------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    out.push(t.ndim() as u8);
    for &d in t.shape() {
        put_u32(out, d as u32);
    }
    for &v in t.data() {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    put_u32(out, v.len() as u32);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_i32s(out: &mut Vec<u8>, v: &[i32]) {
    put_u32(out, v.len() as u32);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .i
            .checked_add(n)
            .ok_or_else(|| Error::Protocol("payload offset overflows".into()))?;
        if end > self.b.len() {
            return Err(Error::Protocol("truncated payload".into()));
        }
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Fixed 32-byte field (nonces, MACs).
    fn bytes32(&mut self) -> Result<[u8; 32]> {
        Ok(self.take(32)?.try_into().unwrap())
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| Error::Protocol("non-utf8 string".into()))
    }

    /// `count * 4` bytes with overflow-checked arithmetic — dims in a
    /// hostile frame can multiply past `usize::MAX` (8 dims of u32::MAX
    /// wrap a 64-bit product), which must fail typed, not wrap into a
    /// bogus small read.
    fn take_f32_sized(&mut self, count: usize) -> Result<&'a [u8]> {
        let nbytes = count
            .checked_mul(4)
            .ok_or_else(|| Error::Protocol(format!("element count {count} overflows")))?;
        self.take(nbytes)
    }

    fn tensor(&mut self) -> Result<Tensor> {
        let nd = self.u8()? as usize;
        if nd > 8 {
            return Err(Error::Protocol(format!("tensor rank {nd} too large")));
        }
        let mut shape = Vec::with_capacity(nd);
        for _ in 0..nd {
            shape.push(self.u32()? as usize);
        }
        let numel = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| {
                Error::Protocol(format!("tensor shape {shape:?} overflows element count"))
            })?;
        let raw = self.take_f32_sized(numel)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Tensor::new(&shape, data)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take_f32_sized(n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let raw = self.take_f32_sized(n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn done(&self) -> Result<()> {
        if self.i == self.b.len() {
            Ok(())
        } else {
            Err(Error::Protocol("trailing bytes in payload".into()))
        }
    }
}

// ---------------------------------------------------------------------------
// message codec
// ---------------------------------------------------------------------------

/// Encode a message payload (without the frame header).
pub fn encode(msg: &Message) -> Vec<u8> {
    let mut out = Vec::new();
    match msg {
        Message::Hello {
            version,
            model,
            epoch,
            geometry,
            kappa,
            fingerprint,
            num_batches,
            batch_size,
        } => {
            put_u32(&mut out, *version);
            put_str(&mut out, model);
            put_u32(&mut out, *epoch);
            put_u32(&mut out, geometry.alpha as u32);
            put_u32(&mut out, geometry.m as u32);
            put_u32(&mut out, geometry.beta as u32);
            put_u32(&mut out, geometry.p as u32);
            put_u32(&mut out, *kappa as u32);
            put_str(&mut out, fingerprint);
            put_u32(&mut out, *num_batches);
            put_u32(&mut out, *batch_size);
        }
        Message::Conv1Weights { w1, b1 } => {
            put_tensor(&mut out, w1);
            put_f32s(&mut out, b1);
        }
        Message::AugConv { matrix, bias } => {
            put_tensor(&mut out, matrix);
            put_f32s(&mut out, bias);
        }
        Message::MorphedBatch { id, rows, labels } => {
            put_u64(&mut out, *id);
            put_tensor(&mut out, rows);
            put_i32s(&mut out, labels);
        }
        Message::EndOfData => {}
        Message::InferRequest { id, model, epoch, row } => {
            put_u64(&mut out, *id);
            put_str(&mut out, model);
            put_u32(&mut out, *epoch);
            put_tensor(&mut out, row);
        }
        Message::InferResponse { id, logits } => {
            put_u64(&mut out, *id);
            put_f32s(&mut out, logits);
        }
        Message::Ack { of } => put_u64(&mut out, *of),
        Message::Fault { of, fault } => {
            put_u64(&mut out, *of);
            match fault {
                Fault::Generic { msg } => {
                    out.push(0);
                    put_str(&mut out, msg);
                }
                Fault::Draining { model, epoch, successor } => {
                    out.push(1);
                    put_str(&mut out, model);
                    put_u32(&mut out, *epoch);
                    put_u32(&mut out, *successor);
                }
                Fault::Retired { model, epoch, successor } => {
                    out.push(2);
                    put_str(&mut out, model);
                    put_u32(&mut out, *epoch);
                    put_u32(&mut out, *successor);
                }
                Fault::AdminAuth { msg } => {
                    out.push(3);
                    put_str(&mut out, msg);
                }
                Fault::Overloaded { retry_after_ms } => {
                    out.push(4);
                    put_u64(&mut out, *retry_after_ms);
                }
            }
        }
        Message::AdminRegister { model, vault_path, kappa, seed, trunk_seed } => {
            put_str(&mut out, model);
            put_str(&mut out, vault_path);
            put_u32(&mut out, *kappa);
            put_u64(&mut out, *seed);
            put_u64(&mut out, *trunk_seed);
        }
        Message::AdminDrain { model, epoch } | Message::AdminRetire { model, epoch } => {
            put_str(&mut out, model);
            put_u32(&mut out, *epoch);
        }
        Message::AdminStatus => {}
        Message::AdminOk { detail } => put_str(&mut out, detail),
        Message::AdminHello => {}
        Message::AdminChallenge { nonce } => out.extend_from_slice(nonce),
        Message::AdminAuthed { counter, mac, inner_tag, inner } => {
            put_u64(&mut out, *counter);
            out.extend_from_slice(mac);
            out.push(*inner_tag);
            put_u32(&mut out, inner.len() as u32);
            out.extend_from_slice(inner);
        }
        Message::DatasetHello { version, dataset_id } => {
            put_u32(&mut out, *version);
            put_str(&mut out, dataset_id);
        }
        Message::ManifestRequest { dataset_id } => put_str(&mut out, dataset_id),
        Message::Manifest { dataset_id, total_rows, chunk_rows, chunks, signature } => {
            put_str(&mut out, dataset_id);
            put_u64(&mut out, *total_rows);
            put_u32(&mut out, *chunk_rows);
            put_u32(&mut out, chunks.len() as u32);
            for c in chunks {
                put_u32(&mut out, c.raw_len);
                put_u32(&mut out, c.wire_len);
                out.push(c.compressed as u8);
                out.extend_from_slice(&c.sha256);
            }
            match signature {
                None => out.push(0),
                Some(s) => {
                    out.push(1);
                    out.extend_from_slice(&s.signer);
                    out.extend_from_slice(&s.sig);
                }
            }
        }
        Message::ChunkRequest { first, count } => {
            put_u64(&mut out, *first);
            put_u32(&mut out, *count);
        }
        Message::Chunk { index, compressed, raw_len, data } => {
            put_u64(&mut out, *index);
            out.push(*compressed as u8);
            put_u32(&mut out, *raw_len);
            put_u32(&mut out, data.len() as u32);
            out.extend_from_slice(data);
        }
        Message::DeliveryDone => {}
        Message::AdminRevoke { label } => put_str(&mut out, label),
        Message::AdminFleetStatus => {}
    }
    out
}

/// Decode a message payload given its tag.
pub fn decode(tag: u8, payload: &[u8]) -> Result<Message> {
    let mut c = Cursor { b: payload, i: 0 };
    let msg = match tag {
        1 => {
            let version = c.u32()?;
            if version != PROTOCOL_VERSION {
                // The rest of the payload has an unknown layout; surface
                // the typed mismatch so sessions can reply with a Fault.
                return Err(Error::Version { got: version, want: PROTOCOL_VERSION });
            }
            let model = c.str()?;
            let epoch = c.u32()?;
            let alpha = c.u32()? as usize;
            let m = c.u32()? as usize;
            let beta = c.u32()? as usize;
            let p = c.u32()? as usize;
            Message::Hello {
                version,
                model,
                epoch,
                geometry: Geometry::new(alpha, m, beta, p),
                kappa: c.u32()? as usize,
                fingerprint: c.str()?,
                num_batches: c.u32()?,
                batch_size: c.u32()?,
            }
        }
        2 => Message::Conv1Weights { w1: c.tensor()?, b1: c.f32s()? },
        3 => Message::AugConv { matrix: c.tensor()?, bias: c.f32s()? },
        4 => Message::MorphedBatch { id: c.u64()?, rows: c.tensor()?, labels: c.i32s()? },
        5 => Message::EndOfData,
        6 => Message::InferRequest {
            id: c.u64()?,
            model: c.str()?,
            epoch: c.u32()?,
            row: c.tensor()?,
        },
        7 => Message::InferResponse { id: c.u64()?, logits: c.f32s()? },
        8 => Message::Ack { of: c.u64()? },
        9 => {
            let of = c.u64()?;
            let fault = match c.u8()? {
                0 => Fault::Generic { msg: c.str()? },
                1 => Fault::Draining {
                    model: c.str()?,
                    epoch: c.u32()?,
                    successor: c.u32()?,
                },
                2 => Fault::Retired {
                    model: c.str()?,
                    epoch: c.u32()?,
                    successor: c.u32()?,
                },
                3 => Fault::AdminAuth { msg: c.str()? },
                4 => Fault::Overloaded { retry_after_ms: c.u64()? },
                k => return Err(Error::Protocol(format!("unknown fault kind {k}"))),
            };
            Message::Fault { of, fault }
        }
        10 => Message::AdminRegister {
            model: c.str()?,
            vault_path: c.str()?,
            kappa: c.u32()?,
            seed: c.u64()?,
            trunk_seed: c.u64()?,
        },
        11 => Message::AdminDrain { model: c.str()?, epoch: c.u32()? },
        12 => Message::AdminRetire { model: c.str()?, epoch: c.u32()? },
        13 => Message::AdminStatus,
        14 => Message::AdminOk { detail: c.str()? },
        15 => Message::AdminHello,
        16 => Message::AdminChallenge { nonce: c.bytes32()? },
        17 => {
            let counter = c.u64()?;
            let mac = c.bytes32()?;
            let inner_tag = c.u8()?;
            let n = c.u32()? as usize;
            let inner = c.take(n)?.to_vec();
            Message::AdminAuthed { counter, mac, inner_tag, inner }
        }
        18 => {
            let version = c.u32()?;
            if version != PROTOCOL_VERSION {
                // Same contract as Hello: the rest of the payload has an
                // unknown layout, so surface the typed mismatch and let
                // the session answer with a Fault naming both versions.
                return Err(Error::Version { got: version, want: PROTOCOL_VERSION });
            }
            Message::DatasetHello { version, dataset_id: c.str()? }
        }
        19 => Message::ManifestRequest { dataset_id: c.str()? },
        20 => {
            let dataset_id = c.str()?;
            let total_rows = c.u64()?;
            let chunk_rows = c.u32()?;
            let n = c.u32()? as usize;
            // no with_capacity(n): a lying count must fail at the cursor
            // bounds check, not pre-allocate gigabytes
            let mut chunks = Vec::new();
            for _ in 0..n {
                let raw_len = c.u32()?;
                let wire_len = c.u32()?;
                let compressed = match c.u8()? {
                    0 => false,
                    1 => true,
                    k => {
                        return Err(Error::Protocol(format!(
                            "bad chunk compression flag {k}"
                        )))
                    }
                };
                chunks.push(ChunkMeta { raw_len, wire_len, compressed, sha256: c.bytes32()? });
            }
            let signature = match c.u8()? {
                0 => None,
                1 => {
                    let signer = c.bytes32()?;
                    let sig: [u8; 64] = c.take(64)?.try_into().unwrap();
                    Some(ManifestSig { signer, sig })
                }
                k => {
                    return Err(Error::Protocol(format!(
                        "bad manifest signature flag {k}"
                    )))
                }
            };
            Message::Manifest { dataset_id, total_rows, chunk_rows, chunks, signature }
        }
        21 => Message::ChunkRequest { first: c.u64()?, count: c.u32()? },
        22 => {
            let index = c.u64()?;
            let compressed = match c.u8()? {
                0 => false,
                1 => true,
                k => {
                    return Err(Error::Protocol(format!("bad chunk compression flag {k}")))
                }
            };
            let raw_len = c.u32()?;
            let n = c.u32()? as usize;
            let data = c.take(n)?.to_vec();
            Message::Chunk { index, compressed, raw_len, data }
        }
        23 => Message::DeliveryDone,
        24 => Message::AdminRevoke { label: c.str()? },
        25 => Message::AdminFleetStatus,
        t => return Err(Error::Protocol(format!("unknown message tag {t}"))),
    };
    c.done()?;
    Ok(msg)
}

/// Write one framed message.
pub fn write_message<W: Write>(w: &mut W, msg: &Message) -> Result<usize> {
    let payload = encode(msg);
    if payload.len() > MAX_PAYLOAD {
        return Err(Error::Protocol(format!("payload {} too large", payload.len())));
    }
    w.write_all(&FRAME_MAGIC)?;
    w.write_all(&[msg.tag()])?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&payload)?;
    w.flush()?;
    Ok(7 + payload.len())
}

/// Try to peel one framed message off the front of a byte buffer — the
/// evented session layer's decode entry point (per-session read buffers
/// accumulate whatever the socket had; frames are consumed as they
/// complete). Returns `Ok(None)` while the buffer holds only a partial
/// frame (read more), `Ok(Some((msg, consumed)))` when a full frame
/// decoded (`consumed` bytes, header included, should be drained), and
/// `Err` for the same malformed-framing cases the blocking
/// [`read_message`] raises (bad magic, oversized length, bad payload).
/// A hostile length field is rejected from the 7-byte header alone —
/// before the buffer is ever asked to hold the claimed bytes.
pub fn try_decode_frame(buf: &[u8]) -> Result<Option<(Message, usize)>> {
    if buf.len() < 7 {
        return Ok(None);
    }
    if buf[0..2] != FRAME_MAGIC {
        return Err(Error::Protocol("bad frame magic".into()));
    }
    let tag = buf[2];
    let len = u32::from_le_bytes(buf[3..7].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        return Err(Error::Protocol(format!("frame length {len} too large")));
    }
    if buf.len() < 7 + len {
        return Ok(None);
    }
    Ok(Some((decode(tag, &buf[7..7 + len])?, 7 + len)))
}

/// Read one framed message (blocking).
///
/// The payload buffer grows with the bytes that actually arrive rather
/// than being sized up-front from the length field, so a hostile header
/// claiming a near-`MAX_PAYLOAD` frame over a short stream fails with a
/// typed error without ever allocating gigabytes.
pub fn read_message<R: Read>(r: &mut R) -> Result<Message> {
    let mut head = [0u8; 7];
    r.read_exact(&mut head)?;
    if head[0..2] != FRAME_MAGIC {
        return Err(Error::Protocol("bad frame magic".into()));
    }
    let tag = head[2];
    let len = u32::from_le_bytes(head[3..7].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        return Err(Error::Protocol(format!("frame length {len} too large")));
    }
    let mut payload = Vec::new();
    r.by_ref().take(len as u64).read_to_end(&mut payload)?;
    if payload.len() < len {
        return Err(Error::Io(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            format!("frame truncated: header claims {len} bytes, got {}", payload.len()),
        )));
    }
    decode(tag, &payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn roundtrip(msg: Message) {
        let mut buf = Vec::new();
        let n = write_message(&mut buf, &msg).unwrap();
        assert_eq!(n, buf.len());
        let mut slice = buf.as_slice();
        let got = read_message(&mut slice).unwrap();
        assert_eq!(got, msg);
    }

    #[test]
    fn roundtrip_all_variants() {
        for msg in all_variants() {
            roundtrip(msg);
        }
        // the buffer-based decoder agrees with the stream decoder: every
        // variant, concatenated on one wire, peels off in order with the
        // exact consumed count, and every strict prefix is "incomplete",
        // never an error
        let msgs = all_variants();
        let mut wire = Vec::new();
        for m in &msgs {
            write_message(&mut wire, m).unwrap();
        }
        let mut at = 0;
        for m in &msgs {
            let (got, used) = try_decode_frame(&wire[at..]).unwrap().unwrap();
            assert_eq!(&got, m);
            for cut in (0..used).step_by(1.max(used / 64)) {
                assert!(
                    try_decode_frame(&wire[at..at + cut]).unwrap().is_none(),
                    "prefix of {cut}/{used} bytes decoded"
                );
            }
            at += used;
        }
        assert_eq!(at, wire.len());
        // malformed headers die from the 7 header bytes alone
        assert!(try_decode_frame(b"XX\x01\x00\x00\x00\x00").is_err());
        let mut huge = FRAME_MAGIC.to_vec();
        huge.push(1);
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(try_decode_frame(&huge).is_err());
        // routing fields survive the trip
        roundtrip(Message::Hello {
            version: PROTOCOL_VERSION,
            model: "resnet-morph".into(),
            epoch: 3,
            geometry: Geometry::SMALL,
            kappa: 16,
            fingerprint: "abc123".into(),
            num_batches: 10,
            batch_size: 64,
        });
        roundtrip(Message::InferRequest {
            id: 99,
            model: "resnet-morph".into(),
            epoch: EPOCH_LATEST,
            row: Tensor::new(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]).unwrap(),
        });
    }

    /// A `Hello` whose leading version field differs from ours must come
    /// back as the typed [`Error::Version`], not a shape-dependent decode
    /// error — sessions turn this into a `Fault` for the peer.
    #[test]
    fn version_mismatch_is_typed() {
        // a v1-shaped Hello: no version field, payload starts with the
        // geometry (α = 3 for every shipped geometry)
        let frame = crate::testkit::net::legacy_v1_hello_frame();
        match read_message(&mut frame.as_slice()) {
            Err(Error::Version { got: 3, want }) => assert_eq!(want, PROTOCOL_VERSION),
            other => panic!("expected version mismatch, got {other:?}"),
        }
        // a made-up future version is rejected the same way
        let mut future = Vec::new();
        put_u32(&mut future, PROTOCOL_VERSION + 7);
        let mut frame = Vec::new();
        frame.extend_from_slice(b"ML");
        frame.push(1);
        frame.extend_from_slice(&(future.len() as u32).to_le_bytes());
        frame.extend_from_slice(&future);
        assert!(matches!(
            read_message(&mut frame.as_slice()),
            Err(Error::Version { got, .. }) if got == PROTOCOL_VERSION + 7
        ));
        // DatasetHello (v7) mirrors Hello's version-first contract: the
        // version field is checked before the dataset id is parsed
        let mut payload = Vec::new();
        put_u32(&mut payload, PROTOCOL_VERSION - 1);
        put_str(&mut payload, "cifar-morphed");
        let mut frame = Vec::new();
        frame.extend_from_slice(b"ML");
        frame.push(18);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        assert!(matches!(
            read_message(&mut frame.as_slice()),
            Err(Error::Version { got, want })
                if got == PROTOCOL_VERSION - 1 && want == PROTOCOL_VERSION
        ));
    }

    /// Manifest decode hardening: a lying chunk count dies at the cursor
    /// bounds check (no pre-allocation from the count), and a bad
    /// compression flag is a typed refusal, not a silent bool coercion.
    #[test]
    fn manifest_decode_hardened() {
        let msg = Message::Manifest {
            dataset_id: "d".into(),
            total_rows: 10,
            chunk_rows: 2,
            chunks: vec![ChunkMeta {
                raw_len: 8,
                wire_len: 8,
                compressed: false,
                sha256: [1; 32],
            }],
            signature: None,
        };
        let mut buf = Vec::new();
        write_message(&mut buf, &msg).unwrap();
        // a bad signature flag (trailing byte) is refused typed too
        let mut bad_sig = buf.clone();
        let last = bad_sig.len() - 1;
        bad_sig[last] = 9;
        match read_message(&mut bad_sig.as_slice()) {
            Err(Error::Protocol(m)) => assert!(m.contains("signature flag"), "{m}"),
            other => panic!("expected bad-signature-flag error, got {other:?}"),
        }
        // count field sits after dataset_id(4+1) + total_rows(8) +
        // chunk_rows(4) in the payload; lie that there are 2^32-1 chunks
        let count_at = 7 + 4 + 1 + 8 + 4;
        let t0 = std::time::Instant::now();
        let mut lying = buf.clone();
        lying[count_at..count_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        match read_message(&mut lying.as_slice()) {
            Err(Error::Protocol(m)) => assert!(m.contains("truncated"), "{m}"),
            other => panic!("expected truncated-payload error, got {other:?}"),
        }
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(1),
            "lying chunk count must fail fast"
        );
        // compression flag 7 is refused typed
        let flag_at = count_at + 4 + 4 + 4;
        let mut bad = buf.clone();
        bad[flag_at] = 7;
        match read_message(&mut bad.as_slice()) {
            Err(Error::Protocol(m)) => assert!(m.contains("compression flag"), "{m}"),
            other => panic!("expected bad-flag error, got {other:?}"),
        }
    }

    #[test]
    fn property_roundtrip_random_batches() {
        crate::testkit::forall(
            77,
            16,
            |rng| {
                let b = 1 + rng.below(8);
                let d = 1 + rng.below(32);
                let rows = crate::testkit::gen::tensor(rng, &[b, d], 1.0);
                let labels = (0..b).map(|_| rng.below(10) as i32).collect::<Vec<_>>();
                Message::MorphedBatch { id: rng.next_u64(), rows, labels }
            },
            |msg| {
                let mut buf = Vec::new();
                write_message(&mut buf, msg).map_err(|e| e.to_string())?;
                let got = read_message(&mut buf.as_slice()).map_err(|e| e.to_string())?;
                if &got == msg {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }

    #[test]
    fn corrupt_frames_rejected() {
        let mut buf = Vec::new();
        write_message(&mut buf, &Message::Ack { of: 1 }).unwrap();
        // bad magic
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(read_message(&mut bad.as_slice()).is_err());
        // bad tag
        let mut bad = buf.clone();
        bad[2] = 200;
        assert!(read_message(&mut bad.as_slice()).is_err());
        // truncated
        assert!(read_message(&mut &buf[..5]).is_err());
        // trailing bytes in payload
        let mut bad = buf.clone();
        let len = u32::from_le_bytes(bad[3..7].try_into().unwrap()) + 1;
        bad[3..7].copy_from_slice(&len.to_le_bytes());
        bad.push(0);
        assert!(read_message(&mut bad.as_slice()).is_err());
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut head = Vec::new();
        head.extend_from_slice(b"ML");
        head.push(8);
        head.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(read_message(&mut head.as_slice()).is_err());
    }

    /// One representative frame per `Message` variant (every tag).
    fn all_variants() -> Vec<Message> {
        let mut rng = Rng::new(0);
        vec![
            Message::Hello {
                version: PROTOCOL_VERSION,
                model: "demo_model".into(),
                epoch: 1,
                geometry: Geometry::SMALL,
                kappa: 16,
                fingerprint: "abc123".into(),
                num_batches: 10,
                batch_size: 64,
            },
            Message::Conv1Weights {
                w1: Tensor::new(&[2, 3, 3, 3], rng.normal_vec(54, 1.0)).unwrap(),
                b1: vec![0.5, -0.5],
            },
            Message::AugConv {
                matrix: Tensor::new(&[4, 8], rng.normal_vec(32, 1.0)).unwrap(),
                bias: vec![1.0; 8],
            },
            Message::MorphedBatch {
                id: 7,
                rows: Tensor::new(&[2, 5], rng.normal_vec(10, 1.0)).unwrap(),
                labels: vec![3, 9],
            },
            Message::EndOfData,
            Message::InferRequest {
                id: 99,
                model: String::new(),
                epoch: EPOCH_LATEST,
                row: Tensor::new(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]).unwrap(),
            },
            Message::InferResponse { id: 99, logits: vec![0.1, 0.9] },
            Message::Ack { of: 42 },
            Message::Fault {
                of: FAULT_SESSION,
                fault: Fault::Generic { msg: "boom".into() },
            },
            Message::Fault {
                of: 7,
                fault: Fault::Draining { model: "alpha".into(), epoch: 0, successor: 1 },
            },
            Message::Fault {
                of: 8,
                fault: Fault::Retired {
                    model: "alpha".into(),
                    epoch: 0,
                    successor: EPOCH_LATEST,
                },
            },
            Message::AdminRegister {
                model: "alpha".into(),
                vault_path: "/tmp/alpha.v1.key".into(),
                kappa: 16,
                seed: 11,
                trunk_seed: 11,
            },
            Message::AdminDrain { model: "alpha".into(), epoch: 0 },
            Message::AdminRetire { model: "alpha".into(), epoch: 0 },
            Message::AdminStatus,
            Message::AdminOk { detail: "registered alpha@1".into() },
            Message::Fault {
                of: FAULT_SESSION,
                fault: Fault::AdminAuth { msg: "MAC verification failed".into() },
            },
            Message::Fault {
                of: 9,
                fault: Fault::Overloaded { retry_after_ms: 25 },
            },
            Message::AdminHello,
            Message::AdminChallenge { nonce: [7u8; 32] },
            seal_admin(
                &[1u8; 32],
                &[2u8; 32],
                1,
                &Message::AdminDrain { model: "alpha".into(), epoch: 0 },
            ),
            // v7 bulk-delivery frames (tags 18–23): their presence here
            // pulls them into every truncation / bit-flip / lying-length
            // suite below
            Message::DatasetHello {
                version: PROTOCOL_VERSION,
                dataset_id: "cifar-morphed".into(),
            },
            Message::ManifestRequest { dataset_id: "cifar-morphed".into() },
            Message::Manifest {
                dataset_id: "cifar-morphed".into(),
                total_rows: 60_000,
                chunk_rows: 64,
                chunks: vec![
                    ChunkMeta {
                        raw_len: 12_288,
                        wire_len: 12_288,
                        compressed: false,
                        sha256: [0xAB; 32],
                    },
                    ChunkMeta {
                        raw_len: 12_288,
                        wire_len: 96,
                        compressed: true,
                        sha256: [0xCD; 32],
                    },
                ],
                signature: None,
            },
            // a signed manifest (v8): the trailing signature block rides
            // through every truncation / bit-flip suite below
            Message::Manifest {
                dataset_id: "cifar-morphed".into(),
                total_rows: 60_000,
                chunk_rows: 64,
                chunks: vec![ChunkMeta {
                    raw_len: 12_288,
                    wire_len: 12_288,
                    compressed: false,
                    sha256: [0xEF; 32],
                }],
                signature: Some(ManifestSig { signer: [0x11; 32], sig: [0x22; 64] }),
            },
            Message::ChunkRequest { first: 3, count: 5 },
            Message::Chunk {
                index: 3,
                compressed: false,
                raw_len: 6,
                data: vec![1, 2, 3, 4, 5, 6],
            },
            Message::Chunk {
                index: 4,
                compressed: true,
                raw_len: 300,
                data: vec![255, 0, 45, 7],
            },
            Message::DeliveryDone,
            // v8 frames: the operator-revocation verb, bare and sealed,
            // plus a sealed server reply ([`DIR_REPLY`] direction)
            Message::AdminRevoke { label: "ada".into() },
            seal_admin(
                &[1u8; 32],
                &[2u8; 32],
                2,
                &Message::AdminRevoke { label: "ada".into() },
            ),
            seal_admin_reply(
                &[1u8; 32],
                &[2u8; 32],
                2,
                &Message::AdminOk { detail: "revoked operator \"ada\"".into() },
            ),
            // v9 frames: the fleet-status query, bare and sealed, plus a
            // sealed per-node aggregate reply as the gateway sends it
            Message::AdminFleetStatus,
            seal_admin(&[1u8; 32], &[2u8; 32], 3, &Message::AdminFleetStatus),
            seal_admin_reply(
                &[1u8; 32],
                &[2u8; 32],
                3,
                &Message::AdminOk {
                    detail: "node 127.0.0.1:4101 ok | node 127.0.0.1:4102 failed: probe timeout"
                        .into(),
                },
            ),
        ]
    }

    /// Every variant must reject (not panic on) a frame whose stream is
    /// cut mid-header or mid-payload, and — when the header length is
    /// patched to lie about a shorter payload — fail typed from the
    /// cursor instead of reading past the buffer.
    #[test]
    fn every_variant_rejects_truncation() {
        for msg in all_variants() {
            let mut buf = Vec::new();
            write_message(&mut buf, &msg).unwrap();
            // cut mid-header
            assert!(read_message(&mut &buf[..3.min(buf.len())]).is_err(), "{msg:?}");
            // cut one byte short of a complete frame (EndOfData's frame is
            // header-only, so cutting it hits the header read instead)
            assert!(read_message(&mut &buf[..buf.len() - 1]).is_err(), "{msg:?}");
            // lie in the header: claim 4 fewer payload bytes than the
            // fields need — decode must error, not read out of bounds
            let payload_len = buf.len() - 7;
            if payload_len >= 4 {
                let mut lying = buf.clone();
                lying[3..7].copy_from_slice(&((payload_len - 4) as u32).to_le_bytes());
                lying.truncate(buf.len() - 4);
                assert!(read_message(&mut lying.as_slice()).is_err(), "{msg:?}");
            }
        }
    }

    /// A hostile header claiming a ~1 GiB payload over a 2-byte stream
    /// must fail fast without allocating the claimed size (the payload
    /// buffer grows with arriving bytes only).
    #[test]
    fn hostile_length_does_not_overallocate() {
        let mut frame = Vec::new();
        frame.extend_from_slice(b"ML");
        frame.push(7); // InferResponse
        frame.extend_from_slice(&((MAX_PAYLOAD as u32) - 1).to_le_bytes());
        frame.extend_from_slice(&[0u8, 0u8]); // 2 bytes instead of ~1 GiB
        let t0 = std::time::Instant::now();
        match read_message(&mut frame.as_slice()) {
            Err(Error::Io(e)) => assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof),
            other => panic!("expected truncated-frame io error, got {other:?}"),
        }
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(1),
            "hostile length field should fail fast"
        );
    }

    /// Tensor dims whose product overflows `usize` must come back as a
    /// typed protocol error (unchecked math would wrap into a tiny read
    /// and hand a corrupt tensor to the caller).
    #[test]
    fn tensor_dim_overflow_rejected() {
        let mut payload = Vec::new();
        put_u64(&mut payload, 1); // request id
        put_str(&mut payload, ""); // model (session default)
        put_u32(&mut payload, EPOCH_LATEST); // epoch
        payload.push(8); // ndim = 8
        for _ in 0..8 {
            put_u32(&mut payload, u32::MAX); // 2^256 elements total
        }
        let mut frame = Vec::new();
        frame.extend_from_slice(b"ML");
        frame.push(6); // InferRequest
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        match read_message(&mut frame.as_slice()) {
            Err(Error::Protocol(m)) => {
                assert!(m.contains("overflow"), "unexpected message: {m}")
            }
            other => panic!("expected protocol error, got {other:?}"),
        }
    }

    /// Lifecycle faults map losslessly between the wire [`Fault`] and the
    /// crate [`Error`] (the client's retry loop depends on `successor`
    /// surviving the trip); everything else folds into `Generic`.
    #[test]
    fn fault_error_mapping_roundtrips() {
        let e = Error::Draining { model: "alpha".into(), epoch: 0, successor: 1 };
        let f = Fault::from_error(&e);
        assert_eq!(
            f,
            Fault::Draining { model: "alpha".into(), epoch: 0, successor: 1 }
        );
        assert!(matches!(
            f.into_error(),
            Error::Draining { model, epoch: 0, successor: 1 } if model == "alpha"
        ));
        let e = Error::Retired { model: "beta".into(), epoch: 3, successor: EPOCH_LATEST };
        assert!(matches!(
            Fault::from_error(&e).into_error(),
            Error::Retired { epoch: 3, successor: EPOCH_LATEST, .. }
        ));
        let f = Fault::from_error(&Error::Protocol("boom".into()));
        assert!(matches!(&f, Fault::Generic { msg } if msg.contains("boom")));
        assert!(f.to_string().contains("boom"));
        // admin-auth refusals stay typed across the wire mapping
        let f = Fault::from_error(&Error::AdminAuth("bad MAC".into()));
        assert!(matches!(&f, Fault::AdminAuth { msg } if msg == "bad MAC"));
        assert!(matches!(
            f.clone().into_error(),
            Error::AdminAuth(msg) if msg == "bad MAC"
        ));
        assert!(f.to_string().contains("admin auth"), "{f}");
        // overload faults carry the backoff hint losslessly both ways
        let f = Fault::from_error(&Error::Overloaded { retry_after_ms: 25 });
        assert!(matches!(&f, Fault::Overloaded { retry_after_ms: 25 }));
        assert!(matches!(
            f.clone().into_error(),
            Error::Overloaded { retry_after_ms: 25 }
        ));
        assert!(f.to_string().contains("25 ms"), "{f}");
        // typed faults display the successor so raw logs stay readable
        let f = Fault::Draining { model: "alpha".into(), epoch: 0, successor: 1 };
        assert!(f.to_string().contains("draining"), "{f}");
        assert!(f.to_string().contains("epoch 1"), "{f}");
    }

    /// Satellite: property-style decoder fuzz. Seeded-random frames from
    /// every v6 + Admin variant are mutated — truncated anywhere,
    /// bit-flipped, replaced with pure garbage, or given a lying length
    /// header — and fed to `read_message`, which must always return a
    /// typed result: never panic, and never allocate/stall past the
    /// bytes that actually arrived (the grow-with-arrival property).
    #[test]
    fn fuzz_decode_never_panics() {
        let variants = all_variants();
        let t0 = std::time::Instant::now();
        crate::testkit::forall(
            0xF022,
            256,
            |rng| {
                let mut frame = Vec::new();
                write_message(&mut frame, &variants[rng.below(variants.len())]).unwrap();
                match rng.below(4) {
                    // cut anywhere: mid-magic, mid-header, mid-payload
                    0 => frame.truncate(rng.below(frame.len() + 1)),
                    // flip 1–4 bits anywhere in the frame
                    1 => {
                        for _ in 0..=rng.below(4) {
                            let i = rng.below(frame.len());
                            frame[i] ^= 1 << rng.below(8);
                        }
                    }
                    // replace with seeded garbage (any magic/tag/length)
                    2 => {
                        let n = rng.below(64);
                        frame = (0..n).map(|_| rng.below(256) as u8).collect();
                    }
                    // keep a valid frame but lie in the length field
                    _ => {
                        let lie = (rng.next_u64() as u32).to_le_bytes();
                        frame[3..7].copy_from_slice(&lie);
                    }
                }
                frame
            },
            |frame| {
                let _ = read_message(&mut frame.as_slice());
                Ok(())
            },
        );
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(10),
            "hostile frames must fail fast, not by timeout"
        );
    }

    /// The seal/open pair: a sealed verb round-trips the wire and opens
    /// against the same credential/nonce with an advancing counter; each
    /// forgery axis (credential, nonce, counter lie, payload tamper, tag
    /// transplant, byte-identical replay) dies with the pinned typed
    /// error, MAC check strictly before the replay check.
    #[test]
    fn seal_open_roundtrip_and_forgeries() {
        let cred = [0x41u8; 32];
        let nonce = [0x42u8; 32];
        let verb = Message::AdminDrain { model: "alpha".into(), epoch: 0 };
        let sealed = seal_admin(&cred, &nonce, 1, &verb);
        // wire round-trip preserves the envelope bit-for-bit
        let mut buf = Vec::new();
        write_message(&mut buf, &sealed).unwrap();
        let got = read_message(&mut buf.as_slice()).unwrap();
        assert_eq!(got, sealed);
        // opens cleanly; counter advances
        let (counter, inner) = open_admin(&cred, &nonce, 0, &got).unwrap();
        assert_eq!(counter, 1);
        assert_eq!(inner, verb);
        // wrong credential → MAC failure
        let err = open_admin(&[0x99; 32], &nonce, 0, &sealed).unwrap_err();
        assert!(matches!(&err, Error::AdminAuth(m) if m.contains("MAC")), "{err}");
        // wrong session nonce (frame captured from another session)
        let err = open_admin(&cred, &[0x99; 32], 0, &sealed).unwrap_err();
        assert!(matches!(&err, Error::AdminAuth(m) if m.contains("MAC")), "{err}");
        // byte-identical replay: MAC valid, counter stale → typed replay
        let err = open_admin(&cred, &nonce, 1, &sealed).unwrap_err();
        assert!(matches!(&err, Error::AdminAuth(m) if m.contains("anti-replay")), "{err}");
        // reordered (lower) counter, freshly MACed → still the replay arm
        let old = seal_admin(&cred, &nonce, 3, &verb);
        let (c, _) = open_admin(&cred, &nonce, 0, &old).unwrap();
        assert_eq!(c, 3);
        let late = seal_admin(&cred, &nonce, 2, &verb);
        let err = open_admin(&cred, &nonce, 3, &late).unwrap_err();
        assert!(matches!(&err, Error::AdminAuth(m) if m.contains("anti-replay")), "{err}");
        // tampered payload: flip one bit in the inner bytes
        if let Message::AdminAuthed { counter, mac, inner_tag, mut inner } = sealed.clone()
        {
            inner[0] ^= 1;
            let bad = Message::AdminAuthed { counter, mac, inner_tag, inner };
            let err = open_admin(&cred, &nonce, 0, &bad).unwrap_err();
            assert!(matches!(&err, Error::AdminAuth(m) if m.contains("MAC")), "{err}");
        } else {
            unreachable!()
        }
        // tag transplant: same payload claimed as a different verb
        if let Message::AdminAuthed { counter, mac, inner, .. } = sealed.clone() {
            let bad = Message::AdminAuthed { counter, mac, inner_tag: 12, inner };
            let err = open_admin(&cred, &nonce, 0, &bad).unwrap_err();
            assert!(matches!(&err, Error::AdminAuth(m) if m.contains("MAC")), "{err}");
        } else {
            unreachable!()
        }
        // lying counter: the counter is MAC-covered, so bumping it is a
        // forgery (MAC arm), not a fresh frame
        if let Message::AdminAuthed { mac, inner_tag, inner, .. } = sealed.clone() {
            let bad = Message::AdminAuthed { counter: 9, mac, inner_tag, inner };
            let err = open_admin(&cred, &nonce, 0, &bad).unwrap_err();
            assert!(matches!(&err, Error::AdminAuth(m) if m.contains("MAC")), "{err}");
        } else {
            unreachable!()
        }
        // a non-envelope frame fed to open_admin is refused typed
        let err = open_admin(&cred, &nonce, 0, &Message::AdminStatus).unwrap_err();
        assert!(matches!(err, Error::AdminAuth(_)));
    }

    /// The v8 reply path: a sealed `AdminOk` opens against the request's
    /// counter; every forgery axis — cleartext downgrade, tampered
    /// detail, replayed earlier ack, reflected request envelope,
    /// cross-direction confusion — dies with its pinned typed error,
    /// MAC check strictly before the counter check.
    #[test]
    fn sealed_reply_roundtrip_and_forgeries() {
        let cred = [0x41u8; 32];
        let nonce = [0x42u8; 32];
        let ok = Message::AdminOk { detail: "drained alpha@0".into() };
        let reply = seal_admin_reply(&cred, &nonce, 5, &ok);
        // wire round-trip, then opens against the matching request counter
        let mut buf = Vec::new();
        write_message(&mut buf, &reply).unwrap();
        let got = read_message(&mut buf.as_slice()).unwrap();
        assert_eq!(open_admin_reply(&cred, &nonce, 5, &got).unwrap(), ok);
        // a cleartext AdminOk — the exact v5 hole — is refused typed
        let err = open_admin_reply(&cred, &nonce, 5, &ok).unwrap_err();
        assert!(
            matches!(&err, Error::AdminAuth(m) if m.contains("forged or downgraded")),
            "{err}"
        );
        // wrong credential / wrong session nonce → reply-MAC failure
        for (c, n) in [(&[0x99u8; 32], &nonce), (&cred, &[0x99u8; 32])] {
            let err = open_admin_reply(c, n, 5, &reply).unwrap_err();
            assert!(
                matches!(&err, Error::AdminAuth(m) if m.contains("reply MAC")),
                "{err}"
            );
        }
        // a replayed ack from an earlier verb: valid MAC for *its*
        // counter, refused as a reply replay (counter mismatch)
        let stale = seal_admin_reply(&cred, &nonce, 3, &ok);
        let err = open_admin_reply(&cred, &nonce, 5, &stale).unwrap_err();
        assert!(
            matches!(&err, Error::AdminAuth(m)
                if m.contains("anti-replay") && m.contains("reply counter 3")),
            "{err}"
        );
        // direction separation: a *request* envelope reflected back at
        // the client never verifies as a reply, even at the matching
        // counter — and a reply never opens as a request
        let request = seal_admin(&cred, &nonce, 5, &ok);
        let err = open_admin_reply(&cred, &nonce, 5, &request).unwrap_err();
        assert!(
            matches!(&err, Error::AdminAuth(m) if m.contains("reply MAC")),
            "{err}"
        );
        let err = open_admin(&cred, &nonce, 0, &reply).unwrap_err();
        assert!(matches!(&err, Error::AdminAuth(m) if m.contains("MAC")), "{err}");
        // tampered detail string inside the sealed reply
        if let Message::AdminAuthed { counter, mac, inner_tag, mut inner } = reply.clone() {
            inner[5] ^= 1;
            let bad = Message::AdminAuthed { counter, mac, inner_tag, inner };
            let err = open_admin_reply(&cred, &nonce, 5, &bad).unwrap_err();
            assert!(
                matches!(&err, Error::AdminAuth(m) if m.contains("reply MAC")),
                "{err}"
            );
        } else {
            unreachable!()
        }
        // a sealed Fault reply (typed refusal) opens the same way
        let fault = Message::Fault {
            of: FAULT_SESSION,
            fault: Fault::Generic { msg: "no epoch 7".into() },
        };
        let sealed_fault = seal_admin_reply(&cred, &nonce, 6, &fault);
        assert_eq!(open_admin_reply(&cred, &nonce, 6, &sealed_fault).unwrap(), fault);
    }

    /// Valid MAC over garbage inner bytes: authentication succeeds, the
    /// inner decode then fails with its own typed error (never a panic).
    #[test]
    fn authenticated_garbage_inner_fails_typed() {
        let cred = [1u8; 32];
        let nonce = [2u8; 32];
        // garbage after the MAC, but *covered* by it: tag 11 with junk
        let inner = vec![0xFFu8; 9];
        let mac = admin_mac(&cred, &nonce, 1, DIR_REQUEST, 11, &inner);
        let frame = Message::AdminAuthed { counter: 1, mac, inner_tag: 11, inner };
        match open_admin(&cred, &nonce, 0, &frame) {
            Err(Error::Protocol(_) | Error::Io(_)) => {}
            other => panic!("expected a typed decode error, got {other:?}"),
        }
        // unknown inner tag, correctly MACed
        let mac = admin_mac(&cred, &nonce, 1, DIR_REQUEST, 200, b"");
        let frame =
            Message::AdminAuthed { counter: 1, mac, inner_tag: 200, inner: Vec::new() };
        match open_admin(&cred, &nonce, 0, &frame) {
            Err(Error::Protocol(m)) => assert!(m.contains("unknown message tag"), "{m}"),
            other => panic!("expected unknown-tag error, got {other:?}"),
        }
    }

    /// Satellite: seeded fuzz over the *authenticated* admin plane.
    /// Sealed frames from every admin verb are mutated — truncated,
    /// MAC-bit-flipped, given lying counters, or fed trailing garbage
    /// after the MAC field — then pushed through `read_message` +
    /// `open_admin`. The pipeline must never panic, and any mutated
    /// frame that still decodes must be refused typed by `open_admin`
    /// (only byte-identical frames may authenticate).
    #[test]
    fn fuzz_authed_admin_frames_fail_typed() {
        let cred = [0xA5u8; 32];
        let nonce = [0x5Au8; 32];
        let verbs = [
            Message::AdminRegister {
                model: "alpha".into(),
                vault_path: "/tmp/alpha.key".into(),
                kappa: 16,
                seed: 11,
                trunk_seed: 11,
            },
            Message::AdminDrain { model: "alpha".into(), epoch: 0 },
            Message::AdminRetire { model: "alpha".into(), epoch: 0 },
            Message::AdminStatus,
        ];
        crate::testkit::forall(
            0xAD71,
            256,
            |rng| {
                let counter = 1 + rng.below(1000) as u64;
                let sealed =
                    seal_admin(&cred, &nonce, counter, &verbs[rng.below(verbs.len())]);
                let mut frame = Vec::new();
                write_message(&mut frame, &sealed).unwrap();
                let mutated = rng.below(4) != 0; // 1/4 pass through intact
                if mutated {
                    match rng.below(4) {
                        // truncate anywhere (header, envelope, inner)
                        0 => frame.truncate(rng.below(frame.len())),
                        // flip a bit anywhere: MAC bytes, counter,
                        // inner-tag, inner payload, length fields
                        1 => {
                            let i = rng.below(frame.len());
                            frame[i] ^= 1 << rng.below(8);
                        }
                        // lie about the counter (MAC-covered, so forged)
                        2 => {
                            let lie = rng.next_u64().to_le_bytes();
                            frame[7..15].copy_from_slice(&lie);
                        }
                        // garbage appended after the MAC'd envelope
                        _ => {
                            let extra = 1 + rng.below(16);
                            let new_len =
                                (frame.len() - 7 + extra) as u32;
                            frame[3..7].copy_from_slice(&new_len.to_le_bytes());
                            for _ in 0..extra {
                                frame.push(rng.below(256) as u8);
                            }
                        }
                    }
                }
                (frame, mutated, counter)
            },
            |(frame, mutated, counter)| {
                match read_message(&mut frame.as_slice()) {
                    Err(_) => Ok(()), // typed decode refusal is fine
                    Ok(msg) => match open_admin(&cred, &nonce, 0, &msg) {
                        Ok((c, _)) => {
                            // every envelope byte is either framing
                            // (decode-checked) or MAC-covered, so only
                            // untouched frames may authenticate
                            if *mutated {
                                Err(format!(
                                    "mutated frame authenticated (counter {c})"
                                ))
                            } else if c != *counter {
                                Err(format!("counter {c}, sealed {counter}"))
                            } else {
                                Ok(())
                            }
                        }
                        Err(Error::AdminAuth(_) | Error::Protocol(_) | Error::Io(_)) => {
                            Ok(())
                        }
                        Err(e) => Err(format!("unexpected error type: {e}")),
                    },
                }
            },
        );
    }

    /// An element count that does not overflow but exceeds the actual
    /// payload must also fail from the cursor bounds check.
    #[test]
    fn element_count_beyond_payload_rejected() {
        let mut payload = Vec::new();
        put_u64(&mut payload, 1);
        put_u32(&mut payload, 1_000_000); // logits: claims 4 MB of f32s
        let mut frame = Vec::new();
        frame.extend_from_slice(b"ML");
        frame.push(7); // InferResponse
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        match read_message(&mut frame.as_slice()) {
            Err(Error::Protocol(m)) => {
                assert!(m.contains("truncated"), "unexpected message: {m}")
            }
            other => panic!("expected protocol error, got {other:?}"),
        }
    }
}
