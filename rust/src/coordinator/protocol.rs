//! Wire protocol: length-prefixed binary frames over any Read/Write.
//!
//! Frame layout: `b"ML"` | u8 msg-tag | u32 payload-len | payload.
//! Tensors encode as u8 ndim | u32 dims… | f32-LE data. The protocol
//! carries **only** the HBC-visible surface (§4.1): morphed rows T^r, the
//! Aug-Conv matrix C^ac, first-layer weights (public direction:
//! developer → provider), and inference traffic. Keys never appear here.

use crate::tensor::Tensor;
use crate::{Error, Geometry, Result};
use std::io::{Read, Write};

const FRAME_MAGIC: [u8; 2] = *b"ML";
/// Guard against hostile / corrupt length fields (C^ac for CIFAR-VGG16 is
/// ~805 MB; cap frames at 1 GiB).
const MAX_PAYLOAD: usize = 1 << 30;

/// Protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Session handshake (provider → developer).
    Hello {
        geometry: Geometry,
        kappa: usize,
        fingerprint: String,
        num_batches: u32,
        batch_size: u32,
    },
    /// Developer's pre-trained first layer (developer → provider).
    Conv1Weights { w1: Tensor, b1: Vec<f32> },
    /// The Aug-Conv layer (provider → developer).
    AugConv { matrix: Tensor, bias: Vec<f32> },
    /// One morphed training batch (provider → developer).
    MorphedBatch { id: u64, rows: Tensor, labels: Vec<i32> },
    /// End of training-data stream.
    EndOfData,
    /// Serving: one morphed row in (client → developer).
    InferRequest { id: u64, row: Tensor },
    /// Serving: logits out.
    InferResponse { id: u64, logits: Vec<f32> },
    /// Generic acknowledgement.
    Ack { of: u64 },
    /// Fatal error notification.
    Fault { msg: String },
}

impl Message {
    fn tag(&self) -> u8 {
        match self {
            Message::Hello { .. } => 1,
            Message::Conv1Weights { .. } => 2,
            Message::AugConv { .. } => 3,
            Message::MorphedBatch { .. } => 4,
            Message::EndOfData => 5,
            Message::InferRequest { .. } => 6,
            Message::InferResponse { .. } => 7,
            Message::Ack { .. } => 8,
            Message::Fault { .. } => 9,
        }
    }
}

// ---------------------------------------------------------------------------
// primitive encoders
// ---------------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    out.push(t.ndim() as u8);
    for &d in t.shape() {
        put_u32(out, d as u32);
    }
    for &v in t.data() {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    put_u32(out, v.len() as u32);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_i32s(out: &mut Vec<u8>, v: &[i32]) {
    put_u32(out, v.len() as u32);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            return Err(Error::Protocol("truncated payload".into()));
        }
        let s = &self.b[self.i..self.i + n];
        self.i = self.i + n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| Error::Protocol("non-utf8 string".into()))
    }

    fn tensor(&mut self) -> Result<Tensor> {
        let nd = self.u8()? as usize;
        if nd > 8 {
            return Err(Error::Protocol(format!("tensor rank {nd} too large")));
        }
        let mut shape = Vec::with_capacity(nd);
        for _ in 0..nd {
            shape.push(self.u32()? as usize);
        }
        let numel: usize = shape.iter().product();
        let raw = self.take(numel * 4)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Tensor::new(&shape, data)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn done(&self) -> Result<()> {
        if self.i == self.b.len() {
            Ok(())
        } else {
            Err(Error::Protocol("trailing bytes in payload".into()))
        }
    }
}

// ---------------------------------------------------------------------------
// message codec
// ---------------------------------------------------------------------------

/// Encode a message payload (without the frame header).
pub fn encode(msg: &Message) -> Vec<u8> {
    let mut out = Vec::new();
    match msg {
        Message::Hello { geometry, kappa, fingerprint, num_batches, batch_size } => {
            put_u32(&mut out, geometry.alpha as u32);
            put_u32(&mut out, geometry.m as u32);
            put_u32(&mut out, geometry.beta as u32);
            put_u32(&mut out, geometry.p as u32);
            put_u32(&mut out, *kappa as u32);
            put_str(&mut out, fingerprint);
            put_u32(&mut out, *num_batches);
            put_u32(&mut out, *batch_size);
        }
        Message::Conv1Weights { w1, b1 } => {
            put_tensor(&mut out, w1);
            put_f32s(&mut out, b1);
        }
        Message::AugConv { matrix, bias } => {
            put_tensor(&mut out, matrix);
            put_f32s(&mut out, bias);
        }
        Message::MorphedBatch { id, rows, labels } => {
            put_u64(&mut out, *id);
            put_tensor(&mut out, rows);
            put_i32s(&mut out, labels);
        }
        Message::EndOfData => {}
        Message::InferRequest { id, row } => {
            put_u64(&mut out, *id);
            put_tensor(&mut out, row);
        }
        Message::InferResponse { id, logits } => {
            put_u64(&mut out, *id);
            put_f32s(&mut out, logits);
        }
        Message::Ack { of } => put_u64(&mut out, *of),
        Message::Fault { msg } => put_str(&mut out, msg),
    }
    out
}

/// Decode a message payload given its tag.
pub fn decode(tag: u8, payload: &[u8]) -> Result<Message> {
    let mut c = Cursor { b: payload, i: 0 };
    let msg = match tag {
        1 => {
            let alpha = c.u32()? as usize;
            let m = c.u32()? as usize;
            let beta = c.u32()? as usize;
            let p = c.u32()? as usize;
            Message::Hello {
                geometry: Geometry::new(alpha, m, beta, p),
                kappa: c.u32()? as usize,
                fingerprint: c.str()?,
                num_batches: c.u32()?,
                batch_size: c.u32()?,
            }
        }
        2 => Message::Conv1Weights { w1: c.tensor()?, b1: c.f32s()? },
        3 => Message::AugConv { matrix: c.tensor()?, bias: c.f32s()? },
        4 => Message::MorphedBatch { id: c.u64()?, rows: c.tensor()?, labels: c.i32s()? },
        5 => Message::EndOfData,
        6 => Message::InferRequest { id: c.u64()?, row: c.tensor()? },
        7 => Message::InferResponse { id: c.u64()?, logits: c.f32s()? },
        8 => Message::Ack { of: c.u64()? },
        9 => Message::Fault { msg: c.str()? },
        t => return Err(Error::Protocol(format!("unknown message tag {t}"))),
    };
    c.done()?;
    Ok(msg)
}

/// Write one framed message.
pub fn write_message<W: Write>(w: &mut W, msg: &Message) -> Result<usize> {
    let payload = encode(msg);
    if payload.len() > MAX_PAYLOAD {
        return Err(Error::Protocol(format!("payload {} too large", payload.len())));
    }
    w.write_all(&FRAME_MAGIC)?;
    w.write_all(&[msg.tag()])?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&payload)?;
    w.flush()?;
    Ok(7 + payload.len())
}

/// Read one framed message (blocking).
pub fn read_message<R: Read>(r: &mut R) -> Result<Message> {
    let mut head = [0u8; 7];
    r.read_exact(&mut head)?;
    if head[0..2] != FRAME_MAGIC {
        return Err(Error::Protocol("bad frame magic".into()));
    }
    let tag = head[2];
    let len = u32::from_le_bytes(head[3..7].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        return Err(Error::Protocol(format!("frame length {len} too large")));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    decode(tag, &payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn roundtrip(msg: Message) {
        let mut buf = Vec::new();
        let n = write_message(&mut buf, &msg).unwrap();
        assert_eq!(n, buf.len());
        let mut slice = buf.as_slice();
        let got = read_message(&mut slice).unwrap();
        assert_eq!(got, msg);
    }

    #[test]
    fn roundtrip_all_variants() {
        let mut rng = Rng::new(0);
        roundtrip(Message::Hello {
            geometry: Geometry::SMALL,
            kappa: 16,
            fingerprint: "abc123".into(),
            num_batches: 10,
            batch_size: 64,
        });
        roundtrip(Message::Conv1Weights {
            w1: Tensor::new(&[2, 3, 3, 3], rng.normal_vec(54, 1.0)).unwrap(),
            b1: vec![0.5, -0.5],
        });
        roundtrip(Message::AugConv {
            matrix: Tensor::new(&[4, 8], rng.normal_vec(32, 1.0)).unwrap(),
            bias: vec![1.0; 8],
        });
        roundtrip(Message::MorphedBatch {
            id: 7,
            rows: Tensor::new(&[2, 5], rng.normal_vec(10, 1.0)).unwrap(),
            labels: vec![3, 9],
        });
        roundtrip(Message::EndOfData);
        roundtrip(Message::InferRequest {
            id: 99,
            row: Tensor::new(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]).unwrap(),
        });
        roundtrip(Message::InferResponse { id: 99, logits: vec![0.1, 0.9] });
        roundtrip(Message::Ack { of: 42 });
        roundtrip(Message::Fault { msg: "boom".into() });
    }

    #[test]
    fn property_roundtrip_random_batches() {
        crate::testkit::forall(
            77,
            16,
            |rng| {
                let b = 1 + rng.below(8);
                let d = 1 + rng.below(32);
                let rows = crate::testkit::gen::tensor(rng, &[b, d], 1.0);
                let labels = (0..b).map(|_| rng.below(10) as i32).collect::<Vec<_>>();
                Message::MorphedBatch { id: rng.next_u64(), rows, labels }
            },
            |msg| {
                let mut buf = Vec::new();
                write_message(&mut buf, msg).map_err(|e| e.to_string())?;
                let got = read_message(&mut buf.as_slice()).map_err(|e| e.to_string())?;
                if &got == msg {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }

    #[test]
    fn corrupt_frames_rejected() {
        let mut buf = Vec::new();
        write_message(&mut buf, &Message::Ack { of: 1 }).unwrap();
        // bad magic
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(read_message(&mut bad.as_slice()).is_err());
        // bad tag
        let mut bad = buf.clone();
        bad[2] = 200;
        assert!(read_message(&mut bad.as_slice()).is_err());
        // truncated
        assert!(read_message(&mut &buf[..5]).is_err());
        // trailing bytes in payload
        let mut bad = buf.clone();
        let len = u32::from_le_bytes(bad[3..7].try_into().unwrap()) + 1;
        bad[3..7].copy_from_slice(&len.to_le_bytes());
        bad.push(0);
        assert!(read_message(&mut bad.as_slice()).is_err());
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut head = Vec::new();
        head.extend_from_slice(b"ML");
        head.push(8);
        head.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(read_message(&mut head.as_slice()).is_err());
    }
}
